#!/usr/bin/env python3
"""Markdown link checker for the docs tree — stdlib only, no network.

  python tools/check_links.py README.md docs/

Checks every inline link/image ``[text](target)`` in the given markdown
files (directories are scanned for ``*.md``):

  * relative file targets must exist (resolved against the source file);
  * ``#anchors`` — bare or after a relative .md target — must match a
    heading in the target file, using GitHub's slug rules (lowercase,
    punctuation stripped, spaces to hyphens, ``-N`` suffix for dups);
  * absolute URLs (http/https/mailto) are skipped: CI must not flake on
    the outside world, and the README badge is a placeholder.

Exit 0 when clean, 1 with a per-link report otherwise.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$")
CODE_FENCE_RE = re.compile(r"^(```|~~~)")
EXTERNAL = ("http://", "https://", "mailto:")


def github_slug(heading: str, seen: dict) -> str:
    """GitHub's anchor slug: strip markdown emphasis/code, lowercase,
    drop everything but word chars/spaces/hyphens, spaces -> hyphens."""
    text = re.sub(r"[*_`]", "", heading.strip())
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)  # linked headings
    slug = re.sub(r"[^\w\- ]", "", text.lower(), flags=re.UNICODE)
    slug = slug.replace(" ", "-")
    n = seen.get(slug, 0)
    seen[slug] = n + 1
    return slug if n == 0 else f"{slug}-{n}"


def markdown_lines(path: Path):
    """Lines with fenced code blocks blanked (links in code are examples,
    not navigation)."""
    in_fence = False
    for line in path.read_text(encoding="utf-8").splitlines():
        if CODE_FENCE_RE.match(line.strip()):
            in_fence = not in_fence
            yield ""
            continue
        yield "" if in_fence else line


def anchors_of(path: Path) -> set:
    seen: dict = {}
    out = set()
    for line in markdown_lines(path):
        m = HEADING_RE.match(line)
        if m:
            out.add(github_slug(m.group(1), seen))
    return out


def check_file(path: Path, repo_root: Path) -> list:
    errors = []
    for lineno, line in enumerate(markdown_lines(path), 1):
        for m in LINK_RE.finditer(line):
            target = m.group(1)
            if target.startswith(EXTERNAL):
                continue
            ref, _, anchor = target.partition("#")
            dest = path if not ref else (path.parent / ref).resolve()
            if ref and not dest.exists():
                errors.append(f"{path}:{lineno}: broken link -> {target}")
                continue
            if anchor and dest.suffix == ".md":
                if anchor not in anchors_of(dest):
                    errors.append(f"{path}:{lineno}: missing anchor -> {target}")
            if ref and repo_root not in dest.parents and dest != repo_root:
                errors.append(f"{path}:{lineno}: link escapes the repo -> {target}")
    return errors


def main(argv) -> int:
    if not argv:
        argv = ["README.md", "docs"]
    repo_root = Path(__file__).resolve().parent.parent
    files = []
    for arg in argv:
        p = Path(arg)
        files.extend(sorted(p.rglob("*.md")) if p.is_dir() else [p])
    errors = []
    for f in files:
        errors.extend(check_file(f.resolve(), repo_root))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} markdown files: "
          f"{'OK' if not errors else f'{len(errors)} broken links'}")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
