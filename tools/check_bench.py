#!/usr/bin/env python3
"""Validate committed ``BENCH_<area>.json`` files against the envelope
schema — the CI tripwire that keeps the perf trajectory machine-readable.

Usage::

    python tools/check_bench.py [FILE...]
    python tools/check_bench.py --diff NEW [COMMITTED]

With no arguments, validates every ``BENCH_*.json`` at the repo root.
Exit 0 when every file is schema-valid, 1 with a per-file error report
otherwise (every violation listed, not just the first).

``--diff`` compares the *deterministic* columns of a freshly
regenerated envelope against a committed one: ``results`` arms are
matched by ``(overload, scheduler, variant)`` and the clock-domain
metrics (:data:`DIFF_KEYS` — request counts, completion/timeout/shed
tallies, TTFT percentiles in engine steps, SLO-met and generated token
counts, peak pages) must agree exactly; ``entries`` rows are matched by
``name`` and their ``deterministic`` sub-objects (analytic roofline
columns in BENCH_kernels.json) must agree exactly. Wall-clock columns
(``wall_s``, ``tokens_per_s``, ITL, ``us_per_call``) are
machine-dependent and deliberately ignored.
``COMMITTED`` defaults to the repo-root file with the regenerated
envelope's name (``BENCH_<area>.json``). This is the CI
regenerate-and-diff step: a code change that silently moves the
committed serving numbers fails here instead of landing as stale data.

Deliberately dependency-free: the schema module
(src/repro/bench/schema.py) is stdlib-only at import time and is loaded
here by file path, so this check runs in a bare interpreter without
jax or the ``repro`` package installed — a milliseconds-long CI step.
"""
from __future__ import annotations

import importlib.util
import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
SCHEMA_PATH = REPO_ROOT / "src" / "repro" / "bench" / "schema.py"

# the deterministic (engine-step clock domain) metric columns a
# regenerated envelope must reproduce exactly; everything wall-clock
# (wall_s, tokens_per_s, goodput_tokens_per_s, itl_*) varies by machine.
# The kv_transfer_* ledger only appears on disaggregated arms — absent
# keys compare None == None, so plain arms pass through unchanged.
DIFF_KEYS = (
    "requests",
    "completed",
    "timed_out",
    "shed",
    "ttft_p50_steps",
    "ttft_p99_steps",
    "slo_met_tokens",
    "generated_tokens",
    "peak_pages",
    "kv_transfer_pages",
    "kv_transfer_bytes",
    "kv_transfer_wire_bytes",
    "prefill_pool_peak_pages",
    # streaming ledger (streaming-bench arms only; absent elsewhere)
    "stream_evictions",
    "stream_demotions",
    "cold_page_bytes",
)


def _load_schema():
    spec = importlib.util.spec_from_file_location("bench_schema", SCHEMA_PATH)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _load_doc(path: Path):
    return json.loads(path.read_text())


def _arm_key(arm: dict) -> tuple:
    # variant is optional (the speculative bench's baseline/speculative
    # axis); plain serving arms key on (overload, scheduler) alone
    return (arm.get("overload"), arm.get("scheduler"),
            arm.get("variant", ""))


def diff_envelopes(new_doc: dict, old_doc: dict) -> list[str]:
    """Mismatch report between two envelopes' deterministic columns
    (empty list = they agree). Arms must match one-to-one."""
    errs: list[str] = []
    if new_doc.get("area") != old_doc.get("area"):
        errs.append(f"area: regenerated {new_doc.get('area')!r} != "
                    f"committed {old_doc.get('area')!r}")
    new_arms = {_arm_key(a): a for a in new_doc.get("results", [])}
    old_arms = {_arm_key(a): a for a in old_doc.get("results", [])}

    def _name(key: tuple) -> str:
        base = f"{key[0]:g}x/{key[1]}"
        return f"{base}/{key[2]}" if key[2] else base

    for key in sorted(set(old_arms) - set(new_arms), key=str):
        errs.append(f"arm {_name(key)}: in committed file only")
    for key in sorted(set(new_arms) - set(old_arms), key=str):
        errs.append(f"arm {_name(key)}: in regenerated file only")
    for key in sorted(set(new_arms) & set(old_arms), key=str):
        new_m = new_arms[key].get("metrics", {})
        old_m = old_arms[key].get("metrics", {})
        for col in DIFF_KEYS:
            if new_m.get(col) != old_m.get(col):
                errs.append(f"arm {_name(key)}: {col} regenerated "
                            f"{new_m.get(col)!r} != committed "
                            f"{old_m.get(col)!r}")

    # entries rows: matched by name, "deterministic" sub-object exact
    # (wall-clock keys like us_per_call live outside it and are ignored)
    new_rows = {e.get("name"): e for e in new_doc.get("entries", [])
                if e.get("name")}
    old_rows = {e.get("name"): e for e in old_doc.get("entries", [])
                if e.get("name")}
    for name in sorted(set(old_rows) - set(new_rows)):
        errs.append(f"entry {name}: in committed file only")
    for name in sorted(set(new_rows) - set(old_rows)):
        errs.append(f"entry {name}: in regenerated file only")
    for name in sorted(set(new_rows) & set(old_rows)):
        new_d = new_rows[name].get("deterministic", {})
        old_d = old_rows[name].get("deterministic", {})
        if new_d == old_d:
            continue
        cols = sorted(set(new_d) | set(old_d))
        for col in cols:
            if new_d.get(col) != old_d.get(col):
                errs.append(f"entry {name}: {col} regenerated "
                            f"{new_d.get(col)!r} != committed "
                            f"{old_d.get(col)!r}")
    return errs


def run_diff(argv: list[str]) -> int:
    if not 1 <= len(argv) <= 2:
        print("usage: check_bench.py --diff NEW [COMMITTED]",
              file=sys.stderr)
        return 2
    new_path = Path(argv[0])
    old_path = Path(argv[1]) if len(argv) == 2 else REPO_ROOT / new_path.name
    schema = _load_schema()
    docs = {}
    for path in (new_path, old_path):
        try:
            docs[path] = _load_doc(path)
        except (OSError, json.JSONDecodeError) as exc:
            print(f"FAIL {path}: {exc}")
            return 1
        errors = schema.validate_bench(docs[path])
        if errors:
            print(f"FAIL {path}:")
            for err in errors:
                print(f"  - {err}")
            return 1
    errors = diff_envelopes(docs[new_path], docs[old_path])
    if errors:
        print(f"FAIL {old_path} is stale vs regenerated {new_path}:")
        for err in errors:
            print(f"  - {err}")
        print("regenerate the committed envelope (benchmarks/run.py "
              "--spec-from) and commit the result")
        return 1
    n = len(docs[new_path].get("results", []))
    rows = len(docs[new_path].get("entries", []))
    print(f"ok   {old_path} matches {new_path} on the deterministic "
          f"columns ({n} arms, {rows} entries)")
    return 0


def main(argv: list[str]) -> int:
    if argv and argv[0] == "--diff":
        return run_diff(argv[1:])
    schema = _load_schema()
    paths = [Path(a) for a in argv] or sorted(REPO_ROOT.glob("BENCH_*.json"))
    if not paths:
        print("check_bench: no BENCH_*.json files found", file=sys.stderr)
        return 1
    failed = False
    for path in paths:
        try:
            doc = _load_doc(path)
        except (OSError, json.JSONDecodeError) as exc:
            print(f"FAIL {path}: {exc}")
            failed = True
            continue
        errors = schema.validate_bench(doc)
        if errors:
            failed = True
            print(f"FAIL {path}:")
            for err in errors:
                print(f"  - {err}")
        else:
            arms = len(doc.get("results", []))
            rows = len(doc.get("entries", []))
            print(f"ok   {path} (schema_version {doc['schema_version']}, "
                  f"{arms} arms, {rows} entries)")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
