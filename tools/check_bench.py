#!/usr/bin/env python3
"""Validate committed ``BENCH_<area>.json`` files against the envelope
schema — the CI tripwire that keeps the perf trajectory machine-readable.

Usage::

    python tools/check_bench.py [FILE...]

With no arguments, validates every ``BENCH_*.json`` at the repo root.
Exit 0 when every file is schema-valid, 1 with a per-file error report
otherwise (every violation listed, not just the first).

Deliberately dependency-free: the schema module
(src/repro/bench/schema.py) is stdlib-only at import time and is loaded
here by file path, so this check runs in a bare interpreter without
jax or the ``repro`` package installed — a milliseconds-long CI step.
"""
from __future__ import annotations

import importlib.util
import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
SCHEMA_PATH = REPO_ROOT / "src" / "repro" / "bench" / "schema.py"


def _load_schema():
    spec = importlib.util.spec_from_file_location("bench_schema", SCHEMA_PATH)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def main(argv: list[str]) -> int:
    schema = _load_schema()
    paths = [Path(a) for a in argv] or sorted(REPO_ROOT.glob("BENCH_*.json"))
    if not paths:
        print("check_bench: no BENCH_*.json files found", file=sys.stderr)
        return 1
    failed = False
    for path in paths:
        try:
            doc = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError) as exc:
            print(f"FAIL {path}: {exc}")
            failed = True
            continue
        errors = schema.validate_bench(doc)
        if errors:
            failed = True
            print(f"FAIL {path}:")
            for err in errors:
                print(f"  - {err}")
        else:
            arms = len(doc.get("results", []))
            rows = len(doc.get("entries", []))
            print(f"ok   {path} (schema_version {doc['schema_version']}, "
                  f"{arms} arms, {rows} entries)")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
