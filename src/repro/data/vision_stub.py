"""Modality frontend STUBS per the assignment: [vlm]/[audio] archs get
precomputed patch/frame embeddings; the transformer backbone is real.
"""
from __future__ import annotations

import numpy as np


def vision_stub_embeddings(batch: int, n_patches: int, d_model: int, seed: int = 0):
    """Stand-in for the Qwen2-VL vision tower output (dynamic-resolution
    patch embeddings). Deterministic, unit-variance."""
    rng = np.random.default_rng(seed)
    return rng.standard_normal((batch, n_patches, d_model)).astype(np.float32) * (d_model ** -0.5)


def audio_frame_stub(batch: int, n_frames: int, d_model: int, seed: int = 0):
    """Stand-in for Whisper's conv1d+GELU frontend over log-mel frames
    (30 s -> 1500 frames)."""
    rng = np.random.default_rng(seed + 1)
    return rng.standard_normal((batch, n_frames, d_model)).astype(np.float32) * (d_model ** -0.5)
