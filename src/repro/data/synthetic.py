"""Deterministic synthetic LM data: a learnable Markov-ish token stream.

Offline container => no real corpus. The stream has genuine structure
(low-entropy bigram transitions + periodic motifs) so cross-entropy has
a floor well below uniform and convergence curves mean something —
needed by the rank-sweep reproduction (paper Table 3's qualitative
claims) and the hillclimb integration tests.

Host-sharded: each host materializes only its slice of the global batch
(data-parallel contract at 1000+ nodes).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np


@dataclasses.dataclass
class SyntheticLMDataset:
    vocab: int
    seq_len: int
    seed: int = 0
    branching: int = 4      # out-degree of the bigram graph (entropy knob)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # sparse deterministic bigram table: token t -> one of `branching`
        # successors, chosen by a position-dependent selector
        self.successors = rng.integers(0, self.vocab, size=(self.vocab, self.branching))

    def sequence(self, idx: int) -> np.ndarray:
        """Deterministic sequence for global index idx (reproducible
        across restarts — checkpoint resume re-generates identically)."""
        rng = np.random.default_rng((self.seed, idx))
        toks = np.empty(self.seq_len + 1, dtype=np.int32)
        toks[0] = rng.integers(0, self.vocab)
        sel = rng.integers(0, self.branching, size=self.seq_len)
        for i in range(self.seq_len):
            toks[i + 1] = self.successors[toks[i], sel[i]]
        return toks

    def batch(self, step: int, batch_size: int, shard: int = 0, num_shards: int = 1):
        """Global batch row i lives on shard i % num_shards. Returns this
        shard's (tokens, labels) of shape (batch_size/num_shards, seq)."""
        assert batch_size % num_shards == 0
        local = batch_size // num_shards
        rows = [self.sequence(step * batch_size + shard * local + i) for i in range(local)]
        arr = np.stack(rows)
        return arr[:, :-1], arr[:, 1:]


def make_batch_iterator(ds: SyntheticLMDataset, batch_size: int,
                        start_step: int = 0, shard: int = 0, num_shards: int = 1
                        ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    step = start_step
    while True:
        yield ds.batch(step, batch_size, shard, num_shards)
        step += 1
