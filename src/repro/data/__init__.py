from repro.data.synthetic import SyntheticLMDataset, make_batch_iterator
from repro.data.vision_stub import vision_stub_embeddings, audio_frame_stub

__all__ = [
    "SyntheticLMDataset",
    "make_batch_iterator",
    "vision_stub_embeddings",
    "audio_frame_stub",
]
