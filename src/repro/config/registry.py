"""Architecture registry: maps assigned arch ids to config modules."""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "qwen2-vl-72b",
    "jamba-v0.1-52b",
    "qwen1.5-4b",
    "llama3.2-1b",
    "granite-3-2b",
    "qwen1.5-0.5b",
    "whisper-medium",
    "deepseek-v3-671b",
    "deepseek-v2-236b",
    "xlstm-1.3b",
    # the paper's own experiment configs
    "smollm2-1.7b",
    "smollm2-135m",
    "llama-70b-sct",
]


def _module_name(arch_id: str) -> str:
    return "repro.configs." + arch_id.replace("-", "_").replace(".", "p")


def get_config(arch_id: str, reduced: bool = False):
    mod = importlib.import_module(_module_name(arch_id))
    return mod.REDUCED if reduced else mod.CONFIG


def list_archs():
    return list(ARCH_IDS)
