from repro.config.model_config import ModelConfig, SCTConfig
from repro.config.shapes import SHAPES, ShapeSpec, input_specs, shape_applicable
from repro.config.registry import get_config, list_archs, ARCH_IDS

__all__ = [
    "ModelConfig",
    "SCTConfig",
    "SHAPES",
    "ShapeSpec",
    "input_specs",
    "shape_applicable",
    "get_config",
    "list_archs",
    "ARCH_IDS",
]
