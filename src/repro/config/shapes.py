"""Assigned input shapes and ShapeDtypeStruct factories for the dry-run.

Four shapes per LM arch (assignment block):
  train_4k     seq 4,096   global_batch 256   -> train_step
  prefill_32k  seq 32,768  global_batch 32    -> serve prefill
  decode_32k   seq 32,768  global_batch 128   -> serve_step (1 new token,
                                                 KV cache of seq_len)
  long_500k    seq 524,288 global_batch 1     -> serve_step; ONLY for
                                                 sub-quadratic archs

``input_specs`` returns weak-type-correct ShapeDtypeStructs — no device
allocation, exactly what jit(...).lower(**specs) needs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.config.model_config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "skip(full-attention): 500k decode needs sub-quadratic arch"
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        specs = {
            "tokens": _sds((b, s), jnp.int32),
            "labels": _sds((b, s), jnp.int32),
        }
        if cfg.family == "encdec":
            specs["encoder_frames"] = _sds((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": _sds((b, s), jnp.int32)}
        if cfg.family == "encdec":
            specs["encoder_frames"] = _sds((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        return specs
    if shape.kind == "decode":
        # one new token against a cache of s tokens
        from repro.models.model import decode_state_specs

        specs = {
            "tokens": _sds((b, 1), jnp.int32),
            "cache_len": _sds((), jnp.int32),
            "state": decode_state_specs(cfg, batch=b, max_seq=s),
        }
        if cfg.family == "encdec":
            specs["encoder_out"] = _sds((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        return specs
    raise ValueError(shape.kind)
