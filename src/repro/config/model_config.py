"""ModelConfig: one dataclass describing every assigned architecture,
plus the SCT (paper technique) settings.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class SCTConfig:
    """Paper technique settings (core/). Defaults are paper-faithful:
    spectral MLP, dense attention, QR retraction every step."""
    spectral_mlp: bool = True
    rank: int = 128                      # paper's Pareto-optimal rank
    spectral_attention: bool = False     # paper S5: future work; our option
    spectral_mamba: bool = False         # jamba mixer projections option
    retraction: str = "qr"               # qr | cholesky_qr2 | cayley
    retract_every: int = 1               # paper: every step
    energy: Optional[float] = None       # e.g. 0.95 -> rank from energy (S4.4)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                          # dense_lm | moe_lm | hybrid | ssm_lm | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                    # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope: str = "rope"                   # rope | mrope | none
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = (16, 24, 24)
    act: str = "swiglu"                  # swiglu | gelu
    norm: str = "rmsnorm"                # rmsnorm | layernorm
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0          # deepseek: leading dense MLP layers
    moe_every: int = 1                   # jamba: MoE on every 2nd layer
    moe_norm_topk: bool = True
    aux_loss_coef: float = 0.001
    capacity_factor: float = 1.25

    # --- MLA (deepseek) ---
    attention: str = "gqa"               # gqa | mla
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- hybrid (jamba) ---
    attn_every: int = 0                  # 0 -> all layers attention; 8 -> 1-in-8
    attn_offset: int = 4                 # position of the attn layer in the period
    mamba_expand: int = 2
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_dt_rank: int = 0               # 0 -> ceil(d_model / 16)

    # --- xlstm ---
    slstm_every: int = 0                 # 0 -> no sLSTM; 8 -> 1-in-8 layers
    slstm_offset: int = 7

    # --- encdec (whisper) ---
    n_encoder_layers: int = 0
    encoder_seq: int = 1500              # whisper 30s -> 1500 frames (stubbed)

    # --- SCT ---
    sct: SCTConfig = dataclasses.field(default_factory=SCTConfig)

    # --- numerics / runtime ---
    dtype: str = "bfloat16"              # compute dtype (params fp32 master)
    remat: bool = True
    use_pallas: bool = False
    max_seq: int = 4096
    # sequence-parallel layer-boundary activations (measured win for
    # dense families; conflicts with the MoE shard_map x-layout, see
    # EXPERIMENTS.md §Perf) — set per arch config
    seq_parallel: bool = False

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.attn_every and self.mamba_dt_rank == 0:
            object.__setattr__(self, "mamba_dt_rank", -(-self.d_model // 16))

    @property
    def mlp_rank(self) -> Optional[int]:
        return self.sct.rank if self.sct.spectral_mlp else None

    @property
    def attn_rank(self) -> Optional[int]:
        return self.sct.rank if self.sct.spectral_attention else None

    @property
    def mamba_rank(self) -> Optional[int]:
        return self.sct.rank if self.sct.spectral_mamba else None

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """Can decode a 500k-token context without quadratic attention /
        unbounded KV growth dominating? True for SSM/hybrid families."""
        return self.family in ("ssm_lm", "hybrid")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def replace_sct(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, sct=dataclasses.replace(self.sct, **kw))
