"""RMSNorm / LayerNorm.

Statistics (mean/variance) are computed in fp32 — but only as fused
reductions; the normalized output path stays in the INPUT dtype, so a
bf16 model keeps a bf16 residual/backward stream. This halves the
memory-roofline traffic of the norm backward (EXPERIMENTS.md §Perf,
llama/deepseek hillclimb iteration: fp32[b,s,d] mul/add chains -> bf16).
Set ``FP32_NORM_PATH = True`` to restore full-fp32 normalization
(paper-faithful numerics ablation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

FP32_NORM_PATH = False


def init_rmsnorm(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype=dtype)}


def apply_rmsnorm(p, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    if FP32_NORM_PATH:
        xf = x.astype(jnp.float32)
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
        return y.astype(x.dtype)
    # fp32 accumulation INSIDE the reduce — no fp32 (b, s, d) tensor is
    # ever materialized (the convert fuses into the reduction)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True, dtype=jnp.float32)
    inv = jax.lax.rsqrt(var + eps)
    return x * inv.astype(x.dtype) * p["scale"].astype(x.dtype)


def init_layernorm(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype=dtype), "bias": jnp.zeros((dim,), dtype=dtype)}


def apply_layernorm(p, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True) - jnp.square(mu)
    inv = jax.lax.rsqrt(var + eps)
    if FP32_NORM_PATH:
        y = (xf - mu) * inv * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
        return y.astype(x.dtype)
    y = (x - mu.astype(x.dtype)) * inv.astype(x.dtype)
    return y * p["scale"].astype(x.dtype) + p["bias"].astype(x.dtype)
