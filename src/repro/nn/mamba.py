"""Mamba selective-SSM mixer — the '7' in Jamba's 1:7 attention:mamba
interleave [arXiv:2403.19887]. Training path runs a lax.scan over time;
decode carries (conv buffer, ssm state) and costs O(1) per token — which
is why jamba runs the long_500k cell that full-attention archs skip.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.nn.linear import init_linear, apply_linear


def init_mamba(key, cfg, dtype=jnp.float32):
    """cfg needs: d_model, mamba_expand, mamba_d_state, mamba_d_conv,
    mamba_dt_rank, mlp_rank (spectral option for in/out projections —
    kept dense in paper-faithful mode, see DESIGN.md S7)."""
    d = cfg.d_model
    di = cfg.mamba_expand * d
    ds, dc = cfg.mamba_d_state, cfg.mamba_d_conv
    dtr = cfg.mamba_dt_rank
    ks = jax.random.split(key, 6)
    rank = cfg.mamba_rank  # None in faithful mode
    p = {
        "in_proj": init_linear(ks[0], d, 2 * di, rank=rank, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (dc, di), dtype=jnp.float32) * (dc ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype=dtype),
        "x_proj": init_linear(ks[2], di, dtr + 2 * ds, dtype=dtype),
        "dt_proj": init_linear(ks[3], dtr, di, bias=True, dtype=dtype),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, 1))).astype(dtype),
        "D": jnp.ones((di,), dtype=dtype),
        "out_proj": init_linear(ks[4], di, d, rank=rank, dtype=dtype),
    }
    return p


def _causal_conv(x, w, b):
    """x: (b, s, di); depthwise causal conv, kernel (dc, di)."""
    dc = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
    # unrolled taps (dc is 4): sum_j w[j] * x[t - dc + 1 + j]
    out = sum(pad[:, j : j + x.shape[1], :] * w[j].astype(x.dtype) for j in range(dc))
    return out + b.astype(x.dtype)


def _ssm_scan(u, dt, B, C, A, D, h0=None):
    """Selective scan. u: (b, s, di); dt: (b, s, di); B, C: (b, s, ds);
    A: (di, ds) negative; returns ((b, s, di), final state (b, di, ds)).

    The (b, s, di, ds) discretized tensors are never materialized —
    dA/dBu are formed per-step inside the scan body (memory-roofline
    fix: scan inputs are O(b*s*di), not O(b*s*di*ds))."""
    b, s, di = u.shape
    ds = B.shape[-1]

    def step(h, inp):
        dt_t, B_t, C_t, u_t = inp                          # (b,di),(b,ds),(b,ds),(b,di)
        # PALLAS_EQ marker: the selective scan runs as a fused kernel on
        # TPU (state resident in VMEM across steps, as mamba's CUDA
        # kernel does on GPU); roofline substitutes kernel traffic.
        with jax.named_scope("PALLAS_EQ_mamba_scan"):
            dA_t = jnp.exp(dt_t[..., None] * A[None])      # (b, di, ds)
            dBu_t = dt_t[..., None] * B_t[:, None, :] * u_t[..., None]
            h = dA_t * h + dBu_t                           # (b, di, ds)
            y = jnp.einsum("bds,bs->bd", h, C_t)
        return h, y

    if h0 is None:
        h0 = jnp.zeros((b, di, ds), dtype=u.dtype)
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (dt, B, C, u))
    hT, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)                             # (b, s, di)
    return y + u * D.astype(u.dtype), hT


def _mamba_pre(p, x, cfg):
    di = cfg.mamba_expand * cfg.d_model
    xz = apply_linear(p["in_proj"], x)
    xi, z = jnp.split(xz, [di], axis=-1)
    return xi, z


def _mamba_ssm_params(p, xi, cfg):
    dtr, ds = cfg.mamba_dt_rank, cfg.mamba_d_state
    proj = apply_linear(p["x_proj"], xi)
    dt_in, B, C = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(apply_linear(p["dt_proj"], dt_in))
    A = -jnp.exp(p["A_log"].astype(jnp.float32)).astype(xi.dtype)
    return dt, B, C, A


def apply_mamba(p, x, cfg, *, return_state: bool = False):
    """Training / prefill forward. x: (b, s, d). With return_state=True
    also returns the exact decode state (conv tail + final SSM state)."""
    xi, z = _mamba_pre(p, x, cfg)
    xi_c = jax.nn.silu(_causal_conv(xi, p["conv_w"], p["conv_b"]))
    dt, B, C, A = _mamba_ssm_params(p, xi_c, cfg)
    y, hT = _ssm_scan(xi_c, dt, B, C, A, p["D"])
    y = y * jax.nn.silu(z)
    out = apply_linear(p["out_proj"], y)
    if return_state:
        conv_tail = xi[:, -(cfg.mamba_d_conv - 1):, :]
        return out, {"conv": conv_tail, "ssm": hT}
    return out


def mamba_init_state(cfg, batch, dtype=jnp.bfloat16):
    di = cfg.mamba_expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, di), dtype=dtype),
        "ssm": jnp.zeros((batch, di, cfg.mamba_d_state), dtype=dtype),
    }


def apply_mamba_decode(p, x, cfg, *, state):
    """One-token step. x: (b, 1, d); O(1) in sequence length."""
    b = x.shape[0]
    di = cfg.mamba_expand * cfg.d_model
    xi, z = _mamba_pre(p, x, cfg)                          # (b, 1, di)
    conv_in = jnp.concatenate([state["conv"].astype(xi.dtype), xi], axis=1)  # (b, dc, di)
    w = p["conv_w"].astype(xi.dtype)
    xi_c = jnp.einsum("bcd,cd->bd", conv_in, w)[:, None, :] + p["conv_b"].astype(xi.dtype)
    xi_c = jax.nn.silu(xi_c)
    dt, B, C, A = _mamba_ssm_params(p, xi_c, cfg)
    dA = jnp.exp(dt[:, 0, :, None] * A[None])              # (b, di, ds)
    dBu = dt[:, 0, :, None] * B[:, 0, None, :] * xi_c[:, 0, :, None]
    h = dA * state["ssm"].astype(dA.dtype) + dBu
    y = jnp.einsum("bds,bs->bd", h, C[:, 0])[:, None, :]
    y = (y + xi_c * p["D"].astype(xi_c.dtype)) * jax.nn.silu(z)
    out = apply_linear(p["out_proj"], y)
    new_state = {"conv": conv_in[:, 1:, :].astype(state["conv"].dtype), "ssm": h.astype(state["ssm"].dtype)}
    return out, new_state
