"""xLSTM blocks [arXiv:2405.04517]: mLSTM (matrix memory, parallel
training form) and sLSTM (scalar memory, sequential scan).

Training uses the mLSTM's quadratic *parallel* form (decay-masked
attention-like einsum, as trained in the paper); decode uses the O(1)
recurrent state — which is why xlstm-1.3b runs the long_500k cell.

The recurrent cell matrices are dynamics-coupled, so SCT is applied to
the surrounding up/down projections only (DESIGN.md S7).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.nn.linear import init_linear, apply_linear
from repro.nn.norms import init_rmsnorm, apply_rmsnorm


# ------------------------------------------------------------- mLSTM ----

def init_mlstm(key, cfg, dtype=jnp.float32):
    """mLSTM block, projection factor 2. cfg: d_model, n_heads, mlp_rank."""
    d = cfg.d_model
    di = 2 * d
    h = cfg.n_heads
    ks = jax.random.split(key, 8)
    r = cfg.mlp_rank
    return {
        "up": init_linear(ks[0], d, 2 * di, rank=r, dtype=dtype),
        "wq": init_linear(ks[1], di, di, dtype=dtype),
        "wk": init_linear(ks[2], di, di, dtype=dtype),
        "wv": init_linear(ks[3], di, di, dtype=dtype),
        "wi": init_linear(ks[4], di, h, bias=True, dtype=dtype),
        "wf": init_linear(ks[5], di, h, bias=True, dtype=dtype),
        "wo_gate": init_linear(ks[6], di, di, bias=True, dtype=dtype),
        "norm": init_rmsnorm(di, dtype=dtype),
        "down": init_linear(ks[7], di, d, rank=r, dtype=dtype),
    }


def _mlstm_gates_qkv(p, xu, cfg):
    b, s, di = xu.shape
    h = cfg.n_heads
    dh = di // h
    q = apply_linear(p["wq"], xu).reshape(b, s, h, dh)
    k = apply_linear(p["wk"], xu).reshape(b, s, h, dh) / math.sqrt(dh)
    v = apply_linear(p["wv"], xu).reshape(b, s, h, dh)
    i_pre = apply_linear(p["wi"], xu).astype(jnp.float32)   # (b, s, h)
    f_pre = apply_linear(p["wf"], xu).astype(jnp.float32)
    return q, k, v, i_pre, f_pre


MLSTM_CHUNK = 256


def _mlstm_chunk_body(q_c, k_c, v_c, i_c, logf_c, C0, n0, m0):
    """One chunk of the exact chunkwise-parallel mLSTM (xLSTM paper's
    training form). q/k/v_c: (b, T, h, dh) fp32; i/logf_c: (b, T, h);
    carried state (C0 (b,h,dh,dh), n0 (b,h,dh), m0 (b,h)) in the same
    stabilized units as the recurrent decode cell (apply_mlstm_decode) —
    the two forms agree exactly, which tests assert."""
    b, T, h, dh = q_c.shape
    bcum = jnp.cumsum(logf_c, axis=1)                        # (b, T, h)
    btot = bcum[:, -1]                                       # (b, h)
    # intra-chunk log weights w_{t,j} = b_t - b_j + i_j  (j <= t)
    logD = bcum[:, :, None, :] - bcum[:, None, :, :] + i_c[:, None, :, :]
    tpos = jnp.arange(T)
    causal = tpos[:, None] >= tpos[None, :]
    logD = jnp.where(causal[None, :, :, None], logD, -jnp.inf)
    inter = bcum + m0[:, None, :]                            # (b, T, h)
    m_loc = jnp.maximum(inter, jnp.max(logD, axis=2))        # (b, T, h)
    w = jnp.exp(logD - m_loc[:, :, None, :])                 # (b, t, j, h)
    inter_sc = jnp.exp(inter - m_loc)                        # (b, T, h)
    scores = jnp.einsum("bthd,bjhd->btjh", q_c, k_c)
    num = (
        jnp.einsum("btjh,bjhd->bthd", w * scores, v_c)
        + inter_sc[..., None] * jnp.einsum("bthd,bhde->bthe", q_c, C0)
    )
    den = (
        jnp.einsum("btjh,btjh->bth", w, scores)
        + inter_sc * jnp.einsum("bthd,bhd->bth", q_c, n0)
    )
    den = jnp.maximum(jnp.abs(den), jnp.exp(-m_loc))
    out = num / den[..., None]                               # (b, T, h, dh)
    # end-of-chunk state
    a = btot[:, None, :] - bcum + i_c                        # (b, T, h)
    m_new = jnp.maximum(btot + m0, jnp.max(a, axis=1))       # (b, h)
    decay0 = jnp.exp(btot + m0 - m_new)
    wa = jnp.exp(a - m_new[:, None, :])
    C_new = decay0[..., None, None] * C0 + jnp.einsum("bjh,bjhd,bjhe->bhde", wa, k_c, v_c)
    n_new = decay0[..., None] * n0 + jnp.einsum("bjh,bjhd->bhd", wa, k_c)
    return out, (C_new, n_new, m_new)


def _mlstm_core(p, xu, cfg, state=None, chunk=MLSTM_CHUNK):
    """Chunkwise mLSTM over (b, s, di) gate inputs. Returns (y, state).
    Peak intra tensor is (b, chunk, chunk, h) instead of (b, s, s, h) —
    the memory-roofline fix that lets xlstm train_4k fit HBM."""
    b, s, di = xu.shape
    h = cfg.n_heads
    dh = di // h
    q, k, v, i_pre, f_pre = _mlstm_gates_qkv(p, xu, cfg)
    q, k, v = (t.astype(jnp.float32) for t in (q, k, v))
    logf = jax.nn.log_sigmoid(f_pre)
    if state is None:
        C0 = jnp.zeros((b, h, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, h, dh), jnp.float32)
        m0 = jnp.full((b, h), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    T = min(chunk, s)
    if s % T != 0:
        T = s  # fall back to one chunk (small/odd lengths)
    nc = s // T

    def to_chunks(t):
        return jnp.moveaxis(t.reshape(b, nc, T, *t.shape[2:]), 1, 0)

    xs = tuple(to_chunks(t) for t in (q, k, v, i_pre, logf))

    def step(carry, xc):
        q_c, k_c, v_c, i_c, lf_c = xc
        # PALLAS_EQ marker: kernel-substituted in the roofline (the
        # chunkwise mLSTM cell is the same fused-kernel shape as flash
        # attention — decay-masked scores in VMEM; see DESIGN.md S6).
        with jax.named_scope("PALLAS_EQ_mlstm_chunk"):
            out, carry = _mlstm_chunk_body(q_c, k_c, v_c, i_c, lf_c, *carry)
        return carry, out

    (C, n, m), outs = jax.lax.scan(step, (C0, n0, m0), xs)
    y = jnp.moveaxis(outs, 0, 1).reshape(b, s, h, dh)
    return y, {"C": C, "n": n, "m": m}


def apply_mlstm(p, x, cfg):
    """Training forward (exact chunkwise-parallel form). x: (b, s, d)."""
    b, s, d = x.shape
    up = apply_linear(p["up"], x)
    xu, z = jnp.split(up, 2, axis=-1)                       # (b, s, di) each
    y, _ = _mlstm_core(p, xu, cfg)
    y = y.reshape(b, s, -1).astype(x.dtype)
    o = jax.nn.sigmoid(apply_linear(p["wo_gate"], xu))
    y = apply_rmsnorm(p["norm"], y * o) * jax.nn.silu(z)
    return apply_linear(p["down"], y)


def apply_mlstm_with_state(p, x, cfg, state=None):
    """Prefill path: same as apply_mlstm but returns the final recurrent
    state for the decode loop."""
    b, s, d = x.shape
    up = apply_linear(p["up"], x)
    xu, z = jnp.split(up, 2, axis=-1)
    y, new_state = _mlstm_core(p, xu, cfg, state=state)
    y = y.reshape(b, s, -1).astype(x.dtype)
    o = jax.nn.sigmoid(apply_linear(p["wo_gate"], xu))
    y = apply_rmsnorm(p["norm"], y * o) * jax.nn.silu(z)
    return apply_linear(p["down"], y), new_state


def mlstm_init_state(cfg, batch, dtype=jnp.float32):
    di = 2 * cfg.d_model
    h = cfg.n_heads
    dh = di // h
    return {
        "C": jnp.zeros((batch, h, dh, dh), dtype=dtype),
        "n": jnp.zeros((batch, h, dh), dtype=dtype),
        "m": jnp.full((batch, h), -1e30, dtype=dtype),
    }


def apply_mlstm_decode(p, x, cfg, *, state):
    """Recurrent single-token step — O(1) in sequence length."""
    b = x.shape[0]
    up = apply_linear(p["up"], x)
    xu, z = jnp.split(up, 2, axis=-1)
    q, k, v, i_pre, f_pre = _mlstm_gates_qkv(p, xu, cfg)
    q, k, v = (t[:, 0].astype(jnp.float32) for t in (q, k, v))   # (b, h, dh)
    i_pre, f_pre = i_pre[:, 0], f_pre[:, 0]                      # (b, h)
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + state["m"], i_pre)
    f_sc = jnp.exp(logf + state["m"] - m_new)[..., None]
    i_sc = jnp.exp(i_pre - m_new)[..., None]
    C = f_sc[..., None] * state["C"] + i_sc[..., None] * jnp.einsum("bhk,bhv->bhkv", k, v)
    n = f_sc * state["n"] + i_sc * k
    num = jnp.einsum("bhk,bhkv->bhv", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q, n)), jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(b, 1, -1).astype(x.dtype)
    o = jax.nn.sigmoid(apply_linear(p["wo_gate"], xu))
    y = apply_rmsnorm(p["norm"], y * o) * jax.nn.silu(z)
    out = apply_linear(p["down"], y)
    return out, {"C": C, "n": n, "m": m_new}


# ------------------------------------------------------------- sLSTM ----

def init_slstm(key, cfg, dtype=jnp.float32):
    """sLSTM block: scalar memory with per-head recurrent mixing, plus a
    4/3-factor gated FFN (paper's block design)."""
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 7)
    r = cfg.mlp_rank
    dff = int(4 * d / 3)
    return {
        "wx": init_linear(ks[0], d, 4 * d, bias=True, dtype=dtype),   # i,f,z,o pre-acts
        "wr": (jax.random.normal(ks[1], (h, dh, 4 * dh), dtype=jnp.float32) * dh ** -0.5).astype(dtype),
        "norm": init_rmsnorm(d, dtype=dtype),
        "ff_up": init_linear(ks[2], d, 2 * dff, rank=r, dtype=dtype),
        "ff_down": init_linear(ks[3], dff, d, rank=r, dtype=dtype),
    }


def _slstm_cell(p, cfg, xg, state):
    """One time step. xg: (b, 4d) input pre-activations; state dict with
    h,c,n,m each (b, h, dh) / (b, h)."""
    b = xg.shape[0]
    nh = cfg.n_heads
    dh = cfg.d_model // nh
    # recurrent contribution: per-head h @ wr -> (b, h, 4dh)
    rec = jnp.einsum("bhd,hdg->bhg", state["h"], p["wr"].astype(state["h"].dtype))
    pre = xg.reshape(b, nh, 4 * dh) + rec
    i_pre, f_pre, z_pre, o_pre = jnp.split(pre.astype(jnp.float32), 4, axis=-1)
    # stabilized exponential gating (per head-dim)
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + state["m"], i_pre)
    i_sc = jnp.exp(i_pre - m_new)
    f_sc = jnp.exp(logf + state["m"] - m_new)
    c = f_sc * state["c"] + i_sc * jnp.tanh(z_pre)
    n = f_sc * state["n"] + i_sc
    hat = c / jnp.maximum(n, 1.0)
    h_new = jax.nn.sigmoid(o_pre) * hat
    return {"h": h_new.astype(state["h"].dtype), "c": c, "n": n, "m": m_new}


def slstm_init_state(cfg, batch, dtype=jnp.float32):
    nh = cfg.n_heads
    dh = cfg.d_model // nh
    z = jnp.zeros((batch, nh, dh), dtype=dtype)
    return {"h": z, "c": z, "n": z, "m": jnp.full((batch, nh, dh), -1e30, dtype=dtype)}


def _slstm_ffn(p, y):
    u = apply_linear(p["ff_up"], y)
    a, g = jnp.split(u, 2, axis=-1)
    return apply_linear(p["ff_down"], jax.nn.gelu(a) * g)


def apply_slstm(p, x, cfg):
    """Training forward: sequential scan over time. x: (b, s, d)."""
    b, s, d = x.shape
    xg = apply_linear(p["wx"], x)                          # (b, s, 4d)
    state = slstm_init_state(cfg, b, dtype=jnp.float32)

    def step(st, xg_t):
        st = _slstm_cell(p, cfg, xg_t, st)
        return st, st["h"]

    _, hs = jax.lax.scan(step, state, jnp.moveaxis(xg, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    y = apply_rmsnorm(p["norm"], y)
    return _slstm_ffn(p, y)


def apply_slstm_decode(p, x, cfg, *, state):
    xg = apply_linear(p["wx"], x)[:, 0]                    # (b, 4d)
    state = _slstm_cell(p, cfg, xg, state)
    y = state["h"].reshape(x.shape[0], 1, cfg.d_model).astype(x.dtype)
    y = apply_rmsnorm(p["norm"], y)
    return _slstm_ffn(p, y), state
