"""Token embedding + (tied or untied) LM head, vocab-shardable."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_embedding(key: jax.Array, vocab: int, dim: int, dtype=jnp.float32):
    w = jax.random.normal(key, (vocab, dim), dtype=jnp.float32) * (dim ** -0.5)
    return {"w": w.astype(dtype)}


def apply_embedding(p, tokens: jax.Array, compute_dtype=jnp.bfloat16) -> jax.Array:
    # take() keeps the vocab axis shardable (gather across shards is a
    # collective the partitioner handles; no full-table replication).
    return jnp.take(p["w"].astype(compute_dtype), tokens, axis=0)


def apply_lm_head(p, x: jax.Array) -> jax.Array:
    """Logits = x @ E^T. Output vocab axis stays sharded; the loss uses a
    shard-local max/sum so the full-vocab tensor is never gathered."""
    return x @ p["w"].astype(x.dtype).T
