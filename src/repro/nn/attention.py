"""Attention blocks: GQA (llama/qwen/granite/whisper/qwen2-vl) and MLA
(deepseek-v2/v3), each with a training path, a prefill path (fills the
cache) and a single-token decode path.

Projections can optionally be spectral (SCT) via ``rank`` — the paper
leaves attention dense (S5 'Attention layers'); we expose the extension
as a config flag and benchmark it separately.

Cache layouts (per layer, stacked with a leading L axis by the model):
  GQA: {"k": (b, S, kvh, hd), "v": (b, S, kvh, hd)}
  MLA: {"ckv": (b, S, kv_lora), "krope": (b, S, rope_dim)}   <- the MLA
       memory win: compressed latent is cached, not full K/V.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.nn.linear import init_linear, apply_linear
from repro.nn.norms import init_rmsnorm, apply_rmsnorm
from repro.nn.rotary import apply_rope, apply_mrope

NEG_INF = -1e30


def _tp_slice(a, tp_axis, n_local, axis):
    """This shard's contiguous block of ``n_local`` entries along
    ``axis`` under ``shard_map`` — block i of the mesh axis owns
    entries [i*n_local, (i+1)*n_local). Head blocks are contiguous per
    kv group (see the (kvh, rep) reshape in :func:`_sdpa`), so slicing
    q and k/v by the same shard index keeps GQA grouping congruent
    with the single-device layout."""
    idx = jax.lax.axis_index(tp_axis)
    return jax.lax.dynamic_slice_in_dim(a, idx * n_local, n_local, axis=axis)


FLASH_THRESHOLD = 2048  # direct softmax below this sequence length
# big chunks: few loop iterations => few HBM round-trips of the chunk
# intermediates in the XLA fallback (a Pallas flash kernel keeps them in
# VMEM; see kernels/flash_attention.py and EXPERIMENTS.md §Perf)
FLASH_Q_CHUNK = 2048
FLASH_KV_CHUNK = 4096


def _sdpa_direct(q, k, v, *, causal: bool, q_offset=0, kv_len_mask=None):
    """Reference O(s^2)-memory attention — short sequences and
    single-token decode (sq == 1). q: (b, sq, g, r, d) grouped;
    k/v: (b, skv, g, d)."""
    b, sq, g, r, d = q.shape
    scores = jnp.einsum("bqgrd,bkgd->bgrqk", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(d))
    if causal:
        qpos = jnp.arange(sq) + q_offset
        kpos = jnp.arange(k.shape[1])
        mask = qpos[:, None] >= kpos[None, :]
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    if kv_len_mask is not None:
        scores = jnp.where(kv_len_mask[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bgrqk,bkgd->bqgrd", probs, v)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _flash(q, k, v, causal):
    out, _, _ = _flash_fwd_impl(q, k, v, causal)
    return out


def _flash_vjp_fwd(q, k, v, causal):
    out, m, l = _flash_fwd_impl(q, k, v, causal)
    return out, (q, k, v, out, m, l)


def _flash_vjp_bwd(causal, res, dout):
    return _flash_bwd_impl(causal, res, dout)


def _flash_fwd_impl(q, k, v, causal):
    """Exact flash-style attention in pure jnp: lax.map over q chunks,
    lax.scan over kv chunks with online softmax. Peak live scores tensor
    is (b, g, r, cq, ck) instead of (b, g, r, s, s). On TPU this region
    runs as the fused kernels/flash_attention.py kernel; this jnp
    equivalent is what the 512-device dry-run partitions.
    q: (b, sq, g, r, d); k/v: (b, skv, g, d).
    Returns (out, m, l) — the softmax stats the backward needs."""
    b, sq, g, r, d = q.shape
    skv = k.shape[1]
    dv = v.shape[-1]          # MLA: v_head_dim != qk head dim
    cq = min(FLASH_Q_CHUNK, sq)
    ck = min(FLASH_KV_CHUNK, skv)
    nq, nk = sq // cq, skv // ck
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    qc = q.reshape(b, nq, cq, g, r, d)
    kc = k.reshape(b, nk, ck, g, d)
    vc = v.reshape(b, nk, ck, g, dv)

    def per_q_chunk(qi):
        q_i = qc[:, qi]                                   # (b, cq, g, r, d)
        qpos = qi * cq + jnp.arange(cq)

        def kv_step(carry, kj):
            acc, m, l = carry
            k_j = kc[:, kj]
            v_j = vc[:, kj]
            s_ij = jnp.einsum("bqgrd,bkgd->bgrqk", q_i, k_j).astype(jnp.float32) * scale
            if causal:
                kpos = kj * ck + jnp.arange(ck)
                mask = qpos[:, None] >= kpos[None, :]
                s_ij = jnp.where(mask[None, None, None], s_ij, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s_ij, axis=-1))
            p = jnp.exp(s_ij - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bgrqk,bkgd->bgrqd", p.astype(q_i.dtype), v_j
            ).astype(jnp.float32)
            return (acc, m_new, l), None

        acc0 = jnp.zeros((b, g, r, cq, dv), jnp.float32)
        m0 = jnp.full((b, g, r, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, g, r, cq), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0), jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        # out: (b, g, r, cq, dv) -> (b, cq, g, r, dv); stats (b, g, r, cq)
        return jnp.moveaxis(out, 3, 1).astype(q.dtype), m, l

    outs, ms, ls = jax.lax.map(per_q_chunk, jnp.arange(nq))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, g, r, dv)
    return out, ms, ls                                    # ms/ls: (nq, b, g, r, cq)


def _flash_bwd_impl(causal, res, dout):
    """Chunked flash backward (the standard recompute-p form — what the
    Pallas backward kernel implements on TPU):
      delta_i = rowsum(dO_i * O_i)
      p_ij    = exp(s_ij - m_i) / l_i
      dV_j   += p_ij^T dO_i
      ds_ij   = p_ij * (dO_i V_j^T - delta_i) * scale
      dQ_i   += ds_ij K_j ;  dK_j += ds_ij^T Q_i
    Never materializes an (s, s) tensor."""
    q, k, v, out, ms, ls = res
    b, sq, g, r, d = q.shape
    skv = k.shape[1]
    dv = v.shape[-1]
    cq = min(FLASH_Q_CHUNK, sq)
    ck = min(FLASH_KV_CHUNK, skv)
    nq, nk = sq // cq, skv // ck
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    qc = q.reshape(b, nq, cq, g, r, d)
    kc = k.reshape(b, nk, ck, g, d)
    vc = v.reshape(b, nk, ck, g, dv)
    doc = dout.reshape(b, nq, cq, g, r, dv)
    oc = out.reshape(b, nq, cq, g, r, dv)

    def per_q_chunk(carry, qi):
        dk_acc, dv_acc = carry                            # (b, skv, g, d/dv) f32
        q_i = qc[:, qi]
        do_i = doc[:, qi].astype(jnp.float32)
        o_i = oc[:, qi].astype(jnp.float32)
        m_i = ms[qi]                                      # (b, g, r, cq)
        l_i = jnp.maximum(ls[qi], 1e-30)
        delta = jnp.einsum("bqgrd,bqgrd->bgrq", do_i, o_i)  # (b, g, r, cq)
        qpos = qi * cq + jnp.arange(cq)

        def kv_step(inner, kj):
            dq_i, dk_acc, dv_acc = inner
            k_j = kc[:, kj]
            v_j = vc[:, kj]
            s_ij = jnp.einsum("bqgrd,bkgd->bgrqk", q_i, k_j).astype(jnp.float32) * scale
            if causal:
                kpos = kj * ck + jnp.arange(ck)
                mask = qpos[:, None] >= kpos[None, :]
                s_ij = jnp.where(mask[None, None, None], s_ij, NEG_INF)
            p = jnp.exp(s_ij - m_i[..., None]) / l_i[..., None]   # (b,g,r,cq,ck)
            pv = p.astype(v_j.dtype)
            dv_j = jnp.einsum("bgrqk,bqgrd->bkgd", pv, do_i.astype(v_j.dtype))
            dp = jnp.einsum("bqgrd,bkgd->bgrqk", do_i.astype(v_j.dtype), v_j).astype(jnp.float32)
            ds = p * (dp - delta[..., None]) * scale             # (b,g,r,cq,ck)
            dsq = ds.astype(q_i.dtype)
            dq_i = dq_i + jnp.einsum("bgrqk,bkgd->bqgrd", dsq, k_j).astype(jnp.float32)
            dk_j = jnp.einsum("bgrqk,bqgrd->bkgd", dsq, q_i)
            dk_acc = _acc_update(dk_acc, dk_j.astype(jnp.float32), kj, ck)
            dv_acc = _acc_update(dv_acc, dv_j.astype(jnp.float32), kj, ck)
            return (dq_i, dk_acc, dv_acc), None

        dq0 = jnp.zeros((b, cq, g, r, d), jnp.float32)
        (dq_i, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_step, (dq0, dk_acc, dv_acc), jnp.arange(nk))
        return (dk_acc, dv_acc), dq_i

    dk0 = jnp.zeros((b, skv, g, d), jnp.float32)
    dv0 = jnp.zeros((b, skv, g, dv), jnp.float32)
    with jax.named_scope("PALLAS_EQ_flash_attention_bwd"):
        (dk, dvv), dqs = jax.lax.scan(per_q_chunk, (dk0, dv0), jnp.arange(nq))
    dq = jnp.moveaxis(dqs, 0, 1).reshape(b, sq, g, r, d).astype(q.dtype)
    return dq, dk.astype(k.dtype), dvv.astype(v.dtype)


def _acc_update(acc, delta, kj, ck):
    """acc[:, kj*ck:(kj+1)*ck] += delta, XLA-friendly."""
    cur = jax.lax.dynamic_slice_in_dim(acc, kj * ck, ck, axis=1)
    return jax.lax.dynamic_update_slice_in_dim(acc, cur + delta, kj * ck, axis=1)


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def _sdpa(q, k, v, *, causal: bool, q_offset=0, kv_len_mask=None):
    """q: (b, sq, h, d); k/v: (b, skv, kvh, d). GQA via grouped-head
    einsums — kv heads are never materialized repeated (a rep x HBM-
    traffic save over jnp.repeat). Softmax in fp32. causal uses absolute
    positions (q_offset for decode); kv_len_mask: (b, skv) valid slots.
    """
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    qg = q.reshape(b, sq, kvh, rep, d)
    dv = v.shape[-1]
    use_flash = (
        kv_len_mask is None
        and sq == skv
        and sq > FLASH_THRESHOLD
        and sq % min(FLASH_Q_CHUNK, sq) == 0
        and skv % min(FLASH_KV_CHUNK, skv) == 0
        and isinstance(q_offset, int)   # traced offset (chunked prefill)
        and q_offset == 0               # => direct path, mask handles it
    )
    if use_flash:
        # PALLAS_EQ marker: on TPU this region runs as the fused
        # kernels/flash_attention.py kernel (validated against the same
        # math); the roofline cost model substitutes the kernel's HBM
        # traffic for the XLA fallback's (roofline/hlo_cost.py).
        with jax.named_scope("PALLAS_EQ_flash_attention"):
            out = _flash(qg, k, v, causal)
    else:
        out = _sdpa_direct(qg, k, v, causal=causal, q_offset=q_offset,
                           kv_len_mask=kv_len_mask)
    return out.reshape(b, sq, h, dv)


# ---------------------------------------------------------------- GQA ----

def init_gqa(key, cfg, dtype=jnp.float32):
    """cfg needs: d_model, n_heads, n_kv_heads, head_dim, qkv_bias,
    attn_rank (None => dense, the paper-faithful default)."""
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    r = cfg.attn_rank
    return {
        "wq": init_linear(kq, d, h * hd, rank=r, bias=cfg.qkv_bias, dtype=dtype),
        "wk": init_linear(kk, d, kvh * hd, rank=r, bias=cfg.qkv_bias, dtype=dtype),
        "wv": init_linear(kv, d, kvh * hd, rank=r, bias=cfg.qkv_bias, dtype=dtype),
        "wo": init_linear(ko, h * hd, d, rank=r, bias=False, dtype=dtype),
    }


def _gqa_qkv(p, x, cfg, positions, use_pallas=False):
    b, s, _ = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = apply_linear(p["wq"], x, use_pallas=use_pallas).reshape(b, s, h, hd)
    k = apply_linear(p["wk"], x, use_pallas=use_pallas).reshape(b, s, kvh, hd)
    v = apply_linear(p["wv"], x, use_pallas=use_pallas).reshape(b, s, kvh, hd)
    if cfg.rope == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope == "mrope":
        mpos = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        q = apply_mrope(q, mpos, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, mpos, cfg.mrope_sections, cfg.rope_theta)
    return q, k, v


def apply_gqa(p, x, cfg, *, positions, causal=True, use_pallas=False):
    """Training / no-cache forward."""
    b, s, _ = x.shape
    q, k, v = _gqa_qkv(p, x, cfg, positions, use_pallas)
    o = _sdpa(q, k, v, causal=causal)
    return apply_linear(p["wo"], o.reshape(b, s, -1), use_pallas=use_pallas)


def gqa_init_cache(cfg, batch, max_seq, dtype=jnp.bfloat16):
    kvh, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_seq, kvh, hd), dtype=dtype),
        "v": jnp.zeros((batch, max_seq, kvh, hd), dtype=dtype),
    }


def apply_gqa_prefill(p, x, cfg, *, positions, cache, use_pallas=False):
    """Fill cache[:, :s] and return outputs (causal)."""
    b, s, _ = x.shape
    q, k, v = _gqa_qkv(p, x, cfg, positions, use_pallas)
    cache = {
        "k": jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)),
    }
    o = _sdpa(q, k, v, causal=True)
    return apply_linear(p["wo"], o.reshape(b, s, -1), use_pallas=use_pallas), cache


def apply_gqa_decode(p, x, cfg, *, cache, cache_len, use_pallas=False):
    """One-token step. x: (b, 1, d); cache_len: scalar int32 (tokens
    already in cache). Attends over the full cache with a validity mask
    — S stays static so the step compiles once."""
    b, s, _ = x.shape
    positions = jnp.broadcast_to(cache_len[None, None], (b, s)).astype(jnp.int32)
    q, k, v = _gqa_qkv(p, x, cfg, positions, use_pallas)
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), cache_len, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), cache_len, axis=1)
    S = ck.shape[1]
    valid = (jnp.arange(S)[None, :] <= cache_len).astype(bool)
    valid = jnp.broadcast_to(valid, (b, S))
    # Decode-step attention computes in fp32 regardless of model dtype
    # (the step is memory-bound, so the upcast is free). All three decode
    # paths — this static oracle, the paged jnp branch, and the Pallas
    # flash-decode kernel (fp32 scratch) — then agree to fp32 epsilon
    # with a single output rounding, which is what keeps bf16 greedy
    # decode token-identical across them.
    o = _sdpa(q.astype(jnp.float32), ck.astype(jnp.float32),
              cv.astype(jnp.float32), causal=False,
              kv_len_mask=valid).astype(q.dtype)
    return apply_linear(p["wo"], o.reshape(b, s, -1), use_pallas=use_pallas), {"k": ck, "v": cv}


def _gather_cold(cache, name, block_table, cold_flags):
    """Gather one paged pool leaf into the logical (b, S, *f) view with
    cold pages transparently substituted by their dequantized int8
    shadow rows (streaming cold-KV tier; serving/quantize.py
    ``quantize_kv_pages``). Returns fp32 when substitution is active so
    the jnp path sees the same dequantized values as the cold-aware
    Pallas kernels; without cold flags (or without shadow leaves in the
    cache) this is exactly ``paged_gather``."""
    from repro.serving.paged_cache import paged_gather

    pool = cache[name]
    g = paged_gather(pool, block_table)
    if cold_flags is None or name + "_q8" not in cache:
        return g
    b, n = block_table.shape
    page = pool.shape[1]
    q8 = paged_gather(cache[name + "_q8"], block_table)        # (b, S, *f)
    scale = jnp.take(cache[name + "_scale"], block_table, axis=0)
    deq = (q8.astype(jnp.float32).reshape(b, n, page, *pool.shape[2:])
           * scale[:, :, None].astype(jnp.float32)).reshape(g.shape)
    flag = jnp.take(cold_flags, block_table, axis=0) != 0      # (b, n)
    flag = jnp.repeat(flag, page, axis=1)                      # (b, S)
    flag = flag.reshape(flag.shape + (1,) * (g.ndim - 2))
    return jnp.where(flag, deq, g.astype(jnp.float32))


def apply_gqa_prefill_paged(p, x, cfg, *, cache, block_table, start, use_pallas=False,
                            tp_axis=None, tp_size=1, cold_flags=None):
    """Chunked prefill from a logical offset against a paged pool.

    x: (1, c, d) — one sequence's prompt tokens for absolute positions
    [start, start+c); cache: {"k"/"v": (P+1, page, kvh, hd)} shared
    pool; block_table: (1, n_pages); start: scalar int32 (data — one
    executable per chunk length serves every offset). The chunk's K/V
    is scattered into the sequence's pages, then attention runs over
    the gathered logical view: positions < start are the already-cached
    (possibly shared) prefix, positions ≥ start+c stay behind the
    causal mask. Row-for-row this matches a full static prefill
    restricted to the chunk's query positions.

    ``tp_axis`` runs the body tensor-parallel under ``shard_map``:
    projections are computed from replicated weights, this shard keeps
    its contiguous kv-head block (``cache`` is the pool *shard* with
    kvh/tp_size heads), attention runs per-shard, and the head outputs
    are all-gathered before the replicated wo — per-head math is
    untouched, so outputs are bit-identical to single-device."""
    from repro.serving.paged_cache import paged_write_slice

    b, c, _ = x.shape
    positions = jnp.broadcast_to(start + jnp.arange(c, dtype=jnp.int32)[None], (b, c))
    q, k, v = _gqa_qkv(p, x, cfg, positions, use_pallas)
    if tp_axis is not None:
        q = _tp_slice(q, tp_axis, cfg.n_heads // tp_size, 2)
        k = _tp_slice(k, tp_axis, cfg.n_kv_heads // tp_size, 2)
        v = _tp_slice(v, tp_axis, cfg.n_kv_heads // tp_size, 2)
    pk = paged_write_slice(cache["k"], block_table[0], start, k[0])
    pv = paged_write_slice(cache["v"], block_table[0], start, v[0])
    new_cache = dict(cache, k=pk, v=pv)     # shadow leaves ride through
    ck = _gather_cold(new_cache, "k", block_table, cold_flags)
    cv = _gather_cold(new_cache, "v", block_table, cold_flags)
    o = _sdpa(q, ck.astype(q.dtype), cv.astype(q.dtype), causal=True, q_offset=start)
    if tp_axis is not None:
        o = jax.lax.all_gather(o, tp_axis, axis=2, tiled=True)
    return apply_linear(p["wo"], o.reshape(b, c, -1), use_pallas=use_pallas), new_cache


def apply_gqa_decode_paged(p, x, cfg, *, cache, block_table, seq_lens, use_pallas=False,
                           tp_axis=None, tp_size=1, cold_flags=None):
    """One-token step against a paged pool (serving/paged_cache.py).

    cache: {"k"/"v": (P+1, page, kvh, hd)} — this layer's shared pool;
    block_table: (b, n_pages) int32; seq_lens: (b,) int32 per-slot fill
    level (mixed lengths — the continuous-batching contract). The new
    token is appended into each slot's current page, then attention runs
    through the paged flash-decode kernel, which walks the block table
    inside the kernel (kernels/paged_decode.py — no gathered-KV copy).
    ``SCT_PAGED_KERNEL=0`` selects the jnp reference branch instead:
    gather into the logical view, then masked softmax — the oracle the
    differential suite (tests/test_kernels_paged.py) compares against;
    both match apply_gqa_decode row-for-row.

    ``tp_axis`` (under ``shard_map``): ``cache`` is this shard's pool
    slice holding kvh/tp_size kv heads; the matching contiguous q-head
    block attends per-shard (the paged kernel runs unchanged on the
    smaller head count) and head outputs are all-gathered before wo.
    Per-head attention math is identical to single-device, so greedy
    decode stays token-for-token identical at any tp_size that divides
    n_kv_heads."""
    from repro.kernels.paged_decode import (
        paged_gqa_decode_cold_pallas,
        paged_gqa_decode_pallas,
        paged_kernel_enabled,
    )
    from repro.serving.paged_cache import paged_append

    b, s, _ = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    positions = seq_lens[:, None].astype(jnp.int32)
    q, k, v = _gqa_qkv(p, x, cfg, positions, use_pallas)
    if tp_axis is not None:
        h, kvh = h // tp_size, kvh // tp_size
        q = _tp_slice(q, tp_axis, h, 2)
        k = _tp_slice(k, tp_axis, kvh, 2)
        v = _tp_slice(v, tp_axis, kvh, 2)
    pk = paged_append(cache["k"], block_table, seq_lens, k[:, 0])
    pv = paged_append(cache["v"], block_table, seq_lens, v[:, 0])
    new_cache = dict(cache, k=pk, v=pv)     # shadow leaves ride through
    if paged_kernel_enabled():
        qg = q[:, 0].reshape(b, kvh, h // kvh, hd)
        if cold_flags is not None and "k_q8" in cache:
            og = paged_gqa_decode_cold_pallas(
                qg, pk, pv, cache["k_q8"], cache["k_scale"],
                cache["v_q8"], cache["v_scale"],
                block_table, seq_lens, cold_flags)
        else:
            og = paged_gqa_decode_pallas(qg, pk, pv, block_table, seq_lens)
        o = og.reshape(b, s, h, hd)
    else:
        ck = _gather_cold(new_cache, "k", block_table, cold_flags)
        cv = _gather_cold(new_cache, "v", block_table, cold_flags)
        S = ck.shape[1]
        valid = jnp.arange(S)[None, :] <= seq_lens[:, None]
        # fp32 like the kernel branch and the static oracle (see
        # apply_gqa_decode) — one output rounding, bf16 token identity.
        o = _sdpa(q.astype(jnp.float32), ck.astype(jnp.float32),
                  cv.astype(jnp.float32), causal=False,
                  kv_len_mask=valid).astype(q.dtype)
    if tp_axis is not None:
        o = jax.lax.all_gather(o, tp_axis, axis=2, tiled=True)
    return apply_linear(p["wo"], o.reshape(b, s, -1), use_pallas=use_pallas), new_cache


# ---------------------------------------------------------------- MLA ----

def init_mla(key, cfg, dtype=jnp.float32):
    """DeepSeek Multi-head Latent Attention. cfg needs: d_model, n_heads,
    q_lora_rank (0 => direct q proj), kv_lora_rank, qk_nope_dim,
    qk_rope_dim, v_head_dim."""
    keys = jax.random.split(key, 6)
    d, h = cfg.d_model, cfg.n_heads
    nope, rope_d, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    p = {}
    if cfg.q_lora_rank:
        p["wdq"] = init_linear(keys[0], d, cfg.q_lora_rank, dtype=dtype)
        p["q_norm"] = init_rmsnorm(cfg.q_lora_rank, dtype=dtype)
        p["wuq"] = init_linear(keys[1], cfg.q_lora_rank, h * (nope + rope_d), dtype=dtype)
    else:
        p["wq"] = init_linear(keys[1], d, h * (nope + rope_d), dtype=dtype)
    p["wdkv"] = init_linear(keys[2], d, cfg.kv_lora_rank + rope_d, dtype=dtype)
    p["kv_norm"] = init_rmsnorm(cfg.kv_lora_rank, dtype=dtype)
    p["wukv"] = init_linear(keys[3], cfg.kv_lora_rank, h * (nope + vd), dtype=dtype)
    p["wo"] = init_linear(keys[4], h * vd, d, dtype=dtype)
    return p


def _mla_q(p, x, cfg):
    b, s, _ = x.shape
    h, nope, rope_d = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    if cfg.q_lora_rank:
        cq = apply_rmsnorm(p["q_norm"], apply_linear(p["wdq"], x))
        q = apply_linear(p["wuq"], cq)
    else:
        q = apply_linear(p["wq"], x)
    q = q.reshape(b, s, h, nope + rope_d)
    return jnp.split(q, [nope], axis=-1)  # q_nope (b,s,h,nope), q_rope (b,s,h,rope)


def _mla_ckv(p, x, cfg, positions):
    """Compressed latent + shared rope key. Returns ckv (b,s,kv_lora),
    krope (b,s,rope_d) — exactly what the decode cache stores."""
    lat = apply_linear(p["wdkv"], x)
    ckv, krope = jnp.split(lat, [cfg.kv_lora_rank], axis=-1)
    ckv = apply_rmsnorm(p["kv_norm"], ckv)
    krope = apply_rope(krope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return ckv, krope


def apply_mla(p, x, cfg, *, positions, causal=True):
    """Training/prefill form: expand full K/V from the latent."""
    b, s, _ = x.shape
    h, nope, rope_d, vd = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(p, x, cfg)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ckv, krope = _mla_ckv(p, x, cfg, positions)
    kv = apply_linear(p["wukv"], ckv).reshape(b, s, h, nope + vd)
    k_nope, v = jnp.split(kv, [nope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(krope[:, :, None, :], (b, s, h, rope_d))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    o = _sdpa(q, k, v, causal=causal)
    return apply_linear(p["wo"], o.reshape(b, s, -1))


def mla_init_cache(cfg, batch, max_seq, dtype=jnp.bfloat16):
    return {
        "ckv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype=dtype),
        "krope": jnp.zeros((batch, max_seq, cfg.qk_rope_dim), dtype=dtype),
    }


def apply_mla_prefill(p, x, cfg, *, positions, cache):
    b, s, _ = x.shape
    ckv, krope = _mla_ckv(p, x, cfg, positions)
    cache = {
        "ckv": jax.lax.dynamic_update_slice(cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, 0, 0)),
        "krope": jax.lax.dynamic_update_slice(cache["krope"], krope.astype(cache["krope"].dtype), (0, 0, 0)),
    }
    # reuse the training attention for outputs
    out = apply_mla(p, x, cfg, positions=positions, causal=True)
    return out, cache


def _split_wukv(p, cfg):
    """Split the (kv_lora, h*(nope+vd)) up-projection into per-head
    W_uk (h, kv_lora, nope) and W_uv (h, kv_lora, vd) for the absorbed
    decode path. Works for dense wukv (MLA up-proj is never spectral —
    it IS already a low-rank factor by design)."""
    h, nope, vd = cfg.n_heads, cfg.qk_nope_dim, cfg.v_head_dim
    w = p["wukv"]["w"]                                  # (kv_lora, h*(nope+vd))
    w = w.reshape(cfg.kv_lora_rank, h, nope + vd)
    return w[:, :, :nope], w[:, :, nope:]               # (kv_lora,h,nope), (kv_lora,h,vd)


def _mla_absorbed_attend(p, x, cfg, q_nope, q_rope, cckv, ckr, valid, *,
                         precise=False, tp_axis=None, tp_size=1):
    """Shared absorbed-decode attention: scores and values computed
    directly against the compressed latent view cckv (b, S, kv_lora) /
    ckr (b, S, rope_d) under a validity mask — no full K/V is ever
    materialized (the MLA idea, mirroring SCT's never-materialize
    rule). ``valid`` is (b, S) (same mask for every query — the decode
    case) or (b, s, S) (per-query causal mask — the chunked-prefill
    case). ``precise`` runs every einsum in fp32 with a single rounding
    back to x.dtype before wo — the decode paths use it so this oracle
    and the paged flash-decode kernel (fp32 scratch) agree to fp32
    epsilon and bf16 greedy decode stays token-identical.

    ``tp_axis`` (under ``shard_map``) shards the *query heads*: the
    latent view is tiny and stays replicated (the MLA memory win makes
    latent replication the cheap placement), each shard attends its
    contiguous head block with the matching W_uk/W_uv slices, and head
    outputs are all-gathered before wo. Per-head math is unchanged."""
    b, s, _ = x.shape
    h, nope, rope_d, vd = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    wuk, wuv = _split_wukv(p, cfg)
    if tp_axis is not None:
        h = h // tp_size
        q_nope = _tp_slice(q_nope, tp_axis, h, 2)
        q_rope = _tp_slice(q_rope, tp_axis, h, 2)
        wuk = _tp_slice(wuk, tp_axis, h, 1)
        wuv = _tp_slice(wuv, tp_axis, h, 1)
    ct = jnp.float32 if precise else x.dtype
    # absorb W_uk into q: q_lat (b,s,h,kv_lora)
    q_lat = jnp.einsum("bshn,lhn->bshl", q_nope.astype(ct), wuk.astype(ct))
    scores = (
        jnp.einsum("bshl,bSl->bhsS", q_lat, cckv.astype(ct))
        + jnp.einsum("bshr,bSr->bhsS", q_rope.astype(ct), ckr.astype(ct))
    ).astype(jnp.float32) / jnp.sqrt(jnp.float32(nope + rope_d))
    mask = valid[:, None, None, :] if valid.ndim == 2 else valid[:, None, :, :]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(ct)
    o_lat = jnp.einsum("bhsS,bSl->bshl", probs, cckv.astype(ct))   # (b,s,h,kv_lora)
    o = jnp.einsum("bshl,lhv->bshv", o_lat, wuv.astype(ct))        # (b,s,h,vd)
    if tp_axis is not None:
        o = jax.lax.all_gather(o, tp_axis, axis=2, tiled=True)
    return apply_linear(p["wo"], o.astype(x.dtype).reshape(b, s, cfg.n_heads * vd))


def apply_mla_decode(p, x, cfg, *, cache, cache_len):
    """Absorbed single-token decode against the static latent cache."""
    b, s, _ = x.shape
    positions = jnp.broadcast_to(cache_len[None, None], (b, s)).astype(jnp.int32)
    q_nope, q_rope = _mla_q(p, x, cfg)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ckv_new, krope_new = _mla_ckv(p, x, cfg, positions)
    cckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv_new.astype(cache["ckv"].dtype), cache_len, axis=1)
    ckr = jax.lax.dynamic_update_slice_in_dim(cache["krope"], krope_new.astype(cache["krope"].dtype), cache_len, axis=1)
    S = cckv.shape[1]
    valid = jnp.broadcast_to((jnp.arange(S)[None, :] <= cache_len), (b, S))
    out = _mla_absorbed_attend(p, x, cfg, q_nope, q_rope, cckv, ckr, valid,
                               precise=True)
    return out, {"ckv": cckv, "krope": ckr}


def apply_mla_prefill_paged(p, x, cfg, *, cache, block_table, start,
                            tp_axis=None, tp_size=1, cold_flags=None):
    """Chunked prefill from a logical offset against paged latent
    pools — the MLA twin of :func:`apply_gqa_prefill_paged`. The
    chunk's compressed latent/rope-key is scattered into the sequence's
    pages, then the absorbed attend runs over the gathered view under a
    per-query causal mask at absolute positions (cached prefix latents
    are already roped, so nothing is recomputed for shared pages).

    ``tp_axis`` shards query heads per-shard inside the absorbed
    attend; the latent pools are replicated (every shard scatters the
    same latent chunk into its copy, so the pools stay consistent)."""
    from repro.serving.paged_cache import paged_write_slice

    b, c, _ = x.shape
    positions = jnp.broadcast_to(start + jnp.arange(c, dtype=jnp.int32)[None], (b, c))
    q_nope, q_rope = _mla_q(p, x, cfg)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ckv, krope = _mla_ckv(p, x, cfg, positions)
    pckv = paged_write_slice(cache["ckv"], block_table[0], start, ckv[0])
    pkr = paged_write_slice(cache["krope"], block_table[0], start, krope[0])
    new_cache = dict(cache, ckv=pckv, krope=pkr)
    cckv = _gather_cold(new_cache, "ckv", block_table, cold_flags)
    ckr = _gather_cold(new_cache, "krope", block_table, cold_flags)
    S = cckv.shape[1]
    valid = jnp.arange(S)[None, None, :] <= positions[:, :, None]      # (b, c, S)
    out = _mla_absorbed_attend(p, x, cfg, q_nope, q_rope, cckv, ckr, valid,
                               tp_axis=tp_axis, tp_size=tp_size)
    return out, new_cache


def apply_mla_decode_paged(p, x, cfg, *, cache, block_table, seq_lens,
                           tp_axis=None, tp_size=1, cold_flags=None):
    """Absorbed single-token decode against paged latent pools
    cache = {"ckv"/"krope": (P+1, page, ...)}; per-slot seq_lens.

    Default path is the absorbed-MLA paged flash-decode kernel
    (kernels/paged_decode.py): q_nope is absorbed through W_uk outside,
    the kernel walks the block table over the latent pools and returns
    the latent context o_lat, W_uv/W_o apply outside — full K/V is never
    expanded and no gathered latent copy exists. ``SCT_PAGED_KERNEL=0``
    selects the jnp reference branch (gather + _mla_absorbed_attend).

    ``tp_axis`` (under ``shard_map``) shards query heads; the latent
    pools are replicated (each shard appends the identical new latent
    to its copy). The paged kernel runs per-shard on its head block and
    head outputs are all-gathered before wo — greedy decode stays
    token-identical at any tp_size dividing n_heads."""
    from repro.kernels.paged_decode import (
        paged_kernel_enabled,
        paged_mla_decode_cold_pallas,
        paged_mla_decode_pallas,
    )
    from repro.serving.paged_cache import paged_append

    b, s, _ = x.shape
    positions = seq_lens[:, None].astype(jnp.int32)
    q_nope, q_rope = _mla_q(p, x, cfg)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ckv_new, krope_new = _mla_ckv(p, x, cfg, positions)
    pckv = paged_append(cache["ckv"], block_table, seq_lens, ckv_new[:, 0])
    pkr = paged_append(cache["krope"], block_table, seq_lens, krope_new[:, 0])
    new_cache = dict(cache, ckv=pckv, krope=pkr)
    if paged_kernel_enabled():
        h, nope, rope_d, vd = (cfg.n_heads, cfg.qk_nope_dim,
                               cfg.qk_rope_dim, cfg.v_head_dim)
        wuk, wuv = _split_wukv(p, cfg)
        qn, qr = q_nope, q_rope
        if tp_axis is not None:
            h = h // tp_size
            qn = _tp_slice(qn, tp_axis, h, 2)
            qr = _tp_slice(qr, tp_axis, h, 2)
            wuk = _tp_slice(wuk, tp_axis, h, 1)
            wuv = _tp_slice(wuv, tp_axis, h, 1)
        # fp32 absorb/up-project around the fp32-scratch kernel, matching
        # _mla_absorbed_attend(precise=True) — one rounding before wo.
        q_lat = jnp.einsum("bshn,lhn->bshl", qn.astype(jnp.float32),
                           wuk.astype(jnp.float32))[:, 0]       # (b, h, L)
        qr_f32 = qr[:, 0].astype(jnp.float32)
        kscale = 1.0 / float(nope + rope_d) ** 0.5
        if cold_flags is not None and "ckv_q8" in cache:
            o_lat = paged_mla_decode_cold_pallas(
                q_lat, qr_f32, pckv, pkr,
                cache["ckv_q8"], cache["ckv_scale"],
                cache["krope_q8"], cache["krope_scale"],
                block_table, seq_lens, cold_flags, scale=kscale)
        else:
            o_lat = paged_mla_decode_pallas(
                q_lat, qr_f32, pckv, pkr, block_table, seq_lens,
                scale=kscale)
        o = jnp.einsum("bhl,lhv->bhv", o_lat, wuv.astype(jnp.float32))
        if tp_axis is not None:
            o = jax.lax.all_gather(o, tp_axis, axis=1, tiled=True)
        out = apply_linear(p["wo"],
                           o.astype(x.dtype).reshape(b, s, cfg.n_heads * vd))
    else:
        cckv = _gather_cold(new_cache, "ckv", block_table, cold_flags)
        ckr = _gather_cold(new_cache, "krope", block_table, cold_flags)
        S = cckv.shape[1]
        valid = jnp.arange(S)[None, :] <= seq_lens[:, None]
        out = _mla_absorbed_attend(p, x, cfg, q_nope, q_rope, cckv, ckr,
                                   valid, precise=True,
                                   tp_axis=tp_axis, tp_size=tp_size)
    return out, new_cache


# ----------------------------------------------------------- cross-attn --

def init_cross_attn(key, cfg, dtype=jnp.float32):
    """Whisper decoder cross-attention (no rope)."""
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    return {
        "wq": init_linear(kq, d, h * hd, bias=True, dtype=dtype),
        "wk": init_linear(kk, d, h * hd, bias=False, dtype=dtype),
        "wv": init_linear(kv, d, h * hd, bias=True, dtype=dtype),
        "wo": init_linear(ko, h * hd, d, bias=True, dtype=dtype),
    }


def apply_cross_attn(p, x, enc_out, cfg):
    b, s, _ = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    se = enc_out.shape[1]
    q = apply_linear(p["wq"], x).reshape(b, s, h, hd)
    k = apply_linear(p["wk"], enc_out).reshape(b, se, h, hd)
    v = apply_linear(p["wv"], enc_out).reshape(b, se, h, hd)
    o = _sdpa(q, k, v, causal=False)
    return apply_linear(p["wo"], o.reshape(b, s, -1))
