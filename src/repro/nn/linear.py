"""Linear layers: dense or spectral (SCT). One call site for both, so the
paper's technique is a config switch on every projection in the system.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.spectral import spectral_init, spectral_apply, is_spectral


def _is_q8_spectral(p) -> bool:
    # lazy import: serving.quantize owns the single definition of
    # "quantized"; a dict-valued U/w that is NOT a {"q8","scale"} tensor
    # falls through to the dense branch instead of misrouting here
    if not isinstance(p.get("U"), dict):
        return False
    from repro.serving.quantize import is_quantized_spectral

    return is_quantized_spectral(p)


def _is_q8_dense(p) -> bool:
    if not isinstance(p.get("w"), dict):
        return False
    from repro.serving.quantize import is_quantized

    return is_quantized(p["w"])


def init_linear(
    key: jax.Array,
    in_dim: int,
    out_dim: int,
    *,
    rank: Optional[int] = None,
    bias: bool = False,
    dtype: Any = jnp.float32,
    scale: float | None = None,
):
    """rank=None -> dense {'w': (in, out)[, 'b']}; rank=k -> spectral
    {'U': (in,k), 's': (k,), 'V': (out,k)[, 'b']} (paper Eq. 1)."""
    if rank is not None:
        k = min(rank, in_dim, out_dim)
        p = spectral_init(key, in_dim, out_dim, k, dtype=dtype, scale=scale)
    else:
        sigma = scale if scale is not None else in_dim ** -0.5
        w = jax.random.normal(key, (in_dim, out_dim), dtype=jnp.float32) * sigma
        p = {"w": w.astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype=dtype)
    return p


def apply_linear(p, x: jax.Array, *, use_pallas: bool = False) -> jax.Array:
    """Dispatch on parameterization. The dense (m, n) matrix is never
    built in the spectral branch. Int8-quantized spectral groups
    (serving/quantize.py) route to the fused q8 kernel under
    ``use_pallas`` — int8 factors are consumed directly, no dequantized
    fp factor exists; the non-Pallas branches dequantize on the fly
    (int8 lives in HBM, the fp copy is a per-call transient)."""
    if is_spectral(p):
        if use_pallas:
            from repro.kernels.ops import spectral_matmul

            y = spectral_matmul(x, p["U"], p["s"], p["V"])
        else:
            y = spectral_apply(p, x)
    elif _is_q8_spectral(p):                    # int8 spectral group
        if use_pallas:
            from repro.kernels.ops import spectral_matmul_q8

            y = spectral_matmul_q8(x, p["U"], p["s"], p["V"])
        else:
            from repro.serving.quantize import dequantize_int8

            y = spectral_apply(
                {"U": dequantize_int8(p["U"], x.dtype), "s": p["s"],
                 "V": dequantize_int8(p["V"], x.dtype)}, x)
    elif _is_q8_dense(p):                       # int8 dense weight
        from repro.serving.quantize import dequantize_int8

        y = x @ dequantize_int8(p["w"], x.dtype)
    else:
        w = p["w"]
        y = x @ w.astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def linear_out_dim(p) -> int:
    return p["V"].shape[-2] if is_spectral(p) else p["w"].shape[-1]
