"""MLP blocks: SwiGLU (llama/qwen/deepseek/granite/jamba) and GELU
(whisper). These are the layers the paper converts to spectral form
(gate_proj / up_proj / down_proj — S4.2)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.linear import init_linear, apply_linear


def init_mlp(key, d_model: int, d_ff: int, *, rank=None, act: str = "swiglu",
             bias: bool = False, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {
        "up": init_linear(ks[0], d_model, d_ff, rank=rank, bias=bias, dtype=dtype),
        "down": init_linear(ks[1], d_ff, d_model, rank=rank, bias=bias, dtype=dtype),
    }
    if act == "swiglu":
        p["gate"] = init_linear(ks[2], d_model, d_ff, rank=rank, bias=bias, dtype=dtype)
    return p


def apply_mlp(p, x: jax.Array, *, act: str = "swiglu", use_pallas: bool = False) -> jax.Array:
    up = apply_linear(p["up"], x, use_pallas=use_pallas)
    if act == "swiglu":
        gate = apply_linear(p["gate"], x, use_pallas=use_pallas)
        h = jax.nn.silu(gate) * up
    elif act == "gelu":
        h = jax.nn.gelu(up)
    else:
        raise ValueError(act)
    return apply_linear(p["down"], h, use_pallas=use_pallas)
