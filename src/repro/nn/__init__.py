"""Neural-net substrate: every block the 10 assigned architectures need.

Functional style: ``init_*(key, ...) -> params`` / ``apply_*(params, x, ...)``.
Params are plain nested dicts (pytrees) so they compose with pjit, our
optimizer, and the SCT retraction walker without a module framework.
"""
