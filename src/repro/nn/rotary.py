"""Rotary position embeddings: standard RoPE and Qwen2-VL's M-RoPE.

M-RoPE splits the rotary dimension into (temporal, height, width)
sections, each rotated by its own position id. For text-only input all
three position streams are equal and M-RoPE reduces exactly to RoPE —
which is what the vlm backbone stub exercises (the vision frontend that
would produce distinct h/w positions is a stub per the assignment).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (b, s, h, d), positions: (b, s) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                                  # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs     # (b, s, d/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array,
    positions: jax.Array,
    sections: Sequence[int] = (16, 24, 24),
    theta: float = 1_000_000.0,
) -> jax.Array:
    """Qwen2-VL multimodal RoPE. positions: (3, b, s) — temporal/h/w ids.
    sections are in half-dim units and must sum to head_dim // 2."""
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(d, theta)                                   # (half,)
    # angle per half-dim slot, selecting the position stream per section
    angles_per_stream = positions[..., None].astype(jnp.float32) * freqs  # (3, b, s, half)
    sect_id = jnp.repeat(
        jnp.arange(len(sections)), jnp.array(sections), total_repeat_length=half
    )                                                              # (half,)
    # select the stream for each half-dim slot: one-hot over streams
    sel = jax.nn.one_hot(sect_id, len(sections), dtype=jnp.float32)  # (half, 3)
    angles = jnp.einsum("pbsh,hp->bsh", angles_per_stream, sel)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
