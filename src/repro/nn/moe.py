"""Mixture-of-Experts with capacity-bounded sort-free dispatch, shared
experts (deepseek), and SCT inside every expert.

Dispatch strategy (TPU-native, DESIGN.md S5): instead of the dense
one-hot dispatch einsum (FLOPs = tokens x E x d — would dwarf the real
compute), tokens are scattered into an (E, C, d) buffer using positions
computed with a cumsum over the top-k assignment mask, processed with a
single batched per-expert matmul, and gathered back with the router
weights. FLOPs = active FLOPs = tokens x top_k x (expert matmuls); the
scatter/gather are memory ops that XLA turns into all-to-all style
collectives when experts are sharded over the 'model' mesh axis.

Expert weights carry a leading E axis; spectral experts are
{"U": (E, d, k), "s": (E, k), "V": (E, f, k)} and the Stiefel retraction
vmaps over E for free (retraction broadcasting, core/retraction.py).
"""
from __future__ import annotations

import inspect
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.spectral import spectral_init, is_spectral


def _init_expert_linear(key, E, m, n, rank, dtype):
    if rank is not None:
        k = min(rank, m, n)
        ks = jax.random.split(key, E)
        return jax.vmap(lambda kk: spectral_init(kk, m, n, k, dtype=dtype))(ks)
    w = jax.random.normal(key, (E, m, n), dtype=jnp.float32) * (m ** -0.5)
    return {"w": w.astype(dtype)}


def init_moe(key, cfg, dtype=jnp.float32):
    """cfg needs: d_model, moe_d_ff, n_experts, n_shared_experts, top_k,
    mlp_rank (None => dense experts)."""
    ks = jax.random.split(key, 7)
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    r = cfg.mlp_rank
    p = {
        "router": {"w": (jax.random.normal(ks[0], (d, E), dtype=jnp.float32) * d ** -0.5).astype(dtype)},
        "gate": _init_expert_linear(ks[1], E, d, f, r, dtype),
        "up": _init_expert_linear(ks[2], E, d, f, r, dtype),
        "down": _init_expert_linear(ks[3], E, f, d, r, dtype),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        from repro.nn.mlp import init_mlp

        p["shared"] = init_mlp(ks[4], d, fs, rank=r, act="swiglu", dtype=dtype)
    return p


def _expert_matmul(p, x):
    """x: (G, E, C, d) @ expert weights -> (G, E, C, n). Spectral experts
    never materialize (E, d, n); with U/V long axes FSDP-sharded the
    cross-shard reduction payload is the RANK axis (G,E,C,k) — the
    spectral-TP collective win (DESIGN.md S5)."""
    if is_spectral(p):
        h = jnp.einsum("gecd,edk->geck", x, p["U"].astype(x.dtype))
        h = h * p["s"][None, :, None, :].astype(x.dtype)
        return jnp.einsum("geck,enk->gecn", h, p["V"].astype(x.dtype))
    return jnp.einsum("gecd,edn->gecn", x, p["w"].astype(x.dtype))


def apply_moe_sharded(p, x, cfg, *, capacity_factor: float = 1.25,
                      use_pallas: bool = False):
    """Explicit shard_map MoE (EXPERIMENTS.md §Perf, deepseek hillclimb
    iteration 2). Device (i, j) on the (data, model) mesh holds tokens-
    shard-i and experts-shard-j; it dispatches ITS tokens to ITS experts
    locally (zero-communication dispatch), so the only collectives are:

      * router logits all-gather over 'model'   (T_loc x E, tiny)
      * FSDP weight all-gather over 'data'      (k(m+n) per expert, the
        SCT factors — this is where the paper's compression pays again)
      * combine psum over 'model'               (T_loc x d bf16)

    vs. the GSPMD-inferred version whose gather/scatter partitioning
    replicated the (E, C, d) buffers (measured 224-1552 s/step collective
    at deepseek-v3 scale; this path: ~2 s/step class).
    """
    from repro.sharding import rules as rules_mod

    mesh = rules_mod._CURRENT_MESH
    b, s, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    n_model = mesh.shape.get("model", 1)
    n_data = mesh.shape.get("data", 1)
    dp = rules_mod.dp_axes(mesh)
    E_loc = E // n_model

    moe_specs = rules_mod.param_pspecs({"moe": p}, n_model, n_data)["moe"]
    # shared expert runs outside (plain jnp path handles it)
    router_experts = {k: v for k, v in p.items() if k != "shared"}
    re_specs = {k: moe_specs[k] for k in router_experts}

    from jax.sharding import PartitionSpec as P

    x_spec = P(dp, None, None)

    def f(pp, xx):
        j = jax.lax.axis_index("model")
        bl, sl, _ = xx.shape
        T_loc = bl * sl
        xt = xx.reshape(T_loc, d)

        # router: local columns -> all-gather over model (tiny)
        w_loc = pp["router"]["w"].astype(xt.dtype)              # (d, E_loc)
        logits_loc = (xt @ w_loc).astype(jnp.float32)
        logits = jax.lax.all_gather(logits_loc, "model", axis=1, tiled=True)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, K)          # (T_loc, K)
        if cfg.moe_norm_topk:
            gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

        # aux loss: local token fractions, global mean over data+model
        assign = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)
        frac_tokens = jnp.mean(jnp.sum(assign, axis=1), axis=0)
        frac_probs = jnp.mean(probs, axis=0)
        for ax in dp:
            frac_tokens = jax.lax.pmean(frac_tokens, ax)
            frac_probs = jax.lax.pmean(frac_probs, ax)
        aux = E * jnp.sum(frac_tokens * frac_probs)

        # local dispatch: this shard's tokens x this shard's experts.
        # Only int32 slot bookkeeping is (T_loc*K)-sized; token payloads
        # move via an (E_loc*C_loc)-sized gather — the (T_loc*K, d)
        # repeat never exists (§Perf iteration 3).
        C_loc = max(1, int(capacity_factor * T_loc * K / E))
        flat_idx = expert_idx.reshape(T_loc * K)
        local_e = flat_idx - j * E_loc
        in_range = (local_e >= 0) & (local_e < E_loc)
        local_e = jnp.clip(local_e, 0, E_loc - 1)
        onehot = jax.nn.one_hot(local_e, E_loc, dtype=jnp.int32)
        onehot = onehot * in_range[:, None].astype(jnp.int32)
        pos = jnp.sum((jnp.cumsum(onehot, axis=0) - onehot) * onehot, axis=-1)
        keep = in_range & (pos < C_loc)
        slot = jnp.where(keep, local_e * C_loc + pos, 0)
        # inverse map: which token (and gate) fills each slot
        tok_of_pick = jnp.repeat(jnp.arange(T_loc, dtype=jnp.int32), K)
        gate_of_pick = gate_vals.reshape(T_loc * K)
        slot_tok = jnp.zeros((E_loc * C_loc,), jnp.int32).at[slot].add(
            jnp.where(keep, tok_of_pick + 1, 0))
        slot_gate = jnp.zeros((E_loc * C_loc,), jnp.float32).at[slot].add(
            jnp.where(keep, gate_of_pick, 0.0))
        slot_mask = slot_tok > 0
        slot_tok = jnp.maximum(slot_tok - 1, 0)
        ein = jnp.where(slot_mask[:, None], xt[slot_tok], 0).reshape(E_loc, C_loc, d)

        # FSDP just-in-time weight gather over 'data' (factors are small)
        def gather_w(q, axis):
            return jax.lax.all_gather(q, "data", axis=axis, tiled=True)

        def expert_mm(wp, t):
            if is_spectral(wp):
                U = gather_w(wp["U"], 1).astype(t.dtype)         # (E_loc, m, k)
                V = gather_w(wp["V"], 1).astype(t.dtype)         # (E_loc, n, k)
                hh = jnp.einsum("ecd,edk->eck", t, U)
                hh = hh * wp["s"][:, None, :].astype(t.dtype)
                return jnp.einsum("eck,enk->ecn", hh, V)
            w = gather_w(wp["w"], 1).astype(t.dtype)
            return jnp.einsum("ecd,edn->ecn", t, w)

        g = expert_mm(pp["gate"], ein)
        u = expert_mm(pp["up"], ein)
        hh = jax.nn.silu(g) * u
        eout = expert_mm(pp["down"], hh)                          # (E_loc, C_loc, d)

        # combine: scatter-add slot contributions back to tokens (slots
        # holding different picks of a token sum correctly), then ONE
        # psum over 'model' of (T_loc, d)
        contrib = eout.reshape(E_loc * C_loc, d) * slot_gate[:, None].astype(eout.dtype)
        contrib = jnp.where(slot_mask[:, None], contrib, 0)
        partial = jnp.zeros((T_loc, d), eout.dtype).at[slot_tok].add(contrib)
        out = jax.lax.psum(partial, "model")
        return out.reshape(bl, sl, d), aux

    shard_map = getattr(jax, "shard_map", None)
    if shard_map is None:  # pragma: no cover - older jax
        from jax.experimental.shard_map import shard_map

    kw = dict(mesh=mesh, in_specs=(re_specs, x_spec), out_specs=(x_spec, P()))
    # replication-check kwarg was renamed check_rep -> check_vma across
    # jax versions; pass whichever this jax understands
    sm_params = inspect.signature(shard_map).parameters
    if "check_vma" in sm_params:
        kw["check_vma"] = False
    elif "check_rep" in sm_params:
        kw["check_rep"] = False
    out, aux = shard_map(f, **kw)(router_experts, x)

    if cfg.n_shared_experts:
        from repro.nn.mlp import apply_mlp

        out = out + apply_mlp(p["shared"], x, act="swiglu", use_pallas=use_pallas)
    return out, aux


def _dp_groups(b: int) -> int:
    """Number of local-dispatch groups = the data-parallel degree the
    batch is actually sharded over (1 when no mesh is active)."""
    from repro.sharding import rules as rules_mod

    mesh = rules_mod._CURRENT_MESH
    if mesh is None:
        return 1
    n = 1
    for a in rules_mod.dp_axes(mesh):
        n *= mesh.shape[a]
    return n if (n > 1 and b % n == 0) else 1


def _sharded_moe_ok(cfg, b, s):
    """Use the explicit shard_map path when the mesh and dims permit."""
    from repro.sharding import rules as rules_mod

    mesh = rules_mod._CURRENT_MESH
    if mesh is None or "model" not in mesh.axis_names:
        return False
    n_model = mesh.shape["model"]
    n_data = mesh.shape.get("data", 1)
    n_dp = 1
    for a in rules_mod.dp_axes(mesh):
        n_dp *= mesh.shape[a]
    return (
        cfg.n_experts % n_model == 0
        and b % max(n_dp, 1) == 0
        and cfg.d_model % n_data == 0
        and cfg.moe_d_ff % n_data == 0
    )


def apply_moe(p, x, cfg, *, capacity_factor: float = 1.25, use_pallas: bool = False):
    """x: (b, s, d) -> (b, s, d), plus the load-balance aux loss.

    Dispatches to the explicit shard_map implementation under a mesh
    (apply_moe_sharded); the pure-jnp path below is the single-device /
    fallback reference the tests validate against.

    Hierarchical LOCAL-CAPACITY dispatch (EXPERIMENTS.md §Perf, the
    deepseek hillclimb): tokens are grouped by their data shard; the
    capacity cumsum, scatter and gather-back are all group-local (no
    collective), and the single cross-shard movement is the
    (data-major -> expert-major) buffer transpose, which GSPMD lowers to
    the canonical MoE all-to-all. Capacity is enforced per shard
    (C_loc = C/n_dp), as production MoE systems do."""
    b, s_len, d = x.shape
    if _sharded_moe_ok(cfg, b, s_len):
        return apply_moe_sharded(p, x, cfg, capacity_factor=capacity_factor,
                                 use_pallas=use_pallas)
    s = s_len
    E, K = cfg.n_experts, cfg.top_k
    T = b * s
    G = _dp_groups(b)
    Tg = T // G
    xg = x.reshape(G, Tg, d)

    from repro.sharding import rules as rules_mod

    dp = rules_mod.dp_axes(rules_mod._CURRENT_MESH) if rules_mod._CURRENT_MESH else None
    xg = rules_mod.constrain(xg, dp, None, None)

    logits = jnp.einsum("gtd,de->gte", xg, p["router"]["w"].astype(xg.dtype)
                        ).astype(jnp.float32)                              # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)                        # (G, Tg, K)
    if cfg.moe_norm_topk:
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # aux load-balance loss (Switch-style): E * sum_e f_e * P_e
    assign = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)              # (G, Tg, K, E)
    frac_tokens = jnp.mean(jnp.sum(assign, axis=2), axis=(0, 1))           # (E,)
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux_loss = E * jnp.sum(frac_tokens * frac_probs)

    # group-local capacity positions: cumsum runs within each group only
    C_loc = max(1, int(capacity_factor * Tg * K / E))
    flat_idx = expert_idx.reshape(G, Tg * K)                               # (G, TgK)
    onehot = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)                  # (G, TgK, E)
    pos_in_expert = jnp.cumsum(onehot, axis=1) - onehot
    pos = jnp.sum(pos_in_expert * onehot, axis=-1)                         # (G, TgK)
    keep = pos < C_loc
    slot = jnp.where(keep, flat_idx * C_loc + pos, 0)                      # group-local

    # group-local scatter-add into (G, E*C_loc, d), then slice experts to
    # their model shard: device (i, j) holds groups-shard-i x
    # experts-shard-j, so the expert matmuls below are fully LOCAL —
    # the classic MoE all-to-all is traded for a redundant local scatter
    # plus a slice (the no-a2a dispatch).
    src = jnp.repeat(xg, K, axis=1)                                        # (G, TgK, d)
    src = jnp.where(keep[..., None], src, 0)
    buf = jnp.zeros((G, E * C_loc, d), dtype=x.dtype)
    buf = buf.at[jnp.arange(G)[:, None], slot].add(src)
    buf = rules_mod.constrain(buf, dp, None, None)
    expert_in = buf.reshape(G, E, C_loc, d)
    expert_in = rules_mod.constrain(expert_in, dp, "model", None, None)

    # per-expert SwiGLU MLP (spectral or dense), (g, e) batch all-local
    g = _expert_matmul(p["gate"], expert_in)
    u = _expert_matmul(p["up"], expert_in)
    h = jax.nn.silu(g) * u
    expert_out = _expert_matmul(p["down"], h)                              # (G, E, C_loc, d)
    expert_out = rules_mod.constrain(expert_out, dp, "model", None, None)

    # combine: gather over the model-sharded expert axis — GSPMD lowers
    # this to a local gather + psum over 'model' (the return movement)
    out_flat = expert_out.reshape(G, E * C_loc, d)
    gathered = out_flat[jnp.arange(G)[:, None], slot]                      # (G, TgK, d)
    gathered = jnp.where(keep[..., None], gathered, 0)
    weighted = gathered * gate_vals.reshape(G, Tg * K, 1).astype(gathered.dtype)
    out = jnp.sum(weighted.reshape(G, Tg, K, d), axis=2)                   # (G, Tg, d)

    if cfg.n_shared_experts:
        from repro.nn.mlp import apply_mlp

        out = out + apply_mlp(p["shared"], xg, act="swiglu", use_pallas=use_pallas)
    return out.reshape(b, s, d), aux_loss
