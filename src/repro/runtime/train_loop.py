"""Fault-tolerant training loop.

Production behaviours, all exercised by tests on CPU:

  * checkpoint/restart: periodic async checkpoints with rotation; on
    (re)start the loop resumes from the newest complete checkpoint and
    regenerates the data stream deterministically from the step index —
    a restarted run is bit-identical to an uninterrupted one.
  * failure injection: ``failure_hook`` lets tests (and chaos drills)
    raise mid-run; the loop converts unhandled step failures into a
    clean checkpoint-backed restart up to ``max_restarts``.
  * straggler mitigation: per-step deadline; steps that exceed it are
    counted and surfaced (on real multi-host this feeds the
    reschedule/evict policy; here it is monitored + tested).
  * elastic scaling: ``CheckpointManager`` stores host arrays, so a
    restart may use a different mesh/DP width — resharding happens at
    load via the new mesh's NamedShardings.
  * adaptive rank: an optional ``rank_controller`` (rank/controller.py)
    is consulted at every step boundary; when its schedule fires, the
    loop swaps in the resized state, the re-jitted step function, and
    the regenerated sharding tree mid-run. Resize events are recorded
    in ``controller.resizes`` and counted here in ``rank_resizes``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    keep_checkpoints: int = 3
    step_deadline_s: Optional[float] = None   # straggler threshold
    max_restarts: int = 3
    log_every: int = 10


class TrainLoop:
    def __init__(
        self,
        step_fn: Callable,                      # jitted (state, batch) -> (state, metrics)
        batch_iter_factory: Callable[[int], Iterator],  # start_step -> iterator
        ckpt_dir: str,
        cfg: TrainLoopConfig,
        init_state_fn: Callable[[], Any],
        state_shardings: Any = None,
        metrics_cb: Optional[Callable[[int, Dict], None]] = None,
        failure_hook: Optional[Callable[[int], None]] = None,
        rank_controller: Optional[Any] = None,
        checkpoint_manager: Optional[CheckpointManager] = None,
    ):
        self.step_fn = step_fn
        self.batch_iter_factory = batch_iter_factory
        self.cfg = cfg
        # an injected manager wins — the API facade passes one carrying
        # the serialized RunSpec so every sidecar is self-describing
        self.mgr = checkpoint_manager or CheckpointManager(
            ckpt_dir, keep=cfg.keep_checkpoints)
        self.init_state_fn = init_state_fn
        self.state_shardings = state_shardings
        self.metrics_cb = metrics_cb
        self.failure_hook = failure_hook
        self.rank_controller = rank_controller
        self.straggler_steps = 0
        self.restarts = 0
        self.rank_resizes = 0
        # mixed precision: overflow-skipped steps, mirrored from the
        # authoritative checkpointed counter state["loss_scale"]["skipped"]
        # when the run finishes
        self.overflow_steps = 0

    # ------------------------------------------------------------------
    def _start_state(self):
        step, state = self.mgr.restore_latest(self.state_shardings)
        if state is None:
            return 0, self.init_state_fn()
        return step, state

    def run(self) -> Any:
        attempt = 0
        while True:
            try:
                return self._run_once()
            except Exception:  # noqa: BLE001 — any step failure
                attempt += 1
                self.restarts += 1
                if attempt > self.cfg.max_restarts:
                    raise
                # flush any in-flight async checkpoint write before the
                # restart touches the checkpoint directory: a writer
                # still running would race the restarted attempt's
                # restore_latest/save. Writer errors are swallowed —
                # the restart path must not die on a failed background
                # save (the restore picks the newest *complete*
                # checkpoint either way).
                try:
                    self.mgr.wait()
                except Exception:  # noqa: BLE001 — writer error
                    pass
                # fall through: restart from the latest checkpoint

    def _apply_rank_decision(self, step: int, state, metrics=None):
        """Consult the rank controller at a step boundary; on a resize,
        swap in the new state, the re-jitted step_fn, and the
        regenerated shardings (stale old-shape executables are simply
        abandoned — jit keeps them cached but they are never called)."""
        if self.rank_controller is None:
            return state
        result = self.rank_controller.maybe_resize(step, state, metrics)
        if result is None:
            return state
        state, self.step_fn, self.state_shardings = result
        self.rank_resizes += 1
        return state

    def _run_once(self) -> Any:
        start_step, state = self._start_state()
        # resize-on-restore: a restored checkpoint may carry a different
        # rank than the schedule dictates at this step (the schedule is
        # a pure function of the global step, so replay is consistent)
        state = self._apply_rank_decision(start_step, state)
        batches = self.batch_iter_factory(start_step)
        step = start_step
        while step < self.cfg.total_steps:
            batch = next(batches)
            t0 = time.time()
            if self.failure_hook is not None:
                self.failure_hook(step)
            state, metrics = self.step_fn(state, batch)
            # straggler detection needs the actual step time
            jax.block_until_ready(metrics["loss"])
            dt = time.time() - t0
            if self.cfg.step_deadline_s and dt > self.cfg.step_deadline_s:
                self.straggler_steps += 1
            step += 1
            state = self._apply_rank_decision(step, state, metrics)
            if self.metrics_cb and step % self.cfg.log_every == 0:
                self.metrics_cb(step, {k: float(np.asarray(v)) for k, v in metrics.items()})
            if step % self.cfg.checkpoint_every == 0 or step == self.cfg.total_steps:
                # fetch to host *before* handing off to the async writer:
                # the next step donates these device buffers (train.py
                # jits with donate_argnums), and a save thread reading
                # them after donation sees deleted arrays
                self.mgr.save(step, jax.device_get(state))
        self.mgr.wait()
        if isinstance(state, dict) and "loss_scale" in state:
            # derived once at the end, not per step — no extra host
            # readback in the hot loop
            self.overflow_steps = int(np.asarray(state["loss_scale"]["skipped"]))
        return state
