from repro.runtime.train_loop import TrainLoop, TrainLoopConfig
from repro.runtime.compression import compress_int8, decompress_int8, ErrorFeedbackState

__all__ = [
    "TrainLoop",
    "TrainLoopConfig",
    "compress_int8",
    "decompress_int8",
    "ErrorFeedbackState",
]
