"""Gradient compression for cross-pod data-parallel all-reduce:
int8 quantization with error feedback (EF-SGD style).

SCT note: spectral-factor gradients are already k(m+n+1) — the paper's
memory compression is also a *communication* compression, so this
int8 path matters mostly for the remaining dense leaves (attention,
embeddings), and for multi-pod meshes where the 'pod' axis crosses slow
links (DESIGN.md S5).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class ErrorFeedbackState(NamedTuple):
    residual: Any  # pytree mirroring grads


def init_error_feedback(grads_like: Any) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        residual=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
    )


def compress_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8: returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads: Any, axis_name: str, ef: ErrorFeedbackState
                    ) -> Tuple[Any, ErrorFeedbackState]:
    """int8 all-reduce with error feedback, for use inside shard_map
    over the cross-pod DP axis. The quantization error is fed back into
    the next step's gradients, preserving convergence (EF-SGD).

    Each shard's natural int8 scale is its own max, so payloads from
    different shards live on different scales. Summing raw int8
    payloads and multiplying by the *averaged* scale (the old math
    here) is biased whenever shard scales differ: a shard with tiny
    gradients has its contribution inflated by a neighbour's large
    scale and vice versa, with error unbounded in the scale ratio.
    Instead, all shards agree on the max scale first (a scalar pmax —
    negligible next to the payload), requantize to that shared scale,
    and psum the int8 payload: the sum is then exact int arithmetic
    under one scale, the wire still carries int8, and the per-element
    error of the mean is bounded by shared_scale / 2 (each shard's
    rounding error <= shared_scale/2, averaged over n). The error
    feedback residual keys off the *shared-scale* dequantization, so
    what the wire lost this step is exactly what re-enters next step."""

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        _, scale = compress_int8(gf)
        shared = jax.lax.pmax(scale, axis_name)
        q = jnp.clip(jnp.round(gf / shared), -127, 127).astype(jnp.int8)
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        out = summed.astype(jnp.float32) * shared / n
        new_r = gf - decompress_int8(q, shared)
        return out.astype(g.dtype), new_r

    flat_g, td = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(ef.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = jax.tree.unflatten(td, [o[0] for o in outs])
    new_r = jax.tree.unflatten(td, [o[1] for o in outs])
    return new_g, ErrorFeedbackState(residual=new_r)
