"""Multi-pod dry-run: prove the distribution config is coherent by
lowering + compiling every (architecture x input-shape x mesh) cell with
512 placeholder host devices, and extracting the roofline inputs
(memory_analysis, cost_analysis, collective bytes from the HLO).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both
  ... --variant <name>   # perf-hillclimb variants (EXPERIMENTS.md §Perf)

Results append to reports/dryrun/<arch>__<shape>__<mesh>[__<variant>].json.
"""
# The VERY FIRST lines, before ANY other import (jax locks device count
# on first init):
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Optional, Sequence, Union  # noqa: E402

import jax  # noqa: E402

from repro.api.specs import ModelSpec  # noqa: E402
from repro.config import ARCH_IDS, SHAPES, shape_applicable  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch import steps as steps_mod  # noqa: E402
from repro.launch.variants import apply_variant, VARIANTS  # noqa: E402
from repro.roofline.analysis import roofline_terms, model_flops  # noqa: E402

ASSIGNED = ARCH_IDS[:10]  # the 10 assigned architectures

REPORT_DIR = os.path.join(os.path.dirname(__file__), "../../../reports/dryrun")


def run_cell(model: Union[ModelSpec, str], shape_name: str, multi_pod: bool,
             variant: str | None = None, report_dir: str = REPORT_DIR) -> dict:
    """Lower + compile one (model x shape x mesh) cell. ``model`` is a
    ModelSpec (the API's registry reference — a bare arch-id string is
    coerced for convenience), so sweeps route through the same
    declarative spec the launchers use."""
    if isinstance(model, str):
        model = ModelSpec(arch=model)
    arch = model.arch
    cfg = model.config()
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}__{shape_name}__{mesh_name}" + (f"__{variant}" if variant else "")
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "variant": variant or "baseline"}
    if not ok:
        result["status"] = "skip"
        result["reason"] = why
        _write(report_dir, tag, result)
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    if variant:
        cfg = apply_variant(cfg, shape, variant)

    t0 = time.time()
    try:
        lowered = steps_mod.lower_step(cfg, shape, mesh)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        terms = roofline_terms(cost, hlo, chips, model_flops(cfg, shape))

        result.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            },
            roofline=terms.as_dict(),
        )
    except Exception as e:  # noqa: BLE001 - report and continue the sweep
        result.update(status="error", error=f"{type(e).__name__}: {e}",
                      trace=traceback.format_exc()[-2000:])
    _write(report_dir, tag, result)
    return result


def _write(report_dir: str, tag: str, result: dict) -> None:
    os.makedirs(report_dir, exist_ok=True)
    with open(os.path.join(report_dir, tag + ".json"), "w") as f:
        json.dump(result, f, indent=1, default=str)


def main(argv: Optional[Sequence[str]] = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="16x16", choices=["16x16", "2x16x16", "both"])
    ap.add_argument("--variant", default=None, choices=[None] + list(VARIANTS))
    ap.add_argument("--report-dir", default=REPORT_DIR)
    args = ap.parse_args(argv)

    archs = ASSIGNED if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.mesh == "both" else [args.mesh == "2x16x16"]

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                r = run_cell(ModelSpec(arch=arch), shape, mp, args.variant,
                             args.report_dir)
                status = r["status"]
                extra = ""
                if status == "ok":
                    rt = r["roofline"]
                    extra = (f" dominant={rt['dominant']}"
                             f" step={rt['step_time_s']*1e3:.2f}ms"
                             f" mfu={rt['mfu']:.3f}"
                             f" compile={r['compile_s']}s")
                elif status == "error":
                    extra = " " + r["error"][:120]
                print(f"[dryrun] {arch} {shape} {r['mesh']} -> {status}{extra}",
                      flush=True)


if __name__ == "__main__":
    main()
