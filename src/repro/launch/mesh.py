"""Production mesh definitions.

Functions, not module-level constants — importing this module never
touches jax device state (required: smoke tests see 1 CPU device, only
dryrun.py forces 512 host devices).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (v5e pod); 2 pods = 512 chips multi-pod.
    Axis order puts 'model' innermost — ICI-contiguous for the TP
    collectives, with 'pod' outermost crossing the (slower) inter-pod
    links only for DP gradient all-reduces (DESIGN.md S5)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CPU tests (requires XLA host-device override)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
