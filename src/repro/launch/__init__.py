"""Launchers: mesh construction, train/serve entry points, and the
multi-pod dry-run (lower + compile proof for every arch x shape x mesh).
"""
