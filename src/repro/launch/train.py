"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch smollm2-1.7b \\
      --reduced --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/run1

Runs on whatever devices exist (1 CPU here; the production mesh on a
real slice) with the same code path the dry-run proves at 512 devices:
sharded state, jitted train_step with donation, fault-tolerant loop.
"""
from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config, SHAPES
from repro.config.shapes import ShapeSpec
from repro.data.synthetic import SyntheticLMDataset
from repro.launch import steps as steps_mod
from repro.optim import make_sct_optimizer
from repro.models.model import init_model
from repro.rank import RankController, parse_rank_schedule
from repro.runtime.train_loop import TrainLoop, TrainLoopConfig
from repro.sharding.rules import set_current_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm2-1.7b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--precision", choices=["fp32", "bf16", "mixed"], default=None,
                    help="fp32: everything fp32; bf16: bf16 factors+compute; "
                         "mixed: fp32 master factors, bf16 compute, dynamic "
                         "loss scaling with overflow skip (default: legacy "
                         "config dtype, no scaling)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--rank-schedule", default=None,
                    help="adaptive spectral rank schedule: 'static:K' "
                         "(resize once, incl. on restore), "
                         "'step:S1=K1[,S2=K2...]' (step-triggered), or "
                         "'energy:T[,min=..][,max=..][,every=..][,factor=..]"
                         "[,grow_below=..]' (telemetry-triggered on the "
                         "rank/energy_top metric). See src/repro/rank/.")
    ap.add_argument("--telemetry", action="store_true",
                    help="emit spectral telemetry (rank/* metrics) in the "
                         "train log even without a rank schedule")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    opt = make_sct_optimizer(cfg, lr=args.lr, warmup=min(100, args.steps // 10 + 1),
                             total_steps=args.steps, precision=args.precision)

    n_dev = jax.device_count()
    mesh = None
    if n_dev > 1:
        n_model = 1
        for cand in (16, 8, 4, 2, 1):
            if n_dev % cand == 0 and cfg.d_ff % cand == 0:
                n_model = cand
                break
        mesh = jax.make_mesh((n_dev // n_model, n_model), ("data", "model"))
        set_current_mesh(mesh)

    rank_schedule = parse_rank_schedule(args.rank_schedule)
    telemetry = args.telemetry or rank_schedule is not None

    step_fn = steps_mod.make_train_step(cfg, opt, microbatches=args.microbatches,
                                        telemetry=telemetry)
    shape = ShapeSpec("cli", args.seq, args.batch, "train")
    if mesh is not None:
        state_sh, batch_sh = steps_mod.train_shardings(cfg, shape, mesh)
        step_fn = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                          out_shardings=(state_sh, None), donate_argnums=(0,))
        state_shardings = state_sh
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0,))
        state_shardings = None

    controller = None
    if rank_schedule is not None:
        controller = RankController(cfg, opt, rank_schedule, mesh=mesh,
                                    shape=shape, microbatches=args.microbatches,
                                    seed=args.seed)

    ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=args.seq, seed=args.seed)

    def batch_iter(start_step):
        step = start_step
        while True:
            t, l = ds.batch(step, args.batch)
            batch = {"tokens": jnp.asarray(t), "labels": jnp.asarray(l)}
            if cfg.family == "encdec":
                from repro.data.vision_stub import audio_frame_stub
                batch["encoder_frames"] = jnp.asarray(
                    audio_frame_stub(args.batch, cfg.encoder_seq, cfg.d_model))
            yield batch
            step += 1

    def init_state():
        params = init_model(jax.random.PRNGKey(args.seed), cfg)
        return opt.init(params)

    def log(step, metrics):
        line = f"step {step:6d}  loss {metrics['loss']:.4f}  ce {metrics['ce_loss']:.4f}"
        if "loss_scale" in metrics:
            line += f"  scale {metrics['loss_scale']:.0f}"
        if "rank/mean" in metrics:
            line += (f"  rank {metrics['rank/mean']:.0f}"
                     f" (eff {metrics['rank/eff_mean']:.1f},"
                     f" energy {metrics['rank/energy_top']:.3f},"
                     f" ortho {metrics['rank/ortho_max']:.1e})")
        print(line, flush=True)

    loop = TrainLoop(
        step_fn=step_fn,
        batch_iter_factory=batch_iter,
        ckpt_dir=args.ckpt_dir,
        cfg=TrainLoopConfig(total_steps=args.steps, checkpoint_every=args.ckpt_every),
        init_state_fn=init_state,
        state_shardings=state_shardings,
        metrics_cb=log,
        rank_controller=controller,
    )
    state = loop.run()
    if controller is not None:
        for at, old, new in controller.resizes:
            print(f"rank resize @ step {at}: {old} -> {new}")
    from repro.core.tree import max_orthogonality_error

    print("final ortho error:", float(max_orthogonality_error(state["params"])))
    if "loss_scale" in state:
        print(f"loss scale: {float(state['loss_scale']['scale']):.0f}  "
              f"overflow-skipped steps: {int(state['loss_scale']['skipped'])} "
              f"(loop saw {loop.overflow_steps})")


if __name__ == "__main__":
    main()
