"""Training launcher — a thin argparse adapter over the experiment API
(api/specs.py + api/trainer.py). Every flag maps onto a RunSpec field;
the Trainer facade owns the wiring (mesh, optimizer, rank controller,
fault-tolerant loop), so this file is only flag parsing and end-of-run
printing.

  PYTHONPATH=src python -m repro.launch.train --arch smollm2-1.7b \\
      --reduced --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/run1

(equivalently: ``python -m repro train ...``). ``--dump-spec`` prints
the resolved RunSpec JSON and exits — the declarative record of what
the flags mean, replayable programmatically via ``RunSpec.from_json``.

Runs on whatever devices exist (1 CPU here; the production mesh on a
real slice) with the same code path the dry-run proves at 512 devices:
sharded state, jitted train_step with donation, fault-tolerant loop.
"""
from __future__ import annotations

import argparse
from typing import Optional, Sequence

from repro.api import (
    CheckpointSpec,
    ModelSpec,
    PrecisionSpec,
    RankScheduleSpec,
    RunSpec,
    Trainer,
    TrainSpec,
    log_metrics,
)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm2-1.7b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--precision",
                    choices=["legacy", "fp32", "bf16", "mixed"],
                    default="legacy",
                    help="legacy: compute in the config dtype, no scaling "
                         "(the default, now an explicit mode); fp32: "
                         "everything fp32; bf16: bf16 factors+compute; "
                         "mixed: fp32 master factors, bf16 compute, dynamic "
                         "loss scaling with overflow skip")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--rank-schedule", default=None,
                    help="adaptive spectral rank schedule: 'static:K' "
                         "(resize once, incl. on restore), "
                         "'step:S1=K1[,S2=K2...]' (step-triggered), or "
                         "'energy:T[,min=..][,max=..][,every=..][,factor=..]"
                         "[,grow_below=..]' (telemetry-triggered on the "
                         "rank/energy_top metric). See src/repro/rank/.")
    ap.add_argument("--telemetry", action="store_true",
                    help="emit spectral telemetry (rank/* metrics) in the "
                         "train log even without a rank schedule")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dump-spec", action="store_true",
                    help="print the resolved RunSpec JSON and exit")
    return ap


def build_spec(args: argparse.Namespace) -> RunSpec:
    """argparse Namespace -> RunSpec: the whole adapter."""
    return RunSpec(
        model=ModelSpec(arch=args.arch, reduced=args.reduced),
        train=TrainSpec(steps=args.steps, batch=args.batch, seq=args.seq,
                        lr=args.lr, microbatches=args.microbatches,
                        seed=args.seed, telemetry=args.telemetry),
        precision=PrecisionSpec(mode=args.precision or "legacy"),
        rank=RankScheduleSpec(schedule=args.rank_schedule),
        checkpoint=CheckpointSpec(directory=args.ckpt_dir,
                                  every=args.ckpt_every),
    )


def main(argv: Optional[Sequence[str]] = None) -> None:
    args = build_parser().parse_args(argv)
    spec = build_spec(args)
    if args.dump_spec:
        print(spec.to_json(indent=2))
        return

    trainer = Trainer(spec, metrics_cb=log_metrics)
    state = trainer.fit()

    if trainer.controller is not None:
        for at, old, new in trainer.controller.resizes:
            print(f"rank resize @ step {at}: {old} -> {new}")
    from repro.core.tree import max_orthogonality_error

    print("final ortho error:", float(max_orthogonality_error(state["params"])))
    if "loss_scale" in state:
        print(f"loss scale: {float(state['loss_scale']['scale']):.0f}  "
              f"overflow-skipped steps: {int(state['loss_scale']['skipped'])} "
              f"(loop saw {trainer.loop.overflow_steps})")


if __name__ == "__main__":
    main()
