"""Serving launcher: batched prefill + decode loop with a static-shape
cache (compile once, serve any request length up to max_seq).

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \\
      --reduced --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config
from repro.models.model import (
    init_model,
    init_decode_state,
    prefill,
    decode_step,
)


def sample_greedy(logits):
    return jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    key = jax.random.PRNGKey(args.seed)
    params = init_model(key, cfg)
    max_seq = args.prompt_len + args.gen

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)

    extra_prefill, extra_decode = {}, {}
    if cfg.family == "encdec":
        from repro.data.vision_stub import audio_frame_stub
        from repro.models.encdec import encode

        frames = jnp.asarray(audio_frame_stub(args.batch, cfg.encoder_seq, cfg.d_model))
        extra_prefill["encoder_frames"] = frames
        extra_decode["encoder_out"] = encode(params, frames, cfg)

    state = init_decode_state(cfg, args.batch, max_seq)

    prefill_fn = jax.jit(lambda p, t, s, **e: prefill(p, t, cfg, s, **e))
    decode_fn = jax.jit(
        lambda p, t, s, n, **e: decode_step(p, t, s, n, cfg, **e),
        donate_argnums=(2,),
    )

    t0 = time.time()
    logits, state = prefill_fn(params, prompts, state, **extra_prefill)
    tok = sample_greedy(logits)
    jax.block_until_ready(tok)
    t_prefill = time.time() - t0

    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        cache_len = jnp.int32(args.prompt_len + i)
        logits, state = decode_fn(params, tok, state, cache_len, **extra_decode)
        tok = sample_greedy(logits)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.asarray(jnp.concatenate(out_tokens, axis=1))
    print(f"prefill: {t_prefill*1e3:.1f} ms for {args.batch}x{args.prompt_len} tokens")
    print(f"decode:  {t_decode/max(args.gen-1,1)*1e3:.2f} ms/token "
          f"({args.batch} sequences)")
    print("generated token ids (first sequence):", gen[0][:16], "...")


if __name__ == "__main__":
    main()
