"""Serving launcher — a thin argparse adapter over the experiment API:
flags map onto a RunSpec (api/specs.py) and the paged/streaming path is
the :class:`repro.api.Server` facade; this file keeps only flag parsing,
trace construction, and the --verify oracle checks.

Static mode (the original path): one batch, one shared prompt length,
dense ``(batch, max_seq)`` cache — compile once, serve any length up to
max_seq:

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \\
      --reduced --batch 4 --prompt-len 16 --gen 32

Streaming mode (continuous batching + paged KV cache): replays a trace
of staggered, variable-length requests through the Server —
requests arrive mid-flight, join free decode slots, and share one page
pool:

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \\
      --reduced --paged --stream [--verify]

(equivalently: ``python -m repro serve ...``; ``--dump-spec`` prints
the resolved RunSpec JSON and exits.)

``--verify`` re-decodes every request through the static path and
checks the greedy outputs match token for token.

Production traffic shape (streaming mode): ``--shared-prefix N`` opens
every prompt with the same N-token system prefix, ``--prefix-cache``
serves repeated page-aligned prefixes from the refcounted prefix index
(only prompt tails are prefilled), ``--chunked-prefill`` splits prompt
tails into ``--prefill-budget``-sized chunks interleaved with decode
steps, and ``--request-timeout`` bounds per-request service time in
engine steps (expired requests are evicted with their partial output).
Recurrent families opt out of prefix sharing/chunking — see
docs/serving.md.

Self-speculative decoding (streaming mode): ``--speculative-rank 8``
drafts each burst with a rank-8 truncation of the same weights and
verifies at full rank (``--speculative-rank 4,8`` stages the
verification through a rank ladder); ``--draft-tokens`` sets the burst
length. Output is the target's greedy decode token for token —
``--verify`` applies unchanged — and the run prints the acceptance
rate and tokens per decode step (docs/serving.md has the full story).

Int8 serving (``--quantize int8``, either mode): spectral factors and
dense projections are quantized per-channel to int8
(serving/quantize.py) and dequantized on the fly at apply time. With
``--verify`` the oracle is the *fp32 static path over the dequantized
weights* — the greedy outputs of the int8 runtime must match it token
for token (same effective weights, so any divergence is a bug in the
on-the-fly dequant path, not quantization noise). The greedy agreement
against the original unquantized weights is reported as a diagnostic.

Checkpoint serving: ``--ckpt-dir`` loads the newest snapshot (with
``--serve-rank`` resizing spectral groups at load). The zero-flag form
— model and serving geometry read from the checkpoint's embedded
RunSpec — is the programmatic ``Server.from_checkpoint(path)``
(docs/api.md); the CLI keeps explicit flags so pre-API checkpoints and
flag overrides keep working.
"""
from __future__ import annotations

import argparse
import functools
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (
    ModelSpec,
    RunSpec,
    ServeSpec,
    ShardingSpec,
    StreamingSpec,
    Server,
)
from repro.models.model import (
    init_model,
    init_decode_state,
    prefill,
    decode_step,
)


def sample_greedy(logits):
    return jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)


def build_trace(args, vocab, pcfg):
    """Staggered mixed-length request trace: lengths cycle through a
    spread around --prompt-len, arrivals step every --arrive-every
    engine steps. With --shared-prefix, every prompt starts with the
    same system-prompt prefix (the prefix-cache workload); with
    --request-timeout, each request carries that deadline."""
    from repro.serving import Request

    rng = np.random.default_rng(args.seed)
    shared = rng.integers(0, vocab, size=(args.shared_prefix,)).astype(np.int32) \
        if args.shared_prefix else np.zeros((0,), np.int32)
    lens = [max(2, args.prompt_len + d) for d in (-7, 0, 5, -3, 9, 2, -5, 12)]
    reqs = []
    for i in range(args.requests):
        plen = lens[i % len(lens)]
        gen = max(1, args.gen + (i % 3) * 4 - 4)
        if gen + 2 + args.shared_prefix > pcfg.max_seq:
            raise SystemExit(
                f"request {i}: gen={gen} (spread from --gen {args.gen}) plus a "
                f">=2-token prompt (+{args.shared_prefix} shared prefix) exceeds "
                f"page-size x pages-per-seq = {pcfg.max_seq} tokens")
        plen = min(plen, pcfg.max_seq - gen - args.shared_prefix)
        tail = rng.integers(0, vocab, size=(plen,)).astype(np.int32)
        reqs.append(Request(
            rid=i,
            prompt=np.concatenate([shared, tail]),
            max_new_tokens=gen,
            arrival=i // max(1, args.slots) * args.arrive_every,
            deadline=args.request_timeout,
        ))
    return reqs


@functools.lru_cache(maxsize=None)
def _reference_step_fns(cfg):
    """Jitted prefill/decode for the oracle (one compile per config +
    shape, shared across requests)."""
    pf = jax.jit(lambda p, t, s: prefill(p, t, cfg, s))
    df = jax.jit(lambda p, t, s, n: decode_step(p, t, s, n, cfg))
    return pf, df


def static_greedy_reference(cfg, params, prompt, gen, max_seq):
    """Batch-1 static-cache greedy decode — the token-for-token oracle
    for --verify (also used by tests/test_serving.py)."""
    prefill_fn, decode_fn = _reference_step_fns(cfg)
    state = init_decode_state(cfg, 1, max_seq)
    logits, state = prefill_fn(params, jnp.asarray(prompt)[None], state)
    toks = [int(jnp.argmax(logits[0, -1]))]
    for i in range(gen - 1):
        tok = jnp.asarray([[toks[-1]]], dtype=jnp.int32)
        logits, state = decode_fn(params, tok, state, jnp.int32(len(prompt) + i))
        toks.append(int(jnp.argmax(logits[0, -1])))
    return np.asarray(toks, dtype=np.int32)


def run_stream(args, spec: RunSpec, params) -> None:
    from repro.serving import dequantize_tree

    server = Server(spec, params)
    cfg, pcfg = server.cfg, spec.serve.paged_config()
    trace = build_trace(args, cfg.vocab, pcfg)
    print(f"streaming {len(trace)} requests, prompt lens "
          f"{sorted({r.prompt_len for r in trace})}, slots={pcfg.max_slots}, "
          f"pool={pcfg.num_pages}x{pcfg.page_size} tokens")
    if server.engine.tp > 1:
        print(f"tensor parallel: tp={server.engine.tp} over "
              f"{server.engine.tp} devices (mesh axis 'model')")
    out = server.run(trace)
    server.engine.sched.check_invariants()
    st = server.stats()
    if args.disaggregate:
        print(f"disaggregated prefill: {int(st['kv_transfer_pages'])} pages "
              f"shipped ({int(st['kv_transfer_bytes'])} bytes raw, "
              f"{int(st['kv_transfer_wire_bytes'])} bytes on the "
              f"{args.kv_transfer} wire), prefill pool peak "
              f"{int(st['prefill_pool_peak_pages'])} pages")
    print(f"served {int(st['requests'])} requests: "
          f"{int(st['prefill_tokens'])} prefill + {int(st['generated_tokens'])} generated "
          f"tokens in {st['wall_s']:.2f}s ({st['tokens_per_s']:.1f} tok/s)")
    print(f"paged attention cache: {int(st['attn_cache_bytes'])} bytes "
          f"({pcfg.num_pages}+1 pages x {pcfg.page_size} tokens)")
    if args.prefix_cache:
        saved = int(st["prefix_shared_tokens"])
        total = int(st["prompt_tokens"])
        hit = st.get("prefix_hit_pages", 0.0)
        look = max(st.get("prefix_lookup_pages", 0.0), 1.0)
        print(f"prefix cache: {saved}/{total} prompt tokens served from cache "
              f"({100.0 * saved / max(total, 1):.0f}% prefill saved), "
              f"page hit-rate {100.0 * hit / look:.0f}%"
              + ("" if server.engine.prefix_cache else
                 " [family opted out: recurrent state, exact-match only]"))
    print(f"inter-token latency: p50 {st['itl_p50_s'] * 1e3:.1f} ms, "
          f"p99 {st['itl_p99_s'] * 1e3:.1f} ms")
    if args.streaming_window is not None:
        line = (f"streaming: sink={args.sink_pages}p + "
                f"window={args.streaming_window}p resident cap, "
                f"{int(st['stream_evictions'])} pages evicted")
        if args.cold_kv == "int8":
            line += (f", {int(st['stream_demotions'])} demoted to int8 "
                     f"({int(st['cold_page_bytes'])} shadow bytes)")
        print(line)
    if args.speculative_rank is not None:
        # speculative output IS the target's greedy output (acceptance
        # only moves latency), so --verify below applies unchanged
        print(f"speculative (ranks {args.speculative_rank} -> full, "
              f"{int(st['draft_tokens'])} draft tokens/burst): "
              f"acceptance {st['acceptance_rate']:.2f} "
              f"({int(st['draft_accepted'])}/{int(st['draft_proposed'])} "
              f"drafted tokens kept), "
              f"{st['tokens_per_step']:.2f} tokens/decode-step "
              f"over {int(st['decode_steps'])} steps")
    if args.request_timeout is not None:
        print(f"deadlines: {int(st['timed_out'])} timed out, "
              f"{int(st['cancelled'])} cancelled"
              + (f", {int(st['shed'])} shed" if args.scheduler == "slo"
                 else ""))
    if args.quantize:
        print(f"weights: {int(st['weight_bytes'])} bytes {args.quantize} "
              f"(fp32 {int(st['weight_bytes_fp'])} bytes, "
              f"{st['weight_bytes_fp'] / st['weight_bytes']:.2f}x smaller)")
    first = trace[0]
    print("generated token ids (request 0):", out[first.rid][:16], "...")

    if args.verify:
        # oracle: fp32 static path over the engine's effective weights
        # (dequantized when --quantize) — must match token for token.
        # Under streaming the guarantee holds only within the identity
        # horizon (sink + window tokens); longer requests are by design
        # lossy and are skipped here.
        horizon = None
        if args.streaming_window is not None:
            from repro.serving import identity_horizon

            horizon = identity_horizon(spec.serve.streaming.config(), pcfg)
        oracle_params = dequantize_tree(server.params) if args.quantize else params
        bad = skipped = 0
        for r in trace:
            if horizon is not None and r.prompt_len + r.max_new_tokens > horizon:
                skipped += 1
                continue
            ref = static_greedy_reference(cfg, oracle_params, r.prompt,
                                          r.max_new_tokens, pcfg.max_seq)
            got = out[r.rid]
            if server.last_statuses.get(r.rid) != "finished":
                # timed-out/cancelled: partial output must still be a
                # prefix of the oracle's tokens
                ok = np.array_equal(ref[:len(got)], got)
            else:
                ok = np.array_equal(ref, got)
            if not ok:
                bad += 1
                print(f"request {r.rid}: MISMATCH\n  static {ref}\n  paged  {got}")
        if bad:
            raise SystemExit(f"{bad}/{len(trace)} requests diverged from the static path")
        checked = len(trace) - skipped
        print(f"verify: all {checked} requests match the fp32 static path "
              f"token-for-token"
              + (f" ({skipped} beyond the {horizon}-token streaming "
                 f"identity horizon skipped)" if skipped else ""))
        if args.quantize:
            agree = total = 0
            for r in trace:
                ref = static_greedy_reference(cfg, params, r.prompt,
                                              r.max_new_tokens, pcfg.max_seq)
                agree += int(np.sum(ref == out[r.rid]))
                total += ref.size
            print(f"diagnostic: {agree}/{total} greedy tokens agree with the "
                  f"unquantized fp32 weights")


def run_static(args, cfg, params) -> np.ndarray:
    key = jax.random.PRNGKey(args.seed)
    max_seq = args.prompt_len + args.gen
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)

    extra_prefill, extra_decode = {}, {}
    if cfg.family == "encdec":
        from repro.data.vision_stub import audio_frame_stub
        from repro.models.encdec import encode

        frames = jnp.asarray(audio_frame_stub(args.batch, cfg.encoder_seq, cfg.d_model))
        extra_prefill["encoder_frames"] = frames
        extra_decode["encoder_out"] = encode(params, frames, cfg)

    state = init_decode_state(cfg, args.batch, max_seq)

    prefill_fn = jax.jit(lambda p, t, s, **e: prefill(p, t, cfg, s, **e))
    decode_fn = jax.jit(
        lambda p, t, s, n, **e: decode_step(p, t, s, n, cfg, **e),
        donate_argnums=(2,),
    )

    t0 = time.time()
    logits, state = prefill_fn(params, prompts, state, **extra_prefill)
    tok = sample_greedy(logits)
    jax.block_until_ready(tok)
    t_prefill = time.time() - t0

    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        cache_len = jnp.int32(args.prompt_len + i)
        logits, state = decode_fn(params, tok, state, cache_len, **extra_decode)
        tok = sample_greedy(logits)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.asarray(jnp.concatenate(out_tokens, axis=1))
    print(f"prefill: {t_prefill*1e3:.1f} ms for {args.batch}x{args.prompt_len} tokens")
    print(f"decode:  {t_decode/max(args.gen-1,1)*1e3:.2f} ms/token "
          f"({args.batch} sequences)")
    print("generated token ids (first sequence):", gen[0][:16], "...")
    return gen


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    # streaming / paged mode
    ap.add_argument("--paged", action="store_true",
                    help="use the paged KV cache (serving/paged_cache.py)")
    ap.add_argument("--stream", action="store_true",
                    help="continuous batching over a staggered request trace")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4, help="decode slots")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=64)
    ap.add_argument("--pages-per-seq", type=int, default=8)
    ap.add_argument("--arrive-every", type=int, default=4,
                    help="engine steps between arrival waves")
    ap.add_argument("--prefill-budget", type=int, default=64,
                    help="max prefill tokens admitted per engine step "
                         "(with --chunked-prefill, also the chunk size)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share page-aligned prompt prefixes across requests "
                         "(refcounted copy-on-write pages; recurrent families "
                         "opt out — see docs/serving.md)")
    ap.add_argument("--chunked-prefill", action="store_true",
                    help="split prompt prefill into budget-sized chunks "
                         "interleaved with decode steps (tail-latency control "
                         "for long prompts)")
    ap.add_argument("--request-timeout", type=int, default=None,
                    help="per-request deadline in engine steps; expired "
                         "requests are evicted with their partial output")
    ap.add_argument("--scheduler", choices=["fifo", "slo"], default="fifo",
                    help="admission policy: fifo (arrival order) or slo "
                         "(per-tenant fair share + priority + deadline-"
                         "aware shedding — serving/scheduler.py)")
    ap.add_argument("--speculative-rank", default=None,
                    help="self-speculative decoding: draft at these spectral "
                         "ranks (comma-separated ladder, lowest first, e.g. "
                         "'8' or '4,8') and verify at full rank — the "
                         "drafters are rank-truncations of the same weights "
                         "(serving/speculative.py)")
    ap.add_argument("--draft-tokens", type=int, default=4,
                    help="tokens the drafter proposes per engine step "
                         "(with --speculative-rank)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel paged decode over this many "
                         "devices (1-D serve mesh; GQA shards kv heads, MLA "
                         "shards query heads over the replicated latent — "
                         "sharding/partition.py; on CPU force devices with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count)")
    ap.add_argument("--disaggregate", action="store_true",
                    help="split prefill onto a separate worker with its own "
                         "page pool; finished pages ship to the decode pool "
                         "(serving/distributed.py)")
    ap.add_argument("--kv-transfer", choices=["raw", "int8"], default="raw",
                    help="wire format for disaggregated KV shipment: raw "
                         "(lossless page copy) or int8 (quantized on the "
                         "wire, opt-in)")
    ap.add_argument("--streaming-window", type=int, default=None,
                    help="long-context streaming: keep only this many "
                         "sliding-window pages (plus the pinned sinks) "
                         "resident per sequence — older pages are evicted "
                         "and their tokens dropped (serving/streaming.py)")
    ap.add_argument("--sink-pages", type=int, default=1,
                    help="attention-sink pages pinned forever at the head "
                         "of every sequence (with --streaming-window)")
    ap.add_argument("--cold-kv", choices=["none", "int8"], default="none",
                    help="tier for resident pages older than the window: "
                         "none keeps pool precision, int8 demotes them to "
                         "page-granular int8 shadow pools with transparent "
                         "dequant-on-attend (with --streaming-window)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend this many shared system-prompt tokens to "
                         "every request in the trace (the prefix-cache "
                         "workload)")
    ap.add_argument("--verify", action="store_true",
                    help="check streaming outputs against the static path "
                         "(with --quantize: int8 outputs against the fp32 "
                         "static path over the dequantized weights)")
    ap.add_argument("--quantize", choices=["int8"], default=None,
                    help="serve with int8 per-channel quantized weights "
                         "(spectral factors + dense projections; "
                         "dequant-on-the-fly)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="serve the newest training checkpoint under this "
                         "directory instead of a random init")
    ap.add_argument("--serve-rank", type=int, default=None,
                    help="resize spectral groups to this rank at load time "
                         "(cheap serving from a higher-rank training "
                         "snapshot; requires --ckpt-dir)")
    ap.add_argument("--dump-spec", action="store_true",
                    help="print the resolved RunSpec JSON and exit")
    return ap


def build_spec(args: argparse.Namespace) -> RunSpec:
    """argparse Namespace -> RunSpec: the whole adapter. Trace-shape
    knobs (--requests, --arrive-every, --shared-prefix, --seed) stay
    CLI-side — they describe the synthetic workload, not the runtime."""
    return RunSpec(
        model=ModelSpec(arch=args.arch, reduced=args.reduced),
        serve=ServeSpec(
            mode="paged" if args.paged else "static",
            slots=args.slots,
            page_size=args.page_size,
            num_pages=args.num_pages,
            pages_per_seq=args.pages_per_seq,
            prefill_budget=args.prefill_budget,
            prefix_cache=args.prefix_cache,
            chunked_prefill=args.chunked_prefill,
            request_timeout=args.request_timeout,
            scheduler=args.scheduler,
            quantize=args.quantize,
            rank=args.serve_rank,
            batch=args.batch,
            prompt_len=args.prompt_len,
            gen=args.gen,
            speculative_rank=args.speculative_rank,
            draft_tokens=args.draft_tokens,
            disaggregate=args.disaggregate,
            kv_transfer=args.kv_transfer,
            streaming=StreamingSpec(
                sink_pages=args.sink_pages,
                window_pages=args.streaming_window,
                cold_kv=args.cold_kv,
            ),
        ),
        sharding=ShardingSpec(decode_mesh=args.tp if args.tp > 1 else None),
    )


def main(argv: Optional[Sequence[str]] = None) -> None:
    args = build_parser().parse_args(argv)

    if args.paged != args.stream:
        raise SystemExit("--paged and --stream go together (static mode: neither)")
    if args.serve_rank is not None and args.ckpt_dir is None:
        raise SystemExit("--serve-rank needs --ckpt-dir")
    if args.speculative_rank is not None and not args.paged:
        raise SystemExit("--speculative-rank needs --paged --stream")
    if args.disaggregate and not args.paged:
        raise SystemExit("--disaggregate needs --paged --stream")
    if args.tp > 1 and not args.paged:
        raise SystemExit("--tp needs --paged --stream")
    if args.tp < 1:
        raise SystemExit(f"--tp {args.tp} must be >= 1")
    if args.streaming_window is not None and not args.paged:
        raise SystemExit("--streaming-window needs --paged --stream")
    if args.streaming_window is None and args.cold_kv != "none":
        raise SystemExit("--cold-kv needs --streaming-window")
    if args.streaming_window is not None and args.tp > 1:
        raise SystemExit("--streaming-window and --tp are mutually "
                         "exclusive (no per-shard shadow pools)")

    spec = build_spec(args)
    if args.dump_spec:
        print(spec.to_json(indent=2))
        return

    cfg = spec.model.config()
    if args.ckpt_dir:
        from repro.serving.engine import params_from_checkpoint

        try:
            step, params = params_from_checkpoint(args.ckpt_dir,
                                                  rank=args.serve_rank)
        except FileNotFoundError as e:
            raise SystemExit(str(e))
        from repro.rank import current_ranks

        ranks = current_ranks(params)
        print(f"loaded checkpoint step {step} from {args.ckpt_dir}"
              + (f", spectral rank(s) {list(ranks)}" if ranks else ""))
    else:
        params = init_model(jax.random.PRNGKey(args.seed), cfg)
    if args.paged:
        run_stream(args, spec, params)
        return

    if args.quantize:
        from repro.serving import dequantize_tree, param_bytes, quantize_tree

        qparams = quantize_tree(params)
        print(f"weights: {param_bytes(qparams)} bytes {args.quantize} "
              f"(fp32 {param_bytes(params)} bytes, "
              f"{param_bytes(params) / param_bytes(qparams):.2f}x smaller)")
        gen_q = run_static(args, cfg, qparams)
        if args.verify:
            gen_ref = run_static(args, cfg, dequantize_tree(qparams))
            if not np.array_equal(gen_q, gen_ref):
                bad = int(np.sum(np.any(gen_q != gen_ref, axis=1)))
                raise SystemExit(
                    f"{bad}/{args.batch} sequences: int8 path diverged from "
                    f"the fp32 static path over dequantized weights")
            print(f"verify: all {args.batch} sequences match the fp32 static "
                  f"path token-for-token")
    elif args.verify:
        raise SystemExit("--verify needs --paged --stream or --quantize int8")
    else:
        run_static(args, cfg, params)


if __name__ == "__main__":
    main()
