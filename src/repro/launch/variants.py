"""Perf-hillclimb variants (EXPERIMENTS.md §Perf): named config
transformations applied on top of an arch's baseline for a dry-run cell.
Each is one hypothesis in the hypothesis->change->measure loop.
"""
from __future__ import annotations

from typing import Callable, Dict

from repro.config.model_config import ModelConfig
from repro.config.shapes import ShapeSpec
from repro.sharding import rules as rules_mod


def _seq_parallel(cfg: ModelConfig, shape: ShapeSpec) -> ModelConfig:
    """Shard layer-boundary activations' seq axis over 'model'."""
    return cfg.replace(seq_parallel=True)


def _no_seq_parallel(cfg: ModelConfig, shape: ShapeSpec) -> ModelConfig:
    return cfg.replace(seq_parallel=False)


def _cholesky_retraction(cfg: ModelConfig, shape: ShapeSpec) -> ModelConfig:
    return cfg.replace_sct(retraction="cholesky_qr2")


def _qr_retraction(cfg: ModelConfig, shape: ShapeSpec) -> ModelConfig:
    return cfg.replace_sct(retraction="qr")


def _retract_every_4(cfg: ModelConfig, shape: ShapeSpec) -> ModelConfig:
    return cfg.replace_sct(retraction="cholesky_qr2", retract_every=4)


def _no_remat(cfg: ModelConfig, shape: ShapeSpec) -> ModelConfig:
    return cfg.replace(remat=False)


def _rank_64(cfg: ModelConfig, shape: ShapeSpec) -> ModelConfig:
    return cfg.replace_sct(rank=64)


def _rank_512(cfg: ModelConfig, shape: ShapeSpec) -> ModelConfig:
    return cfg.replace_sct(rank=512)


def _dense_mlp(cfg: ModelConfig, shape: ShapeSpec) -> ModelConfig:
    """Ablation: the dense baseline the paper compares against."""
    return cfg.replace_sct(spectral_mlp=False)


def _spectral_attention(cfg: ModelConfig, shape: ShapeSpec) -> ModelConfig:
    """Paper S5 extension: attention projections spectral too."""
    return cfg.replace_sct(spectral_attention=True)


def _capacity_1(cfg: ModelConfig, shape: ShapeSpec) -> ModelConfig:
    return cfg.replace(capacity_factor=1.0)


VARIANTS: Dict[str, Callable[[ModelConfig, ShapeSpec], ModelConfig]] = {
    "seq_parallel": _seq_parallel,
    "no_seq_parallel": _no_seq_parallel,
    "cholesky_qr2": _cholesky_retraction,
    "qr_retraction": _qr_retraction,
    "retract_every_4": _retract_every_4,
    "no_remat": _no_remat,
    "rank_64": _rank_64,
    "rank_512": _rank_512,
    "dense_mlp": _dense_mlp,
    "spectral_attention": _spectral_attention,
    "capacity_1": _capacity_1,
}


def apply_variant(cfg: ModelConfig, shape: ShapeSpec, name: str) -> ModelConfig:
    return VARIANTS[name](cfg, shape)
