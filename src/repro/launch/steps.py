"""Step builders: the jit-able train_step / prefill_step / serve_step
closures plus their in/out shardings for a given (config, shape, mesh).

These are shared by the real launcher (train.py / serve.py), the
dry-run (dryrun.py lowers them with ShapeDtypeStructs), and the tests.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config.model_config import ModelConfig
from repro.config.shapes import ShapeSpec, input_specs
from repro.core.precision import effective_policy, scale_loss
from repro.models.model import init_model, train_loss, prefill, decode_step
from repro.optim import make_sct_optimizer, SCTOptimizer
from repro.sharding.rules import param_pspecs, set_current_mesh, constrain, dp_axes
from repro.sharding.partition import (
    batch_pspecs,
    named_shardings,
    batch_axes,
)


# ----------------------------------------------------------------------
# Train
# ----------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, optimizer: Optional[SCTOptimizer] = None,
                    microbatches: int = 1, telemetry: bool = False):
    """(state, batch) -> (state, metrics). Pure; jit elsewhere.

    microbatches > 1 scans over batch slices accumulating gradients —
    activation memory drops by the microbatch count while the gradient
    accumulator is only params-sized fp32, which SCT makes k(m+n+1)
    instead of mn (gradient accumulation is disproportionately cheap for
    spectral models — DESIGN.md S2).

    If the optimizer carries a PrecisionPolicy, its compute dtype
    overrides ``cfg.dtype`` for the forward (bf16 apply-time casts off
    the fp32 masters), and with loss scaling on, the loss is multiplied
    by the dynamic scale before differentiation — ``opt.apply`` unscales
    and skips overflowed steps. Metrics then report the *unscaled* loss
    plus ``loss_scale`` / ``overflow``.

    ``telemetry=True`` folds the spectral-rank summary (rank/telemetry.py:
    effective rank, energy capture, tail mass, Stiefel drift — all
    computed on the post-update factors inside the same jit) into the
    metrics dict under ``rank/*`` keys; dense models emit nothing."""
    opt = optimizer or make_sct_optimizer(cfg)
    # always a concrete policy: the legacy precision mode resolves
    # to (cfg.dtype compute, fp32 accum, no scaling) instead of a None
    # sentinel branching every dtype decision below
    pol = effective_policy(cfg, opt.precision)
    cfg_eff = cfg.replace(dtype=pol.compute_dtype)
    accum_dtype = pol.accum_jnp

    def train_step(state, batch):
        params = state["params"]
        # scaling requires BOTH the policy and the state entry (a state
        # restored from a non-mixed checkpoint lacks it) — mirrored by
        # SCTOptimizer.apply, so scale and unscale always agree
        scaling = pol.loss_scaling and "loss_scale" in state
        scale = state["loss_scale"]["scale"] if scaling else None

        def loss_fn(params, batch):
            total, metrics = train_loss(params, batch, cfg_eff)
            total = scale_loss(total, state["loss_scale"] if scaling else None)
            return total, metrics

        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        else:
            from repro.sharding import rules as rules_mod

            def split(x):
                y = x.reshape(microbatches, x.shape[0] // microbatches, *x.shape[1:])
                if rules_mod._CURRENT_MESH is not None:
                    bt = rules_mod.dp_axes(rules_mod._CURRENT_MESH)
                    y = rules_mod.constrain(y, None, bt, *([None] * (y.ndim - 2)))
                return y

            mbatch = jax.tree.map(split, batch)

            def body(acc, mb):
                (l, met), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                acc = jax.tree.map(lambda a, b: a + b.astype(accum_dtype), acc, g)
                return acc, (l, met)

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)
            grads, (losses, mets) = jax.lax.scan(body, zeros, mbatch)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(lambda m: jnp.mean(m), mets)
        new_state = opt.apply(state, grads)
        metrics = dict(metrics)
        if scaling:
            # report the unscaled loss (scale is a power of two: exact)
            metrics["loss"] = loss / scale
            metrics["loss_scale"] = scale
            metrics["overflow"] = (
                new_state["loss_scale"]["skipped"] > state["loss_scale"]["skipped"]
            ).astype(jnp.float32)
        else:
            metrics["loss"] = loss
        if telemetry:
            from repro.rank.telemetry import telemetry_summary

            metrics.update(telemetry_summary(new_state["params"]))
        return new_state, metrics

    return train_step


def default_microbatches(cfg: ModelConfig, shape: ShapeSpec, mesh) -> int:
    """Adaptive default: keep per-device-per-microbatch tokens at or
    under ~16k so transient activations fit v5e HBM alongside the SCT
    state. Divisibility-safe."""
    from repro.sharding.partition import batch_axes

    bt = batch_axes(shape.global_batch, mesh) or ()
    n_dp = 1
    for a in bt:
        n_dp *= mesh.shape[a]
    local_batch = shape.global_batch // max(n_dp, 1)
    tokens = local_batch * shape.seq_len
    mb = 1
    while tokens // mb > 16_384 and mb < local_batch and local_batch % (mb * 2) == 0:
        mb *= 2
    return mb


def abstract_train_state(cfg: ModelConfig, optimizer: Optional[SCTOptimizer] = None):
    """ShapeDtypeStruct tree of the full train state — no allocation.
    This is what the dry-run lowers against."""
    opt = optimizer or make_sct_optimizer(cfg)

    def build():
        params = init_model(jax.random.PRNGKey(0), cfg)
        return opt.init(params)

    return jax.eval_shape(build)


def train_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh, state_like=None):
    """(state_shardings, batch_shardings) as NamedSharding trees.
    ``state_like`` may be abstract (dry-run) or a live resized state
    (rank/controller.py) — shardings key on structure, not values."""
    from repro.sharding.partition import state_shardings_for

    if state_like is None:
        state_like = abstract_train_state(cfg)
    bspec = batch_pspecs(cfg, shape, mesh)
    return state_shardings_for(state_like, mesh), named_shardings(bspec, mesh)


def lower_train_step(cfg: ModelConfig, shape: ShapeSpec, mesh,
                     optimizer: Optional[SCTOptimizer] = None, donate: bool = True,
                     microbatches: Optional[int] = None):
    """jit(train_step).lower(...) with full sharding annotations —
    the dry-run entry point for training shapes."""
    opt = optimizer or make_sct_optimizer(cfg)
    state_like = abstract_train_state(cfg, opt)
    state_sh, batch_sh = train_shardings(cfg, shape, mesh, state_like)
    if microbatches is None:
        microbatches = default_microbatches(cfg, shape, mesh)
    step_fn = make_train_step(cfg, opt, microbatches=microbatches)
    set_current_mesh(mesh)
    jitted = jax.jit(
        step_fn,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, None),
        donate_argnums=(0,) if donate else (),
    )
    batch_like = input_specs(cfg, shape)
    with mesh:
        lowered = jitted.lower(state_like, batch_like)
    return lowered


# ----------------------------------------------------------------------
# Serve (prefill / decode)
# ----------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig):
    if cfg.family == "encdec":
        def prefill_step(params, tokens, state, encoder_frames):
            return prefill(params, tokens, cfg, state, encoder_frames=encoder_frames)
    else:
        def prefill_step(params, tokens, state):
            return prefill(params, tokens, cfg, state)

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    if cfg.family == "encdec":
        def serve_step(params, tokens, state, cache_len, encoder_out):
            return decode_step(params, tokens, state, cache_len, cfg, encoder_out=encoder_out)
    else:
        def serve_step(params, tokens, state, cache_len):
            return decode_step(params, tokens, state, cache_len, cfg)

    return serve_step


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg))


def lower_serve_step(cfg: ModelConfig, shape: ShapeSpec, mesh):
    """Lower prefill (shape.kind == 'prefill') or single-token decode
    (shape.kind == 'decode') with sharding annotations."""
    params_like = abstract_params(cfg)
    n_model = mesh.shape.get("model", 1)
    n_data = mesh.shape.get("data", 1)
    p_sh = named_shardings(param_pspecs(params_like, n_model, n_data), mesh)
    specs = input_specs(cfg, shape)
    b_sh = named_shardings(batch_pspecs(cfg, shape, mesh), mesh)
    set_current_mesh(mesh)

    if shape.kind == "prefill":
        from repro.models.model import decode_state_specs
        from repro.sharding.partition import decode_state_pspecs

        state_like = decode_state_specs(cfg, batch=shape.global_batch, max_seq=shape.seq_len)
        bt = batch_axes(shape.global_batch, mesh)
        st_sh = named_shardings(decode_state_pspecs(cfg, shape, mesh, bt), mesh)
        fn = make_prefill_step(cfg)
        args = [params_like, specs["tokens"], state_like]
        in_sh = [p_sh, b_sh["tokens"], st_sh]
        if cfg.family == "encdec":
            args.append(specs["encoder_frames"])
            in_sh.append(b_sh["encoder_frames"])
        jitted = jax.jit(fn, in_shardings=tuple(in_sh), out_shardings=(None, st_sh))
        with mesh:
            return jitted.lower(*args)

    # decode
    fn = make_serve_step(cfg)
    st_sh = b_sh["state"]
    args = [params_like, specs["tokens"], specs["state"], specs["cache_len"]]
    in_sh = [p_sh, b_sh["tokens"], st_sh, b_sh["cache_len"]]
    if cfg.family == "encdec":
        args.append(specs["encoder_out"])
        in_sh.append(b_sh["encoder_out"])
    jitted = jax.jit(
        fn,
        in_shardings=tuple(in_sh),
        out_shardings=(None, st_sh),
        donate_argnums=(2,),
    )
    with mesh:
        return jitted.lower(*args)


def lower_step(cfg: ModelConfig, shape: ShapeSpec, mesh):
    from repro.sharding.rules import set_activation_seq_sharding

    # seq-parallel needs the attention head axis to divide the model
    # axis, else every layer's SP boundary resharding degenerates into
    # gathers (measured: qwen1.5-4b's 20 heads on a 16-way axis regressed
    # 6.2 -> 7.3 s; with this guard it keeps its baseline).
    n_model = mesh.shape.get("model", 1)
    sp = cfg.seq_parallel and cfg.n_heads % n_model == 0
    set_activation_seq_sharding("model" if sp else None)
    try:
        if shape.kind == "train":
            return lower_train_step(cfg, shape, mesh)
        return lower_serve_step(cfg, shape, mesh)
    finally:
        set_activation_seq_sharding(None)
