"""Logical sharding rules: param-path suffix -> PartitionSpec over the
trailing axes (leading stack axes — layers L, periods P, experts E —
are padded with None, except expert axes which shard over 'model').

Spectral-TP scheme (DESIGN.md S5): the *long* axis of each factor is
sharded over 'model'; the rank axis k is always replicated, so the TP
collective carries b x k activations instead of b x d_ff — the paper's
compression applied to communication.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P

MODEL = "model"
DATA = "data"

# (suffix regex, trailing-axes spec). First match wins. `None` entries
# replicate. Specs are relative to the LAST len(spec) axes of the leaf.
_RULES: Sequence[Tuple[str, Tuple[Optional[str], ...]]] = (
    # ---- embeddings (vocab-sharded; rules.py falls back to d-sharding
    #      when vocab %% n_model != 0, see _embed_spec) ----
    (r"embed/w$", ("__embed__",)),
    (r"(enc_pos|dec_pos)/w$", (None, None)),
    # ---- MoE: expert axis E shards over 'model' (expert parallelism);
    #      the within-expert long axis shards over 'data' (FSDP) — SCT
    #      state is k(m+n+1) so even the *gathered* factor is small ----
    (r"moe/(gate|up|down)/(U|V)$", ("__expert__", DATA, None)),
    (r"moe/(gate|up|down)/s$", ("__expert__", None)),
    (r"moe/(gate|up|down)/w$", ("__expert__", DATA, None)),
    (r"router/w$", (None, MODEL)),
    # ---- spectral MLP / shared expert / mamba / xlstm projections ----
    # up/gate: U (d, k) FSDP rows; V (f, k) TP rows (the spectral-TP
    # scheme: rank axis replicated, collective payload is b x k)
    (r"(up|gate|ff_up|in_proj)/U$", (DATA, None)),
    (r"(up|gate|ff_up|in_proj)/V$", (MODEL, None)),
    # down: U (f, k) TP rows; V (d, k) FSDP rows
    (r"(down|ff_down|out_proj)/U$", (MODEL, None)),
    (r"(down|ff_down|out_proj)/V$", (DATA, None)),
    (r"(up|gate|down|ff_up|ff_down|in_proj|out_proj)/s$", (None,)),
    # ---- spectral attention (option): long axis = heads side ----
    (r"(wq|wk|wv)/U$", (DATA, None)),
    (r"(wq|wk|wv)/V$", (MODEL, None)),
    (r"wo/U$", (MODEL, None)),
    (r"wo/V$", (DATA, None)),
    (r"(wq|wk|wv|wo)/s$", (None,)),
    # ---- dense projections: FSDP rows x TP cols (in), TP rows x FSDP
    #      cols (out) ----
    (r"(wq|wk|wv|wuq|wdq|wdkv|wukv|wx|up|gate|ff_up|in_proj|dt_proj)/w$", (DATA, MODEL)),
    (r"(wq|wk|wv|wuq|wx|up|gate|ff_up|in_proj|dt_proj)/b$", (MODEL,)),
    (r"(wo|down|ff_down|out_proj|x_proj|wo_gate)/w$", (MODEL, DATA)),
    (r"(wo|down|ff_down|out_proj|x_proj|wo_gate)/b$", (None,)),
    (r"(wdq|wdkv)/b$", (MODEL,)),
    (r"(wi|wf)/(w|b)$", (None, None)),
    # ---- mamba per-channel tensors (di sharded like the conv) ----
    (r"conv_w$", (None, MODEL)),
    (r"conv_b$", (MODEL,)),
    (r"A_log$", (MODEL, None)),
    (r"D$", (MODEL,)),
    # ---- xlstm recurrent cell (small, replicated) ----
    (r"wr$", (None, None, None)),
    # ---- norms / everything else: replicated ----
)

_COMPILED = [(re.compile(rx), spec) for rx, spec in _RULES]


def _embed_spec(shape, n_model: int):
    vocab, d = shape[-2], shape[-1]
    if vocab % n_model == 0:
        return (MODEL, DATA)  # vocab-TP x FSDP
    if d % n_model == 0:
        return (DATA, MODEL)
    return (None, None)


def _resolve(path: str, shape, n_model: int):
    for rx, spec in _COMPILED:
        if rx.search(path):
            out = []
            for s in spec:
                if s == "__embed__":
                    return _embed_spec(shape, n_model)
                out.append(MODEL if s == "__expert__" else s)
            return tuple(out)
    return None  # fully replicated


def _divisible(shape, spec, n_model: int, n_data: int):
    """Drop mesh-axis entries whose dim isn't divisible (e.g.
    qwen1.5-4b's 20 heads on a 16-way axis) — replicate instead; GSPMD
    would insert a gather anyway, better to make it explicit."""
    out = []
    for dim, s in zip(shape[-len(spec):], spec):
        if s == MODEL and dim % n_model != 0:
            out.append(None)
        elif s == DATA and dim % n_data != 0:
            out.append(None)
        else:
            out.append(s)
    return tuple(out)


def param_pspecs(params: Any, n_model: int = 16, n_data: int = 16) -> Any:
    """PartitionSpec tree mirroring ``params``."""

    def walk(tree, path):
        if isinstance(tree, dict):
            return {k: walk(v, f"{path}/{k}" if path else k) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            return type(tree)(walk(v, f"{path}/[{i}]") for i, v in enumerate(tree))
        shape = tree.shape
        spec = _resolve(path, shape, n_model)
        if spec is None:
            return P()
        spec = _divisible(shape, spec, n_model, n_data)
        lead = len(shape) - len(spec)
        return P(*((None,) * lead + spec))

    return walk(params, "")


# ----------------------------------------------------------------------
# Activation constraint helper (mesh-agnostic model code)
# ----------------------------------------------------------------------

_CURRENT_MESH = None
_ACT_SEQ_AXIS = None  # set to 'model' for sequence-parallel activations


def set_current_mesh(mesh) -> None:
    global _CURRENT_MESH
    _CURRENT_MESH = mesh


def set_activation_seq_sharding(axis: Optional[str]) -> None:
    """Sequence-parallelism knob: shard layer-boundary activations'
    sequence axis over ``axis`` ('model'). Cuts per-device activation
    memory by n_model at the cost of boundary collectives (hillclimb
    lever, EXPERIMENTS.md §Perf)."""
    global _ACT_SEQ_AXIS
    _ACT_SEQ_AXIS = axis


def constrain(x, *spec):
    """with_sharding_constraint if a mesh is active, else identity."""
    if _CURRENT_MESH is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(_CURRENT_MESH, P(*spec))
    )


def constrain_activation(x):
    """Layer-boundary (b, s, d) activation constraint: batch over the DP
    axes, sequence optionally over 'model' (sequence parallelism)."""
    if _CURRENT_MESH is None or x.ndim != 3:
        return x
    bt = dp_axes(_CURRENT_MESH)
    if x.shape[0] % max(1, _prod(_CURRENT_MESH.shape[a] for a in bt)) != 0:
        bt = None
    seq = _ACT_SEQ_AXIS
    if seq is not None and x.shape[1] % _CURRENT_MESH.shape.get(seq, 1) != 0:
        seq = None
    return constrain(x, bt, seq, None)


def _prod(it):
    out = 1
    for v in it:
        out *= v
    return out


def constrain_expert_buffer(x):
    """MoE (E, C, d) dispatch buffer: experts over 'model', capacity over
    the DP axes — keeps the buffer's per-device footprint at
    E/n_model x C/n_dp x d (DESIGN.md S5)."""
    if _CURRENT_MESH is None or x.ndim != 3:
        return x
    m = MODEL if x.shape[0] % _CURRENT_MESH.shape.get(MODEL, 1) == 0 else None
    bt = dp_axes(_CURRENT_MESH)
    if bt and x.shape[1] % _prod(_CURRENT_MESH.shape[a] for a in bt) != 0:
        bt = None
    return constrain(x, m, bt, None)


def dp_axes(mesh) -> tuple:
    """The data-parallel mesh axes: ('pod', 'data') when a pod axis
    exists, else ('data',)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
