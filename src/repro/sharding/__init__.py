from repro.sharding.rules import param_pspecs, set_current_mesh, constrain, dp_axes
from repro.sharding.partition import (
    state_pspecs,
    batch_pspecs,
    decode_state_pspecs,
    named_shardings,
)

__all__ = [
    "param_pspecs",
    "set_current_mesh",
    "constrain",
    "dp_axes",
    "state_pspecs",
    "batch_pspecs",
    "decode_state_pspecs",
    "named_shardings",
]
