"""Whole-state and input partition specs per (config, shape, mesh)."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config.model_config import ModelConfig
from repro.config.shapes import ShapeSpec
from repro.sharding.rules import param_pspecs, dp_axes, MODEL


def state_pspecs(state_like: Any, n_model: int, n_data: int = 16) -> Any:
    """TrainState {'params','opt':{'mu','nu','count'},'step'} specs:
    optimizer moments mirror the parameter sharding exactly. Any extra
    state entries (e.g. the mixed-precision ``loss_scale`` scalars) are
    replicated."""
    pspec = param_pspecs(state_like["params"], n_model, n_data)
    out = {
        "params": pspec,
        "opt": {
            "mu": param_pspecs(state_like["opt"]["mu"], n_model, n_data),
            "nu": param_pspecs(state_like["opt"]["nu"], n_model, n_data),
            "count": P(),
        },
        "step": P(),
    }
    for key in state_like:
        if key not in out:
            out[key] = jax.tree.map(lambda _: P(), state_like[key])
    return out


def state_shardings_for(state_like: Any, mesh) -> Any:
    """NamedSharding tree for a TrainState (live arrays or
    ShapeDtypeStructs), regenerated from the mesh's axis sizes.

    This is the rank-resize path (rank/controller.py): a resize changes
    the spectral factors' k dimension, so the sharding tree must be
    rebuilt against the *new* shapes — the partition rules name mesh
    axes, not sizes, so the same rules re-apply and divisibility guards
    in rules.py drop any axis the new shape no longer divides."""
    n_model = mesh.shape.get("model", 1)
    n_data = mesh.shape.get("data", 1)
    return named_shardings(state_pspecs(state_like, n_model, n_data), mesh)


def batch_axes(global_batch: int, mesh):
    """The mesh axes the batch dim shards over: all DP axes when the
    batch divides them, 'data' alone as a fallback, else unsharded."""
    dp = dp_axes(mesh)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    if dp and global_batch % n_dp == 0:
        return dp
    if "data" in mesh.axis_names and global_batch % mesh.shape["data"] == 0:
        return ("data",)
    return None


def batch_pspecs(cfg: ModelConfig, shape: ShapeSpec, mesh) -> Any:
    bt = batch_axes(shape.global_batch, mesh)

    specs = {}
    if shape.kind == "train":
        specs["tokens"] = P(bt, None)
        specs["labels"] = P(bt, None)
        if cfg.family == "encdec":
            specs["encoder_frames"] = P(bt, None, None)
        return specs
    if shape.kind == "prefill":
        specs["tokens"] = P(bt, None)
        if cfg.family == "encdec":
            specs["encoder_frames"] = P(bt, None, None)
        return specs
    # decode
    specs["tokens"] = P(bt, None)
    specs["cache_len"] = P()
    specs["state"] = decode_state_pspecs(cfg, shape, mesh, bt)
    if cfg.family == "encdec":
        specs["encoder_out"] = P(bt, None, None)
    return specs


def decode_state_pspecs(cfg: ModelConfig, shape: ShapeSpec, mesh, bt) -> Any:
    """Decode caches: batch-shard when the batch divides the DP axes;
    otherwise (long_500k, batch=1) shard the cache *sequence* axis over
    'data' (sequence parallelism for the KV cache)."""
    from repro.models.model import decode_state_specs

    specs = decode_state_specs(cfg, batch=shape.global_batch, max_seq=shape.seq_len)
    seq_shard = bt is None  # batch unshardable -> shard cache seq over data

    def spec_for(path, leaf):
        shp = leaf.shape
        nd = len(shp)
        # KV caches: (L, b, S, ...) — attn k/v/ckv/krope
        tail = path.split("/")[-1]
        if tail in ("k", "v", "ckv", "krope"):
            out = [None] * nd
            out[1] = bt
            if seq_shard and shp[2] % mesh.shape.get("data", 1) == 0:
                out[2] = "data"
            return P(*out)
        # recurrent states: (P, n, b, ...) or (P, b, ...); shard batch
        # axis if possible, model-dim channels over 'model' where they
        # divide (mamba di)
        out = [None] * nd
        # find the batch axis: it equals shape.global_batch
        for i, d in enumerate(shp):
            if d == shape.global_batch and bt is not None:
                out[i] = bt
                break
        return P(*out)

    def walk(tree, path=""):
        if isinstance(tree, dict):
            return {k: walk(v, f"{path}/{k}" if path else k) for k, v in tree.items()}
        return spec_for(path, tree)

    return walk(specs)


def named_shardings(pspecs: Any, mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )
