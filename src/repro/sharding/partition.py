"""Whole-state and input partition specs per (config, shape, mesh) —
for the training step and, since the distributed-serving refactor, the
paged decode path (serve meshes, pool placement, shard_map wrapping)."""
from __future__ import annotations

import inspect
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config.model_config import ModelConfig
from repro.config.shapes import ShapeSpec
from repro.sharding.rules import param_pspecs, dp_axes, MODEL


def state_pspecs(state_like: Any, n_model: int, n_data: int = 16) -> Any:
    """TrainState {'params','opt':{'mu','nu','count'},'step'} specs:
    optimizer moments mirror the parameter sharding exactly. Any extra
    state entries (e.g. the mixed-precision ``loss_scale`` scalars) are
    replicated."""
    pspec = param_pspecs(state_like["params"], n_model, n_data)
    out = {
        "params": pspec,
        "opt": {
            "mu": param_pspecs(state_like["opt"]["mu"], n_model, n_data),
            "nu": param_pspecs(state_like["opt"]["nu"], n_model, n_data),
            "count": P(),
        },
        "step": P(),
    }
    for key in state_like:
        if key not in out:
            out[key] = jax.tree.map(lambda _: P(), state_like[key])
    return out


def state_shardings_for(state_like: Any, mesh) -> Any:
    """NamedSharding tree for a TrainState (live arrays or
    ShapeDtypeStructs), regenerated from the mesh's axis sizes.

    This is the rank-resize path (rank/controller.py): a resize changes
    the spectral factors' k dimension, so the sharding tree must be
    rebuilt against the *new* shapes — the partition rules name mesh
    axes, not sizes, so the same rules re-apply and divisibility guards
    in rules.py drop any axis the new shape no longer divides."""
    n_model = mesh.shape.get("model", 1)
    n_data = mesh.shape.get("data", 1)
    return named_shardings(state_pspecs(state_like, n_model, n_data), mesh)


def batch_axes(global_batch: int, mesh):
    """The mesh axes the batch dim shards over: all DP axes when the
    batch divides them, 'data' alone as a fallback, else unsharded."""
    dp = dp_axes(mesh)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    if dp and global_batch % n_dp == 0:
        return dp
    if "data" in mesh.axis_names and global_batch % mesh.shape["data"] == 0:
        return ("data",)
    return None


def batch_pspecs(cfg: ModelConfig, shape: ShapeSpec, mesh) -> Any:
    bt = batch_axes(shape.global_batch, mesh)

    specs = {}
    if shape.kind == "train":
        specs["tokens"] = P(bt, None)
        specs["labels"] = P(bt, None)
        if cfg.family == "encdec":
            specs["encoder_frames"] = P(bt, None, None)
        return specs
    if shape.kind == "prefill":
        specs["tokens"] = P(bt, None)
        if cfg.family == "encdec":
            specs["encoder_frames"] = P(bt, None, None)
        return specs
    # decode
    specs["tokens"] = P(bt, None)
    specs["cache_len"] = P()
    specs["state"] = decode_state_pspecs(cfg, shape, mesh, bt)
    if cfg.family == "encdec":
        specs["encoder_out"] = P(bt, None, None)
    return specs


def decode_state_pspecs(cfg: ModelConfig, shape: ShapeSpec, mesh, bt) -> Any:
    """Decode caches: batch-shard when the batch divides the DP axes;
    otherwise (long_500k, batch=1) shard the cache *sequence* axis over
    'data' (sequence parallelism for the KV cache)."""
    from repro.models.model import decode_state_specs

    specs = decode_state_specs(cfg, batch=shape.global_batch, max_seq=shape.seq_len)
    seq_shard = bt is None  # batch unshardable -> shard cache seq over data

    def spec_for(path, leaf):
        shp = leaf.shape
        nd = len(shp)
        # KV caches: (L, b, S, ...) — attn k/v/ckv/krope
        tail = path.split("/")[-1]
        if tail in ("k", "v", "ckv", "krope"):
            out = [None] * nd
            out[1] = bt
            if seq_shard and shp[2] % mesh.shape.get("data", 1) == 0:
                out[2] = "data"
            return P(*out)
        # recurrent states: (P, n, b, ...) or (P, b, ...); shard batch
        # axis if possible, model-dim channels over 'model' where they
        # divide (mamba di)
        out = [None] * nd
        # find the batch axis: it equals shape.global_batch
        for i, d in enumerate(shp):
            if d == shape.global_batch and bt is not None:
                out[i] = bt
                break
        return P(*out)

    def walk(tree, path=""):
        if isinstance(tree, dict):
            return {k: walk(v, f"{path}/{k}" if path else k) for k, v in tree.items()}
        return spec_for(path, tree)

    return walk(specs)


def named_shardings(pspecs: Any, mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )


# ======================================================================
# Decode-path placement (distributed serving)
# ======================================================================

# the mesh axis tensor-parallel serving shards over — the same axis
# name the training rules use, so activation constraints compose
TP_AXIS = "model"


def serve_mesh(tp: int) -> Mesh:
    """1-D ``('model',)`` mesh over the first ``tp`` local devices —
    the tensor-parallel serve mesh. Raises when the host doesn't expose
    enough devices (tests force them with
    ``XLA_FLAGS=--xla_force_host_platform_device_count``)."""
    devices = jax.devices()
    if tp > len(devices):
        raise ValueError(
            f"serve mesh needs {tp} devices, host has {len(devices)} "
            f"(force more on CPU with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={tp})")
    return Mesh(np.array(devices[:tp]), (TP_AXIS,))


def serve_tp_valid(cfg: ModelConfig, tp: int) -> bool:
    """Whether ``tp`` ways of head parallelism divide this config's
    attention: GQA shards the kv-head axis (each shard keeps whole
    query groups — see the (kvh, rep) grouping in nn/attention.py), MLA
    shards query heads over the replicated latent."""
    if cfg.attention == "mla":
        return cfg.n_heads % tp == 0
    return cfg.n_kv_heads % tp == 0


def paged_state_pspecs(cfg: ModelConfig, state_like: Any, n_model: int) -> Any:
    """Placement of the paged decode state over a serve mesh: GQA KV
    pool leaves (L, P+1, page, kvh, hd) shard the kv-head axis over
    'model' when it divides; MLA latent pools (no head axis — the
    latent is tiny, replication is the cheap placement) and recurrent
    slot state replicate. Used both as device_put placement and as the
    shard_map in/out specs for the decode and chunk-prefill steps."""
    def spec_for(path, leaf):
        tail = path.split("/")[-1]
        shp = getattr(leaf, "shape", ())
        if tail in ("k", "v") and len(shp) == 5 and shp[3] % n_model == 0:
            return P(None, None, None, TP_AXIS, None)
        return P()

    def walk(tree, path=""):
        if isinstance(tree, dict):
            return {k: walk(v, f"{path}/{k}" if path else k)
                    for k, v in tree.items()}
        return spec_for(path, tree)

    return walk(state_like)


def shard_map_compat(f, mesh, in_specs, out_specs):
    """``shard_map`` across jax versions: the replication-check kwarg
    was renamed check_rep -> check_vma; disable it either way (the
    decode step's logits/pools are replicated by construction — every
    shard computes them from all-gathered head outputs)."""
    shard_map = getattr(jax, "shard_map", None)
    if shard_map is None:  # pragma: no cover - older jax
        from jax.experimental.shard_map import shard_map
    kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    sm_params = inspect.signature(shard_map).parameters
    if "check_vma" in sm_params:
        kw["check_vma"] = False
    elif "check_rep" in sm_params:
        kw["check_rep"] = False
    return shard_map(f, **kw)
