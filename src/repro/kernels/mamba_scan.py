"""Pallas TPU kernel for the Mamba selective scan (jamba's mixer).

Grid: (batch, di_chunks, time_chunks); the time axis is sequential and
the SSM state h (di_chunk, d_state) persists in VMEM scratch across time
chunks — the discretized (dA, dBu) tensors exist only one timestep at a
time in registers/VMEM, mirroring mamba's fused CUDA scan on GPU. This
is the execution path for the `PALLAS_EQ_mamba_scan` region
(nn/mamba.py `_ssm_scan` — same recurrence, asserted equal by tests).

VMEM at Tc=512, dic=512, ds=16 fp32: u/dt 2x1MB + B/C 2x32K + h 32K
+ y 1MB ~= 3.2 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.interpret import resolve_interpret


def _kernel(u_ref, dt_ref, B_ref, C_ref, A_ref, D_ref, y_ref, h_ref, *, Tc: int):
    tchunk = pl.program_id(2)

    @pl.when(tchunk == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = A_ref[...].astype(jnp.float32)                   # (dic, ds)
    D = D_ref[...].astype(jnp.float32)                   # (1, dic)

    def step(t, carry):
        h = carry                                        # (dic, ds)
        u_t = u_ref[0, t, :].astype(jnp.float32)         # (dic,)
        dt_t = dt_ref[0, t, :].astype(jnp.float32)       # (dic,)
        B_t = B_ref[0, t, :].astype(jnp.float32)         # (ds,)
        C_t = C_ref[0, t, :].astype(jnp.float32)         # (ds,)
        dA = jnp.exp(dt_t[:, None] * A)                  # (dic, ds)
        dBu = (dt_t * u_t)[:, None] * B_t[None, :]
        h = dA * h + dBu
        y_t = jnp.sum(h * C_t[None, :], axis=1) + u_t * D[0]
        y_ref[0, t, :] = y_t.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, Tc, step, h_ref[...])
    h_ref[...] = h


def mamba_scan_pallas(u, dt, B, C, A, D, *, t_chunk: int = 512,
                      di_chunk: int = 512, interpret: bool | None = None):
    """u/dt: (b, S, di); B/C: (b, S, ds); A: (di, ds); D: (di,).
    Returns y: (b, S, di). Requires S % t_chunk == 0, di % di_chunk == 0
    (callers pad; dims in the assigned configs already divide)."""
    b, S, di = u.shape
    ds = B.shape[-1]
    Tc = min(t_chunk, S)
    dic = min(di_chunk, di)
    assert S % Tc == 0 and di % dic == 0, (S, di, Tc, dic)

    return pl.pallas_call(
        functools.partial(_kernel, Tc=Tc),
        grid=(b, di // dic, S // Tc),
        in_specs=[
            pl.BlockSpec((1, Tc, dic), lambda i, j, t: (i, t, j)),
            pl.BlockSpec((1, Tc, dic), lambda i, j, t: (i, t, j)),
            pl.BlockSpec((1, Tc, ds), lambda i, j, t: (i, t, 0)),
            pl.BlockSpec((1, Tc, ds), lambda i, j, t: (i, t, 0)),
            pl.BlockSpec((dic, ds), lambda i, j, t: (j, 0)),
            pl.BlockSpec((1, dic), lambda i, j, t: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, Tc, dic), lambda i, j, t: (i, t, j)),
        out_shape=jax.ShapeDtypeStruct((b, S, di), u.dtype),
        scratch_shapes=[pltpu.VMEM((dic, ds), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(u, dt, B, C, A, D.reshape(1, di))
