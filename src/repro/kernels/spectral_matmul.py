"""Fused spectral matmul Pallas TPU kernel: y = ((x @ U) * s) @ V.T.

The rank-k bottleneck activation ``h = x @ U`` lives ONLY in VMEM
scratch — it is never written to HBM (the kernel-level expression of the
paper's never-materialize rule; the naive 3-op chain writes h to HBM and
reads it back).

Tiling (DESIGN.md S6): grid = (M/bm, Tm + Tn) with Tm = m/cm, Tn = n/cn.
For a fixed row-block i, phases t = 0..Tm-1 stream x/U m-chunks and
accumulate h (bm, k) into fp32 scratch; phases t = Tm..Tm+Tn-1 stream V
n-chunks and write y tiles from (h * s). MXU contraction dims are
multiples of 128 for aligned shapes (cm = cn = 512; k is the small dim
by construction — Mosaic pads lanes for k < 128, acceptable because
rank is what the paper compresses).

VMEM at bm=256, cm=cn=512, k=256, bf16 in / fp32 acc:
x 256K + U 256K + V 256K + y 256K + h-scratch 256K ~= 1.3 MB << 16 MB,
leaving room for double-buffered prefetch of the streamed operands.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 256
DEFAULT_CM = 512
DEFAULT_CN = 512


def _kernel(x_ref, u_ref, s_ref, v_ref, y_ref, h_ref, *, tm: int, tn: int):
    t = pl.program_id(1)

    # ---- phase 1: accumulate h += x_chunk @ U_chunk ----
    @pl.when(t == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    @pl.when(t < tm)
    def _accum():
        h_ref[...] += jnp.dot(
            x_ref[...], u_ref[...], preferred_element_type=jnp.float32
        )

    # ---- phase 2: y_tile = (h * s) @ V_chunk^T ----
    @pl.when(t >= tm)
    def _emit():
        hs = (h_ref[...] * s_ref[...].astype(jnp.float32)).astype(x_ref.dtype)
        y_ref[...] = jnp.dot(
            hs, v_ref[...].T, preferred_element_type=jnp.float32
        ).astype(y_ref.dtype)


def spectral_matmul_pallas(
    x: jax.Array,
    U: jax.Array,
    s: jax.Array,
    V: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    cm: int = DEFAULT_CM,
    cn: int = DEFAULT_CN,
    interpret: bool = False,
) -> jax.Array:
    """x: (M, m), U: (m, k), s: (k,), V: (n, k) -> (M, n).
    Requires M % bm == 0, m % cm == 0, n % cn == 0 (ops.py pads)."""
    M, m = x.shape
    mk, k = U.shape
    n, vk = V.shape
    assert m == mk and k == vk and s.shape == (k,), (x.shape, U.shape, s.shape, V.shape)
    bm = min(bm, M)
    cm = min(cm, m)
    cn = min(cn, n)
    assert M % bm == 0 and m % cm == 0 and n % cn == 0, (M, m, n, bm, cm, cn)
    tm, tn = m // cm, n // cn

    return pl.pallas_call(
        functools.partial(_kernel, tm=tm, tn=tn),
        grid=(M // bm, tm + tn),
        in_specs=[
            # x m-chunks stream during phase 1; index clamps in phase 2
            pl.BlockSpec((bm, cm), lambda i, t: (i, jnp.minimum(t, tm - 1))),
            pl.BlockSpec((cm, k), lambda i, t: (jnp.minimum(t, tm - 1), 0)),
            pl.BlockSpec((1, k), lambda i, t: (0, 0)),
            # V n-chunks stream during phase 2
            pl.BlockSpec((cn, k), lambda i, t: (jnp.maximum(t - tm, 0), 0)),
        ],
        out_specs=pl.BlockSpec((bm, cn), lambda i, t: (i, jnp.maximum(t - tm, 0))),
        out_shape=jax.ShapeDtypeStruct((M, n), x.dtype),
        # h accumulator: fp32 VMEM scratch, persists across the whole t
        # sweep for a fixed row-block i (both phases).
        scratch_shapes=[pltpu.VMEM((bm, k), jnp.float32)],
        interpret=interpret,
    )(x, U, s.reshape(1, k), V)
