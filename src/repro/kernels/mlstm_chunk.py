"""Pallas TPU kernel for the chunkwise-parallel mLSTM cell (xLSTM).

One program per (batch*head); the grid's chunk axis is sequential and
the inter-chunk state (C (dh, dh), n (dh,), m ()) lives in VMEM scratch
across chunk iterations — the decay-masked intra-chunk matrices
(logD, w, scores: (T, T)) never leave VMEM. This is the fused execution
path for the `PALLAS_EQ_mlstm_chunk` region that the 512-device dry-run
partitions in jnp form (nn/xlstm.py `_mlstm_chunk_body` — same math,
asserted equal by tests).

VMEM at T=256, dh=512 fp32: q/k/v 3x512K + (T,T) intra 256K + C 1MB
+ out 512K ~= 3.5 MB << 16 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.interpret import resolve_interpret

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, i_ref, f_ref, o_ref, C_ref, n_ref, m_ref,
            *, T: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        C_ref[...] = jnp.zeros_like(C_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)

    q = q_ref[0].astype(jnp.float32)                    # (T, dh)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    i_c = i_ref[0].astype(jnp.float32)                  # (T,)
    logf = jax.nn.log_sigmoid(f_ref[0].astype(jnp.float32))

    C0 = C_ref[...]
    n0 = n_ref[...]                                     # (1, dh)
    m0 = m_ref[0, 0]

    bcum = jnp.cumsum(logf)                             # (T,)
    btot = bcum[T - 1]
    logD = bcum[:, None] - bcum[None, :] + i_c[None, :]
    tpos = jax.lax.broadcasted_iota(jnp.int32, (T, T), 0)
    jpos = jax.lax.broadcasted_iota(jnp.int32, (T, T), 1)
    logD = jnp.where(tpos >= jpos, logD, NEG_INF)
    inter = bcum + m0                                   # (T,)
    m_loc = jnp.maximum(inter, jnp.max(logD, axis=1))
    w = jnp.exp(logD - m_loc[:, None])                  # (T, T)
    inter_sc = jnp.exp(inter - m_loc)                   # (T,)

    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    ws = w * scores
    num = jax.lax.dot_general(ws, v, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    num = num + inter_sc[:, None] * jax.lax.dot_general(
        q, C0, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    den = jnp.sum(ws, axis=1) + inter_sc * jnp.sum(q * n0, axis=1)
    den = jnp.maximum(jnp.abs(den), jnp.exp(-m_loc))
    o_ref[0] = (num / den[:, None]).astype(o_ref.dtype)

    # inter-chunk state update
    a = btot - bcum + i_c                               # (T,)
    m_new = jnp.maximum(btot + m0, jnp.max(a))
    decay0 = jnp.exp(btot + m0 - m_new)
    wa = jnp.exp(a - m_new)                             # (T,)
    C_ref[...] = decay0 * C0 + jax.lax.dot_general(
        wa[:, None] * k, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    n_ref[...] = decay0 * n0 + jnp.sum(wa[:, None] * k, axis=0)[None, :]
    m_ref[...] = jnp.full_like(m_ref, m_new)


def mlstm_chunk_pallas(q, k, v, i_pre, f_pre, *, chunk: int = 256,
                       interpret: bool | None = None):
    """q/k/v: (B, S, dh) with B = batch*heads folded (k pre-scaled by
    1/sqrt(dh)); i_pre/f_pre: (B, S) gate pre-activations.
    Returns (B, S, dh). Requires S % chunk == 0."""
    B, S, dh = q.shape
    T = min(chunk, S)
    assert S % T == 0, (S, T)

    return pl.pallas_call(
        functools.partial(_kernel, T=T),
        grid=(B, S // T),
        in_specs=[
            pl.BlockSpec((1, T, dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, T, dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, T, dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, T), lambda b, c: (b, c)),
            pl.BlockSpec((1, T), lambda b, c: (b, c)),
        ],
        out_specs=pl.BlockSpec((1, T, dh), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((dh, dh), jnp.float32),  # C
            pltpu.VMEM((1, dh), jnp.float32),   # n
            pltpu.VMEM((1, 1), jnp.float32),    # m
        ],
        interpret=resolve_interpret(interpret),
    )(q, k, v, i_pre, f_pre)
