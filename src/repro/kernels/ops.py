"""jit'd public wrapper for the fused spectral matmul: shape handling
(leading batch dims, padding to tile multiples), custom_vjp, and the
interpret-mode switch for CPU validation.

Backward design note: the forward fuses three ops to keep ``h`` in VMEM.
The backward's five GEMMs (dV, dh, ds, dU, dx) have no equivalent fusion
win — each is a single standard GEMM that XLA already schedules at MXU
peak, so they are expressed in jnp (recomputing h, remat-style) rather
than as more Pallas. This keeps the custom kernel surface exactly at the
paper's hot-spot.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.interpret import resolve_interpret
from repro.kernels.spectral_matmul import spectral_matmul_pallas
from repro.kernels.ref import spectral_matmul_ref


def _pad_to(x, mult, axis):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x, size
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad), size


def _fwd_2d(x2, U, s, V):
    """x2: (M, m). Pads every dim to tile multiples, calls the kernel,
    slices back."""
    M, m = x2.shape
    n = V.shape[0]
    # modest tiles so small test shapes stay multi-block
    bm = 256 if M >= 256 else max(8, 1 << (M - 1).bit_length())
    cm = 512 if m >= 512 else m
    cn = 512 if n >= 512 else n
    x2, M0 = _pad_to(x2, bm, 0)
    xp, _ = _pad_to(x2, cm, 1)
    Up, _ = _pad_to(U, cm, 0)
    Vp, _ = _pad_to(V, cn, 0)
    y = spectral_matmul_pallas(xp, Up, s, Vp, bm=bm, cm=cm, cn=cn,
                               interpret=resolve_interpret(None))
    return y[:M0, :n]


@jax.custom_vjp
def spectral_matmul(x, U, s, V):
    """y = ((x @ U) * s) @ V.T with the h-in-VMEM fused kernel.
    x: (..., m); U: (m, k); s: (k,); V: (n, k) -> (..., n)."""
    lead = x.shape[:-1]
    m = x.shape[-1]
    x2 = x.reshape(-1, m)
    y = _fwd_2d(x2, U, s, V)
    return y.reshape(*lead, V.shape[0])


def spectral_matmul_q8(x, U_qt, s, V_qt):
    """Fused spectral matmul over int8-quantized factors
    (serving/quantize.py): per-channel dequant on the fly, then the same
    h-in-VMEM kernel. The int8 tensors are the *persistent* weight
    storage; the dequantized fp factors are transient per-call
    allocations (XLA does not fuse producers into a pallas_call, so a
    full-size fp U/V does exist in HBM for the call's duration — the
    steady-state weight footprint is still the int8 one).

    Factors dequantize to fp32 — exactly what the ``--verify`` oracle
    (dequantize_tree) feeds the same kernel — so the quantized and
    oracle paths stay bit-identical regardless of x.dtype."""
    from repro.serving.quantize import dequantize_int8

    U = dequantize_int8(U_qt)
    V = dequantize_int8(V_qt)
    return spectral_matmul(x, U, s, V)


def _vjp_fwd(x, U, s, V):
    return spectral_matmul(x, U, s, V), (x, U, s, V)


def _vjp_bwd(res, dy):
    x, U, s, V = res
    lead = x.shape[:-1]
    m = x.shape[-1]
    n = V.shape[0]
    x2 = x.reshape(-1, m)
    dy2 = dy.reshape(-1, n)
    # recompute h (remat) — never stored in HBM by the forward
    h = jnp.dot(x2, U.astype(x2.dtype), preferred_element_type=jnp.float32)
    hs = h * s.astype(jnp.float32)
    dV = jnp.einsum("Mn,Mk->nk", dy2.astype(jnp.float32), hs).astype(V.dtype)
    dhs = jnp.dot(dy2, V.astype(dy2.dtype), preferred_element_type=jnp.float32)
    ds = jnp.einsum("Mk,Mk->k", dhs, h).astype(s.dtype)
    dh = dhs * s.astype(jnp.float32)
    dU = jnp.einsum("Mm,Mk->mk", x2.astype(jnp.float32), dh).astype(U.dtype)
    dx = jnp.dot(dh.astype(x2.dtype), U.T.astype(x2.dtype),
                 preferred_element_type=jnp.float32).astype(x.dtype)
    return dx.reshape(*lead, m), dU, ds, dV


spectral_matmul.defvjp(_vjp_fwd, _vjp_bwd)
