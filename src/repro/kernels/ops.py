"""jit'd public wrapper for the fused spectral matmul: shape handling
(leading batch dims, padding to tile multiples), custom_vjp, and the
interpret-mode switch for CPU validation.

Backward design note: the forward fuses three ops to keep ``h`` in VMEM.
The backward's five GEMMs (dV, dh, ds, dU, dx) have no equivalent fusion
win — each is a single standard GEMM that XLA already schedules at MXU
peak, so they are expressed in jnp (recomputing h, remat-style) rather
than as more Pallas. This keeps the custom kernel surface exactly at the
paper's hot-spot.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.interpret import resolve_interpret
from repro.kernels.spectral_matmul import spectral_matmul_pallas
from repro.kernels.spectral_matmul_q8 import spectral_matmul_q8_pallas
from repro.kernels.ref import spectral_matmul_ref


def _pad_to(x, mult, axis):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x, size
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad), size


def _fwd_2d(x2, U, s, V):
    """x2: (M, m). Pads every dim to tile multiples, calls the kernel,
    slices back."""
    M, m = x2.shape
    n = V.shape[0]
    # modest tiles so small test shapes stay multi-block
    bm = 256 if M >= 256 else max(8, 1 << (M - 1).bit_length())
    cm = 512 if m >= 512 else m
    cn = 512 if n >= 512 else n
    x2, M0 = _pad_to(x2, bm, 0)
    xp, _ = _pad_to(x2, cm, 1)
    Up, _ = _pad_to(U, cm, 0)
    Vp, _ = _pad_to(V, cn, 0)
    y = spectral_matmul_pallas(xp, Up, s, Vp, bm=bm, cm=cm, cn=cn,
                               interpret=resolve_interpret(None))
    return y[:M0, :n]


@jax.custom_vjp
def spectral_matmul(x, U, s, V):
    """y = ((x @ U) * s) @ V.T with the h-in-VMEM fused kernel.
    x: (..., m); U: (m, k); s: (k,); V: (n, k) -> (..., n)."""
    lead = x.shape[:-1]
    m = x.shape[-1]
    x2 = x.reshape(-1, m)
    y = _fwd_2d(x2, U, s, V)
    return y.reshape(*lead, V.shape[0])


def _q8_fwd_2d(x2, U_q8, gain, V_q8):
    """x2: (M, m) against raw int8 factors. Same pad-to-tile handling as
    _fwd_2d; int8 zero-padding is exact and the padded k-columns carry
    zero gain."""
    M, m = x2.shape
    n = V_q8.shape[0]
    bm = 256 if M >= 256 else max(8, 1 << (M - 1).bit_length())
    cm = 512 if m >= 512 else m
    cn = 512 if n >= 512 else n
    x2, M0 = _pad_to(x2, bm, 0)
    xp, _ = _pad_to(x2, cm, 1)
    Up, _ = _pad_to(U_q8, cm, 0)
    Vp, _ = _pad_to(V_q8, cn, 0)
    y = spectral_matmul_q8_pallas(xp, Up, gain, Vp, bm=bm, cm=cm, cn=cn,
                                  interpret=resolve_interpret(None))
    return y[:M0, :n]


@jax.custom_vjp
def spectral_matmul_q8(x, U_qt, s, V_qt):
    """Fused spectral matmul consuming int8 factors *directly*
    (serving/quantize.py ``{"q8", "scale"}`` tensors for U/V, fp32 s).
    The dequantized fp factor is never materialized: per-column scales
    commute with the matmuls, so u_scale * s * v_scale collapse into one
    fused k-length gain on the VMEM-resident bottleneck ``h``, and the
    int8 tiles widen to the activation dtype per-tile in VMEM
    (kernels/spectral_matmul_q8.py). Equivalence to the
    dequantize-then-matmul oracle is tolerance-based (the fused gain
    reassociates the per-channel scaling) — asserted per-dtype by the
    differential harness, not bit-exact.

    Serving-only: int8 factors carry no gradient (training holds the fp
    factors). Differentiating through this op raises instead of
    silently returning a wrong cotangent."""
    lead = x.shape[:-1]
    m = x.shape[-1]
    gain = (U_qt["scale"].astype(jnp.float32)
            * s.astype(jnp.float32)
            * V_qt["scale"].astype(jnp.float32))
    y = _q8_fwd_2d(x.reshape(-1, m), U_qt["q8"], gain, V_qt["q8"])
    return y.reshape(*lead, V_qt["q8"].shape[0])


def _q8_vjp_fwd(x, U_qt, s, V_qt):
    raise TypeError(
        "spectral_matmul_q8 is a serving-only kernel over int8 factors; "
        "it has no gradient (train against the fp spectral factors, or "
        "dequantize_tree first)")


def _q8_vjp_bwd(res, dy):  # pragma: no cover - fwd already raised
    raise TypeError("spectral_matmul_q8 has no gradient")


spectral_matmul_q8.defvjp(_q8_vjp_fwd, _q8_vjp_bwd)


def _vjp_fwd(x, U, s, V):
    return spectral_matmul(x, U, s, V), (x, U, s, V)


def _vjp_bwd(res, dy):
    x, U, s, V = res
    lead = x.shape[:-1]
    m = x.shape[-1]
    n = V.shape[0]
    x2 = x.reshape(-1, m)
    dy2 = dy.reshape(-1, n)
    # recompute h (remat) — never stored in HBM by the forward
    h = jnp.dot(x2, U.astype(x2.dtype), preferred_element_type=jnp.float32)
    hs = h * s.astype(jnp.float32)
    dV = jnp.einsum("Mn,Mk->nk", dy2.astype(jnp.float32), hs).astype(V.dtype)
    dhs = jnp.dot(dy2, V.astype(dy2.dtype), preferred_element_type=jnp.float32)
    ds = jnp.einsum("Mk,Mk->k", dhs, h).astype(s.dtype)
    dh = dhs * s.astype(jnp.float32)
    dU = jnp.einsum("Mm,Mk->mk", x2.astype(jnp.float32), dh).astype(U.dtype)
    dx = jnp.dot(dh.astype(x2.dtype), U.T.astype(x2.dtype),
                 preferred_element_type=jnp.float32).astype(x.dtype)
    return dx.reshape(*lead, m), dU, ds, dV


spectral_matmul.defvjp(_vjp_fwd, _vjp_bwd)
