"""Fused int8 spectral matmul Pallas TPU kernel:
y = ((x @ U_q8) * (u_scale * s * v_scale)) @ V_q8.T.

Consumes the int8 factors *directly* — the dequantized fp U/V of the old
serving path (dequantize_int8 then the fp kernel) is never materialized,
in HBM or anywhere else. This works because quantize_int8 scales U and V
per *column* (the rank axis k), so dequantization commutes with both
matmuls:

    x @ (U_q8 · diag(u_scale))            = (x @ U_q8) · diag(u_scale)
    h  @ (V_q8 · diag(v_scale))ᵀ          = (h · diag(v_scale)) @ V_q8ᵀ

and the three per-k vectors (u_scale, s, v_scale) collapse into one
fused gain applied to the VMEM-resident bottleneck ``h`` — a k-length
multiply where the unfused chain pays two full (m, k)/(n, k) dequant
materializations. Int8 tiles are widened to the activation dtype
per-tile in VMEM on their way into the MXU (int8 values are exact in
bf16: |q| <= 127 < 2^8).

Same two-phase tiling as the fp kernel (spectral_matmul.py): grid
(M/bm, Tm + Tn); phase 1 accumulates h (bm, k) into fp32 scratch from
streamed x/U_q8 m-chunks, phase 2 emits y tiles from (h * gain) @ V_q8ᵀ
n-chunks. VMEM drops below the fp kernel's budget — the streamed factor
tiles are 2-4x smaller at int8.

Serving-only: quantized factors carry no gradient (the training params
are the fp factors). ops.py wraps this with a custom_vjp that *raises*
under differentiation instead of silently miscomputing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, uq_ref, g_ref, vq_ref, y_ref, h_ref, *, tm: int, tn: int):
    t = pl.program_id(1)

    # ---- phase 1: h += x_chunk @ widen(U_q8_chunk) ----
    @pl.when(t == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    @pl.when(t < tm)
    def _accum():
        h_ref[...] += jnp.dot(
            x_ref[...], uq_ref[...].astype(x_ref.dtype),
            preferred_element_type=jnp.float32,
        )

    # ---- phase 2: y_tile = (h * gain) @ widen(V_q8_chunk)^T ----
    @pl.when(t >= tm)
    def _emit():
        hs = (h_ref[...] * g_ref[...]).astype(x_ref.dtype)
        y_ref[...] = jnp.dot(
            hs, vq_ref[...].T.astype(x_ref.dtype),
            preferred_element_type=jnp.float32,
        ).astype(y_ref.dtype)


def spectral_matmul_q8_pallas(
    x: jax.Array,
    U_q8: jax.Array,
    gain: jax.Array,
    V_q8: jax.Array,
    *,
    bm: int,
    cm: int,
    cn: int,
    interpret: bool = False,
) -> jax.Array:
    """x: (M, m) float; U_q8: (m, k) int8; gain: (k,) fp32 — the fused
    u_scale * s * v_scale; V_q8: (n, k) int8 -> (M, n) in x.dtype.
    Requires M % bm == 0, m % cm == 0, n % cn == 0 (ops.py pads)."""
    M, m = x.shape
    mk, k = U_q8.shape
    n, vk = V_q8.shape
    assert m == mk and k == vk and gain.shape == (k,), \
        (x.shape, U_q8.shape, gain.shape, V_q8.shape)
    assert M % bm == 0 and m % cm == 0 and n % cn == 0, (M, m, n, bm, cm, cn)
    tm, tn = m // cm, n // cn

    return pl.pallas_call(
        functools.partial(_kernel, tm=tm, tn=tn),
        grid=(M // bm, tm + tn),
        in_specs=[
            pl.BlockSpec((bm, cm), lambda i, t: (i, jnp.minimum(t, tm - 1))),
            pl.BlockSpec((cm, k), lambda i, t: (jnp.minimum(t, tm - 1), 0)),
            pl.BlockSpec((1, k), lambda i, t: (0, 0)),
            pl.BlockSpec((cn, k), lambda i, t: (jnp.maximum(t - tm, 0), 0)),
        ],
        out_specs=pl.BlockSpec((bm, cn), lambda i, t: (i, jnp.maximum(t - tm, 0))),
        out_shape=jax.ShapeDtypeStruct((M, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, k), jnp.float32)],
        interpret=interpret,
    )(x, U_q8, gain.astype(jnp.float32).reshape(1, k), V_q8)
