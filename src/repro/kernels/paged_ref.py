"""Pure-jnp oracles for the paged flash-decode kernels: gather the
block table into a contiguous logical view, then masked direct softmax —
the exact composition the serving path used before the kernels existed
(nn/attention.py keeps the same math inline as its reference branch).
Signatures mirror kernels/paged_decode.py one-for-one so the
differential harness can swap them freely."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_gqa_decode_ref(q, k_pool, v_pool, block_table, seq_lens):
    """q: (b, kvh, rep, hd); pools (P+1, page, kvh, hd); block_table
    (b, n); seq_lens (b,). Returns (b, kvh, rep, hd) in q.dtype."""
    from repro.serving.paged_cache import paged_gather

    hd = q.shape[-1]
    ck = paged_gather(k_pool, block_table).astype(q.dtype)  # (b, S, kvh, hd)
    cv = paged_gather(v_pool, block_table).astype(q.dtype)
    S = ck.shape[1]
    valid = jnp.arange(S)[None, :] <= seq_lens[:, None]
    scores = jnp.einsum("bgrd,bkgd->bgrk", q, ck).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bgrk,bkgd->bgrd", probs, cv)


def paged_mla_decode_ref(q_lat, q_rope, ckv_pool, kr_pool, block_table,
                         seq_lens, *, scale):
    """q_lat: (b, h, L); q_rope: (b, h, R); latent pools (P+1, page, L)
    / (P+1, page, R). Returns o_lat (b, h, L) — same contract as the
    kernel: the caller applies W_uv / W_o."""
    from repro.serving.paged_cache import paged_gather

    cckv = paged_gather(ckv_pool, block_table).astype(q_lat.dtype)  # (b,S,L)
    ckr = paged_gather(kr_pool, block_table).astype(q_rope.dtype)
    S = cckv.shape[1]
    valid = jnp.arange(S)[None, :] <= seq_lens[:, None]
    scores = (
        jnp.einsum("bhl,bSl->bhS", q_lat, cckv)
        + jnp.einsum("bhr,bSr->bhS", q_rope, ckr)
    ).astype(jnp.float32) * scale
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q_lat.dtype)
    return jnp.einsum("bhS,bSl->bhl", probs, cckv)
