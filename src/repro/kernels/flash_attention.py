"""Pallas TPU flash-attention (forward) kernel.

Grid: (batch*kv_groups*rep, q_chunks). Each program streams kv chunks
for one (batch, head, q-chunk) tile with online softmax — scores/probs
never leave VMEM. This is the production TPU path for the attention
layers; the jnp fallback in nn/attention.py (same math, same chunking)
is what the 512-device dry-run partitions, and the roofline substitutes
this kernel's HBM traffic for the fallback's (roofline/hlo_cost.py
KERNEL_SCOPES) — see DESIGN.md S6.

VMEM at cq=512, ck=1024, d=128, bf16 in / fp32 acc:
q 128K + k/v 2x256K + scores 2MB (f32) + acc 256K ~= 3 MB << 16 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.interpret import resolve_interpret

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            nk: int, cq: int, ck: int, causal: bool, scale: float):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    s_ij = jax.lax.dot_general(
        q_ref[0], k_ref[0], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                              # (cq, ck)
    if causal:
        qpos = qi * cq + jax.lax.broadcasted_iota(jnp.int32, (cq, ck), 0)
        kpos = kj * ck + jax.lax.broadcasted_iota(jnp.int32, (cq, ck), 1)
        s_ij = jnp.where(qpos >= kpos, s_ij, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s_ij, axis=-1, keepdims=True))
    p = jnp.exp(s_ij - m_new)                              # (cq, ck)
    alpha = jnp.exp(m_prev - m_new)                        # (cq, 1)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(kj == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           cq: int = 512, ck: int = 1024,
                           interpret: bool | None = None):
    """q: (B, sq, d), k/v: (B, skv, d) with B = batch*heads folded.
    Returns (B, sq, d). Requires sq % cq == 0, skv % ck == 0."""
    B, sq, d = q.shape
    skv = k.shape[1]
    cq = min(cq, sq)
    ck = min(ck, skv)
    assert sq % cq == 0 and skv % ck == 0, (sq, skv, cq, ck)
    nq, nk = sq // cq, skv // ck
    scale = 1.0 / (d ** 0.5)

    return pl.pallas_call(
        functools.partial(_kernel, nk=nk, cq=cq, ck=ck, causal=causal, scale=scale),
        grid=(B, nq, nk),
        in_specs=[
            pl.BlockSpec((1, cq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, ck, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, ck, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, cq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((cq, d), jnp.float32),   # acc
            pltpu.VMEM((cq, 1), jnp.float32),   # running max
            pltpu.VMEM((cq, 1), jnp.float32),   # running sum
        ],
        interpret=resolve_interpret(interpret),
    )(q, k, v)
