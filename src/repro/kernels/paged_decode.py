"""Paged flash-decode Pallas TPU kernels: batched one-token attention
straight against the paged KV pools, walking each slot's block table
*inside* the kernel.

The jnp serving path (nn/attention.py ``apply_*_decode_paged``) gathers
every slot's pages into a contiguous ``(b, S, ...)`` view before
attending — a full logical-cache copy written to and re-read from HBM on
every decode step. Here the block table rides in as a scalar-prefetch
operand, so the BlockSpec index map resolves ``logical page j of slot i
-> physical page bt[i, j]`` while the grid walks pages: KV stream
page-by-page from the pool into VMEM and the gathered copy never exists
(the serving-side expression of the paper's never-materialize rule).

Two variants, matching the two attention families that page:

  * GQA  — q ``(b, kvh, rep, hd)`` against pools ``(P+1, page, kvh, hd)``;
    one program per (slot, kv head, page), online softmax over the page
    axis with per-position validity ``pos <= seq_lens[i]``.
  * MLA (absorbed) — q already absorbed into the latent space:
    ``q_lat (b, h, L)`` / ``q_rope (b, h, R)`` against latent pools
    ``(P+1, page, L)`` / ``(P+1, page, R)``; scores are the sum of both
    dot products and the page's ckv rows double as the values (the MLA
    trick — full K/V is never expanded).

Inactive slots follow the paged_append contract: their block tables
point at the null page (physical id P) and ``seq_lens == 0``, so the
kernel harmlessly attends over one null-page position; the engine
ignores those rows.

Interpret/compiled resolution is the shared ``SCT_INTERPRET`` switch
(kernels/interpret.py). The jnp references live in kernels/paged_ref.py
and tests/test_kernels_paged.py holds the differential suite.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.interpret import resolve_interpret

NEG_INF = -1e30

_TRUTHY = ("1", "true", "yes", "on")
_FALSY = ("0", "false", "no", "off")


def paged_kernel_enabled() -> bool:
    """Serving gate for the paged flash-decode kernels, read at trace
    time by nn/attention.py. ``SCT_PAGED_KERNEL=0`` falls back to the
    jnp gather-then-attend reference path (the differential oracle);
    default is the kernel (its interpret/compiled mode is then resolved
    by ``SCT_INTERPRET`` like every other kernel)."""
    env = os.environ.get("SCT_PAGED_KERNEL")
    if env is not None and env.strip():
        v = env.strip().lower()
        if v in _TRUTHY:
            return True
        if v in _FALSY:
            return False
        raise ValueError(
            f"SCT_PAGED_KERNEL={env!r}: expected one of {_TRUTHY + _FALSY}")
    return True


# ------------------------------------------------------------------ GQA --

def _gqa_kernel(bt_ref, sl_ref, q_ref, k_ref, v_ref, o_ref,
                acc_ref, m_ref, l_ref, *, page: int, n_pages: int,
                scale: float):
    i = pl.program_id(0)                      # slot
    j = pl.program_id(2)                      # logical page

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                    # (rep, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)              # (page, hd)
    s_ij = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                              # (rep, page)
    pos = j * page + jax.lax.broadcasted_iota(jnp.int32, s_ij.shape, 1)
    s_ij = jnp.where(pos <= sl_ref[i], s_ij, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s_ij, axis=-1, keepdims=True))
    p = jnp.exp(s_ij - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v_ref[0, :, 0, :].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(j == n_pages - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                       ).astype(o_ref.dtype)


def paged_gqa_decode_pallas(q, k_pool, v_pool, block_table, seq_lens, *,
                            interpret: bool | None = None):
    """q: (b, kvh, rep, hd) grouped one-token queries; k_pool/v_pool:
    (P+1, page, kvh, hd) shared pools (paged_append already ran — the
    new token sits at logical position seq_lens[i]); block_table:
    (b, n_pages) int32; seq_lens: (b,) int32. Returns (b, kvh, rep, hd)
    in q.dtype: softmax attention over logical positions
    ``pos <= seq_lens[i]``, bit-comparable to gather + masked _sdpa."""
    b, kvh, rep, hd = q.shape
    page = k_pool.shape[1]
    n_pages = block_table.shape[1]
    scale = 1.0 / (hd ** 0.5)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kvh, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, rep, hd), lambda i, g, j, bt, sl: (i, g, 0, 0)),
            # the block-table walk: logical page j of slot i -> physical
            # page bt[i, j] of the pool (null page for inactive slots)
            pl.BlockSpec((1, page, 1, hd),
                         lambda i, g, j, bt, sl: (bt[i, j], 0, g, 0)),
            pl.BlockSpec((1, page, 1, hd),
                         lambda i, g, j, bt, sl: (bt[i, j], 0, g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, hd),
                               lambda i, g, j, bt, sl: (i, g, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep, hd), jnp.float32),   # acc
            pltpu.VMEM((rep, 1), jnp.float32),    # running max
            pltpu.VMEM((rep, 1), jnp.float32),    # running sum
        ],
    )
    return pl.pallas_call(
        functools.partial(_gqa_kernel, page=page, n_pages=n_pages,
                          scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, rep, hd), q.dtype),
        interpret=resolve_interpret(interpret),
    )(block_table, seq_lens, q, k_pool, v_pool)


# ------------------------------------------------------- GQA cold-KV --

def _gqa_cold_kernel(bt_ref, sl_ref, cold_ref, q_ref, k_ref, v_ref,
                     kq_ref, ks_ref, vq_ref, vs_ref, o_ref,
                     acc_ref, m_ref, l_ref, *, page: int, n_pages: int,
                     scale: float):
    """GQA paged decode with per-page cold-KV substitution: pages whose
    physical id is flagged in ``cold_ref`` read their K/V from the int8
    shadow pool, dequantized in-register with the page's per-channel
    scale. Hot pages are bit-identical to :func:`_gqa_kernel`."""
    i = pl.program_id(0)                      # slot
    j = pl.program_id(2)                      # logical page

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    is_cold = cold_ref[bt_ref[i, j]] != 0
    q = q_ref[0, 0].astype(jnp.float32)                    # (rep, hd)
    k_hot = k_ref[0, :, 0, :].astype(jnp.float32)          # (page, hd)
    k_cold = (kq_ref[0, :, 0, :].astype(jnp.float32)
              * ks_ref[0, 0].astype(jnp.float32)[None, :])
    k = jnp.where(is_cold, k_cold, k_hot)
    s_ij = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                              # (rep, page)
    pos = j * page + jax.lax.broadcasted_iota(jnp.int32, s_ij.shape, 1)
    s_ij = jnp.where(pos <= sl_ref[i], s_ij, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s_ij, axis=-1, keepdims=True))
    p = jnp.exp(s_ij - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    v_hot = v_ref[0, :, 0, :].astype(jnp.float32)
    v_cold = (vq_ref[0, :, 0, :].astype(jnp.float32)
              * vs_ref[0, 0].astype(jnp.float32)[None, :])
    v = jnp.where(is_cold, v_cold, v_hot)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(j == n_pages - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                       ).astype(o_ref.dtype)


def paged_gqa_decode_cold_pallas(q, k_pool, v_pool, k_q8, k_scale,
                                 v_q8, v_scale, block_table, seq_lens,
                                 cold_flags, *,
                                 interpret: bool | None = None):
    """Cold-aware :func:`paged_gqa_decode_pallas`: same contract plus the
    int8 shadow pools ``k_q8``/``v_q8`` (P+1, page, kvh, hd), per-page
    scales ``k_scale``/``v_scale`` (P+1, kvh, hd) — the token axis is
    the reduced one (serving/quantize.py ``quantize_kv_pages``) — and
    ``cold_flags`` (P+1,) int32, riding as a third scalar-prefetch
    operand so the flag lookup costs one SMEM read per page."""
    b, kvh, rep, hd = q.shape
    page = k_pool.shape[1]
    n_pages = block_table.shape[1]
    scale = 1.0 / (hd ** 0.5)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, kvh, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, rep, hd),
                         lambda i, g, j, bt, sl, cold: (i, g, 0, 0)),
            pl.BlockSpec((1, page, 1, hd),
                         lambda i, g, j, bt, sl, cold: (bt[i, j], 0, g, 0)),
            pl.BlockSpec((1, page, 1, hd),
                         lambda i, g, j, bt, sl, cold: (bt[i, j], 0, g, 0)),
            pl.BlockSpec((1, page, 1, hd),
                         lambda i, g, j, bt, sl, cold: (bt[i, j], 0, g, 0)),
            pl.BlockSpec((1, 1, hd),
                         lambda i, g, j, bt, sl, cold: (bt[i, j], g, 0)),
            pl.BlockSpec((1, page, 1, hd),
                         lambda i, g, j, bt, sl, cold: (bt[i, j], 0, g, 0)),
            pl.BlockSpec((1, 1, hd),
                         lambda i, g, j, bt, sl, cold: (bt[i, j], g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, hd),
                               lambda i, g, j, bt, sl, cold: (i, g, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep, hd), jnp.float32),   # acc
            pltpu.VMEM((rep, 1), jnp.float32),    # running max
            pltpu.VMEM((rep, 1), jnp.float32),    # running sum
        ],
    )
    return pl.pallas_call(
        functools.partial(_gqa_cold_kernel, page=page, n_pages=n_pages,
                          scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, rep, hd), q.dtype),
        interpret=resolve_interpret(interpret),
    )(block_table, seq_lens, cold_flags, q, k_pool, v_pool,
      k_q8, k_scale, v_q8, v_scale)


# ------------------------------------------------------------------ MLA --

def _mla_kernel(bt_ref, sl_ref, ql_ref, qr_ref, ckv_ref, kr_ref, o_ref,
                acc_ref, m_ref, l_ref, *, page: int, n_pages: int,
                scale: float):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    ckv = ckv_ref[0].astype(jnp.float32)                   # (page, L)
    s_ij = (
        jax.lax.dot_general(
            ql_ref[0].astype(jnp.float32), ckv, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        + jax.lax.dot_general(
            qr_ref[0].astype(jnp.float32), kr_ref[0].astype(jnp.float32),
            (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    ) * scale                                              # (h, page)
    pos = j * page + jax.lax.broadcasted_iota(jnp.int32, s_ij.shape, 1)
    s_ij = jnp.where(pos <= sl_ref[i], s_ij, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s_ij, axis=-1, keepdims=True))
    p = jnp.exp(s_ij - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    # the page's latent rows double as the values — no K/V expansion
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, ckv, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(j == n_pages - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


def paged_mla_decode_pallas(q_lat, q_rope, ckv_pool, kr_pool, block_table,
                            seq_lens, *, scale: float,
                            interpret: bool | None = None):
    """Absorbed-MLA one-token decode against paged latent pools.

    q_lat: (b, h, L) — q_nope already absorbed through W_uk; q_rope:
    (b, h, R); ckv_pool: (P+1, page, L); kr_pool: (P+1, page, R);
    block_table: (b, n_pages); seq_lens: (b,). ``scale`` is the score
    scale 1/sqrt(qk_nope_dim + qk_rope_dim) — the *pre-absorption* head
    dim, so it is passed in rather than derived from L. Returns the
    latent context o_lat (b, h, L); the caller applies W_uv + W_o."""
    b, h, lat = q_lat.shape
    rope_d = q_rope.shape[-1]
    page = ckv_pool.shape[1]
    n_pages = block_table.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, n_pages),
        in_specs=[
            pl.BlockSpec((1, h, lat), lambda i, j, bt, sl: (i, 0, 0)),
            pl.BlockSpec((1, h, rope_d), lambda i, j, bt, sl: (i, 0, 0)),
            pl.BlockSpec((1, page, lat), lambda i, j, bt, sl: (bt[i, j], 0, 0)),
            pl.BlockSpec((1, page, rope_d),
                         lambda i, j, bt, sl: (bt[i, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, lat), lambda i, j, bt, sl: (i, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((h, lat), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_mla_kernel, page=page, n_pages=n_pages,
                          scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, lat), q_lat.dtype),
        interpret=resolve_interpret(interpret),
    )(block_table, seq_lens, q_lat, q_rope, ckv_pool, kr_pool)


# ------------------------------------------------------- MLA cold-KV --

def _mla_cold_kernel(bt_ref, sl_ref, cold_ref, ql_ref, qr_ref,
                     ckv_ref, kr_ref, cq_ref, cs_ref, rq_ref, rs_ref,
                     o_ref, acc_ref, m_ref, l_ref, *, page: int,
                     n_pages: int, scale: float):
    """Absorbed-MLA paged decode with cold-page substitution: flagged
    pages read latent/rope rows from the int8 shadow pools, dequantized
    in-register. The dequantized ckv rows double as the values, same as
    the hot path."""
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    is_cold = cold_ref[bt_ref[i, j]] != 0
    ckv_hot = ckv_ref[0].astype(jnp.float32)               # (page, L)
    ckv_cold = (cq_ref[0].astype(jnp.float32)
                * cs_ref[0].astype(jnp.float32)[None, :])
    ckv = jnp.where(is_cold, ckv_cold, ckv_hot)
    kr_hot = kr_ref[0].astype(jnp.float32)                 # (page, R)
    kr_cold = (rq_ref[0].astype(jnp.float32)
               * rs_ref[0].astype(jnp.float32)[None, :])
    kr = jnp.where(is_cold, kr_cold, kr_hot)
    s_ij = (
        jax.lax.dot_general(
            ql_ref[0].astype(jnp.float32), ckv, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        + jax.lax.dot_general(
            qr_ref[0].astype(jnp.float32), kr,
            (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    ) * scale                                              # (h, page)
    pos = j * page + jax.lax.broadcasted_iota(jnp.int32, s_ij.shape, 1)
    s_ij = jnp.where(pos <= sl_ref[i], s_ij, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s_ij, axis=-1, keepdims=True))
    p = jnp.exp(s_ij - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, ckv, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(j == n_pages - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


def paged_mla_decode_cold_pallas(q_lat, q_rope, ckv_pool, kr_pool,
                                 ckv_q8, ckv_scale, kr_q8, kr_scale,
                                 block_table, seq_lens, cold_flags, *,
                                 scale: float,
                                 interpret: bool | None = None):
    """Cold-aware :func:`paged_mla_decode_pallas`: adds the int8 latent
    shadow pools (P+1, page, L)/(P+1, page, R), their per-page scales
    (P+1, L)/(P+1, R), and the (P+1,) int32 ``cold_flags`` as a third
    scalar-prefetch operand."""
    b, h, lat = q_lat.shape
    rope_d = q_rope.shape[-1]
    page = ckv_pool.shape[1]
    n_pages = block_table.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, n_pages),
        in_specs=[
            pl.BlockSpec((1, h, lat), lambda i, j, bt, sl, cold: (i, 0, 0)),
            pl.BlockSpec((1, h, rope_d),
                         lambda i, j, bt, sl, cold: (i, 0, 0)),
            pl.BlockSpec((1, page, lat),
                         lambda i, j, bt, sl, cold: (bt[i, j], 0, 0)),
            pl.BlockSpec((1, page, rope_d),
                         lambda i, j, bt, sl, cold: (bt[i, j], 0, 0)),
            pl.BlockSpec((1, page, lat),
                         lambda i, j, bt, sl, cold: (bt[i, j], 0, 0)),
            pl.BlockSpec((1, lat), lambda i, j, bt, sl, cold: (bt[i, j], 0)),
            pl.BlockSpec((1, page, rope_d),
                         lambda i, j, bt, sl, cold: (bt[i, j], 0, 0)),
            pl.BlockSpec((1, rope_d),
                         lambda i, j, bt, sl, cold: (bt[i, j], 0)),
        ],
        out_specs=pl.BlockSpec((1, h, lat),
                               lambda i, j, bt, sl, cold: (i, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((h, lat), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_mla_cold_kernel, page=page, n_pages=n_pages,
                          scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, lat), q_lat.dtype),
        interpret=resolve_interpret(interpret),
    )(block_table, seq_lens, cold_flags, q_lat, q_rope, ckv_pool, kr_pool,
      ckv_q8, ckv_scale, kr_q8, kr_scale)
