"""One switch for Pallas interpret mode, shared by every kernel.

Resolution order:
  1. ``SCT_INTERPRET`` env var ("1"/"true" forces interpret, "0"/"false"
     forces compiled) — what CI sets explicitly;
  2. otherwise: interpret everywhere except on a TPU backend.

CI, laptops, and TPU runs all go through this one code path instead of
a hand-flipped module constant.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

_TRUTHY = ("1", "true", "yes", "on")
_FALSY = ("0", "false", "no", "off")


def default_interpret() -> bool:
    env = os.environ.get("SCT_INTERPRET")
    # empty string == unset (lets CI matrix legs blank the var instead of
    # conditionally exporting it)
    if env is not None and env.strip():
        v = env.strip().lower()
        if v in _TRUTHY:
            return True
        if v in _FALSY:
            return False
        raise ValueError(f"SCT_INTERPRET={env!r}: expected one of {_TRUTHY + _FALSY}")
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """None -> environment default; explicit bool wins."""
    return default_interpret() if interpret is None else interpret
