"""Pure-jnp oracle for the fused spectral matmul kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def spectral_matmul_ref(x: jax.Array, U: jax.Array, s: jax.Array, V: jax.Array) -> jax.Array:
    """y = ((x @ U) * s) @ V.T — paper Eq. 2-4. x: (M, m), U: (m, k),
    s: (k,), V: (n, k) -> y: (M, n). Accumulation in fp32."""
    h = jnp.dot(x, U.astype(x.dtype), preferred_element_type=jnp.float32)
    h = h * s.astype(jnp.float32)
    y = jnp.dot(h.astype(x.dtype), V.T.astype(x.dtype), preferred_element_type=jnp.float32)
    return y.astype(x.dtype)
