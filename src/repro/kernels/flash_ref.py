"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q: (B, sq, d), k/v: (B, skv, d). fp32 softmax."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) / (d ** 0.5)
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bqk,bkd->bqd", p, v)
