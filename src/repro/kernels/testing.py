"""Differential kernel-vs-reference test harness.

Every Pallas kernel in this repo ships with a jnp oracle (kernels/ref.py,
flash_ref.py, paged_ref.py) and a test that sweeps the two against each
other. This module is the shared machinery for those sweeps so each new
kernel adds *cases*, not comparison plumbing:

  - a per-precision tolerance ladder (``TOLERANCE_LADDER`` /
    :func:`tolerance_for`) — one place where "how close is close enough
    in bf16" is decided, instead of magic constants per test file;
  - :func:`assert_kernel_matches` — runs kernel and reference on the same
    inputs and compares in fp32, normalized by the reference magnitude so
    a kernel whose output is large doesn't pass on rtol alone;
  - :func:`forced_interpret` — a context manager pinning
    ``SCT_INTERPRET=1`` for the enclosed block, so a test can assert the
    interpret path specifically regardless of the CI matrix leg it runs
    under;
  - fuzz helpers (:func:`scale_profile`, :func:`ragged_seq_lens`,
    :func:`make_block_table`) generating the adversarial inputs the
    paged/int8 kernels must survive: per-channel scales spanning eight
    decades, sequence lengths hitting every page-boundary edge, block
    tables with shuffled physical pages and null-page tails.

How to add a kernel: write the jnp oracle first, then the Pallas kernel
with the same signature, then a parameterized test calling
``assert_kernel_matches(kernel, oracle, args, dtype=...)`` over a shape
sweep that includes non-tile-multiple sizes. See docs/kernels.md.
"""
from __future__ import annotations

import contextlib
import os
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class Tol(NamedTuple):
    """Relative / absolute tolerance pair for one precision rung."""

    rtol: float
    atol: float


# One rung per compute precision. fp32 kernels accumulate in fp32 and
# differ from the oracle only by reassociation (~1e-6 observed; 5e-5
# leaves headroom for unlucky shapes). bf16 inputs carry ~3 decimal
# digits, so anything tighter than ~1e-2 tests the rounding of the
# inputs, not the kernel.
TOLERANCE_LADDER: dict = {
    jnp.dtype(jnp.float32): Tol(rtol=5e-5, atol=5e-5),
    jnp.dtype(jnp.bfloat16): Tol(rtol=5e-2, atol=5e-2),
    jnp.dtype(jnp.float16): Tol(rtol=5e-3, atol=5e-3),
}


def tolerance_for(dtype: Any, ladder: Optional[dict] = None) -> Tol:
    """Look up the tolerance rung for ``dtype`` (raises KeyError for a
    precision the ladder has no opinion on — add a rung deliberately
    rather than inheriting a neighbour's)."""
    table = TOLERANCE_LADDER if ladder is None else ladder
    return table[jnp.dtype(dtype)]


def assert_kernel_matches(
    kernel_fn: Callable[..., jax.Array],
    ref_fn: Callable[..., jax.Array],
    args: tuple,
    *,
    dtype: Any = None,
    tol: Optional[Tol] = None,
    ladder: Optional[dict] = None,
    ref_args: Optional[tuple] = None,
    label: str = "",
) -> None:
    """Run ``kernel_fn(*args)`` and ``ref_fn(*(ref_args or args))`` and
    assert the outputs agree within the ladder rung for ``dtype``.

    Both outputs are compared in fp32 after dividing by
    ``max(1, max|ref|)`` — the reference magnitude, so rtol means the
    same thing whether the kernel emits O(1) attention outputs or O(1e3)
    logits. ``dtype`` defaults to the kernel output's dtype; pass
    ``tol`` to override the ladder for one call (e.g. an int8 kernel
    whose error floor is set by quantization, not by the activation
    precision). ``ref_args`` lets the oracle take a different argument
    layout than the kernel (gathered vs paged)."""
    y = kernel_fn(*args)
    yr = ref_fn(*(args if ref_args is None else ref_args))
    assert y.shape == yr.shape, (
        f"{label or kernel_fn.__name__}: kernel shape {y.shape} != "
        f"reference shape {yr.shape}")
    if tol is None:
        tol = tolerance_for(y.dtype if dtype is None else dtype, ladder)
    yf = np.asarray(y, np.float32)
    yrf = np.asarray(yr, np.float32)
    scale = max(1.0, float(np.max(np.abs(yrf))) if yrf.size else 1.0)
    np.testing.assert_allclose(
        yf / scale, yrf / scale, rtol=tol.rtol, atol=tol.atol,
        err_msg=f"{label or kernel_fn.__name__}: kernel vs reference "
                f"(outputs scaled by 1/{scale:g})")


@contextlib.contextmanager
def forced_interpret(value: str = "1"):
    """Pin ``SCT_INTERPRET`` for the enclosed block (default: force
    interpret mode), restoring the previous value — including *unset* —
    on exit. Kernels resolve the env var at call time
    (kernels/interpret.py), so no re-jit bookkeeping is needed; callers
    must not reuse a function already jitted outside the block."""
    prev = os.environ.get("SCT_INTERPRET")
    os.environ["SCT_INTERPRET"] = value
    try:
        yield
    finally:
        if prev is None:
            os.environ.pop("SCT_INTERPRET", None)
        else:
            os.environ["SCT_INTERPRET"] = prev


# ---------------------------------------------------------------------------
# Fuzz input generators
# ---------------------------------------------------------------------------

# Per-channel scale shapes the int8 kernels must absorb without drift.
# "extreme" spans eight decades across the rank axis — the fused gain
# multiplies three such vectors, so this is the stress test for the
# scale-commutation identity.
SCALE_PROFILES = ("unit", "extreme", "tiny", "huge", "alternating")


def scale_profile(kind: str, k: int) -> jax.Array:
    """A (k,) fp32 per-channel scale vector of the named shape."""
    if kind == "unit":
        return jnp.ones((k,), jnp.float32)
    if kind == "extreme":
        return (10.0 ** jnp.linspace(-4.0, 4.0, k)).astype(jnp.float32)
    if kind == "tiny":
        return jnp.full((k,), 1e-4, jnp.float32)
    if kind == "huge":
        return jnp.full((k,), 1e4, jnp.float32)
    if kind == "alternating":
        return jnp.where(jnp.arange(k) % 2 == 0, 1e-3, 1e3).astype(jnp.float32)
    raise ValueError(f"unknown scale profile {kind!r}; one of {SCALE_PROFILES}")


def ragged_seq_lens(batch: int, max_len: int, page: int,
                    seed: int = 0) -> jax.Array:
    """(batch,) int32 sequence lengths covering the masking edge cases:
    slot 0 is empty (len 0, the inactive-slot convention), slot 1 ends
    exactly on a page boundary, slot 2 one *before* a boundary, slot 3
    is full; remaining slots are uniform random. Lengths count valid
    positions as the paged kernels see them post-append (``pos <= len``
    is in-bounds), so ``max_len`` here is the largest legal index."""
    edges = [0, min(page, max_len), min(2 * page - 1, max_len), max_len]
    rng = np.random.default_rng(seed)
    body = rng.integers(0, max_len + 1, size=max(0, batch - len(edges)))
    lens = np.concatenate([np.asarray(edges[:batch]), body])[:batch]
    return jnp.asarray(lens, jnp.int32)


def make_block_table(batch: int, n_pages_per_seq: int, num_pages: int,
                     seq_lens: jax.Array, page: int,
                     seed: int = 0) -> jax.Array:
    """(batch, n_pages_per_seq) int32 block table with *shuffled*
    physical page ids — adjacent logical pages land on non-adjacent
    physical pages, so a kernel that secretly assumes contiguity fails
    loudly. Pages past each row's live prefix point at the null page
    (physical id ``num_pages``), matching serving/paged_cache.py's
    layout for unallocated tail pages."""
    assert batch * n_pages_per_seq <= num_pages, "pool too small to fuzz"
    rng = np.random.default_rng(seed)
    perm = rng.permutation(num_pages)[: batch * n_pages_per_seq]
    table = perm.reshape(batch, n_pages_per_seq).astype(np.int32)
    lens = np.asarray(seq_lens)
    for i in range(batch):
        live = int(lens[i]) // page + 1          # page holding position len
        table[i, live:] = num_pages              # null page
    return jnp.asarray(table, jnp.int32)
