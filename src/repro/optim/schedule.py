"""LR schedules: linear warmup + cosine decay (the standard pre-training
schedule; the paper's diagnosed bottleneck is exactly this knob)."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ScheduleConfig:
    peak_lr: float = 5e-4
    warmup_steps: int = 100
    total_steps: int = 2000
    final_fraction: float = 0.1
    kind: str = "cosine"  # cosine | linear | constant


def make_schedule(cfg: ScheduleConfig):
    def schedule(step):
        # 1-indexed so the first optimizer step gets a nonzero LR
        step = jnp.asarray(step, jnp.float32) + 1.0
        warm = cfg.peak_lr * jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
        if cfg.kind == "constant":
            return warm
        frac = jnp.clip(
            (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
            0.0, 1.0,
        )
        if cfg.kind == "cosine":
            decay = cfg.final_fraction + (1 - cfg.final_fraction) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        else:
            decay = 1.0 - (1 - cfg.final_fraction) * frac
        return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * decay)

    return schedule
