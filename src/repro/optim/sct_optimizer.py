"""The SCT training step as an optimizer wrapper: AdamW on all params,
then Stiefel retraction of every spectral U/V (paper Algorithm 1).

``retract_every`` > 1 is a beyond-paper optimization: orthogonality
drift per AdamW step is O(lr), so retracting every r steps keeps the
error bounded at O(r*lr) while cutting the retraction cost (40-50% of
the paper's 70B step time) by r. r=1 is the faithful default.

Mixed precision (core/precision.py): with a loss-scaling policy the
state carries a ``loss_scale`` entry, incoming gradients are *scaled*
(the step builder multiplied the loss), and ``apply`` unscales them,
checks finiteness, and wraps the AdamW-update + retraction in a
``lax.cond`` so an overflowed step leaves params, moments, and the
manifold untouched while the scale backs off. Master params are stored
in ``policy.param_dtype`` (fp32 for 'mixed' — the master U/s/V the
forward casts down from).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.precision import (
    PrecisionPolicy,
    all_finite,
    cast_tree,
    loss_scale_init,
    loss_scale_update,
    precision_policy,
    unscale_grads,
)
from repro.core.tree import retract_tree
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.clip import clip_by_global_norm
from repro.optim.schedule import ScheduleConfig, make_schedule

TrainState = dict  # {"params", "opt", "step"[, "loss_scale"]}


@dataclasses.dataclass(frozen=True)
class SCTOptimizer:
    adamw: AdamWConfig
    schedule: ScheduleConfig
    retraction: str = "qr"
    retract_every: int = 1
    clip_norm: float = 1.0
    retract_axis_name: Optional[str] = None   # set inside shard_map
    precision: Optional[PrecisionPolicy] = None  # None -> legacy fp32 path

    def init(self, params: Any) -> TrainState:
        if self.precision is not None:
            params = cast_tree(params, self.precision.param_jnp)
        state = {
            "params": params,
            "opt": adamw_init(params, self.adamw.moment_dtype),
            "step": jnp.zeros((), jnp.int32),
        }
        if self.precision is not None and self.precision.loss_scaling:
            state["loss_scale"] = loss_scale_init(self.precision)
        return state

    # ------------------------------------------------------------------
    def _update(self, params: Any, opt: Any, step: jax.Array, grads: Any):
        """One AdamW step + (conditional) retraction. ``step`` is the
        pre-increment counter: the schedule reads it, the retraction
        cadence checks step+1 — both exactly as the fp32 path always did."""
        lr_t = make_schedule(self.schedule)(step)
        if self.clip_norm:
            grads, _ = clip_by_global_norm(grads, self.clip_norm)
        params, opt = adamw_update(params, grads, opt, self.adamw, lr_t)
        if self.retract_every == 1:
            params = retract_tree(params, self.retraction, self.retract_axis_name)
        else:
            params = jax.lax.cond(
                (step + 1) % self.retract_every == 0,
                lambda p: retract_tree(p, self.retraction, self.retract_axis_name),
                lambda p: p,
                params,
            )
        return params, opt

    def resize(self, key: jax.Array, state: TrainState, target) -> TrainState:
        """Resize every spectral group in the TrainState to ``target``
        (uniform int or ``{group_path: rank}`` mapping) — params and the
        Adam moments together, Stiefel feasibility preserved via this
        optimizer's own retraction (rank/resize.py). Host-side: the
        returned state has new shapes, so the caller must re-jit its
        step function (rank/controller.py owns that in the train loop)."""
        from repro.rank.resize import resize_train_state

        return resize_train_state(key, state, target, retraction=self.retraction)

    def apply(self, state: TrainState, grads: Any) -> TrainState:
        pol = self.precision
        # both the step builder (which scales the loss) and this unscale
        # path key on policy AND state, so a checkpoint written under a
        # different precision policy degrades to the unscaled path on
        # both sides instead of scaling on one side only
        if pol is None or not pol.loss_scaling or "loss_scale" not in state:
            params, opt = self._update(state["params"], state["opt"],
                                       state["step"], grads)
            out = dict(state)
            out.update(params=params, opt=opt, step=state["step"] + 1)
            return out

        # mixed path: grads arrive scaled by state["loss_scale"]["scale"]
        ls = state["loss_scale"]
        grads = unscale_grads(grads, ls)
        finite = all_finite(grads)
        params, opt = jax.lax.cond(
            finite,
            lambda p, o, g: self._update(p, o, state["step"], g),
            lambda p, o, g: (p, o),
            state["params"], state["opt"], grads,
        )
        # the step counter advances even on a skip: the data stream and
        # LR schedule stay aligned with the global step
        out = dict(state)   # preserve any extra TrainState entries
        out.update(params=params, opt=opt, step=state["step"] + 1,
                   loss_scale=loss_scale_update(ls, finite, pol))
        return out


def make_sct_optimizer(
    model_cfg=None,
    *,
    lr: float = 5e-4,
    warmup: int = 100,
    total_steps: int = 2000,
    clip_norm: float = 1.0,
    spectral_lr_scale: float = 1.0,
    dense_lr_scale: float = 1.0,
    weight_decay: float = 0.01,
    precision: Union[str, PrecisionPolicy, None] = None,
) -> SCTOptimizer:
    retraction = model_cfg.sct.retraction if model_cfg is not None else "qr"
    retract_every = model_cfg.sct.retract_every if model_cfg is not None else 1
    return SCTOptimizer(
        adamw=AdamWConfig(
            lr=lr,
            weight_decay=weight_decay,
            spectral_lr_scale=spectral_lr_scale,
            dense_lr_scale=dense_lr_scale,
        ),
        schedule=ScheduleConfig(peak_lr=lr, warmup_steps=warmup, total_steps=total_steps),
        retraction=retraction,
        retract_every=retract_every,
        clip_norm=clip_norm,
        precision=precision_policy(precision),
    )
