"""The SCT training step as an optimizer wrapper: AdamW on all params,
then Stiefel retraction of every spectral U/V (paper Algorithm 1).

``retract_every`` > 1 is a beyond-paper optimization: orthogonality
drift per AdamW step is O(lr), so retracting every r steps keeps the
error bounded at O(r*lr) while cutting the retraction cost (40-50% of
the paper's 70B step time) by r. r=1 is the faithful default.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.tree import retract_tree
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.clip import clip_by_global_norm
from repro.optim.schedule import ScheduleConfig, make_schedule

TrainState = dict  # {"params", "opt", "step"}


@dataclasses.dataclass(frozen=True)
class SCTOptimizer:
    adamw: AdamWConfig
    schedule: ScheduleConfig
    retraction: str = "qr"
    retract_every: int = 1
    clip_norm: float = 1.0
    retract_axis_name: Optional[str] = None   # set inside shard_map

    def init(self, params: Any) -> TrainState:
        return {
            "params": params,
            "opt": adamw_init(params),
            "step": jnp.zeros((), jnp.int32),
        }

    def apply(self, state: TrainState, grads: Any) -> TrainState:
        lr_t = make_schedule(self.schedule)(state["step"])
        if self.clip_norm:
            grads, _ = clip_by_global_norm(grads, self.clip_norm)
        params, opt = adamw_update(state["params"], grads, state["opt"], self.adamw, lr_t)
        step = state["step"] + 1
        if self.retract_every == 1:
            params = retract_tree(params, self.retraction, self.retract_axis_name)
        else:
            params = jax.lax.cond(
                step % self.retract_every == 0,
                lambda p: retract_tree(p, self.retraction, self.retract_axis_name),
                lambda p: p,
                params,
            )
        return {"params": params, "opt": opt, "step": step}


def make_sct_optimizer(
    model_cfg=None,
    *,
    lr: float = 5e-4,
    warmup: int = 100,
    total_steps: int = 2000,
    clip_norm: float = 1.0,
    spectral_lr_scale: float = 1.0,
    dense_lr_scale: float = 1.0,
    weight_decay: float = 0.01,
) -> SCTOptimizer:
    retraction = model_cfg.sct.retraction if model_cfg is not None else "qr"
    retract_every = model_cfg.sct.retract_every if model_cfg is not None else 1
    return SCTOptimizer(
        adamw=AdamWConfig(
            lr=lr,
            weight_decay=weight_decay,
            spectral_lr_scale=spectral_lr_scale,
            dense_lr_scale=dense_lr_scale,
        ),
        schedule=ScheduleConfig(peak_lr=lr, warmup_steps=warmup, total_steps=total_steps),
        retraction=retraction,
        retract_every=retract_every,
        clip_norm=clip_norm,
    )
