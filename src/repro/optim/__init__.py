from repro.core.precision import (
    PrecisionPolicy,
    precision_policy,
    loss_scale_init,
    loss_scale_update,
    all_finite,
)
from repro.optim.adamw import adamw_init, adamw_update, AdamWConfig
from repro.optim.schedule import make_schedule, ScheduleConfig
from repro.optim.clip import global_norm, clip_by_global_norm
from repro.optim.sct_optimizer import (
    SCTOptimizer,
    make_sct_optimizer,
    TrainState,
)

__all__ = [
    "PrecisionPolicy",
    "precision_policy",
    "loss_scale_init",
    "loss_scale_update",
    "all_finite",
    "adamw_init",
    "adamw_update",
    "AdamWConfig",
    "make_schedule",
    "ScheduleConfig",
    "global_norm",
    "clip_by_global_norm",
    "SCTOptimizer",
    "make_sct_optimizer",
    "TrainState",
]
