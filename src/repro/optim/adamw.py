"""AdamW from scratch (no optax in this environment), with per-leaf
learning-rate scaling — the paper's 'per-component learning rate
scheduling' next step (S4.3): dense attention/embeddings can train at
the dense LR while spectral factors get a higher one.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 5e-4                  # paper's SCT learning rate
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    # per-component scaling (multiplies lr on the matching leaves):
    spectral_lr_scale: float = 1.0    # U, V factors
    sv_lr_scale: float = 1.0          # singular values s
    dense_lr_scale: float = 1.0       # everything else
    decay_spectral: bool = False      # weight decay fights orthonormality;
                                      # retraction would undo it anyway
    moment_dtype: str = "float32"     # storage dtype of mu/nu (math is
                                      # always fp32; bf16 halves state
                                      # memory at some Adam fidelity cost)


def adamw_init(params: Any, moment_dtype: str = "float32") -> dict:
    md = jnp.dtype(moment_dtype)
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros_like(x, dtype=md), p)
    return {"mu": zeros(params), "nu": zeros(params), "count": jnp.zeros((), jnp.int32)}


def _leaf_kind_tree(params: Any):
    """0 = dense, 1 = spectral U/V, 2 = spectral s. Mirrors params."""
    from repro.core.spectral import is_spectral

    def walk(tree):
        if is_spectral(tree):
            return {k: (1 if k in ("U", "V") else 2 if k == "s" else 0) for k in tree}
        if isinstance(tree, dict):
            return {k: walk(v) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            return type(tree)(walk(v) for v in tree)
        return 0

    return walk(params)


def adamw_update(params: Any, grads: Any, state: dict, cfg: AdamWConfig,
                 lr_t: jax.Array | float | None = None):
    """One AdamW step. lr_t overrides cfg.lr (schedule value).
    Returns (new_params, new_state)."""
    count = state["count"] + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    base_lr = cfg.lr if lr_t is None else lr_t
    kinds = _leaf_kind_tree(params)

    md = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, mu, nu, kind):
        g = g.astype(jnp.float32)
        mu = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g
        nu = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * (g * g)
        mhat = mu / b1c
        nhat = nu / b2c
        scale = {0: cfg.dense_lr_scale, 1: cfg.spectral_lr_scale, 2: cfg.sv_lr_scale}[kind]
        step = mhat / (jnp.sqrt(nhat) + cfg.eps)
        wd = cfg.weight_decay
        if kind in (1, 2) and not cfg.decay_spectral:
            wd = 0.0
        new_p = p.astype(jnp.float32) - base_lr * scale * (step + wd * p.astype(jnp.float32))
        return new_p.astype(p.dtype), mu.astype(md), nu.astype(md)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    flat_k = jax.tree.leaves(kinds)
    out = [upd(p, g, mu, nu, k) for p, g, mu, nu, k in
           zip(flat_p, flat_g, flat_mu, flat_nu, flat_k)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, {"mu": new_mu, "nu": new_nu, "count": count}
