"""Top-level entrypoint: ``python -m repro <command> [flags...]``.

One front door for the whole system, dispatching to the thin CLI
adapters (each of which is argparse -> RunSpec -> facade):

  train    repro.launch.train    Trainer facade (fault-tolerant loop)
  serve    repro.launch.serve    Server facade (paged) / static oracle
  dryrun   repro.launch.dryrun   512-device lower+compile sweep
  bench    benchmarks.run        traffic harness + paper tables/kernels

Every ``train``/``serve`` flag set resolves to a RunSpec first and
every ``bench`` subcommand to a BenchSpec (``--dump-spec`` prints it),
so the CLI surface and the programmatic API (docs/api.md,
docs/benchmarks.md) can never drift. ``bench`` needs the repo root on
sys.path (run from the checkout, as ``benchmarks/`` sits next to
``src/``).
"""
from __future__ import annotations

import sys
from typing import Optional, Sequence

USAGE = """\
usage: python -m repro {train,serve,dryrun,bench} [flags...]

commands:
  train    train a model (argparse -> RunSpec -> repro.api.Trainer)
  serve    serve a model (argparse -> RunSpec -> repro.api.Server)
  dryrun   lower + compile every (arch x shape x mesh) cell at 512 devices
  bench    traffic harness (bench serving -> BENCH_serving.json) +
           paper-table / kernel benches; `bench --help` lists suites

`python -m repro <command> --help` shows that command's flags.
"""


def main(argv: Optional[Sequence[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv or argv[0] in ("-h", "--help", "help"):
        print(USAGE, end="")
        return 0
    cmd, rest = argv[0], argv[1:]
    if cmd == "train":
        from repro.launch.train import main as run

        run(rest)
    elif cmd == "serve":
        from repro.launch.serve import main as run

        run(rest)
    elif cmd == "dryrun":
        from repro.launch.dryrun import main as run

        run(rest)
    elif cmd == "bench":
        try:
            from benchmarks.run import main as run
        except ModuleNotFoundError:
            print("python -m repro bench: the benchmarks/ package is not "
                  "importable — run from the repo root (it lives next to "
                  "src/, outside the installed package)", file=sys.stderr)
            return 2
        sys.argv = ["benchmarks.run", *rest]
        run()
    else:
        print(f"python -m repro: unknown command {cmd!r}\n{USAGE}",
              file=sys.stderr, end="")
        return 2
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
