"""Production traffic harness: deterministic load generation over the
``Server`` facade, swept by a declarative :class:`BenchSpec`, emitting
schema-validated ``BENCH_<area>.json`` perf-trajectory files.

    from repro.api import BenchSpec
    from repro.bench import run_bench, write_bench

    doc = run_bench(BenchSpec())        # 1x/2x overload, fifo vs slo
    write_bench(doc, "BENCH_serving.json")

``schema`` stays importable without jax (tools/check_bench.py loads it
by file path); the generator and runner import lazily through here.
"""
from repro.bench.schema import (
    ARM_METRIC_KEYS,
    SCHEMA_VERSION,
    bench_envelope,
    validate_bench,
)
from repro.bench.workload import generate_requests
from repro.bench.runner import (
    arm_metrics,
    run_bench,
    run_speculative_bench,
    run_streaming_bench,
    write_bench,
)

__all__ = [
    "SCHEMA_VERSION",
    "ARM_METRIC_KEYS",
    "bench_envelope",
    "validate_bench",
    "generate_requests",
    "arm_metrics",
    "run_bench",
    "run_speculative_bench",
    "run_streaming_bench",
    "write_bench",
]
