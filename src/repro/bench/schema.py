"""The ``BENCH_<area>.json`` envelope: builder + validator.

This module is the **perf-trajectory contract**: every benchmark emits
one envelope, the envelope is committed at the repo root, and CI
re-validates both the freshly emitted file and the committed ones — so
a schema change that would silently orphan historical numbers fails
the build instead (tools/check_bench.py).

Deliberately **stdlib-only at import time**: the CI checker loads this
file by path via importlib, outside the jax-heavy ``repro`` package,
so validation runs in a bare interpreter in milliseconds.

Envelope shape (``schema_version`` 1)::

    {
      "schema_version": 1,
      "area": "serving",                  # BENCH_<area>.json
      "spec": { ... BenchSpec.to_dict() ... },
      "results": [                        # one entry per swept arm
        {"overload": 1.0, "scheduler": "fifo",
         "metrics": {requests, completed, timed_out, shed,
                     ttft_p50_steps, ttft_p99_steps,
                     itl_p50_s, itl_p99_s,
                     tokens_per_s, goodput_tokens_per_s,
                     slo_met_tokens, generated_tokens,
                     peak_pages, wall_s, ...}},
        ...
      ],
      "throughput": [                     # optional: variant axis
        {"precision": "fp32", "rank": null,
         "tokens_per_s": ..., "weight_bytes": ...}, ...
      ],
      "entries": [                        # optional: table-style rows
        {"name": "spectral_q8",           # required when "deterministic"
         "us_per_call": 123.4,            #   is present (diffed by name)
         "deterministic": { ... }},       # machine-independent columns:
        ...                               #   CI diffs these exactly
      ]
    }

``metrics`` may carry extra keys (per-tenant token counts, cache-page
stats); the required set above is the floor a trajectory diff can rely
on across PRs.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

__all__ = ["SCHEMA_VERSION", "ARM_METRIC_KEYS", "THROUGHPUT_KEYS",
           "bench_envelope", "validate_bench"]

SCHEMA_VERSION = 1

# the metric floor every results arm must report (all numbers)
ARM_METRIC_KEYS = (
    "requests",
    "completed",
    "timed_out",
    "shed",
    "ttft_p50_steps",
    "ttft_p99_steps",
    "itl_p50_s",
    "itl_p99_s",
    "tokens_per_s",
    "goodput_tokens_per_s",
    "slo_met_tokens",
    "generated_tokens",
    "peak_pages",
    "wall_s",
)

THROUGHPUT_KEYS = ("precision", "rank", "tokens_per_s", "weight_bytes")


def bench_envelope(area: str, spec: Dict[str, Any],
                   results: List[Dict[str, Any]],
                   throughput: Optional[List[Dict[str, Any]]] = None,
                   entries: Optional[List[Dict[str, Any]]] = None,
                   ) -> Dict[str, Any]:
    """Assemble a schema-valid envelope (and assert it is one — an
    emitter bug should die here, not in CI)."""
    doc: Dict[str, Any] = {
        "schema_version": SCHEMA_VERSION,
        "area": area,
        "spec": spec,
        "results": results,
    }
    if throughput is not None:
        doc["throughput"] = throughput
    if entries is not None:
        doc["entries"] = entries
    errors = validate_bench(doc)
    if errors:
        raise ValueError("emitter produced an invalid envelope:\n  "
                         + "\n  ".join(errors))
    return doc


def _is_num(v: Any) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def validate_bench(doc: Any) -> List[str]:
    """All schema violations in ``doc`` (empty list = valid). Collects
    every error instead of stopping at the first, so a drifted file
    reads as one actionable report."""
    errs: List[str] = []
    if not isinstance(doc, dict):
        return [f"envelope must be a JSON object, got {type(doc).__name__}"]
    if doc.get("schema_version") != SCHEMA_VERSION:
        errs.append(f"schema_version must be {SCHEMA_VERSION}, "
                    f"got {doc.get('schema_version')!r}")
    if not isinstance(doc.get("area"), str) or not doc.get("area"):
        errs.append("area must be a non-empty string")
    if not isinstance(doc.get("spec"), dict):
        errs.append("spec must be an object (BenchSpec.to_dict())")

    results = doc.get("results", [])
    if not isinstance(results, list):
        errs.append("results must be an array of arm objects")
        results = []
    for i, arm in enumerate(results):
        where = f"results[{i}]"
        if not isinstance(arm, dict):
            errs.append(f"{where} must be an object")
            continue
        if not _is_num(arm.get("overload")):
            errs.append(f"{where}.overload must be a number")
        if not isinstance(arm.get("scheduler"), str):
            errs.append(f"{where}.scheduler must be a string")
        metrics = arm.get("metrics")
        if not isinstance(metrics, dict):
            errs.append(f"{where}.metrics must be an object")
            continue
        for key in ARM_METRIC_KEYS:
            if key not in metrics:
                errs.append(f"{where}.metrics missing {key!r}")
            elif metrics[key] is not None and not _is_num(metrics[key]):
                errs.append(f"{where}.metrics.{key} must be a number "
                            f"or null, got {type(metrics[key]).__name__}")

    if "throughput" in doc:
        tp = doc["throughput"]
        if not isinstance(tp, list):
            errs.append("throughput must be an array")
            tp = []
        for i, row in enumerate(tp):
            where = f"throughput[{i}]"
            if not isinstance(row, dict):
                errs.append(f"{where} must be an object")
                continue
            for key in THROUGHPUT_KEYS:
                if key not in row:
                    errs.append(f"{where} missing {key!r}")
            if "precision" in row and not isinstance(row["precision"], str):
                errs.append(f"{where}.precision must be a string")
            for key in ("tokens_per_s", "weight_bytes"):
                if key in row and not _is_num(row[key]):
                    errs.append(f"{where}.{key} must be a number")
            if "rank" in row and row["rank"] is not None \
                    and not _is_num(row["rank"]):
                errs.append(f"{where}.rank must be a number or null")

    entries = doc.get("entries", [])
    if not isinstance(entries, list):
        errs.append("entries must be an array")
        entries = []
    else:
        for i, row in enumerate(entries):
            if not isinstance(row, dict):
                errs.append(f"entries[{i}] must be an object")
                continue
            # rows carrying CI-diffed deterministic columns must be
            # addressable: check_bench --diff matches entries by name
            if "deterministic" in row:
                if not isinstance(row.get("name"), str) or not row["name"]:
                    errs.append(f"entries[{i}]: rows with a "
                                "'deterministic' object need a non-empty "
                                "string 'name'")
                elif not isinstance(row["deterministic"], dict):
                    errs.append(f"entries[{i}].deterministic must be an "
                                "object")
    # serving-style benches fill results arms; table-style benches fill
    # entries rows; an envelope with neither measures nothing
    if not results and not entries:
        errs.append("at least one of results / entries must be non-empty")
    return errs
