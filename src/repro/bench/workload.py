"""Deterministic synthetic traffic from a :class:`WorkloadSpec`.

The generator's contract is **body/arrival separation**: request
*bodies* (tenant, priority, token ids, prompt/output lengths, deadline)
depend only on ``(spec, seed, geometry)`` — the ``overload`` factor
scales nothing but the arrival-time process. Two traces at 1× and 2×
overload therefore contain the *same* requests, just pushed at the
engine faster, which is what makes goodput-under-overload comparisons
meaningful: the work offered is identical, only its timing differs.

Arrival processes run in engine-step time (the deterministic clock the
scheduler's deadlines are measured against):

  * ``poisson``  — arrivals per step ~ Poisson(rate × overload);
  * ``onoff``    — the bursty variant: Poisson(rate × overload) during
    ``on_steps``-step bursts, zero arrivals for ``off_steps`` between
    them (rate is *not* rescaled to preserve the long-run mean — an
    ON-window at the same instantaneous rate is the point: queueing
    behaviour under bursts, not under a thinner trickle);
  * ``fixed``    — evenly spaced, ``rate × overload`` per step.

Lengths are lognormal(mean, cv) — the long-tail shape of production
prompt/output lengths — clipped to the serving geometry so every
generated request is admissible (an inadmissible request would wedge
FIFO admission forever and say nothing about scheduling).
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.api.specs import SLOSpec, WorkloadSpec
from repro.serving.scheduler import Request

__all__ = ["generate_requests"]

# seed-stream tags: the body stream must stay byte-identical when the
# arrival stream changes (overload), so each draws from its own rng
_BODY_STREAM = 0
_ARRIVAL_STREAM = 1
_PREFIX_STREAM = 2


def _lognormal_lengths(rng: np.random.Generator, n: int, mean: float,
                       cv: float) -> np.ndarray:
    """n integer lengths >= 1 with the requested mean and coefficient
    of variation; cv=0 pins every draw to the mean exactly."""
    if cv <= 0:
        return np.full(n, max(1, int(round(mean))), dtype=np.int64)
    sigma2 = np.log1p(cv * cv)
    mu = np.log(mean) - sigma2 / 2.0
    draws = rng.lognormal(mean=mu, sigma=np.sqrt(sigma2), size=n)
    return np.maximum(1, np.rint(draws).astype(np.int64))


def _arrival_steps(wl: WorkloadSpec, overload: float) -> List[int]:
    """One engine-step arrival time per request, non-decreasing."""
    n = wl.requests
    rate = wl.rate * overload
    if wl.arrival == "fixed":
        return [int(i / rate) for i in range(n)]
    rng = np.random.default_rng([wl.seed, _ARRIVAL_STREAM])
    arrivals: List[int] = []
    step = 0
    period = wl.on_steps + wl.off_steps
    while len(arrivals) < n:
        if wl.arrival == "onoff" and (step % period) >= wl.on_steps:
            step += 1
            continue
        count = rng.poisson(rate)
        arrivals.extend([step] * int(count))
        step += 1
    return arrivals[:n]


def generate_requests(wl: WorkloadSpec, slo: Optional[SLOSpec] = None, *,
                      vocab: int, max_total: int,
                      overload: float = 1.0) -> List[Request]:
    """The trace for one bench arm: ``wl.requests`` scheduler Requests,
    arrival-stamped by the spec's process at ``overload`` times the
    nominal rate.

    ``vocab`` bounds the token ids; ``max_total`` is the serving
    geometry's per-sequence capacity (pages_per_seq × page_size) —
    prompt + generation budget are clipped under it. Deadlines come
    from ``slo.deadline_for(priority)``; tenants get ids ``t0..tN`` and
    a per-tenant shared system prefix of ``wl.shared_prefix`` tokens.
    """
    if max_total < wl.shared_prefix + 2:
        raise ValueError(
            f"geometry max_total={max_total} cannot fit shared_prefix="
            f"{wl.shared_prefix} plus a 1-token tail and 1 generated token")
    slo = slo if slo is not None else SLOSpec()
    n = wl.requests
    body = np.random.default_rng([wl.seed, _BODY_STREAM])

    tw = np.asarray(wl.tenant_weights(), dtype=np.float64)
    pw = np.asarray(wl.priority_weights(), dtype=np.float64)
    tenant_idx = body.choice(len(tw), size=n, p=tw / tw.sum())
    priorities = body.choice(len(pw), size=n, p=pw / pw.sum())

    # one shared system prompt per tenant, stable across specs that
    # only differ in arrival shape (own stream, keyed by tenant index)
    prefixes = [
        np.random.default_rng([wl.seed, _PREFIX_STREAM, t]).integers(
            0, vocab, size=wl.shared_prefix, dtype=np.int64)
        for t in range(len(tw))
    ]

    tails = _lognormal_lengths(body, n, wl.prompt_mean, wl.prompt_cv)
    gens = _lognormal_lengths(body, n, wl.gen_mean, wl.gen_cv)
    # clip to geometry: tail first (keep >= 1), then the gen budget
    budget = max_total - wl.shared_prefix
    tails = np.minimum(tails, budget - 1)
    gens = np.minimum(gens, budget - tails)

    arrivals = _arrival_steps(wl, overload)

    out: List[Request] = []
    for i in range(n):
        tail = body.integers(0, vocab, size=int(tails[i]), dtype=np.int64)
        prompt = np.concatenate([prefixes[tenant_idx[i]], tail])
        pri = int(priorities[i])
        out.append(Request(
            rid=i,
            prompt=prompt.astype(np.int32),
            max_new_tokens=int(gens[i]),
            arrival=int(arrivals[i]),
            deadline=slo.deadline_for(pri),
            tenant=f"t{int(tenant_idx[i])}",
            priority=pri,
        ))
    return out
