"""Rank resize ops: grow/shrink live spectral factors between steps.

Shrink keeps the columns of ``U``/``V`` belonging to the ``new_k``
largest singular values — by Eckart–Young the best rank-``new_k``
approximation of the represented matrix, with error exactly the
discarded tail mass (telemetry's ``tail_mass``). Grow pads ``U``/``V``
with random columns orthogonal-completed against the existing basis
(project-then-QR, applied twice for fp32-grade orthogonality) and pads
``s`` with zeros, so the represented matrix is *unchanged* by a grow:
the new directions start contributing nothing and are recruited by the
optimizer through the gradient of ``s``.

Both operations also resize the Adam moments: shrink gathers the same
column indices chosen for the params (a moment must follow its
parameter), grow zero-pads (fresh optimizer state for fresh columns).

Everything runs host-side between steps — a resize changes array shapes
and therefore forces a re-jit of the train step and regeneration of the
sharding specs anyway (rank/controller.py owns that), so there is
nothing to win by tracing these ops.

Shape conventions: spectral groups are ``{"U": (..., m, k),
"s": (..., k), "V": (..., n, k)}`` with an optional stacked layer/expert
prefix ``...``; all ops broadcast over the prefix, and stacked layers
each select their own top-k columns on shrink.
"""
from __future__ import annotations

import hashlib
from typing import Any, Dict, Mapping, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.retraction import retract
from repro.core.spectral import is_spectral

RankTarget = Union[int, Mapping[str, int]]


def _fold_path(key: jax.Array, path: str) -> jax.Array:
    """Deterministic per-group key: fold a stable hash of the group path
    into the base key so resize is reproducible across processes."""
    h = int.from_bytes(hashlib.sha256(path.encode()).digest()[:4], "big")
    return jax.random.fold_in(key, h)


def shrink_indices(s: jax.Array, new_k: int) -> jax.Array:
    """Column indices of the ``new_k`` largest-|s| singular values,
    kept in original column order (stable: minimizes the permutation a
    shrink applies). ``s (..., k)`` -> int32 ``(..., new_k)``."""
    order = jnp.argsort(-jnp.abs(s.astype(jnp.float32)), axis=-1)
    return jnp.sort(order[..., :new_k], axis=-1).astype(jnp.int32)


def _take_cols(M: jax.Array, idx: jax.Array) -> jax.Array:
    """Gather columns of ``M (..., m, k)`` per stacked entry using
    ``idx (..., new_k)`` -> ``(..., m, new_k)``."""
    return jnp.take_along_axis(M, idx[..., None, :], axis=-1)


def shrink_group(group: Dict[str, jax.Array], new_k: int,
                 idx: Optional[jax.Array] = None) -> Tuple[Dict[str, jax.Array], jax.Array]:
    """Truncate a spectral group to its top-``new_k`` singular
    directions. Returns ``(new_group, idx)`` where ``idx`` is the
    column-selection tensor — pass it back in to shrink the matching
    Adam-moment group consistently. No retraction needed: a column
    subset of an orthonormal basis is orthonormal."""
    k = group["s"].shape[-1]
    if not 1 <= new_k <= k:
        raise ValueError(f"shrink target {new_k} outside [1, {k}]")
    if idx is None:
        idx = shrink_indices(group["s"], new_k)
    out = dict(group)
    out["U"] = _take_cols(group["U"], idx)
    out["V"] = _take_cols(group["V"], idx)
    out["s"] = jnp.take_along_axis(group["s"], idx, axis=-1)
    return out, idx


def _orthogonal_complement_cols(key: jax.Array, U: jax.Array, add: int) -> jax.Array:
    """``add`` new orthonormal columns orthogonal to the columns of
    ``U (..., m, k)``. Gaussian draw, project out span(U), QR, and a
    second projection pass (classical Gram-Schmidt loses orthogonality
    at fp32 when the random draw leans into span(U); twice is enough)."""
    m = U.shape[-2]
    Uf = U.astype(jnp.float32)
    E = jax.random.normal(key, U.shape[:-1] + (add,), dtype=jnp.float32)
    for _ in range(2):
        E = E - jnp.einsum("...mk,...kl->...ml", Uf,
                           jnp.einsum("...mk,...ml->...kl", Uf, E))
        Q, R = jnp.linalg.qr(E)
        d = jnp.diagonal(R, axis1=-2, axis2=-1)
        E = Q * jnp.where(d >= 0, 1.0, -1.0).astype(Q.dtype)[..., None, :]
    return E


def grow_group(key: jax.Array, group: Dict[str, jax.Array], new_k: int, *,
               retraction: str = "qr", s_init: float = 0.0) -> Dict[str, jax.Array]:
    """Grow a spectral group to rank ``new_k``: pad ``U`` and ``V`` with
    orthogonal-completed random columns, pad ``s`` with ``s_init``
    (default 0.0 — the represented matrix is bit-unchanged and the new
    directions are recruited via the gradient of ``s``), then re-retract
    the padded factors so the group lands exactly on the Stiefel
    manifold in its storage dtype."""
    k = group["s"].shape[-1]
    m, n = group["U"].shape[-2], group["V"].shape[-2]
    if new_k < k:
        raise ValueError(f"grow target {new_k} < current rank {k}")
    if new_k > min(m, n):
        raise ValueError(f"grow target {new_k} exceeds min(m={m}, n={n})")
    if new_k == k:
        return dict(group)
    add = new_k - k
    ku, kv = jax.random.split(_fold_path(key, "grow"))
    out = dict(group)
    for name, kk in (("U", ku), ("V", kv)):
        M = group[name]
        new_cols = _orthogonal_complement_cols(kk, M, add)
        padded = jnp.concatenate([M.astype(jnp.float32), new_cols], axis=-1)
        out[name] = retract(padded, method=retraction).astype(M.dtype)
    pad = jnp.full(group["s"].shape[:-1] + (add,), s_init, group["s"].dtype)
    out["s"] = jnp.concatenate([group["s"], pad], axis=-1)
    return out


def resize_group(key: jax.Array, group: Dict[str, jax.Array], new_k: int, *,
                 retraction: str = "qr") -> Dict[str, jax.Array]:
    """Dispatch: shrink when ``new_k`` is below the current rank, grow
    when above, and an explicit bit-exact no-op when equal — same-rank
    targets come from config-driven callers (a degenerate speculative
    ladder like ``[128, 128]``, a schedule that re-states the current
    rank) and must neither gather nor re-retract the factors."""
    k = group["s"].shape[-1]
    if new_k == k:
        return dict(group)
    if new_k < k:
        return shrink_group(group, new_k)[0]
    return grow_group(key, group, new_k, retraction=retraction)


# ----------------------------------------------------------------- trees --

def _walk_resize(key, params, moments, target, retraction, path=""):
    """Joint walk over params and an optional tuple of moment trees with
    identical structure; spectral groups resize together."""
    if is_spectral(params):
        new_k = target.get(path) if isinstance(target, Mapping) else target
        if new_k is None:
            return params, moments
        k = params["s"].shape[-1]
        new_k = int(new_k)
        if new_k == k:
            return params, moments
        gkey = _fold_path(key, path)
        if new_k < k:
            new_p, idx = shrink_group(params, new_k)
            new_m = tuple(shrink_group(m, new_k, idx)[0] for m in moments)
        else:
            new_p = grow_group(gkey, params, new_k, retraction=retraction)
            add = new_k - k

            def pad_moment(g):
                out = dict(g)
                for name in ("U", "V"):
                    M = g[name]
                    z = jnp.zeros(M.shape[:-1] + (add,), M.dtype)
                    out[name] = jnp.concatenate([M, z], axis=-1)
                z = jnp.zeros(g["s"].shape[:-1] + (add,), g["s"].dtype)
                out["s"] = jnp.concatenate([g["s"], z], axis=-1)
                return out

            new_m = tuple(pad_moment(m) for m in moments)
        return new_p, new_m
    if isinstance(params, dict):
        outs = {}
        mouts = [dict(m) for m in moments]
        for k in params:
            sub = tuple(m[k] for m in moments)
            p2, m2 = _walk_resize(key, params[k], sub, target, retraction,
                                  f"{path}/{k}" if path else k)
            outs[k] = p2
            for mo, v in zip(mouts, m2):
                mo[k] = v
        return outs, tuple(mouts)
    if isinstance(params, (list, tuple)):
        items = []
        mitems = [[] for _ in moments]
        for i, v in enumerate(params):
            sub = tuple(m[i] for m in moments)
            p2, m2 = _walk_resize(key, v, sub, target, retraction, f"{path}/[{i}]")
            items.append(p2)
            for li, x in zip(mitems, m2):
                li.append(x)
        ctor = type(params)
        return ctor(items), tuple(ctor(li) for li in mitems)
    return params, moments


def resize_tree(key: jax.Array, params: Any, target: RankTarget, *,
                retraction: str = "qr") -> Any:
    """Resize every spectral group in a parameter tree to ``target``
    (an int applied uniformly, or a ``{group_path: rank}`` mapping as
    produced by :func:`rank_metadata`; groups absent from the mapping
    keep their rank). Non-spectral leaves pass through untouched."""
    out, _ = _walk_resize(key, params, (), target, retraction)
    return out


def resize_train_state(key: jax.Array, state: Dict[str, Any], target: RankTarget, *,
                       retraction: str = "qr") -> Dict[str, Any]:
    """Resize a full TrainState — params and the Adam moments ``mu``/
    ``nu`` in one joint walk, so a shrink gathers identical column
    indices in all three trees and a grow zero-pads the moments (fresh
    optimizer state for the fresh directions). ``step``, ``count``,
    ``loss_scale`` and any other scalar entries carry over unchanged."""
    moments = (state["opt"]["mu"], state["opt"]["nu"])
    new_params, (new_mu, new_nu) = _walk_resize(key, state["params"], moments,
                                                target, retraction)
    out = dict(state)
    out["params"] = new_params
    out["opt"] = dict(state["opt"], mu=new_mu, nu=new_nu)
    return out


def clamp_target(params: Any, target: int) -> Dict[str, int]:
    """Expand a uniform rank target into a per-group ``{path: rank}``
    mapping with each entry clamped to that group's ``min(m, n)``, so a
    grow can never overshoot a small projection's full rank. Used by
    the controller and the checkpoint resize-on-restore path."""
    from repro.rank.telemetry import _walk_groups

    out = {}
    for path, g in _walk_groups(params):
        lim = min(g["U"].shape[-2], g["V"].shape[-2])
        out[path] = min(int(target), lim)
    return out


def rank_metadata(params: Any) -> Dict[str, int]:
    """``{group_path: retained_rank}`` for every spectral group — the
    per-layer rank record a checkpoint stores so a restore can detect a
    rank mismatch and resize-on-restore (checkpoint/manager.py)."""
    from repro.rank.telemetry import _walk_groups

    return {path: int(g["s"].shape[-1]) for path, g in _walk_groups(params)}


def current_ranks(params: Any) -> Tuple[int, ...]:
    """Sorted unique retained ranks across the tree (a uniform-rank
    model reports a single value)."""
    return tuple(sorted(set(rank_metadata(params).values())))
