"""Rank schedules: who decides the target rank, and when.

A schedule is consulted at step *boundaries* (after the optimizer step,
before the next batch) with the global step, the current uniform rank,
and the latest host-side telemetry summary. It returns the new target
rank — or None, meaning keep training at the current shapes. The
controller (rank/controller.py) turns a non-None decision into an
actual resize + re-jit.

Three policies, selectable from the CLI (``--rank-schedule``):

  static:K                   resize to K once, at the first boundary
                             (override a checkpoint's rank at resume)
  step:S1=K1[,S2=K2...]      step-triggered: at step Si, resize to Ki
  energy:T[,kv...]           telemetry-triggered: when the mean top-half
                             energy capture exceeds T the model is
                             over-ranked -> shrink by ``factor``; when
                             it falls below ``grow_below`` the spectrum
                             is saturated -> grow by 1/``factor``.
                             kv options: min=8, max=1024, every=25,
                             factor=0.75, grow_below=0.0 (off)

``parse_rank_schedule`` maps those strings to instances.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Tuple


class RankSchedule:
    """Base policy. ``decide`` returns the target uniform rank for the
    next step, or None to keep the current shapes. Implementations must
    be idempotent across repeated calls at the same step (the loop may
    consult more than once around a restart)."""

    def decide(self, step: int, current_rank: int,
               metrics: Optional[Mapping[str, float]] = None) -> Optional[int]:
        raise NotImplementedError


@dataclasses.dataclass
class StaticRankSchedule(RankSchedule):
    """Resize to ``rank`` at the first boundary, then never again —
    the resize-on-restore policy expressed as a schedule."""
    rank: int

    def decide(self, step, current_rank, metrics=None):
        return self.rank if current_rank != self.rank else None


@dataclasses.dataclass
class StepRankSchedule(RankSchedule):
    """``triggers`` is a sorted tuple of (step, rank): at each boundary
    the latest trigger at or before ``step`` wins. Restart-safe: the
    decision is a pure function of the global step, so a run resumed
    from a checkpoint lands on the same rank trajectory."""
    triggers: Tuple[Tuple[int, int], ...]

    def decide(self, step, current_rank, metrics=None):
        target = None
        for at, rank in self.triggers:
            if step >= at:
                target = rank
        if target is not None and target != current_rank:
            return target
        return None


@dataclasses.dataclass
class EnergyRankSchedule(RankSchedule):
    """Telemetry-triggered policy on the ``rank/energy_top`` metric
    (mean fraction of spectral energy in the top half of the retained
    spectrum, telemetry.py). Checked every ``every`` steps:

      energy_top >= shrink_above  -> the tail is dead weight; shrink to
                                     max(min_rank, round(k * factor))
      energy_top <= grow_below    -> the spectrum is flat to the edge;
                                     grow to min(max_rank, round(k / factor))

    ``grow_below=0.0`` disables growth. A flat-spectrum *random init*
    scores energy_top ~0.5, so grow_below should stay well under 0.5.
    """
    shrink_above: float = 0.98
    grow_below: float = 0.0
    factor: float = 0.75
    min_rank: int = 8
    max_rank: int = 1024
    every: int = 25

    def decide(self, step, current_rank, metrics=None):
        if metrics is None or step == 0 or step % self.every:
            return None
        energy = metrics.get("rank/energy_top")
        if energy is None:
            return None
        if energy >= self.shrink_above:
            target = max(self.min_rank, int(round(current_rank * self.factor)))
        elif self.grow_below and energy <= self.grow_below:
            target = min(self.max_rank, int(round(current_rank / self.factor)))
        else:
            return None
        return target if target != current_rank else None


def parse_rank_schedule(spec: Optional[str]) -> Optional[RankSchedule]:
    """Parse a ``--rank-schedule`` CLI string (module docstring grammar)
    into a schedule, or None for None/""/"none"."""
    if spec is None or not spec.strip() or spec.strip().lower() == "none":
        return None
    kind, _, rest = spec.strip().partition(":")
    kind = kind.lower()
    if kind == "static":
        return StaticRankSchedule(rank=int(rest))
    if kind == "step":
        triggers = []
        for part in rest.split(","):
            at, _, rank = part.partition("=")
            if not rank:
                raise ValueError(f"step trigger {part!r}: expected STEP=RANK")
            triggers.append((int(at), int(rank)))
        if not triggers:
            raise ValueError("step schedule needs at least one STEP=RANK trigger")
        return StepRankSchedule(triggers=tuple(sorted(triggers)))
    if kind == "energy":
        parts = [p for p in rest.split(",") if p]
        if not parts or "=" in parts[0]:
            raise ValueError("energy schedule: first field is the shrink threshold")
        kw: Dict[str, float] = {"shrink_above": float(parts[0])}
        names = {"min": "min_rank", "max": "max_rank", "every": "every",
                 "factor": "factor", "grow_below": "grow_below"}
        for part in parts[1:]:
            k, _, v = part.partition("=")
            if k not in names:
                raise ValueError(f"energy schedule: unknown option {k!r} "
                                 f"(options: {sorted(names)})")
            field = names[k]
            kw[field] = float(v) if field in ("factor", "grow_below") else int(v)
        return EnergyRankSchedule(**kw)
    raise ValueError(f"unknown rank schedule kind {kind!r} "
                     "(options: static, step, energy)")
