"""Adaptive rank subsystem.

The paper's central empirical finding (Table 3 / Figure 2) is that all
tested MLP ranks converge to the same loss floor — rank is a *runtime*
resource, not an architectural constant. This package operationalizes
that:

  * ``telemetry``  — cheap per-layer spectral health metrics computed
    from the live ``U/s/V`` factors (effective rank, energy capture,
    tail mass, Stiefel orthogonality drift), emitted through the
    train-loop metrics path.
  * ``resize``     — grow/shrink spectral parameter groups *and* their
    Adam moments between steps, preserving Stiefel feasibility.
  * ``schedule``   — static / step-triggered / telemetry-triggered
    policies that decide the target rank at each step boundary.
  * ``controller`` — glue that applies a schedule inside the training
    loop: resize the train state, regenerate shardings, re-jit the step.
"""
from repro.rank.telemetry import (
    effective_rank,
    energy_capture,
    tail_mass,
    spectral_group_telemetry,
    spectral_telemetry,
    telemetry_summary,
)
from repro.rank.resize import (
    grow_group,
    shrink_group,
    resize_group,
    resize_tree,
    resize_train_state,
    rank_metadata,
    current_ranks,
)
from repro.rank.schedule import (
    RankSchedule,
    StaticRankSchedule,
    StepRankSchedule,
    EnergyRankSchedule,
    parse_rank_schedule,
)

__all__ = [
    "effective_rank",
    "energy_capture",
    "tail_mass",
    "spectral_group_telemetry",
    "spectral_telemetry",
    "telemetry_summary",
    "grow_group",
    "shrink_group",
    "resize_group",
    "resize_tree",
    "resize_train_state",
    "rank_metadata",
    "current_ranks",
    "RankSchedule",
    "StaticRankSchedule",
    "StepRankSchedule",
    "EnergyRankSchedule",
    "parse_rank_schedule",
    "RankController",
]


def __getattr__(name):
    # controller imports launch/sharding machinery; keep it lazy so the
    # core rank ops stay importable from low-level modules without cycles
    if name == "RankController":
        from repro.rank.controller import RankController
        return RankController
    raise AttributeError(name)
