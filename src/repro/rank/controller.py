"""RankController: applies a rank schedule inside the training loop.

A resize changes every spectral factor's shape, which invalidates the
compiled train step and (on a mesh) the NamedSharding tree the loop
restores checkpoints against. The controller owns that lifecycle:

  1. consult the schedule at each step boundary (host-side, O(1));
  2. on a decision: resize the TrainState (params + Adam moments,
     rank/resize.py), clamping the uniform target per-group to
     ``min(m, n)``;
  3. regenerate sharding specs from the *resized* state
     (sharding/partition.py — partition specs name axes, not sizes, so
     the same rules re-apply at the new shapes);
  4. re-jit the train step with the fresh shardings and hand the
     (state, step_fn, shardings) triple back to the loop.

The loop (runtime/train_loop.py) treats the controller as an opaque
hook, so runtime/ stays import-clean of launch/.
"""
from __future__ import annotations

from typing import Any, Mapping, Optional, Tuple

import jax
import numpy as np

from repro.rank.resize import clamp_target, rank_metadata, resize_train_state
from repro.rank.schedule import RankSchedule


class RankController:
    """Drives one schedule for one (cfg, optimizer, mesh) training run.

    ``maybe_resize(step, state, metrics)`` returns None (keep going) or
    ``(new_state, new_step_fn, new_state_shardings)``. ``resizes``
    records ``(step, old_rank, new_rank)`` events for logs and tests.
    """

    def __init__(self, cfg, optimizer, schedule: RankSchedule, *,
                 mesh=None, shape=None, microbatches: int = 1, seed: int = 0,
                 telemetry: bool = True):
        self.cfg = cfg
        self.optimizer = optimizer
        self.schedule = schedule
        self.mesh = mesh
        self.shape = shape
        self.microbatches = microbatches
        # telemetry defaults on: the rank/* metrics are the observable
        # record of a resize (and what energy schedules consume); pass
        # False to trade that visibility for the per-step O(m k^2)
        # orthogonality checks
        self.telemetry = telemetry
        self.key = jax.random.PRNGKey(np.uint32(seed ^ 0x5C7A11))
        self.resizes: list[Tuple[int, int, int]] = []

    # ------------------------------------------------------------------
    @staticmethod
    def uniform_rank(params: Any) -> Optional[int]:
        """The single retained rank when the model is uniform, else the
        max over groups (the schedule reasons about one number; clamped
        per-group targets handle the rest)."""
        ranks = rank_metadata(params)
        return max(ranks.values()) if ranks else None

    def _host_metrics(self, metrics) -> Optional[Mapping[str, float]]:
        if metrics is None:
            return None
        return {k: float(np.asarray(v)) for k, v in metrics.items()
                if k.startswith("rank/")}

    # ------------------------------------------------------------------
    def build_step(self, state: Any):
        """(jitted step_fn, state_shardings) for the state's current
        shapes. Single-device runs jit without explicit shardings; mesh
        runs regenerate the NamedSharding tree from the resized state."""
        from repro.launch import steps as steps_mod
        from repro.sharding.rules import set_current_mesh

        step_fn = steps_mod.make_train_step(self.cfg, self.optimizer,
                                            microbatches=self.microbatches,
                                            telemetry=self.telemetry)
        if self.mesh is None:
            return jax.jit(step_fn, donate_argnums=(0,)), None
        set_current_mesh(self.mesh)
        state_sh, batch_sh = steps_mod.train_shardings(
            self.cfg, self.shape, self.mesh, state_like=state)
        jitted = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None), donate_argnums=(0,))
        return jitted, state_sh

    def maybe_resize(self, step: int, state: Any, metrics=None):
        current = self.uniform_rank(state["params"])
        if current is None:
            return None
        target = self.schedule.decide(step, current, self._host_metrics(metrics))
        if target is None:
            return None
        per_group = clamp_target(state["params"], int(target))
        meta = rank_metadata(state["params"])
        if all(per_group[p] == meta[p] for p in per_group):
            return None
        key = jax.random.fold_in(self.key, step)
        state = resize_train_state(key, state, per_group,
                                   retraction=self.optimizer.retraction)
        step_fn, shardings = self.build_step(state)
        if shardings is not None:
            # the resize ran outside jit, so its outputs carry default
            # placement — commit them to the regenerated sharding tree
            # before the re-jitted step (explicit in_shardings) sees them
            state = jax.device_put(state, shardings)
        # record the *achieved* rank (clamping may cap the schedule's
        # ask). A checkpoint-restart replaying past a trigger re-applies
        # the same deterministic resize — log the event once.
        event = (step, current, max(per_group.values()))
        if event not in self.resizes:
            self.resizes.append(event)
        return state, step_fn, shardings
