"""Spectral telemetry: cheap per-layer health metrics from live factors.

Everything here is O(k) or O(m k^2) on the *factors* — no dense (m, n)
matrix, no SVD of anything bigger than the k singular values we already
store. All functions are pure jnp and jit-safe, so the train step can
fold them into its metrics dict with no extra host round-trip.

Shape conventions: a spectral group is ``{"U": (..., m, k), "s": (..., k),
"V": (..., n, k)}`` where ``...`` is an optional stacked layer/expert
prefix (our models vmap-stack homogeneous layers for lax.scan).
Per-group metrics reduce over the stacked prefix; tree-level summaries
reduce over groups.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.core.manifold import orthogonality_error
from repro.core.spectral import is_spectral


def effective_rank(s: jax.Array) -> jax.Array:
    """Entropy-based effective rank ``exp(H(p))`` of a singular-value
    vector ``s (..., k)``, with ``p_i = s_i^2 / sum_j s_j^2``.

    Returns a float in ``[1, k]`` per stacked entry: k when the spectrum
    is flat, ~1 when one direction dominates. This is the standard
    erank of Roy & Vetterli and what AdaSVD-style importance allocation
    keys on. Reduces nothing — output shape is ``s.shape[:-1]``.
    """
    p = s.astype(jnp.float32) ** 2
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    h = -jnp.sum(p * jnp.log(jnp.maximum(p, 1e-30)), axis=-1)
    return jnp.exp(h)


def energy_capture(s: jax.Array, frac: float = 0.5) -> jax.Array:
    """Fraction of spectral energy ``sum s_i^2`` captured by the top
    ``ceil(frac * k)`` singular values (sorted by magnitude).

    Near 1.0 means the trailing columns carry almost nothing — the layer
    is over-ranked and a shrink is nearly free; near ``frac`` means the
    spectrum is flat and every retained direction is earning its keep.
    Output shape ``s.shape[:-1]``.
    """
    k = s.shape[-1]
    top = max(1, math.ceil(frac * k))
    s2 = jnp.sort(s.astype(jnp.float32) ** 2, axis=-1)[..., ::-1]
    total = jnp.maximum(jnp.sum(s2, axis=-1), 1e-30)
    return jnp.sum(s2[..., :top], axis=-1) / total


def tail_mass(s: jax.Array, tail: int = 1) -> jax.Array:
    """Relative Frobenius mass ``sqrt(sum_{i in tail} s_i^2 / sum s_i^2)``
    of the ``tail`` smallest singular values — the normalized
    Eckart-Young error a shrink by ``tail`` columns would introduce.
    Output shape ``s.shape[:-1]``.
    """
    k = s.shape[-1]
    tail = min(max(tail, 0), k)
    s2 = jnp.sort(s.astype(jnp.float32) ** 2, axis=-1)  # ascending
    total = jnp.maximum(jnp.sum(s2, axis=-1), 1e-30)
    return jnp.sqrt(jnp.sum(s2[..., :tail], axis=-1) / total)


def spectral_group_telemetry(group: Dict[str, jax.Array],
                             energy_frac: float = 0.5) -> Dict[str, jax.Array]:
    """Scalar telemetry for one spectral group (stacked prefix reduced).

    Returns ``{"rank", "eff_rank", "energy_top", "tail_mass",
    "ortho_err"}`` — all 0-d float32. ``rank`` is the static k (emitted
    so metrics streams record resize events), ``ortho_err`` is the max
    Stiefel drift ``max(|U^T U - I|, |V^T V - I|)`` over the stack.
    """
    s = group["s"]
    return {
        "rank": jnp.float32(s.shape[-1]),
        "eff_rank": jnp.mean(effective_rank(s)),
        "energy_top": jnp.mean(energy_capture(s, energy_frac)),
        "tail_mass": jnp.max(tail_mass(s)),
        "ortho_err": jnp.maximum(
            orthogonality_error(group["U"]), orthogonality_error(group["V"])
        ),
    }


def _walk_groups(tree: Any, path: str = "") -> List[Tuple[str, Dict[str, jax.Array]]]:
    if is_spectral(tree):
        return [(path, tree)]
    out: List[Tuple[str, Dict[str, jax.Array]]] = []
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.extend(_walk_groups(tree[k], f"{path}/{k}" if path else k))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.extend(_walk_groups(v, f"{path}/[{i}]"))
    return out


def spectral_telemetry(params: Any, energy_frac: float = 0.5) -> Dict[str, Dict[str, jax.Array]]:
    """Per-group telemetry for every spectral group in a parameter tree:
    ``{"path/to/group": {metric: scalar}}``. Paths match the checkpoint
    store's flattened naming, so a telemetry stream lines up with the
    per-layer rank metadata a checkpoint records.
    """
    return {path: spectral_group_telemetry(g, energy_frac)
            for path, g in _walk_groups(params)}


def telemetry_summary(params: Any, energy_frac: float = 0.5,
                      prefix: str = "rank/") -> Dict[str, jax.Array]:
    """Flat scalar summary for the train-loop metrics dict.

    Reduces per-group telemetry across groups (mean for the rank-shape
    statistics, max for the drift/tail safety metrics) and prefixes keys
    so they sit next to loss/ce_loss without collisions:

      rank/mean        mean retained k over spectral groups
      rank/eff_mean    mean effective rank
      rank/energy_top  mean top-half energy capture
      rank/tail_max    max single-column tail mass (worst layer)
      rank/ortho_max   max Stiefel orthogonality drift (worst factor)

    jit-safe; returns an empty dict when the model has no spectral
    groups (dense baselines emit nothing rather than zeros).
    """
    per = spectral_telemetry(params, energy_frac)
    if not per:
        return {}
    stack = lambda name: jnp.stack([m[name] for m in per.values()])
    return {
        prefix + "mean": jnp.mean(stack("rank")),
        prefix + "eff_mean": jnp.mean(stack("eff_rank")),
        prefix + "energy_top": jnp.mean(stack("energy_top")),
        prefix + "tail_max": jnp.max(stack("tail_mass")),
        prefix + "ortho_max": jnp.max(stack("ortho_err")),
    }
