from repro.checkpoint.store import save_pytree, load_pytree, tree_equal
from repro.checkpoint.manager import CheckpointManager

__all__ = ["save_pytree", "load_pytree", "tree_equal", "CheckpointManager"]
