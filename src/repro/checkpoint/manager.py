"""Checkpoint lifecycle: rotation, latest-discovery, async save.

The fault-tolerant loop (runtime/train_loop.py) calls ``save(step,
state)`` every N steps; on restart ``restore_latest`` resumes from the
newest complete checkpoint. Writes happen on a background thread
(overlap with the next training steps); rotation keeps ``keep`` newest.
A checkpoint is only visible after its atomic rename, so a crash
mid-write can never corrupt the restore path.
"""
from __future__ import annotations

import os
import re
import threading
from typing import Any, Optional, Tuple

from repro.checkpoint.store import save_pytree, load_pytree

_CKPT_RE = re.compile(r"^step_(\d+)\.npz$")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}.npz")

    def list_steps(self):
        steps = []
        for name in os.listdir(self.directory):
            m = _CKPT_RE.match(name)
            if m:
                steps.append(int(m.group(1)))
        return sorted(steps)

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any, block: bool = False) -> None:
        self.wait()  # one in-flight save at a time

        def _do():
            save_pytree(state, self._path(step))
            self._rotate()

        if self.async_save and not block:
            self._thread = threading.Thread(target=_do, daemon=True)
            self._thread.start()
        else:
            _do()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _rotate(self) -> None:
        steps = self.list_steps()
        for s in steps[: -self.keep] if self.keep else []:
            try:
                os.remove(self._path(s))
            except FileNotFoundError:
                pass

    # ------------------------------------------------------------------
    def restore_latest(self, shardings: Any = None) -> Tuple[Optional[int], Any]:
        """(step, state) of the newest checkpoint, or (None, None)."""
        self.wait()
        steps = self.list_steps()
        if not steps:
            return None, None
        step = steps[-1]
        return step, load_pytree(self._path(step), shardings)

    def restore(self, step: int, shardings: Any = None) -> Any:
        return load_pytree(self._path(step), shardings)
