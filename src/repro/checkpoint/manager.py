"""Checkpoint lifecycle: rotation, latest-discovery, async save.

The fault-tolerant loop (runtime/train_loop.py) calls ``save(step,
state)`` every N steps; on restart ``restore_latest`` resumes from the
newest complete checkpoint. Writes happen on a background thread
(overlap with the next training steps); rotation keeps ``keep`` newest.
A checkpoint is only visible after its atomic rename, so a crash
mid-write can never corrupt the restore path.

Cross-rank restore: every save records the per-layer retained ranks of
the spectral groups in a ``.meta.json`` sidecar (readable without
loading the arrays — serving uses it to pick a snapshot). Passing
``target_rank`` to a restore resizes the loaded state on the host
(rank/resize.py: params and Adam moments together) before any device
placement, so a run checkpointed at rank 128 can resume — or serve —
at rank 64, and vice versa.

Self-describing checkpoints: a manager constructed with ``run_spec``
(the serialized RunSpec dict, api/specs.py) embeds it in the same
sidecar, so a snapshot carries its full experiment description —
``Server.from_checkpoint(path)`` and ``Trainer.resume(path)`` rebuild
the run with zero re-specified flags via :meth:`latest_run_spec`.
"""
from __future__ import annotations

import json
import os
import re
import threading
from typing import Any, Dict, Optional, Tuple

from repro.checkpoint.store import save_pytree, load_pytree

_CKPT_RE = re.compile(r"^step_(\d+)\.npz$")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True,
                 run_spec: Optional[Dict[str, Any]] = None):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self.run_spec = run_spec
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}.npz")

    def _meta_path(self, step: int) -> str:
        return self._path(step) + ".meta.json"

    def list_steps(self):
        steps = []
        for name in os.listdir(self.directory):
            m = _CKPT_RE.match(name)
            if m:
                steps.append(int(m.group(1)))
        return sorted(steps)

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any, block: bool = False) -> None:
        self.wait()  # one in-flight save at a time

        def _do():
            save_pytree(state, self._path(step))
            self._write_meta(step, state)
            self._rotate()

        if self.async_save and not block:
            self._thread = threading.Thread(target=_do, daemon=True)
            self._thread.start()
        else:
            _do()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write_meta(self, step: int, state: Any) -> None:
        """Per-layer spectral rank sidecar (atomic, like the arrays)."""
        from repro.rank.resize import rank_metadata

        params = state.get("params", state) if isinstance(state, dict) else state
        ranks = rank_metadata(params)
        meta = {"step": step, "ranks": ranks}
        if self.run_spec is not None:
            meta["run_spec"] = self.run_spec
        tmp = self._meta_path(step) + ".tmp"
        with open(tmp, "w") as f:
            json.dump(meta, f, indent=1, sort_keys=True)
        os.replace(tmp, self._meta_path(step))

    def rank_metadata_for(self, step: int) -> Optional[Dict[str, int]]:
        """The ``{group_path: rank}`` record of a checkpoint, read from
        the sidecar without loading any arrays — the cheap way for
        tooling to inspect what rank a snapshot holds before deciding
        to restore/resize it. None for pre-sidecar checkpoints (older
        runs restore fine; they just can't be inspected cheaply)."""
        try:
            with open(self._meta_path(step)) as f:
                return dict(json.load(f)["ranks"])
        except (FileNotFoundError, KeyError, json.JSONDecodeError):
            return None

    def run_spec_for(self, step: int) -> Optional[Dict[str, Any]]:
        """The serialized RunSpec embedded at ``step``'s save, read from
        the sidecar without loading any arrays. None for checkpoints
        written without a spec (pre-API runs restore fine; they just
        need their flags re-specified)."""
        try:
            with open(self._meta_path(step)) as f:
                return dict(json.load(f)["run_spec"])
        except (FileNotFoundError, KeyError, json.JSONDecodeError, TypeError):
            return None

    def latest_run_spec(self) -> Tuple[Optional[int], Optional[Dict[str, Any]]]:
        """(step, serialized RunSpec) of the newest checkpoint, or
        (None, None) for an empty directory. The step is returned even
        when the sidecar carries no spec, so callers can distinguish
        'no checkpoint' from 'checkpoint without a spec'."""
        self.wait()
        steps = self.list_steps()
        if not steps:
            return None, None
        return steps[-1], self.run_spec_for(steps[-1])

    def _rotate(self) -> None:
        steps = self.list_steps()
        for s in steps[: -self.keep] if self.keep else []:
            for path in (self._path(s), self._meta_path(s)):
                try:
                    os.remove(path)
                except FileNotFoundError:
                    pass

    # ------------------------------------------------------------------
    def restore_latest(self, shardings: Any = None,
                       target_rank: Optional[int] = None,
                       retraction: str = "qr") -> Tuple[Optional[int], Any]:
        """(step, state) of the newest checkpoint, or (None, None).
        ``target_rank`` resizes the spectral groups on restore (see
        :meth:`restore`)."""
        self.wait()
        steps = self.list_steps()
        if not steps:
            return None, None
        step = steps[-1]
        return step, self.restore(step, shardings, target_rank, retraction)

    def restore(self, step: int, shardings: Any = None,
                target_rank: Optional[int] = None,
                retraction: str = "qr") -> Any:
        """Load the checkpoint at ``step``. With ``target_rank``, every
        spectral group (and its Adam moments, when the tree is a full
        TrainState) is resized to that rank on the host *before* device
        placement — the resize-on-restore path. ``retraction`` only
        matters for a grow (pass the run's configured method to match
        in-run resizes; shrinks never retract). The resize key derives
        from the checkpoint step, so a given (checkpoint, target_rank)
        pair restores deterministically on every process."""
        if target_rank is None:
            return load_pytree(self._path(step), shardings)

        import jax

        from repro.checkpoint.store import place_tree
        from repro.rank.resize import clamp_target, resize_train_state, resize_tree

        state = load_pytree(self._path(step), shardings=None)
        key = jax.random.PRNGKey(step)
        if isinstance(state, dict) and "opt" in state and "params" in state:
            target = clamp_target(state["params"], int(target_rank))
            state = resize_train_state(key, state, target, retraction=retraction)
        else:
            state = resize_tree(key, state, clamp_target(state, int(target_rank)),
                                retraction=retraction)
        if shardings is not None:
            state = place_tree(state, shardings)
        return state
