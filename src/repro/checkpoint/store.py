"""Mesh-agnostic pytree (de)serialization.

Checkpoints are stored as host numpy arrays (npz) plus a json treedef —
so a checkpoint written on a (16,16) mesh restores onto (2,16,16), a
different DP width, or one CPU (elastic scaling / disaster recovery).
Atomic: write to <path>.tmp, fsync, rename.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np

_SEP = "/"


def _flatten_with_paths(tree: Any, prefix=""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten_with_paths(tree[k], f"{prefix}{_SEP}{k}" if prefix else str(k))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten_with_paths(v, f"{prefix}{_SEP}[{i}]")
    else:
        yield prefix, tree


def _structure(tree: Any):
    if isinstance(tree, dict):
        return {k: _structure(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return {"__seq__": type(tree).__name__, "items": [_structure(v) for v in tree]}
    return None  # leaf marker


def _rebuild(struct, leaves: dict, prefix=""):
    if isinstance(struct, dict) and "__seq__" in struct:
        items = [
            _rebuild(s, leaves, f"{prefix}{_SEP}[{i}]")
            for i, s in enumerate(struct["items"])
        ]
        return tuple(items) if struct["__seq__"] == "tuple" else items
    if isinstance(struct, dict):
        return {
            k: _rebuild(v, leaves, f"{prefix}{_SEP}{k}" if prefix else str(k))
            for k, v in struct.items()
        }
    return leaves[prefix]


def save_pytree(tree: Any, path: str) -> None:
    """Atomic save. Device arrays are fetched to host (fully addressable
    arrays only — the multi-host path gathers per-shard in runtime/)."""
    arrays = {}
    for p, leaf in _flatten_with_paths(tree):
        arrays[p] = np.asarray(jax.device_get(leaf))
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        np.savez(f, **{"__struct__": json.dumps(_structure(tree))}, **arrays)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def place_tree(tree: Any, shardings: Any) -> Any:
    """Device-place a host pytree onto a matching NamedSharding tree
    (None entries get default placement). The one placement path shared
    by plain restores and resize-on-restore (checkpoint/manager.py)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s) if s is not None else jax.device_put(x),
        tree, shardings,
        is_leaf=lambda x: isinstance(x, np.ndarray),
    )


def load_pytree(path: str, shardings: Any = None) -> Any:
    """Load a checkpoint; if ``shardings`` (a pytree of NamedSharding
    matching the checkpoint structure) is given, leaves are placed
    sharded — this is the elastic-reshard path: any mesh works."""
    with np.load(path, allow_pickle=False) as z:
        struct = json.loads(str(z["__struct__"]))
        leaves = {k: z[k] for k in z.files if k != "__struct__"}
    tree = _rebuild(struct, leaves)
    if shardings is not None:
        tree = place_tree(tree, shardings)
    return tree


def tree_equal(a: Any, b: Any) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    if len(la) != len(lb):
        return False
    return all(np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))
