"""Loop-aware HLO cost model.

``compiled.cost_analysis()`` visits each while-loop body ONCE (verified
empirically: a scan of 8 matmuls reports 1 matmul of FLOPs), so for
scan-based models — ours scan over layers, microbatches, attention
chunks — both FLOPs and collective bytes are undercounted by the trip
counts. The optimized HLO keeps ``backend_config={"known_trip_count":
{"n": ...}}`` on while ops, so we parse the module text and account
properly:

  flops       : 2 * prod(out) * prod(contracting dims) per dot
                (MXU flops; elementwise ALU ops are not counted — they
                are bandwidth-, not compute-, limited on TPU)
  bytes       : operands + outputs per top-level op (fusion internals
                excluded — the XLA HBM-traffic model)
  collectives : output bytes per collective op, by kind

All values are per-device (the module is the post-GSPMD partitioned
module) and include loop multipliers, including nested loops.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2, "s32": 4,
    "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast",
)

# one HLO instruction: [ROOT] %name = <shape> opcode(operands), attrs
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$"
)
_COMP_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.+\s*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _ARRAY_RE.finditer(shape_str):
        nb = _DTYPE_BYTES.get(m.group(1))
        if nb is None:
            continue
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n * nb
    return total


def _shape_elems(shape_str: str) -> int:
    total = 0
    for m in _ARRAY_RE.finditer(shape_str):
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _first_array(shape_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _ARRAY_RE.search(shape_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


@dataclasses.dataclass
class _Op:
    name: str
    shape: str
    opcode: str
    rest: str  # operands + attrs (raw tail of the line)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult

    @property
    def coll_bytes(self) -> float:
        return sum(v for k, v in self.coll.items() if not k.endswith("_count"))


KERNEL_MARKER = "PALLAS_EQ"


class HloCostModel:
    """Set ``kernel_substitution=False`` to cost the raw XLA fallback
    (the 'as-lowered' number reported alongside the kernel-substituted
    one in EXPERIMENTS.md §Roofline)."""

    def __init__(self, hlo_text: str, kernel_substitution: bool = True):
        self.comps: Dict[str, List[_Op]] = {}
        self.kernel_substitution = kernel_substitution
        self._parse(hlo_text)
        self._memo: Dict[str, Cost] = {}
        self._shapes: Dict[str, Dict[str, str]] = {}
        self._marked_comp: Dict[str, bool] = {}

    def _op_marked(self, op: _Op) -> bool:
        """Op belongs to a PALLAS_EQ named scope: on TPU it executes
        inside a fused Pallas kernel (VMEM-resident intermediates), so
        its HBM-byte charge is suppressed; FLOPs still count."""
        if not self.kernel_substitution:
            return False
        return KERNEL_MARKER in op.rest

    def _comp_marked(self, comp: str) -> bool:
        """A called computation counts as kernel-interior if any of its
        ops carries the marker (fusions inherit metadata from a
        representative op)."""
        if comp not in self._marked_comp:
            self._marked_comp[comp] = any(
                KERNEL_MARKER in op.rest for op in self.comps.get(comp, [])
            )
        return self._marked_comp[comp]

    def _parse(self, text: str) -> None:
        current: Optional[str] = None
        for line in text.splitlines():
            mc = _COMP_RE.match(line)
            if mc:
                current = mc.group(2)
                self.comps[current] = []
                continue
            if current is None:
                continue
            if line.strip() == "}":
                current = None
                continue
            mo = _OP_RE.match(line)
            if mo:
                self.comps[current].append(
                    _Op(mo.group(1), mo.group(2).strip(), mo.group(3), mo.group(4))
                )

    # ------------------------------------------------------------------
    def _sym(self, comp: str) -> Dict[str, str]:
        if comp not in self._shapes:
            self._shapes[comp] = {op.name: op.shape for op in self.comps.get(comp, [])}
        return self._shapes[comp]

    def _dot_flops(self, comp: str, op: _Op) -> float:
        out = _first_array(op.shape)
        if out is None:
            return 0.0
        _, out_dims = out
        n_out = 1
        for d in out_dims:
            n_out *= d
        # contracted size from lhs operand shape + contracting dims
        mct = _CONTRACT_RE.search(op.rest)
        k = 1
        if mct:
            lhs_name = op.rest.split("(", 0)[0] if False else None
            # operands are the leading %refs of rest
            ops = re.findall(r"%([\w.\-]+)", op.rest.split(")")[0])
            if ops:
                lhs_shape = self._sym(comp).get(ops[0])
                if lhs_shape:
                    arr = _first_array(lhs_shape)
                    if arr:
                        dims = arr[1]
                        for ci in mct.group(1).split(","):
                            if ci:
                                ci = int(ci)
                                if ci < len(dims):
                                    k *= dims[ci]
        return 2.0 * n_out * k

    def _operand_names(self, op: _Op) -> List[str]:
        return re.findall(r"%([\w.\-]+)", op.rest.split(")")[0])

    def _operand_bytes(self, comp: str, op: _Op) -> float:
        total = 0
        sym = self._sym(comp)
        for o in self._operand_names(op):
            sh = sym.get(o)
            if sh:
                total += _shape_bytes(sh)
        return float(total)

    def _fusion_bytes(self, comp: str, op: _Op) -> float:
        """HBM traffic of a fusion: output + operands, EXCEPT operands
        that are only dynamic-sliced/gathered inside (a scanned layer
        stack reads one layer's slice per iteration, not the whole
        stack — charging full operands would overcount bytes by ~L)."""
        called = _CALLS_RE.search(op.rest)
        sym = self._sym(comp)
        operands = self._operand_names(op)
        sliced_params = {}
        dus_aliased_params = set()
        out_bytes = float(_shape_bytes(op.shape))
        if called:
            inner = self.comps.get(called.group(1), [])
            param_ids = {}
            for iop in inner:
                if iop.opcode == "parameter":
                    m = re.match(r"(\d+)", iop.rest)
                    if m:
                        param_ids[iop.name] = int(m.group(1))
            for iop in inner:
                if iop.opcode in ("dynamic-slice", "gather"):
                    names = self._operand_names(iop)
                    if names and names[0] in param_ids:
                        idx = param_ids[names[0]]
                        prev = sliced_params.get(idx, 0.0)
                        sliced_params[idx] = prev + _shape_bytes(iop.shape)
                elif iop.opcode == "dynamic-update-slice":
                    # aliased in-place update fused at the root (KV cache
                    # write / scan-carry stacking): traffic ~ updates,
                    # not the full buffer
                    names = self._operand_names(iop)
                    if names and _shape_elems(iop.shape) == _shape_elems(op.shape):
                        if names[0] in param_ids:
                            dus_aliased_params.add(param_ids[names[0]])
                        upd = names[1] if len(names) > 1 else None
                        inner_sym = {o2.name: o2.shape for o2 in inner}
                        upd_b = _shape_bytes(inner_sym.get(upd, "")) if upd else 0
                        out_bytes = 3.0 * upd_b
        total = out_bytes
        aliased_by_shape_done = not dus_aliased_params and out_bytes != _shape_bytes(op.shape)
        for i, o in enumerate(operands):
            sh = sym.get(o)
            if not sh:
                continue
            if i in dus_aliased_params:
                continue
            # alias fallback: when the inner DUS matched but its operand
            # wasn't a direct parameter, skip the one operand that has
            # the same element count as the (aliased) output buffer
            if aliased_by_shape_done and _shape_elems(sh) == _shape_elems(op.shape):
                aliased_by_shape_done = False
                continue
            if i in sliced_params:
                total += min(sliced_params[i], _shape_bytes(sh))
            else:
                total += _shape_bytes(sh)
        return total

    def comp_cost(self, comp: str) -> Cost:
        if comp in self._memo:
            return self._memo[comp]
        # pre-seed to break recursion cycles defensively
        self._memo[comp] = Cost()
        cost = Cost()
        for op in self.comps.get(comp, []):
            oc = op.opcode
            if oc == "while":
                n = 1
                mt = _TRIP_RE.search(op.rest)
                if mt:
                    n = int(mt.group(1))
                body = _BODY_RE.search(op.rest)
                cond = _COND_RE.search(op.rest)
                if body:
                    cost.add(self.comp_cost(body.group(1)), n)
                if cond:
                    cost.add(self.comp_cost(cond.group(1)), n)
            elif oc in ("fusion", "call", "async-start", "custom-call"):
                mc = _CALLS_RE.search(op.rest)
                marked = self._op_marked(op)
                if mc:
                    sub = self.comp_cost(mc.group(1))
                    # fusion: internal flops count; internal bytes don't
                    cost.flops += sub.flops
                    for k, v in sub.coll.items():
                        cost.coll[k] = cost.coll.get(k, 0.0) + v
                    marked = marked or self._comp_marked(mc.group(1))
                if not marked:
                    cost.bytes += self._fusion_bytes(comp, op)
            elif oc in ("dynamic-slice", "gather"):
                # reads only the slice, not the full operand
                if not self._op_marked(op):
                    cost.bytes += 2.0 * _shape_bytes(op.shape)
            elif oc in ("scatter", "dynamic-update-slice"):
                # aliased in-place update: traffic ~ the updates, not the
                # full buffer (KV-cache writes inside the layer scan!)
                if not self._op_marked(op):
                    names = self._operand_names(op)
                    upd = names[-1] if names else None
                    upd_b = _shape_bytes(self._sym(comp).get(upd, "")) if upd else 0
                    cost.bytes += 3.0 * upd_b
            elif oc == "conditional":
                for m in re.finditer(r"(?:true_computation|false_computation|branch_computations=\{)([\w.\-,% ]+)", op.rest):
                    for sub in re.findall(r"[\w.\-]+", m.group(1)):
                        cost.add(self.comp_cost(sub), 1.0)
                cost.bytes += _shape_bytes(op.shape)
            elif oc in ("dot", "dot-general"):
                cost.flops += self._dot_flops(comp, op)
                if not self._op_marked(op):
                    cost.bytes += _shape_bytes(op.shape) + self._operand_bytes(comp, op)
            elif oc == "convolution":
                # treat like dot via output x window (rare here: stubs)
                cost.bytes += _shape_bytes(op.shape) + self._operand_bytes(comp, op)
            elif any(oc == c or oc == c + "-start" for c in _COLLECTIVES):
                kind = oc[:-6] if oc.endswith("-start") else oc
                nbytes = _shape_bytes(op.shape)
                cost.coll[kind] = cost.coll.get(kind, 0.0) + nbytes
                cost.coll[kind + "_count"] = cost.coll.get(kind + "_count", 0.0) + 1
                cost.bytes += nbytes
            elif oc in ("parameter", "constant", "get-tuple-element", "tuple",
                        "bitcast", "after-all", "all-reduce-done",
                        "all-gather-done", "async-done", "copy-done"):
                continue
            else:
                # plain op at module level (rare post-fusion): memory only
                if not self._op_marked(op):
                    cost.bytes += _shape_bytes(op.shape) + self._operand_bytes(comp, op)
        self._memo[comp] = cost
        return cost

    def entry_cost(self) -> Cost:
        # the ENTRY computation is conventionally named 'main...' — find
        # the computation that no other computation references
        referenced = set()
        for ops in self.comps.values():
            for op in ops:
                for pat in (_CALLS_RE, _BODY_RE, _COND_RE):
                    m = pat.search(op.rest)
                    if m:
                        referenced.add(m.group(1))
        entries = [c for c in self.comps if c not in referenced and c.startswith("main")]
        if not entries:
            entries = [c for c in self.comps if c not in referenced]
        cost = Cost()
        for e in entries[:1] if entries else []:
            cost.add(self.comp_cost(e))
        return cost


def analyze_hlo(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).entry_cost()


def top_bytes_contributors(hlo_text: str, n: int = 25):
    """Debug: (bytes_with_multipliers, comp, op, opcode) heaviest first —
    the §Perf hypothesis generator (what to optimize next)."""
    m = HloCostModel(hlo_text)
    contrib: Dict[Tuple[str, str, str], float] = {}

    def walk(comp: str, mult: float):
        for op in m.comps.get(comp, []):
            oc = op.opcode
            if oc == "while":
                nmt = _TRIP_RE.search(op.rest)
                nn = int(nmt.group(1)) if nmt else 1
                bm = _BODY_RE.search(op.rest)
                cm = _COND_RE.search(op.rest)
                if bm:
                    walk(bm.group(1), mult * nn)
                if cm:
                    walk(cm.group(1), mult * nn)
                continue
            key = (comp, op.name, oc)
            if oc in ("fusion", "call", "async-start", "custom-call"):
                marked = m._op_marked(op)
                mc = _CALLS_RE.search(op.rest)
                if mc:
                    marked = marked or m._comp_marked(mc.group(1))
                if not marked:
                    contrib[key] = contrib.get(key, 0.0) + m._fusion_bytes(comp, op) * mult
            elif oc in ("dynamic-slice", "gather"):
                if not m._op_marked(op):
                    contrib[key] = contrib.get(key, 0.0) + 2.0 * _shape_bytes(op.shape) * mult
            elif oc in ("scatter", "dynamic-update-slice"):
                if not m._op_marked(op):
                    names = m._operand_names(op)
                    upd = names[-1] if names else None
                    ub = _shape_bytes(m._sym(comp).get(upd, "")) if upd else 0
                    contrib[key] = contrib.get(key, 0.0) + 3.0 * ub * mult
            elif oc in ("parameter", "constant", "get-tuple-element", "tuple",
                        "bitcast", "after-all"):
                continue
            else:
                if not m._op_marked(op):
                    contrib[key] = contrib.get(key, 0.0) + (
                        _shape_bytes(op.shape) + m._operand_bytes(comp, op)
                    ) * mult

    referenced = set()
    for ops in m.comps.values():
        for op in ops:
            for pat in (_CALLS_RE, _BODY_RE, _COND_RE):
                mm = pat.search(op.rest)
                if mm:
                    referenced.add(mm.group(1))
    entries = [c for c in m.comps if c not in referenced and c.startswith("main")]
    if not entries:
        entries = [c for c in m.comps if c not in referenced]
    if entries:
        walk(entries[0], 1.0)
    return sorted(((v, *k) for k, v in contrib.items()), reverse=True)[:n]
