"""Analytic roofline placement for the hand-written serving kernels.

The dry-run roofline (analysis.py) prices whole partitioned HLO modules;
this module prices the *individual Pallas kernels* from first principles
— FLOPs and HBM traffic derived from the shapes alone — so the kernel
bench can report where each kernel sits on the v5e roofline without any
hardware, and so the numbers are exactly reproducible (they are
arithmetic, not measurements). bench_kernels.py publishes them as the
``deterministic`` columns of BENCH_kernels.json; tools/check_bench.py
--diff re-derives and compares them in CI.

Traffic model: every operand is read from HBM once per use and every
output written once; VMEM-resident intermediates (the spectral ``h``,
flash's running softmax state, paged decode's accumulators) are free.
That is the idealized best case the kernels are *designed* to hit — the
point of fusing is to make the model true.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.roofline.analysis import HW


def place(flops: int, hbm_bytes: int, hw: Optional[Dict] = None) -> Dict:
    """Roofline placement of one kernel invocation: arithmetic intensity
    (FLOP/byte), time floors under each ceiling, and which bound binds.
    The ridge point (peak_flops / hbm_bw, ~240 FLOP/byte on v5e) is
    where the two floors cross."""
    h = HW if hw is None else hw
    compute_s = flops / h["peak_flops"]
    memory_s = hbm_bytes / h["hbm_bw"]
    return {
        "flops": int(flops),
        "hbm_bytes": int(hbm_bytes),
        "intensity_flop_per_byte": round(flops / max(hbm_bytes, 1), 3),
        "ridge_flop_per_byte": round(h["peak_flops"] / h["hbm_bw"], 1),
        "compute_us": round(compute_s * 1e6, 4),
        "memory_us": round(memory_s * 1e6, 4),
        "bound": "compute" if compute_s >= memory_s else "memory",
    }


def spectral_matmul_terms(M: int, m: int, n: int, k: int, *,
                          act_bytes: int = 2, factor_bytes: int = 2,
                          fused: bool = True) -> Dict:
    """y = ((x @ U) * s) @ V.T. ``fused`` keeps the bottleneck ``h``
    (M, k) in VMEM; the unfused chain writes it to HBM and reads it
    back (plus the k-length scale, priced with ``h``'s fp32 round
    trip). ``factor_bytes=1`` prices the int8 variant — the fused q8
    kernel streams raw int8 factors plus one fp32 gain vector."""
    flops = 2 * M * k * (m + n) + M * k           # two GEMMs + the scale
    traffic = (M * m * act_bytes                  # x
               + m * k * factor_bytes             # U
               + n * k * factor_bytes             # V
               + M * n * act_bytes                # y
               + k * 4)                           # s / fused gain (fp32)
    if not fused:
        traffic += 2 * M * k * 4                  # h out + back in, fp32
    out = place(flops, traffic)
    out["shape"] = {"M": M, "m": m, "n": n, "k": k,
                    "act_bytes": act_bytes, "factor_bytes": factor_bytes}
    return out


def paged_gqa_decode_terms(b: int, kvh: int, rep: int, hd: int, seq: int, *,
                           cache_bytes: int = 2, paged: bool = True) -> Dict:
    """One batched decode step of paged GQA attention over ``seq`` live
    positions per slot. ``paged=True`` is the kernel: K/V pages stream
    from the pool exactly once. ``paged=False`` prices the jnp reference
    branch, which materializes the gathered (b, S, kvh, hd) copy —
    written once and read once on top of the pool reads."""
    kv = b * seq * kvh * hd                       # positions actually read
    flops = 2 * 2 * b * kvh * rep * seq * hd      # QK^T + PV
    traffic = (b * kvh * rep * hd * cache_bytes   # q
               + 2 * kv * cache_bytes             # K + V pool pages
               + b * kvh * rep * hd * cache_bytes)  # out
    if not paged:
        traffic += 2 * 2 * kv * cache_bytes       # gathered copy: write+read
    out = place(flops, traffic)
    out["shape"] = {"b": b, "kvh": kvh, "rep": rep, "hd": hd, "seq": seq,
                    "cache_bytes": cache_bytes}
    return out


def paged_mla_decode_terms(b: int, h: int, lat: int, rope: int, seq: int, *,
                           cache_bytes: int = 2, paged: bool = True) -> Dict:
    """One batched decode step of absorbed-MLA attention: latent scores
    plus rope scores, with the ckv rows doubling as values (read once,
    used twice — the MLA trick keeps traffic at the latent width, not
    the expanded K/V width)."""
    rows = b * seq
    flops = 2 * b * h * seq * (lat + rope) + 2 * b * h * seq * lat  # scores + PV
    traffic = (b * h * (lat + rope) * cache_bytes           # q_lat + q_rope
               + rows * (lat + rope) * cache_bytes          # ckv + krope pages
               + b * h * lat * cache_bytes)                 # o_lat
    if not paged:
        traffic += 2 * rows * (lat + rope) * cache_bytes    # gathered copies
    out = place(flops, traffic)
    out["shape"] = {"b": b, "h": h, "lat": lat, "rope": rope, "seq": seq,
                    "cache_bytes": cache_bytes}
    return out
