from repro.roofline.analysis import (
    roofline_terms,
    collective_bytes,
    model_flops,
    HW,
)
from repro.roofline.kernels import (
    place,
    spectral_matmul_terms,
    paged_gqa_decode_terms,
    paged_mla_decode_terms,
)

__all__ = ["roofline_terms", "collective_bytes", "model_flops", "HW",
           "place", "spectral_matmul_terms", "paged_gqa_decode_terms",
           "paged_mla_decode_terms"]
