from repro.roofline.analysis import (
    roofline_terms,
    collective_bytes,
    model_flops,
    HW,
)

__all__ = ["roofline_terms", "collective_bytes", "model_flops", "HW"]
