"""Roofline terms from a compiled dry-run artifact (no real hardware):

  compute    = HLO_FLOPs            / (chips * peak_FLOP/s)
  memory     = HLO_bytes            / (chips * HBM_bw)
  collective = collective_bytes     / (chips * link_bw)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``. Collective
bytes are NOT in cost_analysis — we parse the optimized HLO text and sum
operand sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops (per the assignment spec).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s ICI
per link.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# ---- hardware constants (TPU v5e) ----
HW = {
    "peak_flops": 197e12,     # bf16 FLOP/s per chip
    "hbm_bw": 819e9,          # bytes/s per chip
    "ici_bw": 50e9,           # bytes/s per link (per-chip injection ~ 2-3 links;
                              # we charge the single-link figure = conservative)
}

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# matches e.g.  bf16[256,4096,8192]{2,1,0}  or f32[128]
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_COLLECTIVE_OPS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "collective-broadcast",
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        nbytes = _DTYPE_BYTES.get(dt)
        if nbytes is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * nbytes
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum of *output* shape bytes per collective kind, over the whole
    module. Output size == the data each collective materializes; for
    all-reduce it equals the reduced tensor, for all-gather the gathered
    one (the larger side). Fusion-wrapped collectives keep their opcode
    in the op name, so a line scan is robust across XLA versions."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVE_OPS}
    out["count"] = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # HLO instruction lines look like: `%name = <shape> opcode(...)`
        m = re.match(r"%?[\w.\-]+ = (.+?) (\w[\w\-]*)\(", stripped)
        if not m:
            continue
        shape_part, opcode = m.group(1), m.group(2)
        for kind in _COLLECTIVE_OPS:
            if opcode == kind or opcode.startswith(kind + "-start"):
                out[kind] += _shape_bytes(shape_part)
                out["count"] += 1
                break
    out["total"] = sum(out[k] for k in _COLLECTIVE_OPS)
    return out


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D for training (N = params that multiply
    activations, D = tokens); 2*N*D for inference. MoE uses N_active.
    Embedding-table rows don't multiply -> excluded; the LM head does."""
    N = active_param_count(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * N * tokens


def active_param_count(cfg) -> float:
    """Active (per-token) matmul parameters of the configured model —
    spectral layers count k(m+n+1), MoE counts top_k + shared experts
    only. Analytic (no allocation)."""
    d, L = cfg.d_model, cfg.n_layers

    def lin(m, n, spectral):
        if spectral:
            k = min(cfg.sct.rank, m, n)
            return k * (m + n + 1)
        return m * n

    sp = cfg.sct.spectral_mlp
    spa = cfg.sct.spectral_attention

    def mlp_params(ff):
        n_mat = 3 if cfg.act == "swiglu" else 2
        return (n_mat - 1) * lin(d, ff, sp) + lin(ff, d, sp)

    total = 0.0
    if cfg.attention == "mla":
        attn = 0.0
        if cfg.q_lora_rank:
            attn += lin(d, cfg.q_lora_rank, False)
            attn += lin(cfg.q_lora_rank, cfg.n_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim), False)
        else:
            attn += lin(d, cfg.n_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim), False)
        attn += lin(d, cfg.kv_lora_rank + cfg.qk_rope_dim, False)
        attn += lin(cfg.kv_lora_rank, cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim), False)
        attn += lin(cfg.n_heads * cfg.v_head_dim, d, False)
    else:
        hd = cfg.head_dim
        attn = (
            lin(d, cfg.n_heads * hd, spa)
            + 2 * lin(d, cfg.n_kv_heads * hd, spa)
            + lin(cfg.n_heads * hd, d, spa)
        )

    if cfg.family == "dense_lm":
        total = L * (attn + mlp_params(cfg.d_ff))
    elif cfg.family == "moe_lm":
        Ld = cfg.first_dense_layers
        moe_active = cfg.top_k * mlp_params(cfg.moe_d_ff)
        if cfg.n_shared_experts:
            moe_active += mlp_params(cfg.moe_d_ff * cfg.n_shared_experts)
        total = Ld * (attn + mlp_params(cfg.d_ff)) + (L - Ld) * (attn + moe_active + d * cfg.n_experts)
    elif cfg.family == "hybrid":
        P = cfg.attn_every
        di = cfg.mamba_expand * d
        mamba = (
            lin(d, 2 * di, cfg.sct.spectral_mamba and sp)
            + di * (cfg.mamba_dt_rank + 2 * cfg.mamba_d_state)
            + cfg.mamba_dt_rank * di
            + lin(di, d, cfg.sct.spectral_mamba and sp)
        )
        n_attn = L // P
        n_mamba = L - n_attn
        n_moe = L // cfg.moe_every
        n_mlp = L - n_moe
        moe_active = cfg.top_k * mlp_params(cfg.moe_d_ff) + d * cfg.n_experts
        total = n_attn * attn + n_mamba * mamba + n_moe * moe_active + n_mlp * mlp_params(cfg.d_ff)
    elif cfg.family == "ssm_lm":
        P = cfg.slstm_every
        di = 2 * d
        mlstm = lin(d, 2 * di, sp) + 3 * di * di + 2 * di * cfg.n_heads + di * di + lin(di, d, sp)
        dff = int(4 * d / 3)
        slstm = d * 4 * d + cfg.n_heads * (d // cfg.n_heads) * 4 * (d // cfg.n_heads) + lin(d, 2 * dff, sp) + lin(dff, d, sp)
        n_s = L // P
        total = (L - n_s) * mlstm + n_s * slstm
    elif cfg.family == "encdec":
        Le = cfg.n_encoder_layers or L
        xattn = 4 * lin(d, cfg.n_heads * cfg.head_dim, False)
        total = Le * (attn + mlp_params(cfg.d_ff)) + L * (attn + xattn + mlp_params(cfg.d_ff))
    # LM head (tied or not, the matmul happens)
    total += d * cfg.vocab
    return total


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes_accessed: float
    coll_bytes: int
    coll_count: int
    model_flops: float
    chips: int

    @property
    def dominant(self) -> str:
        vals = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(vals, key=vals.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step-time estimate: max of the three terms (perfect
        overlap assumption — the optimistic bound)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / compiled FLOPs (per-chip share) — catches
        remat/redundancy waste. > 1 means the compiler *removed* work
        relative to the analytic count (e.g. fused/strength-reduced)."""
        per_chip = self.model_flops / self.chips
        return per_chip / self.flops if self.flops else 0.0

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline bound (per-chip model
        FLOPs over the roofline step time at peak)."""
        denom = self.step_time_s * HW["peak_flops"]
        return (self.model_flops / self.chips) / denom if denom else 0.0

    def as_dict(self):
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_time_s": self.step_time_s,
            "hlo_flops": self.flops,
            "hlo_bytes": self.bytes_accessed,
            "collective_bytes": self.coll_bytes,
            "collective_count": self.coll_count,
            "model_flops": self.model_flops,
            "useful_fraction": self.useful_fraction,
            "mfu": self.mfu,
            "chips": self.chips,
        }


def roofline_terms(cost: dict, hlo_text: str, chips: int,
                   mflops: float) -> RooflineTerms:
    """cost = compiled.cost_analysis() (kept for reference only);
    hlo_text = compiled.as_text().

    NOTE 1: with GSPMD, ``compiled`` is the *partitioned per-device*
    module, so everything derived from it is per-chip; the terms divide
    by single-chip peaks and ``chips`` apportions the global
    MODEL_FLOPS for MFU/useful-fraction.

    NOTE 2: ``cost_analysis()`` visits while-loop bodies ONCE (verified:
    a scan of 8 matmuls reports 1 matmul) — for scan-over-layers models
    it undercounts FLOPs, bytes AND collectives by the trip counts. We
    therefore use our loop-aware HLO cost model (hlo_cost.py), which
    multiplies by ``known_trip_count`` recursively.
    """
    from repro.roofline.hlo_cost import analyze_hlo

    c = analyze_hlo(hlo_text)
    flops = c.flops
    bytes_accessed = c.bytes
    coll_total = c.coll_bytes
    coll_count = int(sum(v for k, v in c.coll.items() if k.endswith("_count")))
    return RooflineTerms(
        compute_s=flops / HW["peak_flops"],
        memory_s=bytes_accessed / HW["hbm_bw"],
        collective_s=coll_total / HW["ici_bw"],
        flops=flops,
        bytes_accessed=bytes_accessed,
        coll_bytes=int(coll_total),
        coll_count=coll_count,
        model_flops=mflops,
        chips=chips,
    )
