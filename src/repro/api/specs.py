"""Declarative experiment specs: the serializable description of a run.

A :class:`RunSpec` is the single object that describes everything the
repo knows how to do — which architecture to build (``model``), how to
train it (``train``, ``precision``, ``rank``, ``sharding``,
``checkpoint``) and how to serve it (``serve``). It is:

  * **frozen** — specs are values; deriving a variant goes through
    :meth:`RunSpec.replace`, never mutation;
  * **JSON-round-trippable** — ``to_json``/``from_json`` are bit-exact
    inverses (sorted keys, no float surprises: every field is an int,
    str, bool or None except learning rates, which JSON represents
    exactly via repr round-trip);
  * **self-validating** — unknown keys are rejected on ``from_dict``,
    and enum-ish fields (precision mode, serve mode, quantize, rank
    schedule grammar) are checked at construction time, so a spec that
    exists is a spec that can run.

The facades (api/trainer.py, api/server.py) consume RunSpecs; the CLIs
(launch/train.py, launch/serve.py, ``python -m repro``) are thin
argparse -> RunSpec adapters; CheckpointManager embeds the serialized
spec in every checkpoint sidecar so a snapshot is self-describing —
``Server.from_checkpoint(path)`` and ``Trainer.resume(path)`` need zero
re-specified flags.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional

__all__ = [
    "ModelSpec",
    "TrainSpec",
    "PrecisionSpec",
    "RankScheduleSpec",
    "ShardingSpec",
    "StreamingSpec",
    "ServeSpec",
    "CheckpointSpec",
    "RunSpec",
    "WorkloadSpec",
    "SLOSpec",
    "BenchSpec",
]


# ----------------------------------------------------------------------
# shared (de)serialization machinery
# ----------------------------------------------------------------------

class _Spec:
    """Base for all spec dataclasses: dict/JSON round-trip with
    unknown-key rejection, and field-validated ``replace``."""

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, data: Dict[str, Any]) -> "_Spec":
        if not isinstance(data, dict):
            raise TypeError(f"{cls.__name__}.from_dict wants a dict, "
                            f"got {type(data).__name__}")
        fields = {f.name: f for f in dataclasses.fields(cls)}
        unknown = sorted(set(data) - set(fields))
        if unknown:
            raise ValueError(f"{cls.__name__}: unknown key(s) {unknown} "
                             f"(known: {sorted(fields)})")
        kw = {}
        for name, value in data.items():
            sub = _subspec_type(fields[name])
            kw[name] = sub.from_dict(value) if sub is not None else value
        return cls(**kw)

    def replace(self, **overrides) -> "_Spec":
        """A new spec with ``overrides`` applied. Keys are validated, so
        a typo raises instead of silently minting a field."""
        fields = {f.name: f for f in dataclasses.fields(self)}
        unknown = sorted(set(overrides) - set(fields))
        if unknown:
            raise ValueError(f"{type(self).__name__}.replace: unknown "
                             f"field(s) {unknown} (known: {sorted(fields)})")
        return dataclasses.replace(self, **overrides)

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "_Spec":
        return cls.from_dict(json.loads(text))


def _subspec_type(field: dataclasses.Field):
    """Nested-spec detection: a field whose default is itself a spec
    instance (RunSpec's sub-specs) recurses through that class's
    ``from_dict``; everything else is a plain JSON scalar."""
    return type(field.default) if isinstance(field.default, _Spec) else None


def _spec(cls):
    return dataclasses.dataclass(frozen=True)(cls)


# ----------------------------------------------------------------------
# sub-specs
# ----------------------------------------------------------------------

@_spec
class ModelSpec(_Spec):
    """Reference into the config registry (config/registry.py) plus the
    declarative SCT overrides a sweep needs: ``rank`` overrides
    ``cfg.sct.rank``; ``spectral_mlp=False`` is the dense baseline."""
    arch: str = "smollm2-1.7b"
    reduced: bool = False
    rank: Optional[int] = None
    spectral_mlp: Optional[bool] = None

    def config(self):
        from repro.config import get_config

        cfg = get_config(self.arch, reduced=self.reduced)
        sct_kw = {}
        if self.rank is not None:
            sct_kw["rank"] = int(self.rank)
        if self.spectral_mlp is not None:
            sct_kw["spectral_mlp"] = bool(self.spectral_mlp)
        return cfg.replace_sct(**sct_kw) if sct_kw else cfg


@_spec
class TrainSpec(_Spec):
    """The optimization run: step budget, batch geometry, LR schedule
    inputs, microbatching, and the data/init seed. ``warmup=None`` is
    the CLI's historical auto rule ``min(100, steps // 10 + 1)``."""
    steps: int = 100
    batch: int = 8
    seq: int = 64
    lr: float = 1e-3
    warmup: Optional[int] = None
    microbatches: int = 1
    seed: int = 0
    telemetry: bool = False

    @property
    def warmup_steps(self) -> int:
        return self.warmup if self.warmup is not None \
            else min(100, self.steps // 10 + 1)


@_spec
class PrecisionSpec(_Spec):
    """The precision contract, with the legacy path an explicit mode
    rather than a sentinel ``None``:

      legacy  compute in ``ModelConfig.dtype``, fp32 accumulation, no
              loss scaling — what every run did before --precision grew
              presets.
      fp32 / bf16 / mixed — the core/precision.py presets.
    """
    mode: str = "legacy"

    def __post_init__(self):
        from repro.core.precision import LEGACY, POLICIES

        allowed = [LEGACY, *POLICIES]
        if self.mode not in allowed:
            raise ValueError(f"precision mode {self.mode!r}; options {allowed}")

    def policy(self):
        """The optimizer-facing PrecisionPolicy — None for legacy (the
        optimizer's no-cast, no-scaling path; steps.py resolves the
        effective dtypes via core/precision.effective_policy)."""
        from repro.core.precision import precision_policy

        return precision_policy(self.mode)


@_spec
class RankScheduleSpec(_Spec):
    """Adaptive-rank policy as its CLI grammar string (rank/schedule.py:
    ``static:K`` | ``step:S=K,...`` | ``energy:T[,kv...]``), or None for
    fixed-rank training. The string is the serialization format — it is
    validated at construction by actually parsing it."""
    schedule: Optional[str] = None

    def __post_init__(self):
        self.parsed()     # grammar errors surface at spec build time

    def parsed(self):
        from repro.rank import parse_rank_schedule

        return parse_rank_schedule(self.schedule)


@_spec
class ShardingSpec(_Spec):
    """Mesh geometry. ``data``/``model`` of None means the launcher
    heuristic: all visible devices, with the model axis the largest of
    (16, 8, 4, 2, 1) dividing both the device count and ``cfg.d_ff``;
    single-device runs get no mesh (plain jit). Explicit values pin the
    axes (their product must equal the device count)."""
    data: Optional[int] = None
    model: Optional[int] = None
    # decode-path tensor parallelism: the serve mesh is 1-D ('model',)
    # over this many devices (sharding/partition.py:serve_mesh); None/1
    # keeps single-device serving. Orthogonal to the training axes —
    # serving never builds the 2-D training mesh.
    decode_mesh: Optional[int] = None

    def __post_init__(self):
        if self.decode_mesh is not None and self.decode_mesh < 1:
            raise ValueError(f"decode_mesh {self.decode_mesh} must be >= 1")

    def serve_mesh(self):
        """The tensor-parallel serve mesh, or None for single-device
        serving (tp unset or 1)."""
        if self.decode_mesh is None or self.decode_mesh == 1:
            return None
        from repro.sharding.partition import serve_mesh

        return serve_mesh(self.decode_mesh)

    def mesh(self, cfg):
        import jax

        n_dev = jax.device_count()
        if self.data is None and self.model is None:
            if n_dev <= 1:
                return None
            n_model = 1
            for cand in (16, 8, 4, 2, 1):
                if n_dev % cand == 0 and cfg.d_ff % cand == 0:
                    n_model = cand
                    break
            return jax.make_mesh((n_dev // n_model, n_model), ("data", "model"))
        n_model = self.model or 1
        n_data = self.data or n_dev // n_model
        if n_data * n_model != n_dev:
            raise ValueError(f"sharding {n_data}x{n_model} wants "
                             f"{n_data * n_model} devices, have {n_dev}")
        if n_data == n_model == 1:
            return None
        return jax.make_mesh((n_data, n_model), ("data", "model"))


@_spec
class StreamingSpec(_Spec):
    """Long-context streaming KV policy (serving/streaming.py):
    ``window_pages=None`` disables streaming entirely (the default —
    every existing spec round-trips unchanged); setting it turns on
    attention sinks + sliding-window page eviction, with ``sink_pages``
    pages pinned forever at the head of every sequence. ``cold_kv``
    picks the tier for resident pages older than the window: ``"none"``
    keeps them at pool precision, ``"int8"`` demotes them to the
    page-granular int8 shadow pools (transparent dequant-on-attend)."""
    sink_pages: int = 1
    window_pages: Optional[int] = None
    cold_kv: str = "none"

    def __post_init__(self):
        if self.sink_pages < 1:
            raise ValueError(f"sink_pages {self.sink_pages} must be >= 1")
        if self.window_pages is not None and self.window_pages < 1:
            raise ValueError(f"window_pages {self.window_pages} must be >= 1")
        if self.cold_kv not in ("none", "int8"):
            raise ValueError(f"cold_kv {self.cold_kv!r}; options none|int8")
        if self.cold_kv != "none" and self.window_pages is None:
            raise ValueError("cold_kv needs streaming on (set window_pages)")

    @property
    def enabled(self) -> bool:
        return self.window_pages is not None

    def config(self):
        """The runtime StreamingConfig, or None when disabled."""
        if not self.enabled:
            return None
        from repro.serving.streaming import StreamingConfig

        return StreamingConfig(sink_pages=self.sink_pages,
                               window_pages=self.window_pages,
                               cold_kv=self.cold_kv)


@_spec
class ServeSpec(_Spec):
    """The serving side. ``mode="paged"`` is the continuous-batching
    engine (serving/engine.py) — page geometry, slots, prefill budget,
    prefix cache, chunked prefill, deadlines, int8 quantization.
    ``mode="static"`` is the dense (batch, max_seq)-cache path; it only
    reads ``batch``/``prompt_len``/``gen``/``quantize``. ``rank``
    resizes spectral groups at checkpoint-load time (cheap serving of a
    shrunk snapshot); ``gen`` doubles as the default ``max_new_tokens``
    for ``Server.submit``.

    Multi-tenant scheduling: ``scheduler`` picks the admission policy —
    ``"fifo"`` (strict arrival order, the original scheduler) or
    ``"slo"`` (per-tenant fair-share token accounting, priority
    classes, deadline-aware shedding — serving/scheduler.py:
    SLOScheduler; ``shed=False`` keeps the fair-share ordering but
    never rejects a doomed request, for apples-to-apples ordering
    studies). ``tenant``/``priority``/``default_deadline`` are the
    per-request defaults :meth:`Server.submit` stamps onto requests
    that don't say otherwise (priority 0 is the most urgent class;
    ``default_deadline`` falls back to ``request_timeout`` when None,
    keeping the pre-SLO flag meaningful).

    Self-speculative decoding: ``speculative_rank`` names the rank
    ladder as a grammar string — ``"32"`` drafts at rank 32 and
    verifies at full rank; ``"32,128"`` adds a rank-128 intermediate
    verification stage (comma-separated, non-decreasing, drafter
    first; the full-rank target is always implicit). The drafters are
    rank-truncations of the *same* checkpoint (the paper's rank-sweep
    result is what makes them usable for free); ``draft_tokens`` is
    the burst length the drafter proposes per engine step. Requires
    ``mode="paged"`` and is mutually exclusive with ``prefix_cache``
    (serving/speculative.py explains both)."""
    mode: str = "paged"
    slots: int = 4
    page_size: int = 16
    num_pages: int = 64
    pages_per_seq: int = 8
    prefill_budget: Optional[int] = 64
    prefix_cache: bool = False
    chunked_prefill: bool = False
    request_timeout: Optional[int] = None
    quantize: Optional[str] = None
    rank: Optional[int] = None
    batch: int = 4
    prompt_len: int = 16
    gen: int = 32
    scheduler: str = "fifo"
    shed: bool = True
    tenant: str = "default"
    priority: int = 0
    default_deadline: Optional[int] = None
    speculative_rank: Optional[str] = None
    draft_tokens: int = 4
    # disaggregated serving: prompt prefill runs on a separate worker
    # with its own page pool; finished pages ship to the decode pool
    # through serving/distributed.py:KVTransfer. ``kv_transfer`` picks
    # the wire format: "raw" (lossless page copy at pool dtype) or
    # "int8" (symmetric per-channel quantization on the wire, opt-in).
    disaggregate: bool = False
    kv_transfer: str = "raw"
    # long-context streaming KV policy; off by default (window unset)
    streaming: StreamingSpec = StreamingSpec()

    def __post_init__(self):
        if self.mode not in ("paged", "static"):
            raise ValueError(f"serve mode {self.mode!r}; options paged|static")
        if self.quantize not in (None, "int8"):
            raise ValueError(f"quantize {self.quantize!r}; options int8")
        if self.scheduler not in ("fifo", "slo"):
            raise ValueError(f"serve scheduler {self.scheduler!r}; "
                             f"options fifo|slo")
        if self.priority < 0:
            raise ValueError(f"priority {self.priority} must be >= 0 "
                             f"(0 is the most urgent class)")
        if not self.tenant:
            raise ValueError("tenant must be a non-empty string")
        if self.draft_tokens < 1:
            raise ValueError(f"draft_tokens {self.draft_tokens} must be >= 1")
        if self.speculative_rank is not None:
            if self.mode != "paged":
                raise ValueError("speculative decoding needs mode='paged'")
            if self.prefix_cache:
                raise ValueError(
                    "speculative_rank and prefix_cache are mutually "
                    "exclusive (an index page holds one ladder level's KV; "
                    "a speculative sequence needs every level's)")
            self.speculative_ladder()   # grammar errors at build time
        if self.kv_transfer not in ("raw", "int8"):
            raise ValueError(f"kv_transfer {self.kv_transfer!r}; "
                             f"options raw|int8")
        if self.disaggregate:
            if self.mode != "paged":
                raise ValueError("disaggregated prefill needs mode='paged'")
            if self.prefix_cache:
                raise ValueError(
                    "disaggregate and prefix_cache are mutually exclusive "
                    "(shared prefix pages live in the decode pool, which "
                    "the prefill worker cannot see)")
            if self.speculative_rank is not None:
                raise ValueError(
                    "disaggregate and speculative_rank are mutually "
                    "exclusive (the speculative engine owns its own "
                    "prefill/verify interleaving)")
        if self.streaming.enabled:
            if self.mode != "paged":
                raise ValueError("streaming KV needs mode='paged'")
            if self.speculative_rank is not None:
                raise ValueError(
                    "streaming and speculative_rank are mutually exclusive "
                    "(a drafted burst can cross an eviction boundary the "
                    "verifier no longer sees)")
            if self.disaggregate:
                raise ValueError(
                    "streaming and disaggregate are mutually exclusive "
                    "(the prefill worker's pool has no eviction policy)")
            cap = self.streaming.sink_pages + self.streaming.window_pages + 1
            if cap > self.pages_per_seq:
                raise ValueError(
                    f"streaming resident cap {cap} pages (sink + window + "
                    f"growth) exceeds pages_per_seq={self.pages_per_seq}")

    def speculative_ladder(self) -> list:
        """The parsed rank ladder (drafter first), or ``[]`` when
        speculation is off — serving/speculative.py owns the grammar."""
        if self.speculative_rank is None:
            return []
        from repro.serving.speculative import parse_ladder

        return parse_ladder(self.speculative_rank)

    @property
    def effective_deadline(self) -> Optional[int]:
        """The submit-time deadline default: ``default_deadline`` when
        set, else the engine-level ``request_timeout``."""
        return (self.default_deadline if self.default_deadline is not None
                else self.request_timeout)

    def paged_config(self):
        from repro.serving import PagedCacheConfig

        return PagedCacheConfig(
            page_size=self.page_size,
            num_pages=self.num_pages,
            max_slots=self.slots,
            max_pages_per_seq=self.pages_per_seq,
        )


@_spec
class CheckpointSpec(_Spec):
    """Where and how often the run checkpoints. ``directory=None`` means
    no checkpointing — :meth:`Trainer.fit` requires a directory (the
    fault-tolerant loop restarts from disk); step-at-a-time
    ``Trainer.step`` runs fine without one."""
    directory: Optional[str] = None
    every: int = 50
    keep: int = 3


# ----------------------------------------------------------------------
# the top-level spec
# ----------------------------------------------------------------------

@_spec
class RunSpec(_Spec):
    """One experiment, fully described. Sub-specs compose orthogonally;
    derive variants with :meth:`replace` (sub-spec instances, dicts
    merged into a sub-spec, or dotted leaf paths):

        spec.replace(precision=PrecisionSpec("mixed"))
        spec.replace(serve={"quantize": "int8", "slots": 8})
        spec.replace(**{"train.steps": 500, "serve.rank": 64})
    """
    model: ModelSpec = ModelSpec()
    train: TrainSpec = TrainSpec()
    precision: PrecisionSpec = PrecisionSpec()
    rank: RankScheduleSpec = RankScheduleSpec()
    sharding: ShardingSpec = ShardingSpec()
    serve: ServeSpec = ServeSpec()
    checkpoint: CheckpointSpec = CheckpointSpec()

    def replace(self, **overrides) -> "RunSpec":
        return _composite_replace(self, overrides)


def _composite_replace(spec, overrides: Dict[str, Any]):
    """``replace`` for specs composed of sub-specs (RunSpec, BenchSpec):
    accepts sub-spec instances, dicts merged into the existing sub-spec,
    and dotted leaf paths — every key validated, typos raise."""
    cls_name = type(spec).__name__
    fields = {f.name: f for f in dataclasses.fields(spec)}
    merged: Dict[str, Dict[str, Any]] = {}
    flat: Dict[str, Any] = {}
    for key, value in overrides.items():
        name, dot, leaf = key.partition(".")
        if name not in fields:
            raise ValueError(f"{cls_name}.replace: unknown field {name!r} "
                             f"(known: {sorted(fields)})")
        if dot:
            merged.setdefault(name, {})[leaf] = value
        elif isinstance(value, dict) and _subspec_type(fields[name]) is not None:
            merged.setdefault(name, {}).update(value)
        else:
            expected = type(fields[name].default)
            if not isinstance(value, expected):
                raise TypeError(f"{cls_name}.replace: {name} wants "
                                f"{expected.__name__} (or a dict / "
                                f"dotted '{name}.<field>' override), "
                                f"got {type(value).__name__}")
            flat[name] = value
    for name, sub_overrides in merged.items():
        base = flat.get(name, getattr(spec, name))
        flat[name] = base.replace(**sub_overrides)
    return dataclasses.replace(spec, **flat)


# ----------------------------------------------------------------------
# benchmark specs: declarative workloads, SLOs, and bench runs
# ----------------------------------------------------------------------

def _parse_weights(text: str, what: str) -> list:
    """Comma-separated positive weights (``"1"``, ``"3,1"``)."""
    try:
        weights = [float(w) for w in text.split(",") if w.strip()]
    except ValueError:
        raise ValueError(f"{what} {text!r}: want comma-separated numbers")
    if not weights or any(w <= 0 for w in weights):
        raise ValueError(f"{what} {text!r}: want positive weights")
    return weights


@_spec
class WorkloadSpec(_Spec):
    """A synthetic production traffic trace, fully determined by its
    fields (seeded — the same spec always generates the same requests;
    bench/workload.py is the generator).

      * **arrival process** (engine-step time): ``poisson`` draws the
        per-step arrival count from Poisson(``rate``); ``onoff`` is the
        bursty variant — Poisson(``rate``) for ``on_steps`` steps, then
        silent for ``off_steps``; ``fixed`` spaces arrivals evenly at
        ``rate`` per step (deterministic smoke traces).
      * **multi-tenant shared-prefix mix** — requests draw a tenant
        from ``tenants`` weights (ids ``t0``, ``t1``, ...); each tenant
        has its own ``shared_prefix``-token system prompt opening every
        one of its requests (the prefix-cache workload, per tenant).
      * **long-tail lengths** — prompt tails and output budgets are
        lognormal with the given mean and coefficient of variation
        (``cv=0`` pins the length exactly); the generator clips to the
        serving geometry so every request is admissible.
      * **priority classes** — each request draws a class from
        ``priority_mix`` weights (class 0 first, most urgent).
    """
    arrival: str = "poisson"
    rate: float = 0.5                # mean arrivals per engine step
    requests: int = 32
    seed: int = 0
    tenants: str = "1"               # per-tenant arrival weights
    shared_prefix: int = 0           # system-prompt tokens per tenant
    prompt_mean: int = 16
    prompt_cv: float = 0.5
    gen_mean: int = 16
    gen_cv: float = 0.5
    priority_mix: str = "1"          # per-class weights, class 0 first
    on_steps: int = 8                # onoff: burst length
    off_steps: int = 8               # onoff: silence length

    def __post_init__(self):
        if self.arrival not in ("poisson", "onoff", "fixed"):
            raise ValueError(f"arrival process {self.arrival!r}; "
                             f"options poisson|onoff|fixed")
        if self.rate <= 0:
            raise ValueError(f"arrival rate {self.rate} must be > 0")
        if self.requests < 1:
            raise ValueError(f"requests {self.requests} must be >= 1")
        if self.prompt_mean < 1 or self.gen_mean < 1:
            raise ValueError("prompt_mean and gen_mean must be >= 1")
        if self.prompt_cv < 0 or self.gen_cv < 0:
            raise ValueError("length cv must be >= 0")
        if self.shared_prefix < 0:
            raise ValueError("shared_prefix must be >= 0")
        if self.arrival == "onoff" and (self.on_steps < 1 or self.off_steps < 1):
            raise ValueError("onoff arrivals need on_steps/off_steps >= 1")
        self.tenant_weights()
        self.priority_weights()

    def tenant_weights(self) -> list:
        return _parse_weights(self.tenants, "tenants")

    def priority_weights(self) -> list:
        return _parse_weights(self.priority_mix, "priority_mix")


@_spec
class SLOSpec(_Spec):
    """Service-level objectives the bench scores against (and the SLO
    scheduler enforces). ``deadlines`` maps priority classes to
    end-to-end deadlines in engine steps, as a grammar string (the
    serialization format, validated by parsing): ``"64"`` gives every
    class the same deadline, ``"0=32,1=96"`` is per-class, ``None``
    means no deadline (every completion counts as SLO-met). ``ttft`` is
    the time-to-first-token target in engine steps — reported against,
    never enforced by eviction. ``shed`` lets the SLO scheduler refuse
    admission to requests that provably cannot finish inside their
    deadline (status ``"shed"``) instead of letting them burn decode
    slots and time out."""
    deadlines: Optional[str] = None
    ttft: Optional[int] = None
    shed: bool = True

    def __post_init__(self):
        self.deadline_map()
        if self.ttft is not None and self.ttft < 1:
            raise ValueError(f"ttft target {self.ttft} must be >= 1")

    def deadline_map(self) -> Dict[int, int]:
        """{priority class -> deadline steps}; empty when no SLO."""
        if self.deadlines is None:
            return {}
        text = self.deadlines.strip()
        try:
            if "=" not in text:
                return {0: int(text)}
            out = {}
            for part in text.split(","):
                cls_s, _, dl_s = part.partition("=")
                out[int(cls_s)] = int(dl_s)
            return out
        except ValueError:
            raise ValueError(
                f"SLO deadlines {self.deadlines!r}: want 'N' or "
                f"'CLS=N,CLS=N,...' (engine steps per priority class)")

    def deadline_for(self, priority: int) -> Optional[int]:
        """The deadline for a priority class: its own entry, else the
        highest class's entry (a single ``"64"`` covers everyone),
        else None."""
        dmap = self.deadline_map()
        if not dmap:
            return None
        if priority in dmap:
            return dmap[priority]
        return dmap[max(dmap)]


@_spec
class BenchSpec(_Spec):
    """One benchmark run, fully described: the model and serving
    geometry under test, the workload driven at it, the SLOs scored,
    and the sweep axes — ``overloads`` (arrival-rate multipliers; 1 is
    the nominal rate, 2 doubles it), ``schedulers`` (admission policies
    compared arm-by-arm), ``precisions``/``ranks`` (throughput-per-
    variant axes). ``python -m repro bench`` resolves every benchmark
    CLI to one of these first (``--dump-spec`` prints it), and
    bench/runner.py turns it into a schema-valid ``BENCH_<area>.json``
    (docs/benchmarks.md)."""
    name: str = "serving"
    model: ModelSpec = ModelSpec("llama3.2-1b", reduced=True)
    serve: ServeSpec = ServeSpec()
    workload: WorkloadSpec = WorkloadSpec()
    slo: SLOSpec = SLOSpec()
    overloads: str = "1,2"
    schedulers: str = "fifo,slo"
    precisions: str = "fp32"
    ranks: str = ""
    # serving-topology axis: "colocated" is the single-engine baseline,
    # "disaggregated" runs the same workload through the prefill/decode
    # worker split (serving/distributed.py) — arm-by-arm comparable
    # because both emit identical tokens
    serving_modes: str = "colocated"

    def __post_init__(self):
        if not self.name:
            raise ValueError("bench name must be non-empty")
        self.overload_factors()
        for s in self.scheduler_arms():
            if s not in ("fifo", "slo"):
                raise ValueError(f"scheduler {s!r}; options fifo|slo")
        for p in self.precision_arms():
            if p not in ("fp32", "int8"):
                raise ValueError(f"precision {p!r}; options fp32|int8")
        for m in self.serving_mode_arms():
            if m not in ("colocated", "disaggregated"):
                raise ValueError(f"serving mode {m!r}; options "
                                 f"colocated|disaggregated")
        self.rank_arms()

    def overload_factors(self) -> list:
        return _parse_weights(self.overloads, "overloads")

    def scheduler_arms(self) -> list:
        arms = [s.strip() for s in self.schedulers.split(",") if s.strip()]
        if not arms:
            raise ValueError("schedulers must name at least one arm")
        return arms

    def precision_arms(self) -> list:
        return [p.strip() for p in self.precisions.split(",") if p.strip()]

    def rank_arms(self) -> list:
        try:
            return [int(r) for r in self.ranks.split(",") if r.strip()]
        except ValueError:
            raise ValueError(f"ranks {self.ranks!r}: want comma-separated ints")

    def serving_mode_arms(self) -> list:
        arms = [m.strip() for m in self.serving_modes.split(",") if m.strip()]
        if not arms:
            raise ValueError("serving_modes must name at least one arm")
        return arms

    def replace(self, **overrides) -> "BenchSpec":
        return _composite_replace(self, overrides)
