"""Declarative experiment specs: the serializable description of a run.

A :class:`RunSpec` is the single object that describes everything the
repo knows how to do — which architecture to build (``model``), how to
train it (``train``, ``precision``, ``rank``, ``sharding``,
``checkpoint``) and how to serve it (``serve``). It is:

  * **frozen** — specs are values; deriving a variant goes through
    :meth:`RunSpec.replace`, never mutation;
  * **JSON-round-trippable** — ``to_json``/``from_json`` are bit-exact
    inverses (sorted keys, no float surprises: every field is an int,
    str, bool or None except learning rates, which JSON represents
    exactly via repr round-trip);
  * **self-validating** — unknown keys are rejected on ``from_dict``,
    and enum-ish fields (precision mode, serve mode, quantize, rank
    schedule grammar) are checked at construction time, so a spec that
    exists is a spec that can run.

The facades (api/trainer.py, api/server.py) consume RunSpecs; the CLIs
(launch/train.py, launch/serve.py, ``python -m repro``) are thin
argparse -> RunSpec adapters; CheckpointManager embeds the serialized
spec in every checkpoint sidecar so a snapshot is self-describing —
``Server.from_checkpoint(path)`` and ``Trainer.resume(path)`` need zero
re-specified flags.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional

__all__ = [
    "ModelSpec",
    "TrainSpec",
    "PrecisionSpec",
    "RankScheduleSpec",
    "ShardingSpec",
    "ServeSpec",
    "CheckpointSpec",
    "RunSpec",
]


# ----------------------------------------------------------------------
# shared (de)serialization machinery
# ----------------------------------------------------------------------

class _Spec:
    """Base for all spec dataclasses: dict/JSON round-trip with
    unknown-key rejection, and field-validated ``replace``."""

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, data: Dict[str, Any]) -> "_Spec":
        if not isinstance(data, dict):
            raise TypeError(f"{cls.__name__}.from_dict wants a dict, "
                            f"got {type(data).__name__}")
        fields = {f.name: f for f in dataclasses.fields(cls)}
        unknown = sorted(set(data) - set(fields))
        if unknown:
            raise ValueError(f"{cls.__name__}: unknown key(s) {unknown} "
                             f"(known: {sorted(fields)})")
        kw = {}
        for name, value in data.items():
            sub = _subspec_type(fields[name])
            kw[name] = sub.from_dict(value) if sub is not None else value
        return cls(**kw)

    def replace(self, **overrides) -> "_Spec":
        """A new spec with ``overrides`` applied. Keys are validated, so
        a typo raises instead of silently minting a field."""
        fields = {f.name: f for f in dataclasses.fields(self)}
        unknown = sorted(set(overrides) - set(fields))
        if unknown:
            raise ValueError(f"{type(self).__name__}.replace: unknown "
                             f"field(s) {unknown} (known: {sorted(fields)})")
        return dataclasses.replace(self, **overrides)

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "_Spec":
        return cls.from_dict(json.loads(text))


def _subspec_type(field: dataclasses.Field):
    """Nested-spec detection: a field whose default is itself a spec
    instance (RunSpec's sub-specs) recurses through that class's
    ``from_dict``; everything else is a plain JSON scalar."""
    return type(field.default) if isinstance(field.default, _Spec) else None


def _spec(cls):
    return dataclasses.dataclass(frozen=True)(cls)


# ----------------------------------------------------------------------
# sub-specs
# ----------------------------------------------------------------------

@_spec
class ModelSpec(_Spec):
    """Reference into the config registry (config/registry.py) plus the
    declarative SCT overrides a sweep needs: ``rank`` overrides
    ``cfg.sct.rank``; ``spectral_mlp=False`` is the dense baseline."""
    arch: str = "smollm2-1.7b"
    reduced: bool = False
    rank: Optional[int] = None
    spectral_mlp: Optional[bool] = None

    def config(self):
        from repro.config import get_config

        cfg = get_config(self.arch, reduced=self.reduced)
        sct_kw = {}
        if self.rank is not None:
            sct_kw["rank"] = int(self.rank)
        if self.spectral_mlp is not None:
            sct_kw["spectral_mlp"] = bool(self.spectral_mlp)
        return cfg.replace_sct(**sct_kw) if sct_kw else cfg


@_spec
class TrainSpec(_Spec):
    """The optimization run: step budget, batch geometry, LR schedule
    inputs, microbatching, and the data/init seed. ``warmup=None`` is
    the CLI's historical auto rule ``min(100, steps // 10 + 1)``."""
    steps: int = 100
    batch: int = 8
    seq: int = 64
    lr: float = 1e-3
    warmup: Optional[int] = None
    microbatches: int = 1
    seed: int = 0
    telemetry: bool = False

    @property
    def warmup_steps(self) -> int:
        return self.warmup if self.warmup is not None \
            else min(100, self.steps // 10 + 1)


@_spec
class PrecisionSpec(_Spec):
    """The precision contract, with the legacy path an explicit mode
    rather than a sentinel ``None``:

      legacy  compute in ``ModelConfig.dtype``, fp32 accumulation, no
              loss scaling — what every run did before --precision grew
              presets.
      fp32 / bf16 / mixed — the core/precision.py presets.
    """
    mode: str = "legacy"

    def __post_init__(self):
        from repro.core.precision import LEGACY, POLICIES

        allowed = [LEGACY, *POLICIES]
        if self.mode not in allowed:
            raise ValueError(f"precision mode {self.mode!r}; options {allowed}")

    def policy(self):
        """The optimizer-facing PrecisionPolicy — None for legacy (the
        optimizer's no-cast, no-scaling path; steps.py resolves the
        effective dtypes via core/precision.effective_policy)."""
        from repro.core.precision import precision_policy

        return precision_policy(self.mode)


@_spec
class RankScheduleSpec(_Spec):
    """Adaptive-rank policy as its CLI grammar string (rank/schedule.py:
    ``static:K`` | ``step:S=K,...`` | ``energy:T[,kv...]``), or None for
    fixed-rank training. The string is the serialization format — it is
    validated at construction by actually parsing it."""
    schedule: Optional[str] = None

    def __post_init__(self):
        self.parsed()     # grammar errors surface at spec build time

    def parsed(self):
        from repro.rank import parse_rank_schedule

        return parse_rank_schedule(self.schedule)


@_spec
class ShardingSpec(_Spec):
    """Mesh geometry. ``data``/``model`` of None means the launcher
    heuristic: all visible devices, with the model axis the largest of
    (16, 8, 4, 2, 1) dividing both the device count and ``cfg.d_ff``;
    single-device runs get no mesh (plain jit). Explicit values pin the
    axes (their product must equal the device count)."""
    data: Optional[int] = None
    model: Optional[int] = None

    def mesh(self, cfg):
        import jax

        n_dev = jax.device_count()
        if self.data is None and self.model is None:
            if n_dev <= 1:
                return None
            n_model = 1
            for cand in (16, 8, 4, 2, 1):
                if n_dev % cand == 0 and cfg.d_ff % cand == 0:
                    n_model = cand
                    break
            return jax.make_mesh((n_dev // n_model, n_model), ("data", "model"))
        n_model = self.model or 1
        n_data = self.data or n_dev // n_model
        if n_data * n_model != n_dev:
            raise ValueError(f"sharding {n_data}x{n_model} wants "
                             f"{n_data * n_model} devices, have {n_dev}")
        if n_data == n_model == 1:
            return None
        return jax.make_mesh((n_data, n_model), ("data", "model"))


@_spec
class ServeSpec(_Spec):
    """The serving side. ``mode="paged"`` is the continuous-batching
    engine (serving/engine.py) — page geometry, slots, prefill budget,
    prefix cache, chunked prefill, deadlines, int8 quantization.
    ``mode="static"`` is the dense (batch, max_seq)-cache path; it only
    reads ``batch``/``prompt_len``/``gen``/``quantize``. ``rank``
    resizes spectral groups at checkpoint-load time (cheap serving of a
    shrunk snapshot); ``gen`` doubles as the default ``max_new_tokens``
    for ``Server.submit``."""
    mode: str = "paged"
    slots: int = 4
    page_size: int = 16
    num_pages: int = 64
    pages_per_seq: int = 8
    prefill_budget: Optional[int] = 64
    prefix_cache: bool = False
    chunked_prefill: bool = False
    request_timeout: Optional[int] = None
    quantize: Optional[str] = None
    rank: Optional[int] = None
    batch: int = 4
    prompt_len: int = 16
    gen: int = 32

    def __post_init__(self):
        if self.mode not in ("paged", "static"):
            raise ValueError(f"serve mode {self.mode!r}; options paged|static")
        if self.quantize not in (None, "int8"):
            raise ValueError(f"quantize {self.quantize!r}; options int8")

    def paged_config(self):
        from repro.serving import PagedCacheConfig

        return PagedCacheConfig(
            page_size=self.page_size,
            num_pages=self.num_pages,
            max_slots=self.slots,
            max_pages_per_seq=self.pages_per_seq,
        )


@_spec
class CheckpointSpec(_Spec):
    """Where and how often the run checkpoints. ``directory=None`` means
    no checkpointing — :meth:`Trainer.fit` requires a directory (the
    fault-tolerant loop restarts from disk); step-at-a-time
    ``Trainer.step`` runs fine without one."""
    directory: Optional[str] = None
    every: int = 50
    keep: int = 3


# ----------------------------------------------------------------------
# the top-level spec
# ----------------------------------------------------------------------

@_spec
class RunSpec(_Spec):
    """One experiment, fully described. Sub-specs compose orthogonally;
    derive variants with :meth:`replace` (sub-spec instances, dicts
    merged into a sub-spec, or dotted leaf paths):

        spec.replace(precision=PrecisionSpec("mixed"))
        spec.replace(serve={"quantize": "int8", "slots": 8})
        spec.replace(**{"train.steps": 500, "serve.rank": 64})
    """
    model: ModelSpec = ModelSpec()
    train: TrainSpec = TrainSpec()
    precision: PrecisionSpec = PrecisionSpec()
    rank: RankScheduleSpec = RankScheduleSpec()
    sharding: ShardingSpec = ShardingSpec()
    serve: ServeSpec = ServeSpec()
    checkpoint: CheckpointSpec = CheckpointSpec()

    def replace(self, **overrides) -> "RunSpec":
        fields = {f.name: f for f in dataclasses.fields(self)}
        merged: Dict[str, Dict[str, Any]] = {}
        flat: Dict[str, Any] = {}
        for key, value in overrides.items():
            name, dot, leaf = key.partition(".")
            if name not in fields:
                raise ValueError(f"RunSpec.replace: unknown field {name!r} "
                                 f"(known: {sorted(fields)})")
            if dot:
                merged.setdefault(name, {})[leaf] = value
            elif isinstance(value, dict):
                merged.setdefault(name, {}).update(value)
            else:
                expected = type(fields[name].default)
                if not isinstance(value, expected):
                    raise TypeError(f"RunSpec.replace: {name} wants "
                                    f"{expected.__name__} (or a dict / "
                                    f"dotted '{name}.<field>' override), "
                                    f"got {type(value).__name__}")
                flat[name] = value
        for name, sub_overrides in merged.items():
            base = flat.get(name, getattr(self, name))
            flat[name] = base.replace(**sub_overrides)
        return dataclasses.replace(self, **flat)
