"""Unified experiment API: declarative RunSpecs + Trainer/Server facades.

    from repro.api import ModelSpec, RunSpec, TrainSpec, Trainer, Server

    spec = RunSpec(model=ModelSpec("smollm2-1.7b", reduced=True),
                   train=TrainSpec(steps=200, lr=3e-3),
                   checkpoint=CheckpointSpec(directory="/tmp/run1"))
    Trainer(spec).fit()                       # fault-tolerant, resumable
    server = Server.from_checkpoint("/tmp/run1")   # zero flags
    rid = server.submit(prompt_tokens)
    tokens = server.run()[rid]

Specs are frozen, JSON-round-trippable values (specs.py); the facades
own all wiring (mesh, optimizer, rank controller, engine); the CLIs
(``python -m repro``, launch/train.py, launch/serve.py) are thin
argparse adapters over this module. docs/api.md is the reference.
"""
from repro.api.specs import (
    BenchSpec,
    CheckpointSpec,
    ModelSpec,
    PrecisionSpec,
    RankScheduleSpec,
    RunSpec,
    ServeSpec,
    ShardingSpec,
    SLOSpec,
    StreamingSpec,
    TrainSpec,
    WorkloadSpec,
)
from repro.api.trainer import Trainer, log_metrics
from repro.api.server import Server, load_run_spec

__all__ = [
    "ModelSpec",
    "TrainSpec",
    "PrecisionSpec",
    "RankScheduleSpec",
    "ShardingSpec",
    "StreamingSpec",
    "ServeSpec",
    "CheckpointSpec",
    "RunSpec",
    "WorkloadSpec",
    "SLOSpec",
    "BenchSpec",
    "Trainer",
    "Server",
    "load_run_spec",
    "log_metrics",
]
