"""Trainer: the programmatic training facade over one :class:`RunSpec`.

Owns everything ``launch/train.py`` used to wire by hand — config
resolution, mesh construction, optimizer, the jitted train step (with
shardings on a mesh), the adaptive-rank controller, synthetic data, and
the fault-tolerant :class:`TrainLoop` — and exposes two ways to run:

  * :meth:`fit` — the production path: checkpoint/restart loop to
    ``spec.train.steps``, periodic async checkpoints whose sidecars
    embed the serialized RunSpec (self-describing snapshots);
  * :meth:`step` — one optimizer step at a time for notebooks, sweeps,
    and benchmarks that need per-step metrics; no checkpoint directory
    required.

``Trainer.resume(ckpt_dir)`` rebuilds a Trainer from the spec embedded
in the newest checkpoint — zero re-specified flags — and
``resume(ckpt_dir, **{"rank.schedule": "static:K"})`` is the explicit
cross-rank restore: the schedule fires at the restored boundary and the
controller resizes params + Adam moments before the first step.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.specs import RunSpec
from repro.checkpoint.manager import CheckpointManager
from repro.config.shapes import ShapeSpec
from repro.data.synthetic import SyntheticLMDataset
from repro.launch import steps as steps_mod
from repro.models.model import init_model
from repro.optim import make_sct_optimizer
from repro.rank import RankController
from repro.runtime.train_loop import TrainLoop, TrainLoopConfig
from repro.sharding.rules import set_current_mesh

__all__ = ["Trainer", "log_metrics"]


def log_metrics(step: int, metrics: Dict[str, float]) -> None:
    """The CLI's train-log line (loss, loss scale, rank telemetry) —
    the default ``metrics_cb`` for verbose runs."""
    line = f"step {step:6d}  loss {metrics['loss']:.4f}  ce {metrics['ce_loss']:.4f}"
    if "loss_scale" in metrics:
        line += f"  scale {metrics['loss_scale']:.0f}"
    if "rank/mean" in metrics:
        line += (f"  rank {metrics['rank/mean']:.0f}"
                 f" (eff {metrics['rank/eff_mean']:.1f},"
                 f" energy {metrics['rank/energy_top']:.3f},"
                 f" ortho {metrics['rank/ortho_max']:.1e})")
    print(line, flush=True)


class Trainer:
    """One training run, fully described by ``spec``.

    ``metrics_cb(step, {name: float})`` fires every ``log_every`` steps
    inside :meth:`fit` (pass :func:`log_metrics` for the CLI format);
    ``failure_hook`` is the chaos-drill injection point the loop already
    supports. Construction is cheap-ish (config + jit closure building,
    no weights); parameters materialize on the first :meth:`fit` /
    :meth:`step` / :meth:`save`.
    """

    def __init__(self, spec: RunSpec, *,
                 metrics_cb: Optional[Callable[[int, Dict], None]] = None,
                 failure_hook: Optional[Callable[[int], None]] = None):
        self.spec = spec
        self.cfg = spec.model.config()
        t = spec.train
        self.optimizer = make_sct_optimizer(
            self.cfg, lr=t.lr, warmup=t.warmup_steps, total_steps=t.steps,
            precision=spec.precision.mode)
        self.mesh = spec.sharding.mesh(self.cfg)
        if self.mesh is not None:
            set_current_mesh(self.mesh)
        self.rank_schedule = spec.rank.parsed()
        self.telemetry = t.telemetry or self.rank_schedule is not None
        self.shape = ShapeSpec("api", t.seq, t.batch, "train")
        self.metrics_cb = metrics_cb
        self.failure_hook = failure_hook

        step_fn = steps_mod.make_train_step(
            self.cfg, self.optimizer, microbatches=t.microbatches,
            telemetry=self.telemetry)
        if self.mesh is not None:
            state_sh, batch_sh = steps_mod.train_shardings(
                self.cfg, self.shape, self.mesh)
            self._step_fn = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                                    out_shardings=(state_sh, None),
                                    donate_argnums=(0,))
            self.state_shardings = state_sh
        else:
            self._step_fn = jax.jit(step_fn, donate_argnums=(0,))
            self.state_shardings = None

        self.controller = None
        if self.rank_schedule is not None:
            self.controller = RankController(
                self.cfg, self.optimizer, self.rank_schedule, mesh=self.mesh,
                shape=self.shape, microbatches=t.microbatches, seed=t.seed)

        self.dataset = SyntheticLMDataset(vocab=self.cfg.vocab,
                                          seq_len=t.seq, seed=t.seed)
        self.manager: Optional[CheckpointManager] = None
        if spec.checkpoint.directory is not None:
            self.manager = CheckpointManager(
                spec.checkpoint.directory, keep=spec.checkpoint.keep,
                run_spec=spec.to_dict())
        self.loop: Optional[TrainLoop] = None
        self._state: Any = None
        self._step = 0
        self._batches = None

    # ---------------------------------------------------------------- data --
    def make_batch(self, step: int) -> Dict[str, jax.Array]:
        """The spec's synthetic batch for ``step`` — what :meth:`step`
        consumes by default; public so benchmarks can build the batch
        outside their timed region."""
        t, l = self.dataset.batch(step, self.spec.train.batch)
        batch = {"tokens": jnp.asarray(t), "labels": jnp.asarray(l)}
        if self.cfg.family == "encdec":
            from repro.data.vision_stub import audio_frame_stub

            batch["encoder_frames"] = jnp.asarray(audio_frame_stub(
                self.spec.train.batch, self.cfg.encoder_seq, self.cfg.d_model))
        return batch

    def _batch_iter(self, start_step: int):
        step = start_step
        while True:
            yield self.make_batch(step)
            step += 1

    def _init_state(self):
        params = init_model(jax.random.PRNGKey(self.spec.train.seed), self.cfg)
        return self.optimizer.init(params)

    # ----------------------------------------------------------------- fit --
    def fit(self) -> Any:
        """Run the fault-tolerant loop to ``spec.train.steps`` and return
        the final TrainState. Resumes automatically from the newest
        checkpoint under ``spec.checkpoint.directory`` (which is
        required here — the restart path is disk-backed; use
        :meth:`step` for checkpoint-free experimentation)."""
        if self.manager is None:
            raise ValueError(
                "Trainer.fit needs spec.checkpoint.directory (the "
                "fault-tolerant loop restarts from disk); set it via "
                "spec.replace(**{'checkpoint.directory': ...}) or drive "
                "the run with Trainer.step() instead")
        if self._state is not None:
            # the loop resumes from disk (fault tolerance); progress made
            # in-memory via step() must land there first or it would be
            # silently re-run from the last checkpoint
            latest = self.manager.list_steps()
            if self._step > (latest[-1] if latest else -1):
                self.manager.save(self._step, jax.device_get(self._state),
                                  block=True)
        self.loop = TrainLoop(
            step_fn=self._step_fn,
            batch_iter_factory=self._batch_iter,
            ckpt_dir=self.spec.checkpoint.directory,
            cfg=TrainLoopConfig(total_steps=self.spec.train.steps,
                                checkpoint_every=self.spec.checkpoint.every,
                                keep_checkpoints=self.spec.checkpoint.keep),
            init_state_fn=self._init_state,
            state_shardings=self.state_shardings,
            metrics_cb=self.metrics_cb,
            failure_hook=self.failure_hook,
            rank_controller=self.controller,
            checkpoint_manager=self.manager,
        )
        self._state = self.loop.run()
        # the achieved step comes from the state itself: a checkpoint
        # already past train.steps restores and runs zero steps, and
        # current_step/save() must reflect that, not the budget
        self._step = (int(np.asarray(self._state["step"]))
                      if isinstance(self._state, dict) and "step" in self._state
                      else self.spec.train.steps)
        # the loop may have swapped in a resized step_fn/shardings, and
        # step() may be used to keep going — keep the data stream aligned
        self._step_fn = self.loop.step_fn
        self.state_shardings = self.loop.state_shardings
        self._batches = self._batch_iter(self._step)
        return self._state

    # ---------------------------------------------------------------- step --
    def _ensure_state(self) -> None:
        if self._state is not None:
            return
        step, state = (self.manager.restore_latest(self.state_shardings)
                       if self.manager is not None else (None, None))
        if state is None:
            step, state = 0, self._init_state()
        if self.controller is not None:
            # resize-on-restore: same boundary consult the loop performs
            result = self.controller.maybe_resize(step, state)
            if result is not None:
                state, self._step_fn, self.state_shardings = result
        self._state, self._step = state, step
        self._batches = self._batch_iter(step)

    def step(self, batch: Optional[Dict[str, jax.Array]] = None) -> Dict[str, jax.Array]:
        """One optimizer step; returns the step's metrics (device
        arrays — ``float(...)`` them as needed). The first call restores
        the newest checkpoint when a directory is configured, else
        initializes from ``spec.train.seed``. ``batch`` defaults to the
        spec's synthetic stream at the current step index; rank
        schedules fire at the same step boundaries as in :meth:`fit`."""
        self._ensure_state()
        if batch is None:
            batch = next(self._batches)
        self._state, metrics = self._step_fn(self._state, batch)
        self._step += 1
        if self.controller is not None:
            result = self.controller.maybe_resize(self._step, self._state, metrics)
            if result is not None:
                self._state, self._step_fn, self.state_shardings = result
        return metrics

    # ---------------------------------------------------------------- save --
    def save(self, block: bool = True) -> int:
        """Checkpoint the current state at the current step index (with
        the RunSpec embedded in the sidecar); returns the step saved."""
        if self.manager is None:
            raise ValueError("Trainer.save needs spec.checkpoint.directory")
        self._ensure_state()
        self.manager.save(self._step, jax.device_get(self._state), block=block)
        return self._step

    # -------------------------------------------------------------- resume --
    @classmethod
    def resume(cls, ckpt_dir: str, **overrides) -> "Trainer":
        """A Trainer rebuilt from the RunSpec embedded in the newest
        checkpoint under ``ckpt_dir`` — no flags re-specified; the next
        :meth:`fit`/:meth:`step` restores that snapshot. ``overrides``
        are :meth:`RunSpec.replace` arguments — the explicit cross-rank
        (``{"rank.schedule": "static:64"}``), cross-precision
        (``{"precision.mode": "mixed"}``), or extended-budget
        (``{"train.steps": 600}``) restore paths."""
        from repro.api.server import load_run_spec

        _, spec = load_run_spec(ckpt_dir)
        merged = {"checkpoint.directory": ckpt_dir}
        merged.update(overrides)
        return cls(spec.replace(**merged))

    # --------------------------------------------------------------- state --
    @property
    def state(self) -> Any:
        """The live TrainState (materializing it on first access)."""
        self._ensure_state()
        return self._state

    @property
    def params(self) -> Any:
        return self.state["params"]

    @property
    def current_step(self) -> int:
        """The global step of the live state (materializing it on first
        access, like :attr:`state` — a resumed trainer reports the
        checkpoint's step, not 0)."""
        self._ensure_state()
        return self._step
