"""Server: the programmatic serving facade over one :class:`RunSpec`.

Owns the continuous-batching :class:`ServingEngine` — paged KV cache,
int8 quantization, prefix cache, chunked prefill, deadlines — built
entirely from ``spec.serve``. Three ways in:

  * ``Server(spec)`` — random-init weights (demos, benchmarks);
  * ``Server(spec, params=...)`` — weights you already hold;
  * ``Server.from_checkpoint(path)`` — the zero-flag path: the RunSpec
    embedded in the newest checkpoint sidecar describes the model, the
    serving geometry, and the quantization; ``**overrides`` are
    :meth:`RunSpec.replace` arguments, so serving a shrunk snapshot is
    ``Server.from_checkpoint(path, **{"serve.rank": 64})``.

Requests go in through :meth:`submit` (or a prebuilt ``Request`` list
to :meth:`run`); results come back as a batch dict from :meth:`run` or
incrementally from the :meth:`stream` generator. :meth:`stats` is the
engine's throughput/memory/prefix-cache/latency counters.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.api.specs import RunSpec
from repro.serving.scheduler import Request

__all__ = ["Server", "load_run_spec"]


def load_run_spec(ckpt_dir: str) -> Tuple[int, RunSpec]:
    """(step, RunSpec) embedded in the newest checkpoint under
    ``ckpt_dir``. Raises FileNotFoundError for an empty directory and
    ValueError for pre-API checkpoints without an embedded spec."""
    from repro.checkpoint.manager import CheckpointManager

    # a read path must not mkdir (CheckpointManager's constructor does):
    # a typo'd path should stay a loud FileNotFoundError, not become a
    # plausible-looking empty run directory
    if not os.path.isdir(ckpt_dir):
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir!r}")
    step, spec_dict = CheckpointManager(ckpt_dir).latest_run_spec()
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir!r}")
    if spec_dict is None:
        raise ValueError(
            f"checkpoint step {step} under {ckpt_dir!r} predates spec "
            f"embedding — rebuild the RunSpec by hand: Trainer(spec) "
            f"restores the snapshot, Server(spec, params) serves it")
    return step, RunSpec.from_dict(spec_dict)


class Server:
    """One serving runtime for one model, described by ``spec``. Only
    ``spec.model`` and ``spec.serve`` are consulted — plus
    ``spec.train.seed`` when no ``params`` are given and the weights
    are random-initialized (a training RunSpec serves unchanged; the
    sub-specs are orthogonal). ``spec.serve.mode`` must be ``"paged"``,
    the engine's runtime (the static dense-cache path stays a
    launcher/test oracle, not a production server)."""

    def __init__(self, spec: RunSpec, params: Any = None,
                 drafter_params: Optional[Sequence[Any]] = None):
        if spec.serve.mode != "paged":
            raise ValueError(
                f"Server drives the paged engine; serve.mode is "
                f"{spec.serve.mode!r} (the static path lives in "
                f"launch/serve.py as the verification oracle)")
        from repro.models.model import init_model
        from repro.serving.engine import ServingEngine
        import jax

        self.spec = spec
        self.cfg = spec.model.config()
        if params is None:
            params = init_model(jax.random.PRNGKey(spec.train.seed), self.cfg)
        sv = spec.serve
        mesh = spec.sharding.serve_mesh()
        streaming = sv.streaming.config()
        common = dict(
            prefill_token_budget=sv.prefill_budget,
            quantize=sv.quantize,
            prefix_cache=sv.prefix_cache,
            chunked_prefill=sv.chunked_prefill,
            scheduler=sv.scheduler,
            shed=sv.shed,
            mesh=mesh,
        )
        if sv.speculative_rank is not None and mesh is not None:
            raise ValueError(
                "speculative_rank and sharding.decode_mesh are mutually "
                "exclusive: the rank-ladder engine drives its own "
                "draft/verify executables outside the shard_map wrapping")
        if sv.disaggregate:
            from repro.serving.distributed import DisaggregatedEngine

            self.engine: ServingEngine = DisaggregatedEngine(
                self.cfg, params, sv.paged_config(),
                kv_transfer=sv.kv_transfer, **common)
            if drafter_params is not None:
                raise ValueError("drafter_params given but "
                                 "serve.speculative_rank is unset")
        elif sv.speculative_rank is not None:
            from repro.serving.speculative import SpeculativeEngine

            # drafter_params=None derives the ladder by shrinking
            # ``params`` — identical factors to a per-rank checkpoint
            # restore (from_checkpoint passes the restored trees in)
            self.engine: ServingEngine = SpeculativeEngine(
                self.cfg, params, sv.paged_config(),
                speculative_ranks=sv.speculative_ladder(),
                draft_tokens=sv.draft_tokens,
                drafter_params=drafter_params,
                **common,
            )
        else:
            if drafter_params is not None:
                raise ValueError("drafter_params given but "
                                 "serve.speculative_rank is unset")
            self.engine = ServingEngine(self.cfg, params, sv.paged_config(),
                                        streaming=streaming, **common)
        self.checkpoint_step: Optional[int] = None
        self._pending: List[Request] = []
        self._next_rid = 0
        # rids currently owned by this server or its engine (pending,
        # queued, in flight, undelivered) — maintained incrementally so
        # submit() stays O(1); delivery discards, so a finished rid is
        # reusable, matching engine.known_rids() semantics
        self._live_rids: set = set()

    # -------------------------------------------------------------- load --
    @classmethod
    def from_checkpoint(cls, ckpt_dir: str, **overrides) -> "Server":
        """Serve the newest checkpoint under ``ckpt_dir`` with zero
        re-specified flags: model + serving geometry come from the
        embedded RunSpec; ``overrides`` are :meth:`RunSpec.replace`
        arguments applied on top (``{"serve.rank": K}`` resizes the
        spectral groups at load, ``{"serve.quantize": "int8"}`` serves
        the snapshot quantized)."""
        from repro.serving.engine import params_from_checkpoint

        step, spec = load_run_spec(ckpt_dir)
        spec = spec.replace(**overrides)
        # pin the params load to the step the spec came from: a live
        # training run may land (and rotate in) a newer checkpoint
        # between the two reads, and spec/weights must describe the
        # same snapshot (they can disagree on rank otherwise)
        _, params = params_from_checkpoint(ckpt_dir, rank=spec.serve.rank,
                                           step=step)
        # speculative serving restores the SAME snapshot once more per
        # ladder rank — the checkpoint manager's resize-on-restore path
        # is the paper-exact rank truncation, so one checkpoint yields
        # the whole drafter/verifier ladder
        drafters = None
        if spec.serve.speculative_rank is not None:
            drafters = [params_from_checkpoint(ckpt_dir, rank=k, step=step)[1]
                        for k in spec.serve.speculative_ladder()]
        server = cls(spec, params, drafter_params=drafters)
        server.checkpoint_step = step
        return server

    # ------------------------------------------------------------ submit --
    def submit(self, prompt, max_new_tokens: Optional[int] = None, *,
               arrival: int = 0, eos_id: Optional[int] = None,
               deadline: Optional[int] = None,
               tenant: Optional[str] = None,
               priority: Optional[int] = None,
               rid: Optional[int] = None) -> int:
        """Queue one request; returns its rid (auto-assigned unless
        given). ``max_new_tokens`` defaults to ``spec.serve.gen``,
        ``deadline`` to ``spec.serve.effective_deadline``
        (``default_deadline`` falling back to ``request_timeout``), and
        ``tenant``/``priority`` to the ``spec.serve`` defaults — the
        SLO scheduler reads all three; FIFO ignores tenant/priority.
        The request sits host-side until the next
        :meth:`run`/:meth:`stream` drives the engine."""
        if rid is None:
            # auto-assignment must also dodge rids the engine learned
            # from explicit Request lists passed straight to run/stream
            rid = self._next_rid
            while rid in self._live_rids:
                rid += 1
        elif rid in self._live_rids:
            raise ValueError(f"rid {rid} is already queued or in flight — "
                             f"results key on rid, so a duplicate would "
                             f"silently overwrite the other request's "
                             f"output")
        self._live_rids.add(rid)
        self._next_rid = max(self._next_rid, rid + 1)
        self._pending.append(Request(
            rid=rid,
            prompt=np.asarray(prompt, dtype=np.int32),
            max_new_tokens=(max_new_tokens if max_new_tokens is not None
                            else self.spec.serve.gen),
            arrival=arrival,
            eos_id=eos_id,
            deadline=(deadline if deadline is not None
                      else self.spec.serve.effective_deadline),
            tenant=(tenant if tenant is not None
                    else self.spec.serve.tenant),
            priority=(priority if priority is not None
                      else self.spec.serve.priority),
        ))
        return rid

    def _take(self, requests: Optional[Sequence[Request]]) -> List[Request]:
        if requests is not None:
            return list(requests)
        taken, self._pending = self._pending, []
        # an empty take is fine while the engine still holds in-flight
        # work or undelivered results — a stream() abandoned mid-trace
        # strands its remaining requests, and a fresh run()/stream()
        # with nothing new submitted is how they are recovered
        if not taken and not self.engine.has_pending_work:
            raise ValueError("nothing to serve: submit() requests first "
                             "(or pass an explicit Request list)")
        return taken

    # --------------------------------------------------------------- run --
    def run(self, requests: Optional[Sequence[Request]] = None) -> Dict[int, np.ndarray]:
        """Serve everything submitted (or an explicit ``Request`` list)
        to completion; rid -> generated int32 token ids. Per-rid
        outcomes land in :attr:`last_statuses`."""
        return {rid: tokens for rid, tokens, _ in self.stream(requests)}

    def stream(self, requests: Optional[Sequence[Request]] = None
               ) -> Iterator[Tuple[int, np.ndarray, str]]:
        """Incremental form of :meth:`run`: yields ``(rid, tokens,
        status)`` the engine step each request finishes — the
        continuous-batching loop advances between yields, so consumers
        see completions in service order, not submission order."""
        reqs = self._take(requests)
        # explicit Request lists bypass submit(); their rids join the
        # live ledger here so auto-assignment dodges them too
        self._live_rids.update(r.rid for r in reqs)
        inner = self.engine.serve(reqs)   # registers with the engine now

        def _events():
            for rid, tokens, status in inner:
                self._live_rids.discard(rid)
                yield rid, tokens, status

        return _events()

    def cancel(self, rid: int) -> bool:
        """Cancel an in-flight request (only meaningful from another
        thread of control, e.g. between :meth:`stream` iterations)."""
        return self.engine.cancel(rid)

    # ------------------------------------------------------------- stats --
    def stats(self) -> Dict[str, float]:
        return self.engine.stats()

    @property
    def last_statuses(self) -> Dict[int, str]:
        return self.engine.last_statuses

    @property
    def params(self) -> Any:
        """The engine's effective weights (quantized when serving
        int8 — dequantize with serving.dequantize_tree for oracles)."""
        return self.engine.params
