"""Serving engine: continuous batching over jitted prefill/decode steps
with paged caches.

Shape discipline — the decode step compiles exactly once per engine:
``(max_slots, 1)`` tokens against the shared pools, with block tables
and per-slot fill levels as data. A mixed stream of request lengths
never retriggers decode compilation. Prefill runs one request at a time
at its exact prompt length (jax caches one executable per distinct
length), writes the resulting cache into that sequence's pages, and
scatters recurrent (mamba/xlstm) state into the sequence's slot — so
every model family in models/decode.py serves through the same engine.

The loop each engine step: admit waiting requests into free slots
(FIFO, under the prefill token budget) -> prefill them -> one batched
decode step for every active slot -> record tokens, evict finished
sequences, free their pages.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.model_config import ModelConfig
from repro.models.decode import ATTN_STATE_KEYS, recurrent_slot_axes
from repro.models.model import (
    decode_step_paged,
    init_decode_state,
    init_paged_state,
    prefill,
)
from repro.serving.paged_cache import PagedCacheConfig, paged_write_pages, slot_write
from repro.serving.scheduler import ContinuousBatchingScheduler, Request, SeqState


def params_from_checkpoint(ckpt_dir: str, *, rank: Optional[int] = None,
                           step: Optional[int] = None):
    """(step, params) from a training checkpoint directory, optionally
    resized to ``rank`` via the manager's resize-on-restore path. The
    one serving-side loader — the engine classmethod and the serve CLI
    both route through here, so checkpoint-layout or resize-semantics
    changes have a single call site. Full TrainStates are stripped to
    their ``params``; a bare params tree passes through."""
    from repro.checkpoint.manager import CheckpointManager

    mgr = CheckpointManager(ckpt_dir)
    if step is None:
        step, state = mgr.restore_latest(target_rank=rank)
        if state is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir!r}")
    else:
        state = mgr.restore(step, target_rank=rank)
    params = state["params"] if isinstance(state, dict) and "params" in state \
        else state
    return step, params


class ServingEngine:
    """Continuous-batching serving runtime over one model + one paged
    cache pool. Construct with live ``params`` (optionally
    ``quantize="int8"``) or via :meth:`from_checkpoint` (optionally at
    a different spectral rank), submit ``Request`` traces through
    :meth:`run`, read throughput/memory from :meth:`stats`. The decode
    step compiles once per engine — ``(max_slots, 1)`` tokens against
    the shared pools with block tables as data — so mixed-length
    request streams never retrigger compilation."""

    def __init__(self, cfg: ModelConfig, params, pcfg: PagedCacheConfig, *,
                 prefill_token_budget: Optional[int] = None,
                 quantize: Optional[str] = None):
        if cfg.family == "encdec":
            raise NotImplementedError("paged serving targets decoder-only families")
        self.cfg = cfg
        from repro.serving.quantize import param_bytes, quantize_tree

        self.weight_bytes_fp = param_bytes(params)
        if quantize == "int8":
            params = quantize_tree(params)
        elif quantize is not None:
            raise ValueError(f"unknown quantization {quantize!r}; options: int8")
        self.quantize = quantize
        self.weight_bytes = param_bytes(params)
        self.params = params
        self.pcfg = pcfg
        self.state = init_paged_state(cfg, pcfg)
        self.sched = ContinuousBatchingScheduler(pcfg, prefill_token_budget)
        self._next_input = np.zeros((pcfg.max_slots,), dtype=np.int32)

        self._decode_fn = jax.jit(
            lambda p, t, st, bt, sl: decode_step_paged(p, t, st, bt, sl, cfg),
            donate_argnums=(2,),
        )
        self._prefill_fn = jax.jit(lambda p, t, st: prefill(p, t, cfg, st))
        self._write_pages = jax.jit(
            lambda pool, ids, v: paged_write_pages(pool, ids, jnp.squeeze(v, 1), n_stack=1),
            donate_argnums=(0,),
        )
        self._scatter = {}
        for key, ax in recurrent_slot_axes(cfg).items():
            axes_tree = jax.tree.map(lambda _: ax, self.state[key])
            self._scatter[key] = jax.jit(
                lambda full, vals, slot, _axes=axes_tree: slot_write(full, _axes, slot, vals),
                static_argnums=(2,), donate_argnums=(0,),
            )

        # stats
        self.prefill_tokens = 0
        self.decoded_tokens = 0
        self.decode_steps = 0
        self.wall_s = 0.0

    # -------------------------------------------------------------- load --
    @classmethod
    def from_checkpoint(cls, cfg: ModelConfig, ckpt_dir: str,
                        pcfg: PagedCacheConfig, *,
                        rank: Optional[int] = None,
                        step: Optional[int] = None,
                        **kw) -> "ServingEngine":
        """Build an engine straight from a training checkpoint directory.

        ``rank`` shrinks (or grows) every spectral group to that rank at
        load time via the checkpoint manager's resize-on-restore path —
        the cheap-serving story: a run trained at rank 128 serves from
        the same snapshot at rank 64 with ~2x smaller spectral factors,
        keeping the top-64 singular directions (Eckart–Young optimal for
        the represented weights). The Adam moments in the checkpoint are
        dropped; only ``params`` board the engine. Composes with
        ``quantize="int8"`` (shrink first, then quantize).
        """
        _, params = params_from_checkpoint(ckpt_dir, rank=rank, step=step)
        return cls(cfg, params, pcfg, **kw)

    # --------------------------------------------------------------- run --
    def run(self, requests: Sequence[Request]) -> Dict[int, np.ndarray]:
        """Serve a trace to completion. ``Request.arrival`` staggers
        enqueueing in engine-step time (a request is invisible to the
        scheduler before its arrival step). Returns rid -> generated
        token ids (first token from prefill, rest from decode)."""
        pending: List[Request] = sorted(requests, key=lambda r: r.arrival)
        first_new = len(self.sched.finished)            # segment repeated run()s
        t0 = time.time()
        clock = 0
        while pending or self.sched.has_work:
            while pending and pending[0].arrival <= clock:
                self.sched.submit(pending.pop(0))
            for seq in self.sched.admit():
                self._prefill_into(seq)
            if self.sched.active:
                self._decode_once()
            clock += 1
        jax.block_until_ready(jax.tree.leaves(self.state)[0])
        self.wall_s += time.time() - t0
        return {s.request.rid: np.asarray(s.generated, dtype=np.int32)
                for s in self.sched.finished[first_new:]}

    # ------------------------------------------------------------- steps --
    def _prefill_into(self, seq: SeqState) -> None:
        req = seq.request
        tokens = jnp.asarray(req.prompt, dtype=jnp.int32)[None]
        tmp = init_decode_state(self.cfg, 1, req.prompt_len)
        logits, filled = self._prefill_fn(self.params, tokens, tmp)
        page_ids = jnp.asarray(np.asarray(seq.pages, dtype=np.int32))
        for key in ATTN_STATE_KEYS:
            if key in self.state:
                self.state[key] = jax.tree.map(
                    lambda pool, v: self._write_pages(pool, page_ids, v),
                    self.state[key], filled[key])
        for key, scatter in self._scatter.items():
            self.state[key] = scatter(self.state[key], filled[key], seq.slot)
        tok = int(np.asarray(jnp.argmax(logits[0, -1])))
        self._next_input[seq.slot] = tok
        self.prefill_tokens += req.prompt_len
        self.sched.on_prefill_token(seq.slot, tok)

    def _decode_once(self) -> None:
        self.sched.ensure_append_capacity()
        bt = jnp.asarray(self.sched.block_table)
        sl = jnp.asarray(self.sched.seq_lens)
        toks = jnp.asarray(self._next_input)[:, None]
        logits, self.state = self._decode_fn(self.params, toks, self.state, bt, sl)
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1)).astype(np.int32)
        active_slots = list(self.sched.active)
        for slot in active_slots:
            tok = int(nxt[slot])
            self._next_input[slot] = tok
            self.sched.on_token(slot, tok)
        self.decode_steps += 1
        self.decoded_tokens += len(active_slots)

    # ------------------------------------------------------------- stats --
    def attn_cache_bytes(self) -> int:
        """Bytes held by the paged attention pools (the memory the
        static (batch, max_seq) layout pins at worst case instead)."""
        total = 0
        for key in ATTN_STATE_KEYS:
            if key in self.state:
                total += sum(leaf.size * leaf.dtype.itemsize
                             for leaf in jax.tree.leaves(self.state[key]))
        return total

    def stats(self) -> Dict[str, float]:
        gen = sum(len(s.generated) for s in self.sched.finished)
        return {
            "requests": float(len(self.sched.finished)),
            "prefill_tokens": float(self.prefill_tokens),
            "generated_tokens": float(gen),
            "decode_steps": float(self.decode_steps),
            "wall_s": self.wall_s,
            "tokens_per_s": (self.prefill_tokens + gen) / self.wall_s if self.wall_s else 0.0,
            "attn_cache_bytes": float(self.attn_cache_bytes()),
            "weight_bytes": float(self.weight_bytes),
            "weight_bytes_fp": float(self.weight_bytes_fp),
        }
