"""Serving engine: continuous batching over jitted prefill/decode steps
with paged caches, shared-prefix reuse, and chunked prefill.

Shape discipline — the decode step compiles exactly once per engine:
``(max_slots, 1)`` tokens against the shared pools, with block tables
and per-slot fill levels as data. A mixed stream of request lengths
never retriggers decode compilation. Prompt processing depends on
family:

  * attention families (dense/moe) prefill through the paged
    chunk path — ``models/decode.py:prefill_chunk_lm_paged`` writes KV
    straight into the sequence's pages from a logical offset, so a
    prompt whose prefix is already cached (shared system prompt) only
    computes its tail, and with ``chunked_prefill`` the tail is split
    into budget-sized chunks interleaved with decode steps (a long
    prompt no longer stalls every active slot for its full length).
    One executable per distinct chunk length.
  * recurrent families (hybrid mamba, xlstm) opt out of prefix sharing
    and chunking (models/decode.py:PREFIX_SHARING_FAMILIES): their
    prompts prefill in one shot at exact length through a temporary
    static cache that is scattered into pages / slot state.

The loop each engine step: expire deadlines -> admit waiting requests
into free slots (FIFO, shared prefixes mapped from the index) -> run
prefill chunks under the step budget -> one batched decode step for
every *decoding* slot (mid-prefill slots are invisible to it) ->
record tokens, drain finished/cancelled sequences to the caller.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.model_config import ModelConfig
from repro.models.decode import (
    ATTN_STATE_KEYS,
    recurrent_slot_axes,
    supports_prefix_sharing,
)
from repro.models.model import (
    decode_step_paged,
    init_decode_state,
    init_paged_state,
    prefill,
    prefill_chunk_paged,
)
from repro.serving.paged_cache import PagedCacheConfig, paged_write_pages, slot_write
from repro.serving.scheduler import (
    ContinuousBatchingScheduler,
    Request,
    SeqState,
    SLOScheduler,
)
from repro.serving.streaming import StreamingConfig

# inter-token latency samples kept for percentile stats; bounded so a
# long-lived engine under continuous traffic cannot leak host memory
LATENCY_WINDOW = 4096


def params_from_checkpoint(ckpt_dir: str, *, rank: Optional[int] = None,
                           step: Optional[int] = None):
    """(step, params) from a training checkpoint directory, optionally
    resized to ``rank`` via the manager's resize-on-restore path. The
    one serving-side loader — the engine classmethod and the serve CLI
    both route through here, so checkpoint-layout or resize-semantics
    changes have a single call site. Full TrainStates are stripped to
    their ``params``; a bare params tree passes through."""
    from repro.checkpoint.manager import CheckpointManager

    mgr = CheckpointManager(ckpt_dir)
    if step is None:
        step, state = mgr.restore_latest(target_rank=rank)
        if state is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir!r}")
    else:
        state = mgr.restore(step, target_rank=rank)
    params = state["params"] if isinstance(state, dict) and "params" in state \
        else state
    return step, params


class ServingEngine:
    """Continuous-batching serving runtime over one model + one paged
    cache pool. Construct with live ``params`` (optionally
    ``quantize="int8"``, ``prefix_cache=True``, ``chunked_prefill=True``)
    or via :meth:`from_checkpoint` (optionally at a different spectral
    rank), submit ``Request`` traces through :meth:`run`, cancel
    in-flight requests with :meth:`cancel`, read throughput/memory/
    prefix-cache/latency numbers from :meth:`stats`. The decode step
    compiles once per engine — ``(max_slots, 1)`` tokens against the
    shared pools with block tables as data — so mixed-length request
    streams never retrigger compilation."""

    def __init__(self, cfg: ModelConfig, params, pcfg: PagedCacheConfig, *,
                 prefill_token_budget: Optional[int] = None,
                 quantize: Optional[str] = None,
                 prefix_cache: bool = False,
                 chunked_prefill: bool = False,
                 scheduler: str = "fifo",
                 shed: bool = True,
                 streaming: Optional[StreamingConfig] = None,
                 mesh=None):
        if cfg.family == "encdec":
            raise NotImplementedError("paged serving targets decoder-only families")
        self.cfg = cfg
        from repro.serving.quantize import param_bytes, quantize_tree

        self.weight_bytes_fp = param_bytes(params)
        if quantize == "int8":
            params = quantize_tree(params)
        elif quantize is not None:
            raise ValueError(f"unknown quantization {quantize!r}; options: int8")
        self.quantize = quantize
        self.weight_bytes = param_bytes(params)
        self.params = params
        self.pcfg = pcfg
        self.prefill_token_budget = prefill_token_budget
        # chunk size for chunked prefill: the step budget when set, else
        # a few pages' worth — chunked_prefill=True must never silently
        # degrade to whole-tail prefill just because no budget was given
        self.prefill_chunk = prefill_token_budget or 4 * pcfg.page_size
        # family policy: recurrent families silently opt out (explicit
        # in models/decode.py:PREFIX_SHARING_FAMILIES and docs/serving.md)
        self._offset_prefill = supports_prefix_sharing(cfg)
        self.prefix_cache = bool(prefix_cache) and self._offset_prefill
        self.chunked_prefill = bool(chunked_prefill) and self._offset_prefill
        # streaming KV policy (serving/streaming.py): attention sinks +
        # sliding-window eviction + optional int8 cold tier. Eviction
        # rewrites cache-resident history, which only the offset-prefill
        # families can express (positions are cache-slot-relative).
        self.streaming = streaming
        if streaming is not None and not self._offset_prefill:
            raise NotImplementedError(
                "streaming KV needs the offset-prefill paged path; family "
                f"{cfg.family!r} carries recurrent state that cannot drop "
                "evicted history")
        if streaming is not None and mesh is not None:
            raise NotImplementedError(
                "streaming KV is not supported under tensor-parallel "
                "serving (per-shard shadow pools are not wired)")
        self._cold = streaming is not None and streaming.cold_kv == "int8"
        self.state = init_paged_state(cfg, pcfg,
                                      "int8" if self._cold else "none")
        if scheduler == "slo":
            self.sched: ContinuousBatchingScheduler = SLOScheduler(
                pcfg, prefill_token_budget, prefix_sharing=self.prefix_cache,
                streaming=streaming, shed=shed)
        elif scheduler == "fifo":
            self.sched = ContinuousBatchingScheduler(
                pcfg, prefill_token_budget, prefix_sharing=self.prefix_cache,
                streaming=streaming)
        else:
            raise ValueError(f"unknown scheduler {scheduler!r}; options: "
                             f"fifo, slo")
        self.scheduler = scheduler
        self._next_input = np.zeros((pcfg.max_slots,), dtype=np.int32)

        # cold-tier bookkeeping: a host flag per physical page (1 = the
        # int8 shadow copy is authoritative for attention) mirrored to
        # device lazily, cleared whenever the pool frees a page (evict,
        # finish, cancel, prefix-cache eviction — one hook covers all)
        self.stream_demotions = 0
        self.cold_page_bytes = 0
        self._cold_np = np.zeros((pcfg.num_pages + 1,), dtype=np.int32)
        self._cold_dev = None
        if self._cold:
            self.sched.pool.on_free = self._on_pages_freed
            from repro.serving.quantize import quantize_kv_pages

            def _demote(state, page):
                for key in ATTN_STATE_KEYS:
                    if key not in state:
                        continue
                    cache = dict(state[key])
                    for name in [n for n in cache if n + "_q8" in cache]:
                        qt = quantize_kv_pages(cache[name][:, page],
                                               token_axis=1)
                        cache[name + "_q8"] = \
                            cache[name + "_q8"].at[:, page].set(qt["q8"])
                        cache[name + "_scale"] = \
                            cache[name + "_scale"].at[:, page].set(qt["scale"])
                    state = dict(state, **{key: cache})
                return state

            self._demote_fn = jax.jit(_demote, donate_argnums=(0,))
            # int8 shadow bytes one demoted page occupies across every
            # layer of every q8 leaf — the deterministic cost metric
            self._cold_bytes_per_page = sum(
                int(leaf.shape[0]) * int(np.prod(leaf.shape[2:]))
                for key in ATTN_STATE_KEYS if key in self.state
                for name, leaf in self.state[key].items()
                if name.endswith("_q8"))

        # tensor-parallel serving: under a serve mesh the decode and
        # chunk-prefill steps run inside shard_map — GQA KV pools live
        # as per-shard kv-head slices, MLA latent pools and everything
        # else (params: tiny spectral factors — replication is the
        # cheap placement the paper's compression buys) replicate, and
        # the per-shard attention all-gathers head outputs before wo,
        # so greedy outputs stay token-identical to single-device.
        self.mesh = mesh
        self.tp = 1
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P

            from repro.sharding.partition import (
                TP_AXIS,
                named_shardings,
                paged_state_pspecs,
                serve_tp_valid,
                shard_map_compat,
            )

            self.tp = int(mesh.shape[TP_AXIS])
            if not self._offset_prefill:
                raise NotImplementedError(
                    "tensor-parallel paged decode needs pure paged-attention "
                    f"state; family {cfg.family!r} carries recurrent state")
            if not serve_tp_valid(cfg, self.tp):
                dim = "n_heads" if cfg.attention == "mla" else "n_kv_heads"
                raise ValueError(
                    f"tp={self.tp} does not divide this config's {dim}")
        if mesh is not None and self.tp > 1:
            tp = self.tp
            state_specs = paged_state_pspecs(cfg, self.state, tp)
            self._decode_fn = jax.jit(shard_map_compat(
                lambda p, t, st, bt, sl: decode_step_paged(
                    p, t, st, bt, sl, cfg, tp_axis=TP_AXIS, tp_size=tp),
                mesh, in_specs=(P(), P(), state_specs, P(), P()),
                out_specs=(P(), state_specs)), donate_argnums=(2,))
            self._chunk_fn = jax.jit(shard_map_compat(
                lambda p, t, st, bt, s0: prefill_chunk_paged(
                    p, t, st, bt, s0, cfg, tp_axis=TP_AXIS, tp_size=tp),
                mesh, in_specs=(P(), P(), state_specs, P(), P()),
                out_specs=(P(), state_specs)), donate_argnums=(2,))
            self.state = jax.device_put(self.state,
                                        named_shardings(state_specs, mesh))
            rep = NamedSharding(mesh, P())
            self.params = jax.device_put(
                self.params, jax.tree.map(lambda _: rep, self.params))
        elif self._cold:
            # cold-tier variants thread the page flag vector; attention
            # substitutes dequantized shadow rows for flagged pages
            self._decode_fn = jax.jit(
                lambda p, t, st, bt, sl, cf: decode_step_paged(
                    p, t, st, bt, sl, cfg, cold_flags=cf),
                donate_argnums=(2,),
            )
            self._chunk_fn = jax.jit(
                lambda p, t, st, bt, s0, cf: prefill_chunk_paged(
                    p, t, st, bt, s0, cfg, cold_flags=cf),
                donate_argnums=(2,),
            )
        else:
            self._decode_fn = jax.jit(
                lambda p, t, st, bt, sl: decode_step_paged(p, t, st, bt, sl, cfg),
                donate_argnums=(2,),
            )
            self._chunk_fn = jax.jit(
                lambda p, t, st, bt, s0: prefill_chunk_paged(p, t, st, bt, s0, cfg),
                donate_argnums=(2,),
            )
        self._prefill_fn = jax.jit(lambda p, t, st: prefill(p, t, cfg, st))
        self._write_pages = jax.jit(
            lambda pool, ids, v: paged_write_pages(pool, ids, jnp.squeeze(v, 1), n_stack=1),
            donate_argnums=(0,),
        )
        # COW fork: pools are layer-stacked (L, P, page, *f) -> copy one
        # physical page across every layer of every leaf
        self._copy_page_fn = jax.jit(
            lambda pool, src, dst: jax.tree.map(
                lambda leaf: leaf.at[:, dst].set(leaf[:, src]), pool),
            donate_argnums=(0,),
        )
        self._scatter = {}
        for key, ax in recurrent_slot_axes(cfg).items():
            axes_tree = jax.tree.map(lambda _: ax, self.state[key])
            self._scatter[key] = jax.jit(
                lambda full, vals, slot, _axes=axes_tree: slot_write(full, _axes, slot, vals),
                static_argnums=(2,), donate_argnums=(0,),
            )

        # stats (bounded: counters + a fixed-width latency window)
        self.prefill_tokens = 0          # prompt tokens actually computed
        self.prompt_tokens = 0           # prompt tokens admitted
        self.prefix_shared_tokens = 0    # prompt tokens served from the index
        self.decoded_tokens = 0
        self.decode_steps = 0
        self.requests_done = 0
        self.generated_total = 0
        self.cancelled = 0
        self.timed_out = 0
        self.shed = 0
        self.wall_s = 0.0
        self.step_times: Deque[float] = deque(maxlen=LATENCY_WINDOW)
        # per-request completion records (rid, tenant, TTFT, SLO-met, ...)
        # — what bench/runner.py aggregates into goodput/TTFT percentiles.
        # Bounded like the latency window: a long-lived engine keeps the
        # most recent LATENCY_WINDOW completions
        self.request_log: Deque[Dict] = deque(maxlen=LATENCY_WINDOW)
        self._arrive_wall: Dict[int, float] = {}   # rid -> submit wall time
        self._first_tok_wall: Dict[int, float] = {}
        self.last_statuses: Dict[int, str] = {}
        # completions drained from the scheduler but not yet handed to a
        # consumer — survives an abandoned serve() generator (several
        # requests can finish in one step; closing the generator between
        # their yields must not lose the rest)
        self._undelivered: List[tuple] = []
        # requests handed to serve() but not yet submitted to the
        # scheduler (future arrivals) — engine state, not generator
        # state, for the same reason: an abandoned or never-iterated
        # generator must not lose them
        self._backlog: List[Request] = []
        # the engine-step clock arrivals and deadlines are measured
        # against; persists across an abandoned generator (a recovery
        # must not restart deadlines) and resets per fresh trace
        self._clock = 0

    # -------------------------------------------------------------- load --
    @classmethod
    def from_checkpoint(cls, cfg: ModelConfig, ckpt_dir: str,
                        pcfg: PagedCacheConfig, *,
                        rank: Optional[int] = None,
                        step: Optional[int] = None,
                        **kw) -> "ServingEngine":
        """Build an engine straight from a training checkpoint directory.

        ``rank`` shrinks (or grows) every spectral group to that rank at
        load time via the checkpoint manager's resize-on-restore path —
        the cheap-serving story: a run trained at rank 128 serves from
        the same snapshot at rank 64 with ~2x smaller spectral factors,
        keeping the top-64 singular directions (Eckart–Young optimal for
        the represented weights). The Adam moments in the checkpoint are
        dropped; only ``params`` board the engine. Composes with
        ``quantize="int8"`` (shrink first, then quantize).
        """
        _, params = params_from_checkpoint(ckpt_dir, rank=rank, step=step)
        return cls(cfg, params, pcfg, **kw)

    # --------------------------------------------------------------- run --
    def serve(self, requests: Sequence[Request]):
        """Generator form of the serving loop: drives the trace one
        engine step per iteration and yields ``(rid, tokens, status)``
        as each request finishes (status ``finished`` / ``cancelled`` /
        ``timeout``; tokens are the int32 generated ids, partial for
        evicted requests). ``Request.arrival`` staggers enqueueing in
        engine-step time (a request is invisible to the scheduler before
        its arrival step). Results are drained from the scheduler every
        step, so neither side accumulates state across requests; per-rid
        outcomes also land in :attr:`last_statuses`. :meth:`run` is the
        collect-everything wrapper; ``api.Server.stream`` is the
        incremental consumer.

        Abandonment-safe: the request backlog and drained-but-unyielded
        completions live on the engine, so a generator dropped mid-trace
        (or never iterated) strands nothing — a later ``serve(())`` /
        :meth:`run` picks up exactly where it left off (see
        :attr:`has_pending_work`)."""
        # registration happens eagerly, NOT inside the generator body: a
        # never-iterated generator must still have handed its requests
        # to the engine. Checked before the merge: a fresh trace restarts
        # engine-step time, while any leftover work — backlog included —
        # keeps the clock so arrivals/deadlines retain their meaning.
        if not self.has_pending_work:
            self._clock = 0
        self._backlog = sorted(self._backlog + list(requests),
                               key=lambda r: r.arrival)
        return self._serve_loop()

    def _deliver(self):
        """Yield undelivered completions, popping before the yield (a
        consumer that bails mid-delivery never sees one twice) and
        re-recording the per-rid outcome (a stranded completion's
        status must survive the reset a recovery run starts with)."""
        while self._undelivered:
            rid, tokens, status = self._undelivered.pop(0)
            self.last_statuses[rid] = status
            yield (rid, tokens, status)

    def _serve_loop(self):
        self.last_statuses = {}
        t0 = time.time()
        last_decode_t = None
        try:
            # completions stranded by a previously abandoned generator
            # are delivered first
            yield from self._deliver()
            while self._backlog or self.sched.has_work:
                while self._backlog and self._backlog[0].arrival <= self._clock:
                    req = self._backlog.pop(0)
                    self._arrive_wall[req.rid] = time.time()
                    # submit-time clock anchors the relative deadline: a
                    # reused engine's clock never reset, and the request
                    # must not inherit steps it was never alive for
                    self.sched.submit(req, now=self._clock)
                self.sched.expire_deadlines(self._clock)
                for seq in self.sched.admit():
                    self.prompt_tokens += seq.request.prompt_len
                    self.prefix_shared_tokens += seq.shared_len
                self._prefill_step()
                if any(s.status == "decoding" for s in self.sched.active.values()):
                    self._decode_once()
                    # inter-token latency = gap between consecutive decode
                    # completions, so prefill stalls *between* decode steps
                    # (what chunked prefill exists to bound) count against
                    # the tail; the first decode of a run is TTFT, not ITL
                    now = time.time()
                    if last_decode_t is not None:
                        self.step_times.append(now - last_decode_t)
                    last_decode_t = now
                self._undelivered.extend(
                    (seq.request.rid,
                     np.asarray(seq.generated, dtype=np.int32),
                     seq.status)
                    for seq in self._drain())
                yield from self._deliver()
                self._clock += 1
            jax.block_until_ready(jax.tree.leaves(self.state)[0])
        finally:
            # wall clock closes even when the consumer abandons the
            # generator mid-trace (stats stay meaningful either way)
            self.wall_s += time.time() - t0

    def run(self, requests: Sequence[Request]) -> Dict[int, np.ndarray]:
        """Serve a trace to completion: rid -> generated token ids
        (first token from prefill, rest from decode). The batch wrapper
        over :meth:`serve`."""
        return {rid: tokens for rid, tokens, _ in self.serve(requests)}

    @property
    def peak_pages(self) -> int:
        """Max pool pages ever resident at once. Read from the pool's
        own allocation-site high-water mark, so pages allocated and
        released *within* one engine step (COW forks, a decode-time
        boundary page on a sequence that finishes the same step) count —
        a per-step poll of ``allocated_count`` missed those."""
        return self.sched.pool.peak_allocated

    @property
    def has_pending_work(self) -> bool:
        """True while a fresh :meth:`serve` call with no new requests
        can still produce completions: a future-arrival backlog,
        in-flight scheduler work, or results drained but not yet
        delivered (an abandoned generator)."""
        return (bool(self._undelivered) or bool(self._backlog)
                or self.sched.has_work)

    def known_rids(self) -> set:
        """Every rid the runtime currently owns — backlog, queued,
        active, or finished-but-undelivered. Results key on rid, so
        admitting a duplicate would silently cross-wire two requests;
        submitters check here."""
        rids = {r.rid for r in self._backlog}
        rids.update(r.rid for r in self.sched.waiting)
        rids.update(seq.request.rid for seq in self.sched.active.values())
        rids.update(rid for rid, _, _ in self._undelivered)
        return rids

    def cancel(self, rid: int) -> bool:
        """Cancel a request mid-flight (queue or active). Partial
        results surface on the next drain with status ``cancelled``."""
        return self.sched.cancel(rid)

    def _drain(self) -> List[SeqState]:
        drained = self.sched.drain_finished()
        for seq in drained:
            self.last_statuses[seq.request.rid] = seq.status
            self.requests_done += 1
            self.generated_total += len(seq.generated)
            if seq.status == "cancelled":
                self.cancelled += 1
            elif seq.status == "timeout":
                self.timed_out += 1
            elif seq.status == "shed":
                self.shed += 1
            self.request_log.append(self._record(seq))
        return drained

    def _record(self, seq: SeqState) -> Dict:
        """One completion record for :attr:`request_log`: identity,
        outcome, clock-domain latencies (deterministic: engine steps),
        wall-clock TTFT, and the SLO verdict. ``slo_met`` is True only
        for requests that finished inside their deadline — deadline
        eviction makes finishing imply that, but the record states it
        explicitly so consumers needn't know the eviction contract."""
        req = seq.request
        arrive_wall = self._arrive_wall.pop(req.rid, None)
        first_wall = self._first_tok_wall.pop(req.rid, None)
        finish = self._clock
        # clock-domain latencies measure from the deadline anchor
        # (submit-time clock, == arrival on any fresh trace) so engine
        # reuse cannot charge a request for steps before it existed
        anchor = req.deadline_anchor
        return {
            "rid": req.rid,
            "tenant": req.tenant,
            "priority": req.priority,
            "status": seq.status,
            "arrival": req.arrival,
            "deadline": req.deadline,
            "admit_clock": seq.admit_clock,
            "first_token_clock": seq.first_token_clock,
            "finish_clock": finish,
            "ttft_steps": (seq.first_token_clock - anchor
                           if seq.first_token_clock is not None else None),
            "ttft_s": (first_wall - arrive_wall
                       if first_wall is not None and arrive_wall is not None
                       else None),
            "prompt_tokens": req.prompt_len,
            "new_tokens": len(seq.generated),
            "slo_met": (seq.status == "finished"
                        and (req.deadline is None
                             or finish - anchor <= req.deadline)),
        }

    # ------------------------------------------------------------- steps --
    def _prefill_step(self) -> None:
        """Advance every prefilling sequence, FIFO, under the per-step
        chunk budget (when chunking; otherwise each tail runs whole).
        The first chunk of a step always runs — progress guarantee."""
        budget = self.prefill_chunk if self.chunked_prefill else None
        # streaming caps every chunk at a window of tokens: eviction can
        # then always make room, and each chunk advances by at least a
        # page (termination under arbitrarily long prompts)
        cap = (self.streaming.window_pages * self.pcfg.page_size
               if self.streaming is not None else None)
        spent = 0
        for seq in self.sched.prefilling():
            if not self._offset_prefill:
                self._prefill_full(seq)
                continue
            plen = seq.request.prompt_len
            logits = None
            while seq.prefill_pos < plen:
                remaining = plen - seq.prefill_pos
                c = remaining if budget is None else min(remaining, max(1, budget - spent))
                if cap is not None:
                    c = min(c, cap)
                if budget is not None and spent > 0 and spent + c > budget:
                    return                       # budget exhausted; resume next step
                if self.streaming is not None:
                    self.sched.stream_prepare_chunk(seq.slot, c)
                    self._stream_demote(seq.slot)
                logits = self._run_chunk(seq, c)
                spent += c
            self._complete_prefill(seq, logits)
            if budget is not None and spent >= budget:
                return

    def _run_chunk(self, seq: SeqState, c: int):
        req = seq.request
        toks = jnp.asarray(req.prompt[seq.prefill_pos:seq.prefill_pos + c],
                           dtype=jnp.int32)[None]
        bt = jnp.asarray(self.sched.block_table[seq.slot:seq.slot + 1])
        # cache-slot-relative start: evicted history no longer occupies
        # cache positions, so the chunk writes (and RoPE-rotates) at its
        # resident offset — the StreamingLLM position contract
        start = jnp.int32(seq.prefill_pos - seq.evicted_tokens)
        if self._cold:
            logits, self.state = self._chunk_fn(self.params, toks, self.state,
                                                bt, start, self._cold_flags())
        else:
            logits, self.state = self._chunk_fn(self.params, toks, self.state,
                                                bt, start)
        seq.prefill_pos += c
        self.prefill_tokens += c
        return logits

    # --------------------------------------------------------- streaming --
    def _cold_flags(self):
        """Device copy of the per-page cold flags, rebuilt only when the
        host mirror changed (demotion or page free)."""
        if self._cold_dev is None:
            self._cold_dev = jnp.asarray(self._cold_np)
        return self._cold_dev

    def _on_pages_freed(self, pages) -> None:
        """PagePool.on_free hook: a freed page's shadow copy is stale —
        whatever sequence reuses the page starts hot."""
        if pages and self._cold_np[np.asarray(pages)].any():
            self._cold_np[np.asarray(pages)] = 0
            self._cold_dev = None

    def _stream_demote(self, slot: int) -> None:
        """Demote this slot's newly cold pages (resident, outside the
        window, unshared) into the int8 shadow pools."""
        if not self._cold:
            return
        for p in self.sched.stream_cold_pages(slot):
            if self._cold_np[p]:
                continue
            self.state = self._demote_fn(self.state, jnp.int32(p))
            self._cold_np[p] = 1
            self._cold_dev = None
            self.stream_demotions += 1
            self.cold_page_bytes += self._cold_bytes_per_page

    def score_nll(self, tokens) -> float:
        """Teacher-forced mean NLL of ``tokens`` under this engine's
        exact cache policy: the sequence prefills through the paged
        chunk path — evicting and demoting just as serving would — and
        each chunk's logits score its next-token targets. The
        perplexity-vs-eviction-policy bench sweep is built on this."""
        if not self._offset_prefill:
            raise NotImplementedError("score_nll needs the offset-prefill "
                                      "paged path")
        toks = np.asarray(tokens, dtype=np.int32)
        rid = max(self.known_rids(), default=-1) + 1
        self.sched.submit(Request(rid=rid, prompt=toks, max_new_tokens=1))
        seq = next((s for s in self.sched.admit() if s.request.rid == rid),
                   None)
        if seq is None:
            raise RuntimeError("score_nll: request was not admitted "
                               "(no free slot or pages)")
        cap = (self.streaming.window_pages * self.pcfg.page_size
               if self.streaming is not None else self.prefill_chunk)
        plen = seq.request.prompt_len
        total, count = 0.0, 0
        while seq.prefill_pos < plen:
            c = min(plen - seq.prefill_pos, cap)
            if self.streaming is not None:
                self.sched.stream_prepare_chunk(seq.slot, c)
                self._stream_demote(seq.slot)
            pos0 = seq.prefill_pos
            logits = self._run_chunk(seq, c)
            upto = min(c, plen - 1 - pos0)       # last token has no target
            if upto > 0:
                lg = jax.nn.log_softmax(
                    logits[0, :upto].astype(jnp.float32), axis=-1)
                tgt = jnp.asarray(toks[pos0 + 1:pos0 + 1 + upto],
                                  dtype=jnp.int32)
                total += float(-jnp.sum(
                    jnp.take_along_axis(lg, tgt[:, None], axis=1)))
                count += upto
        self.sched.cancel(rid)
        self.sched.drain_finished()
        return total / max(count, 1)

    def _complete_prefill(self, seq: SeqState, logits) -> None:
        tok = int(np.asarray(jnp.argmax(logits[0, -1])))
        self._next_input[seq.slot] = tok
        self._first_tok_wall.setdefault(seq.request.rid, time.time())
        self.sched.finish_prefill(seq.slot)
        self.sched.on_prefill_token(seq.slot, tok)

    def _prefill_full(self, seq: SeqState) -> None:
        """Recurrent-family prompt path: full-length prefill through a
        temporary static cache, scattered into pages / slot state."""
        req = seq.request
        tokens = jnp.asarray(req.prompt, dtype=jnp.int32)[None]
        tmp = init_decode_state(self.cfg, 1, req.prompt_len)
        logits, filled = self._prefill_fn(self.params, tokens, tmp)
        page_ids = jnp.asarray(np.asarray(seq.pages, dtype=np.int32))
        for key in ATTN_STATE_KEYS:
            if key in self.state:
                self.state[key] = jax.tree.map(
                    lambda pool, v: self._write_pages(pool, page_ids, v),
                    self.state[key], filled[key])
        for key, scatter in self._scatter.items():
            self.state[key] = scatter(self.state[key], filled[key], seq.slot)
        seq.prefill_pos = req.prompt_len
        self.prefill_tokens += req.prompt_len
        self._complete_prefill(seq, logits)

    def _decode_once(self) -> None:
        if self.streaming is not None:
            # window maintenance first: eviction may shrink seq_len, so
            # it must precede the append-capacity check that reasons
            # about the next token's page
            for slot, seq in list(self.sched.active.items()):
                if seq.status == "decoding":
                    self.sched.stream_maintain(slot, 1)
                    self._stream_demote(slot)
        for _, src, dst in self.sched.ensure_append_capacity():
            # copy-on-write fork: duplicate the shared page before the
            # batched append may write it (unreachable under full-page
            # sharing, but the semantics are complete and fuzz-tested)
            for key in ATTN_STATE_KEYS:
                if key in self.state:
                    self.state[key] = self._copy_page_fn(
                        self.state[key], jnp.int32(src), jnp.int32(dst))
        bt_np, sl_np = self.sched.decode_view()
        bt = jnp.asarray(bt_np)
        sl = jnp.asarray(sl_np)
        toks = jnp.asarray(self._next_input)[:, None]
        if self._cold:
            logits, self.state = self._decode_fn(self.params, toks, self.state,
                                                 bt, sl, self._cold_flags())
        else:
            logits, self.state = self._decode_fn(self.params, toks, self.state,
                                                 bt, sl)
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1)).astype(np.int32)
        decoding = [s for s, seq in self.sched.active.items()
                    if seq.status == "decoding"]
        for slot in decoding:
            tok = int(nxt[slot])
            self._next_input[slot] = tok
            self.sched.on_token(slot, tok)
        self.decode_steps += 1
        self.decoded_tokens += len(decoding)

    # ------------------------------------------------------------- stats --
    def attn_cache_bytes(self) -> int:
        """Bytes held by the paged attention pools (the memory the
        static (batch, max_seq) layout pins at worst case instead)."""
        total = 0
        for key in ATTN_STATE_KEYS:
            if key in self.state:
                total += sum(leaf.size * leaf.dtype.itemsize
                             for leaf in jax.tree.leaves(self.state[key]))
        return total

    def latency_percentiles(self) -> Dict[str, float]:
        """p50/p99 inter-token latency (seconds) over the sliding window
        of gaps between consecutive decode-step completions — prefill
        work scheduled between decode steps shows up in the tail."""
        if not self.step_times:
            return {"itl_p50_s": 0.0, "itl_p99_s": 0.0}
        arr = np.asarray(self.step_times)
        return {"itl_p50_s": float(np.percentile(arr, 50)),
                "itl_p99_s": float(np.percentile(arr, 99))}

    def stats(self) -> Dict[str, float]:
        gen = self.generated_total
        out = {
            "requests": float(self.requests_done),
            "cancelled": float(self.cancelled),
            "timed_out": float(self.timed_out),
            "shed": float(self.shed),
            "peak_pages": float(self.peak_pages),
            "prefill_tokens": float(self.prefill_tokens),
            "prompt_tokens": float(self.prompt_tokens),
            "prefix_shared_tokens": float(self.prefix_shared_tokens),
            "generated_tokens": float(gen),
            "decode_steps": float(self.decode_steps),
            "cow_forks": float(self.sched.cow_forks),
            "wall_s": self.wall_s,
            "tokens_per_s": (self.prefill_tokens + gen) / self.wall_s if self.wall_s else 0.0,
            "attn_cache_bytes": float(self.attn_cache_bytes()),
            "weight_bytes": float(self.weight_bytes),
            "weight_bytes_fp": float(self.weight_bytes_fp),
        }
        out.update(self.latency_percentiles())
        if self.streaming is not None:
            out["stream_evictions"] = float(self.sched.stream_evictions)
            out["stream_demotions"] = float(self.stream_demotions)
            out["cold_page_bytes"] = float(self.cold_page_bytes)
        if self.sched.prefix_cache is not None:
            out.update({k: float(v)
                        for k, v in self.sched.prefix_cache.stats().items()})
        if isinstance(self.sched, SLOScheduler):
            out.update({k: float(v) for k, v in self.sched.stats().items()})
        return out
