"""Serving runtime: paged KV cache + continuous batching.

``engine`` is imported lazily: models/decode.py imports the paged-cache
ops from this package, and the engine imports models — eager re-export
here would close that cycle.
"""
from repro.serving.paged_cache import (
    PagedCacheConfig,
    PagePool,
    copy_page,
    paged_append,
    paged_gather,
    paged_write_pages,
    paged_write_slice,
    slot_read,
    slot_write,
)
from repro.serving.quantize import (
    dequantize_int8,
    dequantize_tree,
    is_quantized,
    is_quantized_spectral,
    param_bytes,
    quantize_int8,
    quantize_tree,
)
from repro.serving.scheduler import (
    ContinuousBatchingScheduler,
    PrefixCache,
    Request,
    SLOScheduler,
)
from repro.serving.streaming import (
    StreamingConfig,
    identity_horizon,
    resident_cap,
    windowed_reservation,
)

__all__ = [
    "quantize_int8",
    "dequantize_int8",
    "quantize_tree",
    "dequantize_tree",
    "is_quantized",
    "is_quantized_spectral",
    "param_bytes",
    "PagedCacheConfig",
    "PagePool",
    "PrefixCache",
    "copy_page",
    "paged_append",
    "paged_gather",
    "paged_write_pages",
    "paged_write_slice",
    "slot_read",
    "slot_write",
    "ContinuousBatchingScheduler",
    "SLOScheduler",
    "Request",
    "StreamingConfig",
    "identity_horizon",
    "resident_cap",
    "windowed_reservation",
    "ServingEngine",
    "SpeculativeEngine",
]


def __getattr__(name):
    if name == "ServingEngine":
        from repro.serving.engine import ServingEngine
        return ServingEngine
    if name == "SpeculativeEngine":
        from repro.serving.speculative import SpeculativeEngine
        return SpeculativeEngine
    raise AttributeError(name)
