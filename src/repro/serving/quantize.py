"""Int8 per-channel post-training quantization of serving weights.

SCT's spectral factors are ideal int8 targets: U and V have orthonormal
columns (every column has unit norm and entries O(1/sqrt(m))), so a
per-column symmetric scale loses ~0.2-0.4% relative — while the k
singular values in ``s``, which carry the entire dynamic range of the
layer, stay fp32 at negligible cost (k floats). Dense projections
quantize per output channel. Embeddings / LM head stay fp32: the tied
head computes the logits whose argmax greedy decoding compares, the one
place quantization noise turns into token flips.

A quantized tensor is the dict ``{"q8": int8, "scale": fp32}`` with the
scale indexed by the last (channel) axis; a quantized spectral group
keeps its {"U","s","V"} shape with U/V replaced by quantized tensors, so
the pytree routes through jit/engine code unchanged. On the Pallas path
(``kernels/ops.spectral_matmul_q8``) the int8 factors feed the fused
kernel *directly* — per-column scales commute with the matmuls, so
``u_scale * s * v_scale`` collapse into one k-length gain and the
dequantized fp factor is never materialized. The non-Pallas fallback
(``nn/linear.py``) dequantizes on the fly: int8 is what lives in HBM,
the fp copy a per-call transient.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.spectral import SPECTRAL_KEYS, is_spectral

# Subtrees never quantized (keyed by name in the parameter tree):
#   embed   — the tied LM head computes the logits greedy decoding argmaxes;
#   moe     — routers and expert banks are consumed by raw einsums in
#             nn/moe.py, not through apply_linear's quantized dispatch;
#   wukv    — the MLA up-projection is split raw by _split_wukv for the
#             absorbed decode path (and is already a low-rank factor);
#   enc_pos / dec_pos — encdec positional tables are sliced raw
#             (models/encdec.py ``params["dec_pos"]["w"][:s]``).
SKIP_KEYS = ("embed", "moe", "wukv", "enc_pos", "dec_pos")


def quantize_int8(w: jax.Array) -> dict:
    """Symmetric per-channel int8: channels = last axis, amax taken over
    axis -2 (the m/in axis for (..., m, k) factors and (..., in, out)
    dense weights — the one layout every quantized leaf uses, matching
    dequantize_int8's broadcast). Leading stacked layer axes broadcast."""
    wf = jnp.asarray(w, jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=-2)                     # (..., channels)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(wf / jnp.expand_dims(scale, -2)), -127, 127)
    return {"q8": q.astype(jnp.int8), "scale": scale.astype(jnp.float32)}


def dequantize_int8(qt: dict, dtype: Any = jnp.float32) -> jax.Array:
    """Inverse of :func:`quantize_int8`: ``{"q8": int8 (..., m, c),
    "scale": f32 (..., c)}`` -> float ``(..., m, c)`` with the scale
    broadcast over the -2 axis. This is the transient apply-time
    expansion of the non-Pallas fallback (nn/linear.py) and the
    ``--verify`` oracle (dequantize_tree) — the fused Pallas kernel
    (kernels/ops.spectral_matmul_q8) never calls it: int8 factors go
    straight into the MXU with the scales folded into the bottleneck
    gain."""
    return (qt["q8"].astype(jnp.float32)
            * jnp.expand_dims(qt["scale"], -2)).astype(dtype)


def is_quantized(x: Any) -> bool:
    """Structural check for one quantized tensor: a dict carrying
    ``q8``/``scale``. Tree walkers (apply dispatch, param_bytes,
    dequantize_tree) key on this the way core code keys on
    ``is_spectral`` — by shape of the pytree, not by type."""
    return isinstance(x, dict) and "q8" in x and "scale" in x


def is_quantized_spectral(p: Any) -> bool:
    """A spectral group whose ``U (m, k)`` / ``V (n, k)`` were replaced
    by quantized tensors while ``s (k,)`` stayed float (the k singular
    values carry the layer's whole dynamic range at negligible cost).
    nn/linear.py routes such groups to the q8 spectral matmul."""
    return (
        isinstance(p, dict)
        and set(p.keys()) >= set(SPECTRAL_KEYS)
        and is_quantized(p["U"])
        and is_quantized(p["V"])
    )


def quantize_tree(params: Any, include_dense: bool = True) -> Any:
    """Walk a parameter tree: spectral groups get int8 U/V (s and bias
    stay fp32); dense 2D+ ``w`` leaves get per-output-channel int8 when
    ``include_dense``; everything else (norms, biases, SKIP_KEYS
    subtrees) passes through untouched."""

    def walk(tree):
        if is_spectral(tree):
            out = dict(tree)
            out["U"] = quantize_int8(tree["U"])
            out["V"] = quantize_int8(tree["V"])
            return out
        if isinstance(tree, dict):
            out = {}
            for key, val in tree.items():
                if key in SKIP_KEYS:
                    out[key] = val
                elif (include_dense and key == "w"
                      and hasattr(val, "ndim") and val.ndim >= 2):
                    out[key] = quantize_int8(val)
                else:
                    out[key] = walk(val)
            return out
        if isinstance(tree, (list, tuple)):
            return type(tree)(walk(v) for v in tree)
        return tree

    return walk(params)


def dequantize_tree(params: Any, dtype: Any = jnp.float32) -> Any:
    """Materialize every quantized tensor back to floating point — the
    fp32 oracle for ``--verify`` (the on-the-fly dequant runtime path
    must match this token-for-token under greedy decoding)."""

    def walk(tree):
        if is_quantized(tree):
            return dequantize_int8(tree, dtype)
        if isinstance(tree, dict):
            return {k: walk(v) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            return type(tree)(walk(v) for v in tree)
        return tree

    return walk(params)


def quantize_kv_pages(vals: jax.Array, token_axis: int = 1) -> dict:
    """Page-granular symmetric int8 for cold KV pages (the streaming
    subsystem's cold-tier codec). ``vals`` is one page's worth of cache
    rows with ``token_axis`` the page_size axis — per-layer GQA pages
    are ``(L, page, kvh, hd)``, MLA latent pages ``(L, page, lat)`` —
    and the amax is taken over tokens so every remaining (layer, head,
    feature) channel keeps its own scale. Unlike the weight codec above
    the channel axis here is *everything but* the token axis: KV rows
    have per-head/per-feature dynamic range, not per-column."""
    wf = jnp.asarray(vals, jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=token_axis)         # (..., channels...)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(wf / jnp.expand_dims(scale, token_axis)),
                 -127, 127)
    return {"q8": q.astype(jnp.int8), "scale": scale.astype(jnp.float32)}


def dequantize_kv_pages(qt: dict, token_axis: int = 1,
                        dtype: Any = jnp.float32) -> jax.Array:
    """Inverse of :func:`quantize_kv_pages`: broadcast the per-channel
    scale back over the token axis. The transparent dequant-on-attend
    expansion for cold pages in the paged gather path."""
    return (qt["q8"].astype(jnp.float32)
            * jnp.expand_dims(qt["scale"], token_axis)).astype(dtype)


def param_bytes(params: Any) -> int:
    """Bytes held by a parameter tree (int8 leaves count 1 byte/elem —
    the serving weight-memory figure bench_serving reports)."""
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree.leaves(params)
               if hasattr(leaf, "dtype"))
