"""Continuous-batching scheduler: a FIFO request queue feeding a fixed
set of decode slots, with refcounted page-pool accounting, an optional
shared-prefix index, chunked prefill, and cancellation/deadlines.

Policy (host-side, cheap — the device only ever sees static shapes):

  * **admission** — strictly FIFO: the head request is admitted when a
    slot is free, its worst-case page need fits the *unreserved* pool,
    and the per-step prefill token budget allows it. Later requests
    never jump the head (no starvation under a full queue).
  * **reservation** — pages for ``prompt + max_new_tokens`` are reserved
    at admission (in full, even when a prefix is shared — the
    conservative bound under which an admitted sequence can never hit
    pool OOM mid-flight) but allocated lazily as the sequence crosses
    page boundaries. Pages held only by the prefix index are evictable
    on demand, so reservations stay honourable with a warm cache.
  * **prefix sharing** — at admission the prompt's page-aligned chunks
    are looked up in the :class:`PrefixCache`; matched pages are mapped
    into the block table via ``PagePool.share`` and only the tail is
    prefilled. At least one tail token always remains (prefill must
    produce next-token logits). A completed prefill inserts its full
    prompt pages back into the index.
  * **chunked prefill** — a sequence is admitted in ``prefilling``
    status with ``prefill_pos`` tracking cached tokens; the engine
    advances it in budget-sized chunks interleaved with decode steps
    and calls :meth:`finish_prefill` when the prompt is fully cached.
    Prefilling slots are invisible to the decode step
    (:meth:`decode_view` nulls their block-table rows).
  * **copy-on-write** — :meth:`ensure_append_capacity` forks any page a
    decode append would write while its refcount is > 1 (fresh page +
    device copy, reported to the engine). Under the full-page-sharing
    policy appends never actually target shared pages — the fork path
    is the safety net that makes that a checked invariant rather than
    an assumption.
  * **eviction** — finished sequences (max_new reached, EOS, a
    ``cancel`` call, or a blown deadline) free their slot, release
    their pages, and land in the per-step drain list — the caller
    collects them via :meth:`drain_finished` every step, so nothing
    accumulates in the scheduler under continuous traffic.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.paged_cache import PagedCacheConfig, PagePool
from repro.serving.streaming import (
    StreamingConfig,
    cold_page_indices,
    evictions_needed,
    resident_cap,
    validate_geometry,
    windowed_reservation,
)


@dataclasses.dataclass
class Request:
    """One serving request: ``prompt`` is a 1-D int32 token array of
    shape ``(prompt_len,)``; generation runs until ``max_new_tokens``
    (or ``eos_id``, when set). ``arrival`` is the engine step at which
    the request becomes visible to the scheduler — traces with
    staggered arrivals exercise mid-flight slot joins. ``deadline``
    (engine steps after arrival) bounds total service time: a request
    still unfinished when it expires is evicted with status
    ``"timeout"`` and whatever tokens it produced. ``rid`` keys the
    result dict ``ServingEngine.run`` returns.

    ``tenant`` and ``priority`` are scheduling metadata the
    :class:`SLOScheduler` consumes (per-tenant fair share; priority
    class 0 is the most urgent) — the FIFO scheduler carries them
    through untouched.

    ``submit_clock`` is stamped by the scheduler when the request is
    actually handed over (:meth:`ContinuousBatchingScheduler.submit`),
    and relative deadlines are measured from
    :attr:`deadline_anchor` = ``max(arrival, submit_clock)`` — on a
    reused engine whose step clock never reset, a fresh request with
    ``arrival=0`` must not inherit steps it was never alive for."""
    rid: int
    prompt: np.ndarray                 # (prompt_len,) int32 token ids
    max_new_tokens: int
    arrival: int = 0                   # engine step at which it enters the queue
    eos_id: Optional[int] = None
    deadline: Optional[int] = None     # max engine steps after deadline_anchor
    tenant: str = "default"
    priority: int = 0                  # 0 = most urgent class
    submit_clock: Optional[int] = None  # engine step of scheduler hand-over

    @property
    def deadline_anchor(self) -> int:
        """The step relative deadlines count from: submit time, never
        earlier than the declared arrival (a future-arrival request's
        deadline still starts at its arrival)."""
        if self.submit_clock is None:
            return self.arrival
        return max(self.arrival, self.submit_clock)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def max_total_len(self) -> int:
        return self.prompt_len + self.max_new_tokens


@dataclasses.dataclass
class SeqState:
    request: Request
    slot: int
    seq_len: int                       # tokens whose KV/state is cached
    pages: List[int]                   # mapped physical pages, logical order
    reserved_pages: int                # worst-case commitment at admission
    shared_len: int = 0                # prefix tokens mapped from the cache
    prefill_pos: int = 0               # prompt tokens cached so far
    status: str = "prefilling"         # prefilling|decoding|finished|cancelled|
                                       # timeout|shed
    generated: List[int] = dataclasses.field(default_factory=list)
    admit_clock: Optional[int] = None  # engine step of admission
    first_token_clock: Optional[int] = None  # engine step of the first token
    evicted_tokens: int = 0            # tokens dropped by streaming eviction
    pinned: List[int] = dataclasses.field(default_factory=list)  # sink pages

    @property
    def finished(self) -> bool:
        if len(self.generated) >= self.request.max_new_tokens:
            return True
        eos = self.request.eos_id
        return eos is not None and len(self.generated) > 0 and self.generated[-1] == eos


@dataclasses.dataclass
class _PrefixEntry:
    page: int
    key: int
    parent: Optional[int]              # parent chain key (None at the root)
    tick: int
    children: set = dataclasses.field(default_factory=set)


class PrefixCache:
    """Index of page-aligned prompt chunks -> physical pages.

    Keys are a running hash chain over page-sized token chunks, so a
    lookup walks the chain from the root and stops at the first miss —
    only a *prefix* of full pages is ever matched. Entries hold one
    pool reference each (the cache keeps hot prefixes alive after their
    sequences finish); :meth:`evict` drops LRU leaf entries whose page
    nobody else references, so eviction never orphans a reachable chain
    or steals a page out from under a live sequence."""

    def __init__(self, pool: PagePool, page_size: int):
        self.pool = pool
        self.page_size = page_size
        self._entries: Dict[int, _PrefixEntry] = {}
        self._tick = 0
        self.hit_pages = 0
        self.lookup_pages = 0
        self.inserted_pages = 0
        self.evicted_pages = 0

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def pages(self) -> List[int]:
        return [e.page for e in self._entries.values()]

    def _chain_keys(self, prompt: np.ndarray, n_pages: int) -> List[int]:
        ps = self.page_size
        keys, h = [], 0
        chunks = np.asarray(prompt[: n_pages * ps], dtype=np.int32)
        for i in range(n_pages):
            h = hash((h, chunks[i * ps:(i + 1) * ps].tobytes()))
            keys.append(h)
        return keys

    def lookup(self, prompt: np.ndarray) -> List[int]:
        """Longest chain of cached pages covering a *proper* prefix of
        the prompt (at least one tail token is always left to prefill).
        Returns page ids in logical order; the caller maps them with
        ``pool.share``."""
        n = (len(prompt) - 1) // self.page_size
        self._tick += 1
        self.lookup_pages += n
        pages: List[int] = []
        for key in self._chain_keys(prompt, n):
            e = self._entries.get(key)
            if e is None:
                break
            e.tick = self._tick
            pages.append(e.page)
        self.hit_pages += len(pages)
        return pages

    def insert(self, prompt: np.ndarray, pages: Sequence[int]) -> None:
        """Register every *full* prompt page under its chain key. Pages
        already present (another sequence inserted the same chunk
        first) are skipped; new entries take a pool reference."""
        n = min(len(prompt) // self.page_size, len(pages))
        self._tick += 1
        parent: Optional[int] = None
        for i, key in enumerate(self._chain_keys(prompt, n)):
            e = self._entries.get(key)
            if e is None:
                self.pool.share([pages[i]])
                e = _PrefixEntry(page=int(pages[i]), key=key, parent=parent,
                                 tick=self._tick)
                self._entries[key] = e
                if parent is not None:
                    self._entries[parent].children.add(key)
                self.inserted_pages += 1
            else:
                e.tick = self._tick
            parent = key

    def evictable_count(self) -> int:
        return sum(1 for e in self._entries.values()
                   if not e.children and self.pool.refcount(e.page) == 1)

    def evict(self, n: int) -> int:
        """Drop up to ``n`` LRU leaf entries whose page only the cache
        holds (releasing frees them). Evicting a leaf may expose its
        parent as the next candidate. Returns pages actually freed."""
        freed = 0
        while freed < n:
            candidates = [e for e in self._entries.values()
                          if not e.children and self.pool.refcount(e.page) == 1]
            if not candidates:
                break
            victim = min(candidates, key=lambda e: e.tick)
            del self._entries[victim.key]
            if victim.parent is not None and victim.parent in self._entries:
                self._entries[victim.parent].children.discard(victim.key)
            self.pool.release([victim.page])
            self.evicted_pages += 1
            freed += 1
        return freed

    def stats(self) -> Dict[str, int]:
        return {
            "prefix_entries": len(self._entries),
            "prefix_lookup_pages": self.lookup_pages,
            "prefix_hit_pages": self.hit_pages,
            "prefix_inserted_pages": self.inserted_pages,
            "prefix_evicted_pages": self.evicted_pages,
        }


class ContinuousBatchingScheduler:
    """Owns slots, block tables, the page pool, and the prefix index.
    The engine calls, once per step: ``submit`` -> ``expire_deadlines``
    -> [``admit`` -> chunked prefill -> ``finish_prefill``]* ->
    ``ensure_append_capacity`` (returns COW forks) -> decode via
    ``decode_view`` -> ``on_token`` -> ``drain_finished``."""

    def __init__(self, pcfg: PagedCacheConfig,
                 prefill_token_budget: Optional[int] = None,
                 prefix_sharing: bool = False,
                 streaming: Optional[StreamingConfig] = None):
        self.pcfg = pcfg
        self.pool = PagePool(pcfg.num_pages)
        self.prefill_token_budget = prefill_token_budget
        self.streaming = streaming
        if streaming is not None:
            validate_geometry(streaming, pcfg)
        self.stream_evictions = 0      # pages evicted by the sliding window
        self.prefix_cache = (PrefixCache(self.pool, pcfg.page_size)
                             if prefix_sharing else None)
        self.waiting: Deque[Request] = deque()
        self.active: Dict[int, SeqState] = {}          # slot -> seq
        self._free_slots: List[int] = list(range(pcfg.max_slots - 1, -1, -1))
        self._reserved_total = 0
        self.block_table = np.full((pcfg.max_slots, pcfg.max_pages_per_seq),
                                   pcfg.null_page, dtype=np.int32)
        self.seq_lens = np.zeros((pcfg.max_slots,), dtype=np.int32)
        self._finished_step: List[SeqState] = []       # drained every step
        self.finished_count = 0
        self.cow_forks = 0
        self._now = 0                  # engine-step clock (expire_deadlines)

    # ------------------------------------------------------------- api --
    def submit(self, req: Request, now: Optional[int] = None) -> None:
        """Queue one request. ``now`` is the submitter's engine-step
        clock; it anchors the request's relative deadline (see
        :attr:`Request.deadline_anchor`). When omitted, the scheduler's
        own clock is used — an explicit ``submit_clock`` already on the
        request is respected either way."""
        if req.submit_clock is None:
            req.submit_clock = self._now if now is None else int(now)
        need = self._pages_needed(req.max_total_len)
        if need > self.pcfg.max_pages_per_seq:
            raise ValueError(
                f"request {req.rid}: {req.max_total_len} tokens exceed "
                f"max_pages_per_seq*page_size={self.pcfg.max_seq}")
        if need > self.pcfg.num_pages:
            raise ValueError(
                f"request {req.rid}: needs {need} pages, pool has {self.pcfg.num_pages}")
        self.waiting.append(req)

    def _pages_needed(self, max_total_len: int) -> int:
        """Worst-case page commitment for one request: the full
        ``prompt + max_new_tokens`` footprint, or — under streaming —
        the windowed resident cap, whichever is smaller. This is the
        whole admission story of the streaming subsystem: a 100k-token
        session reserves O(sink + window) pages."""
        if self.streaming is not None:
            return windowed_reservation(self.streaming, self.pcfg,
                                        max_total_len)
        return self.pcfg.pages_for(max_total_len)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.active)

    def _alloc(self, n: int) -> List[int]:
        """Pool alloc that reclaims prefix-cache-only pages on demand —
        reservations count cache-held pages as reclaimable."""
        short = n - self.pool.free_count
        if short > 0 and self.prefix_cache is not None:
            self.prefix_cache.evict(short)
        return self.pool.alloc(n)

    def _next_request(self) -> Optional[Request]:
        """The admission-policy hook: the next waiting request to try,
        or None to stop admitting this step. FIFO (this class) always
        answers the queue head — later requests never jump it. The
        :class:`SLOScheduler` overrides this with fair-share/priority/
        deadline selection (and sheds doomed requests as a side
        effect)."""
        return self.waiting[0] if self.waiting else None

    def _remove_waiting(self, req: Request) -> None:
        for i, r in enumerate(self.waiting):
            if r is req:
                del self.waiting[i]
                return
        raise AssertionError(f"request {req.rid} not in the waiting queue")

    def _on_admitted(self, seq: SeqState) -> None:
        """Post-admission hook (SLO fair-share accounting)."""

    def admit(self) -> List[SeqState]:
        """Admit from the queue while slot/pages/budget allow, in the
        order :meth:`_next_request` dictates (FIFO here). Returns newly
        admitted sequences in ``prefilling`` status, with any cached
        prefix already mapped (the engine prefills the tail from
        ``prefill_pos``). The selected request admits or blocks — when
        it doesn't fit, nothing behind it is admitted either, so big
        requests cannot be starved by small ones under any policy."""
        admitted: List[SeqState] = []
        budget = self.prefill_token_budget
        spent = 0
        while self.waiting and self._free_slots:
            req = self._next_request()
            if req is None:
                break
            need = self._pages_needed(req.max_total_len)
            if self._reserved_total + need > self.pcfg.num_pages:
                break                                   # selected waits; no queue-jumping
            shared = (self.prefix_cache.lookup(req.prompt)
                      if self.prefix_cache is not None else [])
            raw_hits = len(shared)
            if self.streaming is not None and len(shared) >= need:
                # a cached prefix longer than the resident cap cannot be
                # mapped (the block-table row is windowed); keep the
                # head — the part containing the pinned sinks
                shared = shared[:need - 1]
            shared_len = len(shared) * self.pcfg.page_size
            tail = req.prompt_len - shared_len
            if budget is not None and spent and spent + tail > budget:
                if self.prefix_cache is not None:
                    # the request wasn't admitted — it will be looked up
                    # again next step, so roll this probe back out of
                    # the hit-rate stats (the LRU touch is harmless)
                    n = (req.prompt_len - 1) // self.pcfg.page_size
                    self.prefix_cache.lookup_pages -= n
                    self.prefix_cache.hit_pages -= raw_hits
                break                                   # budget bounds each step, but
                                                        # never blocks the first admit
                                                        # (progress guarantee)
            self._remove_waiting(req)
            slot = self._free_slots.pop()
            self.pool.share(shared)
            init = min(self.pcfg.pages_for(req.prompt_len), need)
            fresh = self._alloc(init - len(shared))
            pages = list(shared) + fresh
            self._reserved_total += need
            seq = SeqState(request=req, slot=slot, seq_len=0,
                           pages=pages, reserved_pages=need,
                           shared_len=shared_len, prefill_pos=shared_len,
                           admit_clock=self._now)
            self.active[slot] = seq
            self.block_table[slot, :len(pages)] = pages
            self.seq_lens[slot] = 0                     # decode-invisible until
            spent += tail                               # finish_prefill
            self._pin_sinks(seq)
            admitted.append(seq)
            self._on_admitted(seq)
        return admitted

    def prefilling(self) -> List[SeqState]:
        """Active sequences with prompt tokens still to cache, in slot
        admission order (FIFO over the step)."""
        return [s for s in self.active.values() if s.status == "prefilling"]

    def finish_prefill(self, slot: int) -> None:
        """Prompt fully cached: the sequence joins the decode batch and
        its full prompt pages enter the prefix index. Under streaming
        only the *resident* tokens count toward ``seq_len`` (positions
        are cache-slot-relative), and after a mid-prefill eviction only
        the pinned sink prefix is inserted — the rest of the page list
        no longer corresponds to prompt positions."""
        seq = self.active[slot]
        assert seq.prefill_pos == seq.request.prompt_len
        seq.status = "decoding"
        seq.seq_len = seq.request.prompt_len - seq.evicted_tokens
        self.seq_lens[slot] = seq.seq_len
        if self.prefix_cache is not None:
            if seq.evicted_tokens:
                ps = self.pcfg.page_size
                n_sink = self.streaming.sink_pages
                self.prefix_cache.insert(seq.request.prompt[:n_sink * ps],
                                         seq.pages[:n_sink])
            else:
                self.prefix_cache.insert(seq.request.prompt, seq.pages)

    # ------------------------------------------------------ streaming --
    def _pin_sinks(self, seq: SeqState) -> None:
        """Pin any not-yet-pinned sink-region pages the sequence now
        holds (pages appear lazily, so pinning is incremental: at
        admission, after a prefill-chunk alloc, after a decode-boundary
        alloc). Pins are per-sequence and undone at eviction."""
        if self.streaming is None:
            return
        n = min(self.streaming.sink_pages, len(seq.pages))
        for p in seq.pages[len(seq.pinned):n]:
            self.pool.pin([p])
            seq.pinned.append(p)

    def stream_maintain(self, slot: int, extra_tokens: int) -> int:
        """Evict oldest non-sink pages until ``extra_tokens`` more can
        be appended within the resident cap: release each victim back
        to the pool, compact the block-table row left, and shrink the
        resident length by a page while ``evicted_tokens`` grows by the
        same amount. Returns pages evicted. The engine calls this
        before every decode append and between prefill chunks — the
        sliding-window half of the streaming policy."""
        if self.streaming is None:
            return 0
        seq = self.active[slot]
        resident = (seq.seq_len if seq.status == "decoding"
                    else seq.prefill_pos - seq.evicted_tokens)
        k = evictions_needed(self.streaming, self.pcfg, resident,
                             extra_tokens)
        for _ in range(k):
            self._stream_evict_one(seq)
        return k

    def _stream_evict_one(self, seq: SeqState) -> None:
        ps = self.pcfg.page_size
        n_sink = self.streaming.sink_pages
        assert len(seq.pages) > n_sink, (
            f"seq {seq.request.rid}: eviction would reach a sink page")
        victim = seq.pages.pop(n_sink)
        self.pool.release([victim])
        seq.evicted_tokens += ps
        if seq.status == "decoding":
            seq.seq_len -= ps
            self.seq_lens[seq.slot] = seq.seq_len
        self.block_table[seq.slot, :len(seq.pages)] = seq.pages
        self.block_table[seq.slot, len(seq.pages):] = self.pcfg.null_page
        self.stream_evictions += 1

    def stream_prepare_chunk(self, slot: int, chunk_tokens: int) -> None:
        """Prefill-side capacity: make room for (evicting as needed)
        and allocate every page the next ``chunk_tokens`` cache
        positions touch. The engine caps chunks at
        ``window_pages * page_size``, so eviction can always free
        enough room and each chunk makes at least a page of
        progress."""
        if self.streaming is None:
            return
        self.stream_maintain(slot, chunk_tokens)
        seq = self.active[slot]
        resident = seq.prefill_pos - seq.evicted_tokens
        last = (resident + chunk_tokens - 1) // self.pcfg.page_size
        while len(seq.pages) <= last:
            assert len(seq.pages) < seq.reserved_pages, (
                f"seq {seq.request.rid} outgrew its reservation")
            (page,) = self._alloc(1)
            seq.pages.append(page)
            self.block_table[slot, len(seq.pages) - 1] = page
        self._pin_sinks(seq)

    def stream_cold_pages(self, slot: int) -> List[int]:
        """Physical ids of this sequence's cold pages — resident, older
        than the window, not shared (demoting a page another sequence
        or the prefix index also maps would corrupt *their* hot view).
        The engine demotes these to the int8 shadow pool."""
        if self.streaming is None:
            return []
        seq = self.active[slot]
        return [seq.pages[i]
                for i in cold_page_indices(self.streaming, len(seq.pages))
                if self.pool.refcount(seq.pages[i]) == 1]

    def decode_view(self) -> Tuple[np.ndarray, np.ndarray]:
        """(block_table, seq_lens) as the decode step may see them:
        slots still prefilling are nulled so the batched append can't
        write into their half-filled pages."""
        bt = self.block_table.copy()
        sl = self.seq_lens.copy()
        for seq in self.active.values():
            if seq.status != "decoding":
                bt[seq.slot, :] = self.pcfg.null_page
                sl[seq.slot] = 0
        return bt, sl

    def ensure_append_capacity(self) -> List[Tuple[int, int, int]]:
        """Before a decode step: every decoding slot must own — with
        refcount 1 — the page its next token lands in. Boundary pages
        are allocated from the reservation; a shared target page is
        forked copy-on-write. Returns ``(slot, src_page, dst_page)``
        forks for the engine to copy device-side (empty under the
        full-page sharing policy — see class docstring)."""
        return self.ensure_burst_capacity(
            {slot: 1 for slot, seq in self.active.items()
             if seq.status == "decoding"})

    def ensure_burst_capacity(self, burst: Dict[int, int]
                              ) -> List[Tuple[int, int, int]]:
        """Generalized :meth:`ensure_append_capacity` for multi-token
        draft/verify bursts: each decoding slot in ``burst`` must own —
        with refcount 1 — every page covering the ``burst[slot]`` token
        positions ``[seq_len, seq_len + n)`` the burst will write.
        Missing pages are allocated from the reservation (the caller
        caps ``n`` at the sequence's remaining token budget, so the
        reservation always covers the burst); a shared page in the
        write range forks copy-on-write. Returns ``(slot, src, dst)``
        forks for the engine to copy device-side — in every ladder
        level's pool, for a speculative engine."""
        forks: List[Tuple[int, int, int]] = []
        ps = self.pcfg.page_size
        for slot, n in burst.items():
            seq = self.active[slot]
            if seq.status != "decoding" or n < 1:
                continue
            first = seq.seq_len // ps
            last = (seq.seq_len + n - 1) // ps
            for page_idx in range(first, last + 1):
                if page_idx >= len(seq.pages):
                    assert len(seq.pages) < seq.reserved_pages, (
                        f"seq {seq.request.rid} outgrew its reservation")
                    (page,) = self._alloc(1)
                    seq.pages.append(page)
                    self.block_table[slot, page_idx] = page
                elif self.pool.is_shared(seq.pages[page_idx]):
                    src = seq.pages[page_idx]
                    (dst,) = self._alloc(1)
                    if src in seq.pinned:
                        # forking a pinned (shared sink) page: move our
                        # pin to the private copy before releasing the
                        # reference the pin was counted against
                        self.pool.unpin([src])
                        self.pool.pin([dst])
                        seq.pinned[seq.pinned.index(src)] = dst
                    self.pool.release([src])
                    seq.pages[page_idx] = dst
                    self.block_table[slot, page_idx] = dst
                    self.cow_forks += 1
                    forks.append((slot, src, dst))
            self._pin_sinks(seq)
        return forks

    def on_token(self, slot: int, token: int) -> Optional[SeqState]:
        """Record one generated token for a slot (its KV was appended by
        the decode step). Returns the SeqState if the sequence finished
        (already evicted), else None."""
        seq = self.active[slot]
        seq.generated.append(int(token))
        seq.seq_len += 1
        self.seq_lens[slot] = seq.seq_len
        if seq.finished:
            self._evict(seq, "finished")
            return seq
        return None

    def on_prefill_token(self, slot: int, token: int) -> Optional[SeqState]:
        """Record the token produced by prefill (not yet in the cache —
        the next decode step appends it)."""
        seq = self.active[slot]
        if seq.first_token_clock is None:
            seq.first_token_clock = self._now
        seq.generated.append(int(token))
        if seq.finished:                                 # max_new_tokens == 1
            self._evict(seq, "finished")
            return seq
        return None

    # -------------------------------------------- cancel / deadlines --
    def cancel(self, rid: int, status: str = "cancelled") -> bool:
        """Cancel a request wherever it is: drop it from the queue, or
        evict its sequence with partial results. The cancelled request
        still surfaces through :meth:`drain_finished` (with ``status``
        set) so callers see every submitted rid exactly once."""
        for req in self.waiting:
            if req.rid == rid:
                self.waiting.remove(req)
                seq = SeqState(request=req, slot=-1, seq_len=0, pages=[],
                               reserved_pages=0, status=status)
                self._finished_step.append(seq)
                self.finished_count += 1
                return True
        for seq in list(self.active.values()):
            if seq.request.rid == rid:
                self._evict(seq, status)
                return True
        return False

    def expire_deadlines(self, clock: int) -> int:
        """Evict every request whose deadline (engine steps since its
        :attr:`Request.deadline_anchor` — submit time, not raw arrival,
        so engine reuse cannot dilate a relative deadline) has passed —
        waiting or active. Called once per engine step with the current
        clock. Returns the number expired; the sequences themselves
        surface through :meth:`drain_finished` with status
        ``"timeout"``. Also advances the scheduler's notion of *now* —
        the clock admission policies (SLO shedding, ``admit_clock``)
        reason against."""
        self._now = clock
        expired = [r.rid for r in list(self.waiting)
                   if r.deadline is not None
                   and clock - r.deadline_anchor >= r.deadline]
        expired += [s.request.rid for s in list(self.active.values())
                    if s.request.deadline is not None
                    and clock - s.request.deadline_anchor >= s.request.deadline]
        for rid in expired:
            self.cancel(rid, status="timeout")
        return len(expired)

    def drain_finished(self) -> List[SeqState]:
        """Hand completed/cancelled sequences to the caller and forget
        them — the per-step drain that keeps scheduler memory bounded
        under continuous traffic."""
        out, self._finished_step = self._finished_step, []
        return out

    # -------------------------------------------------------- internal --
    def _evict(self, seq: SeqState, status: str) -> None:
        del self.active[seq.slot]
        if seq.pinned:
            self.pool.unpin(seq.pinned)
            seq.pinned = []
        self.pool.release(seq.pages)
        self._reserved_total -= seq.reserved_pages
        self.block_table[seq.slot, :] = self.pcfg.null_page
        self.seq_lens[seq.slot] = 0
        self._free_slots.append(seq.slot)
        seq.status = status
        self._finished_step.append(seq)
        self.finished_count += 1

    # ------------------------------------------------------ invariants --
    def check_invariants(self) -> None:
        """Cheap structural invariants, asserted by tests after every
        step: slots partition exactly, refcounts account for every
        holder, pages never leak, reservations stay honourable."""
        assert len(self.active) + len(self._free_slots) == self.pcfg.max_slots
        assert set(self.active) | set(self._free_slots) == set(range(self.pcfg.max_slots))
        holders: Dict[int, int] = {}
        for s in self.active.values():
            for p in s.pages:
                holders[p] = holders.get(p, 0) + 1
        cache_pages = set(self.prefix_cache.pages) if self.prefix_cache else set()
        for p in cache_pages:
            holders[p] = holders.get(p, 0) + 1
        # every reference accounted for: refcount == seq holders + index
        for p, n in holders.items():
            assert self.pool.refcount(p) == n, \
                f"page {p}: refcount {self.pool.refcount(p)} != holders {n}"
        assert len(holders) == self.pool.allocated_count, "page leak"
        assert self.pool.free_count + self.pool.allocated_count == self.pcfg.num_pages
        assert self._reserved_total <= self.pcfg.num_pages
        # reservations stay honourable: free + cache-evictable pages
        # cover every sequence's remaining worst-case growth
        remaining = sum(s.reserved_pages - len(s.pages) for s in self.active.values())
        evictable = (self.prefix_cache.evictable_count() if self.prefix_cache else 0)
        assert self.pool.free_count + evictable >= remaining, (
            f"reservation not honourable: free {self.pool.free_count} + "
            f"evictable {evictable} < remaining {remaining}")
        for seq in self.active.values():
            assert len(seq.pages) <= seq.reserved_pages
            assert seq.reserved_pages - len(seq.pages) >= 0
            used = self.block_table[seq.slot][self.block_table[seq.slot] != self.pcfg.null_page]
            assert list(used) == seq.pages
            if seq.status == "prefilling":
                assert seq.shared_len <= seq.prefill_pos <= seq.request.prompt_len
            if self.streaming is not None:
                # windowed residency: never more pages than the cap,
                # sinks pinned exactly (the pages that are pinned are
                # the head of the page list, each with a live pin)
                assert len(seq.pages) <= resident_cap(self.streaming)
                assert len(seq.pinned) <= self.streaming.sink_pages
                assert seq.pinned == seq.pages[:len(seq.pinned)]
                for p in seq.pinned:
                    assert self.pool.pin_count(p) >= 1
                assert seq.evicted_tokens % self.pcfg.page_size == 0


class SLOScheduler(ContinuousBatchingScheduler):
    """SLO-aware multi-tenant admission on top of the continuous-batching
    machinery. Page accounting, prefill chunking, COW, deadlines, and
    eviction are all inherited — only *which waiting request admits
    next* changes, plus deadline-aware shedding:

      * **per-tenant fair share** — every token served (prompt tail
        prefill + each generated token) is charged to its request's
        tenant; admission always picks from the tenant with the least
        service so far. A tenant that stops being served stops
        accumulating charge and therefore becomes the minimum — no
        tenant can be starved by another's volume, however sustained
        the overload (the fuzzed property in
        tests/test_slo_scheduler.py).
      * **priority classes** — within the selected tenant's requests,
        lower ``Request.priority`` admits first (class 0 is
        interactive traffic). Priority deliberately ranks *below*
        tenant fairness: one tenant marking everything urgent must not
        crowd out the rest.
      * **deadline-aware admission / shedding** — among equal
        priorities, the earliest absolute deadline admits first (EDF),
        and with ``shed=True`` a request that provably cannot finish
        inside its deadline — fewer steps remain than tokens it must
        generate, even served ideally — is refused admission with
        status ``"shed"`` instead of burning a decode slot until it
        times out. Shedding is what converts overload from "everyone
        misses" into "feasible work still lands": goodput (SLO-met
        tokens/s) degrades gracefully instead of collapsing
        (bench/runner.py measures exactly this against FIFO).

    When no request is shed (deadlines absent or loose), admission
    *order* is the only difference from FIFO — and greedy decoding is
    per-request, so outputs stay token-identical to the static oracle
    (the no-shedding equivalence test)."""

    def __init__(self, pcfg: PagedCacheConfig,
                 prefill_token_budget: Optional[int] = None,
                 prefix_sharing: bool = False,
                 streaming: Optional[StreamingConfig] = None, *,
                 shed: bool = True):
        super().__init__(pcfg, prefill_token_budget,
                         prefix_sharing=prefix_sharing,
                         streaming=streaming)
        self.shed = shed
        self.served_tokens: Dict[str, int] = {}        # tenant -> tokens charged
        self.shed_count = 0

    # ---------------------------------------------------- accounting --
    def _charge(self, tenant: str, tokens: int) -> None:
        self.served_tokens[tenant] = self.served_tokens.get(tenant, 0) + tokens

    def _on_admitted(self, seq: SeqState) -> None:
        # the prefill work this admission buys: the uncached prompt tail
        self._charge(seq.request.tenant,
                     seq.request.prompt_len - seq.shared_len)

    def on_token(self, slot: int, token: int) -> Optional[SeqState]:
        self._charge(self.active[slot].request.tenant, 1)
        return super().on_token(slot, token)

    def on_prefill_token(self, slot: int, token: int) -> Optional[SeqState]:
        self._charge(self.active[slot].request.tenant, 1)
        return super().on_prefill_token(slot, token)

    # ----------------------------------------------------- admission --
    def _doomed(self, req: Request) -> bool:
        """Provably cannot meet its deadline: even admitted now, with
        prefill completing this very step and one token landing every
        step after, the last token would arrive at or past expiry.
        Best-case finish is ``now + max_new_tokens - 1``; the request
        dies when ``clock - arrival >= deadline``."""
        if req.deadline is None:
            return False
        remaining = req.deadline_anchor + req.deadline - self._now
        return remaining < req.max_new_tokens

    def _shed_doomed(self) -> None:
        """Refuse every waiting request that can no longer make its
        deadline. Runs both at selection time and on every clock tick
        (:meth:`expire_deadlines`) — admission only scans the queue
        while a decode slot is free, so a request doomed *while queued
        behind long-running work* must be shed from the tick path or it
        would sit until the deadline machinery times it out."""
        if not self.shed:
            return
        for req in [r for r in self.waiting if self._doomed(r)]:
            self.cancel(req.rid, status="shed")
            self.shed_count += 1

    def expire_deadlines(self, clock: int) -> int:
        self._now = clock
        self._shed_doomed()
        return super().expire_deadlines(clock)

    def _next_request(self) -> Optional[Request]:
        self._shed_doomed()
        if not self.waiting:
            return None
        return min(
            enumerate(self.waiting),
            key=lambda iv: (self.served_tokens.get(iv[1].tenant, 0),
                            iv[1].priority,
                            (iv[1].deadline_anchor + iv[1].deadline
                             if iv[1].deadline is not None else float("inf")),
                            iv[0]),
        )[1]

    def stats(self) -> Dict[str, int]:
        out = {"shed": self.shed_count}
        for tenant, tokens in sorted(self.served_tokens.items()):
            out[f"tenant_{tenant}_tokens"] = tokens
        return out
