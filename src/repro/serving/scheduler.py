"""Continuous-batching scheduler: a FIFO request queue feeding a fixed
set of decode slots, with page-pool accounting.

Policy (host-side, cheap — the device only ever sees static shapes):

  * **admission** — strictly FIFO: the head request is admitted when a
    slot is free, its worst-case page need fits the *unreserved* pool,
    and the per-step prefill token budget allows it. Later requests
    never jump the head (no starvation under a full queue).
  * **reservation** — pages for ``prompt + max_new_tokens`` are reserved
    at admission but allocated lazily as the sequence crosses page
    boundaries, so a running sequence can never hit pool OOM mid-flight
    and reserved-but-unused pages show up in the accounting.
  * **eviction** — finished sequences (max_new reached or EOS) free
    their slot, pages, and reservation immediately; the freed capacity
    admits the next waiting request on the same engine step.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.serving.paged_cache import PagedCacheConfig, PagePool


@dataclasses.dataclass
class Request:
    """One serving request: ``prompt`` is a 1-D int32 token array of
    shape ``(prompt_len,)``; generation runs until ``max_new_tokens``
    (or ``eos_id``, when set). ``arrival`` is the engine step at which
    the request becomes visible to the scheduler — traces with
    staggered arrivals exercise mid-flight slot joins. ``rid`` keys the
    result dict ``ServingEngine.run`` returns."""
    rid: int
    prompt: np.ndarray                 # (prompt_len,) int32 token ids
    max_new_tokens: int
    arrival: int = 0                   # engine step at which it enters the queue
    eos_id: Optional[int] = None

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def max_total_len(self) -> int:
        return self.prompt_len + self.max_new_tokens


@dataclasses.dataclass
class SeqState:
    request: Request
    slot: int
    seq_len: int                       # tokens whose KV/state is cached
    pages: List[int]                   # allocated physical pages, logical order
    reserved_pages: int                # worst-case commitment at admission
    generated: List[int] = dataclasses.field(default_factory=list)

    @property
    def finished(self) -> bool:
        if len(self.generated) >= self.request.max_new_tokens:
            return True
        eos = self.request.eos_id
        return eos is not None and len(self.generated) > 0 and self.generated[-1] == eos


class ContinuousBatchingScheduler:
    """Owns slots, block tables, and the page pool. The engine calls:
    ``submit`` -> [``admit`` -> prefill]* -> ``ensure_append_capacity``
    -> decode -> ``on_token`` (evicts finished) — once per step."""

    def __init__(self, pcfg: PagedCacheConfig, prefill_token_budget: Optional[int] = None):
        self.pcfg = pcfg
        self.pool = PagePool(pcfg.num_pages)
        self.prefill_token_budget = prefill_token_budget
        self.waiting: Deque[Request] = deque()
        self.active: Dict[int, SeqState] = {}          # slot -> seq
        self._free_slots: List[int] = list(range(pcfg.max_slots - 1, -1, -1))
        self._reserved_total = 0
        self.block_table = np.full((pcfg.max_slots, pcfg.max_pages_per_seq),
                                   pcfg.null_page, dtype=np.int32)
        self.seq_lens = np.zeros((pcfg.max_slots,), dtype=np.int32)
        self.finished: List[SeqState] = []

    # ------------------------------------------------------------- api --
    def submit(self, req: Request) -> None:
        need = self.pcfg.pages_for(req.max_total_len)
        if need > self.pcfg.max_pages_per_seq:
            raise ValueError(
                f"request {req.rid}: {req.max_total_len} tokens exceed "
                f"max_pages_per_seq*page_size={self.pcfg.max_seq}")
        if need > self.pcfg.num_pages:
            raise ValueError(
                f"request {req.rid}: needs {need} pages, pool has {self.pcfg.num_pages}")
        self.waiting.append(req)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.active)

    def admit(self) -> List[SeqState]:
        """Admit from the queue head while slot/pages/budget allow.
        Returns newly admitted sequences (engine prefills them)."""
        admitted: List[SeqState] = []
        budget = self.prefill_token_budget
        spent = 0
        while self.waiting and self._free_slots:
            req = self.waiting[0]
            need = self.pcfg.pages_for(req.max_total_len)
            if self._reserved_total + need > self.pcfg.num_pages:
                break                                   # head waits; no queue-jumping
            if budget is not None and spent and spent + req.prompt_len > budget:
                break                                   # budget bounds each step, but
                                                        # never blocks the first admit
                                                        # (progress guarantee)
            self.waiting.popleft()
            slot = self._free_slots.pop()
            pages = self.pool.alloc(self.pcfg.pages_for(req.prompt_len))
            self._reserved_total += need
            seq = SeqState(request=req, slot=slot, seq_len=req.prompt_len,
                           pages=pages, reserved_pages=need)
            self.active[slot] = seq
            self.block_table[slot, :len(pages)] = pages
            self.seq_lens[slot] = req.prompt_len
            spent += req.prompt_len
            admitted.append(seq)
        return admitted

    def ensure_append_capacity(self) -> None:
        """Before a decode step: every active slot must own the page its
        next token lands in. Allocation cannot fail — the pages were
        reserved at admission."""
        for seq in self.active.values():
            page_idx = seq.seq_len // self.pcfg.page_size
            if page_idx >= len(seq.pages):
                assert len(seq.pages) < seq.reserved_pages, (
                    f"seq {seq.request.rid} outgrew its reservation")
                (page,) = self.pool.alloc(1)
                seq.pages.append(page)
                self.block_table[seq.slot, page_idx] = page

    def on_token(self, slot: int, token: int) -> Optional[SeqState]:
        """Record one generated token for a slot (its KV was appended by
        the decode step). Returns the SeqState if the sequence finished
        (already evicted), else None."""
        seq = self.active[slot]
        seq.generated.append(int(token))
        seq.seq_len += 1
        self.seq_lens[slot] = seq.seq_len
        if seq.finished:
            self._evict(seq)
            return seq
        return None

    def on_prefill_token(self, slot: int, token: int) -> Optional[SeqState]:
        """Record the token produced by prefill (not yet in the cache —
        the next decode step appends it)."""
        seq = self.active[slot]
        seq.generated.append(int(token))
        if seq.finished:                                 # max_new_tokens == 1
            self._evict(seq)
            return seq
        return None

    # -------------------------------------------------------- internal --
    def _evict(self, seq: SeqState) -> None:
        del self.active[seq.slot]
        self.pool.free(seq.pages)
        self._reserved_total -= seq.reserved_pages
        self.block_table[seq.slot, :] = self.pcfg.null_page
        self.seq_lens[seq.slot] = 0
        self._free_slots.append(seq.slot)
        self.finished.append(seq)

    # ------------------------------------------------------ invariants --
    def check_invariants(self) -> None:
        """Cheap structural invariants, asserted by tests after every
        step: slots partition exactly, pages never leak, reservations
        bound allocations."""
        assert len(self.active) + len(self._free_slots) == self.pcfg.max_slots
        assert set(self.active) | set(self._free_slots) == set(range(self.pcfg.max_slots))
        held = [p for s in self.active.values() for p in s.pages]
        assert len(held) == len(set(held)), "page double-booked"
        assert len(held) == self.pool.allocated_count, "page leak"
        assert self.pool.allocated_count <= self._reserved_total <= self.pcfg.num_pages
        for seq in self.active.values():
            assert len(seq.pages) <= seq.reserved_pages
            used = self.block_table[seq.slot][self.block_table[seq.slot] != self.pcfg.null_page]
            assert list(used) == seq.pages
