"""Disaggregated serving: a prefill worker feeding a decode engine
through an explicit KV-transfer seam.

Colocated continuous batching runs prompt chunks and decode steps on
one pool: a long prompt's chunks and the decode batch contend for the
same step loop. Production serving stacks (DistServe, Mooncake,
vLLM-disagg) split the phases — prefill workers with their own KV pool
process prompts, then ship the filled pages to the decode worker's
pool. This module is that split, single-process: the workers are real
(separate ``PagePool`` + paged state + executables), the wire is a
device-to-device page copy (:func:`~repro.serving.paged_cache.
paged_copy_pages`), and the whole arrangement stays token-for-token
identical to the colocated engine because the chunk math is the same
function against the same page geometry.

Three pieces:

  * :class:`KVTransfer` — ships filled pages between pools. ``raw``
    copies at pool dtype (lossless, the default); ``int8`` quantizes
    page payloads symmetric-per-channel on the wire (8x smaller than
    fp32 pools, reusing the scale scheme of ``serving/quantize.py`` /
    ``runtime/compression.py``) and dequantizes into the destination —
    an opt-in accuracy/bandwidth trade, surfaced in stats as raw vs
    wire bytes.
  * :class:`PrefillWorker` — owns a private pool and paged state,
    allocates pages per prompt, runs the same chunked offset-prefill
    executable the colocated engine uses.
  * :class:`DisaggregatedEngine` — a :class:`ServingEngine` whose
    prefill step runs on the worker: prompt chunks never touch the
    decode pool until the finished pages arrive in one transfer, so a
    long prompt never stalls the decode batch mid-write.

Both sides share one process and (under a serve mesh) one mesh with
identically sharded pools, so the transfer is a shard-local gather/
scatter under jit — the seam where a multi-host implementation would
put the actual interconnect.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.model_config import ModelConfig
from repro.models.decode import ATTN_STATE_KEYS
from repro.models.model import init_paged_state, prefill_chunk_paged
from repro.serving.engine import ServingEngine
from repro.serving.paged_cache import PagedCacheConfig, PagePool, paged_copy_pages
from repro.serving.scheduler import SeqState

KV_TRANSFER_MODES = ("raw", "int8")


class KVTransfer:
    """Page shipment between two pools plus the bandwidth ledger.

    ``ship`` copies pages ``src_ids`` of every leaf in ``src_tree``
    into pages ``dst_ids`` of ``dst_tree`` (layer-stacked pools:
    leading axis is layers) and returns the new destination tree.
    ``raw`` copies at pool dtype. ``int8`` quantizes each page's
    payload to symmetric int8 with one fp32 scale per (layer, page,
    channel) — amax over the token-in-page axis, the same per-channel
    scheme ``serving/quantize.py`` applies to weights — then
    dequantizes into the destination pool, so the pools always hold
    pool-dtype values and downstream attention is unchanged.

    The ledger counts ``pages_shipped`` (page-copies, summed over
    stacked pool groups), ``bytes_raw`` (payload at pool dtype — what
    a lossless wire carries) and ``bytes_wire`` (what this mode's wire
    carries: int8 payload + fp32 scales under ``int8``)."""

    def __init__(self, mode: str = "raw"):
        if mode not in KV_TRANSFER_MODES:
            raise ValueError(f"unknown kv transfer mode {mode!r}; "
                             f"options: {', '.join(KV_TRANSFER_MODES)}")
        self.mode = mode
        self.pages_shipped = 0
        self.bytes_raw = 0
        self.bytes_wire = 0
        fn = self._copy_raw if mode == "raw" else self._copy_int8
        # one executable per (tree structure, page count); page counts
        # are small integers so the cache stays bounded in practice
        self._fn = jax.jit(fn, donate_argnums=(0,))

    @staticmethod
    def _copy_raw(dst_tree, dst_ids, src_tree, src_ids):
        return jax.tree.map(
            lambda d, s: paged_copy_pages(d, dst_ids, s, src_ids, n_stack=1),
            dst_tree, src_tree)

    @staticmethod
    def _copy_int8(dst_tree, dst_ids, src_tree, src_ids):
        def one(d, s):
            vals = jnp.take(s, src_ids, axis=1).astype(jnp.float32)
            # (L, n, page, *channels): scale per channel over the page
            amax = jnp.max(jnp.abs(vals), axis=2, keepdims=True)
            scale = jnp.maximum(amax, 1e-12) / 127.0
            q = jnp.clip(jnp.round(vals / scale), -127, 127).astype(jnp.int8)
            deq = q.astype(jnp.float32) * scale
            return d.at[:, dst_ids].set(deq.astype(d.dtype))
        return jax.tree.map(one, dst_tree, src_tree)

    def ship(self, dst_tree, dst_ids: jax.Array, src_tree, src_ids: jax.Array):
        n = int(src_ids.shape[0])
        for leaf in jax.tree.leaves(dst_tree):
            # payload elements of one page across all layers of a leaf
            per_page = leaf.size // leaf.shape[1]
            self.bytes_raw += n * per_page * leaf.dtype.itemsize
            if self.mode == "int8":
                page = leaf.shape[2]
                self.bytes_wire += n * per_page       # int8 payload
                self.bytes_wire += (n * per_page // page) * 4  # fp32 scales
            else:
                self.bytes_wire += n * per_page * leaf.dtype.itemsize
        self.pages_shipped += n
        return self._fn(dst_tree, dst_ids, src_tree, src_ids)


class PrefillWorker:
    """Prompt-side worker: private page pool, private paged state, and
    the same chunked offset-prefill executable the colocated engine
    runs — so its logits and page contents are bit-identical to an
    in-place prefill at the same positions.

    Per prompt: :meth:`begin` allocates ``pages_for(prompt_len)`` pages
    from the worker pool, :meth:`run_chunk` advances ``seq.prefill_pos``
    writing KV into those pages, and when the prompt is done the engine
    ships the pages out and calls :meth:`finish` (ownership passes to
    the transfer; the worker releases after the ship). :meth:`abort`
    reclaims pages for sequences evicted mid-prefill."""

    def __init__(self, cfg: ModelConfig, params, pcfg: PagedCacheConfig, *,
                 mesh=None):
        self.cfg = cfg
        self.params = params
        self.pcfg = pcfg
        self.pool = PagePool(pcfg.num_pages)
        self.state = init_paged_state(cfg, pcfg)
        self.prefilled_tokens = 0
        self._pages: Dict[int, List[int]] = {}   # rid -> worker pages
        if mesh is not None and any(n > 1 for n in mesh.shape.values()):
            from jax.sharding import PartitionSpec as P

            from repro.sharding.partition import (
                TP_AXIS,
                named_shardings,
                paged_state_pspecs,
                shard_map_compat,
            )

            tp = int(mesh.shape[TP_AXIS])
            specs = paged_state_pspecs(cfg, self.state, tp)
            self._chunk_fn = jax.jit(shard_map_compat(
                lambda p, t, st, bt, s0: prefill_chunk_paged(
                    p, t, st, bt, s0, cfg, tp_axis=TP_AXIS, tp_size=tp),
                mesh, in_specs=(P(), P(), specs, P(), P()),
                out_specs=(P(), specs)), donate_argnums=(2,))
            self.state = jax.device_put(self.state,
                                        named_shardings(specs, mesh))
        else:
            self._chunk_fn = jax.jit(
                lambda p, t, st, bt, s0: prefill_chunk_paged(p, t, st, bt, s0, cfg),
                donate_argnums=(2,),
            )

    def begin(self, seq: SeqState) -> None:
        rid = seq.request.rid
        if rid not in self._pages:
            self._pages[rid] = self.pool.alloc(
                self.pcfg.pages_for(seq.request.prompt_len))

    def _block_row(self, rid: int) -> np.ndarray:
        bt = np.full((1, self.pcfg.max_pages_per_seq), self.pcfg.null_page,
                     dtype=np.int32)
        pages = self._pages[rid]
        bt[0, :len(pages)] = pages
        return bt

    def run_chunk(self, seq: SeqState, c: int):
        """Advance one prompt by ``c`` tokens against the worker pool;
        returns the chunk logits (the last chunk's tail logit seeds the
        first generated token, exactly as colocated)."""
        req = seq.request
        toks = jnp.asarray(req.prompt[seq.prefill_pos:seq.prefill_pos + c],
                           dtype=jnp.int32)[None]
        bt = jnp.asarray(self._block_row(req.rid))
        logits, self.state = self._chunk_fn(self.params, toks, self.state, bt,
                                            jnp.int32(seq.prefill_pos))
        seq.prefill_pos += c
        self.prefilled_tokens += c
        return logits

    def finish(self, rid: int) -> List[int]:
        """Hand the prompt's filled pages to the transfer; caller
        releases them (via :meth:`release`) once the ship is issued."""
        return self._pages.pop(rid)

    def release(self, pages: List[int]) -> None:
        self.pool.release(pages)

    def abort(self, rid: int) -> None:
        """Reclaim pages of a sequence evicted mid-prefill (cancel,
        deadline, shed). No-op for prompts already shipped."""
        pages = self._pages.pop(rid, None)
        if pages is not None:
            self.pool.release(pages)


class DisaggregatedEngine(ServingEngine):
    """Continuous-batching engine with disaggregated prefill: prompt
    chunks run on a :class:`PrefillWorker` against its private pool;
    on completion the filled pages ship through :class:`KVTransfer`
    into the pages the scheduler already allocated in the decode pool,
    and the sequence joins the decode batch exactly as if it had
    prefilled in place.

    Scheduling semantics are inherited unchanged — admission still
    allocates/reserves decode-pool pages, chunk budgets still meter
    prompt work per step — so colocated and disaggregated runs admit,
    chunk, and decode in the same order and emit identical tokens.
    Incompatible with ``prefix_cache`` (shared prefix pages live in the
    decode pool, invisible to the worker) and limited to the
    offset-prefill families (recurrent state has no page transfer).

    ``prefill_pcfg`` sizes the worker pool separately (same page size
    and block-table width — the chunk executable's geometry); default
    mirrors the decode pool."""

    def __init__(self, cfg: ModelConfig, params, pcfg: PagedCacheConfig, *,
                 kv_transfer: str = "raw",
                 prefill_pcfg: Optional[PagedCacheConfig] = None,
                 **kw):
        super().__init__(cfg, params, pcfg, **kw)
        if not self._offset_prefill:
            raise NotImplementedError(
                "disaggregated prefill needs the offset-prefill path; "
                f"family {cfg.family!r} carries recurrent state with no "
                "page transfer")
        if self.prefix_cache:
            raise ValueError(
                "disaggregated prefill is incompatible with prefix_cache: "
                "shared prefix pages live in the decode pool, which the "
                "prefill worker cannot see")
        wcfg = prefill_pcfg or pcfg
        if (wcfg.page_size != pcfg.page_size
                or wcfg.max_pages_per_seq != pcfg.max_pages_per_seq):
            raise ValueError(
                "prefill pool must match the decode pool's page_size and "
                f"max_pages_per_seq (got {wcfg.page_size}x"
                f"{wcfg.max_pages_per_seq} vs {pcfg.page_size}x"
                f"{pcfg.max_pages_per_seq}) — the chunk executable's "
                "geometry")
        self.transfer = KVTransfer(kv_transfer)
        # self.params: post-quantize, post-placement — the worker runs
        # the same weights the decode side serves
        self.worker = PrefillWorker(cfg, self.params, wcfg, mesh=self.mesh)

    # ------------------------------------------------------------- steps --
    def _prefill_step(self) -> None:
        """Same budget loop as the colocated engine, but chunks execute
        on the worker; a finished prompt's pages ship before the
        sequence turns visible to decode."""
        budget = self.prefill_chunk if self.chunked_prefill else None
        spent = 0
        for seq in self.sched.prefilling():
            self.worker.begin(seq)
            plen = seq.request.prompt_len
            logits = None
            while seq.prefill_pos < plen:
                remaining = plen - seq.prefill_pos
                c = remaining if budget is None else min(remaining, max(1, budget - spent))
                if budget is not None and spent > 0 and spent + c > budget:
                    return                   # budget exhausted; resume next step
                logits = self.worker.run_chunk(seq, c)
                self.prefill_tokens += c
                spent += c
            self._receive(seq)
            self._complete_prefill(seq, logits)
            if budget is not None and spent >= budget:
                return

    def _receive(self, seq: SeqState) -> None:
        """Ship the worker's filled pages into the sequence's decode-
        pool pages (allocated at admission, one per prompt page) and
        release the worker side."""
        rid = seq.request.rid
        src_pages = self.worker.finish(rid)
        dst_pages = seq.pages[:len(src_pages)]
        src_ids = jnp.asarray(np.asarray(src_pages, dtype=np.int32))
        dst_ids = jnp.asarray(np.asarray(dst_pages, dtype=np.int32))
        for key in ATTN_STATE_KEYS:
            if key in self.state:
                self.state[key] = self.transfer.ship(
                    self.state[key], dst_ids, self.worker.state[key], src_ids)
        self.worker.release(src_pages)

    def _drain(self) -> List[SeqState]:
        drained = super()._drain()
        for seq in drained:
            self.worker.abort(seq.request.rid)
        return drained

    # ------------------------------------------------------------- stats --
    def stats(self) -> Dict[str, float]:
        out = super().stats()
        out.update({
            "kv_transfer_pages": float(self.transfer.pages_shipped),
            "kv_transfer_bytes": float(self.transfer.bytes_raw),
            "kv_transfer_wire_bytes": float(self.transfer.bytes_wire),
            "prefill_pool_peak_pages": float(self.worker.pool.peak_allocated),
        })
        return out
