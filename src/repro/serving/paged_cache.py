"""Paged KV cache: fixed-size pages allocated from a shared pool, with a
per-sequence block table mapping logical token positions to physical
pages (the vLLM/SHARK-Engine design).

The static serving cache materializes ``(batch, max_seq)`` per layer —
worst-case memory for every sequence, the exact materialize-the-maximum
waste SCT's never-materialize rule rejects for weights. Here a sequence
only holds the pages its tokens occupy, so a mixed stream of request
lengths shares one small pool.

Device side (pure, jit-friendly; leaves are per-layer pools):
  * pool layout    — ``(num_pages + 1, page_size, *feature)``; the last
    page is the *null page*: inactive decode slots point at it, so the
    batched one-token append always has a harmless write target.
  * ``paged_gather``      — block table -> contiguous ``(slots, S, ...)``
    view for attention (masked positions may hold stale page data; the
    attention mask makes them unreachable).
  * ``paged_append``      — write one new token per slot at its fill
    position.
  * ``paged_write_pages`` — scatter a prefilled prompt cache into the
    pages allocated for one sequence.

Recurrent (mamba / xlstm) decode state is a fixed-size single "page" per
sequence, so it pages trivially: ``slot_read`` / ``slot_write`` index the
slot axis of the stacked state arrays.

Host side: ``PagePool`` is the refcounted free-list allocator the
continuous-batching scheduler draws from. Shared-prefix caching maps
one physical page into several sequences' block tables: ``share``
bumps the refcount, ``release`` drops it (the page returns to the free
list at zero), and a write into a page with refcount > 1 must first
fork a private copy (``copy_page`` is the device half of that
copy-on-write step).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    """Geometry of the shared pool.

    ``num_pages`` is the allocatable pool size (pool arrays carry one
    extra null page). ``max_pages_per_seq`` bounds the block-table width;
    the contiguous attention view is ``page_size * max_pages_per_seq``
    tokens wide.
    """
    page_size: int = 16
    num_pages: int = 64
    max_slots: int = 4
    max_pages_per_seq: int = 8

    @property
    def max_seq(self) -> int:
        return self.page_size * self.max_pages_per_seq

    @property
    def null_page(self) -> int:
        return self.num_pages

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)


# ======================================================================
# Device-side ops (single pool leaf; models stack a leading layer axis)
# ======================================================================

def paged_gather(pool: jax.Array, block_table: jax.Array) -> jax.Array:
    """pool (P, page, *f) + block_table (b, n) -> (b, n*page, *f).

    Pages land in logical order, so the result is positionally identical
    to a static ``(b, S)`` cache for the first ``seq_len`` tokens of each
    row; positions past ``seq_len`` may hold stale or null-page data and
    must stay behind the attention validity mask.
    """
    b, n = block_table.shape
    g = jnp.take(pool, block_table, axis=0)            # (b, n, page, *f)
    return g.reshape(b, n * pool.shape[1], *pool.shape[2:])


def paged_append(pool: jax.Array, block_table: jax.Array, seq_lens: jax.Array,
                 vals: jax.Array) -> jax.Array:
    """Write one token per slot: pool[bt[i, len_i // page], len_i % page]
    = vals[i]. vals: (b, *f). Inactive slots (len 0, block table on the
    null page) write harmlessly into the null page."""
    page = pool.shape[1]
    page_idx = jnp.minimum(seq_lens // page, block_table.shape[1] - 1)
    phys = jnp.take_along_axis(block_table, page_idx[:, None], axis=1)[:, 0]
    return pool.at[phys, seq_lens % page].set(vals.astype(pool.dtype))


def paged_write_slice(pool: jax.Array, block_table: jax.Array, start: jax.Array,
                      vals: jax.Array) -> jax.Array:
    """Write a contiguous chunk of tokens at a logical offset.

    pool (P, page, *f); block_table (n,) — one sequence's page ids;
    start — scalar int32 logical position of ``vals[0]``; vals (c, *f).
    Token i lands at pool[bt[(start+i) // page], (start+i) % page] — the
    chunked-prefill write path (prefill from an offset against pages
    already holding the shared prefix). ``start`` is data, so one
    executable serves every offset at a given chunk length.
    """
    page = pool.shape[1]
    pos = start + jnp.arange(vals.shape[0], dtype=jnp.int32)
    phys = jnp.take(block_table, pos // page)
    return pool.at[phys, pos % page].set(vals.astype(pool.dtype))


def copy_page(pool: jax.Array, src: jax.Array, dst: jax.Array) -> jax.Array:
    """pool[dst] = pool[src] — the device half of a copy-on-write fork.
    src/dst are scalar int32 page ids (data, not static)."""
    return pool.at[dst].set(pool[src])


def paged_write_pages(pool: jax.Array, page_ids: jax.Array, vals: jax.Array,
                      *, n_stack: int = 0) -> jax.Array:
    """Scatter a contiguous per-sequence cache into its pages.

    pool (*stack, P, page, *f) with ``n_stack`` leading stacked axes
    (layer / period — block tables are shared across layers, so one call
    writes every layer's pool); page_ids (n,); vals (*stack, s, *f) with
    s <= n*page. The tail of the last page is zero-padded — those
    positions are masked until a later append overwrites them.
    """
    page = pool.shape[n_stack + 1]
    n = page_ids.shape[0]
    s = vals.shape[n_stack]
    pad = [(0, 0)] * vals.ndim
    pad[n_stack] = (0, n * page - s)
    vals = jnp.pad(vals, pad)
    new_shape = vals.shape[:n_stack] + (n, page) + vals.shape[n_stack + 1:]
    vals = vals.reshape(new_shape).astype(pool.dtype)
    idx = (slice(None),) * n_stack + (page_ids,)
    return pool.at[idx].set(vals)


def paged_copy_pages(dst_pool: jax.Array, dst_ids: jax.Array,
                     src_pool: jax.Array, src_ids: jax.Array,
                     *, n_stack: int = 0) -> jax.Array:
    """Copy whole pages between two pools: dst[dst_ids[i]] =
    src[src_ids[i]] across the ``n_stack`` leading stacked (layer) axes
    — the receive-side seam of the prefill->decode KV transfer
    (serving/distributed.py). Page-granular like
    :func:`paged_write_pages`: the unfilled tail of the last prompt
    page copies too, but those positions sit behind the attention
    validity mask until a decode append overwrites them, exactly as
    after an in-place prefill."""
    idx_src = (slice(None),) * n_stack + (src_ids,)
    idx_dst = (slice(None),) * n_stack + (dst_ids,)
    return dst_pool.at[idx_dst].set(src_pool[idx_src].astype(dst_pool.dtype))


# ------------------------------------------------- recurrent slot state --

def slot_write(state_tree, slot_axes, slot: int, values):
    """Scatter one sequence's recurrent decode state (batch-1 leaves)
    into the slot axis of the stacked serving state."""
    def put(leaf, axis, val):
        val = jnp.squeeze(val, axis=axis).astype(leaf.dtype)
        idx = (slice(None),) * axis + (slot,)
        return leaf.at[idx].set(val)

    return jax.tree.map(put, state_tree, slot_axes, values)


def slot_read(state_tree, slot_axes, slot: int):
    """Gather one sequence's recurrent state back out (keeps a batch-1
    axis so it round-trips with slot_write)."""
    def take(leaf, axis):
        idx = (slice(None),) * axis + (slice(slot, slot + 1),)
        return leaf[idx]

    return jax.tree.map(take, state_tree, slot_axes)


# ======================================================================
# Host-side allocator
# ======================================================================

class PagePool:
    """Refcounted free-list page allocator. Pages are plain ints in
    [0, num_pages); the null page is never handed out.

    ``alloc`` hands out pages at refcount 1; ``share`` maps an
    already-allocated page into another holder (refcount + 1);
    ``release``/``free`` drop one reference and return the page to the
    free list only when the last holder lets go. A holder about to
    *write* a shared page must fork it first (allocate a fresh page,
    ``copy_page`` on device, release the shared one) — the scheduler's
    copy-on-write step."""

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._refs: Dict[int, int] = {}
        # counted pins: a pinned page may gain/lose *extra* references
        # (prefix sharing), but its refcount may never fall below its pin
        # count — releasing into a pin is an eviction-policy bug and
        # raises instead of silently recycling a live attention sink
        self._pins: Dict[int, int] = {}
        # optional hook fired with the list of pages that just hit
        # refcount zero (after they return to the free list) — the
        # engine uses it to clear cold-KV flags on every release path
        # (streaming eviction, sequence finish, cancel, prefix-cache
        # eviction) without chasing each call site
        self.on_free = None
        # high-water mark of concurrently allocated pages, maintained at
        # the allocation site itself — callers that sample residency at
        # one point in their loop (the engine's per-step stat) would miss
        # pages allocated and released between samples (COW forks,
        # decode-time boundary appends on a finishing sequence)
        self.peak_allocated = 0

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def allocated_count(self) -> int:
        return len(self._refs)

    def refcount(self, page: int) -> int:
        return self._refs.get(page, 0)

    def is_shared(self, page: int) -> bool:
        return self._refs.get(page, 0) > 1

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise RuntimeError(f"page pool exhausted: want {n}, have {len(self._free)}")
        out = [self._free.pop() for _ in range(n)]
        for p in out:
            self._refs[p] = 1
        self.peak_allocated = max(self.peak_allocated, len(self._refs))
        return out

    def share(self, page_ids: Sequence[int]) -> None:
        """Add one reference to each (already-allocated) page."""
        for p in page_ids:
            if p not in self._refs:
                raise RuntimeError(f"share of unallocated page {p}")
        for p in page_ids:
            self._refs[p] += 1

    def pin(self, page_ids: Sequence[int]) -> None:
        """Pin allocated pages (counted): each pin consumes one of the
        page's references, so ``release`` below that floor raises. The
        attention-sink guard — a sliding-window evictor that reaches a
        sink fails loudly instead of corrupting a shared prefix."""
        for p in page_ids:
            if p not in self._refs:
                raise RuntimeError(f"pin of unallocated page {p}")
            if self._pins.get(p, 0) >= self._refs[p]:
                raise RuntimeError(f"pin of page {p} exceeds refcount")
        for p in page_ids:
            self._pins[p] = self._pins.get(p, 0) + 1

    def unpin(self, page_ids: Sequence[int]) -> None:
        """Drop one pin per page (must currently be pinned)."""
        for p in page_ids:
            if self._pins.get(p, 0) <= 0:
                raise RuntimeError(f"unpin of unpinned page {p}")
        for p in page_ids:
            self._pins[p] -= 1
            if self._pins[p] == 0:
                del self._pins[p]

    def pin_count(self, page: int) -> int:
        return self._pins.get(page, 0)

    def release(self, page_ids: Sequence[int]) -> None:
        """Drop one reference per page; free at refcount zero. Releasing
        a page nobody holds raises (the double-free guard), as does a
        release that would take a page's refcount below its pin count
        (the pinned-sink guard)."""
        # validate cumulatively: a batch may release the same page more
        # than once (one list entry per reference), so the guard must
        # check the total drop, not each entry against the pre-state
        drops: Dict[int, int] = {}
        for p in page_ids:
            drops[p] = drops.get(p, 0) + 1
        for p, k in drops.items():
            if self._refs.get(p, 0) < k:
                raise RuntimeError(f"double free of page {p}")
            if self._refs[p] - k < self._pins.get(p, 0):
                raise RuntimeError(f"release of pinned page {p}")
        freed: List[int] = []
        for p in page_ids:
            self._refs[p] -= 1
            if self._refs[p] == 0:
                del self._refs[p]
                self._free.append(p)
                freed.append(p)
        if freed and self.on_free is not None:
            self.on_free(freed)

    # pre-refcount name, kept for callers that never share
    free = release
