"""Self-speculative decoding via the spectral rank ladder.

The paper's rank-sweep finding — every tested rank converges to the
same loss floor — makes a rank-shrunk copy of a checkpoint a *free*
draft model for its own full-rank target: no second model to train,
load, or keep in sync. ``SpeculativeEngine`` runs a ladder of
rank-truncated variants of one set of weights over a **shared page
pool**:

  * the lowest-rank level (the *drafter*) greedy-decodes
    ``draft_tokens`` tokens with the already-compiled batched
    ``(max_slots, 1)`` decode step, writing its own KV as it goes;
  * each higher level *verifies* the previous level's proposal burst in
    one batched forward — the chunked-prefill offset path
    (``prefill_chunk_paged`` -> ``paged_write_slice``) scores all burst
    positions at once while writing that level's KV for them;
  * the full-rank target verifies last; accepted tokens are committed,
    and the first rejection replaces the rest of the burst with the
    target's own greedy token.

**Rollback is free.** Every level's pool keeps stale KV behind the
attention validity mask (positions past ``seq_len`` are unreachable and
are overwritten by later writes at the same logical positions), so
rejecting a suffix of the burst is pure ``seq_len`` accounting — the
scheduler's block tables and page refcounts are shared by all levels
and never move backwards.

**Output is exactly the target's greedy decode.** A committed token is
either a proposal that *matched* the target's greedy prediction for
its position, or the target's own greedy prediction (the correction at
the first mismatch). Acceptance rate changes latency, never the token
stream — the token-for-token property tests against the static oracle
hold for the speculative engine unchanged.

Cache-validity invariant (why every level can keep serving after a
partial commit): level ``l`` caches KV for its verify inputs
``[t0] + P_{l-1}[:-1]`` at positions ``[seq_len, seq_len + |P_{l-1}|)``.
Each verification preserves the first ``|P_l| - 1`` proposals (a
correction only ever lands at the *last* index), and proposal lists
only shrink up the ladder — so for a final commit of ``c`` tokens,
every level's positions ``[seq_len, seq_len + c)`` hold KV for exactly
``[t0] + committed[:c-1]``; the last committed token becomes the next
input and is cached by no level (the same convention the
non-speculative engine keeps for ``_next_input``).

Family policy: speculation needs the paged offset-prefill path, so it
is restricted to ``PREFIX_SHARING_FAMILIES`` (GQA dense and MLA MoE
attention); recurrent families carry state that cannot roll back by
masking. The prefix *cache* is mutually exclusive with speculation:
index pages hold one level's KV, but an admitted sequence needs every
level's KV for its prompt.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.model_config import ModelConfig
from repro.models.decode import ATTN_STATE_KEYS, supports_prefix_sharing
from repro.models.model import init_paged_state
from repro.serving.engine import ServingEngine
from repro.serving.paged_cache import PagedCacheConfig
from repro.serving.scheduler import SeqState

__all__ = ["SpeculativeEngine", "derive_drafters", "parse_ladder"]


def parse_ladder(spec: Any) -> List[int]:
    """Rank-ladder grammar -> ordered rank list. Accepts an int, an int
    sequence, or the ServeSpec string form (``"32"`` or ``"32,128"``).
    Ranks run drafter-first and must be positive and non-decreasing —
    equal adjacent ranks are legal (the degenerate ladder the same-rank
    resize no-op exists for), the full-rank target is implicit."""
    if isinstance(spec, int):
        ranks = [spec]
    elif isinstance(spec, str):
        try:
            ranks = [int(r) for r in spec.split(",") if r.strip()]
        except ValueError:
            raise ValueError(f"speculative rank ladder {spec!r}: want "
                             f"comma-separated ints, lowest (drafter) first")
    else:
        ranks = [int(r) for r in spec]
    if not ranks:
        raise ValueError("speculative rank ladder must name at least one rank")
    if any(r < 1 for r in ranks):
        raise ValueError(f"speculative rank ladder {ranks}: ranks must be >= 1")
    if ranks != sorted(ranks):
        raise ValueError(f"speculative rank ladder {ranks} must be "
                         f"non-decreasing (drafter first, target implicit)")
    return ranks


def derive_drafters(params: Any, ranks: Sequence[int]) -> List[Any]:
    """Rank-shrunk copies of ``params``, one per ladder rank, drafter
    first. A shrink is pure deterministic column selection (Eckart–Young
    top-|s|), so this is bit-identical to restoring the same checkpoint
    at ``target_rank=K`` — ``Server.from_checkpoint`` goes through the
    checkpoint manager's restore-at-rank path instead, one ``restore``
    call per level, and lands on the same factors."""
    from repro.rank.resize import clamp_target, resize_tree

    # shrink never consumes randomness; the key only feeds the (never
    # taken here) grow path of resize_tree
    key = jax.random.PRNGKey(0)
    return [resize_tree(key, params, clamp_target(params, int(r)))
            for r in ranks]


class SpeculativeEngine(ServingEngine):
    """Continuous-batching engine with rank-ladder self-speculation.

    Construction takes the full-rank ``params`` (the verification
    target) plus ``speculative_ranks`` — the rank ladder, drafter
    (lowest) first. Drafter weight trees are derived by shrinking
    ``params`` unless ``drafter_params`` hands them in explicitly
    (``Server.from_checkpoint`` restores each ladder rank from the
    checkpoint). Every level shares the scheduler, block tables, and
    page-pool accounting; each level owns its own device-side KV pools
    with identical geometry, so one physical page id addresses the same
    logical positions at every rank.

    Per engine step, instead of one batched decode: draft
    ``draft_tokens`` greedily at the lowest rank, verify the burst
    through each higher rank, verify at full rank, commit the longest
    target-agreeing prefix (plus the target's correction token at the
    first mismatch). Verify bursts are charged against the chunked-
    prefill token budget, so speculation and prompt chunking share one
    per-step compute bound."""

    def __init__(self, cfg: ModelConfig, params, pcfg: PagedCacheConfig, *,
                 speculative_ranks, draft_tokens: int = 4,
                 drafter_params: Optional[Sequence[Any]] = None, **kw):
        ranks = parse_ladder(speculative_ranks)
        if draft_tokens < 1:
            raise ValueError(f"draft_tokens {draft_tokens} must be >= 1")
        if not supports_prefix_sharing(cfg):
            raise NotImplementedError(
                f"speculative decoding needs the paged offset-prefill path; "
                f"family {cfg.family!r} keeps recurrent state that cannot "
                f"roll back by seq_len masking")
        if kw.get("prefix_cache"):
            raise ValueError(
                "prefix_cache and speculative decoding are mutually "
                "exclusive: index pages hold a single level's KV, but a "
                "speculative sequence needs every ladder level's KV for "
                "its prompt")
        if drafter_params is None:
            drafter_params = derive_drafters(params, ranks)
        elif len(drafter_params) != len(ranks):
            raise ValueError(f"{len(drafter_params)} drafter param trees "
                             f"for a {len(ranks)}-rank ladder")
        super().__init__(cfg, params, pcfg, **kw)
        if self.quantize == "int8":
            # same contract as the target: shrink first, then quantize
            from repro.serving.quantize import quantize_tree

            drafter_params = [quantize_tree(p) for p in drafter_params]
        self.speculative_ranks = tuple(ranks)
        self.draft_tokens = int(draft_tokens)
        self.ladder_params: List[Any] = list(drafter_params)
        self.ladder_states: List[Dict] = [init_paged_state(cfg, pcfg)
                                          for _ in ranks]
        # speculation counters (stats(): acceptance_rate, tokens_per_step)
        self.draft_proposed = 0       # drafter tokens offered to the ladder
        self.draft_accepted = 0       # drafter tokens that survived to commit
        self.spec_bursts = 0          # draft->verify->commit rounds run

    # ----------------------------------------------------------- prefill --
    def _run_chunk(self, seq: SeqState, c: int):
        """Prompt chunks run through *every* level: each rank's pool
        needs its own prompt KV before it can draft or verify. Only the
        full-rank logits seed the first generated token."""
        req = seq.request
        toks = jnp.asarray(req.prompt[seq.prefill_pos:seq.prefill_pos + c],
                           dtype=jnp.int32)[None]
        bt = jnp.asarray(self.sched.block_table[seq.slot:seq.slot + 1])
        start = jnp.int32(seq.prefill_pos)
        for i, lp in enumerate(self.ladder_params):
            _, self.ladder_states[i] = self._chunk_fn(
                lp, toks, self.ladder_states[i], bt, start)
        logits, self.state = self._chunk_fn(self.params, toks, self.state,
                                            bt, start)
        seq.prefill_pos += c
        self.prefill_tokens += c
        return logits

    def _prefill_step(self) -> None:
        """Verify bursts count against the chunked-prefill token budget:
        the tokens the coming decode phase will draft+verify shrink this
        step's prompt-chunk allowance (never below the 1-token progress
        guarantee), so a speculative engine under chunked prefill keeps
        the same per-step compute bound as a plain one."""
        if not self.chunked_prefill:
            return super()._prefill_step()
        burst = sum(
            min(self.draft_tokens,
                seq.request.max_new_tokens - len(seq.generated))
            for seq in self.sched.active.values() if seq.status == "decoding")
        saved = self.prefill_chunk
        self.prefill_chunk = max(1, saved - burst)
        try:
            super()._prefill_step()
        finally:
            self.prefill_chunk = saved

    # ------------------------------------------------------------ decode --
    def _copy_fork_pages(self, src: int, dst: int) -> None:
        """COW fork lands in every level's pools — the page id is shared
        across the ladder, so its contents must fork everywhere."""
        s, d = jnp.int32(src), jnp.int32(dst)
        for key in ATTN_STATE_KEYS:
            if key in self.state:
                self.state[key] = self._copy_page_fn(self.state[key], s, d)
        for st in self.ladder_states:
            for key in ATTN_STATE_KEYS:
                if key in st:
                    st[key] = self._copy_page_fn(st[key], s, d)

    def _verify(self, vparams, level: Optional[int], seq: SeqState,
                t0: int, proposals: List[int]) -> List[int]:
        """Score a proposal burst with one level in a single batched
        forward. Inputs ``[t0] + proposals[:-1]`` run through the
        chunked-prefill offset path at ``start=seq_len`` — writing this
        level's KV for the burst as a side effect — and the greedy
        prediction after input ``i`` is compared against
        ``proposals[i]``. Returns the longest accepted prefix, with
        this level's own greedy token replacing the first mismatch
        (so the result is never empty and never longer than the
        input). ``level=None`` is the full-rank target."""
        if not proposals:
            return proposals
        toks = jnp.asarray([t0] + proposals[:-1], dtype=jnp.int32)[None]
        bt = jnp.asarray(self.sched.block_table[seq.slot:seq.slot + 1])
        state = self.state if level is None else self.ladder_states[level]
        logits, state = self._chunk_fn(vparams, toks, state, bt,
                                       jnp.int32(seq.seq_len))
        if level is None:
            self.state = state
        else:
            self.ladder_states[level] = state
        preds = np.asarray(jnp.argmax(logits[0], axis=-1)).astype(np.int32)
        out: List[int] = []
        for i, p in enumerate(proposals):
            if int(preds[i]) == p:
                out.append(p)
            else:
                out.append(int(preds[i]))      # correction: always last
                break
        return out

    def _decode_once(self) -> None:
        """One draft -> staged-verify -> commit round (replaces the
        single batched decode step)."""
        decoding = {slot: seq for slot, seq in self.sched.active.items()
                    if seq.status == "decoding"}
        if not decoding:
            return
        # per-slot burst size: never draft past the sequence's remaining
        # token budget (the page reservation covers exactly max_total)
        k_eff = {slot: min(self.draft_tokens,
                           seq.request.max_new_tokens - len(seq.generated))
                 for slot, seq in decoding.items()}
        for _, src, dst in self.sched.ensure_burst_capacity(k_eff):
            self._copy_fork_pages(src, dst)

        bt_np, sl_np = self.sched.decode_view()
        bt = jnp.asarray(bt_np)
        slots = np.fromiter(decoding, dtype=np.int64)

        # ---- draft: k_max greedy steps at the lowest rank, against a
        # local copy of the fill levels (rollback = never publishing it)
        k_max = max(k_eff.values())
        sl_local = sl_np.copy()
        toks = self._next_input.copy()
        proposals: Dict[int, List[int]] = {slot: [] for slot in decoding}
        for _ in range(k_max):
            logits, self.ladder_states[0] = self._decode_fn(
                self.ladder_params[0], jnp.asarray(toks)[:, None],
                self.ladder_states[0], bt, jnp.asarray(sl_local))
            nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1)).astype(np.int32)
            for slot in decoding:
                proposals[slot].append(int(nxt[slot]))
            toks = nxt
            sl_local[slots] += 1

        # ---- staged verification up the ladder, then the target commit
        committed_total = 0
        for slot, seq in decoding.items():
            t0 = int(self._next_input[slot])
            prop = proposals[slot][:k_eff[slot]]
            self.draft_proposed += len(prop)
            drafted = list(prop)
            for level in range(1, len(self.ladder_params)):
                prop = self._verify(self.ladder_params[level], level,
                                    seq, t0, prop)
            final = self._verify(self.params, None, seq, t0, prop)
            committed: List[int] = []
            for tok in final:
                self._next_input[slot] = int(tok)
                committed.append(int(tok))
                if self.sched.on_token(slot, int(tok)) is not None:
                    break                       # finished (EOS / budget): evicted
            self.draft_accepted += sum(
                1 for i, t in enumerate(committed)
                if i < len(drafted) and t == drafted[i])
            committed_total += len(committed)
        self.spec_bursts += 1
        self.decode_steps += 1
        self.decoded_tokens += committed_total

    # ------------------------------------------------------------- stats --
    def stats(self) -> Dict[str, float]:
        out = super().stats()
        out.update({
            "draft_proposed": float(self.draft_proposed),
            "draft_accepted": float(self.draft_accepted),
            "acceptance_rate": (self.draft_accepted / self.draft_proposed
                                if self.draft_proposed else 0.0),
            "tokens_per_step": (self.decoded_tokens / self.decode_steps
                                if self.decode_steps else 0.0),
            "spec_bursts": float(self.spec_bursts),
            "draft_tokens": float(self.draft_tokens),
            "ladder_levels": float(len(self.ladder_params)),
        })
        return out
