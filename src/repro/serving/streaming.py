"""Long-context streaming KV policy: attention sinks + sliding-window
page eviction + cold-page int8 demotion (StreamingLLM adapted to the
paged cache).

The insight from StreamingLLM (SNIPPETS.md Snippet 2) is that softmax
attention parks a large fraction of its mass on the first few tokens
regardless of content — evict those *attention sinks* and generation
collapses, keep them plus a sliding window of recent tokens and quality
degrades gracefully while memory stays O(sink + window). Mapped onto
this repo's page-granular cache:

  * the first ``sink_pages`` pages of every streaming sequence are
    **pinned** in the :class:`~repro.serving.paged_cache.PagePool`
    (``pin``/``unpin``) — the evictor cannot reach them, by
    construction and by a loud runtime guard;
  * once a sequence's resident pages would exceed the cap
    ``sink_pages + window_pages + 1`` (sinks + window + the partially
    filled growth page), the **oldest non-sink page** is evicted:
    released back to the refcounted pool, the block-table row compacted
    left, and the sequence's *resident* length shrunk by ``page_size``
    while ``evicted_tokens`` grows by the same amount;
  * resident pages older than the window but not yet evicted are
    **cold**: with ``cold_kv="int8"`` the engine demotes them to a
    page-granular int8 shadow pool (``serving/quantize.py
    quantize_kv_pages``) and attention transparently dequantizes them
    on attend — in the jnp gather path and in the cold-aware Pallas
    paged-decode kernels.

Position contract (the StreamingLLM "positions within the cache" rule):
RoPE positions are **cache-slot-relative**. ``SeqState.seq_len`` counts
*resident* tokens only, so the existing position derivations —
``seq_lens[:, None]`` at decode, ``start + arange(chunk)`` at chunked
prefill with ``start = prefill_pos - evicted_tokens`` — yield cache
positions with no attention-side changes. Keys keep the rotation they
were written with; after an eviction the query-key distance to older
resident keys shrinks by ``page_size``, exactly the in-cache-distance
semantics StreamingLLM uses (and the reason streaming output is
token-identical to the full cache *until* the first eviction).

This module is the pure policy half: geometry, eviction arithmetic,
cold-set enumeration. The scheduler owns the host mutation (evict /
compact / pin), the engine owns the device mutation (demote / flag).
"""
from __future__ import annotations

import dataclasses

from repro.serving.paged_cache import PagedCacheConfig

__all__ = [
    "StreamingConfig",
    "resident_cap",
    "windowed_reservation",
    "evictions_needed",
    "cold_page_indices",
    "identity_horizon",
    "validate_geometry",
]


@dataclasses.dataclass(frozen=True)
class StreamingConfig:
    """Streaming policy knobs.

    ``sink_pages`` — pages pinned forever at the head of every sequence
    (attention sinks; >= 1).
    ``window_pages`` — sliding window of recent pages kept resident
    (>= 1).
    ``cold_kv`` — codec for resident pages older than the window:
    ``"none"`` keeps them bf16, ``"int8"`` demotes them page-granularly
    with transparent dequant-on-attend.
    """
    sink_pages: int = 1
    window_pages: int = 4
    cold_kv: str = "none"

    def __post_init__(self) -> None:
        if self.sink_pages < 1:
            raise ValueError("streaming sink_pages must be >= 1")
        if self.window_pages < 1:
            raise ValueError("streaming window_pages must be >= 1")
        if self.cold_kv not in ("none", "int8"):
            raise ValueError(
                f"streaming cold_kv must be 'none' or 'int8', "
                f"got {self.cold_kv!r}")


def resident_cap(cfg: StreamingConfig) -> int:
    """Maximum pages a streaming sequence ever holds: sinks + window +
    one partially-filled growth page. The page after this cap is the
    eviction trigger."""
    return cfg.sink_pages + cfg.window_pages + 1


def windowed_reservation(cfg: StreamingConfig, pcfg: PagedCacheConfig,
                         max_total_len: int) -> int:
    """Admission reservation for a streaming sequence: the windowed cap
    unless the request is short enough to never hit it — O(sink +
    window) instead of O(prompt + max_new_tokens)."""
    return min(pcfg.pages_for(max_total_len), resident_cap(cfg))


def evictions_needed(cfg: StreamingConfig, pcfg: PagedCacheConfig,
                     resident_len: int, extra_tokens: int) -> int:
    """How many oldest-middle pages must be evicted before appending
    ``extra_tokens`` to a sequence currently holding ``resident_len``
    resident tokens. Each eviction frees exactly one page *and* shrinks
    the resident length by ``page_size``, so the count is simply the
    overshoot past the resident cap."""
    return max(0, pcfg.pages_for(resident_len + extra_tokens)
               - resident_cap(cfg))


def cold_page_indices(cfg: StreamingConfig, n_pages: int) -> range:
    """Logical page indices (into a sequence's page list) that are
    resident but older than the sliding window — the int8 demotion
    candidates. Always full pages: the window covers the trailing
    ``window_pages`` slots including the partial growth page."""
    return range(cfg.sink_pages, max(cfg.sink_pages,
                                     n_pages - cfg.window_pages))


def identity_horizon(cfg: StreamingConfig, pcfg: PagedCacheConfig) -> int:
    """Token count up to which streaming greedy output is guaranteed
    token-identical to the full-cache engine: while the total length
    stays within sinks + window, nothing has been evicted *or* demoted
    (the first demotion candidate appears when the growth page — page
    ``sink + window`` — is allocated)."""
    return (cfg.sink_pages + cfg.window_pages) * pcfg.page_size


def validate_geometry(cfg: StreamingConfig, pcfg: PagedCacheConfig) -> None:
    """The resident cap must fit both the block-table width and the
    pool, or streaming admission could never place a sequence."""
    cap = resident_cap(cfg)
    if cap > pcfg.max_pages_per_seq:
        raise ValueError(
            f"streaming resident cap {cap} (sink {cfg.sink_pages} + "
            f"window {cfg.window_pages} + 1) exceeds max_pages_per_seq "
            f"{pcfg.max_pages_per_seq}")
    if cap > pcfg.num_pages:
        raise ValueError(
            f"streaming resident cap {cap} exceeds the page pool "
            f"({pcfg.num_pages} pages)")
