"""Model dispatcher: one uniform API over every family.

  init_model(key, cfg)                         -> params
  train_loss(params, batch, cfg)               -> (loss, metrics)
  prefill(params, tokens, cfg, state, **extra) -> (logits, state)
  decode_step(params, tokens, state, cache_len, cfg, **extra)
  decode_state_specs(cfg, batch, max_seq)      -> ShapeDtypeStruct tree
  param_count(params) / active_param_count(cfg)
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config.model_config import ModelConfig
from repro.models import lm as lm_mod
from repro.models import decode as decode_mod
from repro.models import encdec as encdec_mod


def init_model(key, cfg: ModelConfig):
    if cfg.family == "encdec":
        return encdec_mod.init_encdec(key, cfg)
    return lm_mod.init_lm(key, cfg)


def train_loss(params, batch: Dict[str, jax.Array], cfg: ModelConfig):
    if cfg.family == "encdec":
        return encdec_mod.train_loss_encdec(params, batch, cfg)
    return lm_mod.train_loss_lm(params, batch, cfg)


def forward(params, tokens, cfg: ModelConfig):
    if cfg.family == "encdec":
        raise ValueError("encdec needs encoder_frames; use train_loss/prefill")
    return lm_mod.forward_lm(params, tokens, cfg)


def decode_state_specs(cfg: ModelConfig, batch: int, max_seq: int):
    if cfg.family == "encdec":
        return encdec_mod.encdec_state_specs(cfg, batch, max_seq)
    return decode_mod.lm_state_specs(cfg, batch, max_seq)


def init_decode_state(cfg: ModelConfig, batch: int, max_seq: int):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), decode_state_specs(cfg, batch, max_seq)
    )


def prefill(params, tokens, cfg: ModelConfig, state, **extra):
    if cfg.family == "encdec":
        return encdec_mod.prefill_encdec(params, tokens, cfg, state,
                                         encoder_frames=extra["encoder_frames"])
    return decode_mod.prefill_lm(params, tokens, cfg, state)


def decode_step(params, tokens, state, cache_len, cfg: ModelConfig, **extra):
    if cfg.family == "encdec":
        return encdec_mod.decode_step_encdec(params, tokens, state, cache_len, cfg,
                                             encoder_out=extra["encoder_out"])
    return decode_mod.decode_step_lm(params, tokens, state, cache_len, cfg)


# ------------------------------------------------------- paged serving --

def paged_state_specs(cfg: ModelConfig, pcfg, cold_kv: str = "none"):
    if cfg.family == "encdec":
        raise NotImplementedError("paged serving targets decoder-only families")
    return decode_mod.lm_paged_state_specs(cfg, pcfg, cold_kv)


def init_paged_state(cfg: ModelConfig, pcfg, cold_kv: str = "none"):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        paged_state_specs(cfg, pcfg, cold_kv)
    )


def decode_step_paged(params, tokens, state, block_table, seq_lens, cfg: ModelConfig,
                      *, tp_axis=None, tp_size=1, cold_flags=None):
    if cfg.family == "encdec":
        raise NotImplementedError("paged serving targets decoder-only families")
    return decode_mod.decode_step_lm_paged(params, tokens, state, block_table,
                                           seq_lens, cfg,
                                           tp_axis=tp_axis, tp_size=tp_size,
                                           cold_flags=cold_flags)


def prefill_chunk_paged(params, tokens, state, block_table, start, cfg: ModelConfig,
                        *, tp_axis=None, tp_size=1, cold_flags=None):
    """Offset/chunked prefill for one sequence against the paged pools
    (decode.prefill_chunk_lm_paged); attention-only families."""
    if cfg.family == "encdec":
        raise NotImplementedError("paged serving targets decoder-only families")
    return decode_mod.prefill_chunk_lm_paged(params, tokens, state, block_table,
                                             start, cfg,
                                             tp_axis=tp_axis, tp_size=tp_size,
                                             cold_flags=cold_flags)


def param_count(params) -> int:
    return sum(int(jnp.size(x)) for x in jax.tree.leaves(params))


def dense_equivalent_param_count(params) -> int:
    """Parameter count of the dense model this spectral model represents
    (paper: '452M spectral parameters correspond to a 77.8B dense
    architecture')."""
    from repro.core.spectral import is_spectral

    total = 0

    def walk(tree):
        nonlocal total
        if is_spectral(tree):
            U, V = tree["U"], tree["V"]
            m, n = U.shape[-2], V.shape[-2]
            lead = 1
            for d in U.shape[:-2]:
                lead *= d
            total += lead * m * n
            total += sum(int(jnp.size(v)) for k, v in tree.items() if k not in ("U", "s", "V"))
            return
        if isinstance(tree, dict):
            for v in tree.values():
                walk(v)
        elif isinstance(tree, (list, tuple)):
            for v in tree:
                walk(v)
        else:
            total += int(jnp.size(tree))

    walk(params)
    return total
