"""Serving paths for decoder-only families: cache/state construction,
prefill, and single-token decode. Caches are stacked along a leading
layer (or period) axis and scanned together with the layer params.

Cache construction is pluggable: the attention cache is either the
static dense ``(batch, max_seq)`` layout or a paged pool layout
(serving/paged_cache.py) where each layer holds a shared page pool and
sequences map logical positions through a block table. Recurrent
(mamba / xlstm) decode state is fixed-size per sequence, so both
layouts index it by slot; only the attention leaves change shape.
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.config.model_config import ModelConfig
from repro.nn import attention as attn
from repro.nn import mamba as mamba_mod
from repro.nn import xlstm as xlstm_mod
from repro.nn.embedding import apply_embedding, apply_lm_head
from repro.nn.mlp import apply_mlp
from repro.nn.moe import apply_moe
from repro.models.lm import _norm_apply, _compute_dtype

Params = Dict[str, Any]

# state-dict keys holding attention caches (layout-dependent leaves) vs
# recurrent per-slot state, and the axis the serving slot lives on after
# layer stacking — the serving engine scatters prefill state with these
ATTN_STATE_KEYS = ("cache", "dense_cache", "moe_cache", "attn_cache")

# Families whose *entire* decode state is paged attention KV. Only these
# support shared-prefix reuse and chunked (offset) prefill: a cached
# page fully determines the contribution of its tokens to any later
# query. Recurrent families (hybrid mamba, xlstm) carry slot-local
# recurrent state that a mid-prompt restart cannot reconstruct from
# pages, so they opt out — their prompts always prefill in one shot
# from position 0 and never share prefix pages (the only reuse that
# could be exact for them is a full-prompt state snapshot, which we
# deliberately do not cache). The serving engine consults this policy;
# tests assert the opt-out families still serve token-identically.
#
# moe_lm caveat: expert capacity is sized per forward (capacity_factor
# * tokens_in_this_forward / n_experts, nn/moe.py), so when capacity
# actually *binds*, a prompt prefilled as chunks can drop a different
# token set than the one-shot oracle prefill. That dependence on the
# forward's token count is pre-existing (batched decode already drops
# differently than the batch-1 oracle at tight capacity — see the
# fp32/capacity_factor pins in tests); the token-identity guarantee for
# MoE therefore holds in the capacity-unbound regime, same as for every
# other MoE serving path in this repo.
PREFIX_SHARING_FAMILIES = ("dense_lm", "moe_lm")


def supports_prefix_sharing(cfg: ModelConfig) -> bool:
    """Whether this family can prefill from an offset against paged KV
    (and therefore share prefix pages / chunk its prefill)."""
    return cfg.family in PREFIX_SHARING_FAMILIES


def recurrent_slot_axes(cfg: ModelConfig) -> Dict[str, int]:
    """state key -> axis of the serving slot (batch) in stacked leaves."""
    if cfg.family == "hybrid":
        return {"mamba": 2}         # (n_periods, n_mamba, batch, ...)
    if cfg.family == "ssm_lm":
        return {"mlstm": 2, "slstm": 1}
    return {}


# ======================================================================
# State specs / init
# ======================================================================

def _stack_specs(n: int, tree):
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), tree)


def _attn_cache_spec(cfg: ModelConfig, batch: int, max_seq: int):
    if cfg.attention == "mla":
        return {
            "ckv": jax.ShapeDtypeStruct((batch, max_seq, cfg.kv_lora_rank), jnp.bfloat16),
            "krope": jax.ShapeDtypeStruct((batch, max_seq, cfg.qk_rope_dim), jnp.bfloat16),
        }
    return {
        "k": jax.ShapeDtypeStruct((batch, max_seq, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16),
        "v": jax.ShapeDtypeStruct((batch, max_seq, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16),
    }


def _attn_pool_spec(cfg: ModelConfig, pcfg, cold_kv: str = "none"):
    """Per-layer paged pool: (num_pages + 1 null page, page_size, *feat).

    ``cold_kv="int8"`` adds page-granular int8 *shadow* pools plus
    per-page scales (token axis reduced) for the streaming cold tier:
    the engine demotes cold pages into the shadow leaves and attention
    substitutes their dequantized rows for flagged pages. Shadow leaves
    ride in the same cache dict, so the layer scan, COW page copy, and
    TP sharding machinery see them as ordinary pool leaves."""
    P, pg = pcfg.num_pages + 1, pcfg.page_size
    if cfg.attention == "mla":
        spec = {
            "ckv": jax.ShapeDtypeStruct((P, pg, cfg.kv_lora_rank), jnp.bfloat16),
            "krope": jax.ShapeDtypeStruct((P, pg, cfg.qk_rope_dim), jnp.bfloat16),
        }
        if cold_kv == "int8":
            spec.update({
                "ckv_q8": jax.ShapeDtypeStruct((P, pg, cfg.kv_lora_rank), jnp.int8),
                "ckv_scale": jax.ShapeDtypeStruct((P, cfg.kv_lora_rank), jnp.float32),
                "krope_q8": jax.ShapeDtypeStruct((P, pg, cfg.qk_rope_dim), jnp.int8),
                "krope_scale": jax.ShapeDtypeStruct((P, cfg.qk_rope_dim), jnp.float32),
            })
        return spec
    spec = {
        "k": jax.ShapeDtypeStruct((P, pg, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16),
        "v": jax.ShapeDtypeStruct((P, pg, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16),
    }
    if cold_kv == "int8":
        spec.update({
            "k_q8": jax.ShapeDtypeStruct((P, pg, cfg.n_kv_heads, cfg.head_dim), jnp.int8),
            "k_scale": jax.ShapeDtypeStruct((P, cfg.n_kv_heads, cfg.head_dim), jnp.float32),
            "v_q8": jax.ShapeDtypeStruct((P, pg, cfg.n_kv_heads, cfg.head_dim), jnp.int8),
            "v_scale": jax.ShapeDtypeStruct((P, cfg.n_kv_heads, cfg.head_dim), jnp.float32),
        })
    return spec


def _mamba_state_spec(cfg, batch):
    di = cfg.mamba_expand * cfg.d_model
    return {
        "conv": jax.ShapeDtypeStruct((batch, cfg.mamba_d_conv - 1, di), jnp.bfloat16),
        "ssm": jax.ShapeDtypeStruct((batch, di, cfg.mamba_d_state), jnp.bfloat16),
    }


def _mlstm_state_spec(cfg, batch):
    di = 2 * cfg.d_model
    dh = di // cfg.n_heads
    h = cfg.n_heads
    return {
        "C": jax.ShapeDtypeStruct((batch, h, dh, dh), jnp.float32),
        "n": jax.ShapeDtypeStruct((batch, h, dh), jnp.float32),
        "m": jax.ShapeDtypeStruct((batch, h), jnp.float32),
    }


def _slstm_state_spec(cfg, batch):
    nh = cfg.n_heads
    dh = cfg.d_model // nh
    s = jax.ShapeDtypeStruct((batch, nh, dh), jnp.float32)
    return {"h": s, "c": s, "n": s, "m": s}


def _lm_state_specs(cfg: ModelConfig, batch: int, attn_spec: Callable[[], Any]):
    """Family state tree; ``attn_spec`` supplies the per-layer attention
    cache spec — the pluggable (static vs. paged) part."""
    if cfg.family == "dense_lm":
        return {"cache": _stack_specs(cfg.n_layers, attn_spec())}
    if cfg.family == "moe_lm":
        st = {}
        if cfg.first_dense_layers:
            st["dense_cache"] = _stack_specs(cfg.first_dense_layers, attn_spec())
        st["moe_cache"] = _stack_specs(cfg.n_layers - cfg.first_dense_layers, attn_spec())
        return st
    if cfg.family == "hybrid":
        n_periods = cfg.n_layers // cfg.attn_every
        n_mamba = cfg.attn_every - 1
        return {
            "attn_cache": _stack_specs(n_periods, attn_spec()),
            "mamba": _stack_specs(n_periods, _stack_specs(n_mamba, _mamba_state_spec(cfg, batch))),
        }
    if cfg.family == "ssm_lm":
        n_periods = cfg.n_layers // cfg.slstm_every
        n_m = cfg.slstm_every - 1
        return {
            "mlstm": _stack_specs(n_periods, _stack_specs(n_m, _mlstm_state_spec(cfg, batch))),
            "slstm": _stack_specs(n_periods, _slstm_state_spec(cfg, batch)),
        }
    raise ValueError(cfg.family)


def lm_state_specs(cfg: ModelConfig, batch: int, max_seq: int):
    """ShapeDtypeStruct tree of the static-cache decode state."""
    return _lm_state_specs(cfg, batch, lambda: _attn_cache_spec(cfg, batch, max_seq))


def lm_paged_state_specs(cfg: ModelConfig, pcfg, cold_kv: str = "none"):
    """Decode state with paged attention pools: recurrent leaves are
    slot-indexed by ``pcfg.max_slots``; attention leaves are shared page
    pools addressed through the engine's block tables. ``cold_kv``
    extends each layer's pools with the streaming int8 shadow tier."""
    return _lm_state_specs(cfg, pcfg.max_slots,
                           lambda: _attn_pool_spec(cfg, pcfg, cold_kv))


def lm_init_state(cfg: ModelConfig, batch: int, max_seq: int):
    """Zero-filled decode state (real allocation — for smoke tests and
    the serving example; the dry-run uses lm_state_specs instead)."""
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), lm_state_specs(cfg, batch, max_seq))


def lm_init_paged_state(cfg: ModelConfig, pcfg, cold_kv: str = "none"):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        lm_paged_state_specs(cfg, pcfg, cold_kv))


# ======================================================================
# Prefill
# ======================================================================

def _attn_prefill(cfg, p, h, positions, cache):
    if cfg.attention == "mla":
        return attn.apply_mla_prefill(p, h, cfg, positions=positions, cache=cache)
    return attn.apply_gqa_prefill(p, h, cfg, positions=positions, cache=cache,
                                  use_pallas=cfg.use_pallas)


def _dense_block_prefill(cfg, p, x, positions, cache):
    h = _norm_apply(cfg, p["attn_norm"], x)
    h, cache = _attn_prefill(cfg, p["attn"], h, positions, cache)
    x = x + h
    h = _norm_apply(cfg, p["mlp_norm"], x)
    body = p.get("mlp")
    if body is not None:
        h = apply_mlp(body, h, act=cfg.act, use_pallas=cfg.use_pallas)
    else:
        h, _ = apply_moe(p["moe"], h, cfg, capacity_factor=cfg.capacity_factor,
                         use_pallas=cfg.use_pallas)
    return x + h, cache


def prefill_lm(params: Params, tokens: jax.Array, cfg: ModelConfig, state):
    """Process the prompt, fill caches. Returns (last-token logits, state).

    For hybrid/ssm families the prefill runs the training forward for
    outputs and reconstructs the recurrent state from a final single-step
    replay (exact for attention caches; SSM/xlstm prefill states are
    produced by their scan's final carry).
    """
    b, s = tokens.shape
    dt = _compute_dtype(cfg)
    x = apply_embedding(params["embed"], tokens, compute_dtype=dt)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    if cfg.family in ("dense_lm", "moe_lm"):
        stacks = []
        if cfg.family == "dense_lm":
            stacks = [("layers", "cache")]
        else:
            if cfg.first_dense_layers:
                stacks.append(("dense_layers", "dense_cache"))
            stacks.append(("moe_layers", "moe_cache"))
        new_state = dict(state)
        for pk, ck in stacks:
            def f(carry, xs):
                layer_p, cache = xs
                h, cache = _dense_block_prefill(cfg, layer_p, carry, positions, cache)
                return h, cache

            x, new_cache = jax.lax.scan(f, x, (params[pk], state[ck]))
            new_state[ck] = new_cache
        state = new_state
    elif cfg.family == "hybrid":
        def f(carry, xs):
            period_p, cache, mstates = xs
            h = carry
            new_m = []
            for p in range(cfg.attn_every):
                lp = period_p[f"p{p}"]
                hh = _norm_apply(cfg, lp["pre_norm"], h)
                if "attn" in lp:
                    hh, cache = attn.apply_gqa_prefill(
                        lp["attn"], hh, cfg, positions=positions, cache=cache,
                        use_pallas=cfg.use_pallas)
                else:
                    mi = p if p < cfg.attn_offset else p - 1
                    hh, ms = _mamba_prefill(lp["mamba"], hh, cfg,
                                            jax.tree.map(lambda t: t[mi], mstates))
                    new_m.append(ms)
                h = h + hh
                hh = _norm_apply(cfg, lp["ff_norm"], h)
                if "moe" in lp:
                    hh, _ = apply_moe(lp["moe"], hh, cfg, capacity_factor=cfg.capacity_factor,
                                      use_pallas=cfg.use_pallas)
                else:
                    hh = apply_mlp(lp["mlp"], hh, act=cfg.act, use_pallas=cfg.use_pallas)
                h = h + hh
            mstacked = jax.tree.map(lambda *xs: jnp.stack(xs), *new_m)
            return h, (cache, mstacked)

        x, (new_cache, new_mamba) = jax.lax.scan(
            f, x, (params["periods"], state["attn_cache"], state["mamba"])
        )
        state = {"attn_cache": new_cache, "mamba": new_mamba}
    elif cfg.family == "ssm_lm":
        def f(carry, xs):
            period_p, mstates, sstate = xs
            h = carry
            new_m = []
            new_s = sstate
            for p in range(cfg.slstm_every):
                lp = period_p[f"p{p}"]
                hh = _norm_apply(cfg, lp["pre_norm"], h)
                if "slstm" in lp:
                    hh, new_s = _slstm_prefill(lp["slstm"], hh, cfg)
                else:
                    mi = p if p < cfg.slstm_offset else p - 1
                    hh, ms = _mlstm_prefill(lp["mlstm"], hh, cfg)
                    new_m.append(ms)
                h = h + hh
            mstacked = jax.tree.map(lambda *xs: jnp.stack(xs), *new_m)
            return h, (mstacked, new_s)

        x, (new_m, new_s) = jax.lax.scan(f, x, (params["periods"], state["mlstm"], state["slstm"]))
        state = {"mlstm": new_m, "slstm": new_s}
    else:
        raise ValueError(cfg.family)

    x = _norm_apply(cfg, params["final_norm"], x[:, -1:, :])
    logits = apply_lm_head(params["embed"], x)
    return logits, state


def _mamba_prefill(p, x, cfg, state):
    """Training scan + exact final state (conv tail, final SSM carry)."""
    y, new = mamba_mod.apply_mamba(p, x, cfg, return_state=True)
    return y, {
        "conv": new["conv"].astype(state["conv"].dtype),
        "ssm": new["ssm"].astype(state["ssm"].dtype),
    }


def _mlstm_prefill(p, x, cfg):
    # chunkwise form returns outputs AND the exact final recurrent state
    return xlstm_mod.apply_mlstm_with_state(p, x, cfg)


def _slstm_prefill(p, x, cfg):
    b, s, d = x.shape
    y = xlstm_mod.apply_slstm(p, x, cfg)
    # final state via the same scan the forward uses
    from repro.nn.linear import apply_linear
    xg = apply_linear(p["wx"], x)
    state = xlstm_mod.slstm_init_state(cfg, b, dtype=jnp.float32)

    def step(st, xg_t):
        return xlstm_mod._slstm_cell(p, cfg, xg_t, st), None

    state, _ = jax.lax.scan(step, state, jnp.moveaxis(xg, 1, 0))
    return y, state


# ======================================================================
# Decode (single token)
# ======================================================================

def decode_step_lm(params: Params, tokens: jax.Array, state, cache_len: jax.Array,
                   cfg: ModelConfig):
    """tokens (b, 1) + state -> (logits (b, 1, vocab), new state).

    cache_len is the number of tokens already in the cache (static cache
    size, dynamic fill level) so the step compiles once and serves any
    position — the serving-loop contract.
    """
    def attn_decode(p, h, cache):
        if cfg.attention == "mla":
            return attn.apply_mla_decode(p, h, cfg, cache=cache, cache_len=cache_len)
        return attn.apply_gqa_decode(p, h, cfg, cache=cache, cache_len=cache_len,
                                     use_pallas=cfg.use_pallas)

    return _decode_step_body(params, tokens, state, cfg, attn_decode)


def decode_step_lm_paged(params: Params, tokens: jax.Array, state,
                         block_table: jax.Array, seq_lens: jax.Array,
                         cfg: ModelConfig, *, tp_axis=None, tp_size=1,
                         cold_flags=None):
    """One-token step against paged attention pools with per-slot fill
    levels — mixed request lengths in one compiled step, the
    continuous-batching contract. block_table: (slots, n_pages) int32;
    seq_lens: (slots,) int32. Recurrent state paths are shared with the
    static step (slot-indexed either way).

    ``tp_axis``/``tp_size`` run the attention heads tensor-parallel
    when the step executes under ``shard_map`` over a serve mesh
    (sharding/partition.py:serve_mesh): GQA KV pools arrive as per-shard
    kv-head slices, MLA latent pools replicated; everything else
    (params, tokens, block tables, logits) is replicated. Per-head math
    is unchanged, so greedy outputs stay token-identical."""
    def attn_decode(p, h, cache):
        if cfg.attention == "mla":
            return attn.apply_mla_decode_paged(
                p, h, cfg, cache=cache, block_table=block_table, seq_lens=seq_lens,
                tp_axis=tp_axis, tp_size=tp_size, cold_flags=cold_flags)
        return attn.apply_gqa_decode_paged(
            p, h, cfg, cache=cache, block_table=block_table, seq_lens=seq_lens,
            use_pallas=cfg.use_pallas, tp_axis=tp_axis, tp_size=tp_size,
            cold_flags=cold_flags)

    return _decode_step_body(params, tokens, state, cfg, attn_decode)


def prefill_chunk_lm_paged(params: Params, tokens: jax.Array, state,
                           block_table: jax.Array, start: jax.Array,
                           cfg: ModelConfig, *, tp_axis=None, tp_size=1,
                           cold_flags=None):
    """Chunked/offset prefill against the paged pools: tokens (1, c)
    occupy absolute positions [start, start+c) of one sequence whose
    pages are mapped in block_table (1, n_pages). Positions < start are
    already cached (a shared prefix, or earlier chunks of this prompt);
    the chunk's KV is written through the block table and attention
    runs causally at absolute positions. Returns (logits (1, c, vocab),
    new state). ``start`` is data — one executable per chunk length.

    Only :data:`PREFIX_SHARING_FAMILIES`; recurrent families raise (see
    the policy note on that constant)."""
    if cfg.family not in PREFIX_SHARING_FAMILIES:
        raise NotImplementedError(
            f"chunked/offset prefill needs pure paged-attention state; "
            f"family {cfg.family!r} carries recurrent state and opts out")

    def attn_chunk(p, h, cache):
        if cfg.attention == "mla":
            return attn.apply_mla_prefill_paged(
                p, h, cfg, cache=cache, block_table=block_table, start=start,
                tp_axis=tp_axis, tp_size=tp_size, cold_flags=cold_flags)
        return attn.apply_gqa_prefill_paged(
            p, h, cfg, cache=cache, block_table=block_table, start=start,
            use_pallas=cfg.use_pallas, tp_axis=tp_axis, tp_size=tp_size,
            cold_flags=cold_flags)

    return _decode_step_body(params, tokens, state, cfg, attn_chunk)


def _decode_step_body(params: Params, tokens: jax.Array, state, cfg: ModelConfig,
                      attn_decode):
    """Family-dispatched layer scan shared by the static and paged steps;
    ``attn_decode(layer_params, h, cache) -> (out, cache)`` is the
    layout-specific part."""
    dt = _compute_dtype(cfg)
    x = apply_embedding(params["embed"], tokens, compute_dtype=dt)

    if cfg.family in ("dense_lm", "moe_lm"):
        stacks = [("layers", "cache")] if cfg.family == "dense_lm" else (
            ([("dense_layers", "dense_cache")] if cfg.first_dense_layers else [])
            + [("moe_layers", "moe_cache")]
        )
        new_state = dict(state)
        for pk, ck in stacks:
            def f(carry, xs):
                layer_p, cache = xs
                h = _norm_apply(cfg, layer_p["attn_norm"], carry)
                h, cache = attn_decode(layer_p["attn"], h, cache)
                hx = carry + h
                h = _norm_apply(cfg, layer_p["mlp_norm"], hx)
                if "mlp" in layer_p:
                    h = apply_mlp(layer_p["mlp"], h, act=cfg.act, use_pallas=cfg.use_pallas)
                else:
                    h, _ = apply_moe(layer_p["moe"], h, cfg,
                                     capacity_factor=cfg.capacity_factor,
                                     use_pallas=cfg.use_pallas)
                return hx + h, cache

            x, new_cache = jax.lax.scan(f, x, (params[pk], state[ck]))
            new_state[ck] = new_cache
        state = new_state
    elif cfg.family == "hybrid":
        def f(carry, xs):
            period_p, cache, mstates = xs
            h = carry
            new_m = []
            for p in range(cfg.attn_every):
                lp = period_p[f"p{p}"]
                hh = _norm_apply(cfg, lp["pre_norm"], h)
                if "attn" in lp:
                    hh, cache = attn_decode(lp["attn"], hh, cache)
                else:
                    mi = p if p < cfg.attn_offset else p - 1
                    hh, ms = mamba_mod.apply_mamba_decode(
                        lp["mamba"], hh, cfg, state=jax.tree.map(lambda t: t[mi], mstates))
                    new_m.append(ms)
                h = h + hh
                hh = _norm_apply(cfg, lp["ff_norm"], h)
                if "moe" in lp:
                    hh, _ = apply_moe(lp["moe"], hh, cfg,
                                      capacity_factor=cfg.capacity_factor,
                                      use_pallas=cfg.use_pallas)
                else:
                    hh = apply_mlp(lp["mlp"], hh, act=cfg.act, use_pallas=cfg.use_pallas)
                h = h + hh
            mstacked = jax.tree.map(lambda *xs: jnp.stack(xs), *new_m)
            return h, (cache, mstacked)

        x, (new_cache, new_m) = jax.lax.scan(
            f, x, (params["periods"], state["attn_cache"], state["mamba"]))
        state = {"attn_cache": new_cache, "mamba": new_m}
    elif cfg.family == "ssm_lm":
        def f(carry, xs):
            period_p, mstates, sstate = xs
            h = carry
            new_m = []
            new_s = sstate
            for p in range(cfg.slstm_every):
                lp = period_p[f"p{p}"]
                hh = _norm_apply(cfg, lp["pre_norm"], h)
                if "slstm" in lp:
                    hh, new_s = xlstm_mod.apply_slstm_decode(lp["slstm"], hh, cfg, state=sstate)
                else:
                    mi = p if p < cfg.slstm_offset else p - 1
                    hh, ms = xlstm_mod.apply_mlstm_decode(
                        lp["mlstm"], hh, cfg, state=jax.tree.map(lambda t: t[mi], mstates))
                    new_m.append(ms)
                h = h + hh
            mstacked = jax.tree.map(lambda *xs: jnp.stack(xs), *new_m)
            return h, (mstacked, new_s)

        x, (new_m, new_s) = jax.lax.scan(f, x, (params["periods"], state["mlstm"], state["slstm"]))
        state = {"mlstm": new_m, "slstm": new_s}
    else:
        raise ValueError(cfg.family)

    x = _norm_apply(cfg, params["final_norm"], x)
    logits = apply_lm_head(params["embed"], x)
    return logits, state
