"""Decoder-only language models, all four LM families:

  dense_lm  — llama3.2-1b, granite-3-2b, qwen1.5-{0.5b,4b}, qwen2-vl-72b
  moe_lm    — deepseek-v2-236b, deepseek-v3-671b (MLA + routed experts)
  hybrid    — jamba-v0.1-52b (mamba:attention 7:1, MoE every 2nd layer)
  ssm_lm    — xlstm-1.3b (mLSTM:sLSTM 7:1)

Homogeneous layer stacks are scanned (lax.scan over stacked params) with
optional remat; heterogeneous families scan over their repeat *period*
(jamba: 8 layers, xlstm: 8 blocks) so the HLO stays small at 32-80
layers. SCT spectral layers appear wherever the config says so; the
dense (m, n) matrices of converted layers never exist.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config.model_config import ModelConfig
from repro.nn import attention as attn
from repro.nn import mamba as mamba_mod
from repro.nn import xlstm as xlstm_mod
from repro.nn.embedding import init_embedding, apply_embedding, apply_lm_head
from repro.nn.mlp import init_mlp, apply_mlp
from repro.nn.moe import init_moe, apply_moe
from repro.nn.norms import (
    init_rmsnorm,
    apply_rmsnorm,
    init_layernorm,
    apply_layernorm,
)
from repro.sharding.rules import constrain_activation

Params = Dict[str, Any]


def _norm_init(cfg, dim=None):
    dim = dim or cfg.d_model
    return init_rmsnorm(dim) if cfg.norm == "rmsnorm" else init_layernorm(dim)


def _norm_apply(cfg, p, x):
    return apply_rmsnorm(p, x) if cfg.norm == "rmsnorm" else apply_layernorm(p, x)


def _compute_dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ======================================================================
# Per-layer init (one layer; stacking via vmap happens in init_lm)
# ======================================================================

def _init_attn(key, cfg):
    if cfg.attention == "mla":
        return attn.init_mla(key, cfg)
    return attn.init_gqa(key, cfg)


def _init_dense_layer(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": _norm_init(cfg),
        "attn": _init_attn(k1, cfg),
        "mlp_norm": _norm_init(cfg),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, rank=cfg.mlp_rank, act=cfg.act),
    }


def _init_moe_layer(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": _norm_init(cfg),
        "attn": _init_attn(k1, cfg),
        "mlp_norm": _norm_init(cfg),
        "moe": init_moe(k2, cfg),
    }


def _init_hybrid_period(key, cfg):
    """One jamba period: attn_every layers; attention at attn_offset,
    mamba elsewhere; MoE on odd positions, dense MLP on even."""
    P = cfg.attn_every
    keys = jax.random.split(key, 2 * P)
    layers = {}
    for p in range(P):
        km, kf = keys[2 * p], keys[2 * p + 1]
        mixer = (
            {"attn": _init_attn(km, cfg)}
            if p == cfg.attn_offset
            else {"mamba": mamba_mod.init_mamba(km, cfg)}
        )
        is_moe = (p % cfg.moe_every) == (cfg.moe_every - 1) and cfg.n_experts > 0
        ff = {"moe": init_moe(kf, cfg)} if is_moe else {
            "mlp": init_mlp(kf, cfg.d_model, cfg.d_ff, rank=cfg.mlp_rank, act=cfg.act)
        }
        layers[f"p{p}"] = {
            "pre_norm": _norm_init(cfg),
            **mixer,
            "ff_norm": _norm_init(cfg),
            **ff,
        }
    return layers


def _init_xlstm_period(key, cfg):
    """One xlstm period: slstm_every blocks; sLSTM at slstm_offset."""
    P = cfg.slstm_every
    keys = jax.random.split(key, P)
    layers = {}
    for p in range(P):
        if p == cfg.slstm_offset:
            body = {"slstm": xlstm_mod.init_slstm(keys[p], cfg)}
        else:
            body = {"mlstm": xlstm_mod.init_mlstm(keys[p], cfg)}
        layers[f"p{p}"] = {"pre_norm": _norm_init(cfg), **body}
    return layers


# ======================================================================
# Model init
# ======================================================================

def init_lm(key, cfg: ModelConfig) -> Params:
    ke, kl, kd, kh = jax.random.split(key, 4)
    params: Params = {"embed": init_embedding(ke, cfg.vocab, cfg.d_model)}

    if cfg.family == "dense_lm":
        L = cfg.n_layers
        keys = jax.random.split(kl, L)
        params["layers"] = jax.vmap(lambda k: _init_dense_layer(k, cfg))(keys)
    elif cfg.family == "moe_lm":
        Ld = cfg.first_dense_layers
        Lm = cfg.n_layers - Ld
        if Ld:
            params["dense_layers"] = jax.vmap(lambda k: _init_dense_layer(k, cfg))(
                jax.random.split(kd, Ld)
            )
        params["moe_layers"] = jax.vmap(lambda k: _init_moe_layer(k, cfg))(
            jax.random.split(kl, Lm)
        )
    elif cfg.family == "hybrid":
        n_periods = cfg.n_layers // cfg.attn_every
        params["periods"] = jax.vmap(lambda k: _init_hybrid_period(k, cfg))(
            jax.random.split(kl, n_periods)
        )
    elif cfg.family == "ssm_lm":
        n_periods = cfg.n_layers // cfg.slstm_every
        params["periods"] = jax.vmap(lambda k: _init_xlstm_period(k, cfg))(
            jax.random.split(kl, n_periods)
        )
    else:
        raise ValueError(cfg.family)

    params["final_norm"] = _norm_init(cfg)
    return params


# ======================================================================
# Forward (training / no-cache)
# ======================================================================

def _dense_block(cfg, p, x, positions):
    h = _norm_apply(cfg, p["attn_norm"], x)
    if cfg.attention == "mla":
        h = attn.apply_mla(p["attn"], h, cfg, positions=positions)
    else:
        h = attn.apply_gqa(p["attn"], h, cfg, positions=positions, use_pallas=cfg.use_pallas)
    x = x + h
    h = _norm_apply(cfg, p["mlp_norm"], x)
    h = apply_mlp(p["mlp"], h, act=cfg.act, use_pallas=cfg.use_pallas)
    return x + h


def _moe_block(cfg, p, x, positions):
    h = _norm_apply(cfg, p["attn_norm"], x)
    if cfg.attention == "mla":
        h = attn.apply_mla(p["attn"], h, cfg, positions=positions)
    else:
        h = attn.apply_gqa(p["attn"], h, cfg, positions=positions, use_pallas=cfg.use_pallas)
    x = x + h
    h = _norm_apply(cfg, p["mlp_norm"], x)
    h, aux = apply_moe(p["moe"], h, cfg, capacity_factor=cfg.capacity_factor,
                       use_pallas=cfg.use_pallas)
    return x + h, aux


def _hybrid_period_fwd(cfg, pp, x, positions):
    aux_total = jnp.float32(0.0)
    for p in range(cfg.attn_every):
        lp = pp[f"p{p}"]
        h = _norm_apply(cfg, lp["pre_norm"], x)
        if "attn" in lp:
            h = attn.apply_gqa(lp["attn"], h, cfg, positions=positions, use_pallas=cfg.use_pallas)
        else:
            h = mamba_mod.apply_mamba(lp["mamba"], h, cfg)
        x = x + h
        h = _norm_apply(cfg, lp["ff_norm"], x)
        if "moe" in lp:
            h, aux = apply_moe(lp["moe"], h, cfg, capacity_factor=cfg.capacity_factor,
                               use_pallas=cfg.use_pallas)
            aux_total = aux_total + aux
        else:
            h = apply_mlp(lp["mlp"], h, act=cfg.act, use_pallas=cfg.use_pallas)
        x = x + h
    return x, aux_total


def _xlstm_period_fwd(cfg, pp, x, positions):
    for p in range(cfg.slstm_every):
        lp = pp[f"p{p}"]
        h = _norm_apply(cfg, lp["pre_norm"], x)
        if "slstm" in lp:
            h = xlstm_mod.apply_slstm(lp["slstm"], h, cfg)
        else:
            h = xlstm_mod.apply_mlstm(lp["mlstm"], h, cfg)
        x = x + h
    return x


def _scan_stack(stacked_params, x, body, cfg):
    """lax.scan over the leading layer axis of stacked params, with
    optional remat of the body (activation recompute in backward)."""

    def f(carry, layer_p):
        return constrain_activation(body(layer_p, carry)), None

    if cfg.remat:
        f = jax.checkpoint(f, policy=jax.checkpoint_policies.nothing_saveable)
    out, _ = jax.lax.scan(f, x, stacked_params)
    return out


def forward_lm(params: Params, tokens: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """tokens (b, s) -> (logits (b, s, vocab) fp32-castable, aux_loss)."""
    b, s = tokens.shape
    dt = _compute_dtype(cfg)
    x = constrain_activation(apply_embedding(params["embed"], tokens, compute_dtype=dt))
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    aux = jnp.float32(0.0)

    if cfg.family == "dense_lm":
        x = _scan_stack(
            params["layers"], x,
            lambda p, h: _dense_block(cfg, p, h, positions), cfg,
        )
    elif cfg.family == "moe_lm":
        if "dense_layers" in params:
            x = _scan_stack(
                params["dense_layers"], x,
                lambda p, h: _dense_block(cfg, p, h, positions), cfg,
            )
        x, aux = _scan_moe(params["moe_layers"], x, cfg, positions)
    elif cfg.family == "hybrid":
        x, aux = _scan_hybrid(params["periods"], x, cfg, positions)
    elif cfg.family == "ssm_lm":
        x = _scan_stack(
            params["periods"], x,
            lambda p, h: _xlstm_period_fwd(cfg, p, h, positions), cfg,
        )
    else:
        raise ValueError(cfg.family)

    x = _norm_apply(cfg, params["final_norm"], x)
    logits = apply_lm_head(params["embed"], x)
    return logits, aux


def _scan_moe(stacked, x, cfg, positions):
    def f(carry, layer_p):
        h, aux = carry
        h, a = _moe_block(cfg, layer_p, h, positions)
        return (constrain_activation(h), aux + a), None

    if cfg.remat:
        f = jax.checkpoint(f, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), _ = jax.lax.scan(f, (x, jnp.float32(0.0)), stacked)
    return x, aux


def _scan_hybrid(stacked, x, cfg, positions):
    def f(carry, period_p):
        h, aux = carry
        h, a = _hybrid_period_fwd(cfg, period_p, h, positions)
        return (constrain_activation(h), aux + a), None

    if cfg.remat:
        f = jax.checkpoint(f, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), _ = jax.lax.scan(f, (x, jnp.float32(0.0)), stacked)
    return x, aux


# ======================================================================
# Loss
# ======================================================================

def cross_entropy(logits: jax.Array, labels: jax.Array, z_loss: float = 1e-4):
    """Stable CE in fp32. Works with a vocab-sharded logits tensor: the
    logsumexp reduction and the label gather lower to per-shard compute
    plus small collectives under GSPMD (no full-vocab gather)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    label_logit = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - label_logit
    loss = jnp.mean(nll)
    if z_loss:
        loss = loss + z_loss * jnp.mean(lse ** 2)
    return loss


def train_loss_lm(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig):
    logits, aux = forward_lm(params, batch["tokens"], cfg)
    loss = cross_entropy(logits, batch["labels"])
    total = loss + cfg.aux_loss_coef * aux
    return total, {"ce_loss": loss, "aux_loss": aux}
