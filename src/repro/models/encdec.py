"""Encoder-decoder model (whisper-medium backbone). The audio conv
frontend is a STUB per the assignment: ``input_specs`` supplies
precomputed frame embeddings (b, n_frames, d_model); everything after
that — encoder stack, decoder with self+cross attention, LM head — is
real. MLPs (enc + dec) are SCT-spectral when configured.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config.model_config import ModelConfig
from repro.nn import attention as attn
from repro.nn.embedding import init_embedding, apply_embedding, apply_lm_head
from repro.nn.mlp import init_mlp, apply_mlp
from repro.nn.norms import init_layernorm, apply_layernorm
from repro.models.lm import cross_entropy, _compute_dtype

Params = Dict[str, Any]


def _init_enc_layer(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": init_layernorm(cfg.d_model),
        "attn": attn.init_gqa(k1, cfg),
        "mlp_norm": init_layernorm(cfg.d_model),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, rank=cfg.mlp_rank, act="gelu", bias=True),
    }


def _init_dec_layer(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "attn_norm": init_layernorm(cfg.d_model),
        "attn": attn.init_gqa(k1, cfg),
        "xattn_norm": init_layernorm(cfg.d_model),
        "xattn": attn.init_cross_attn(k2, cfg),
        "mlp_norm": init_layernorm(cfg.d_model),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, rank=cfg.mlp_rank, act="gelu", bias=True),
    }


def init_encdec(key, cfg: ModelConfig) -> Params:
    ke, kenc, kdec, kp1, kp2 = jax.random.split(key, 5)
    Le = cfg.n_encoder_layers or cfg.n_layers
    Ld = cfg.n_layers
    return {
        "embed": init_embedding(ke, cfg.vocab, cfg.d_model),
        "enc_pos": {"w": (jax.random.normal(kp1, (cfg.encoder_seq, cfg.d_model)) * 0.02)},
        "dec_pos": {"w": (jax.random.normal(kp2, (cfg.max_seq, cfg.d_model)) * 0.02)},
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(jax.random.split(kenc, Le)),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(jax.random.split(kdec, Ld)),
        "enc_norm": init_layernorm(cfg.d_model),
        "final_norm": init_layernorm(cfg.d_model),
    }


def encode(params, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """frames: (b, n_frames, d) precomputed embeddings (conv stub)."""
    dt = _compute_dtype(cfg)
    s = frames.shape[1]
    x = frames.astype(dt) + params["enc_pos"]["w"][:s].astype(dt)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], frames.shape[:2])

    def f(carry, layer_p):
        h = apply_layernorm(layer_p["attn_norm"], carry)
        h = attn.apply_gqa(layer_p["attn"], h, cfg, positions=positions, causal=False,
                           use_pallas=cfg.use_pallas)
        x2 = carry + h
        h = apply_layernorm(layer_p["mlp_norm"], x2)
        h = apply_mlp(layer_p["mlp"], h, act="gelu", use_pallas=cfg.use_pallas)
        return x2 + h, None

    if cfg.remat:
        f = jax.checkpoint(f, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(f, x, params["enc_layers"])
    return apply_layernorm(params["enc_norm"], x)


def _dec_block(cfg, layer_p, x, enc_out, positions, cache=None, cache_len=None):
    h = apply_layernorm(layer_p["attn_norm"], x)
    if cache is None:
        h = attn.apply_gqa(layer_p["attn"], h, cfg, positions=positions,
                           use_pallas=cfg.use_pallas)
    else:
        h, cache = attn.apply_gqa_decode(layer_p["attn"], h, cfg, cache=cache,
                                         cache_len=cache_len, use_pallas=cfg.use_pallas)
    x = x + h
    h = apply_layernorm(layer_p["xattn_norm"], x)
    h = attn.apply_cross_attn(layer_p["xattn"], h, enc_out, cfg)
    x = x + h
    h = apply_layernorm(layer_p["mlp_norm"], x)
    h = apply_mlp(layer_p["mlp"], h, act="gelu", use_pallas=cfg.use_pallas)
    return x + h, cache


def decode_train(params, tokens, enc_out, cfg) -> jax.Array:
    dt = _compute_dtype(cfg)
    b, s = tokens.shape
    x = apply_embedding(params["embed"], tokens, compute_dtype=dt)
    x = x + params["dec_pos"]["w"][:s].astype(dt)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def f(carry, layer_p):
        out, _ = _dec_block(cfg, layer_p, carry, enc_out, positions)
        return out, None

    if cfg.remat:
        f = jax.checkpoint(f, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(f, x, params["dec_layers"])
    x = apply_layernorm(params["final_norm"], x)
    return apply_lm_head(params["embed"], x)


def train_loss_encdec(params, batch, cfg):
    enc_out = encode(params, batch["encoder_frames"], cfg)
    logits = decode_train(params, batch["tokens"], enc_out, cfg)
    loss = cross_entropy(logits, batch["labels"])
    return loss, {"ce_loss": loss, "aux_loss": jnp.float32(0.0)}


def encdec_state_specs(cfg, batch, max_seq):
    kvh, hd = cfg.n_kv_heads, cfg.head_dim
    spec = {
        "k": jax.ShapeDtypeStruct((cfg.n_layers, batch, max_seq, kvh, hd), jnp.bfloat16),
        "v": jax.ShapeDtypeStruct((cfg.n_layers, batch, max_seq, kvh, hd), jnp.bfloat16),
    }
    return {"cache": spec}


def prefill_encdec(params, tokens, cfg, state, encoder_frames):
    """Encode audio + run the decoder prompt, filling self-attn cache."""
    enc_out = encode(params, encoder_frames, cfg)
    dt = _compute_dtype(cfg)
    b, s = tokens.shape
    x = apply_embedding(params["embed"], tokens, compute_dtype=dt)
    x = x + params["dec_pos"]["w"][:s].astype(dt)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def f(carry, xs):
        layer_p, cache = xs
        h = apply_layernorm(layer_p["attn_norm"], carry)
        h, cache = attn.apply_gqa_prefill(layer_p["attn"], h, cfg, positions=positions,
                                          cache=cache, use_pallas=cfg.use_pallas)
        x2 = carry + h
        h = apply_layernorm(layer_p["xattn_norm"], x2)
        h = attn.apply_cross_attn(layer_p["xattn"], h, enc_out, cfg)
        x2 = x2 + h
        h = apply_layernorm(layer_p["mlp_norm"], x2)
        h = apply_mlp(layer_p["mlp"], h, act="gelu", use_pallas=cfg.use_pallas)
        return x2 + h, cache

    x, new_cache = jax.lax.scan(f, x, (params["dec_layers"], state["cache"]))
    x = apply_layernorm(params["final_norm"], x[:, -1:, :])
    return apply_lm_head(params["embed"], x), {"cache": new_cache}


def decode_step_encdec(params, tokens, state, cache_len, cfg, encoder_out):
    dt = _compute_dtype(cfg)
    b = tokens.shape[0]
    x = apply_embedding(params["embed"], tokens, compute_dtype=dt)
    pos_emb = jnp.take(params["dec_pos"]["w"].astype(dt), cache_len[None], axis=0)
    x = x + pos_emb[None]

    def f(carry, xs):
        layer_p, cache = xs
        out, cache = _dec_block(cfg, layer_p, carry, encoder_out,
                                positions=None, cache=cache, cache_len=cache_len)
        return out, cache

    x, new_cache = jax.lax.scan(f, x, (params["dec_layers"], state["cache"]))
    x = apply_layernorm(params["final_norm"], x)
    return apply_lm_head(params["embed"], x), {"cache": new_cache}
