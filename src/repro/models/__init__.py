"""Model definitions: decoder-only LM families + encoder-decoder.

Public entry points live in ``repro.models.model``:
  init_model / train_loss / prefill / decode_step / decode_state_specs
"""
