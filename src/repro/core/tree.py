"""Pytree-wide retraction: walk a model parameter tree, retract every
spectral factor group, leave everything else untouched.

Spectral groups are dicts {"U": (..., m, k), "s": (..., k), "V": (..., n, k)}
— possibly with leading layer/expert axes (our models stack per-layer
params for lax.scan). Retractions broadcast over those axes natively.
"""
from __future__ import annotations

from typing import Any, Dict

import jax

from repro.core.spectral import is_spectral
from repro.core.retraction import retract


def _walk(tree: Any, fn) -> Any:
    """Depth-first walk replacing spectral groups via fn(group)."""
    if is_spectral(tree):
        return fn(tree)
    if isinstance(tree, dict):
        return {k: _walk(v, fn) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return type(tree)(_walk(v, fn) for v in tree)
    return tree


def retract_tree(params: Any, method: str = "qr", axis_name: str | None = None) -> Any:
    """Apply Stiefel retraction to U and V of every spectral group in the
    tree (paper Algorithm 1, lines 5-7, over the whole model)."""

    def _retract_group(g: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
        out = dict(g)
        out["U"] = retract(g["U"], method=method, axis_name=axis_name)
        out["V"] = retract(g["V"], method=method, axis_name=axis_name)
        return out

    return _walk(params, _retract_group)


def spectral_leaf_mask(params: Any) -> Any:
    """Pytree of {"U","s","V"} bools marking spectral leaves — used by the
    optimizer for per-component learning-rate groups (paper S4.3's 'clear
    next step')."""

    def _mark(g):
        return {k: (k in ("U", "s", "V")) for k in g}

    def _walk_mask(tree):
        if is_spectral(tree):
            return _mark(tree)
        if isinstance(tree, dict):
            return {k: _walk_mask(v) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            return type(tree)(_walk_mask(v) for v in tree)
        return False

    return _walk_mask(params)


def max_orthogonality_error(params: Any) -> jax.Array:
    """Max ortho error over all spectral factors in the tree (diagnostic,
    matches the paper's Table 2 'Ortho. Error' row)."""
    import jax.numpy as jnp
    from repro.core.manifold import orthogonality_error

    errs = []

    def _collect(g):
        errs.append(orthogonality_error(g["U"]))
        errs.append(orthogonality_error(g["V"]))
        return g

    _walk(params, _collect)
    if not errs:
        return jnp.float32(0.0)
    return jnp.max(jnp.stack(errs))
