"""SpectralLinear: the paper's permanent truncated-SVD parameterization.

A weight matrix ``W (m, n)`` is stored as ``U (m, k)``, ``s (k,)``,
``V (n, k)`` with ``W = U @ diag(s) @ V.T``. The dense ``W`` is never
materialized — forward/backward flow through the three small factors
(paper Eq. 1–4).

Parameters live in plain dicts so they compose with pjit/shard_map and
our from-scratch optimizer without a module framework.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

# A spectral parameter group is a dict with exactly these keys. Code
# elsewhere (optimizer wrapper, sharding rules, retraction walker)
# recognizes spectral leaves by this structure.
SPECTRAL_KEYS = ("U", "s", "V")

# The spectral group type: {"U": (..., m, k), "s": (..., k),
# "V": (..., n, k)} with U, V column-orthonormal and an optional
# vmap-stacked layer/expert prefix "...". W = U @ diag(s) @ V.T is
# implied, never materialized.
SpectralParams = Dict[str, jax.Array]


def spectral_init(
    key: jax.Array,
    m: int,
    n: int,
    k: int,
    dtype: Any = jnp.float32,
    scale: float | None = None,
) -> SpectralParams:
    """Initialize spectral factors for from-scratch training.

    U, V get orthonormal columns (QR of Gaussian). The singular values
    decay geometrically and are scaled so the implied dense matrix has
    the same Frobenius norm as a LeCun-normal dense init:
    ``E||W||_F^2 = m * n * sigma^2`` with ``sigma^2 = 1/m`` (fan-in), and
    ``||U diag(s) V^T||_F^2 = ||s||_2^2``.
    """
    if k > min(m, n):
        raise ValueError(f"rank {k} exceeds min(m={m}, n={n})")
    ku, kv = jax.random.split(key)
    u0 = jax.random.normal(ku, (m, k), dtype=jnp.float32)
    v0 = jax.random.normal(kv, (n, k), dtype=jnp.float32)
    U, _ = jnp.linalg.qr(u0)
    V, _ = jnp.linalg.qr(v0)
    sigma = scale if scale is not None else 1.0 / math.sqrt(m)
    # geometric decay over the retained spectrum (condition ~ 100)
    decay = jnp.logspace(0.0, -2.0, k)
    s = decay * (sigma * math.sqrt(m * n) / jnp.linalg.norm(decay))
    return {
        "U": U.astype(dtype),
        "s": s.astype(dtype),
        "V": V.astype(dtype),
    }


def spectral_apply(params: SpectralParams, x: jax.Array) -> jax.Array:
    """Forward pass ``y = ((x @ U) * s) @ V.T`` — paper Eq. 2–4.

    Three small matmuls, O(b*k*(m+n)) FLOPs. No (m, n) tensor exists;
    autograd through this function yields factor-shaped gradients only.

    Mixed precision note: the factors cast to ``x.dtype`` at apply time,
    so the compute dtype is whatever the embedding cast chose
    (PrecisionPolicy.compute_dtype via cfg.dtype) while the stored
    masters keep their own dtype — the apply-time-cast contract.
    """
    U, s, V = params["U"], params["s"], params["V"]
    h = x @ U.astype(x.dtype)        # (..., k)   cost O(b m k)
    h = h * s.astype(h.dtype)        # (..., k)   cost O(b k)
    return h @ V.T.astype(x.dtype)   # (..., n)   cost O(b k n)


def spectral_param_count(m: int, n: int, k: int) -> int:
    """Stored numbers for one rank-k spectral layer: ``k(m + n + 1)``
    (U is (m, k), V is (n, k), s is (k,)) — the paper's §3 storage
    analysis. Compare :func:`dense_param_count` for the ``m·n`` matrix
    the factors replace; the ratio is the layer's compression factor."""
    return k * (m + n + 1)


def dense_param_count(m: int, n: int) -> int:
    """Stored numbers for the dense ``(m, n)`` weight the spectral
    parameterization never materializes: ``m·n``. The denominator of
    every compression claim in the paper's Table 1."""
    return m * n


def is_spectral(params: Any) -> bool:
    """True if this pytree node is a spectral parameter group."""
    return (
        isinstance(params, dict)
        and set(params.keys()) >= set(SPECTRAL_KEYS)
        and all(hasattr(params[k], "ndim") for k in SPECTRAL_KEYS)
        and params["U"].ndim >= 2
        and params["s"].ndim == params["U"].ndim - 1
    )
