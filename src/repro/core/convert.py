"""Dense <-> spectral conversion (truncated SVD) and energy-based rank
selection (paper S4.4's '95% energy retention' mode)."""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core.spectral import SpectralParams


def dense_to_spectral(W: jax.Array, k: int, dtype: Any = None) -> SpectralParams:
    """Truncated SVD of a dense (m, n) matrix -> rank-k spectral factors.

    ``W ~= U @ diag(s) @ V.T`` with U (m, k), V (n, k). This is the
    conversion the paper applies to pretrained checkpoints (S4.2, S4.4);
    it is exact when k >= rank(W).
    """
    dtype = dtype or W.dtype
    Wf = W.astype(jnp.float32)
    u, s, vt = jnp.linalg.svd(Wf, full_matrices=False)
    return {
        "U": u[..., :, :k].astype(dtype),
        "s": s[..., :k].astype(dtype),
        "V": jnp.swapaxes(vt, -1, -2)[..., :, :k].astype(dtype),
    }


def spectral_to_dense(params: SpectralParams) -> jax.Array:
    """Materialize the dense matrix. FOR TESTS/EXPORT ONLY — the training
    and serving paths never call this (the paper's core invariant)."""
    U, s, V = params["U"], params["s"], params["V"]
    return jnp.einsum("...mk,...k,...nk->...mn", U, s, V)


def rank_for_energy(s: jax.Array, energy: float = 0.95) -> int:
    """Smallest k with sum_{i<=k} s_i^2 >= energy * sum s_i^2.

    Used for the paper's SmolLM2-135M gradient-integrity experiment
    ('converted to spectral form at 95% energy retention'). Host-side
    (returns a Python int) — rank choice happens at model build time.
    """
    s2 = jnp.sort(jnp.asarray(s) ** 2)[::-1]
    cum = jnp.cumsum(s2)
    total = cum[-1]
    k = int(jnp.searchsorted(cum, energy * total) + 1)
    return min(k, s2.shape[0])


def convert_mlp_tree_to_spectral(params, energy: float = 0.95):
    """Walk a dense parameter tree and convert every MLP projection
    (paths containing '/mlp/') to spectral form via truncated SVD at the
    given energy retention — the paper's S4.4 conversion. Stacked-layer
    weights (L, m, n) use the max rank over layers so the stack stays
    scannable. Returns (new_params, chosen_ranks)."""
    ranks = []

    def conv(tree, path=""):
        if isinstance(tree, dict):
            if set(tree.keys()) == {"w"} and ("/mlp/" in path + "/"):
                W = tree["w"]
                s = jnp.linalg.svd(W, compute_uv=False)
                if W.ndim == 3:  # stacked layers
                    k = max(rank_for_energy(s[i], energy) for i in range(s.shape[0]))
                else:
                    k = rank_for_energy(s, energy)
                ranks.append(k)
                return dense_to_spectral(W, k)
            return {kk: conv(vv, f"{path}/{kk}") for kk, vv in tree.items()}
        return tree

    return conv(params), ranks


def truncation_error(W: jax.Array, params: SpectralParams) -> jax.Array:
    """||W - U diag(s) V^T||_F — tests compare against the Eckart-Young
    optimum."""
    return jnp.linalg.norm(W.astype(jnp.float32) - spectral_to_dense(params).astype(jnp.float32))
