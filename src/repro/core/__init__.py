"""Core SCT (Spectral Compact Training) library.

The paper's contribution: permanent truncated-SVD parameterization
``W = U @ diag(s) @ V.T`` with Stiefel QR retraction after each optimizer
step. The dense matrix is never materialized.
"""
from repro.core.spectral import (
    SpectralParams,
    spectral_init,
    spectral_apply,
    spectral_param_count,
    dense_param_count,
)
from repro.core.convert import (
    dense_to_spectral,
    spectral_to_dense,
    rank_for_energy,
)
from repro.core.retraction import (
    qr_retract,
    cholesky_qr2_retract,
    cayley_retract,
    retract,
    RETRACTIONS,
)
from repro.core.manifold import (
    orthogonality_error,
    project_tangent,
)
from repro.core.precision import (
    PrecisionPolicy,
    POLICIES,
    precision_policy,
    cast_tree,
    loss_scale_init,
    loss_scale_update,
    scale_loss,
    unscale_grads,
    all_finite,
)
from repro.core.tree import retract_tree, spectral_leaf_mask

__all__ = [
    "SpectralParams",
    "spectral_init",
    "spectral_apply",
    "spectral_param_count",
    "dense_param_count",
    "dense_to_spectral",
    "spectral_to_dense",
    "rank_for_energy",
    "PrecisionPolicy",
    "POLICIES",
    "precision_policy",
    "cast_tree",
    "loss_scale_init",
    "loss_scale_update",
    "scale_loss",
    "unscale_grads",
    "all_finite",
    "qr_retract",
    "cholesky_qr2_retract",
    "cayley_retract",
    "retract",
    "RETRACTIONS",
    "orthogonality_error",
    "project_tangent",
    "retract_tree",
    "spectral_leaf_mask",
]
