"""Precision policy: param / compute / accum dtypes plus dynamic loss
scaling with overflow skip.

The paper's memory-wall argument (Table 1) is made entirely in fp32; a
production system needs an explicit precision contract. Three presets:

  fp32   — everything fp32 (the paper's setting; numerics baseline).
  bf16   — bf16 factors AND bf16 compute: the memory-minimal, numerically
           fragile mode. QR retraction on bf16-stored factors is exactly
           the instability the property tests in tests/test_precision.py
           pin down (orthogonality error is bounded by bf16 eps, ~8e-3).
  mixed  — the production policy: *master* spectral factors U/s/V (and
           all dense params + Adam moments) stay fp32; the forward casts
           to bf16 at apply time; the loss is multiplied by a dynamic
           scale and gradients are unscaled before the update. A step
           whose unscaled gradients contain inf/nan is *skipped* (params,
           moments and retraction untouched) and the scale backs off.

Loss-scale state is a tiny pytree that lives inside the TrainState, so
checkpointing, restart bit-exactness, and sharding (replicated) all come
for free from the existing runtime.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Union

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """The precision contract for one training run: storage dtype of
    params/masters (``param_dtype``), forward/backward activation dtype
    (``compute_dtype``), microbatch gradient-accumulation dtype
    (``accum_dtype``), and the dynamic loss-scaling constants used when
    ``loss_scaling`` is on. Use the ``POLICIES`` presets ('fp32',
    'bf16', 'mixed') via :func:`precision_policy` rather than building
    one by hand; ``*_jnp`` properties expose the resolved jnp dtypes."""
    name: str = "fp32"
    param_dtype: str = "float32"      # storage dtype of params / masters
    compute_dtype: str = "float32"    # forward/backward activation dtype
    accum_dtype: str = "float32"      # gradient accumulation (microbatch)
    loss_scaling: bool = False        # dynamic loss scale + overflow skip
    init_scale: float = 2.0 ** 15
    growth_factor: float = 2.0
    backoff_factor: float = 0.5
    growth_interval: int = 2000       # finite steps between scale doublings
    min_scale: float = 1.0
    max_scale: float = 2.0 ** 24

    @property
    def param_jnp(self):
        return jnp.dtype(self.param_dtype)

    @property
    def compute_jnp(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def accum_jnp(self):
        return jnp.dtype(self.accum_dtype)


POLICIES: Dict[str, PrecisionPolicy] = {
    "fp32": PrecisionPolicy(),
    "bf16": PrecisionPolicy(name="bf16", param_dtype="bfloat16",
                            compute_dtype="bfloat16"),
    "mixed": PrecisionPolicy(name="mixed", compute_dtype="bfloat16",
                             loss_scaling=True),
}

# the pre-preset behaviour as an explicit, nameable mode: compute in
# ModelConfig.dtype, fp32 accumulation, no loss scaling, params stored
# as init_model made them. The optimizer represents it as precision=None
# (no cast at init, no scaling branch); everything dtype-shaped goes
# through effective_policy instead of sentinel-None checks.
LEGACY = "legacy"


def precision_policy(policy: Union[str, PrecisionPolicy, None]) -> Optional[PrecisionPolicy]:
    """Resolve a policy by name ('legacy' | 'fp32' | 'bf16' | 'mixed'),
    pass through a PrecisionPolicy, or return None. Both None and
    'legacy' mean the legacy mode — compute dtype from ModelConfig.dtype,
    no loss scaling — for which the optimizer-facing policy is None;
    resolve its effective dtypes with :func:`effective_policy`."""
    if policy is None or isinstance(policy, PrecisionPolicy):
        return policy
    if policy == LEGACY:
        return None
    try:
        return POLICIES[policy]
    except KeyError:
        raise ValueError(
            f"unknown precision {policy!r}; options "
            f"{[LEGACY, *POLICIES]}") from None


def effective_policy(cfg, policy: Union[str, PrecisionPolicy, None]) -> PrecisionPolicy:
    """The *resolved* precision contract for a (config, policy) pair —
    always a concrete PrecisionPolicy, never a sentinel. Legacy mode
    resolves to ``cfg.dtype`` compute with fp32 accumulation and no
    scaling; presets pass through. Step builders key every dtype and
    scaling decision on this, so 'no policy given' is just another
    policy rather than a None threaded through the stack."""
    pol = precision_policy(policy)
    if pol is not None:
        return pol
    return PrecisionPolicy(name=LEGACY, compute_dtype=cfg.dtype)


def cast_tree(tree: Any, dtype) -> Any:
    """Cast every floating-point leaf; integer leaves (step counters,
    token ids) pass through untouched."""
    def cast(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree.map(cast, tree)


# ----------------------------------------------------------------- loss scale

LossScaleState = Dict[str, jax.Array]   # {"scale", "good_steps", "skipped"}


def loss_scale_init(policy: PrecisionPolicy) -> LossScaleState:
    """Fresh loss-scale state: ``{"scale": f32 (init_scale),
    "good_steps": i32, "skipped": i32}`` — all 0-d, living inside the
    TrainState so checkpoints restore the schedule bit-exactly.
    ``skipped`` is the lifetime overflow-skip counter the train loop
    surfaces as ``overflow_steps``."""
    return {
        "scale": jnp.float32(policy.init_scale),
        "good_steps": jnp.zeros((), jnp.int32),
        "skipped": jnp.zeros((), jnp.int32),
    }


def all_finite(tree: Any) -> jax.Array:
    """Scalar bool: every floating-point leaf of the pytree is finite
    (no inf/nan anywhere). The overflow check the mixed-precision
    optimizer runs on unscaled gradients to decide whether to apply or
    skip the step; integer leaves are ignored, an all-integer tree is
    vacuously True."""
    checks = [jnp.all(jnp.isfinite(leaf)) for leaf in jax.tree.leaves(tree)
              if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.floating)]
    if not checks:
        return jnp.bool_(True)
    return functools.reduce(jnp.logical_and, checks)


def loss_scale_update(state: LossScaleState, finite: jax.Array,
                      policy: PrecisionPolicy) -> LossScaleState:
    """Dynamic loss-scale schedule: after ``growth_interval`` consecutive
    finite steps the scale doubles (capped at max_scale); an overflow
    halves it (floored at min_scale) and resets the streak."""
    good = state["good_steps"] + 1
    grow = good >= policy.growth_interval
    grown = jnp.minimum(state["scale"] * policy.growth_factor,
                        jnp.float32(policy.max_scale))
    scale_ok = jnp.where(grow, grown, state["scale"])
    good_ok = jnp.where(grow, 0, good)
    scale_bad = jnp.maximum(state["scale"] * policy.backoff_factor,
                            jnp.float32(policy.min_scale))
    return {
        "scale": jnp.where(finite, scale_ok, scale_bad).astype(jnp.float32),
        "good_steps": jnp.where(finite, good_ok, 0).astype(jnp.int32),
        "skipped": (state["skipped"] + jnp.where(finite, 0, 1)).astype(jnp.int32),
    }


def scale_loss(loss: jax.Array, state: Optional[LossScaleState]) -> jax.Array:
    """Multiply a scalar loss by the current dynamic scale before
    differentiation (so small bf16 gradients don't flush to zero);
    identity when ``state`` is None — the degrade-gracefully path for
    states restored from a non-scaling checkpoint. The scale is a power
    of two, so dividing the reported loss back out is exact."""
    return loss if state is None else loss * state["scale"].astype(loss.dtype)


def unscale_grads(grads: Any, state: LossScaleState) -> Any:
    """Divide scaled gradients back down (and promote to fp32 — the
    dtype AdamW's moment math runs in) before the finiteness check and
    the update. Mirrors :func:`scale_loss`: whatever the step builder
    multiplied in, the optimizer divides out."""
    inv = 1.0 / state["scale"]
    return jax.tree.map(lambda g: g.astype(jnp.float32) * inv, grads)
