"""Stiefel manifold utilities: diagnostics and tangent-space projection."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def orthogonality_error(U: jax.Array) -> jax.Array:
    """max |U^T U - I| — the paper's reported 'Ortho. Error' metric
    (Table 2 reports < 2e-6 after retraction)."""
    Uf = U.astype(jnp.float32)
    G = jnp.einsum("...mk,...ml->...kl", Uf, Uf)
    eye = jnp.eye(G.shape[-1], dtype=G.dtype)
    return jnp.max(jnp.abs(G - eye))


def project_tangent(U: jax.Array, G: jax.Array) -> jax.Array:
    """Project an ambient gradient G (m, k) onto the tangent space of the
    Stiefel manifold at U:  PT(G) = G - U sym(U^T G).

    The paper takes plain Euclidean AdamW steps and relies on retraction;
    Riemannian projection before the step is an optional beyond-paper
    mode (reduces the distance the retraction must correct).
    """
    UtG = jnp.einsum("...mk,...ml->...kl", U, G)
    sym = 0.5 * (UtG + jnp.swapaxes(UtG, -1, -2))
    return G - jnp.einsum("...mk,...kl->...ml", U, sym)


def frobenius_tail(s: jax.Array, k: int) -> jax.Array:
    """Optimal rank-k approximation error sqrt(sum_{i>k} sigma_i^2)
    (Eckart-Young), used by tests to validate truncation."""
    s_sorted = jnp.sort(s)[::-1]
    return jnp.sqrt(jnp.sum(s_sorted[k:] ** 2))
