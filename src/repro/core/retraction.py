"""Stiefel-manifold retractions for spectral factors.

Paper (Algorithm 1, lines 5-7): after each AdamW step,

    Q, R = qr(U);  U <- Q * sign(diag(R))

The sign correction makes the retraction continuous (QR is unique only up
to column signs; fixing diag(R) > 0 picks the branch closest to the
pre-update factor).

Beyond-paper (DESIGN.md S2): CholeskyQR2 — the TPU/distributed-native
retraction. For a row-sharded U only the k x k Gram matrix is
all-reduced; compute is two matmuls + a tiny Cholesky instead of a
sequential Householder QR. Applied twice for fp32-grade orthogonality.
Cayley retraction is included as the paper's own cited alternative
[Li et al., 2020].

All retractions are vmappable over leading (layer / expert) axes.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Dict

import jax
import jax.numpy as jnp


def _sign_fix(Q: jax.Array, R: jax.Array) -> jax.Array:
    """Q * sign(diag(R)) with sign(0) := 1 for determinism."""
    d = jnp.diagonal(R, axis1=-2, axis2=-1)
    sign = jnp.where(d >= 0, 1.0, -1.0).astype(Q.dtype)
    return Q * sign[..., None, :]


def qr_retract(U: jax.Array) -> jax.Array:
    """Paper-faithful QR retraction with sign correction (Eq. 5):
    ``U (..., m, k) -> Q * sign(diag(R))`` where ``Q, R = qr(U)``. Maps
    a factor drifted off the Stiefel manifold by an optimizer step back
    to orthonormal columns; computed in fp32 regardless of storage
    dtype, broadcast over leading stacked axes."""
    orig_dtype = U.dtype
    Q, R = jnp.linalg.qr(U.astype(jnp.float32))
    return _sign_fix(Q, R).astype(orig_dtype)


def _cholesky_qr_once(U: jax.Array, axis_name: str | None) -> jax.Array:
    """One CholeskyQR pass: G = U^T U (psum over row shards), R = chol(G)^T,
    U <- U R^{-1}. Communication: k x k, independent of m."""
    G = jnp.einsum("...mk,...ml->...kl", U, U)
    if axis_name is not None:
        G = jax.lax.psum(G, axis_name)
    # G = R^T R with R upper-triangular  =>  chol(G) = R^T (lower)
    k = G.shape[-1]
    G = G + (1e-10 * jnp.trace(G, axis1=-2, axis2=-1)[..., None, None] / k
             ) * jnp.eye(k, dtype=G.dtype)
    L = jnp.linalg.cholesky(G)
    # Solve U_new L^T = U  =>  U_new = U L^{-T}
    Un = jax.lax.linalg.triangular_solve(
        L, U, left_side=False, lower=True, transpose_a=True
    )
    return Un


def cholesky_qr2_retract(U: jax.Array, axis_name: str | None = None) -> jax.Array:
    """CholeskyQR2 retraction (beyond-paper, distribution-friendly).

    Two passes of CholeskyQR give orthogonality error O(eps) even for
    moderately ill-conditioned inputs (cond(U) <~ 1e4 in fp32). The
    column space equals QR's; the sign convention matches the sign-fixed
    QR (both produce the factor with positive-diagonal R).

    If ``axis_name`` is given, U is interpreted as row-sharded along that
    mapped axis (inside shard_map) and the Gram matrix is psum'd.
    """
    orig_dtype = U.dtype
    Uf = U.astype(jnp.float32)
    Uf = _cholesky_qr_once(Uf, axis_name)
    Uf = _cholesky_qr_once(Uf, axis_name)
    return Uf.astype(orig_dtype)


def cayley_retract(U: jax.Array, tangent_scale: float = 1.0) -> jax.Array:
    """Cayley-transform retraction [Li et al., 2020], the paper's cited
    lower-cost alternative (S5). Projects the deviation of U from its own
    manifold point onto the tangent space and transports along a Cayley
    curve. For a point already near the manifold this acts as a
    corrective retraction like QR, at 2 solves of a k x k system when
    using the low-rank Woodbury form; here we use the full form for
    clarity (U is tall-skinny so the cost is still O(m k^2)).
    """
    orig_dtype = U.dtype
    Uf = U.astype(jnp.float32)
    # Nearest-manifold reference point via one CholeskyQR pass.
    Q = _cholesky_qr_once(Uf, None)
    # Tangent direction Delta = U - Q at Q; skew part drives the Cayley map.
    D = (Uf - Q) * tangent_scale
    A = jnp.einsum("...mk,...ml->...kl", Q, D)
    A = A - jnp.swapaxes(A, -1, -2)  # skew-symmetric k x k
    k = A.shape[-1]
    eye = jnp.eye(k, dtype=Uf.dtype)
    # Cayley: Q_new = Q (I - A/2)^{-1} (I + A/2)
    lhs = eye - 0.5 * A
    rhs = eye + 0.5 * A
    M = jnp.linalg.solve(lhs, rhs)
    out = jnp.einsum("...mk,...kl->...ml", Q, M)
    return out.astype(orig_dtype)


RETRACTIONS: Dict[str, Callable[..., jax.Array]] = {
    "qr": qr_retract,
    "cholesky_qr2": cholesky_qr2_retract,
    "cayley": cayley_retract,
}


def retract(U: jax.Array, method: str = "qr", axis_name: str | None = None,
            **kwargs) -> jax.Array:
    """Dispatch a retraction by name.

    ``axis_name`` marks U as row-sharded along that mapped axis (inside
    shard_map). Only cholesky_qr2 can honour it (the Gram matrix is
    psum'd; communication is k x k). ``qr``/``cayley`` operate on the
    local shard only — silently accepting ``axis_name`` would QR each
    shard independently and return a factor that is *not* orthonormal
    globally, so those combinations raise instead of corrupting the
    manifold. Extra kwargs go to the method (e.g. ``tangent_scale`` for
    cayley)."""
    if method == "cholesky_qr2":
        return cholesky_qr2_retract(U, axis_name=axis_name, **kwargs)
    fn = RETRACTIONS.get(method)
    if fn is None:
        raise ValueError(f"unknown retraction {method!r}; options {list(RETRACTIONS)}")
    if axis_name is not None:
        raise ValueError(
            f"retraction {method!r} cannot distribute over axis_name="
            f"{axis_name!r}: per-shard QR/Cayley of a row-sharded factor is "
            f"not globally orthonormal; use method='cholesky_qr2'")
    return fn(U, **kwargs)
