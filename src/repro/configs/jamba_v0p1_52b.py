"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2. Mamba+attention 1:7 interleave, MoE every
2nd layer [arXiv:2403.19887; hf]. No positional embeddings (mamba
provides position information).
"""
from repro.config.model_config import ModelConfig, SCTConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    moe_d_ff=14336,
    vocab=65536,
    rope="none",
    n_experts=16,
    top_k=2,
    moe_every=2,
    attn_every=8,
    attn_offset=4,
    mamba_expand=2,
    mamba_d_state=16,
    mamba_d_conv=4,
    sct=SCTConfig(spectral_mlp=True, rank=256, retraction="cholesky_qr2"),
)

REDUCED = CONFIG.replace(
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, moe_d_ff=128, vocab=512, n_experts=4, top_k=2,
    attn_every=4, attn_offset=2, mamba_dt_rank=8, max_seq=64,
    sct=SCTConfig(spectral_mlp=True, rank=16),
)
