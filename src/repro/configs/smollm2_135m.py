"""SmolLM2-135M — the paper's gradient-integrity model (Table 4). 30L
d_model=576 9H (kv=3) d_ff=1536 vocab=49152. Converted to spectral at
95% energy in benchmarks/table4."""
from repro.config.model_config import ModelConfig, SCTConfig

CONFIG = ModelConfig(
    name="smollm2-135m",
    family="dense_lm",
    seq_parallel=True,
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    head_dim=64,
    d_ff=1536,
    vocab=49152,
    rope="rope",
    rope_theta=100_000.0,
    tie_embeddings=True,
    sct=SCTConfig(spectral_mlp=True, rank=128, energy=0.95, retraction="qr"),
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=192, vocab=512, max_seq=64,
    sct=SCTConfig(spectral_mlp=True, rank=16, retraction="qr"),
)
