"""deepseek-v2-236b [moe] — 60L d_model=5120 128H d_ff=1536 (expert)
vocab=102400, MoE 160e top-6, MLA kv_lora=512, 2 shared experts, first
layer dense (d_ff=12288) [arXiv:2405.04434; hf]."""
from repro.config.model_config import ModelConfig, SCTConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe_lm",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=12288,
    moe_d_ff=1536,
    vocab=102400,
    rope="rope",
    rope_theta=10_000.0,
    attention="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    head_dim=192,
    n_experts=160,
    n_shared_experts=2,
    top_k=6,
    first_dense_layers=1,
    capacity_factor=1.25,
    sct=SCTConfig(spectral_mlp=True, rank=128, retraction="cholesky_qr2"),
)

REDUCED = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160, moe_d_ff=48,
    vocab=512, q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
    qk_rope_dim=8, v_head_dim=16, head_dim=24, n_experts=4,
    n_shared_experts=2, top_k=2, first_dense_layers=1, max_seq=64,
    sct=SCTConfig(spectral_mlp=True, rank=16),
)
