"""One config module per assigned architecture (+ the paper's own).

Each module exports CONFIG (the exact published dims) and REDUCED (a
same-family small config for CPU smoke tests).
"""
