"""qwen1.5-0.5b [dense] — 24L d_model=1024 16H (kv=16) d_ff=2816
vocab=151936. QKV bias [hf:Qwen/Qwen1.5-0.5B; hf]."""
from repro.config.model_config import ModelConfig, SCTConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense_lm",
    seq_parallel=True,
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=2816,
    vocab=151936,
    qkv_bias=True,
    rope="rope",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    sct=SCTConfig(spectral_mlp=True, rank=128, retraction="cholesky_qr2"),
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=512, max_seq=64,
    sct=SCTConfig(spectral_mlp=True, rank=16),
)
