"""llama3.2-1b [dense] — 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256 [hf:meta-llama/Llama-3.2-1B; unverified]."""
from repro.config.model_config import ModelConfig, SCTConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    family="dense_lm",
    seq_parallel=True,
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab=128256,
    rope="rope",
    rope_theta=500_000.0,
    tie_embeddings=True,
    sct=SCTConfig(spectral_mlp=True, rank=128, retraction="cholesky_qr2"),
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512, max_seq=64,
    sct=SCTConfig(spectral_mlp=True, rank=16),
)
