"""LLaMA-70B-class architecture — the paper's S4.1 memory validation
(80L, d=8192, ffn=28672, SwiGLU) at spectral rank 32. Unlike the paper's
simplified additive attention, our attention is the real GQA softmax
attention — the memory claim must survive the real thing."""
from repro.config.model_config import ModelConfig, SCTConfig

CONFIG = ModelConfig(
    name="llama-70b-sct",
    family="dense_lm",
    seq_parallel=True,
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab=128256,
    rope="rope",
    rope_theta=500_000.0,
    sct=SCTConfig(spectral_mlp=True, rank=32, retraction="qr"),
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=224, vocab=512, max_seq=64,
    sct=SCTConfig(spectral_mlp=True, rank=8, retraction="qr"),
)
