"""qwen2-vl-72b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064. M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

The vision frontend is a STUB per the assignment: the transformer
backbone consumes token embeddings; ``repro.data.vision_stub`` can merge
precomputed patch embeddings. M-RoPE is real (nn/rotary.py) and reduces
to RoPE on text-only positions.
"""
from repro.config.model_config import ModelConfig, SCTConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="dense_lm",
    seq_parallel=True,
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab=152064,
    qkv_bias=True,              # qwen2 uses QKV bias
    rope="mrope",
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),
    sct=SCTConfig(spectral_mlp=True, rank=256, retraction="cholesky_qr2"),
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512, max_seq=64, mrope_sections=(2, 3, 3),
    sct=SCTConfig(spectral_mlp=True, rank=16),
)
