"""SmolLM2-1.7B — the paper's rank-sweep model (Table 3). 24L
d_model=2048 32H d_ff=8192 vocab=49152. MLP layer (2048 x 8192) matches
the paper's Table 1 row."""
from repro.config.model_config import ModelConfig, SCTConfig

CONFIG = ModelConfig(
    name="smollm2-1.7b",
    family="dense_lm",
    seq_parallel=True,
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=49152,
    rope="rope",
    rope_theta=130_000.0,
    tie_embeddings=True,
    sct=SCTConfig(spectral_mlp=True, rank=128, retraction="qr"),  # paper-faithful
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=256, vocab=512, max_seq=64,
    sct=SCTConfig(spectral_mlp=True, rank=16, retraction="qr"),
)
