"""qwen1.5-4b [dense] — 40L d_model=2560 20H (kv=20, i.e. MHA) d_ff=6912
vocab=151936. QKV bias [hf:Qwen/Qwen1.5-4B; hf]."""
from repro.config.model_config import ModelConfig, SCTConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense_lm",
    seq_parallel=True,
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab=151936,
    qkv_bias=True,
    rope="rope",
    rope_theta=1_000_000.0,
    sct=SCTConfig(spectral_mlp=True, rank=128, retraction="cholesky_qr2"),
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=512, max_seq=64,
    sct=SCTConfig(spectral_mlp=True, rank=16),
)
