"""xlstm-1.3b [ssm] — 48L d_model=2048 4H vocab=50304, d_ff=0 (blocks
carry their own projections); sLSTM:mLSTM 1:7 [arXiv:2405.04517;
unverified]. Sub-quadratic: runs the long_500k decode cell.
"""
from repro.config.model_config import ModelConfig, SCTConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm_lm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab=50304,
    rope="none",
    slstm_every=8,
    slstm_offset=7,
    sct=SCTConfig(spectral_mlp=True, rank=128, retraction="cholesky_qr2"),
)

REDUCED = CONFIG.replace(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    vocab=512, slstm_every=2, slstm_offset=1, max_seq=64,
    sct=SCTConfig(spectral_mlp=True, rank=16),
)
