"""whisper-medium [audio] — 24L d_model=1024 16H d_ff=4096 vocab=51865.
Encoder-decoder; conv frontend is a STUB (input_specs supplies
precomputed frame embeddings) [arXiv:2212.04356; unverified].
"""
from repro.config.model_config import ModelConfig, SCTConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    seq_parallel=True,
    n_layers=24,               # decoder layers
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=51865,
    qkv_bias=True,
    rope="none",               # whisper uses learned absolute positions
    act="gelu",
    norm="layernorm",
    encoder_seq=1500,
    max_seq=32_768,            # decode_32k cell needs positions up to 32k
    sct=SCTConfig(spectral_mlp=True, rank=128, retraction="cholesky_qr2"),
)

REDUCED = CONFIG.replace(
    n_layers=2, n_encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=128, vocab=512, encoder_seq=32, max_seq=64,
    sct=SCTConfig(spectral_mlp=True, rank=16),
)
