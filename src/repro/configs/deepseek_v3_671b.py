"""deepseek-v3-671b [moe] — 61L d_model=7168 128H d_ff=2048 (expert)
vocab=129280, MoE 256e top-8, MLA (kv_lora=512, q_lora=1536), 1 shared
expert, first 3 layers dense (d_ff=18432) [arXiv:2412.19437; hf].

This is SCT's most valuable cell: routed-expert MLPs hold ~95% of the
parameters, and every expert is spectral. MTP (multi-token prediction)
is a training objective add-on, not an architecture change; noted as not
implemented (DESIGN.md S7).
"""
from repro.config.model_config import ModelConfig, SCTConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe_lm",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,               # the first_dense_layers MLP width
    moe_d_ff=2048,
    vocab=129280,
    rope="rope",
    rope_theta=10_000.0,
    attention="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    head_dim=192,             # nope + rope
    n_experts=256,
    n_shared_experts=1,
    top_k=8,
    first_dense_layers=3,
    capacity_factor=1.25,
    sct=SCTConfig(spectral_mlp=True, rank=128, retraction="cholesky_qr2"),
)

REDUCED = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160, moe_d_ff=48,
    vocab=512, q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
    qk_rope_dim=8, v_head_dim=16, head_dim=24, n_experts=4,
    n_shared_experts=1, top_k=2, first_dense_layers=1, max_seq=64,
    sct=SCTConfig(spectral_mlp=True, rank=16),
)
