"""Paper §4.4 workflow: take a trained dense model, convert its MLP
weights to spectral form at 95% energy retention (truncated SVD), and
fine-tune with Stiefel retraction — the 'gradient integrity' path.

  PYTHONPATH=src python examples/convert_pretrained.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config
from repro.core.convert import dense_to_spectral, rank_for_energy
from repro.core.tree import max_orthogonality_error
from repro.data.synthetic import SyntheticLMDataset
from repro.launch.steps import make_train_step
from repro.models.model import init_model, param_count
from repro.optim import make_sct_optimizer
from repro.core.convert import convert_mlp_tree_to_spectral


def main():
    cfg_dense = get_config("smollm2-135m", reduced=True).replace_sct(spectral_mlp=False)
    ds = SyntheticLMDataset(vocab=cfg_dense.vocab, seq_len=64, seed=0)

    print("=== step 1: pre-train a DENSE model (100 steps) ===")
    opt = make_sct_optimizer(cfg_dense, lr=2e-3, warmup=10, total_steps=250)
    state = opt.init(init_model(jax.random.PRNGKey(0), cfg_dense))
    step = jax.jit(make_train_step(cfg_dense, opt))
    for i in range(100):
        t, l = ds.batch(i, 8)
        state, m = step(state, {"tokens": jnp.asarray(t), "labels": jnp.asarray(l)})
    print(f"dense loss after pre-train: {float(m['loss']):.3f} "
          f"({param_count(state['params'])/1e3:.0f}K params)")

    print("\n=== step 2: convert MLPs to spectral @ 95% energy ===")
    spectral_params, ranks = convert_mlp_tree_to_spectral(state["params"], 0.95)
    print(f"selected ranks per MLP stack: {ranks}")
    print(f"params after conversion: {param_count(spectral_params)/1e3:.0f}K")

    print("\n=== step 3: fine-tune IN SPECTRAL FORM with QR retraction ===")
    cfg_sct = get_config("smollm2-135m", reduced=True)
    opt2 = make_sct_optimizer(cfg_sct, lr=2e-3, warmup=10, total_steps=100)
    state2 = opt2.init(spectral_params)
    step2 = jax.jit(make_train_step(cfg_sct, opt2))
    for i in range(100, 200):
        t, l = ds.batch(i, 8)
        state2, m2 = step2(state2, {"tokens": jnp.asarray(t), "labels": jnp.asarray(l)})
        if i % 25 == 0:
            print(f"step {i}: loss {float(m2['loss']):.3f}  ortho "
                  f"{float(max_orthogonality_error(state2['params'])):.2e}")
    print(f"\nfinal SCT loss {float(m2['loss']):.3f} — gradients flow through the "
          f"factored form; the dense matrices no longer exist anywhere.")


if __name__ == "__main__":
    main()
