"""Quickstart: SCT in 60 lines — build a spectral model, take training
steps with QR retraction, watch the manifold invariant hold.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.config import get_config
from repro.core.tree import max_orthogonality_error
from repro.data.synthetic import SyntheticLMDataset
from repro.launch.steps import make_train_step
from repro.models.model import init_model, param_count, dense_equivalent_param_count
from repro.optim import make_sct_optimizer


def main():
    # the paper's rank-sweep model family, smoke-test sized for CPU
    cfg = get_config("smollm2-1.7b", reduced=True)
    print(f"arch: {cfg.name} (reduced) | spectral MLP rank {cfg.sct.rank} | "
          f"retraction: {cfg.sct.retraction}")

    params = init_model(jax.random.PRNGKey(0), cfg)
    n = param_count(params)
    n_dense = dense_equivalent_param_count(params)
    print(f"spectral params: {n/1e3:.0f}K  (dense-equivalent {n_dense/1e3:.0f}K, "
          f"{n_dense/n:.2f}x compression)")

    opt = make_sct_optimizer(cfg, lr=3e-3, warmup=5, total_steps=60)
    state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))

    ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=32, seed=0)
    for i in range(60):
        tokens, labels = ds.batch(i, 8)
        state, metrics = step(state, {"tokens": jnp.asarray(tokens),
                                      "labels": jnp.asarray(labels)})
        if i % 10 == 0:
            ortho = float(max_orthogonality_error(state["params"]))
            print(f"step {i:3d}  loss {float(metrics['loss']):.4f}  "
                  f"ortho_err {ortho:.2e}")

    print("\nThe spectral factors stayed orthonormal through every update —")
    print("that's Algorithm 1: AdamW on (U, s, V), then Stiefel QR retraction.")


if __name__ == "__main__":
    main()
