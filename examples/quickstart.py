"""Quickstart: SCT through the experiment API — declare a RunSpec,
drive a Trainer step by step, watch the manifold invariant hold.

  PYTHONPATH=src python examples/quickstart.py

A RunSpec is the whole experiment as one JSON-serializable value; the
Trainer facade owns the wiring (config, optimizer, jitted step). The
same spec given a checkpoint directory runs the fault-tolerant
production loop via ``Trainer(spec).fit()`` — see examples/train_e2e.py
and docs/api.md.
"""
from repro.api import ModelSpec, RunSpec, Trainer, TrainSpec
from repro.core.tree import max_orthogonality_error
from repro.models.model import param_count, dense_equivalent_param_count


def main():
    # the paper's rank-sweep model family, smoke-test sized for CPU
    spec = RunSpec(
        model=ModelSpec("smollm2-1.7b", reduced=True),
        train=TrainSpec(steps=60, batch=8, seq=32, lr=3e-3, warmup=5),
    )
    cfg = spec.model.config()
    print(f"arch: {cfg.name} (reduced) | spectral MLP rank {cfg.sct.rank} | "
          f"retraction: {cfg.sct.retraction}")
    print("spec:", spec.to_json())

    trainer = Trainer(spec)
    n = param_count(trainer.params)
    n_dense = dense_equivalent_param_count(trainer.params)
    print(f"spectral params: {n/1e3:.0f}K  (dense-equivalent {n_dense/1e3:.0f}K, "
          f"{n_dense/n:.2f}x compression)")

    for i in range(spec.train.steps):
        metrics = trainer.step()
        if i % 10 == 0:
            ortho = float(max_orthogonality_error(trainer.params))
            print(f"step {i:3d}  loss {float(metrics['loss']):.4f}  "
                  f"ortho_err {ortho:.2e}")

    print("\nThe spectral factors stayed orthonormal through every update —")
    print("that's Algorithm 1: AdamW on (U, s, V), then Stiefel QR retraction.")


if __name__ == "__main__":
    main()
