"""Batched serving example: prefill a batch of prompts, decode with the
static-shape KV cache, report per-token latency. Exercises the same
prefill/decode_step the decode_32k dry-run cells prove at 512 devices.

  PYTHONPATH=src python examples/serve_batched.py [arch]
"""
import sys
import subprocess
import os


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "llama3.2-1b"
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    cmd = [sys.executable, "-m", "repro.launch.serve",
           "--arch", arch, "--reduced",
           "--batch", "4", "--prompt-len", "16", "--gen", "24"]
    out = subprocess.run(cmd, env=env, capture_output=True, text=True)
    print(out.stdout)
    if out.returncode != 0:
        print(out.stderr[-2000:])
        sys.exit(1)


if __name__ == "__main__":
    main()
