"""Serving demo, both modes plus the programmatic facade:

  1. static batch — prefill a batch of same-length prompts, decode with
     the dense (batch, max_seq) cache;
  2. streaming — continuous batching over a staggered mixed-length
     request trace with the paged KV cache, verified token-for-token
     against the static path;
  3. programmatic — the same paged runtime through ``repro.api.Server``:
     declare a RunSpec, submit prompts, stream completions.

  PYTHONPATH=src python examples/serve_batched.py [arch]
"""
import os
import subprocess
import sys


def run(label, extra):
    arch = sys.argv[1] if len(sys.argv) > 1 else "llama3.2-1b"
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    cmd = [sys.executable, "-m", "repro", "serve",
           "--arch", arch, "--reduced"] + extra
    print(f"--- {label}: {' '.join(cmd[3:])}")
    out = subprocess.run(cmd, env=env, capture_output=True, text=True)
    print(out.stdout)
    if out.returncode != 0:
        print(out.stderr[-2000:])
        sys.exit(1)


def run_api(arch):
    print("--- programmatic: RunSpec -> Server.submit/stream")
    import numpy as np

    from repro.api import ModelSpec, RunSpec, Server, ServeSpec

    spec = RunSpec(model=ModelSpec(arch, reduced=True),
                   serve=ServeSpec(page_size=8, num_pages=32, slots=3,
                                   pages_per_seq=4, gen=10))
    server = Server(spec)
    rng = np.random.default_rng(0)
    for n in (6, 11, 9):
        server.submit(rng.integers(0, server.cfg.vocab, size=(n,)))
    for rid, tokens, status in server.stream():
        print(f"request {rid}: {status}, {len(tokens)} tokens -> "
              f"{tokens[:8].tolist()}...")
    st = server.stats()
    print(f"{st['tokens_per_s']:.1f} tok/s, "
          f"paged cache {int(st['attn_cache_bytes'])} bytes")


def main():
    run("static batch", ["--batch", "4", "--prompt-len", "16", "--gen", "24"])
    run("streaming (paged, continuous batching)",
        ["--paged", "--stream", "--requests", "6", "--slots", "3",
         "--prompt-len", "12", "--gen", "12", "--page-size", "8",
         "--num-pages", "32", "--pages-per-seq", "4", "--verify"])
    run("streaming (shared system prompt, prefix cache + chunked prefill)",
        ["--paged", "--stream", "--requests", "6", "--slots", "3",
         "--prompt-len", "8", "--gen", "10", "--page-size", "8",
         "--num-pages", "48", "--pages-per-seq", "8",
         "--shared-prefix", "24", "--prefix-cache", "--chunked-prefill",
         "--prefill-budget", "16", "--verify"])
    run_api(sys.argv[1] if len(sys.argv) > 1 else "llama3.2-1b")


if __name__ == "__main__":
    main()
