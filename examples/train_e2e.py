"""End-to-end training driver: train a (reduced) model for a few hundred
steps through the production path — fault-tolerant loop, periodic
checkpoints, resume — and then prove restartability by rerunning.

  PYTHONPATH=src python examples/train_e2e.py
"""
import shutil
import subprocess
import sys
import os

CKPT = "/tmp/repro_e2e_ckpt"


def run_training(steps):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "smollm2-1.7b", "--reduced",
           "--steps", str(steps), "--batch", "8", "--seq", "64",
           "--lr", "3e-3", "--ckpt-dir", CKPT, "--ckpt-every", "50"]
    out = subprocess.run(cmd, env=env, capture_output=True, text=True)
    print(out.stdout)
    if out.returncode != 0:
        print(out.stderr[-2000:])
        sys.exit(1)


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    print("=== phase 1: train 200 steps (checkpoints every 50) ===")
    run_training(200)
    print("=== phase 2: extend to 300 steps — resumes from step 200 ===")
    run_training(300)
    print("done: the second run restored from the step-200 checkpoint and "
          "continued — the crash/restart path is the same code.")


if __name__ == "__main__":
    main()
