"""End-to-end training driver: train a (reduced) model for a few hundred
steps through the production path — fault-tolerant loop, periodic
checkpoints, resume — and then prove restartability two ways:

  1. rerun the same CLI command (``python -m repro train``, the thin
     RunSpec adapter) with a larger step budget — it resumes from the
     newest checkpoint;
  2. resume *programmatically* with zero re-specified flags:
     ``Trainer.resume(ckpt_dir)`` rebuilds the run from the RunSpec
     embedded in the checkpoint sidecar.

  PYTHONPATH=src python examples/train_e2e.py
"""
import shutil
import subprocess
import sys
import os

CKPT = "/tmp/repro_e2e_ckpt"


def run_training(steps):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    cmd = [sys.executable, "-m", "repro", "train",
           "--arch", "smollm2-1.7b", "--reduced",
           "--steps", str(steps), "--batch", "8", "--seq", "64",
           "--lr", "3e-3", "--ckpt-dir", CKPT, "--ckpt-every", "50"]
    out = subprocess.run(cmd, env=env, capture_output=True, text=True)
    print(out.stdout)
    if out.returncode != 0:
        print(out.stderr[-2000:])
        sys.exit(1)


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    print("=== phase 1: train 200 steps (checkpoints every 50) ===")
    run_training(200)
    print("=== phase 2: extend to 300 steps — resumes from step 200 ===")
    run_training(300)
    print("=== phase 3: zero-flag programmatic resume from the embedded "
          "RunSpec ===")
    from repro.api import Trainer

    trainer = Trainer.resume(CKPT, **{"train.steps": 320})
    state = trainer.fit()
    print(f"resumed to step {trainer.current_step} "
          f"(optimizer step counter {int(state['step'])}) with zero "
          f"re-specified flags — arch/lr/seed all came from the sidecar")
    print("done: every phase restored from the newest checkpoint and "
          "continued — the crash/restart path is the same code.")


if __name__ == "__main__":
    main()
