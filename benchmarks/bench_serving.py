"""Serving-path benchmark: the paged continuous-batching engine on a
mixed-length workload (reduced llama3.2-1b; CPU timings are indicative
— the comparison that transfers is cache bytes and tokens/s shape, not
absolute latency), against the *analytic* static-path worst case.

Static serving of a mixed stream must pad every sequence to the global
worst case: a (slots, max_seq) cache provisioned for the longest
request the server promises, decoded in waves until the longest member
finishes. That cost needs no driver — it is a closed-form byte count
(models/decode.py:lm_state_specs), which is how this file reports it;
the paged engine admits requests into slots mid-flight and sizes
memory by pages actually touched.

  PYTHONPATH=src python -m benchmarks.bench_serving

``--shared-prefix`` runs the shared-system-prompt workload instead:
every request opens with the same system prefix, and the engine is
driven twice — prefix cache off vs. on (+ chunked prefill) — reporting
prefix page hit-rate, prefill tokens saved, and p50/p99 inter-token
latency. ``--verify`` additionally checks the cached+chunked outputs
token-for-token against the static-cache oracle.

  PYTHONPATH=src python -m benchmarks.bench_serving --shared-prefix --verify

The full traffic harness (arrival processes, SLOs, multi-tenant
scheduling, BENCH_serving.json) is ``python -m repro bench serving``
(benchmarks/run.py); this module keeps the two focused comparisons
above, driven entirely through the ``Server`` facade.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.decode import lm_state_specs

ARCH = "llama3.2-1b"
SLOTS = 4
GEN = 12
PROMPT_LENS = [9, 16, 21, 12, 25, 7, 18, 14]          # 8 requests, mixed


def _workload(vocab):
    rng = np.random.default_rng(0)
    return [rng.integers(0, vocab, size=(n,)).astype(np.int32) for n in PROMPT_LENS]


def _static_cache_bytes(cfg, batch, max_seq) -> int:
    specs = lm_state_specs(cfg, batch, max_seq)
    return sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
               for s in jax.tree.leaves(specs))


def _paged_spec(quantize=None, **serve_kw):
    """The bench's RunSpec: pool sized to the workload's concurrent
    reservation fit, not the global worst case — the paged memory win."""
    from repro.api import ModelSpec, RunSpec, ServeSpec

    return RunSpec(
        model=ModelSpec(ARCH, reduced=True),
        serve=ServeSpec(page_size=8, num_pages=20, slots=SLOTS,
                        pages_per_seq=5, prefill_budget=64,
                        quantize=quantize, gen=GEN, **serve_kw),
    )


def dump_spec_json() -> str:
    """--dump-spec parity for the legacy modes: the RunSpec both
    comparisons drive (the harness's BenchSpec lives in run.py)."""
    return _paged_spec().to_json(indent=2)


def _run_paged(server, prompts):
    for i, p in enumerate(prompts):
        server.submit(p, arrival=(i // SLOTS) * 3)
    server.run()
    st = server.stats()
    return (st["tokens_per_s"], int(st["attn_cache_bytes"]),
            int(st["weight_bytes"]))


def run() -> list[str]:
    from repro.api import Server

    out = []
    print(f"# Serving bench: {ARCH} reduced, {len(PROMPT_LENS)} requests, "
          f"prompts {min(PROMPT_LENS)}..{max(PROMPT_LENS)} tokens, gen {GEN}, "
          f"{SLOTS} slots")
    server = Server(_paged_spec())          # random-init from train.seed
    cfg, params = server.cfg, server.params
    prompts = _workload(cfg.vocab)

    # the static path's cost is analytic: batch x worst-case max_seq
    bytes_s = _static_cache_bytes(cfg, SLOTS, cfg.max_seq)
    print(f"static:     (analytic)       cache {bytes_s:8d} bytes "
          f"(batch x worst-case max_seq)")
    out.append(f"serving_static,0,cache_bytes={bytes_s}")

    tps_p, bytes_p, wb_fp = _run_paged(server, prompts)
    print(f"paged fp32: {tps_p:8.1f} tok/s   cache {bytes_p:8d} bytes "
          f"(shared pool, {bytes_s / max(bytes_p, 1):.2f}x smaller)   "
          f"weights {wb_fp:8d} bytes")
    out.append(f"serving_paged,{1e6 / max(tps_p, 1e-9):.1f},"
               f"tok_s={tps_p:.1f};cache_bytes={bytes_p};weight_bytes={wb_fp}")

    # per-precision weight memory + throughput: int8 per-channel factors
    # dequantized on the fly (serving/quantize.py)
    tps_q, bytes_q, wb_q = _run_paged(
        Server(_paged_spec(quantize="int8"), params), prompts)
    print(f"paged int8: {tps_q:8.1f} tok/s   cache {bytes_q:8d} bytes   "
          f"weights {wb_q:8d} bytes ({wb_fp / max(wb_q, 1):.2f}x smaller)")
    out.append(f"serving_paged_int8,{1e6 / max(tps_q, 1e-9):.1f},"
               f"tok_s={tps_q:.1f};cache_bytes={bytes_q};weight_bytes={wb_q};"
               f"weight_reduction={wb_fp / max(wb_q, 1):.2f}x")
    return out


def run_shared_prefix(verify: bool = False) -> list[str]:
    """Shared-system-prompt workload: prefix cache off vs. on. The two
    runs differ only by a ``spec.replace`` — the declarative record of
    what the comparison toggles."""
    from repro.api import ModelSpec, RunSpec, Server, ServeSpec
    from repro.launch.serve import static_greedy_reference
    from repro.serving import Request

    base = RunSpec(
        model=ModelSpec(ARCH, reduced=True),
        serve=ServeSpec(page_size=8, num_pages=48, slots=SLOTS,
                        pages_per_seq=8, prefill_budget=16, gen=GEN),
    )
    first = Server(base)                    # random-init from train.seed
    cfg, params = first.cfg, first.params
    pcfg = base.serve.paged_config()
    rng = np.random.default_rng(0)
    system = rng.integers(0, cfg.vocab, size=(32,)).astype(np.int32)
    tails = [5, 9, 7, 12, 6, 10, 8, 11]
    # arrivals spaced so the first request's prefix lands in the index
    # before its followers are admitted (hit-rate is what we measure,
    # not admission-race behaviour)
    reqs = [Request(rid=i,
                    prompt=np.concatenate(
                        [system, rng.integers(0, cfg.vocab, size=(t,)).astype(np.int32)]),
                    max_new_tokens=GEN, arrival=i * 3)
            for i, t in enumerate(tails)]
    total_prompt = sum(r.prompt_len for r in reqs)
    print(f"# Shared-prefix bench: {ARCH} reduced, {len(reqs)} requests, "
          f"{len(system)}-token system prompt + {min(tails)}..{max(tails)} "
          f"token tails, gen {GEN}, {SLOTS} slots")

    out = []
    results = {}
    servers = {
        "off": first,
        "on ": Server(base.replace(serve={"prefix_cache": True,
                                          "chunked_prefill": True}), params),
    }
    for label, server in servers.items():
        results[label.strip()] = server.run(reqs)
        server.engine.sched.check_invariants()
        st = server.stats()
        lat = server.engine.latency_percentiles()
        saved = int(st["prompt_tokens"] - st["prefill_tokens"])
        hit = st.get("prefix_hit_pages", 0.0)
        look = max(st.get("prefix_lookup_pages", 0.0), 1.0)
        print(f"prefix cache {label}: prefill {int(st['prefill_tokens']):4d}"
              f"/{int(st['prompt_tokens'])} prompt tokens "
              f"({saved} saved, {100.0 * saved / total_prompt:.0f}%), "
              f"page hit-rate {100.0 * hit / look:.0f}%, "
              f"itl p50 {lat['itl_p50_s'] * 1e3:.1f} ms "
              f"p99 {lat['itl_p99_s'] * 1e3:.1f} ms")
        out.append(
            f"serving_prefix_{label.strip()},{1e6 / max(st['tokens_per_s'], 1e-9):.1f},"
            f"prefill_tokens={int(st['prefill_tokens'])};"
            f"saved_pct={100.0 * saved / total_prompt:.1f};"
            f"hit_rate={100.0 * hit / look:.1f};"
            f"itl_p50_ms={lat['itl_p50_s'] * 1e3:.2f};"
            f"itl_p99_ms={lat['itl_p99_s'] * 1e3:.2f}")

    if verify:
        bad = 0
        for r in reqs:
            ref = static_greedy_reference(cfg, params, r.prompt, r.max_new_tokens,
                                          pcfg.max_seq)
            for mode in ("off", "on"):
                if not np.array_equal(ref, results[mode][r.rid]):
                    bad += 1
                    print(f"request {r.rid} ({mode}): MISMATCH")
        if bad:
            raise SystemExit(f"{bad} request/mode pairs diverged from the "
                             f"static-cache oracle")
        print(f"verify: all {len(reqs)} requests token-identical to the "
              f"static-cache oracle, prefix cache off and on")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--shared-prefix", action="store_true",
                    help="run the shared-system-prompt workload "
                         "(prefix cache off vs on)")
    ap.add_argument("--verify", action="store_true",
                    help="check outputs token-for-token against the "
                         "static-cache oracle")
    ap.add_argument("--dump-spec", action="store_true",
                    help="print the RunSpec both comparisons drive")
    args = ap.parse_args()
    if args.dump_spec:
        print(dump_spec_json())
    elif args.shared_prefix:
        run_shared_prefix(verify=args.verify)
    else:
        run()


if __name__ == "__main__":
    main()
