"""Paper Table 3 / Figure 2: rank sweep (dense vs SCT r in {32..256})
on the SmolLM2-1.7B family — now a one-command sweep driver.

  PYTHONPATH=src python -m benchmarks.table3_rank_sweep \\
      --ranks 8,16,32,64 --steps 300 --json-out table3.json

One warm process runs the whole sweep: the synthetic dataset, the
config, and jax's compilation cache are shared across ranks (each rank
still compiles its own step — the shapes differ — but process startup,
backend init, and data generation are paid once). Alongside the printed
table it emits machine-readable JSON (``--json-out``, default
``table3_rank_sweep.json``): per-rank loss *curve*, train-state bytes,
process peak RSS, and step time — the BENCH_* trajectory format.

Reduced scale for CPU (same family config, smaller dims, synthetic
structured data, fewer steps), reproducing the paper's QUALITATIVE
claims, which we assert programmatically:

  1. all SCT ranks converge to a common loss floor (spread << gap to
     init),
  2. params and step time drop monotonically with rank,
  3. the dense baseline reaches a lower loss in the same budget (the
     paper's ~3-gap, driven by LR configuration).
"""
from __future__ import annotations

import argparse
import resource
import sys
import time

import jax
import numpy as np

from repro.api import ModelSpec, RunSpec, Trainer, TrainSpec
from repro.models.model import param_count

STEPS = 300
BATCH = 8
SEQ = 64
RANKS = (8, 16, 32, 64)  # scaled to the reduced model (d_ff=256)


def _state_bytes(state) -> int:
    """Bytes pinned by the train state (params + Adam moments + scalars)
    — the deterministic, per-rank memory metric (peak RSS is process-
    wide and only monotone across the whole sweep)."""
    return sum(leaf.size * leaf.dtype.itemsize for leaf in jax.tree.leaves(state))


def _peak_rss_mb() -> float:
    ru = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    # linux reports KiB, darwin reports bytes
    return ru / (1024.0 ** 2) if sys.platform == "darwin" else ru / 1024.0


def _run_one(model: ModelSpec, lr, label, steps, batch, seq):
    """One sweep cell = one RunSpec (the declarative record of the
    variant: rank override or dense baseline on the ModelSpec), driven
    step-at-a-time through the Trainer facade for per-step loss/timing."""
    spec = RunSpec(model=model,
                   train=TrainSpec(steps=steps, batch=batch, seq=seq,
                                   lr=lr, warmup=10, seed=0))
    trainer = Trainer(spec)
    losses = []
    t_steps = []
    for i in range(steps):
        data = trainer.make_batch(i)       # host-side data gen stays
        t0 = time.time()                   # outside the timed region
        m = trainer.step(data)
        jax.block_until_ready(m["loss"])
        t_steps.append(time.time() - t0)
        losses.append(float(m["loss"]))
    state = trainer.state
    n = param_count(state["params"])
    smooth = float(np.mean(losses[-20:]))
    ppl = float(np.exp(min(smooth, 20)))
    step_ms = float(np.median(t_steps[5:]) * 1e3)
    print(f"{label:12s} params={n/1e3:8.0f}K loss={smooth:6.3f} ppl={ppl:8.1f} "
          f"step={step_ms:6.1f}ms first_loss={losses[0]:.3f}")
    return {"label": label, "params": n, "loss": smooth, "ppl": ppl,
            "step_ms": step_ms, "first": losses[0],
            "loss_curve": losses, "state_bytes": _state_bytes(state),
            "peak_rss_mb": _peak_rss_mb()}


def run(ranks=RANKS, steps=STEPS, batch=BATCH, seq=SEQ,
        json_out=None) -> list[str]:
    print("# Paper Table 3 — rank sweep (reduced SmolLM2-1.7B family, "
          f"{steps} steps, synthetic data)")
    base = ModelSpec("smollm2-1.7b", reduced=True)
    results = []
    dense = _run_one(base.replace(spectral_mlp=False), lr=1e-3, label="dense",
                     steps=steps, batch=batch, seq=seq)
    for r in ranks:
        results.append(_run_one(base.replace(rank=r), lr=3e-3, label=f"SCT r={r}",
                                steps=steps, batch=batch, seq=seq))

    floors = [x["loss"] for x in results]
    spread = max(floors) - min(floors)
    init_gap = results[0]["first"] - min(floors)
    # claim 1 (all ranks converge): every rank moved most of the way to
    # the best floor. The paper's exact "same floor" needs ranks << dims
    # (1.7B scale); our reduced model's top rank IS full-rank, so rank
    # capacity genuinely differs here — we assert convergence, report
    # the spread, and note the scale caveat.
    claim1 = all(x["first"] - x["loss"] > 0.3 for x in results)
    claim2 = all(a["params"] < b["params"] for a, b in zip(results, results[1:]))
    # claim 3 (paper): dense beat SCT at the paper's mismatched LRs; with
    # our per-component LR groups (the paper's own proposed fix) SCT at
    # adequate rank matches or beats dense in-budget. Assert the
    # framework-level statement: best-SCT within 0.25 of dense or better.
    claim3 = min(floors) <= dense["loss"] + 0.25
    print(f"claim1 all-ranks-converge: spread={spread:.3f} init_gap={init_gap:.3f}"
          f" -> {'OK' if claim1 else 'FAIL'} (exact common-floor needs ranks<<dims"
          f" — 1.7B scale; our top rank is full-rank)")
    print(f"claim2 params monotone in rank -> {'OK' if claim2 else 'FAIL'}")
    print(f"claim3 SCT (per-component LR, the paper's proposed fix) within 0.25 "
          f"of dense or better -> {'OK' if claim3 else 'FAIL'} "
          f"(best SCT {min(floors):.3f} vs dense {dense['loss']:.3f})")

    if json_out:
        # the BENCH_* envelope (docs/benchmarks.md): table-style rows in
        # ``entries``, the swept spec declared up front, schema-checked
        # at write time so a drifted emitter fails here, not in CI
        from repro.api import BenchSpec
        from repro.bench.schema import bench_envelope
        from repro.bench.runner import write_bench

        spec = BenchSpec(name="table3", model=base,
                         ranks=",".join(str(r) for r in ranks),
                         overloads="1", schedulers="fifo")
        payload = bench_envelope(
            "table3", spec.to_dict(), results=[],
            entries=([{"kind": "config", "steps": steps, "batch": batch,
                       "seq": seq}]
                     + [{"kind": "dense", **dense}]
                     + [{"kind": "sct", **x} for x in results]
                     + [{"kind": "claims", "converge": claim1,
                         "params_monotone": claim2,
                         "lr_fix_competitive": claim3}]))
        write_bench(payload, json_out)
        print(f"wrote {json_out} (per-rank loss curves + memory)")

    out = [f"table3_dense,{dense['step_ms']*1e3:.0f},loss={dense['loss']:.3f}"]
    for x in results:
        out.append(f"table3_{x['label'].replace(' ', '')},"
                   f"{x['step_ms']*1e3:.0f},loss={x['loss']:.3f}")
    out.append(f"table3_claims,0,converge={'OK' if claim1 else 'FAIL'}"
               f"_mono={'OK' if claim2 else 'FAIL'}"
               f"_lrfix={'OK' if claim3 else 'FAIL'}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--ranks", default=",".join(str(r) for r in RANKS),
                    help="comma-separated SCT ranks to sweep")
    ap.add_argument("--steps", type=int, default=STEPS)
    ap.add_argument("--batch", type=int, default=BATCH)
    ap.add_argument("--seq", type=int, default=SEQ)
    ap.add_argument("--json-out", default="table3_rank_sweep.json",
                    help="machine-readable results path ('' to skip)")
    args = ap.parse_args()
    ranks = tuple(int(r) for r in args.ranks.split(",") if r)
    run(ranks=ranks, steps=args.steps, batch=args.batch, seq=args.seq,
        json_out=args.json_out or None)


if __name__ == "__main__":
    main()
