"""Kernel micro-benchmarks (CPU timings are indicative only — the
kernels target TPU; correctness is the gate, interpret-mode):
spectral matmul fused kernel vs the unfused jnp chain, flash-attention
kernel vs direct softmax, plus the analytic VMEM/traffic accounting the
TPU roofline uses."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ref import spectral_matmul_ref
from repro.kernels.flash_ref import flash_attention_ref


def _time(f, *args, reps=5):
    f(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.time() - t0) / reps * 1e6


def run() -> list[str]:
    out = []
    key = jax.random.PRNGKey(0)
    print("# Kernel micro-bench (CPU; correctness-gated, TPU is the target)")

    M, m, n, k = 1024, 2048, 8192, 128
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (M, m), jnp.bfloat16)
    U = jax.random.normal(ks[1], (m, k)) / np.sqrt(m)
    s = jax.random.uniform(ks[2], (k,))
    V = jax.random.normal(ks[3], (n, k)) / np.sqrt(n)
    us_ref = _time(jax.jit(spectral_matmul_ref), x, U, s, V)
    # dense equivalent cost for context
    W = jax.random.normal(ks[1], (m, n)).astype(jnp.bfloat16)
    us_dense = _time(jax.jit(lambda a, b: a @ b), x, W)
    print(f"spectral chain (M={M},{m}x{n},k={k}): {us_ref:.0f}us | "
          f"dense matmul: {us_dense:.0f}us | flop ratio {m*n/(k*(m+n)):.1f}x")
    out.append(f"kernel_spectral_ref,{us_ref:.0f},dense={us_dense:.0f}us")

    # analytic traffic of the fused kernel vs unfused chain
    bm, cm, cn = 256, 512, 512
    unfused = (M * m + m * k + M * k * 2 + n * k + M * n) * 2
    fused = (M * m + m * k + n * k + M * n) * 2  # h never hits HBM
    print(f"fused-kernel HBM traffic save: {unfused / fused:.3f}x "
          f"(h={M}x{k} stays in VMEM)")
    out.append(f"kernel_spectral_traffic,0,{unfused/fused:.3f}x")

    B, sq, d = 4, 1024, 64
    q = jax.random.normal(ks[0], (B, sq, d))
    kk = jax.random.normal(ks[1], (B, sq, d))
    v = jax.random.normal(ks[2], (B, sq, d))
    us_attn = _time(jax.jit(lambda *a: flash_attention_ref(*a, causal=True)), q, kk, v)
    print(f"attention ref (B={B},s={sq},d={d}): {us_attn:.0f}us")
    out.append(f"kernel_flash_ref,{us_attn:.0f},B{B}s{sq}d{d}")
    return out


if __name__ == "__main__":
    run()
