"""Kernel micro-benchmarks -> ``BENCH_kernels.json``.

Two kinds of columns, deliberately separated:

  * ``deterministic`` — analytic roofline placement of each serving
    kernel (src/repro/roofline/kernels.py): FLOPs, HBM traffic under the
    fused-kernel traffic model, arithmetic intensity, compute/memory
    floors and which bound binds on v5e, plus the traffic-save ratios
    the fusions buy. Pure arithmetic from the shapes — identical on
    every machine, so CI regenerates them and diffs exactly
    (tools/check_bench.py --diff).
  * ``us_per_call`` — wall-clock of the jnp reference chains on
    whatever machine ran the bench (CPU timings are indicative only; the
    kernels target TPU and correctness is gated in interpret mode).
    Excluded from the diff like every other wall-clock column.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_ref import flash_attention_ref
from repro.kernels.ref import spectral_matmul_ref
from repro.roofline.kernels import (
    paged_gqa_decode_terms,
    paged_mla_decode_terms,
    spectral_matmul_terms,
)

# Reference serving shapes (bf16 activations/cache, serving-scale):
SPECTRAL = dict(M=1024, m=2048, n=8192, k=128)
GQA = dict(b=8, kvh=8, rep=4, hd=64, seq=1024)        # llama-family decode
MLA = dict(b=8, h=16, lat=512, rope=64, seq=1024)     # deepseek-family decode
FLASH = dict(B=4, s=1024, d=64)


def bench_spec():
    """The resolved BenchSpec (--dump-spec parity; also embedded in the
    envelope so --spec-from can rerun it)."""
    from repro.api import BenchSpec, ModelSpec

    return BenchSpec(name="kernels", model=ModelSpec("smollm2-1.7b",
                                                     reduced=True),
                     overloads="1", schedulers="fifo")


def deterministic_entries() -> list[dict]:
    """The analytic rows — everything here must reproduce exactly on
    any machine (the check_bench --diff contract)."""
    fp = spectral_matmul_terms(**SPECTRAL)
    unfused = spectral_matmul_terms(**SPECTRAL, fused=False)
    fp["hbm_save_vs_unfused"] = round(
        unfused["hbm_bytes"] / fp["hbm_bytes"], 3)

    q8 = spectral_matmul_terms(**SPECTRAL, factor_bytes=1)
    q8["hbm_save_vs_fp_fused"] = round(fp["hbm_bytes"] / q8["hbm_bytes"], 3)

    gqa = paged_gqa_decode_terms(**GQA)
    gqa_gather = paged_gqa_decode_terms(**GQA, paged=False)
    gqa["hbm_save_vs_gather"] = round(
        gqa_gather["hbm_bytes"] / gqa["hbm_bytes"], 3)

    mla = paged_mla_decode_terms(**MLA)
    mla_gather = paged_mla_decode_terms(**MLA, paged=False)
    mla["hbm_save_vs_gather"] = round(
        mla_gather["hbm_bytes"] / mla["hbm_bytes"], 3)

    return [
        {"name": "spectral_fp", "deterministic": fp},
        {"name": "spectral_q8", "deterministic": q8},
        {"name": "paged_gqa_decode", "deterministic": gqa},
        {"name": "paged_mla_decode", "deterministic": mla},
        {"name": "flash_ref", "deterministic": {"shape": dict(FLASH)}},
    ]


def _time(f, *args, reps=5):
    f(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.time() - t0) / reps * 1e6


def run(json_out: str | None = None) -> list[str]:
    out = []
    key = jax.random.PRNGKey(0)
    print("# Kernel micro-bench (CPU; correctness-gated, TPU is the target)")
    entries = {e["name"]: e for e in deterministic_entries()}

    M, m, n, k = (SPECTRAL[d] for d in ("M", "m", "n", "k"))
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (M, m), jnp.bfloat16)
    U = jax.random.normal(ks[1], (m, k)) / np.sqrt(m)
    s = jax.random.uniform(ks[2], (k,))
    V = jax.random.normal(ks[3], (n, k)) / np.sqrt(n)
    us_ref = _time(jax.jit(spectral_matmul_ref), x, U, s, V)
    entries["spectral_fp"]["us_per_call"] = round(us_ref, 1)
    # dense equivalent cost for context
    W = jax.random.normal(ks[1], (m, n)).astype(jnp.bfloat16)
    us_dense = _time(jax.jit(lambda a, b: a @ b), x, W)
    print(f"spectral chain (M={M},{m}x{n},k={k}): {us_ref:.0f}us | "
          f"dense matmul: {us_dense:.0f}us | flop ratio {m*n/(k*(m+n)):.1f}x")
    out.append(f"kernel_spectral_ref,{us_ref:.0f},dense={us_dense:.0f}us")

    for name in ("spectral_fp", "spectral_q8",
                 "paged_gqa_decode", "paged_mla_decode"):
        d = entries[name]["deterministic"]
        save = next((f"{k_}={v}x" for k_, v in d.items()
                     if k_.startswith("hbm_save")), "")
        print(f"{name:17s}: {d['intensity_flop_per_byte']:8.1f} FLOP/B "
              f"({d['bound']}-bound; ridge {d['ridge_flop_per_byte']}) "
              f"{save}")
        out.append(f"kernel_{name},0,"
                   f"intensity={d['intensity_flop_per_byte']}_{d['bound']}")

    B, sq, d_ = (FLASH[d] for d in ("B", "s", "d"))
    q = jax.random.normal(ks[0], (B, sq, d_))
    kk = jax.random.normal(ks[1], (B, sq, d_))
    v = jax.random.normal(ks[2], (B, sq, d_))
    us_attn = _time(jax.jit(lambda *a: flash_attention_ref(*a, causal=True)),
                    q, kk, v)
    entries["flash_ref"]["us_per_call"] = round(us_attn, 1)
    print(f"attention ref (B={B},s={sq},d={d_}): {us_attn:.0f}us")
    out.append(f"kernel_flash_ref,{us_attn:.0f},B{B}s{sq}d{d_}")

    if json_out:
        from repro.bench import write_bench
        from repro.bench.schema import bench_envelope

        doc = bench_envelope("kernels", bench_spec().to_dict(), results=[],
                             entries=list(entries.values()))
        write_bench(doc, json_out)
        print(f"wrote {json_out}")
    return out


if __name__ == "__main__":
    run(json_out="BENCH_kernels.json")
