"""Paper Table 4 / S4.4: gradient-integrity test. A trained dense model
is converted to spectral form at 95% energy retention and fine-tuned
with the SAME data/seed/LR as a continued-dense baseline. The claims:

  * conversion causes a loss spike (paper: 8.64 from ~0.2),
  * SCT recovers to within ~1.4x of the dense PPL,
  * trainable params shrink.

Reduced scale: SmolLM2-135M family config, synthetic data, pre-train
200 steps dense, then 150 fine-tune steps each arm.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config
from repro.core.convert import convert_mlp_tree_to_spectral
from repro.data.synthetic import SyntheticLMDataset
from repro.launch.steps import make_train_step
from repro.models.model import init_model, param_count, train_loss
from repro.optim import make_sct_optimizer


def _steps(cfg, state, opt, ds, start, n, batch=8):
    step_fn = jax.jit(make_train_step(cfg, opt))
    losses = []
    for i in range(start, start + n):
        t, l = ds.batch(i, 8)
        state, m = step_fn(state, {"tokens": jnp.asarray(t), "labels": jnp.asarray(l)})
        losses.append(float(m["ce_loss"]))
    return state, losses


def run() -> list[str]:
    print("# Paper Table 4 — fine-tuning gradient integrity (135M family)")
    cfg_dense = get_config("smollm2-135m", reduced=True).replace_sct(spectral_mlp=False)
    ds = SyntheticLMDataset(vocab=cfg_dense.vocab, seq_len=64, seed=0)

    # pre-train a dense model
    opt_pre = make_sct_optimizer(cfg_dense, lr=2e-3, warmup=10, total_steps=350)
    state = opt_pre.init(init_model(jax.random.PRNGKey(0), cfg_dense))
    state, pre_losses = _steps(cfg_dense, state, opt_pre, ds, 0, 200)
    base_loss = float(np.mean(pre_losses[-10:]))
    dense_params = param_count(state["params"])

    # arm A: continue dense
    stateA, lossesA = _steps(cfg_dense, state, opt_pre, ds, 200, 150)
    dense_final = float(np.mean(lossesA[-10:]))

    # arm B: convert MLPs to spectral @95% energy, fine-tune with SCT
    spectral_params, ranks = convert_mlp_tree_to_spectral(state["params"], 0.95)
    cfg_sct = get_config("smollm2-135m", reduced=True)
    # measure the conversion spike before any training
    t, l = ds.batch(200, 8)
    spike = float(train_loss(spectral_params, {"tokens": jnp.asarray(t),
                                               "labels": jnp.asarray(l)}, cfg_sct)[0])
    opt_sct = make_sct_optimizer(cfg_sct, lr=2e-3, warmup=10, total_steps=150)
    stateB = opt_sct.init(spectral_params)
    stateB["step"] = jnp.int32(0)
    stateB, lossesB = _steps(cfg_sct, stateB, opt_sct, ds, 200, 150)
    sct_final = float(np.mean(lossesB[-10:]))
    sct_params = param_count(stateB["params"])

    ratio = np.exp(min(sct_final, 20)) / np.exp(min(dense_final, 20))
    print(f"pre-trained dense loss: {base_loss:.3f} ({dense_params/1e3:.0f}K params)")
    print(f"conversion @95% energy: ranks={ranks}, spike loss={spike:.3f}")
    print(f"dense-continued final: {dense_final:.3f} | SCT final: {sct_final:.3f} "
          f"({sct_params/1e3:.0f}K params)")
    # NOTE: at this reduced scale the 95% threshold picks near-full rank
    # (54/64) so params do NOT shrink — this reproduces the paper's own
    # S5 "small model limitation" ("models below ~1.7B produce ranks
    # close to the full dimension at practical energy thresholds").
    small_model_limit = max(ranks) > 0.8 * 64
    print(f"PPL ratio SCT/dense: {ratio:.2f}x (paper: 1.38x) | spike recovered: "
          f"{'OK' if sct_final < spike - 0.2 else 'FAIL'} | paper-S5 small-model "
          f"limitation reproduced (rank {max(ranks)}/64 at 95% energy): "
          f"{'OK' if small_model_limit else 'no'}")
    return [
        f"table4_spike,0,{spike:.3f}",
        f"table4_dense_final,0,{dense_final:.3f}",
        f"table4_sct_final,0,{sct_final:.3f}",
        f"table4_ppl_ratio,0,{ratio:.2f}x",
        f"table4_params,0,{sct_params}v{dense_params}_S5limit",
    ]


if __name__ == "__main__":
    run()
