"""§Roofline: render the roofline table from the dry-run reports
(reports/dryrun/*.json), optionally emitting a ``BENCH_roofline.json``
envelope (one ``entries`` row per (arch, shape, mesh) with the
per-chip roofline terms as its ``deterministic`` columns). Run the
dry-run sweep first:

  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both
"""
from __future__ import annotations

import glob
import json
import os

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "reports", "dryrun")

# the roofline terms that go into an envelope entry (per-chip seconds
# and derived ratios from the partitioned HLO — machine-independent)
TERM_KEYS = ("compute_s", "memory_s", "collective_s", "step_time_s",
             "dominant", "mfu", "useful_fraction")


def load_reports(report_dir: str = REPORT_DIR):
    rows = []
    for p in sorted(glob.glob(os.path.join(report_dir, "*.json"))):
        with open(p) as f:
            rows.append(json.load(f))
    return rows


def render(rows, mesh="16x16", variant="baseline"):
    print(f"# §Roofline — per (arch x shape), mesh {mesh}, {variant} "
          f"(terms are per-chip seconds from the partitioned HLO)")
    hdr = (f"{'arch':18s} {'shape':12s} {'compute':>9s} {'memory':>9s} "
           f"{'coll':>9s} {'dominant':>10s} {'MFU':>6s} {'useful':>7s}")
    print(hdr)
    out = []
    entries = []
    for r in rows:
        if r.get("mesh") != mesh or r.get("variant", "baseline") != variant:
            continue
        tag = f"{r['arch']:18s} {r['shape']:12s}"
        if r["status"] == "skip":
            print(f"{tag} {'skip: ' + r['reason'][:50]}")
            continue
        if r["status"] != "ok":
            print(f"{tag} ERROR {r.get('error', '')[:60]}")
            continue
        t = r["roofline"]
        print(f"{tag} {t['compute_s']*1e3:8.1f}ms {t['memory_s']*1e3:8.1f}ms "
              f"{t['collective_s']*1e3:8.1f}ms {t['dominant']:>10s} "
              f"{t['mfu']:6.3f} {t['useful_fraction']:7.2f}")
        out.append(
            f"roofline_{r['arch']}_{r['shape']}_{mesh},"
            f"{t['step_time_s']*1e6:.0f},"
            f"dom={t['dominant']}_mfu={t['mfu']:.3f}"
        )
        entries.append({
            "name": f"roofline_{r['arch']}_{r['shape']}_{mesh}",
            "deterministic": {k: t[k] for k in TERM_KEYS if k in t},
        })
    return out, entries


def bench_spec():
    from repro.api import BenchSpec, ModelSpec

    return BenchSpec(name="roofline", model=ModelSpec("smollm2-1.7b",
                                                      reduced=True),
                     overloads="1", schedulers="fifo")


def run(json_out: str | None = None) -> list[str]:
    rows = load_reports()
    if not rows:
        print("no dry-run reports found — run repro.launch.dryrun first")
        return ["roofline,0,no_reports"]
    out, entries = render(rows, "16x16")
    print()
    out2, entries2 = render(rows, "2x16x16")
    out += out2
    entries += entries2
    if json_out and entries:
        from repro.bench import write_bench
        from repro.bench.schema import bench_envelope

        doc = bench_envelope("roofline", bench_spec().to_dict(), results=[],
                             entries=entries)
        write_bench(doc, json_out)
        print(f"wrote {json_out}")
    return out


if __name__ == "__main__":
    run()
