"""§Roofline: render the roofline table from the dry-run reports
(reports/dryrun/*.json). Run the dry-run sweep first:

  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both
"""
from __future__ import annotations

import glob
import json
import os

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "reports", "dryrun")


def load_reports(report_dir: str = REPORT_DIR):
    rows = []
    for p in sorted(glob.glob(os.path.join(report_dir, "*.json"))):
        with open(p) as f:
            rows.append(json.load(f))
    return rows


def render(rows, mesh="16x16", variant="baseline"):
    print(f"# §Roofline — per (arch x shape), mesh {mesh}, {variant} "
          f"(terms are per-chip seconds from the partitioned HLO)")
    hdr = (f"{'arch':18s} {'shape':12s} {'compute':>9s} {'memory':>9s} "
           f"{'coll':>9s} {'dominant':>10s} {'MFU':>6s} {'useful':>7s}")
    print(hdr)
    out = []
    for r in rows:
        if r.get("mesh") != mesh or r.get("variant", "baseline") != variant:
            continue
        tag = f"{r['arch']:18s} {r['shape']:12s}"
        if r["status"] == "skip":
            print(f"{tag} {'skip: ' + r['reason'][:50]}")
            continue
        if r["status"] != "ok":
            print(f"{tag} ERROR {r.get('error', '')[:60]}")
            continue
        t = r["roofline"]
        print(f"{tag} {t['compute_s']*1e3:8.1f}ms {t['memory_s']*1e3:8.1f}ms "
              f"{t['collective_s']*1e3:8.1f}ms {t['dominant']:>10s} "
              f"{t['mfu']:6.3f} {t['useful_fraction']:7.2f}")
        out.append(
            f"roofline_{r['arch']}_{r['shape']}_{mesh},"
            f"{t['step_time_s']*1e6:.0f},"
            f"dom={t['dominant']}_mfu={t['mfu']:.3f}"
        )
    return out


def run() -> list[str]:
    rows = load_reports()
    if not rows:
        print("no dry-run reports found — run repro.launch.dryrun first")
        return ["roofline,0,no_reports"]
    out = render(rows, "16x16")
    print()
    out += render(rows, "2x16x16")
    return out


if __name__ == "__main__":
    run()
