"""Paper Table 2 / S4.1: a full 70B-architecture training step (forward,
backward, AdamW, QR retraction) under 8 GB.

The paper runs the full 80-layer model on a Steam Deck CPU in 6.28 s.
This container has ~35 GB RAM but one core, so we (a) measure the REAL
peak RSS of a full training step on a depth-reduced slice of the exact
70B layer geometry (d=8192, ffn=28672, rank 32 — identical per-layer
memory), and (b) extrapolate the per-layer cost to 80 layers
analytically, which is exact because SCT state is strictly per-layer.
Phase timings (fwd/bwd/optimizer/retraction) are reported like the
paper's Table 2, plus the orthogonality-error check (< 2e-6).
"""
from __future__ import annotations

import resource
import time

import jax
import jax.numpy as jnp

from repro.config import get_config
from repro.core.tree import max_orthogonality_error
from repro.models.model import init_model, train_loss, param_count, dense_equivalent_param_count
from repro.optim import make_sct_optimizer
from repro.optim.adamw import adamw_update
from repro.core.tree import retract_tree

N_LAYERS = 2   # slice depth; per-layer numbers scale linearly to 80
VOCAB = 16384  # the paper's '452M spectral params for 77.8B dense' implies
               # its validation model had a small embedding (a 128k-vocab
               # embedding alone is 1.05B params); we match that regime and
               # report the choice.


def bench_spec():
    """The resolved BenchSpec (--dump-spec parity; also embedded in the
    envelope so --spec-from can rerun it)."""
    from repro.api import BenchSpec, ModelSpec

    return BenchSpec(name="table2", model=ModelSpec("llama3.1-70b",
                                                    reduced=True),
                     overloads="1", schedulers="fifo")


def run(json_out: str | None = None) -> list[str]:
    out = []
    full = get_config("llama-70b-sct")
    cfg = full.replace(n_layers=N_LAYERS, vocab=VOCAB, remat=True)
    key = jax.random.PRNGKey(0)

    rss0 = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1e6  # GB (linux: KB)
    t0 = time.time()
    params = init_model(key, cfg)
    n_spec = param_count(params)
    n_dense_eq = dense_equivalent_param_count(params)
    opt = make_sct_optimizer(cfg, lr=5e-4)
    state = opt.init(params)
    t_init = time.time() - t0

    batch = {
        "tokens": jax.random.randint(key, (1, 512), 0, cfg.vocab),
        "labels": jax.random.randint(key, (1, 512), 0, cfg.vocab),
    }

    # phase 1+2: forward + backward
    loss_fn = jax.jit(lambda p, b: jax.value_and_grad(
        lambda pp: train_loss(pp, b, cfg)[0])(p))
    t0 = time.time()
    loss, grads = loss_fn(state["params"], batch)
    jax.block_until_ready(loss)
    t_fwd_bwd = time.time() - t0

    # phase 3: AdamW
    upd = jax.jit(lambda p, g, s: adamw_update(p, g, s, opt.adamw))
    t0 = time.time()
    new_params, new_opt = upd(state["params"], grads, state["opt"])
    jax.block_until_ready(jax.tree.leaves(new_params)[0])
    t_opt = time.time() - t0

    # phase 4: QR retraction (paper-faithful)
    retr = jax.jit(lambda p: retract_tree(p, "qr"))
    t0 = time.time()
    new_params = retr(new_params)
    jax.block_until_ready(jax.tree.leaves(new_params)[0])
    t_retract = time.time() - t0

    ortho = float(max_orthogonality_error(new_params))
    rss1 = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1e6

    scale = full.n_layers / N_LAYERS
    # SCT layer state scales linearly; embeddings are shared
    print("# Paper Table 2 — 70B-architecture training step (CPU)")
    print(f"layers measured: {N_LAYERS} (geometry identical to 70B: d=8192, "
          f"ffn=28672, rank 32); extrapolation x{scale:.0f} to 80L")
    print(f"spectral params (slice): {n_spec/1e6:.0f}M -> dense-equivalent "
          f"{n_dense_eq/1e9:.2f}B")
    print(f"peak RSS during full step: {rss1:.2f} GB (paper: 7.2 GB on SteamDeck "
          f"for all 80 layers)")
    print(f"fwd+bwd {t_fwd_bwd:.2f}s | adamw {t_opt:.2f}s | retraction(QR) "
          f"{t_retract:.2f}s  (per {N_LAYERS} layers)")
    print(f"ortho error after retraction: {ortho:.2e} (paper: < 2e-6)")
    retr_frac = t_retract / max(t_fwd_bwd + t_opt + t_retract, 1e-9)
    print(f"retraction fraction of step: {retr_frac*100:.0f}% "
          f"(paper reports 40-50% at 70B)")
    ok = ortho < 2e-6
    out.append(f"table2_fwd_bwd,{t_fwd_bwd*1e6:.0f},per{N_LAYERS}L")
    out.append(f"table2_adamw,{t_opt*1e6:.0f},per{N_LAYERS}L")
    out.append(f"table2_qr_retraction,{t_retract*1e6:.0f},frac={retr_frac:.2f}")
    out.append(f"table2_ortho,{0:.0f},{ortho:.2e}_{'OK' if ok else 'FAIL'}")
    out.append(f"table2_peak_rss,{0:.0f},{rss1:.2f}GB")

    if json_out:
        from repro.bench import write_bench
        from repro.bench.schema import bench_envelope

        # this suite is a wall-clock + RSS measurement, so only the
        # parameter-count geometry and the ortho pass/fail are
        # deterministic; phase timings ride along as us_per_call (the
        # envelope is NOT committed/diffed — a full step is too slow
        # for the CI regenerate-and-diff loop)
        entries = [
            {"name": "table2_geometry",
             "deterministic": {"layers_measured": N_LAYERS,
                               "extrapolate_to_layers": full.n_layers,
                               "vocab": VOCAB,
                               "spectral_params": int(n_spec),
                               "dense_equivalent_params": int(n_dense_eq),
                               "ortho_ok": ok}},
            {"name": "table2_init", "us_per_call": round(t_init * 1e6, 1)},
            {"name": "table2_fwd_bwd",
             "us_per_call": round(t_fwd_bwd * 1e6, 1)},
            {"name": "table2_adamw", "us_per_call": round(t_opt * 1e6, 1)},
            {"name": "table2_qr_retraction",
             "us_per_call": round(t_retract * 1e6, 1)},
            {"name": "table2_peak_rss_gb", "us_per_call": round(rss1, 3)},
        ]
        doc = bench_envelope("table2", bench_spec().to_dict(), results=[],
                             entries=entries)
        write_bench(doc, json_out)
        print(f"wrote {json_out}")
    return out


if __name__ == "__main__":
    run()
