"""Benchmark front door: ``python -m repro bench <name> [flags...]``.

Every subcommand is a thin argparse -> :class:`repro.api.BenchSpec`
adapter (``--dump-spec`` prints the resolved spec and exits — the same
parity contract as ``repro train``/``repro serve``), and the serving
harness emits a schema-validated ``BENCH_serving.json`` perf-trajectory
file (docs/benchmarks.md):

  PYTHONPATH=src python -m repro bench                    # run-all CSV
  PYTHONPATH=src python -m repro bench serving            # traffic harness
  PYTHONPATH=src python -m repro bench serving --dump-spec
  PYTHONPATH=src python -m repro bench table3 --ranks 8,16
  PYTHONPATH=src python -m repro bench table1 kernels     # legacy multi-suite

Knobs that describe a suite's *trace shape* rather than the system
under test (table3's ``--steps``/``--batch``/``--seq``) stay CLI-side,
the same rule launch/serve.py applies to its trace flags.
"""
from __future__ import annotations

import argparse
import sys
import traceback
from typing import List, Optional, Sequence

SUITE_NAMES = ("table1", "table2", "table3", "table4",
               "kernels", "serving", "roofline")

USAGE = """\
usage: python -m repro bench [<name>] [flags...]

  (no name)   run every suite, print the consolidated CSV
  serving     SLO/traffic harness -> BENCH_serving.json (--help for knobs)
  speculative rank-ladder self-speculation vs plain decode ->
              BENCH_speculative.json (acceptance rate, tokens/step)
  streaming   long-context streaming KV sweep (full cache vs sinks+
              window vs int8 cold tier) -> BENCH_streaming.json
              (evictions, demotions, cold bytes, NLL per policy)
  kernels     serving-kernel roofline placement + ref timings ->
              BENCH_kernels.json
  roofline    dry-run roofline table (--json-out for an envelope)
  table3      rank sweep (--ranks/--steps/--batch/--seq/--json-out)
  table1      paper Table 1 memory arithmetic -> BENCH_table1.json
              (exact-integer columns, CI regenerate-and-diffed)
  table2      70B-slice training step (--json-out for an envelope;
              wall-clock heavy, not committed)
  table4      single micro-bench suite
  <a> <b> ..  any list of suite names: legacy multi-suite CSV run

every subcommand takes --dump-spec (print the resolved BenchSpec, run
nothing).
"""


def _legacy_run(name: str) -> List[str]:
    from benchmarks import (
        bench_kernels,
        bench_serving,
        roofline_table,
        table1_memory,
        table2_70b_step,
        table3_rank_sweep,
        table4_gradient_integrity,
    )

    return {
        "table1": table1_memory.run,
        "table2": table2_70b_step.run,
        "table3": table3_rank_sweep.run,
        "table4": table4_gradient_integrity.run,
        "kernels": bench_kernels.run,
        "serving": bench_serving.run,
        "roofline": roofline_table.run,
    }[name]() or []


def _run_all(selected: Sequence[str]) -> int:
    rows: List[str] = []
    failed = []
    for name in selected:
        print(f"\n===== {name} =====", flush=True)
        try:
            rows.extend(_legacy_run(name))
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    print("\n===== CSV (name,us_per_call,derived) =====")
    for r in rows:
        print(r)
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        return 1
    return 0


# ------------------------------------------------------------- serving --

def build_serving_parser() -> argparse.ArgumentParser:
    """The traffic-harness flags; defaults are the committed
    BENCH_serving.json configuration (a deadline-bearing two-tenant mix
    whose 2x arm genuinely overloads the default geometry)."""
    ap = argparse.ArgumentParser(
        prog="repro bench serving",
        description="load-generator harness: WorkloadSpec traffic over "
                    "the Server facade, fifo-vs-slo x overload sweep, "
                    "BENCH_serving.json out")
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--full", action="store_true",
                    help="full-size config (default: reduced, CPU-scale)")
    # serving geometry
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=64)
    ap.add_argument("--pages-per-seq", type=int, default=8)
    ap.add_argument("--prefill-budget", type=int, default=64)
    ap.add_argument("--prefix-cache", action="store_true",
                    help="serve repeated page-aligned prefixes from the "
                         "refcounted prefix index")
    ap.add_argument("--chunked-prefill", action="store_true")
    # workload
    ap.add_argument("--arrival", choices=["poisson", "onoff", "fixed"],
                    default="poisson")
    ap.add_argument("--rate", type=float, default=0.35,
                    help="mean arrivals per engine step at 1x overload")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tenants", default="2,1",
                    help="per-tenant arrival weights (ids t0,t1,...)")
    ap.add_argument("--prefix-tokens", type=int, default=0,
                    help="shared system-prompt tokens per tenant")
    ap.add_argument("--prompt-mean", type=int, default=16)
    ap.add_argument("--prompt-cv", type=float, default=0.5)
    ap.add_argument("--gen-mean", type=int, default=12)
    ap.add_argument("--gen-cv", type=float, default=0.5)
    ap.add_argument("--priority-mix", default="1,1",
                    help="per-class arrival weights, class 0 most urgent")
    ap.add_argument("--on-steps", type=int, default=8)
    ap.add_argument("--off-steps", type=int, default=8)
    # SLOs
    ap.add_argument("--deadlines", default="0=20,1=40",
                    help="per-class end-to-end deadlines in engine steps "
                         "('N' or 'CLS=N,...'; 'none' disables)")
    ap.add_argument("--ttft", type=int, default=None,
                    help="TTFT target in engine steps (reported, not "
                         "enforced)")
    ap.add_argument("--no-shed", action="store_true",
                    help="SLO arm keeps fair-share ordering but never "
                         "refuses a doomed request")
    # sweep axes
    ap.add_argument("--overloads", default="1,2",
                    help="arrival-rate multipliers")
    ap.add_argument("--schedulers", default="fifo,slo")
    ap.add_argument("--precisions", default="fp32,int8",
                    help="throughput axis; fp32 alone skips the sweep")
    ap.add_argument("--ranks", default="8,16",
                    help="serve-rank throughput axis (comma-separated; "
                         "'' skips)")
    ap.add_argument("--serving-modes", default="colocated,disaggregated",
                    help="serving-topology arms: colocated and/or "
                         "disaggregated (prefill/decode worker split)")
    # output
    ap.add_argument("--json-out", default="BENCH_serving.json",
                    help="envelope path ('' to skip writing)")
    ap.add_argument("--dump-spec", action="store_true",
                    help="print the resolved BenchSpec JSON and exit")
    ap.add_argument("--spec-from", default=None, metavar="FILE",
                    help="ignore the flags above and rerun the BenchSpec "
                         "embedded in this BENCH_*.json envelope — the "
                         "regenerate-and-diff path tools/check_bench.py "
                         "--diff closes in CI")
    # legacy workloads (benchmarks/bench_serving.py, unchanged flags)
    ap.add_argument("--shared-prefix", action="store_true",
                    help="legacy shared-system-prompt bench: prefix "
                         "cache off vs on")
    ap.add_argument("--verify", action="store_true",
                    help="with --shared-prefix: check outputs against "
                         "the static-cache oracle")
    ap.add_argument("--compare-static", action="store_true",
                    help="legacy static-vs-paged comparison CSV")
    return ap


def serving_bench_from_args(args: argparse.Namespace):
    from repro.api import (
        BenchSpec,
        ModelSpec,
        ServeSpec,
        SLOSpec,
        WorkloadSpec,
    )

    deadlines = None if args.deadlines in ("", "none") else args.deadlines
    return BenchSpec(
        name="serving",
        model=ModelSpec(args.arch, reduced=not args.full),
        serve=ServeSpec(
            slots=args.slots,
            page_size=args.page_size,
            num_pages=args.num_pages,
            pages_per_seq=args.pages_per_seq,
            prefill_budget=args.prefill_budget,
            prefix_cache=args.prefix_cache,
            chunked_prefill=args.chunked_prefill,
        ),
        workload=WorkloadSpec(
            arrival=args.arrival,
            rate=args.rate,
            requests=args.requests,
            seed=args.seed,
            tenants=args.tenants,
            shared_prefix=args.prefix_tokens,
            prompt_mean=args.prompt_mean,
            prompt_cv=args.prompt_cv,
            gen_mean=args.gen_mean,
            gen_cv=args.gen_cv,
            priority_mix=args.priority_mix,
            on_steps=args.on_steps,
            off_steps=args.off_steps,
        ),
        slo=SLOSpec(deadlines=deadlines, ttft=args.ttft,
                    shed=not args.no_shed),
        overloads=args.overloads,
        schedulers=args.schedulers,
        precisions=args.precisions,
        ranks=args.ranks,
        serving_modes=args.serving_modes,
    )


def _bench_from_envelope(path: str):
    """BenchSpec embedded in a committed BENCH_*.json envelope — the
    spec IS the benchmark, so rerunning it reproduces the arms."""
    import json

    from repro.api import BenchSpec

    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or not isinstance(doc.get("spec"), dict):
        raise SystemExit(f"{path}: not a BENCH envelope (no spec object)")
    return BenchSpec.from_dict(doc["spec"])


def cmd_serving(argv: Sequence[str]) -> int:
    args = build_serving_parser().parse_args(argv)
    if args.shared_prefix or args.compare_static:
        from benchmarks import bench_serving

        if args.dump_spec:
            print(bench_serving.dump_spec_json())
            return 0
        if args.shared_prefix:
            bench_serving.run_shared_prefix(verify=args.verify)
        else:
            bench_serving.run()
        return 0

    bench = (_bench_from_envelope(args.spec_from) if args.spec_from
             else serving_bench_from_args(args))
    if args.dump_spec:
        print(bench.to_json(indent=2))
        return 0

    from repro.bench import run_bench, write_bench

    doc = run_bench(bench, log=lambda s: print(f"[bench] {s}", flush=True))
    for arm in doc["results"]:
        m = arm["metrics"]
        mode = arm.get("variant", "colocated")
        print(f"{mode:13s} {arm['overload']:g}x {arm['scheduler']:4s}: "
              f"{int(m['completed'])}/{int(m['requests'])} completed, "
              f"{int(m['timed_out'])} timed out, {int(m['shed'])} shed | "
              f"ttft p50/p99 {m['ttft_p50_steps']}/{m['ttft_p99_steps']} "
              f"steps | goodput {m['goodput_tokens_per_s']:.1f} tok/s "
              f"({int(m['slo_met_tokens'])} SLO-met tokens)")
    for row in doc.get("throughput") or []:
        print(f"throughput {row['precision']:5s} rank={row['rank']}: "
              f"{row['tokens_per_s']:.1f} tok/s, "
              f"{int(row['weight_bytes'])} weight bytes")
    if args.json_out:
        write_bench(doc, args.json_out)
        print(f"wrote {args.json_out}")
    return 0


# --------------------------------------------------------- speculative --

def build_speculative_parser() -> argparse.ArgumentParser:
    """Baseline-vs-speculative harness knobs; defaults are the
    committed BENCH_speculative.json configuration (reduced rank-16
    model, half-rank drafter)."""
    ap = argparse.ArgumentParser(
        prog="repro bench speculative",
        description="rank-ladder self-speculative decoding vs plain "
                    "greedy decode over one workload: acceptance rate, "
                    "tokens/decode-step, token-identity gate, "
                    "BENCH_speculative.json out")
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--full", action="store_true",
                    help="full-size config (default: reduced, CPU-scale)")
    ap.add_argument("--speculative-rank", default="8",
                    help="drafter rank ladder, lowest first ('8', '4,8')")
    ap.add_argument("--draft-tokens", type=int, default=4)
    # serving geometry
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=64)
    ap.add_argument("--pages-per-seq", type=int, default=8)
    ap.add_argument("--prefill-budget", type=int, default=64)
    ap.add_argument("--scheduler", choices=["fifo", "slo"], default="fifo")
    # workload (deterministic by default: fixed arrivals, pinned lengths)
    ap.add_argument("--arrival", choices=["poisson", "onoff", "fixed"],
                    default="fixed")
    ap.add_argument("--rate", type=float, default=0.5)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prompt-mean", type=int, default=16)
    ap.add_argument("--prompt-cv", type=float, default=0.5)
    ap.add_argument("--gen-mean", type=int, default=16)
    ap.add_argument("--gen-cv", type=float, default=0.0)
    # output
    ap.add_argument("--json-out", default="BENCH_speculative.json",
                    help="envelope path ('' to skip writing)")
    ap.add_argument("--dump-spec", action="store_true",
                    help="print the resolved BenchSpec JSON and exit")
    ap.add_argument("--spec-from", default=None, metavar="FILE",
                    help="rerun the BenchSpec embedded in this envelope "
                         "(the CI regenerate-and-diff path)")
    return ap


def speculative_bench_from_args(args: argparse.Namespace):
    from repro.api import BenchSpec, ModelSpec, ServeSpec, WorkloadSpec

    return BenchSpec(
        name="speculative",
        model=ModelSpec(args.arch, reduced=not args.full),
        serve=ServeSpec(
            slots=args.slots,
            page_size=args.page_size,
            num_pages=args.num_pages,
            pages_per_seq=args.pages_per_seq,
            prefill_budget=args.prefill_budget,
            scheduler=args.scheduler,
            speculative_rank=args.speculative_rank,
            draft_tokens=args.draft_tokens,
        ),
        workload=WorkloadSpec(
            arrival=args.arrival,
            rate=args.rate,
            requests=args.requests,
            seed=args.seed,
            prompt_mean=args.prompt_mean,
            prompt_cv=args.prompt_cv,
            gen_mean=args.gen_mean,
            gen_cv=args.gen_cv,
        ),
        overloads="1",
        schedulers=args.scheduler,
    )


def cmd_speculative(argv: Sequence[str]) -> int:
    args = build_speculative_parser().parse_args(argv)
    bench = (_bench_from_envelope(args.spec_from) if args.spec_from
             else speculative_bench_from_args(args))
    if args.dump_spec:
        print(bench.to_json(indent=2))
        return 0

    from repro.bench import run_speculative_bench, write_bench

    doc = run_speculative_bench(
        bench, log=lambda s: print(f"[bench] {s}", flush=True))
    for arm in doc["results"]:
        m = arm["metrics"]
        line = (f"{arm['variant']:11s}: "
                f"{int(m['completed'])}/{int(m['requests'])} completed | "
                f"{m['tokens_per_step']:.2f} tokens/decode-step | "
                f"ttft p50 {m['ttft_p50_steps']} steps")
        if arm["variant"] == "speculative":
            line += (f" | acceptance {m['acceptance_rate']:.2f} "
                     f"({int(m['draft_accepted'])}/"
                     f"{int(m['draft_proposed'])} drafted tokens)")
        print(line)
    print("outputs token-identical across arms")
    if args.json_out:
        write_bench(doc, args.json_out)
        print(f"wrote {args.json_out}")
    return 0


# --------------------------------------------------------- streaming --

def build_streaming_parser() -> argparse.ArgumentParser:
    """Eviction-policy sweep knobs; defaults are the committed
    BENCH_streaming.json configuration: a tiny-page geometry whose
    fixed-length sessions run several windows past the sink+window
    horizon, so every streaming arm genuinely evicts (and the int8 arm
    genuinely demotes)."""
    ap = argparse.ArgumentParser(
        prog="repro bench streaming",
        description="long-context streaming KV policy sweep: full cache "
                    "vs attention sinks + sliding-window eviction vs "
                    "int8 cold tier, over one long-session workload; "
                    "identity gate inside the horizon, NLL per policy, "
                    "BENCH_streaming.json out")
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--full", action="store_true",
                    help="full-size config (default: reduced, CPU-scale)")
    ap.add_argument("--sink-pages", type=int, default=1)
    ap.add_argument("--window-pages", type=int, default=2)
    # serving geometry: small pages so the sessions cross many window
    # boundaries within CPU-scale wall time
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--page-size", type=int, default=4)
    ap.add_argument("--num-pages", type=int, default=32)
    ap.add_argument("--pages-per-seq", type=int, default=16)
    ap.add_argument("--prefill-budget", type=int, default=64)
    ap.add_argument("--scheduler", choices=["fifo", "slo"], default="fifo")
    # workload (deterministic: fixed arrivals, pinned lengths well past
    # the sink+window identity horizon)
    ap.add_argument("--arrival", choices=["poisson", "onoff", "fixed"],
                    default="fixed")
    ap.add_argument("--rate", type=float, default=0.5)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prompt-mean", type=int, default=24)
    ap.add_argument("--prompt-cv", type=float, default=0.0)
    ap.add_argument("--gen-mean", type=int, default=16)
    ap.add_argument("--gen-cv", type=float, default=0.0)
    # output
    ap.add_argument("--json-out", default="BENCH_streaming.json",
                    help="envelope path ('' to skip writing)")
    ap.add_argument("--dump-spec", action="store_true",
                    help="print the resolved BenchSpec JSON and exit")
    ap.add_argument("--spec-from", default=None, metavar="FILE",
                    help="rerun the BenchSpec embedded in this envelope "
                         "(the CI regenerate-and-diff path)")
    return ap


def streaming_bench_from_args(args: argparse.Namespace):
    from repro.api import (
        BenchSpec,
        ModelSpec,
        ServeSpec,
        StreamingSpec,
        WorkloadSpec,
    )

    return BenchSpec(
        name="streaming",
        model=ModelSpec(args.arch, reduced=not args.full),
        serve=ServeSpec(
            slots=args.slots,
            page_size=args.page_size,
            num_pages=args.num_pages,
            pages_per_seq=args.pages_per_seq,
            prefill_budget=args.prefill_budget,
            scheduler=args.scheduler,
            streaming=StreamingSpec(sink_pages=args.sink_pages,
                                    window_pages=args.window_pages),
        ),
        workload=WorkloadSpec(
            arrival=args.arrival,
            rate=args.rate,
            requests=args.requests,
            seed=args.seed,
            prompt_mean=args.prompt_mean,
            prompt_cv=args.prompt_cv,
            gen_mean=args.gen_mean,
            gen_cv=args.gen_cv,
        ),
        overloads="1",
        schedulers=args.scheduler,
    )


def cmd_streaming(argv: Sequence[str]) -> int:
    args = build_streaming_parser().parse_args(argv)
    bench = (_bench_from_envelope(args.spec_from) if args.spec_from
             else streaming_bench_from_args(args))
    if args.dump_spec:
        print(bench.to_json(indent=2))
        return 0

    from repro.bench import run_streaming_bench, write_bench

    doc = run_streaming_bench(
        bench, log=lambda s: print(f"[bench] {s}", flush=True))
    for arm in doc["results"]:
        m = arm["metrics"]
        line = (f"{arm['variant']:11s}: "
                f"{int(m['completed'])}/{int(m['requests'])} completed | "
                f"peak {int(m['peak_pages'])} pages | "
                f"nll {m['score_nll']:.4f}")
        if "stream_evictions" in m:
            line += f" | {int(m['stream_evictions'])} evictions"
        if "stream_demotions" in m:
            line += (f", {int(m['stream_demotions'])} demotions "
                     f"({int(m['cold_page_bytes'])} cold bytes)")
        print(line)
    print("outputs token-identical inside the streaming identity horizon")
    if args.json_out:
        write_bench(doc, args.json_out)
        print(f"wrote {args.json_out}")
    return 0


# ------------------------------------------------------------- kernels --

def cmd_kernels(argv: Sequence[str]) -> int:
    """Serving-kernel bench: analytic roofline placement (deterministic,
    CI-diffed) plus indicative jnp-reference wall timings."""
    from benchmarks import bench_kernels

    ap = argparse.ArgumentParser(
        prog="repro bench kernels",
        description="per-kernel roofline placement + reference timings, "
                    "BENCH_kernels.json out")
    ap.add_argument("--json-out", default="BENCH_kernels.json",
                    help="envelope path ('' to skip writing)")
    ap.add_argument("--dump-spec", action="store_true",
                    help="print the resolved BenchSpec JSON and exit")
    ap.add_argument("--spec-from", default=None, metavar="FILE",
                    help="rerun the BenchSpec embedded in this envelope "
                         "(the CI regenerate-and-diff path; the kernels "
                         "spec carries no sweep knobs, so this validates "
                         "the embed and reruns the fixed suite)")
    args = ap.parse_args(argv)
    if args.spec_from:
        _bench_from_envelope(args.spec_from)    # must parse as a BenchSpec
    if args.dump_spec:
        print(bench_kernels.bench_spec().to_json(indent=2))
        return 0
    for r in bench_kernels.run(json_out=args.json_out or None):
        print(r)
    return 0


def cmd_roofline(argv: Sequence[str]) -> int:
    from benchmarks import roofline_table

    ap = argparse.ArgumentParser(
        prog="repro bench roofline",
        description="roofline table from reports/dryrun/*.json")
    ap.add_argument("--json-out", default="",
                    help="optional BENCH_roofline.json envelope path "
                         "(requires dry-run reports)")
    ap.add_argument("--dump-spec", action="store_true")
    args = ap.parse_args(argv)
    if args.dump_spec:
        print(roofline_table.bench_spec().to_json(indent=2))
        return 0
    for r in roofline_table.run(json_out=args.json_out or None):
        print(r)
    return 0


# -------------------------------------------------------------- tables --

def _table_bench_spec(name: str, model_arch: str, ranks: str = ""):
    from repro.api import BenchSpec, ModelSpec

    return BenchSpec(name=name, model=ModelSpec(model_arch, reduced=True),
                     ranks=ranks, overloads="1", schedulers="fifo")


def cmd_table3(argv: Sequence[str]) -> int:
    from benchmarks import table3_rank_sweep as t3

    ap = argparse.ArgumentParser(prog="repro bench table3")
    ap.add_argument("--ranks", default=",".join(str(r) for r in t3.RANKS))
    ap.add_argument("--steps", type=int, default=t3.STEPS)
    ap.add_argument("--batch", type=int, default=t3.BATCH)
    ap.add_argument("--seq", type=int, default=t3.SEQ)
    ap.add_argument("--json-out", default="table3_rank_sweep.json")
    ap.add_argument("--dump-spec", action="store_true")
    args = ap.parse_args(argv)
    if args.dump_spec:
        print(_table_bench_spec("table3", "smollm2-1.7b",
                                ranks=args.ranks).to_json(indent=2))
        return 0
    ranks = tuple(int(r) for r in args.ranks.split(",") if r)
    rows = t3.run(ranks=ranks, steps=args.steps, batch=args.batch,
                  seq=args.seq, json_out=args.json_out or None)
    for r in rows:
        print(r)
    return 0


def _table_suite(name: str, default_json: str):
    """table1/table2 front door: envelope-emitting fixed suites with the
    same --json-out/--dump-spec/--spec-from contract as cmd_kernels
    (the suites carry no sweep knobs, so --spec-from just validates the
    embedded spec and reruns the fixed table)."""
    def cmd(argv: Sequence[str]) -> int:
        from benchmarks import table1_memory, table2_70b_step

        suite = {"table1": table1_memory, "table2": table2_70b_step}[name]
        ap = argparse.ArgumentParser(prog=f"repro bench {name}")
        ap.add_argument("--json-out", default=default_json,
                        help="envelope path ('' to skip writing)")
        ap.add_argument("--dump-spec", action="store_true",
                        help="print the resolved BenchSpec JSON and exit")
        ap.add_argument("--spec-from", default=None, metavar="FILE",
                        help="rerun the BenchSpec embedded in this "
                             "envelope (the CI regenerate-and-diff path)")
        args = ap.parse_args(argv)
        if args.spec_from:
            _bench_from_envelope(args.spec_from)  # must parse as a BenchSpec
        if args.dump_spec:
            print(suite.bench_spec().to_json(indent=2))
            return 0
        for r in suite.run(json_out=args.json_out or None):
            print(r)
        return 0
    return cmd


def _simple_suite(name: str, arch: str):
    def cmd(argv: Sequence[str]) -> int:
        ap = argparse.ArgumentParser(prog=f"repro bench {name}")
        ap.add_argument("--dump-spec", action="store_true")
        args = ap.parse_args(argv)
        if args.dump_spec:
            print(_table_bench_spec(name, arch).to_json(indent=2))
            return 0
        for r in _legacy_run(name):
            print(r)
        return 0
    return cmd


COMMANDS = {
    "serving": cmd_serving,
    "speculative": cmd_speculative,
    "streaming": cmd_streaming,
    "table3": cmd_table3,
    "table1": _table_suite("table1", "BENCH_table1.json"),
    "table2": _table_suite("table2", ""),
    "table4": _simple_suite("table4", "smollm2-1.7b"),
    "kernels": cmd_kernels,
    "roofline": cmd_roofline,
}


def main(argv: Optional[Sequence[str]] = None) -> None:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv:
        raise SystemExit(_run_all(list(SUITE_NAMES)))
    if argv[0] in ("-h", "--help", "help"):
        print(USAGE, end="")
        return
    # legacy multi-suite form: a bare list of suite names
    if len(argv) > 1 and all(a in SUITE_NAMES for a in argv):
        raise SystemExit(_run_all(argv))
    name, rest = argv[0], argv[1:]
    if name not in COMMANDS:
        print(f"repro bench: unknown suite {name!r}\n{USAGE}",
              file=sys.stderr, end="")
        raise SystemExit(2)
    raise SystemExit(COMMANDS[name](rest))


if __name__ == "__main__":
    main()
