# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark driver: runs every paper-table reproduction + the kernel
micro-bench + the roofline table, then prints the consolidated CSV.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run table3     # one table
"""
from __future__ import annotations

import sys
import traceback

from benchmarks import (
    table1_memory,
    table2_70b_step,
    table3_rank_sweep,
    table4_gradient_integrity,
    bench_kernels,
    bench_serving,
    roofline_table,
)

SUITES = {
    "table1": table1_memory.run,
    "table2": table2_70b_step.run,
    "table3": table3_rank_sweep.run,
    "table4": table4_gradient_integrity.run,
    "kernels": bench_kernels.run,
    "serving": bench_serving.run,
    "roofline": roofline_table.run,
}


def main() -> None:
    selected = sys.argv[1:] or list(SUITES)
    rows: list[str] = []
    failed = []
    for name in selected:
        print(f"\n===== {name} =====", flush=True)
        try:
            rows.extend(SUITES[name]() or [])
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    print("\n===== CSV (name,us_per_call,derived) =====")
    for r in rows:
        print(r)
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
