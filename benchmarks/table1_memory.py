"""Paper Table 1: per-MLP-layer training memory (weights + grads + Adam
moments) at rank 32 — dense vs SCT, with the compression ratio.

This is exact integer arithmetic over the parameterization (the paper's
own methodology), verified against the published ratios, plus an
*instantiated* check at the smallest scale: we actually allocate a
SpectralLinear + its AdamW state and count bytes.

Extended (this repo's precision policy): per-precision *serving* weight
bytes per layer — dense fp32 vs SCT fp32 vs SCT bf16 vs SCT int8
(per-channel scales + fp32 singular values), with an instantiated
quantize_tree check.

Emits a ``BENCH_table1.json`` envelope when asked: every column here is
exact integer arithmetic (byte counts, rounded ratios, match flags), so
the whole table lives in ``deterministic`` sub-objects and CI
regenerates + diffs it like BENCH_kernels.json.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.spectral import spectral_param_count, dense_param_count, spectral_init
from repro.optim import adamw_init


def _sct_serving_bytes(m: int, n: int, k: int, precision: str) -> int:
    """Exact serving footprint of one spectral layer per precision.
    int8: k(m+n) int8 factor entries + 2k fp32 per-column scales + k
    fp32 singular values."""
    if precision == "fp32":
        return 4 * spectral_param_count(m, n, k)
    if precision == "bf16":
        return 2 * spectral_param_count(m, n, k)
    if precision == "int8":
        return k * (m + n) + 4 * (2 * k) + 4 * k
    raise ValueError(precision)

ROWS = [
    ("SmolLM2-135M", 576, 1536, 13),
    ("SmolLM2-360M", 1024, 4096, 26),
    ("SmolLM2-1.7B", 2048, 8192, 51),
    ("LLaMA-7B", 4096, 11008, 93),
    ("Qwen-27B", 4096, 17408, 104),
    ("LLaMA-70B", 8192, 28672, 199),
]


def bench_spec():
    """The resolved BenchSpec (--dump-spec parity; also embedded in the
    envelope so --spec-from can rerun it)."""
    from repro.api import BenchSpec, ModelSpec

    return BenchSpec(name="table1", model=ModelSpec("smollm2-1.7b",
                                                    reduced=True),
                     overloads="1", schedulers="fifo")


def run(json_out: str | None = None) -> list[str]:
    out = []
    entries: list[dict] = []
    k = 32
    print("# Paper Table 1 — per-MLP-layer training memory at rank 32")
    print(f"{'model':14s} {'layer':14s} {'dense+adam':>12s} {'sct(k=32)':>12s} "
          f"{'ratio':>7s} {'paper':>6s}")
    for name, m, n, expected in ROWS:
        dense_b = 4 * dense_param_count(m, n) * 4      # fp32, x4 adam
        sct_b = 4 * spectral_param_count(m, n, k) * 4
        ratio = dense_b / sct_b
        status = "OK" if round(ratio) == expected else "MISMATCH"
        print(f"{name:14s} {m}x{n:<8d} {dense_b/1e6:10.1f}MB {sct_b/1e6:10.2f}MB "
              f"{ratio:6.0f}x {expected:5d}x  {status}")
        out.append(f"table1_{name},0,{ratio:.1f}x_vs_paper_{expected}x_{status}")
        entries.append({
            "name": f"table1_{name}",
            "deterministic": {
                "m": m, "n": n, "rank": k,
                "dense_adam_bytes": dense_b,
                "sct_adam_bytes": sct_b,
                "ratio": round(ratio),
                "paper_ratio": expected,
                "matches_paper": round(ratio) == expected,
            }})

    # instantiated check (smallest row): real arrays + real Adam state
    t0 = time.time()
    p = spectral_init(jax.random.PRNGKey(0), 576, 1536, k)
    opt = adamw_init(p)
    actual = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(p))
    actual += sum(x.size * x.dtype.itemsize
                  for x in jax.tree.leaves((opt["mu"], opt["nu"])))
    # grads would mirror params:
    actual += sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(p))
    us = (time.time() - t0) * 1e6
    expect = 4 * spectral_param_count(576, 1536, k) * 4
    print(f"instantiated SCT state @135M-layer: {actual/1e6:.2f}MB "
          f"(analytic {expect/1e6:.2f}MB)")
    out.append(f"table1_instantiated,{us:.0f},{actual}B")
    entries.append({
        "name": "table1_instantiated",
        "us_per_call": round(us, 1),
        "deterministic": {"actual_bytes": int(actual),
                          "analytic_bytes": expect,
                          "matches_analytic": int(actual) == expect}})

    # ---- per-precision serving weight bytes per MLP layer -------------
    print("\n# Serving weight bytes per MLP layer, by precision "
          "(dense fp32 as baseline)")
    print(f"{'model':14s} {'dense_fp32':>11s} {'sct_fp32':>10s} "
          f"{'sct_bf16':>10s} {'sct_int8':>10s} {'int8_vs_dense':>13s}")
    for name, m, n, _ in ROWS:
        dense_b = 4 * dense_param_count(m, n)
        row = {pr: _sct_serving_bytes(m, n, k, pr)
               for pr in ("fp32", "bf16", "int8")}
        print(f"{name:14s} {dense_b/1e6:9.2f}MB {row['fp32']/1e6:8.3f}MB "
              f"{row['bf16']/1e6:8.3f}MB {row['int8']/1e6:8.3f}MB "
              f"{dense_b/row['int8']:11.0f}x")
        out.append(f"table1_serving_{name},0,"
                   f"int8={row['int8']}B;ratio={dense_b/row['int8']:.0f}x")
        entries.append({
            "name": f"table1_serving_{name}",
            "deterministic": {
                "dense_fp32_bytes": dense_b,
                "sct_fp32_bytes": row["fp32"],
                "sct_bf16_bytes": row["bf16"],
                "sct_int8_bytes": row["int8"],
                "int8_vs_dense": round(dense_b / row["int8"]),
            }})

    # instantiated: quantize_tree over a real spectral layer must match
    # the analytic int8 figure (q8 + 2 scale vectors + s)
    from repro.serving.quantize import param_bytes, quantize_tree

    qp = quantize_tree(p)
    got = param_bytes(qp)
    want = _sct_serving_bytes(576, 1536, k, "int8")
    status = "OK" if got == want else f"MISMATCH (analytic {want})"
    print(f"instantiated int8 @135M-layer: {got/1e6:.3f}MB  {status}")
    out.append(f"table1_int8_instantiated,0,{got}B_{status}")
    entries.append({
        "name": "table1_int8_instantiated",
        "deterministic": {"quantized_bytes": int(got),
                          "analytic_bytes": want,
                          "matches_analytic": int(got) == want}})

    if json_out:
        from repro.bench import write_bench
        from repro.bench.schema import bench_envelope

        doc = bench_envelope("table1", bench_spec().to_dict(), results=[],
                             entries=entries)
        write_bench(doc, json_out)
        print(f"wrote {json_out}")
    return out


if __name__ == "__main__":
    run(json_out="BENCH_table1.json")
