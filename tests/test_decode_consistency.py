"""Serving-path integrity: prefill + single-token decode must agree with
the training forward for every family (exact up to bf16 cache rounding),
and the prefix-cached + chunked-prefill streaming engine must reproduce
the static-cache oracle token for token.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.models.model import (
    init_model,
    init_decode_state,
    prefill,
    decode_step,
    forward,
)

FAMS = [
    ("llama3.2-1b", 0.02),        # bf16 KV cache rounding
    ("qwen2-vl-72b", 0.02),
    ("deepseek-v3-671b", 0.05),   # MoE + MLA absorbed decode
    ("jamba-v0.1-52b", 0.05),
    ("xlstm-1.3b", 0.02),
]


@pytest.mark.parametrize("arch,tol", FAMS)
def test_decode_matches_forward(arch, tol, key):
    cfg = get_config(arch, reduced=True).replace(dtype="float32", capacity_factor=8.0)
    params = init_model(key, cfg)
    b, plen, S = 2, 8, 32
    prompt = jax.random.randint(key, (b, plen), 0, cfg.vocab)
    state = init_decode_state(cfg, b, S)
    logits, state = prefill(params, prompt, cfg, state)
    # prefill last-token logits == forward last-token logits
    flogits, _ = forward(params, prompt, cfg)
    np.testing.assert_allclose(
        np.asarray(logits[:, 0], np.float32), np.asarray(flogits[:, -1], np.float32),
        atol=tol, rtol=tol)
    # decode one token and compare against the full forward
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    dlogits, state = decode_step(params, tok, state, jnp.int32(plen), cfg)
    full = jnp.concatenate([prompt, tok], axis=1)
    flogits2, _ = forward(params, full, cfg)
    np.testing.assert_allclose(
        np.asarray(dlogits[:, 0], np.float32), np.asarray(flogits2[:, -1], np.float32),
        atol=tol, rtol=tol)


def test_multi_step_decode_stays_consistent(key):
    cfg = get_config("llama3.2-1b", reduced=True).replace(dtype="float32")
    params = init_model(key, cfg)
    b, plen, gen, S = 2, 4, 6, 16
    prompt = jax.random.randint(key, (b, plen), 0, cfg.vocab)
    state = init_decode_state(cfg, b, S)
    logits, state = prefill(params, prompt, cfg, state)
    toks = [jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)]
    for i in range(gen):
        logits, state = decode_step(params, toks[-1], state, jnp.int32(plen + i), cfg)
        toks.append(jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32))
    seq = jnp.concatenate([prompt] + toks, axis=1)
    # greedy-decode the same prefix with the training forward
    flogits, _ = forward(params, seq[:, :-1], cfg)
    ref_next = jnp.argmax(flogits[:, plen - 1:], axis=-1)
    got_next = jnp.concatenate(toks, axis=1)
    np.testing.assert_array_equal(np.asarray(got_next), np.asarray(ref_next))


@pytest.mark.parametrize("arch,shares", [
    ("llama3.2-1b", True),        # GQA
    ("deepseek-v3-671b", True),   # absorbed MLA + MoE
    ("xlstm-1.3b", False),        # recurrent: explicit prefix-sharing opt-out
    ("jamba-v0.1-52b", False),    # hybrid mamba: opt-out
])
def test_prefix_chunked_greedy_matches_static(arch, shares, key):
    """Prefix-cached + chunked-prefill serving is token-identical to the
    static-cache oracle. Attention families actually reuse cached
    prefix pages; recurrent families opt out of sharing/chunking
    (models/decode.py:PREFIX_SHARING_FAMILIES) and must still serve the
    same flags token-identically through full-prompt prefill."""
    from repro.launch.serve import static_greedy_reference
    from repro.serving import PagedCacheConfig, Request
    from repro.serving.engine import ServingEngine

    cfg = get_config(arch, reduced=True).replace(dtype="float32",
                                                 capacity_factor=8.0)
    params = init_model(key, cfg)
    pcfg = PagedCacheConfig(page_size=4, num_pages=32, max_slots=2,
                            max_pages_per_seq=6)
    rng = np.random.default_rng(0)
    system = rng.integers(0, cfg.vocab, size=(9,)).astype(np.int32)
    reqs = [Request(rid=i,
                    prompt=np.concatenate(
                        [system, rng.integers(0, cfg.vocab, size=(t,)).astype(np.int32)]),
                    max_new_tokens=g, arrival=a)
            for i, (t, g, a) in enumerate([(3, 4, 0), (2, 3, 2), (4, 4, 4)])]
    engine = ServingEngine(cfg, params, pcfg, prefill_token_budget=6,
                           prefix_cache=True, chunked_prefill=True)
    assert engine.prefix_cache == shares and engine.chunked_prefill == shares
    out = engine.run(reqs)
    engine.sched.check_invariants()
    st = engine.stats()
    if shares:
        assert st["prefix_shared_tokens"] > 0, "no prefix pages were reused"
    else:
        assert st["prefill_tokens"] == st["prompt_tokens"]   # full-prompt prefill
    for r in reqs:
        ref = static_greedy_reference(cfg, params, r.prompt, r.max_new_tokens,
                                      pcfg.max_seq)
        np.testing.assert_array_equal(out[r.rid], ref, err_msg=f"{arch} rid {r.rid}")


@pytest.mark.xfail(
    strict=False,
    reason="known bf16 divergence: absorbed-MLA chunked prefill folds wuk "
           "into the query before the latent dot product, so its bf16 "
           "rounding differs from the static oracle's naive prefill; "
           "near-argmax ties occasionally flip a token (docs/serving.md). "
           "fp32 is exact — test_prefix_chunked_greedy_matches_static.")
def test_bf16_mla_chunked_prefill_token_exact(key):
    """Pin the bf16 absorbed-MLA prefill divergence instead of hiding it:
    at the family's native bfloat16, the chunked paged engine is NOT
    guaranteed token-identical to the static greedy oracle. When this
    starts passing consistently the xfail should be dropped."""
    outs, refs = _bf16_mla_engine_vs_oracle(key)
    for rid in outs:
        np.testing.assert_array_equal(outs[rid], refs[rid])


def test_bf16_mla_chunked_prefill_agreement_floor(key):
    """The companion tolerance bound: bf16 disagreement is a rare tie
    flip (after which the greedy trajectories legitimately separate),
    not wholesale divergence. Two invariants a real chunk-path
    regression would break: every request's first generated token (the
    prefill tail argmax) matches the oracle, and most requests match
    token-for-token end to end."""
    outs, refs = _bf16_mla_engine_vs_oracle(key)
    for r in outs:
        assert outs[r][0] == refs[r][0], \
            f"rid {r}: first token {outs[r][0]} != oracle {refs[r][0]}"
    exact = sum(int(np.array_equal(outs[r], refs[r])) for r in outs)
    assert exact >= len(outs) / 2, \
        f"only {exact}/{len(outs)} requests token-exact at bf16"


def _bf16_mla_engine_vs_oracle(key):
    from repro.launch.serve import static_greedy_reference
    from repro.serving import PagedCacheConfig, Request
    from repro.serving.engine import ServingEngine

    cfg = get_config("deepseek-v3-671b", reduced=True).replace(
        capacity_factor=8.0)   # native bfloat16 kept
    assert cfg.dtype == "bfloat16"
    params = init_model(key, cfg)
    # the serve CLI's default trace geometry — the workload the
    # divergence was first observed on (request 2, a 21-token prompt)
    pcfg = PagedCacheConfig(page_size=16, num_pages=64, max_slots=4,
                            max_pages_per_seq=8)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, size=(t,)).astype(np.int32),
                    max_new_tokens=g, arrival=0)
            for i, (t, g) in enumerate([(9, 4), (16, 8), (21, 12), (13, 4)])]
    engine = ServingEngine(cfg, params, pcfg, prefill_token_budget=64,
                           chunked_prefill=True)
    outs = engine.run(reqs)
    refs = {r.rid: static_greedy_reference(cfg, params, r.prompt,
                                           r.max_new_tokens, pcfg.max_seq)
            for r in reqs}
    return outs, refs


def test_whisper_encdec_decode(key):
    cfg = get_config("whisper-medium", reduced=True).replace(dtype="float32")
    from repro.models.encdec import encode

    params = init_model(key, cfg)
    b, plen, S = 2, 4, 16
    frames = jax.random.normal(key, (b, cfg.encoder_seq, cfg.d_model)) * 0.1
    prompt = jax.random.randint(key, (b, plen), 0, cfg.vocab)
    state = init_decode_state(cfg, b, S)
    logits, state = prefill(params, prompt, cfg, state, encoder_frames=frames)
    enc_out = encode(params, frames, cfg)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    l2, state = decode_step(params, tok, state, jnp.int32(plen), cfg, encoder_out=enc_out)
    assert l2.shape == (b, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(l2.astype(jnp.float32))))
