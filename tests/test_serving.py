"""Serving runtime tests: paged-cache read/append equivalence against
the static layout, scheduler admission/eviction invariants (no slot
leak, no starvation under a full queue), and token-for-token greedy
equivalence between the paged streaming engine and the static-cache
path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.launch.serve import static_greedy_reference
from repro.models.decode import ATTN_STATE_KEYS
from repro.models.model import (
    decode_step,
    decode_step_paged,
    init_decode_state,
    init_model,
    init_paged_state,
    prefill,
)
from repro.serving import (
    ContinuousBatchingScheduler,
    PagedCacheConfig,
    PagePool,
    Request,
    paged_append,
    paged_gather,
    paged_write_pages,
)
from repro.serving.engine import ServingEngine


# ======================================================================
# Paged cache ops
# ======================================================================

def test_paged_append_gather_matches_static(key):
    """Token-by-token paged appends reproduce the dense (b, S) cache."""
    pcfg = PagedCacheConfig(page_size=4, num_pages=12, max_slots=2, max_pages_per_seq=4)
    kvh, hd = 2, 8
    pool = jnp.zeros((pcfg.num_pages + 1, pcfg.page_size, kvh, hd))
    pool_alloc = PagePool(pcfg.num_pages)
    lens = [9, 5]                       # mixed lengths, non-page-aligned
    bt = np.full((2, pcfg.max_pages_per_seq), pcfg.null_page, dtype=np.int32)
    for slot, n in enumerate(lens):
        pages = pool_alloc.alloc(pcfg.pages_for(n + 1))
        bt[slot, :len(pages)] = pages

    static = np.zeros((2, pcfg.max_seq, kvh, hd), dtype=np.float32)
    vals = jax.random.normal(key, (max(lens) + 1, 2, kvh, hd))
    null_row = np.full((pcfg.max_pages_per_seq,), pcfg.null_page, dtype=np.int32)
    for t in range(max(lens) + 1):
        # finished slots are evicted: block table row on the null page
        live_bt = np.stack([bt[s] if t <= lens[s] else null_row for s in range(2)])
        seq_lens = jnp.asarray([t if t <= lens[s] else 0 for s in range(2)],
                               dtype=jnp.int32)
        pool = paged_append(pool, jnp.asarray(live_bt), seq_lens, vals[t])
        for slot in range(2):
            if t <= lens[slot]:
                static[slot, t] = np.asarray(vals[t, slot])

    view = np.asarray(paged_gather(pool, jnp.asarray(bt)))
    for slot, n in enumerate(lens):
        np.testing.assert_array_equal(view[slot, :n + 1], static[slot, :n + 1])


def test_paged_write_pages_roundtrip(key):
    """Prompt-cache scatter (with a leading layer-stack axis) lands the
    tokens at their logical positions; the padded page tail stays out of
    the valid range."""
    page, L, f = 4, 3, 5
    pool = jnp.zeros((L, 9, page, f))
    vals = jax.random.normal(key, (L, 10, f))          # 10 tokens -> 3 pages
    page_ids = jnp.asarray([7, 2, 5], dtype=jnp.int32)
    pool = paged_write_pages(pool, page_ids, vals, n_stack=1)
    bt = jnp.asarray([[7, 2, 5, 8]], dtype=jnp.int32)  # 8 = null page
    view = paged_gather(pool[1], bt[0:1])              # layer 1
    np.testing.assert_allclose(np.asarray(view[0, :10]), np.asarray(vals[1]),
                               rtol=1e-6, atol=1e-6)


def test_page_pool_accounting():
    pool = PagePool(4)
    a = pool.alloc(3)
    assert pool.free_count == 1 and pool.allocated_count == 3
    with pytest.raises(RuntimeError):
        pool.alloc(2)
    pool.free(a[:2])
    assert pool.free_count == 3
    with pytest.raises(RuntimeError):
        pool.free([a[0]])               # double free


# ======================================================================
# Scheduler invariants
# ======================================================================

def _finish_prefill(sched, seq, tok=1):
    """Simulate the engine's prefill of an admitted sequence: mark the
    prompt fully cached, join the decode batch, record the first
    token."""
    seq.prefill_pos = seq.request.prompt_len
    sched.finish_prefill(seq.slot)
    sched.on_prefill_token(seq.slot, tok)


def _drive(sched, max_steps=200):
    """Run the scheduler protocol with fake tokens until idle, checking
    invariants after every step. Returns (admission order, drained)."""
    admitted, drained = [], []
    steps = 0
    while sched.has_work:
        assert steps < max_steps, "scheduler wedged"
        admitted += [seq.request.rid for seq in sched.admit()]
        for seq in sched.prefilling():   # covers pre-driven admissions too
            _finish_prefill(sched, seq)
        sched.ensure_append_capacity()
        for slot, seq in list(sched.active.items()):
            if seq.status == "decoding":
                sched.on_token(slot, 1)
        sched.check_invariants()
        drained += sched.drain_finished()
        steps += 1
    return admitted, drained


def test_scheduler_no_slot_or_page_leak():
    pcfg = PagedCacheConfig(page_size=4, num_pages=16, max_slots=2, max_pages_per_seq=4)
    sched = ContinuousBatchingScheduler(pcfg)
    rng = np.random.default_rng(0)
    for i in range(7):
        plen = int(rng.integers(2, 9))
        sched.submit(Request(rid=i, prompt=np.zeros(plen, np.int32),
                             max_new_tokens=int(rng.integers(1, 8 - 1))))
    _, drained = _drive(sched)
    assert len(drained) == 7 and sched.finished_count == 7
    assert not sched.drain_finished()        # results drained, not retained
    assert sched.pool.allocated_count == 0 and sched.pool.free_count == 16
    assert len(sched._free_slots) == pcfg.max_slots
    assert np.all(sched.block_table == pcfg.null_page)
    assert np.all(sched.seq_lens == 0)


def test_scheduler_fifo_no_starvation_under_full_queue():
    """A big head request must not be starved by small later ones: when
    it can't fit, nothing behind it is admitted either, and it runs as
    soon as capacity frees."""
    pcfg = PagedCacheConfig(page_size=4, num_pages=8, max_slots=2, max_pages_per_seq=4)
    sched = ContinuousBatchingScheduler(pcfg)
    sched.submit(Request(rid=0, prompt=np.zeros(10, np.int32), max_new_tokens=4))
    sched.submit(Request(rid=1, prompt=np.zeros(10, np.int32), max_new_tokens=4))
    first = sched.admit()
    assert [s.request.rid for s in first] == [0, 1]     # both fit: 4+4 pages
    # queue a big request then a stream of small ones behind it
    sched.submit(Request(rid=2, prompt=np.zeros(12, np.int32), max_new_tokens=4))
    for i in range(3, 6):
        sched.submit(Request(rid=i, prompt=np.zeros(2, np.int32), max_new_tokens=2))
    assert sched.admit() == []                          # no pages AND no queue-jumping
    order, _ = _drive(sched)
    # the big request is admitted before every small one queued behind it
    assert order.index(2) < order.index(3) < order.index(4) < order.index(5)


def test_scheduler_prefill_token_budget():
    pcfg = PagedCacheConfig(page_size=4, num_pages=32, max_slots=4, max_pages_per_seq=4)
    sched = ContinuousBatchingScheduler(pcfg, prefill_token_budget=10)
    for i in range(3):
        sched.submit(Request(rid=i, prompt=np.zeros(6, np.int32), max_new_tokens=2))
    assert [s.request.rid for s in sched.admit()] == [0]   # 6+6 > 10
    assert [s.request.rid for s in sched.admit()] == [1]
    assert [s.request.rid for s in sched.admit()] == [2]


def test_scheduler_rejects_oversized_request():
    pcfg = PagedCacheConfig(page_size=4, num_pages=8, max_slots=2, max_pages_per_seq=2)
    sched = ContinuousBatchingScheduler(pcfg)
    with pytest.raises(ValueError):
        sched.submit(Request(rid=0, prompt=np.zeros(8, np.int32), max_new_tokens=4))


# ======================================================================
# Paged decode vs static decode
# ======================================================================

@pytest.mark.parametrize("arch", ["llama3.2-1b", "deepseek-v3-671b", "jamba-v0.1-52b"])
def test_paged_decode_step_matches_static(arch, key):
    """One decode step, same fill level: the paged (GQA, absorbed MLA,
    and hybrid-mamba) paths must agree with the static-cache step."""
    cfg = get_config(arch, reduced=True).replace(dtype="float32", capacity_factor=8.0)
    params = init_model(key, cfg)
    b, plen = 2, 6
    pcfg = PagedCacheConfig(page_size=4, num_pages=8, max_slots=b, max_pages_per_seq=3)
    S = pcfg.max_seq

    prompt = jax.random.randint(key, (b, plen), 0, cfg.vocab)
    state = init_decode_state(cfg, b, S)
    logits, state = prefill(params, prompt, cfg, state)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    ref_logits, _ = decode_step(params, tok, state, jnp.int32(plen), cfg)

    # build the equivalent paged state by scattering the prefilled cache
    pstate = init_paged_state(cfg, pcfg)
    pool_alloc = PagePool(pcfg.num_pages)
    bt = np.full((b, pcfg.max_pages_per_seq), pcfg.null_page, dtype=np.int32)
    for slot in range(b):
        pages = pool_alloc.alloc(pcfg.pages_for(plen + 1))
        bt[slot, :len(pages)] = pages
    for ck in list(pstate):
        if ck in ATTN_STATE_KEYS:
            for slot in range(b):
                ids = jnp.asarray(bt[slot][bt[slot] != pcfg.null_page])
                pstate[ck] = jax.tree.map(
                    lambda pool, v: paged_write_pages(
                        pool, ids, v[:, slot, :plen], n_stack=1),
                    pstate[ck], state[ck])
        else:
            # recurrent (mamba/xlstm) state: slot-indexed with the same
            # layout in both constructions (max_slots == batch here)
            pstate[ck] = state[ck]
    seq_lens = jnp.full((b,), plen, dtype=jnp.int32)
    pl_logits, _ = decode_step_paged(params, tok, pstate, jnp.asarray(bt), seq_lens, cfg)
    np.testing.assert_allclose(np.asarray(ref_logits, np.float32),
                               np.asarray(pl_logits, np.float32),
                               atol=2e-4, rtol=2e-4)


# ======================================================================
# Streaming engine vs static path (token-for-token)
# ======================================================================

def test_streaming_engine_matches_static_greedy(key):
    """The acceptance property: a staggered mixed-length trace through
    the continuous-batching engine reproduces the static path's greedy
    tokens exactly, for every request."""
    cfg = get_config("llama3.2-1b", reduced=True)
    params = init_model(key, cfg)
    pcfg = PagedCacheConfig(page_size=8, num_pages=24, max_slots=3, max_pages_per_seq=4)
    rng = np.random.default_rng(0)
    spec = [(5, 6, 0), (11, 4, 0), (7, 8, 1), (3, 5, 3)]   # (plen, gen, arrival)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=(n,)).astype(np.int32),
                    max_new_tokens=g, arrival=a)
            for i, (n, g, a) in enumerate(spec)]
    engine = ServingEngine(cfg, params, pcfg, prefill_token_budget=16)
    out = engine.run(reqs)
    engine.sched.check_invariants()
    assert engine.sched.pool.allocated_count == 0       # everything evicted
    for r in reqs:
        ref = static_greedy_reference(cfg, params, r.prompt, r.max_new_tokens,
                                      pcfg.max_seq)
        np.testing.assert_array_equal(out[r.rid], ref, err_msg=f"request {r.rid}")


# ======================================================================
# Shared-prefix reuse, chunked prefill, cancellation, deadlines
# ======================================================================

def _shared_prefix_trace(vocab, n=4, system=17, gen=5, stride=3):
    rng = np.random.default_rng(2)
    sysp = rng.integers(0, vocab, size=(system,)).astype(np.int32)
    return [Request(rid=i,
                    prompt=np.concatenate(
                        [sysp, rng.integers(0, vocab, size=(3 + i,)).astype(np.int32)]),
                    max_new_tokens=gen, arrival=i * stride)
            for i in range(n)]


@pytest.mark.parametrize("chunked", [False, True])
def test_prefix_cache_engine_matches_static(key, chunked):
    """Prefix-cached (and chunked) serving is token-identical to the
    static oracle while actually skipping shared prompt compute."""
    cfg = get_config("llama3.2-1b", reduced=True)
    params = init_model(key, cfg)
    pcfg = PagedCacheConfig(page_size=8, num_pages=32, max_slots=2, max_pages_per_seq=4)
    reqs = _shared_prefix_trace(cfg.vocab)
    engine = ServingEngine(cfg, params, pcfg, prefill_token_budget=8,
                           prefix_cache=True, chunked_prefill=chunked)
    out = engine.run(reqs)
    engine.sched.check_invariants()
    st = engine.stats()
    assert st["prefix_shared_tokens"] > 0, "no prefix reuse happened"
    assert st["prefill_tokens"] + st["prefix_shared_tokens"] == st["prompt_tokens"]
    for r in reqs:
        ref = static_greedy_reference(cfg, params, r.prompt, r.max_new_tokens,
                                      pcfg.max_seq)
        np.testing.assert_array_equal(out[r.rid], ref, err_msg=f"request {r.rid}")
    # pages still allocated are exactly the index's retained prefixes
    assert engine.sched.pool.allocated_count == len(engine.sched.prefix_cache.pages)


def test_prefix_cache_survives_across_runs(key):
    """The index retains prefixes after their sequences finish: a second
    run() over the same system prompt starts warm."""
    cfg = get_config("llama3.2-1b", reduced=True)
    params = init_model(key, cfg)
    pcfg = PagedCacheConfig(page_size=8, num_pages=32, max_slots=2, max_pages_per_seq=4)
    reqs = _shared_prefix_trace(cfg.vocab, n=2)
    engine = ServingEngine(cfg, params, pcfg, prefix_cache=True)
    engine.run(reqs)
    shared_before = engine.stats()["prefix_shared_tokens"]
    out = engine.run([Request(rid=10, prompt=reqs[0].prompt, max_new_tokens=4)])
    assert engine.stats()["prefix_shared_tokens"] > shared_before
    ref = static_greedy_reference(cfg, params, reqs[0].prompt, 4, pcfg.max_seq)
    np.testing.assert_array_equal(out[10], ref)


def test_chunked_prefill_without_budget_still_chunks(key):
    """chunked_prefill=True with no prefill_token_budget must not
    silently degrade to whole-tail prefill: a default chunk size kicks
    in, the prompt spans multiple engine steps, outputs stay exact."""
    cfg = get_config("llama3.2-1b", reduced=True)
    params = init_model(key, cfg)
    pcfg = PagedCacheConfig(page_size=4, num_pages=24, max_slots=2, max_pages_per_seq=6)
    assert ServingEngine(cfg, params, pcfg, chunked_prefill=True).prefill_chunk \
        == 4 * pcfg.page_size
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab, size=(20,)).astype(np.int32)  # > one chunk
    engine = ServingEngine(cfg, params, pcfg, chunked_prefill=True)
    out = engine.run([Request(rid=0, prompt=prompt, max_new_tokens=4)])
    ref = static_greedy_reference(cfg, params, prompt, 4, pcfg.max_seq)
    np.testing.assert_array_equal(out[0], ref)


def test_scheduler_cancel_waiting_and_active():
    pcfg = PagedCacheConfig(page_size=4, num_pages=16, max_slots=1, max_pages_per_seq=4)
    sched = ContinuousBatchingScheduler(pcfg)
    for i in range(3):
        sched.submit(Request(rid=i, prompt=np.zeros(4, np.int32), max_new_tokens=4))
    (seq,) = sched.admit()
    _finish_prefill(sched, seq)
    assert sched.cancel(1)                    # waiting: dropped from the queue
    assert sched.cancel(0)                    # active: evicted with partial output
    assert not sched.cancel(99)               # unknown rid
    sched.check_invariants()
    drained = {s.request.rid: s for s in sched.drain_finished()}
    assert drained[0].status == "cancelled" and drained[1].status == "cancelled"
    assert sched.pool.allocated_count == 0
    # the queue head (rid 2) proceeds into the freed slot
    (seq2,) = sched.admit()
    assert seq2.request.rid == 2


def test_engine_request_deadline_times_out(key):
    """A request whose deadline can't cover its decode length is evicted
    with status 'timeout' and a partial output that is a prefix of the
    oracle's tokens; pool accounting stays clean."""
    cfg = get_config("llama3.2-1b", reduced=True)
    params = init_model(key, cfg)
    pcfg = PagedCacheConfig(page_size=8, num_pages=24, max_slots=2, max_pages_per_seq=4)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, size=(6,)).astype(np.int32)
    reqs = [Request(rid=0, prompt=prompt, max_new_tokens=12, deadline=4),
            Request(rid=1, prompt=prompt, max_new_tokens=3)]
    engine = ServingEngine(cfg, params, pcfg)
    out = engine.run(reqs)
    engine.sched.check_invariants()
    assert engine.last_statuses[0] == "timeout"
    assert engine.last_statuses[1] == "finished"
    assert 0 < len(out[0]) < 12
    ref = static_greedy_reference(cfg, params, prompt, 12, pcfg.max_seq)
    np.testing.assert_array_equal(out[0], ref[:len(out[0])])
    assert engine.sched.pool.allocated_count == 0
    assert engine.stats()["timed_out"] == 1.0


def test_scheduler_cow_fork_on_shared_append_target():
    """A decode append whose target page is shared must fork it: fresh
    page in the block table, old page released, fork reported for the
    device copy, invariants green."""
    pcfg = PagedCacheConfig(page_size=4, num_pages=16, max_slots=1, max_pages_per_seq=4)
    sched = ContinuousBatchingScheduler(pcfg)
    sched.submit(Request(rid=0, prompt=np.zeros(6, np.int32), max_new_tokens=4))
    (seq,) = sched.admit()
    _finish_prefill(sched, seq)
    target = seq.pages[seq.seq_len // pcfg.page_size]
    sched.pool.share([target])                # simulate another holder
    forks = sched.ensure_append_capacity()
    assert forks == [(seq.slot, target, seq.pages[seq.seq_len // pcfg.page_size])]
    new = seq.pages[seq.seq_len // pcfg.page_size]
    assert new != target and sched.pool.refcount(new) == 1
    assert sched.pool.refcount(target) == 1   # our ref released, other holder's kept
    assert sched.block_table[seq.slot, seq.seq_len // pcfg.page_size] == new
    assert sched.cow_forks == 1
    sched.pool.release([target])              # the simulated holder lets go
    sched.check_invariants()


def test_copy_page_device_op(key):
    """The device half of a COW fork: dst page becomes bit-identical to
    src across a layer-stacked pool leaf."""
    from repro.serving import copy_page

    pool = jax.random.normal(key, (5, 4, 3))            # (P, page, f)
    out = copy_page(pool, jnp.int32(1), jnp.int32(3))
    np.testing.assert_array_equal(np.asarray(out[3]), np.asarray(pool[1]))
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(pool[0]))


def test_paged_write_slice_offsets(key):
    """Offset writes land tokens at their logical positions across page
    boundaries — the chunked-prefill write primitive."""
    from repro.serving import paged_write_slice

    page, f = 4, 3
    pool = jnp.zeros((7, page, f))
    bt = jnp.asarray([5, 2, 0], dtype=jnp.int32)
    vals = jax.random.normal(key, (6, f))               # spans pages 1..2 of the seq
    out = paged_write_slice(pool, bt, jnp.int32(3), vals)
    view = np.asarray(paged_gather(out, bt[None]))[0]   # (12, f) logical view
    np.testing.assert_array_equal(view[3:9], np.asarray(vals))
    np.testing.assert_array_equal(view[:3], np.zeros((3, f)))


def test_streaming_engine_recurrent_family(key):
    """Slot-scattered recurrent state (xlstm): interleaved requests must
    decode identically to isolated single-request runs."""
    cfg = get_config("xlstm-1.3b", reduced=True)
    params = init_model(key, cfg)
    pcfg = PagedCacheConfig(page_size=4, num_pages=12, max_slots=2, max_pages_per_seq=3)
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=(n,)).astype(np.int32),
                    max_new_tokens=g, arrival=a)
            for i, (n, g, a) in enumerate([(4, 4, 0), (6, 3, 1)])]
    engine = ServingEngine(cfg, params, pcfg)
    out = engine.run(reqs)
    for r in reqs:
        solo = ServingEngine(cfg, params, pcfg)
        ref = solo.run([Request(rid=0, prompt=r.prompt,
                                max_new_tokens=r.max_new_tokens)])[0]
        np.testing.assert_array_equal(out[r.rid], ref, err_msg=f"request {r.rid}")


# ======================================================================
# Accounting regressions: peak-page high-water and deadline anchoring
# ======================================================================

def test_page_pool_peak_is_allocation_site_high_water():
    """peak_allocated is recorded inside alloc(), so it survives
    releases and only moves when a new allocation exceeds it."""
    pool = PagePool(8)
    a = pool.alloc(3)
    assert pool.peak_allocated == 3
    pool.release(a)
    assert pool.allocated_count == 0
    assert pool.peak_allocated == 3          # high-water survives release
    b = pool.alloc(2)
    assert pool.peak_allocated == 3          # below the old peak: unchanged
    c = pool.alloc(4)
    assert pool.peak_allocated == 6
    pool.release(b)
    pool.release(c)
    assert pool.peak_allocated == 6


def test_engine_peak_pages_counts_mid_step_alloc(key):
    """Regression: a request whose final engine step both allocates its
    boundary page and finishes (releasing every page before the step
    ends) must still report the transient maximum. An end-of-step
    sample sees one page — or zero — and undercounts capacity."""
    cfg = get_config("llama3.2-1b", reduced=True).replace(dtype="float32")
    params = init_model(key, cfg)
    pcfg = PagedCacheConfig(page_size=4, num_pages=8, max_slots=2,
                            max_pages_per_seq=3)
    prompt = np.arange(1, 5, dtype=np.int32)         # exactly one page
    eng = ServingEngine(cfg, params, pcfg)
    out = eng.run([Request(rid=0, prompt=prompt, max_new_tokens=2)])
    assert len(out[0]) == 2
    assert eng.sched.pool.allocated_count == 0       # fully released
    assert eng.peak_pages == 2                       # prompt page + boundary


def test_deadline_anchors_to_submit_on_reused_engine(key):
    """Regression: engine reuse must not charge a new request for steps
    it was never alive for. After a partially-consumed serve() left the
    clock advanced, a fresh deadline-bearing request's expiry counts
    from its submit step — anchored at arrival=0 it would time out
    before ever being served."""
    cfg = get_config("llama3.2-1b", reduced=True).replace(dtype="float32")
    params = init_model(key, cfg)
    pcfg = PagedCacheConfig(page_size=4, num_pages=16, max_slots=2,
                            max_pages_per_seq=4)
    eng = ServingEngine(cfg, params, pcfg)
    rng = np.random.default_rng(0)

    def _req(rid, gen, **kw):
        prompt = rng.integers(1, cfg.vocab, size=(4,)).astype(np.int32)
        return Request(rid=rid, prompt=prompt, max_new_tokens=gen, **kw)

    gen = eng.serve([_req(0, 10), _req(1, 12)])
    next(gen)                    # rid 0 completes; abandon with rid 1 live
    assert eng.has_pending_work
    assert eng._clock > 6        # the clock the late request must not inherit

    late = _req(2, 6, deadline=12)
    out = eng.run([late])        # recovery run: finishes rid 1, serves rid 2
    assert eng.last_statuses[2] == "finished"
    assert len(out[2]) == 6
    assert eng.last_statuses[1] == "finished"
