"""End-to-end training integration: loss decreases on structured
synthetic data, spectral factors stay on-manifold throughout, dense
baseline path works (paper's comparison arm), microbatching is
equivalent to full-batch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.core.tree import max_orthogonality_error
from repro.data.synthetic import SyntheticLMDataset
from repro.launch.steps import make_train_step
from repro.models.model import init_model
from repro.optim import make_sct_optimizer


def _train(cfg, steps=40, lr=3e-3, microbatches=1, batch=8, seq=32):
    opt = make_sct_optimizer(cfg, lr=lr, warmup=4, total_steps=steps)
    step_fn = jax.jit(make_train_step(cfg, opt, microbatches=microbatches))
    state = opt.init(init_model(jax.random.PRNGKey(0), cfg))
    ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=seq, seed=0)
    losses = []
    for i in range(steps):
        t, l = ds.batch(i, batch)
        state, m = step_fn(state, {"tokens": jnp.asarray(t), "labels": jnp.asarray(l)})
        losses.append(float(m["loss"]))
    return state, losses


def test_sct_training_converges(key):
    cfg = get_config("smollm2-1.7b", reduced=True)
    state, losses = _train(cfg)
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])
    assert float(max_orthogonality_error(state["params"])) < 2e-5


def test_dense_baseline_converges(key):
    """The paper's dense comparison arm — same model, spectral off."""
    cfg = get_config("smollm2-1.7b", reduced=True).replace_sct(spectral_mlp=False)
    state, losses = _train(cfg)
    assert losses[-1] < losses[0] - 0.3


def test_sct_param_count_below_dense():
    from repro.models.model import param_count

    cfg_s = get_config("smollm2-1.7b", reduced=True)
    cfg_d = cfg_s.replace_sct(spectral_mlp=False)
    ps = param_count(init_model(jax.random.PRNGKey(0), cfg_s))
    pd = param_count(init_model(jax.random.PRNGKey(0), cfg_d))
    assert ps < pd


def test_microbatching_matches_full_batch():
    """Gradient accumulation must be loss-equivalent to the full batch
    (per-microbatch mean CE over equal-sized slices == full-batch CE)."""
    cfg = get_config("smollm2-1.7b", reduced=True).replace(dtype="float32")
    _, l_full = _train(cfg, steps=6, microbatches=1)
    _, l_micro = _train(cfg, steps=6, microbatches=4)
    np.testing.assert_allclose(l_full, l_micro, rtol=2e-3, atol=2e-3)


def test_moe_training_step_runs_and_balances(key):
    cfg = get_config("deepseek-v3-671b", reduced=True)
    state, losses = _train(cfg, steps=10, lr=1e-3)
    assert np.isfinite(losses).all()


def test_hybrid_and_ssm_training(key):
    for arch in ("jamba-v0.1-52b", "xlstm-1.3b"):
        cfg = get_config(arch, reduced=True)
        state, losses = _train(cfg, steps=8, lr=1e-3)
        assert np.isfinite(losses).all(), arch
