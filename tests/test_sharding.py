"""Sharding-rules unit tests + a real multi-device dry-run on a small
host-device mesh (runs in a subprocess so the 1-device default for the
rest of the suite is preserved)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.config import get_config
from repro.models.model import init_model
from repro.sharding.rules import param_pspecs


def _find(tree, path):
    cur = tree
    for part in path.split("/"):
        cur = cur[part]
    return cur


def test_dense_lm_param_specs(key):
    cfg = get_config("llama3.2-1b", reduced=True)
    params = jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg))
    specs = param_pspecs(params, n_model=2, n_data=2)
    # spectral MLP: V of up is TP-row-sharded, U is FSDP-row-sharded
    assert _find(specs, "layers/mlp/up/V") == P(None, "model", None)
    assert _find(specs, "layers/mlp/up/U") == P(None, "data", None)
    assert _find(specs, "layers/mlp/down/U") == P(None, "model", None)
    assert all(a is None for a in _find(specs, "layers/mlp/up/s"))  # replicated
    # dense attention: col-shard in, row-shard out, FSDP on the other axis
    assert _find(specs, "layers/attn/wq/w") == P(None, "data", "model")
    assert _find(specs, "layers/attn/wo/w") == P(None, "model", "data")
    # embeddings vocab-sharded (128256 % 2 == 0)
    assert _find(specs, "embed/w") == P("model", "data")
    # norms replicated
    assert _find(specs, "layers/attn_norm/scale") == P()


def test_moe_expert_axis_sharded(key):
    cfg = get_config("deepseek-v3-671b", reduced=True)
    params = jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg))
    specs = param_pspecs(params, n_model=2, n_data=2)
    # expert spectral factors: (L, E, m, k) -> E over model, m over data
    assert _find(specs, "moe_layers/moe/gate/U") == P(None, "model", "data", None)
    assert _find(specs, "moe_layers/moe/router/w") == P(None, None, "model")


def test_indivisible_dims_replicate(key):
    """qwen1.5-4b heads (20) don't divide 16 -> explicit replication
    instead of a silent GSPMD gather."""
    cfg = get_config("qwen1.5-4b")
    params = jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg))
    specs = param_pspecs(params, n_model=16, n_data=16)
    # h*hd = 2560 divides 16 -> still sharded on the flat dim
    assert _find(specs, "layers/attn/wq/w") == P(None, "data", "model")
    # granite vocab 49155 doesn't divide -> d-sharded (model) embedding,
    # vocab axis replicated (49155 also doesn't divide the data axis)
    cfg_g = get_config("granite-3-2b")
    params_g = jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg_g))
    specs_g = param_pspecs(params_g, n_model=16, n_data=16)
    assert _find(specs_g, "embed/w") == P(None, "model")


_SUBPROCESS_DRYRUN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, json
from repro.config import get_config, SHAPES
from repro.config.shapes import ShapeSpec
from repro.launch import steps as steps_mod

cfg = get_config("{arch}", reduced=True)
mesh = jax.make_mesh((4, 2), ("data", "model"))
shape = ShapeSpec("t", 64, 8, "{kind}")
lowered = steps_mod.lower_step(cfg, shape, mesh)
compiled = lowered.compile()
cost = compiled.cost_analysis()
if isinstance(cost, (list, tuple)):  # older jax returns [dict]
    cost = cost[0] if cost else {{}}
print(json.dumps({{"flops": cost.get("flops", 0.0)}}))
"""


@pytest.mark.parametrize("arch,kind", [
    ("llama3.2-1b", "train"),
    ("deepseek-v3-671b", "train"),
    ("jamba-v0.1-52b", "train"),
    ("llama3.2-1b", "decode"),
])
def test_small_mesh_dryrun_compiles(arch, kind):
    """lower+compile the real step builders on an 8-device host mesh —
    the same code path the 512-device production dry-run uses."""
    code = _SUBPROCESS_DRYRUN.format(arch=arch, kind=kind)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600, env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    assert payload["flops"] > 0
