"""Self-speculative decoding via the rank ladder (serving/speculative.py):
the correctness bar is token-for-token identity with the plain greedy
engine (and therefore with the static-cache oracle) — acceptance rate
may move latency, never the token stream. Covers both offset-prefill
attention families (GQA dense, MLA MoE), staged and degenerate ladders,
the shared-pool layout property for rank-shrunk restores, and the
acceptance-rate sanity bound on a trained checkpoint."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.config import get_config
from repro.launch.serve import static_greedy_reference
from repro.models.decode import PREFIX_SHARING_FAMILIES
from repro.models.model import init_model, init_paged_state
from repro.rank.resize import clamp_target, current_ranks, resize_tree
from repro.serving import PagedCacheConfig, Request
from repro.serving.engine import ServingEngine
from repro.serving.speculative import (
    SpeculativeEngine,
    derive_drafters,
    parse_ladder,
)

ARCHS = {
    "llama3.2-1b": "dense_lm",         # GQA attention
    "deepseek-v3-671b": "moe_lm",      # MLA attention
}


def _config(arch):
    cfg = get_config(arch, reduced=True).replace(dtype="float32")
    if cfg.family == "moe_lm":
        cfg = cfg.replace(capacity_factor=8.0)
    return cfg


def _pcfg():
    return PagedCacheConfig(page_size=8, num_pages=24, max_slots=3,
                            max_pages_per_seq=4)


def _trace(vocab, spec=((5, 9, 0), (11, 7, 1), (3, 12, 2), (7, 6, 4)),
           seed=0):
    rng = np.random.RandomState(seed)
    return [Request(rid=i,
                    prompt=rng.randint(1, vocab, size=(plen,)).astype(np.int32),
                    max_new_tokens=gen, arrival=arrival)
            for i, (plen, gen, arrival) in enumerate(spec)]


def _fresh(reqs):
    """Requests are mutated by the scheduler (submit_clock); every
    engine run gets its own copies."""
    return [dataclasses.replace(r, submit_clock=None) for r in reqs]


# ======================================================================
# Ladder grammar
# ======================================================================

def test_parse_ladder_grammar():
    assert parse_ladder("8") == [8]
    assert parse_ladder("4,8") == [4, 8]
    assert parse_ladder("8,8") == [8, 8]       # degenerate: legal
    assert parse_ladder(8) == [8]
    assert parse_ladder([4, 8]) == [4, 8]
    for bad in ("", "8,4", "0", "a,b", "-2"):
        with pytest.raises(ValueError):
            parse_ladder(bad)


# ======================================================================
# Token-for-token identity with the static greedy oracle
# ======================================================================

@pytest.mark.parametrize("arch", list(ARCHS))
def test_speculative_matches_static_greedy(arch):
    """The tentpole contract, per attention family: the speculative
    engine's output is exactly the target's greedy decode, across a
    staggered mixed-length trace."""
    cfg = _config(arch)
    assert ARCHS[arch] == cfg.family
    params = init_model(jax.random.PRNGKey(0), cfg)
    pcfg = _pcfg()
    reqs = _trace(cfg.vocab)
    eng = SpeculativeEngine(cfg, params, pcfg, speculative_ranks="8",
                            draft_tokens=4, prefill_token_budget=16)
    out = eng.run(_fresh(reqs))
    eng.sched.check_invariants()
    for r in reqs:
        ref = static_greedy_reference(cfg, params, r.prompt,
                                      r.max_new_tokens, pcfg.max_seq)
        np.testing.assert_array_equal(out[r.rid], ref)
    st = eng.stats()
    assert st["draft_proposed"] > 0
    assert 0.0 <= st["acceptance_rate"] <= 1.0
    assert st["tokens_per_step"] > 0.0


@pytest.mark.parametrize("ranks", ["4,8", "8,8", "16"])
def test_ladder_variants_match_static_greedy(ranks):
    """Staged ladders and degenerate same-rank ladders keep identity.
    A ladder naming the full rank ("16" at reduced scale — the
    [128,128]-style degenerate spec) must not trip the resize path and
    must accept everything (drafter == target bit for bit)."""
    cfg = _config("llama3.2-1b")
    params = init_model(jax.random.PRNGKey(0), cfg)
    pcfg = _pcfg()
    reqs = _trace(cfg.vocab)
    eng = SpeculativeEngine(cfg, params, pcfg, speculative_ranks=ranks,
                            draft_tokens=3, chunked_prefill=True,
                            prefill_token_budget=16)
    out = eng.run(_fresh(reqs))
    eng.sched.check_invariants()
    for r in reqs:
        ref = static_greedy_reference(cfg, params, r.prompt,
                                      r.max_new_tokens, pcfg.max_seq)
        np.testing.assert_array_equal(out[r.rid], ref)
    if ranks == "16":
        assert eng.stats()["acceptance_rate"] == 1.0


def test_eos_mid_burst():
    """A drafted burst containing the EOS token commits only through
    the EOS — identical to the plain engine's stopping point."""
    cfg = _config("llama3.2-1b")
    params = init_model(jax.random.PRNGKey(0), cfg)
    pcfg = _pcfg()
    prompt = np.random.RandomState(0).randint(
        1, cfg.vocab, size=(5,)).astype(np.int32)
    ref = static_greedy_reference(cfg, params, prompt, 9, pcfg.max_seq)
    eos = int(ref[4])
    plain = ServingEngine(cfg, params, pcfg)
    want = plain.run([Request(rid=0, prompt=prompt.copy(),
                              max_new_tokens=9, eos_id=eos)])[0]
    spec = SpeculativeEngine(cfg, params, pcfg, speculative_ranks="8",
                             draft_tokens=4)
    got = spec.run([Request(rid=0, prompt=prompt.copy(),
                            max_new_tokens=9, eos_id=eos)])[0]
    np.testing.assert_array_equal(got, want)
    assert got[-1] == eos


def test_speculative_validation():
    cfg = _config("llama3.2-1b")
    params = init_model(jax.random.PRNGKey(0), cfg)
    pcfg = _pcfg()
    with pytest.raises(ValueError):
        SpeculativeEngine(cfg, params, pcfg, speculative_ranks="8,4")
    with pytest.raises(ValueError):
        SpeculativeEngine(cfg, params, pcfg, speculative_ranks="8",
                          draft_tokens=0)
    with pytest.raises(ValueError):
        SpeculativeEngine(cfg, params, pcfg, speculative_ranks="8",
                          prefix_cache=True)
    recurrent = get_config("jamba-v0.1-52b", reduced=True).replace(
        dtype="float32", capacity_factor=8.0)
    rparams = init_model(jax.random.PRNGKey(0), recurrent)
    with pytest.raises(NotImplementedError):
        SpeculativeEngine(recurrent, rparams, pcfg, speculative_ranks="8")


# ======================================================================
# Shared-pool layout property: rank-shrunk restores serve the same
# page geometry (satellite 4)
# ======================================================================

@pytest.mark.parametrize("arch", list(ARCHS))
def test_rank_shrunk_restore_shares_pool_layout(arch):
    """For every offset-prefill family: a rank-shrunk copy of the
    weights (what ``Server.from_checkpoint`` restores per ladder level)
    decodes valid tokens through a plain engine over the *same* paged
    geometry, and its KV pools are shape-identical to the full-rank
    engine's — the property that lets one physical page id address the
    same logical positions at every rank."""
    cfg = _config(arch)
    assert cfg.family in PREFIX_SHARING_FAMILIES
    params = init_model(jax.random.PRNGKey(0), cfg)
    pcfg = _pcfg()
    (shrunk,) = derive_drafters(params, [8])
    assert set(current_ranks(shrunk)) == {8}
    # same Eckart-Young truncation as a checkpoint restore at rank 8
    expect = resize_tree(jax.random.PRNGKey(0), params,
                         clamp_target(params, 8))
    assert all(np.array_equal(a, b) for a, b in
               zip(jax.tree.leaves(shrunk), jax.tree.leaves(expect)))
    # KV pool geometry is rank-independent: identical leaf shapes
    full_state = init_paged_state(cfg, pcfg)
    assert (jax.tree.map(lambda leaf: leaf.shape, full_state)
            == jax.tree.map(lambda leaf: leaf.shape,
                            init_paged_state(cfg, pcfg)))
    # the shrunk weights serve as a plain engine over the same geometry
    eng = ServingEngine(cfg, shrunk, pcfg)
    reqs = _trace(cfg.vocab, spec=((5, 6, 0), (9, 5, 1)))
    out = eng.run(_fresh(reqs))
    eng.sched.check_invariants()
    for r in reqs:
        toks = out[r.rid]
        assert toks.shape == (r.max_new_tokens,)
        assert np.all((toks >= 0) & (toks < cfg.vocab))
        ref = static_greedy_reference(cfg, shrunk, r.prompt,
                                      r.max_new_tokens, pcfg.max_seq)
        np.testing.assert_array_equal(toks, ref)


# ======================================================================
# Trained checkpoint: one snapshot, ladder restores, acceptance sanity
# ======================================================================

def test_trained_checkpoint_speculative(tmp_path):
    """One checkpoint serves as its own drafter: ``Server.from_checkpoint``
    with a ``serve.speculative_rank`` override restores the same
    snapshot once per ladder rank, output stays token-identical to the
    plain server over the same checkpoint, and — the paper's rank-sweep
    claim made operational — the half-rank drafter of a *trained* model
    agrees with the target often enough to be worth running."""
    from repro.api import (
        CheckpointSpec,
        ModelSpec,
        RunSpec,
        Server,
        ServeSpec,
        Trainer,
        TrainSpec,
    )

    spec = RunSpec(
        model=ModelSpec("llama3.2-1b", reduced=True),
        train=TrainSpec(steps=4, batch=4, seq=32, lr=3e-3),
        checkpoint=CheckpointSpec(directory=str(tmp_path / "ckpt"), every=2),
        serve=ServeSpec(page_size=8, num_pages=32, slots=2,
                        pages_per_seq=4, gen=8),
    )
    Trainer(spec).fit()
    prompts = [np.arange(1, 7, dtype=np.int32),
               np.arange(3, 13, dtype=np.int32)]

    plain = Server.from_checkpoint(str(tmp_path / "ckpt"))
    for p in prompts:
        plain.submit(p)
    want = plain.run()

    spec_server = Server.from_checkpoint(
        str(tmp_path / "ckpt"),
        **{"serve.speculative_rank": "8", "serve.draft_tokens": 4})
    assert isinstance(spec_server.engine, SpeculativeEngine)
    for p in prompts:
        spec_server.submit(p)
    got = spec_server.run()

    assert sorted(got) == sorted(want)
    for rid in want:
        np.testing.assert_array_equal(got[rid], want[rid])
    st = spec_server.stats()
    assert st["draft_proposed"] > 0
    # sanity bound, not a tuning target: a half-rank truncation of a
    # trained rank-16 model must agree well above chance (vocab 512)
    assert st["acceptance_rate"] >= 0.25
