"""Gradient integrity of the fused spectral matmul: the custom_vjp in
kernels/ops.py against (a) autodiff through the pure-jnp
core.spectral.spectral_apply and (b) numerical finite differences via
jax.test_util.check_grads — on shapes that are NOT multiples of the
kernel tiles (bm/cm/cn), so every _pad_to edge in ops.py is exercised
in both forward and backward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.test_util import check_grads

from repro.core.spectral import spectral_apply
from repro.kernels.ops import spectral_matmul

# (M, m, n, k): none of M/m/n a multiple of the tile sizes ops.py picks.
# M=9/17/33 pad up to the bm power of two; m=520 exceeds cm=512 so the
# m axis pads 520->1024; n=700 exceeds cn=512 so the n axis pads
# 700->1024 (the only cases where the inner _pad_to calls are not no-ops).
NON_TILE_SHAPES = [
    (9, 24, 40, 5),
    (17, 33, 21, 7),
    (70, 520, 132, 9),
    (33, 100, 700, 11),
]


def _operands(key, M, m, n, k, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (M, m), dtype)
    U = (jax.random.normal(ks[1], (m, k)) / np.sqrt(m)).astype(dtype)
    s = jax.random.uniform(ks[2], (k,), dtype, 0.5, 1.5)
    V = (jax.random.normal(ks[3], (n, k)) / np.sqrt(n)).astype(dtype)
    return x, U, s, V


def _assert_grads_close(ga, gb, tol=1e-4):
    for a, b in zip(ga, gb):
        scale = max(1.0, float(jnp.max(jnp.abs(b))))
        np.testing.assert_allclose(np.asarray(a, np.float32) / scale,
                                   np.asarray(b, np.float32) / scale,
                                   rtol=tol, atol=tol)


@pytest.mark.parametrize("shape", NON_TILE_SHAPES)
def test_custom_vjp_matches_spectral_apply_autodiff(shape, key):
    """Same loss through the kernel custom_vjp and through autodiff of
    the paper's 3-matmul reference: forward and all four gradients agree
    on pad-exercising shapes."""
    M, m, n, k = shape
    x, U, s, V = _operands(key, M, m, n, k)
    cot = jax.random.normal(jax.random.PRNGKey(99), (M, n))

    f_kernel = lambda x, U, s, V: jnp.sum(spectral_matmul(x, U, s, V) * cot)
    f_ref = lambda x, U, s, V: jnp.sum(
        spectral_apply({"U": U, "s": s, "V": V}, x) * cot)

    np.testing.assert_allclose(
        np.asarray(spectral_matmul(x, U, s, V)),
        np.asarray(spectral_apply({"U": U, "s": s, "V": V}, x)),
        rtol=2e-5, atol=2e-5)
    g_kernel = jax.grad(f_kernel, argnums=(0, 1, 2, 3))(x, U, s, V)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2, 3))(x, U, s, V)
    _assert_grads_close(g_kernel, g_ref)


@pytest.mark.parametrize("shape", [(9, 24, 40, 5), (17, 33, 21, 7)])
def test_check_grads_numerical_rev(shape, key):
    """jax.test_util.check_grads: the custom VJP against numerical
    differences (small shapes — finite differencing is O(inputs))."""
    M, m, n, k = shape
    x, U, s, V = _operands(key, M, m, n, k)
    f = lambda x, U, s, V: spectral_matmul(x, U, s, V)
    check_grads(f, (x, U, s, V), order=1, modes=["rev"], atol=5e-2, rtol=5e-2)


def test_vjp_batched_non_tile_leading_dims(key):
    """Leading batch dims that flatten to a non-tile-multiple M."""
    x = jax.random.normal(key, (3, 5, 24))       # M = 15 after reshape
    U = jax.random.normal(jax.random.PRNGKey(1), (24, 6)) / 5.0
    s = jnp.linspace(1.5, 0.5, 6)
    V = jax.random.normal(jax.random.PRNGKey(2), (31, 6)) / 6.0
    cot = jax.random.normal(jax.random.PRNGKey(3), (3, 5, 31))

    f_kernel = lambda x, U, s, V: jnp.sum(spectral_matmul(x, U, s, V) * cot)
    f_ref = lambda x, U, s, V: jnp.sum(
        spectral_apply({"U": U, "s": s, "V": V}, x) * cot)
    g_kernel = jax.grad(f_kernel, argnums=(0, 1, 2, 3))(x, U, s, V)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2, 3))(x, U, s, V)
    _assert_grads_close(g_kernel, g_ref)


def test_vjp_bf16_inputs_fp32_grad_accumulation(key):
    """bf16 operands: the backward accumulates in fp32 (the mixed
    policy's accum contract) — grads match the fp32 reference to bf16
    input tolerance."""
    M, m, n, k = 17, 40, 24, 5
    x, U, s, V = _operands(key, M, m, n, k)
    xb, Ub, sb, Vb = (a.astype(jnp.bfloat16) for a in (x, U, s, V))
    f = lambda *a: jnp.sum(spectral_matmul(*a) ** 2)
    g_b = jax.grad(f, argnums=(0, 1, 2, 3))(xb, Ub, sb, Vb)
    # reference in fp32 over the bf16-rounded values
    fr = lambda *a: jnp.sum(spectral_apply({"U": a[1], "s": a[2], "V": a[3]}, a[0]) ** 2)
    g_f = jax.grad(fr, argnums=(0, 1, 2, 3))(
        *(a.astype(jnp.float32) for a in (xb, Ub, sb, Vb)))
    for a, b in zip(g_b, g_f):
        assert a.dtype == b.dtype or a.dtype == jnp.bfloat16
        scale = max(1.0, float(jnp.max(jnp.abs(b))))
        np.testing.assert_allclose(np.asarray(a, np.float32) / scale,
                                   np.asarray(b, np.float32) / scale,
                                   rtol=3e-2, atol=3e-2)
