"""Int8 spectral serving tests: per-channel round-trip error bounds,
tree-walk structure (factors quantized, embeddings/norms untouched),
on-the-fly dequant equivalence through apply_linear and the fused
kernel wrapper, and end-to-end greedy equality of the int8 engine
against the fp32 static oracle over dequantized weights."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config
from repro.core import spectral_init
from repro.models.model import init_model
from repro.nn.linear import apply_linear
from repro.serving import (
    PagedCacheConfig,
    Request,
    dequantize_int8,
    dequantize_tree,
    is_quantized,
    is_quantized_spectral,
    param_bytes,
    quantize_int8,
    quantize_tree,
)


def test_int8_roundtrip_error_gaussian(key):
    w = jax.random.normal(key, (256, 32)) / 16.0
    qt = quantize_int8(w)
    assert qt["q8"].dtype == jnp.int8 and qt["scale"].shape == (32,)
    rec = dequantize_int8(qt)
    rel = float(jnp.linalg.norm(rec - w) / jnp.linalg.norm(w))
    assert rel < 0.01                      # ~0.4% for per-channel gaussian


def test_int8_on_orthonormal_factors(key):
    """Spectral U/V are the friendly case: unit columns, entries
    O(1/sqrt(m)) — per-column int8 keeps sub-percent error."""
    p = spectral_init(key, 192, 96, 24)
    for f in ("U", "V"):
        qt = quantize_int8(p[f])
        rec = dequantize_int8(qt)
        rel = float(jnp.linalg.norm(rec - p[f]) / jnp.linalg.norm(p[f]))
        assert rel < 0.008, f


def test_int8_stacked_layer_axis(key):
    """Per-channel scales broadcast over stacked (layer, m, k) factors —
    the layout lax.scan models store."""
    w = jax.random.normal(key, (4, 64, 8)) * jnp.arange(1, 5)[:, None, None]
    qt = quantize_int8(w)
    assert qt["scale"].shape == (4, 8)     # per (layer, channel)
    rec = dequantize_int8(qt)
    rel = float(jnp.linalg.norm(rec - w) / jnp.linalg.norm(w))
    assert rel < 0.01


def test_quantize_tree_structure():
    cfg = get_config("smollm2-135m", reduced=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    q = quantize_tree(params)
    # embeddings pass through untouched (argmax-critical, SKIP_KEYS)
    assert q["embed"]["w"] is params["embed"]["w"]
    # spectral MLP factors are quantized, s stays fp32
    mlp_up = q["layers"]["mlp"]["up"]
    assert is_quantized_spectral(mlp_up)
    assert is_quantized(mlp_up["U"]) and is_quantized(mlp_up["V"])
    assert mlp_up["s"].dtype == jnp.float32
    # dense attention projections are quantized per output channel
    assert is_quantized(q["layers"]["attn"]["wq"]["w"])
    # norm vectors untouched
    assert q["layers"]["attn_norm"]["scale"].dtype == jnp.float32
    # weight memory strictly shrinks; dequant restores full structure
    assert param_bytes(q) < param_bytes(params)
    deq = dequantize_tree(q)
    assert jax.tree.structure(deq) == jax.tree.structure(params)


def test_apply_linear_quantized_matches_materialized_dequant(key):
    """The on-the-fly dequant path must equal applying the materialized
    dequantized factors — same effective weights, bit-for-bit."""
    p = spectral_init(key, 48, 36, 8)
    qp = quantize_tree({"lin": p})["lin"]
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 48), jnp.bfloat16)
    y_q = apply_linear(qp, x)
    y_ref = apply_linear(dequantize_tree(qp), x)
    np.testing.assert_array_equal(np.asarray(y_q, np.float32),
                                  np.asarray(y_ref, np.float32))
    # dense weights take the same path
    w = {"w": jax.random.normal(key, (48, 20)) / 7.0}
    qw = quantize_tree({"lin": w})["lin"]
    np.testing.assert_array_equal(
        np.asarray(apply_linear(qw, x), np.float32),
        np.asarray(apply_linear(dequantize_tree(qw), x), np.float32))


def test_spectral_matmul_q8_matches_ref(key):
    """Fused-kernel wrapper (dequant-on-the-fly into the Pallas path,
    interpret mode on CPU) against the dequantized jnp reference."""
    from repro.kernels.ops import spectral_matmul_q8
    from repro.kernels.ref import spectral_matmul_ref

    M, m, n, k = 33, 40, 56, 6             # non-tile-multiple on purpose
    p = spectral_init(key, m, n, k)
    q = quantize_tree({"lin": p})["lin"]
    x = jax.random.normal(jax.random.PRNGKey(2), (M, m))
    y = spectral_matmul_q8(x, q["U"], q["s"], q["V"])
    yr = spectral_matmul_ref(x, dequantize_int8(q["U"]), q["s"],
                             dequantize_int8(q["V"]))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=5e-5, atol=5e-5)


def test_engine_int8_greedy_matches_fp32_static_oracle():
    """The acceptance path behind ``serve.py --quantize int8 --verify``:
    int8 paged continuous batching produces greedy outputs equal, token
    for token, to the fp32 static path over the dequantized weights —
    and reports the weight-memory reduction."""
    from repro.launch.serve import static_greedy_reference
    from repro.serving.engine import ServingEngine

    cfg = get_config("smollm2-135m", reduced=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    pcfg = PagedCacheConfig(page_size=8, num_pages=16, max_slots=2,
                            max_pages_per_seq=4)
    engine = ServingEngine(cfg, params, pcfg, quantize="int8")
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=(n,)).astype(np.int32),
                    max_new_tokens=5, arrival=i // 2)
            for i, n in enumerate([6, 9, 4])]
    out = engine.run(reqs)
    engine.sched.check_invariants()

    oracle = dequantize_tree(engine.params)
    for r in reqs:
        ref = static_greedy_reference(cfg, oracle, r.prompt, r.max_new_tokens,
                                      pcfg.max_seq)
        np.testing.assert_array_equal(ref, out[r.rid])

    st = engine.stats()
    assert st["weight_bytes"] < st["weight_bytes_fp"]


def test_quantize_skips_raw_consumed_subtrees_moe_mla():
    """MoE routers/expert banks and the MLA wukv up-projection are
    consumed by raw einsums (not apply_linear) — quantize_tree must
    leave them untouched, and int8 serving of a MoE+MLA model must
    still match the fp32 oracle."""
    from repro.launch.serve import static_greedy_reference
    from repro.serving.engine import ServingEngine

    cfg = get_config("deepseek-v3-671b", reduced=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    q = quantize_tree(params)
    moe = q["moe_layers"]["moe"]
    assert moe["router"]["w"].dtype == jnp.float32      # untouched
    for part in ("gate", "up", "down"):
        assert not is_quantized(moe[part].get("w", None) or {})
    assert q["moe_layers"]["attn"]["wukv"]["w"].dtype == jnp.float32
    # other MLA projections (apply_linear-consumed) are quantized
    assert is_quantized(q["moe_layers"]["attn"]["wdkv"]["w"])

    pcfg = PagedCacheConfig(page_size=8, num_pages=12, max_slots=2,
                            max_pages_per_seq=3)
    engine = ServingEngine(cfg, params, pcfg, quantize="int8")
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=(n,)).astype(np.int32),
                    max_new_tokens=4, arrival=0)
            for i, n in enumerate([5, 7])]
    out = engine.run(reqs)
    oracle = dequantize_tree(engine.params)
    for r in reqs:
        ref = static_greedy_reference(cfg, oracle, r.prompt, r.max_new_tokens,
                                      pcfg.max_seq)
        np.testing.assert_array_equal(ref, out[r.rid])


def test_quantize_skips_encdec_positional_tables():
    """Whisper's positional tables are sliced raw
    (``params["dec_pos"]["w"][:s]``) — quantize_tree must leave them as
    arrays, and the quantized encdec forward must still run."""
    cfg = get_config("whisper-medium", reduced=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    q = quantize_tree(params)
    assert hasattr(q["enc_pos"]["w"], "ndim") and not is_quantized(q["enc_pos"]["w"])
    assert hasattr(q["dec_pos"]["w"], "ndim") and not is_quantized(q["dec_pos"]["w"])
    # encoder/decoder projections DO quantize, and the forward runs
    from repro.models.model import train_loss
    from repro.data.vision_stub import audio_frame_stub

    batch = {
        "tokens": jnp.zeros((2, 8), jnp.int32),
        "labels": jnp.zeros((2, 8), jnp.int32),
        "encoder_frames": jnp.asarray(audio_frame_stub(2, cfg.encoder_seq, cfg.d_model)),
    }
    loss, _ = train_loss(q, batch, cfg)
    assert np.isfinite(float(loss))
