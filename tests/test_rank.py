"""Adaptive rank subsystem tests: resize ops (grow/shrink + moments),
telemetry, schedules, and the train -> shrink-checkpoint -> resume
integration the ISSUE's acceptance criteria name."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.core.convert import spectral_to_dense
from repro.core.manifold import orthogonality_error
from repro.core.spectral import spectral_init
from repro.checkpoint.manager import CheckpointManager
from repro.data.synthetic import SyntheticLMDataset
from repro.launch.steps import make_train_step
from repro.models.model import init_model
from repro.optim import make_sct_optimizer
from repro.rank import (
    EnergyRankSchedule,
    RankController,
    StaticRankSchedule,
    StepRankSchedule,
    current_ranks,
    grow_group,
    parse_rank_schedule,
    rank_metadata,
    resize_group,
    resize_train_state,
    resize_tree,
    shrink_group,
    spectral_telemetry,
    telemetry_summary,
)
from repro.rank.resize import clamp_target, shrink_indices
from repro.rank.telemetry import effective_rank, energy_capture, tail_mass
from repro.runtime.train_loop import TrainLoop, TrainLoopConfig

M, N, K = 48, 40, 16


@pytest.fixture
def group(key):
    return spectral_init(key, M, N, K)


@pytest.fixture
def stacked_group(key):
    return jax.vmap(lambda k: spectral_init(k, M, N, K))(jax.random.split(key, 3))


# ===================================================================
# resize ops
# ===================================================================

def test_grow_preserves_represented_matrix(key, group):
    grown = grow_group(key, group, 24)
    assert grown["U"].shape == (M, 24) and grown["s"].shape == (24,)
    np.testing.assert_allclose(
        np.asarray(spectral_to_dense(group)),
        np.asarray(spectral_to_dense(grown)), atol=5e-6)
    # zero singular values on the fresh directions
    assert float(jnp.max(jnp.abs(grown["s"][K:]))) == 0.0


def test_grow_factors_orthonormal_after_retraction(key, stacked_group):
    grown = grow_group(key, stacked_group, 32)
    assert float(orthogonality_error(grown["U"])) < 5e-6
    assert float(orthogonality_error(grown["V"])) < 5e-6


def test_shrink_keeps_topk_and_is_eckart_young(key, group):
    # make the spectrum distinctive so top-k is unambiguous
    g = dict(group, s=jnp.arange(K, 0, -1, dtype=jnp.float32))
    shrunk, idx = shrink_group(g, 6)
    np.testing.assert_array_equal(np.asarray(idx), np.arange(6))
    # Eckart-Young: the shrink error equals the dropped tail mass
    err = jnp.linalg.norm(spectral_to_dense(g) - spectral_to_dense(shrunk))
    tail = jnp.linalg.norm(g["s"][6:])
    np.testing.assert_allclose(float(err), float(tail), rtol=1e-4)
    assert float(orthogonality_error(shrunk["U"])) < 5e-6


def test_shrink_selects_by_magnitude_not_position(key, group):
    s = jnp.asarray([0.1, 9.0, 0.2, 8.0] + [0.01] * (K - 4))
    g = dict(group, s=s)
    shrunk, idx = shrink_group(g, 2)
    np.testing.assert_array_equal(np.asarray(idx), [1, 3])
    np.testing.assert_allclose(np.asarray(shrunk["s"]), [9.0, 8.0])


def test_grow_shrink_roundtrip_preserves_topk_subspace(key, group):
    """grow -> shrink back returns the original factors exactly: the
    grown columns carry zero singular values, so the shrink's top-k
    selection recovers precisely the pre-grow columns."""
    grown = grow_group(key, group, 24)
    back, _ = shrink_group(grown, K)
    # s of the original init is strictly positive, so selection is exact
    np.testing.assert_allclose(np.asarray(back["s"]), np.asarray(group["s"]),
                               atol=1e-6)
    # same subspace: projector difference is ~0 (columns may be
    # perturbed only by the grow-time re-retraction, which is ~eps)
    P0 = group["U"] @ group["U"].T
    P1 = back["U"] @ back["U"].T
    assert float(jnp.max(jnp.abs(P0 - P1))) < 5e-6


def test_stacked_layers_select_per_layer(key, stacked_group):
    s = np.ones((3, K), np.float32) * 0.01
    s[0, 2] = s[1, 7] = s[2, 11] = 5.0
    g = dict(stacked_group, s=jnp.asarray(s))
    idx = shrink_indices(g["s"], 1)
    np.testing.assert_array_equal(np.asarray(idx)[:, 0], [2, 7, 11])


def test_resize_train_state_moments_follow_params(key):
    cfg = get_config("smollm2-1.7b", reduced=True)
    opt = make_sct_optimizer(cfg, total_steps=10)
    state = opt.init(init_model(key, cfg))
    # put recognizable values in the moments so gather order is testable
    state["opt"]["mu"] = jax.tree.map(
        lambda x: jnp.arange(x.size, dtype=x.dtype).reshape(x.shape),
        state["opt"]["mu"])
    down = state["params"]["layers"]["mlp"]["down"]
    k0 = down["s"].shape[-1]

    shrunk = resize_train_state(key, state, k0 // 2)
    for tree in (shrunk["params"], shrunk["opt"]["mu"], shrunk["opt"]["nu"]):
        g = tree["layers"]["mlp"]["down"]
        assert g["s"].shape[-1] == k0 // 2
    # the moment columns were gathered with the same indices as params
    idx = shrink_indices(down["s"], k0 // 2)
    expect = jnp.take_along_axis(
        state["opt"]["mu"]["layers"]["mlp"]["down"]["U"], idx[..., None, :], axis=-1)
    np.testing.assert_array_equal(
        np.asarray(shrunk["opt"]["mu"]["layers"]["mlp"]["down"]["U"]),
        np.asarray(expect))

    grown = resize_train_state(key, state, k0 * 2)
    g = grown["opt"]["nu"]["layers"]["mlp"]["down"]
    assert g["U"].shape[-1] == k0 * 2
    # fresh directions start with zeroed optimizer state
    assert float(jnp.max(jnp.abs(g["U"][..., k0:]))) == 0.0
    # non-spectral entries untouched
    assert grown["step"].shape == state["step"].shape
    np.testing.assert_array_equal(
        np.asarray(grown["params"]["embed"]["w"]),
        np.asarray(state["params"]["embed"]["w"]))


def test_clamp_target_respects_min_dim(key):
    cfg = get_config("smollm2-1.7b", reduced=True)  # d_model=64, d_ff=256
    params = init_model(key, cfg)
    t = clamp_target(params, 1000)
    assert set(t.values()) == {64}  # min(m, n) = d_model
    resized = resize_tree(key, params, t)
    assert current_ranks(resized) == (64,)


def test_resize_rejects_bad_targets(key, group):
    with pytest.raises(ValueError):
        shrink_group(group, 0)
    with pytest.raises(ValueError):
        grow_group(key, group, min(M, N) + 1)


# ===================================================================
# telemetry
# ===================================================================

def test_effective_rank_bounds():
    flat = jnp.ones((8,))
    peaked = jnp.asarray([100.0] + [1e-6] * 7)
    assert float(effective_rank(flat)) == pytest.approx(8.0, rel=1e-5)
    assert float(effective_rank(peaked)) == pytest.approx(1.0, abs=1e-3)


def test_energy_capture_and_tail_mass():
    s = jnp.asarray([2.0, 1.0, 0.0, 0.0])
    assert float(energy_capture(s, 0.5)) == pytest.approx(1.0)
    np.testing.assert_allclose(float(tail_mass(s, 2)), 0.0, atol=1e-6)
    assert float(tail_mass(jnp.ones((4,)), 2)) == pytest.approx(np.sqrt(0.5), rel=1e-5)


def test_telemetry_tree_and_summary(key):
    cfg = get_config("smollm2-1.7b", reduced=True)
    params = init_model(key, cfg)
    per = spectral_telemetry(params)
    assert set(per) == {"layers/mlp/down", "layers/mlp/gate", "layers/mlp/up"}
    summary = telemetry_summary(params)
    assert float(summary["rank/mean"]) == cfg.sct.rank
    assert 1.0 <= float(summary["rank/eff_mean"]) <= cfg.sct.rank
    assert 0.0 <= float(summary["rank/energy_top"]) <= 1.0
    assert float(summary["rank/ortho_max"]) < 5e-6
    # dense model: no spectral groups -> empty summary, not zeros
    dense = init_model(key, cfg.replace_sct(spectral_mlp=False))
    assert telemetry_summary(dense) == {}


def test_telemetry_is_jittable(key):
    cfg = get_config("smollm2-1.7b", reduced=True)
    params = init_model(key, cfg)
    out = jax.jit(telemetry_summary)(params)
    assert float(out["rank/mean"]) == cfg.sct.rank


# ===================================================================
# schedules
# ===================================================================

def test_parse_and_decide_step_schedule():
    sch = parse_rank_schedule("step:30=64,60=128")
    assert isinstance(sch, StepRankSchedule)
    assert sch.decide(29, 32) is None
    assert sch.decide(30, 32) == 64
    assert sch.decide(45, 64) is None          # idempotent between triggers
    assert sch.decide(60, 64) == 128
    # restart at step 70 from a rank-32 checkpoint replays to 128
    assert sch.decide(70, 32) == 128


def test_parse_static_and_none():
    assert parse_rank_schedule(None) is None
    assert parse_rank_schedule("none") is None
    sch = parse_rank_schedule("static:64")
    assert isinstance(sch, StaticRankSchedule)
    assert sch.decide(0, 32) == 64
    assert sch.decide(0, 64) is None


def test_energy_schedule_decisions():
    sch = parse_rank_schedule("energy:0.9,min=8,every=10,factor=0.5,grow_below=0.3")
    assert isinstance(sch, EnergyRankSchedule)
    m_hi, m_lo = {"rank/energy_top": 0.95}, {"rank/energy_top": 0.2}
    assert sch.decide(10, 32, m_hi) == 16          # over-ranked -> shrink
    assert sch.decide(10, 16, m_lo) == 32          # saturated -> grow
    assert sch.decide(11, 32, m_hi) is None        # off-cadence
    assert sch.decide(10, 32, None) is None        # no telemetry yet
    assert sch.decide(10, 8, m_hi) is None         # floor reached
    with pytest.raises(ValueError):
        parse_rank_schedule("energy:0.9,bogus=1")
    with pytest.raises(ValueError):
        parse_rank_schedule("warp:9")


# ===================================================================
# integration: train -> resize mid-run / shrink-checkpoint -> resume
# ===================================================================

def _loop(tmp_path, cfg, opt, total, controller=None, telemetry=True):
    step_fn = jax.jit(make_train_step(cfg, opt, telemetry=telemetry))
    ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=16, seed=0)

    def batches(start):
        step = start
        while True:
            t, l = ds.batch(step, 4)
            yield {"tokens": jnp.asarray(t), "labels": jnp.asarray(l)}
            step += 1

    losses = []
    return TrainLoop(
        step_fn=step_fn,
        batch_iter_factory=batches,
        ckpt_dir=str(tmp_path),
        cfg=TrainLoopConfig(total_steps=total, checkpoint_every=5, log_every=1),
        init_state_fn=lambda: opt.init(init_model(jax.random.PRNGKey(0), cfg)),
        metrics_cb=lambda s, m: losses.append((s, m)),
        rank_controller=controller,
    ), losses


def test_midrun_resize_trains_through(tmp_path):
    """Step-triggered grow mid-run: loss stays finite, no >2x spike at
    the boundary, factors stay orthonormal, moments stay congruent."""
    cfg = get_config("smollm2-1.7b", reduced=True)
    opt = make_sct_optimizer(cfg, lr=1e-3, warmup=2, total_steps=16)
    ctrl = RankController(cfg, opt, StepRankSchedule(((8, 32),)))
    loop, losses = _loop(tmp_path, cfg, opt, 16, controller=ctrl)
    state = loop.run()

    assert loop.rank_resizes == 1
    assert ctrl.resizes == [(8, 16, 32)]
    assert current_ranks(state["params"]) == (32,)
    assert jax.tree.all(jax.tree.map(lambda p, m: p.shape == m.shape,
                                     state["params"], state["opt"]["mu"]))
    by_step = {s: m for s, m in losses}
    before, after = by_step[8]["loss"], by_step[9]["loss"]
    assert np.isfinite(after) and after < 2.0 * before
    # telemetry crossed the resize: rank metric tracks the new shapes
    assert by_step[8]["rank/mean"] == 16.0 and by_step[9]["rank/mean"] == 32.0
    from repro.core.tree import max_orthogonality_error

    assert float(max_orthogonality_error(state["params"])) < 5e-6


def test_train_shrink_checkpoint_resume_at_new_rank(tmp_path):
    """Train at rank 16 -> checkpoint -> resume the SAME run at rank 8
    via resize-on-restore (StaticRankSchedule), then finish training."""
    cfg = get_config("smollm2-1.7b", reduced=True)
    opt = make_sct_optimizer(cfg, lr=1e-3, warmup=2, total_steps=10)
    loop, _ = _loop(tmp_path, cfg, opt, 10)
    loop.run()
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.rank_metadata_for(mgr.list_steps()[-1]) == {
        "layers/mlp/down": 16, "layers/mlp/gate": 16, "layers/mlp/up": 16}

    opt2 = make_sct_optimizer(cfg, lr=1e-3, warmup=2, total_steps=20)
    ctrl = RankController(cfg, opt2, StaticRankSchedule(8))
    loop2, losses2 = _loop(tmp_path, cfg, opt2, 20, controller=ctrl)
    state = loop2.run()
    assert current_ranks(state["params"]) == (8,)
    assert int(np.asarray(state["step"])) == 20
    assert all(np.isfinite(m["loss"]) for _, m in losses2)


def test_cross_rank_restore_and_greedy_decode(tmp_path):
    """Rank-16 training checkpoint restores at rank 8 through the
    manager and the engine classmethod; greedy decode stays functional
    and the shrunk engine pins fewer weight bytes."""
    from repro.serving import PagedCacheConfig, Request
    from repro.serving.engine import ServingEngine

    cfg = get_config("smollm2-1.7b", reduced=True)
    opt = make_sct_optimizer(cfg, lr=1e-3, warmup=2, total_steps=6)
    loop, _ = _loop(tmp_path, cfg, opt, 6, telemetry=False)
    loop.run()

    step, state = CheckpointManager(str(tmp_path)).restore_latest(target_rank=8)
    assert current_ranks(state["params"]) == (8,)
    # deterministic resize: same (checkpoint, rank) -> same factors
    _, state2 = CheckpointManager(str(tmp_path)).restore_latest(target_rank=8)
    np.testing.assert_array_equal(
        np.asarray(state["params"]["layers"]["mlp"]["up"]["U"]),
        np.asarray(state2["params"]["layers"]["mlp"]["up"]["U"]))

    pcfg = PagedCacheConfig(page_size=8, num_pages=16, max_slots=2,
                            max_pages_per_seq=4)
    eng = ServingEngine.from_checkpoint(cfg, str(tmp_path), pcfg, rank=8)
    full = ServingEngine.from_checkpoint(cfg, str(tmp_path), pcfg)
    assert eng.weight_bytes < full.weight_bytes
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=(5,)).astype(np.int32),
                    max_new_tokens=6, arrival=0) for i in range(2)]
    out = eng.run(reqs)
    assert sorted(out) == [0, 1]
    for toks in out.values():
        assert toks.shape == (6,) and toks.dtype == np.int32
        assert np.all((0 <= toks) & (toks < cfg.vocab))


_SUBPROCESS_MESH_RESIZE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, json
from jax.sharding import NamedSharding
from repro.config import get_config
from repro.config.shapes import ShapeSpec
from repro.data.synthetic import SyntheticLMDataset
from repro.launch import steps as steps_mod
from repro.models.model import init_model
from repro.optim import make_sct_optimizer
from repro.rank import RankController, StepRankSchedule, current_ranks
from repro.sharding.rules import set_current_mesh

cfg = get_config("smollm2-1.7b", reduced=True)
mesh = jax.make_mesh((4, 2), ("data", "model"))
set_current_mesh(mesh)
shape = ShapeSpec("t", 16, 8, "train")
opt = make_sct_optimizer(cfg, lr=1e-3, warmup=2, total_steps=8)
ctrl = RankController(cfg, opt, StepRankSchedule(((4, 32),)), mesh=mesh, shape=shape)
state_sh, batch_sh = steps_mod.train_shardings(cfg, shape, mesh)
step_fn = jax.jit(steps_mod.make_train_step(cfg, opt, telemetry=True),
                  in_shardings=(state_sh, batch_sh), out_shardings=(state_sh, None),
                  donate_argnums=(0,))
with mesh:
    state = opt.init(init_model(jax.random.PRNGKey(0), cfg))
    state = jax.device_put(state, state_sh)
    ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=16, seed=0)
    losses = []
    for i in range(8):
        t, l = ds.batch(i, 8)
        state, m = step_fn(state, {"tokens": jnp.asarray(t), "labels": jnp.asarray(l)})
        losses.append(float(m["loss"]))
        res = ctrl.maybe_resize(i + 1, state, m)
        if res is not None:
            state, step_fn, state_sh = res
            assert isinstance(jax.tree.leaves(state_sh)[0], NamedSharding)
print(json.dumps({
    "resizes": ctrl.resizes,
    "ranks": list(current_ranks(state["params"])),
    "finite": all(x == x for x in losses),
}))
"""


def test_mesh_resize_regenerates_shardings():
    """Full mesh path in a subprocess (8 host devices): resize mid-run
    on a (4,2) mesh regenerates the NamedSharding tree and the re-jitted
    step keeps training at the new rank."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS_MESH_RESIZE],
                         capture_output=True, text=True, timeout=600, env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    assert payload["resizes"] == [[4, 16, 32]]
    assert payload["ranks"] == [32]
    assert payload["finite"]


def test_same_rank_resize_is_bit_exact_noop(key):
    """Regression: a resize to the current rank (degenerate speculative
    ladder like [128,128], a schedule re-stating the rank) must neither
    gather nor re-retract — params come back as the same buffers."""
    g = {"U": jax.random.normal(key, (6, K)),
         "s": jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (K,))),
         "V": jax.random.normal(jax.random.fold_in(key, 2), (5, K))}
    same = resize_group(key, g, K)
    assert same is not g                         # fresh dict, shared leaves
    for name in ("U", "s", "V"):
        assert same[name] is g[name]

    cfg = get_config("smollm2-1.7b", reduced=True)
    opt = make_sct_optimizer(cfg, total_steps=10)
    state = opt.init(init_model(key, cfg))
    state["opt"]["mu"] = jax.tree.map(
        lambda x: jnp.arange(x.size, dtype=x.dtype).reshape(x.shape),
        state["opt"]["mu"])
    (k0,) = set(current_ranks(state["params"]))
    same_state = resize_train_state(key, state, k0)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(same_state)):
        assert a is b                            # moments included, bit-exact
