"""Distributed serving: tensor-parallel paged decode + disaggregated
prefill/decode workers (serving/distributed.py, sharding/partition.py's
decode-path placement).

Host-level tests cover the seams directly: spec validation, the
KVTransfer page shipment (raw = bit-exact, int8 = bounded error +
smaller wire), placement specs, and colocated-vs-disaggregated token
identity on one device. The multi-device legs run in subprocesses with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (set before jax
imports, same pattern as test_sharding.py) and pin the acceptance
criterion: TP paged decode, disaggregated prefill, and their
composition each emit token-for-token the single-process static greedy
oracle's output, for a GQA and an MLA family.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.config import get_config
from repro.models.model import init_model, init_paged_state
from repro.serving import PagedCacheConfig, Request
from repro.serving.distributed import DisaggregatedEngine, KVTransfer, PrefillWorker
from repro.serving.engine import ServingEngine
from repro.sharding.partition import paged_state_pspecs, serve_tp_valid


# ---------------------------------------------------------------- specs --

def test_serve_spec_disaggregate_validation():
    from repro.api import ServeSpec, ShardingSpec

    ServeSpec(disaggregate=True)                     # valid baseline
    ServeSpec(disaggregate=True, kv_transfer="int8")
    with pytest.raises(ValueError, match="kv_transfer"):
        ServeSpec(kv_transfer="fp4")
    with pytest.raises(ValueError, match="prefix_cache"):
        ServeSpec(disaggregate=True, prefix_cache=True)
    with pytest.raises(ValueError, match="speculative"):
        ServeSpec(disaggregate=True, speculative_rank="8")
    with pytest.raises(ValueError, match="paged"):
        ServeSpec(disaggregate=True, mode="static")
    with pytest.raises(ValueError, match="decode_mesh"):
        ShardingSpec(decode_mesh=0)
    assert ShardingSpec().serve_mesh() is None
    assert ShardingSpec(decode_mesh=1).serve_mesh() is None


def test_bench_spec_serving_modes_axis():
    from repro.api import BenchSpec

    spec = BenchSpec(serving_modes="colocated,disaggregated")
    assert spec.serving_mode_arms() == ["colocated", "disaggregated"]
    with pytest.raises(ValueError, match="serving mode"):
        BenchSpec(serving_modes="remote")


def test_serve_cli_flags_reach_spec():
    from repro.launch.serve import build_parser, build_spec

    args = build_parser().parse_args(
        ["--paged", "--stream", "--disaggregate", "--kv-transfer", "int8",
         "--tp", "2"])
    spec = build_spec(args)
    assert spec.serve.disaggregate and spec.serve.kv_transfer == "int8"
    assert spec.sharding.decode_mesh == 2
    # round-trips: the embedded-spec path serves the same configuration
    from repro.api import RunSpec
    assert RunSpec.from_json(spec.to_json()) == spec


# ------------------------------------------------------------ placement --

def test_serve_tp_divisibility():
    gqa = get_config("llama3.2-1b", reduced=True)      # n_kv_heads=2
    mla = get_config("deepseek-v3-671b", reduced=True)  # n_heads=4
    assert serve_tp_valid(gqa, 2) and not serve_tp_valid(gqa, 4)
    assert serve_tp_valid(mla, 2) and serve_tp_valid(mla, 4)
    assert not serve_tp_valid(mla, 3)


def test_paged_state_pspecs_placement():
    pcfg = PagedCacheConfig(page_size=4, num_pages=8, max_slots=2,
                            max_pages_per_seq=4)
    gqa = get_config("llama3.2-1b", reduced=True)
    state = jax.eval_shape(lambda: init_paged_state(gqa, pcfg))
    specs = paged_state_pspecs(gqa, state, 2)
    flat_specs = {}

    def walk(tree, path=""):
        if isinstance(tree, dict):
            for k, v in tree.items():
                walk(v, f"{path}/{k}" if path else k)
        else:
            flat_specs[path] = tree
    walk(specs)
    kv = {p: s for p, s in flat_specs.items()
          if p.split("/")[-1] in ("k", "v")}
    assert kv, "no GQA KV pool leaves found"
    assert all(s == P(None, None, None, "model", None) for s in kv.values())
    mla = get_config("deepseek-v3-671b", reduced=True)
    state_m = jax.eval_shape(lambda: init_paged_state(mla, pcfg))
    flat_specs.clear()
    walk(paged_state_pspecs(mla, state_m, 2))
    # MLA latent pools have no head axis -> everything replicates
    assert all(s == P() for s in flat_specs.values())


def test_tp_engine_rejects_bad_geometry():
    from repro.sharding.partition import serve_mesh

    cfg = get_config("llama3.2-1b", reduced=True).replace(dtype="float32")
    with pytest.raises(ValueError, match="devices"):
        serve_mesh(4096)
    # tp=1 mesh path must behave exactly like no mesh
    params = init_model(jax.random.PRNGKey(0), cfg)
    pcfg = PagedCacheConfig(page_size=4, num_pages=16, max_slots=2,
                            max_pages_per_seq=4)
    eng = ServingEngine(cfg, params, pcfg, mesh=serve_mesh(1))
    assert eng.tp == 1


# ----------------------------------------------------------- kv transfer --

def _toy_pools(key, n_pages=6, page=4, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    mk = lambda k: {"k": jax.random.normal(k, (2, n_pages + 1, page, 2, 3),
                                           dtype=dtype),
                    "v": jax.random.normal(jax.random.fold_in(k, 1),
                                           (2, n_pages + 1, page, 2, 3),
                                           dtype=dtype)}
    return mk(k1), mk(k2)


def test_kv_transfer_raw_is_bit_exact(key):
    src, dst = _toy_pools(key)
    t = KVTransfer("raw")
    src_ids = jnp.asarray([1, 3], dtype=jnp.int32)
    dst_ids = jnp.asarray([4, 0], dtype=jnp.int32)
    # dst is donated into the ship; snapshot what must survive first
    untouched = {n: np.asarray(dst[n][:, 2]) for n in ("k", "v")}
    out = t.ship(dst, dst_ids, src, src_ids)
    for leaf_name in ("k", "v"):
        np.testing.assert_array_equal(
            np.asarray(out[leaf_name][:, [4, 0]]),
            np.asarray(src[leaf_name][:, [1, 3]]))
        # untouched pages keep their old contents
        np.testing.assert_array_equal(np.asarray(out[leaf_name][:, 2]),
                                      untouched[leaf_name])
    # ledger: 2 pages, raw == wire for the lossless mode
    per_page = 2 * 4 * 2 * 3 * 4        # L * page * channels * itemsize
    assert t.pages_shipped == 2
    assert t.bytes_raw == t.bytes_wire == 2 * 2 * per_page  # k and v


def test_kv_transfer_int8_bounded_and_smaller(key):
    src, dst = _toy_pools(key)
    t = KVTransfer("int8")
    src_ids = jnp.asarray([0, 2, 5], dtype=jnp.int32)
    dst_ids = jnp.asarray([1, 3, 5], dtype=jnp.int32)
    out = t.ship(dst, dst_ids, src, src_ids)
    got = np.asarray(out["k"][:, [1, 3, 5]], np.float32)
    want = np.asarray(src["k"][:, [0, 2, 5]], np.float32)
    # symmetric per-channel int8: error bounded by scale/2 = amax/254
    amax = np.max(np.abs(want), axis=2, keepdims=True)
    assert np.all(np.abs(got - want) <= amax / 254.0 + 1e-7)
    assert t.bytes_wire < t.bytes_raw
    with pytest.raises(ValueError, match="kv transfer"):
        KVTransfer("fp4")


def test_prefill_worker_releases_pages(key):
    cfg = get_config("llama3.2-1b", reduced=True).replace(dtype="float32")
    params = init_model(key, cfg)
    pcfg = PagedCacheConfig(page_size=4, num_pages=16, max_slots=2,
                            max_pages_per_seq=4)
    worker = PrefillWorker(cfg, params, pcfg)

    class _Seq:   # the worker only reads request + prefill_pos
        def __init__(self, rid, prompt):
            self.request = Request(rid=rid, prompt=prompt, max_new_tokens=1)
            self.prefill_pos = 0

    prompt = np.arange(6, dtype=np.int32) % cfg.vocab
    seq = _Seq(0, prompt)
    worker.begin(seq)
    worker.begin(seq)                       # idempotent
    assert worker.pool.allocated_count == pcfg.pages_for(6) == 2
    logits = worker.run_chunk(seq, 6)
    assert seq.prefill_pos == 6 and logits.shape[1] == 6
    pages = worker.finish(0)
    worker.release(pages)
    assert worker.pool.allocated_count == 0
    # abort of an unknown rid is a no-op; of a live one frees its pages
    worker.abort(0)
    seq2 = _Seq(1, prompt)
    worker.begin(seq2)
    worker.abort(1)
    assert worker.pool.allocated_count == 0


# ----------------------------------------- disaggregated token identity --

def _identity_trace(cfg, pcfg, n=3):
    rng = np.random.default_rng(0)
    lens_gens = [(7, 4, 0), (11, 3, 1), (5, 5, 2)][:n]
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, size=(t,)).astype(np.int32),
                    max_new_tokens=g, arrival=a)
            for i, (t, g, a) in enumerate(lens_gens)]


@pytest.mark.parametrize("arch", ["llama3.2-1b", "deepseek-v3-671b"])
def test_disaggregated_matches_colocated_and_oracle(arch, key):
    from repro.launch.serve import static_greedy_reference

    cfg = get_config(arch, reduced=True).replace(dtype="float32",
                                                 capacity_factor=8.0)
    params = init_model(key, cfg)
    pcfg = PagedCacheConfig(page_size=4, num_pages=32, max_slots=2,
                            max_pages_per_seq=6)
    reqs = _identity_trace(cfg, pcfg)
    eng = DisaggregatedEngine(cfg, params, pcfg, chunked_prefill=True,
                              prefill_token_budget=6)
    out = eng.run(reqs)
    eng.sched.check_invariants()
    for r in reqs:
        ref = static_greedy_reference(cfg, params, r.prompt, r.max_new_tokens,
                                      pcfg.max_seq)
        np.testing.assert_array_equal(out[r.rid], ref,
                                      err_msg=f"{arch} rid {r.rid}")
    st = eng.stats()
    assert st["kv_transfer_pages"] > 0
    assert st["kv_transfer_bytes"] == st["kv_transfer_wire_bytes"]  # raw
    assert st["prefill_pool_peak_pages"] > 0
    # every worker page went back after its ship
    assert eng.worker.pool.allocated_count == 0


def test_disaggregated_int8_wire_accounting(key):
    """int8 shipment is opt-in and lossy — identity is NOT asserted;
    the ledger must show the 8x-ish wire shrink and the pools must stay
    coherent (invariants + full drain)."""
    cfg = get_config("llama3.2-1b", reduced=True).replace(dtype="float32")
    params = init_model(key, cfg)
    # page_size 8: the fp32 per-page scales amortize to under the pool
    # dtype's width (at page_size 4 on a bf16 pool they exactly cancel)
    pcfg = PagedCacheConfig(page_size=8, num_pages=32, max_slots=2,
                            max_pages_per_seq=6)
    reqs = _identity_trace(cfg, pcfg)
    eng = DisaggregatedEngine(cfg, params, pcfg, kv_transfer="int8")
    out = eng.run(reqs)
    eng.sched.check_invariants()
    assert set(out) == {r.rid for r in reqs}
    st = eng.stats()
    assert 0 < st["kv_transfer_wire_bytes"] < st["kv_transfer_bytes"]
    assert eng.worker.pool.allocated_count == 0


def test_disaggregated_rejects_incompatible_modes(key):
    cfg = get_config("llama3.2-1b", reduced=True).replace(dtype="float32")
    params = init_model(key, cfg)
    pcfg = PagedCacheConfig(page_size=4, num_pages=16, max_slots=2,
                            max_pages_per_seq=4)
    with pytest.raises(ValueError, match="prefix_cache"):
        DisaggregatedEngine(cfg, params, pcfg, prefix_cache=True)
    rec = get_config("jamba-v0.1-52b", reduced=True).replace(dtype="float32")
    with pytest.raises(NotImplementedError, match="recurrent"):
        DisaggregatedEngine(rec, init_model(key, rec), pcfg)
    with pytest.raises(ValueError, match="page_size"):
        DisaggregatedEngine(cfg, params, pcfg,
                            prefill_pcfg=PagedCacheConfig(
                                page_size=8, num_pages=16, max_slots=2,
                                max_pages_per_seq=4))


def test_disaggregated_eviction_reclaims_worker_pages(key):
    """A request evicted mid-prefill (deadline) must hand its worker
    pages back — the abort seam in _drain."""
    cfg = get_config("llama3.2-1b", reduced=True).replace(dtype="float32")
    params = init_model(key, cfg)
    pcfg = PagedCacheConfig(page_size=4, num_pages=32, max_slots=2,
                            max_pages_per_seq=8)
    rng = np.random.default_rng(1)
    # a long prompt chunked at 2 tokens/step with deadline 3 cannot
    # finish prefilling -> evicted mid-prefill
    reqs = [Request(rid=0,
                    prompt=rng.integers(0, cfg.vocab, size=(24,)).astype(np.int32),
                    max_new_tokens=4, arrival=0, deadline=3)]
    eng = DisaggregatedEngine(cfg, params, pcfg, chunked_prefill=True,
                              prefill_token_budget=2)
    eng.run(reqs)
    eng.sched.check_invariants()
    assert eng.last_statuses[0] == "timeout"
    assert eng.worker.pool.allocated_count == 0
    assert eng.transfer.pages_shipped == 0   # never completed -> no ship


def test_server_builds_disaggregated_engine():
    from repro.api import ModelSpec, RunSpec, ServeSpec, Server

    spec = RunSpec(
        model=ModelSpec("llama3.2-1b", reduced=True),
        serve=ServeSpec(disaggregate=True, slots=2, num_pages=16,
                        pages_per_seq=4, page_size=4, gen=4),
    )
    server = Server(spec)
    assert isinstance(server.engine, DisaggregatedEngine)
    assert server.engine.transfer.mode == "raw"
    rid = server.submit(np.arange(5, dtype=np.int32))
    out = server.run()
    assert len(out[rid]) == 4


# ----------------------------------------------- multi-device subprocess --

_SUBPROCESS_IDENTITY = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax
from repro.config import get_config
from repro.models.model import init_model
from repro.launch.serve import static_greedy_reference
from repro.serving import PagedCacheConfig, Request
from repro.serving.engine import ServingEngine
from repro.serving.distributed import DisaggregatedEngine
from repro.sharding.partition import serve_mesh

cfg = get_config("{arch}", reduced=True).replace(dtype="float32",
                                                 capacity_factor=8.0)
params = init_model(jax.random.PRNGKey(0), cfg)
pcfg = PagedCacheConfig(page_size=4, num_pages=32, max_slots=2,
                        max_pages_per_seq=6)
rng = np.random.default_rng(0)
shapes = [(7, 4, 0), (11, 3, 1), (5, 5, 2)]
prompts = [rng.integers(0, cfg.vocab, size=(t,)).astype(np.int32)
           for t, _, _ in shapes]

def trace():
    return [Request(rid=i, prompt=prompts[i], max_new_tokens=g, arrival=a)
            for i, (_, g, a) in enumerate(shapes)]

refs = [static_greedy_reference(cfg, params, prompts[i], g, pcfg.max_seq)
        for i, (_, g, _) in enumerate(shapes)]

results = {{}}
def check(name, engine):
    out = engine.run(trace())
    engine.sched.check_invariants()
    ok = all(np.array_equal(out[i], refs[i]) for i in range(len(shapes)))
    results[name] = bool(ok)
    if not ok:
        results[name + "_detail"] = {{
            str(i): [np.asarray(out[i]).tolist(), np.asarray(refs[i]).tolist()]
            for i in range(len(shapes))
            if not np.array_equal(out[i], refs[i])}}

kw = dict(chunked_prefill=True, prefill_token_budget=6)
check("tp{tp}", ServingEngine(cfg, params, pcfg, mesh=serve_mesh({tp}), **kw))
check("disagg", DisaggregatedEngine(cfg, params, pcfg, **kw))
check("tp{tp}_disagg",
      DisaggregatedEngine(cfg, params, pcfg, mesh=serve_mesh({tp}), **kw))
print(json.dumps(results))
"""


@pytest.mark.parametrize("arch,tp", [
    ("llama3.2-1b", 2),         # GQA: kv-head-sharded pools
    ("deepseek-v3-671b", 2),    # MLA: query-head split, replicated latent
    ("deepseek-v3-671b", 4),    # MLA at full head parallelism
])
def test_multi_device_token_identity(arch, tp):
    """The acceptance criterion: TP paged decode, disaggregated
    prefill, and TP x disaggregation each reproduce the single-process
    static greedy oracle token for token, under 4 forced host devices."""
    code = _SUBPROCESS_IDENTITY.format(arch=arch, tp=tp)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600, env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    assert all(payload[k] for k in payload), payload
