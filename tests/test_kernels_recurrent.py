"""Recurrent-cell Pallas kernels vs their jnp (dry-run) equivalents:
mLSTM chunk kernel and mamba selective-scan kernel — these back the
PALLAS_EQ kernel-substitution claims in the roofline (DESIGN.md S6)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.kernels.mlstm_chunk import mlstm_chunk_pallas
from repro.kernels.mamba_scan import mamba_scan_pallas
from repro.nn import xlstm as xm
from repro.nn import mamba as mamba_mod


@pytest.mark.parametrize("S,dh,chunk", [(128, 32, 64), (256, 64, 128), (64, 16, 64)])
def test_mlstm_kernel_vs_jnp_chunkwise(S, dh, chunk, key):
    """Kernel output == nn/xlstm.py chunkwise form (the partitioned
    fallback) == the recurrent decode cell, for random gates/qkv."""
    B = 3
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, S, dh))
    k = jax.random.normal(ks[1], (B, S, dh)) / np.sqrt(dh)
    v = jax.random.normal(ks[2], (B, S, dh))
    i_pre = jax.random.normal(ks[3], (B, S))
    f_pre = jax.random.normal(ks[4], (B, S)) + 1.0

    y_kernel = mlstm_chunk_pallas(q, k, v, i_pre, f_pre, chunk=chunk, interpret=True)

    # jnp chunkwise reference via the same _mlstm_chunk_body math
    logf = jax.nn.log_sigmoid(f_pre)
    T = chunk
    nc = S // T
    C = jnp.zeros((B, 1, dh, dh)); n = jnp.zeros((B, 1, dh)); m = jnp.full((B, 1), -1e30)
    outs = []
    for c in range(nc):
        sl = slice(c * T, (c + 1) * T)
        out, (C, n, m) = xm._mlstm_chunk_body(
            q[:, sl, None, :], k[:, sl, None, :], v[:, sl, None, :],
            i_pre[:, sl, None], logf[:, sl, None], C, n, m)
        outs.append(out[:, :, 0, :])  # (B, T, dh) after squeeze head
    y_ref = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_ref),
                               atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mlstm_kernel_dtypes(dtype, key):
    B, S, dh = 2, 128, 32
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, S, dh), dtype)
    k = (jax.random.normal(ks[1], (B, S, dh)) / np.sqrt(dh)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, dh), dtype)
    i_pre = jax.random.normal(ks[3], (B, S), jnp.float32)
    f_pre = jax.random.normal(ks[4], (B, S), jnp.float32)
    y = mlstm_chunk_pallas(q, k, v, i_pre, f_pre, chunk=64, interpret=True)
    assert y.dtype == dtype
    assert bool(jnp.all(jnp.isfinite(y.astype(jnp.float32))))


@pytest.mark.parametrize("S,di,ds,tc,dic", [
    (64, 64, 8, 32, 32),
    (128, 128, 16, 64, 64),
    (96, 32, 4, 96, 32),
])
def test_mamba_kernel_vs_ssm_scan(S, di, ds, tc, dic, key):
    b = 2
    ks = jax.random.split(key, 6)
    u = jax.random.normal(ks[0], (b, S, di)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, di)) - 1.0)
    B = jax.random.normal(ks[2], (b, S, ds)) * 0.5
    C = jax.random.normal(ks[3], (b, S, ds)) * 0.5
    A = -jnp.exp(jax.random.normal(ks[4], (di, ds)) * 0.3)
    D = jnp.ones((di,))

    y_kernel = mamba_scan_pallas(u, dt, B, C, A, D, t_chunk=tc, di_chunk=dic,
                                 interpret=True)
    y_ref, _ = mamba_mod._ssm_scan(u, dt, B, C, A, D)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_ref),
                               atol=2e-5, rtol=2e-5)


def test_mamba_kernel_jamba_dims(key):
    """The exact jamba dims (di=8192/16-shard = 512 per device, ds=16)."""
    b, S, di, ds = 1, 128, 512, 16
    ks = jax.random.split(key, 6)
    u = jax.random.normal(ks[0], (b, S, di)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, di)))
    B = jax.random.normal(ks[2], (b, S, ds)) * 0.3
    C = jax.random.normal(ks[3], (b, S, ds)) * 0.3
    A = -jnp.exp(jax.random.normal(ks[4], (di, ds)) * 0.2)
    D = jnp.ones((di,))
    y = mamba_scan_pallas(u, dt, B, C, A, D, t_chunk=64, di_chunk=512, interpret=True)
    y_ref, _ = mamba_mod._ssm_scan(u, dt, B, C, A, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-5, rtol=2e-5)
