"""Checkpoint + fault-tolerant runtime tests: roundtrip, rotation,
crash/restart bitwise continuation, failure injection, straggler
monitoring. (Gradient-compression tests live in test_compression.py.)"""
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager, save_pytree, load_pytree, tree_equal
from repro.config import get_config
from repro.data.synthetic import SyntheticLMDataset
from repro.launch.steps import make_train_step
from repro.models.model import init_model
from repro.optim import make_sct_optimizer
from repro.runtime.train_loop import TrainLoop, TrainLoopConfig


def test_pytree_roundtrip(tmp_path, key):
    tree = {
        "a": jax.random.normal(key, (4, 5)),
        "nested": {"b": jnp.arange(7), "c": (jnp.ones((2,)), jnp.zeros((3,)))},
    }
    p = str(tmp_path / "ck.npz")
    save_pytree(tree, p)
    out = load_pytree(p)
    assert tree_equal(tree, out)
    assert isinstance(out["nested"]["c"], tuple)


def test_manager_rotation(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (10, 20, 30, 40):
        mgr.save(s, {"x": jnp.full((2,), s)})
    assert mgr.list_steps() == [30, 40]
    step, state = mgr.restore_latest()
    assert step == 40 and float(state["x"][0]) == 40


def _make_loop(tmp_path, cfg, total=12, ckpt_every=4, failure_hook=None,
               deadline=None):
    opt = make_sct_optimizer(cfg, lr=1e-3, warmup=2, total_steps=total)
    step_fn = jax.jit(make_train_step(cfg, opt))
    ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=16, seed=0)

    def batches(start):
        step = start
        while True:
            t, l = ds.batch(step, 4)
            yield {"tokens": jnp.asarray(t), "labels": jnp.asarray(l)}
            step += 1

    def init_state():
        return opt.init(init_model(jax.random.PRNGKey(0), cfg))

    return TrainLoop(
        step_fn=step_fn,
        batch_iter_factory=batches,
        ckpt_dir=str(tmp_path),
        cfg=TrainLoopConfig(total_steps=total, checkpoint_every=ckpt_every,
                            step_deadline_s=deadline, max_restarts=3),
        init_state_fn=init_state,
        failure_hook=failure_hook,
    )


def test_restart_is_bitwise_identical(tmp_path):
    """Train 12 steps straight vs. train-8/crash/restart: identical."""
    cfg = get_config("smollm2-1.7b", reduced=True)
    straight = _make_loop(tmp_path / "a", cfg).run()

    crashed = {"done": False}

    def bomb(step):
        if step == 8 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected node failure")

    loop = _make_loop(tmp_path / "b", cfg, failure_hook=bomb)
    resumed = loop.run()
    assert loop.restarts == 1
    assert tree_equal(straight["params"], resumed["params"])
    assert int(straight["step"]) == int(resumed["step"]) == 12


def test_restart_across_loop_instances(tmp_path):
    """Failure-injection restart across *processes*: the first loop dies
    mid-run past its restart budget, a fresh TrainLoop instance (new
    process in production) resumes from its checkpoints and lands
    bit-identical to an uninterrupted run."""
    cfg = get_config("smollm2-1.7b", reduced=True)
    straight = _make_loop(tmp_path / "a", cfg).run()

    def bomb(step):
        if step == 9:
            raise RuntimeError("injected node failure")

    first = _make_loop(tmp_path / "b", cfg, failure_hook=bomb)
    first.cfg.max_restarts = 0                      # process actually dies
    with pytest.raises(RuntimeError):
        first.run()
    resumed = _make_loop(tmp_path / "b", cfg).run()  # fresh instance, same dir
    assert tree_equal(straight["params"], resumed["params"])
    assert int(resumed["step"]) == 12


def test_too_many_failures_raises(tmp_path):
    cfg = get_config("smollm2-1.7b", reduced=True)

    def always_bomb(step):
        raise RuntimeError("persistent failure")

    loop = _make_loop(tmp_path, cfg, failure_hook=always_bomb)
    with pytest.raises(RuntimeError):
        loop.run()


def test_async_checkpoint_with_donated_state(tmp_path):
    """Async checkpointing must not race with buffer donation (the
    production launcher jits with donate_argnums=(0,)): the loop fetches
    state to host before the next step deletes the donated buffers, so
    every periodic checkpoint lands complete."""
    cfg = get_config("smollm2-1.7b", reduced=True)
    total = 6
    opt = make_sct_optimizer(cfg, lr=1e-3, warmup=2, total_steps=total)
    step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=(0,))
    ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=16, seed=0)

    def batches(start):
        step = start
        while True:
            t, l = ds.batch(step, 4)
            yield {"tokens": jnp.asarray(t), "labels": jnp.asarray(l)}
            step += 1

    loop = TrainLoop(
        step_fn=step_fn,
        batch_iter_factory=batches,
        ckpt_dir=str(tmp_path),
        cfg=TrainLoopConfig(total_steps=total, checkpoint_every=2),
        init_state_fn=lambda: opt.init(init_model(jax.random.PRNGKey(0), cfg)),
    )
    loop.run()
    assert loop.mgr.list_steps() == [2, 4, 6]   # no save lost to the race
    step, state = loop.mgr.restore_latest()
    assert step == 6 and int(state["step"]) == 6


def test_restart_flushes_inflight_checkpoint_writes(tmp_path):
    """A step failure right after a periodic save hands off to the async
    writer must flush (mgr.wait) *before* the restart touches the
    checkpoint directory, and must swallow writer errors surfaced by
    that flush — the restarted run still lands bit-identical."""
    cfg = get_config("smollm2-1.7b", reduced=True)
    straight = _make_loop(tmp_path / "a", cfg).run()

    events = []
    crashed = {"done": False}

    def bomb(step):
        # the loop saves at step 4 (checkpoint_every=4) at the end of
        # that iteration; the hook fires at the top of the next one —
        # i.e. while the async writer may still be in flight
        if step == 4 and not crashed["done"]:
            crashed["done"] = True
            events.append("crash")
            raise RuntimeError("injected failure right after save")

    loop = _make_loop(tmp_path / "b", cfg, failure_hook=bomb)
    mgr = loop.mgr
    orig_wait, orig_restore = mgr.wait, mgr.restore_latest
    raised = {"done": False}

    def wait():
        events.append("wait_postcrash" if crashed["done"] else "wait")
        orig_wait()
        if crashed["done"] and not raised["done"]:
            raised["done"] = True          # the restart-path flush: a
            raise OSError("flaky writer")  # writer error must be swallowed

    def restore_latest(*a, **k):
        events.append("restore")
        return orig_restore(*a, **k)

    mgr.wait = wait
    mgr.restore_latest = restore_latest
    resumed = loop.run()
    assert loop.restarts == 1
    assert tree_equal(straight["params"], resumed["params"])
    assert int(resumed["step"]) == 12
    # the first thing after the crash is the flush, not the restore —
    # and the flush's writer error did not kill the restart
    after_crash = events[events.index("crash") + 1:]
    assert after_crash[0] == "wait_postcrash", events
    assert "restore" in after_crash


def test_straggler_detection(tmp_path):
    cfg = get_config("smollm2-1.7b", reduced=True)
    loop = _make_loop(tmp_path, cfg, total=4, deadline=1e-9)
    loop.run()
    assert loop.straggler_steps == 4  # every step 'misses' a 1ns deadline


def test_elastic_reshard_roundtrip(tmp_path, key):
    """Checkpoints are mesh-agnostic: save plain, load with explicit
    (single-device) shardings — the elastic-scaling path."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    tree = {"w": jax.random.normal(key, (8, 4))}
    p = str(tmp_path / "ck.npz")
    save_pytree(tree, p)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P(None, None))}
    out = load_pytree(p, shardings=sh)
    assert tree_equal(tree, out)
    assert out["w"].sharding == sh["w"]
