"""Checkpoint + fault-tolerant runtime tests: roundtrip, rotation,
crash/restart bitwise continuation, failure injection, straggler
monitoring, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, save_pytree, load_pytree, tree_equal
from repro.config import get_config
from repro.data.synthetic import SyntheticLMDataset
from repro.launch.steps import make_train_step
from repro.models.model import init_model
from repro.optim import make_sct_optimizer
from repro.runtime.train_loop import TrainLoop, TrainLoopConfig
from repro.runtime.compression import (
    compress_int8,
    decompress_int8,
    init_error_feedback,
)


def test_pytree_roundtrip(tmp_path, key):
    tree = {
        "a": jax.random.normal(key, (4, 5)),
        "nested": {"b": jnp.arange(7), "c": (jnp.ones((2,)), jnp.zeros((3,)))},
    }
    p = str(tmp_path / "ck.npz")
    save_pytree(tree, p)
    out = load_pytree(p)
    assert tree_equal(tree, out)
    assert isinstance(out["nested"]["c"], tuple)


def test_manager_rotation(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (10, 20, 30, 40):
        mgr.save(s, {"x": jnp.full((2,), s)})
    assert mgr.list_steps() == [30, 40]
    step, state = mgr.restore_latest()
    assert step == 40 and float(state["x"][0]) == 40


def _make_loop(tmp_path, cfg, total=12, ckpt_every=4, failure_hook=None,
               deadline=None):
    opt = make_sct_optimizer(cfg, lr=1e-3, warmup=2, total_steps=total)
    step_fn = jax.jit(make_train_step(cfg, opt))
    ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=16, seed=0)

    def batches(start):
        step = start
        while True:
            t, l = ds.batch(step, 4)
            yield {"tokens": jnp.asarray(t), "labels": jnp.asarray(l)}
            step += 1

    def init_state():
        return opt.init(init_model(jax.random.PRNGKey(0), cfg))

    return TrainLoop(
        step_fn=step_fn,
        batch_iter_factory=batches,
        ckpt_dir=str(tmp_path),
        cfg=TrainLoopConfig(total_steps=total, checkpoint_every=ckpt_every,
                            step_deadline_s=deadline, max_restarts=3),
        init_state_fn=init_state,
        failure_hook=failure_hook,
    )


def test_restart_is_bitwise_identical(tmp_path):
    """Train 12 steps straight vs. train-8/crash/restart: identical."""
    cfg = get_config("smollm2-1.7b", reduced=True)
    straight = _make_loop(tmp_path / "a", cfg).run()

    crashed = {"done": False}

    def bomb(step):
        if step == 8 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected node failure")

    loop = _make_loop(tmp_path / "b", cfg, failure_hook=bomb)
    resumed = loop.run()
    assert loop.restarts == 1
    assert tree_equal(straight["params"], resumed["params"])
    assert int(straight["step"]) == int(resumed["step"]) == 12


def test_restart_across_loop_instances(tmp_path):
    """Failure-injection restart across *processes*: the first loop dies
    mid-run past its restart budget, a fresh TrainLoop instance (new
    process in production) resumes from its checkpoints and lands
    bit-identical to an uninterrupted run."""
    cfg = get_config("smollm2-1.7b", reduced=True)
    straight = _make_loop(tmp_path / "a", cfg).run()

    def bomb(step):
        if step == 9:
            raise RuntimeError("injected node failure")

    first = _make_loop(tmp_path / "b", cfg, failure_hook=bomb)
    first.cfg.max_restarts = 0                      # process actually dies
    with pytest.raises(RuntimeError):
        first.run()
    resumed = _make_loop(tmp_path / "b", cfg).run()  # fresh instance, same dir
    assert tree_equal(straight["params"], resumed["params"])
    assert int(resumed["step"]) == 12


def test_too_many_failures_raises(tmp_path):
    cfg = get_config("smollm2-1.7b", reduced=True)

    def always_bomb(step):
        raise RuntimeError("persistent failure")

    loop = _make_loop(tmp_path, cfg, failure_hook=always_bomb)
    with pytest.raises(RuntimeError):
        loop.run()


def test_async_checkpoint_with_donated_state(tmp_path):
    """Async checkpointing must not race with buffer donation (the
    production launcher jits with donate_argnums=(0,)): the loop fetches
    state to host before the next step deletes the donated buffers, so
    every periodic checkpoint lands complete."""
    cfg = get_config("smollm2-1.7b", reduced=True)
    total = 6
    opt = make_sct_optimizer(cfg, lr=1e-3, warmup=2, total_steps=total)
    step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=(0,))
    ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=16, seed=0)

    def batches(start):
        step = start
        while True:
            t, l = ds.batch(step, 4)
            yield {"tokens": jnp.asarray(t), "labels": jnp.asarray(l)}
            step += 1

    loop = TrainLoop(
        step_fn=step_fn,
        batch_iter_factory=batches,
        ckpt_dir=str(tmp_path),
        cfg=TrainLoopConfig(total_steps=total, checkpoint_every=2),
        init_state_fn=lambda: opt.init(init_model(jax.random.PRNGKey(0), cfg)),
    )
    loop.run()
    assert loop.mgr.list_steps() == [2, 4, 6]   # no save lost to the race
    step, state = loop.mgr.restore_latest()
    assert step == 6 and int(state["step"]) == 6


def test_straggler_detection(tmp_path):
    cfg = get_config("smollm2-1.7b", reduced=True)
    loop = _make_loop(tmp_path, cfg, total=4, deadline=1e-9)
    loop.run()
    assert loop.straggler_steps == 4  # every step 'misses' a 1ns deadline


def test_elastic_reshard_roundtrip(tmp_path, key):
    """Checkpoints are mesh-agnostic: save plain, load with explicit
    (single-device) shardings — the elastic-scaling path."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    tree = {"w": jax.random.normal(key, (8, 4))}
    p = str(tmp_path / "ck.npz")
    save_pytree(tree, p)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P(None, None))}
    out = load_pytree(p, shardings=sh)
    assert tree_equal(tree, out)
    assert out["w"].sharding == sh["w"]


def test_int8_compression_error_feedback(key):
    g = jax.random.normal(key, (256,))
    q, scale = compress_int8(g)
    rec = decompress_int8(q, scale)
    rel = float(jnp.linalg.norm(rec - g) / jnp.linalg.norm(g))
    assert rel < 0.01  # int8 quantization error ~0.4% for gaussian
    ef = init_error_feedback({"g": g})
    assert float(jnp.max(jnp.abs(ef.residual["g"]))) == 0.0
