"""Optimizer tests: AdamW math, per-component LR groups (the paper's
'clear next step'), schedules, clipping, and the SCT step invariant
(always on-manifold after apply)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import spectral_init, orthogonality_error
from repro.core.tree import max_orthogonality_error
from repro.optim import (
    adamw_init,
    adamw_update,
    AdamWConfig,
    make_schedule,
    ScheduleConfig,
    clip_by_global_norm,
    global_norm,
    make_sct_optimizer,
)


def test_adamw_first_step_is_signed_lr():
    """After one step from zero moments, AdamW moves ~lr*sign(grad)."""
    params = {"w": jnp.array([1.0, -2.0, 3.0])}
    grads = {"w": jnp.array([0.5, -0.1, 0.0])}
    cfg = AdamWConfig(lr=0.01, weight_decay=0.0)
    state = adamw_init(params)
    new, _ = adamw_update(params, grads, state, cfg)
    step = np.asarray(params["w"] - new["w"])
    np.testing.assert_allclose(step[:2], [0.01, -0.01], rtol=1e-3)
    assert abs(step[2]) < 1e-6


def test_per_component_lr_scaling(key):
    spec = spectral_init(key, 16, 24, 4)
    params = {"mlp": spec, "dense": {"w": jnp.ones((4, 4))}}
    grads = jax.tree.map(jnp.ones_like, params)
    cfg = AdamWConfig(lr=0.01, weight_decay=0.0, spectral_lr_scale=10.0,
                      dense_lr_scale=1.0, sv_lr_scale=0.0)
    state = adamw_init(params)
    new, _ = adamw_update(params, grads, state, cfg)
    du = float(jnp.max(jnp.abs(new["mlp"]["U"] - params["mlp"]["U"])))
    dd = float(jnp.max(jnp.abs(new["dense"]["w"] - params["dense"]["w"])))
    ds = float(jnp.max(jnp.abs(new["mlp"]["s"] - params["mlp"]["s"])))
    assert du == pytest.approx(0.1, rel=1e-2)   # 10x scale
    assert dd == pytest.approx(0.01, rel=1e-2)  # 1x
    assert ds == 0.0                             # frozen singular values


def test_schedule_warmup_and_cosine():
    sched = make_schedule(ScheduleConfig(peak_lr=1.0, warmup_steps=10, total_steps=110,
                                         final_fraction=0.1))
    # 1-indexed: the first step gets a nonzero LR
    assert float(sched(0)) == pytest.approx(0.1)
    assert float(sched(9)) == pytest.approx(1.0)
    assert float(sched(4)) == pytest.approx(0.5)
    assert float(sched(109)) == pytest.approx(0.1, abs=1e-6)
    assert float(sched(60)) < 1.0


def test_clip_by_global_norm():
    g = {"a": jnp.ones((10,)) * 3.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(3.0 * np.sqrt(10), rel=1e-5)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)


@pytest.mark.parametrize("retraction", ["qr", "cholesky_qr2"])
def test_sct_optimizer_keeps_manifold(key, retraction):
    spec = spectral_init(key, 32, 48, 8)
    params = {"mlp": spec}
    from repro.config import get_config

    cfg = get_config("smollm2-1.7b", reduced=True).replace_sct(retraction=retraction)
    opt = make_sct_optimizer(cfg, lr=0.05)  # huge LR to stress the manifold
    state = opt.init(params)
    for i in range(3):
        grads = jax.tree.map(
            lambda p: jax.random.normal(jax.random.PRNGKey(i), p.shape), params)
        state = opt.apply(state, grads)
    assert float(max_orthogonality_error(state["params"])) < 2e-5
    assert int(state["step"]) == 3


def test_retract_every_n(key):
    """retract_every=2: off-steps drift, on-steps restore (beyond-paper
    retraction scheduling)."""
    spec = spectral_init(key, 32, 48, 8)
    from repro.config import get_config

    cfg = get_config("smollm2-1.7b", reduced=True).replace_sct(
        retraction="qr", retract_every=2)
    opt = make_sct_optimizer(cfg, lr=0.05, warmup=1)
    state = opt.init({"mlp": spec})
    g = jax.tree.map(lambda p: jax.random.normal(key, p.shape), state["params"])
    state = opt.apply(state, g)   # step 1: no retraction
    err1 = float(max_orthogonality_error(state["params"]))
    state = opt.apply(state, g)   # step 2: retraction fires
    err2 = float(max_orthogonality_error(state["params"]))
    assert err1 > 1e-4           # drifted
    assert err2 < 2e-5           # restored
