"""Stiefel retraction tests: paper Eq. 5 QR + sign fix, CholeskyQR2
equivalence, Cayley, idempotence, vmap-over-layers, property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container without hypothesis: deterministic shim
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    qr_retract,
    cholesky_qr2_retract,
    cayley_retract,
    retract,
    orthogonality_error,
    retract_tree,
    spectral_init,
)
from repro.core.tree import max_orthogonality_error


def _noisy_stiefel(key, m, k, noise):
    U0, _ = jnp.linalg.qr(jax.random.normal(key, (m, k)))
    return U0 + noise * jax.random.normal(jax.random.PRNGKey(1), (m, k))


@pytest.mark.parametrize("method", ["qr", "cholesky_qr2", "cayley"])
def test_retraction_lands_on_manifold(key, method):
    U = _noisy_stiefel(key, 64, 16, 0.05)
    R = retract(U, method)
    assert float(orthogonality_error(R)) < 2e-5


@pytest.mark.parametrize("method", ["qr", "cholesky_qr2"])
def test_retraction_identity_on_manifold(key, method):
    """Retracting an already-orthonormal factor is (nearly) the identity
    — the sign-fix continuity property from paper Eq. 5."""
    U, _ = jnp.linalg.qr(jax.random.normal(key, (48, 12)))
    R = retract(U, method)
    np.testing.assert_allclose(np.asarray(R), np.asarray(U), atol=5e-6)


def test_qr_equals_choleskyqr2(key):
    U = _noisy_stiefel(key, 96, 24, 0.02)
    a = qr_retract(U)
    b = cholesky_qr2_retract(U)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_retraction_preserves_column_space(key):
    U = _noisy_stiefel(key, 64, 8, 0.01)
    R = qr_retract(U)
    # projector onto span(U) == projector onto span(R)
    Pu = np.asarray(U @ jnp.linalg.pinv(U))
    Pr = np.asarray(R @ R.T)
    np.testing.assert_allclose(Pu, Pr, atol=1e-3)


def test_retraction_broadcasts_over_layers(key):
    U = jax.random.normal(key, (5, 32, 8))  # stacked layer axis
    R = qr_retract(U)
    assert R.shape == U.shape
    assert float(orthogonality_error(R)) < 2e-5


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(8, 64),
    kfrac=st.floats(0.1, 0.9),
    noise=st.floats(0.0, 0.1),
    seed=st.integers(0, 2**31 - 1),
)
def test_retraction_property(m, kfrac, noise, seed):
    k = max(1, int(kfrac * m))
    U = _noisy_stiefel(jax.random.PRNGKey(seed), m, k, noise)
    for method in ("qr", "cholesky_qr2"):
        R = retract(U, method)
        assert float(orthogonality_error(R)) < 5e-5


def test_retract_tree_touches_only_spectral(key):
    p = spectral_init(key, 32, 48, 8)
    p_noisy = {**p, "U": p["U"] + 0.05, "V": p["V"] + 0.05}
    tree = {"mlp": p_noisy, "dense": {"w": jnp.ones((4, 4))}, "norm": jnp.ones((4,))}
    out = retract_tree(tree, "qr")
    assert float(max_orthogonality_error(out)) < 2e-5
    np.testing.assert_array_equal(np.asarray(out["dense"]["w"]), np.ones((4, 4)))
    np.testing.assert_array_equal(np.asarray(out["mlp"]["s"]), np.asarray(p["s"]))


def test_dispatcher_rejects_axis_name_for_local_methods(key):
    """Inside shard_map a row-sharded U through qr/cayley would be QR'd
    per-shard — silently non-orthonormal globally. The dispatcher must
    refuse instead of corrupting the manifold."""
    U = _noisy_stiefel(key, 32, 8, 0.01)
    for method in ("qr", "cayley"):
        with pytest.raises(ValueError, match="cholesky_qr2"):
            retract(U, method, axis_name="data")
    # cholesky_qr2 accepts it (None mapping == unsharded single shard)
    R = retract(U, "cholesky_qr2", axis_name=None)
    assert float(orthogonality_error(R)) < 2e-5


def test_dispatcher_threads_method_kwargs(key):
    """tangent_scale must reach cayley through the dispatcher (it used
    to be unreachable — retract() dropped all method kwargs)."""
    U = _noisy_stiefel(key, 48, 12, 0.05)
    via_dispatch = retract(U, "cayley", tangent_scale=0.25)
    direct = cayley_retract(U, tangent_scale=0.25)
    np.testing.assert_allclose(np.asarray(via_dispatch), np.asarray(direct),
                               atol=1e-7)
    # a different scale must actually change the result
    other = retract(U, "cayley", tangent_scale=1.0)
    assert float(jnp.max(jnp.abs(via_dispatch - other))) > 1e-6


def test_paper_ortho_error_bound_after_training_step(key):
    """Paper Table 2 reports ortho error < 2e-6 after a full train step.
    One AdamW-sized perturbation + retraction must restore that level."""
    U, _ = jnp.linalg.qr(jax.random.normal(key, (256, 32)))
    U = U + 5e-4 * jax.random.normal(key, (256, 32))  # ~lr-sized update
    for method in ("qr", "cholesky_qr2"):
        assert float(orthogonality_error(retract(U, method))) < 2e-6
