"""Trainer/Server facade contract (api/trainer.py, api/server.py):
checkpoints are self-describing — train → save embeds the RunSpec in
the sidecar, ``Server.from_checkpoint(path)`` serves with zero
re-specified flags and matches the static greedy oracle token for
token, ``Trainer.resume`` continues a run (and a ``rank.schedule``
override exercises the cross-rank restore path)."""
import os

import numpy as np
import pytest

from repro.api import (
    CheckpointSpec,
    ModelSpec,
    RunSpec,
    Server,
    ServeSpec,
    Trainer,
    TrainSpec,
)
from repro.checkpoint.manager import CheckpointManager
from repro.launch.serve import static_greedy_reference
from repro.rank import current_ranks
from repro.serving import Request

ARCH = "llama3.2-1b"


def _spec(ckpt_dir, steps=4):
    return RunSpec(
        model=ModelSpec(ARCH, reduced=True),
        train=TrainSpec(steps=steps, batch=4, seq=32, lr=3e-3),
        checkpoint=CheckpointSpec(
            directory=None if ckpt_dir is None else str(ckpt_dir), every=2),
        serve=ServeSpec(page_size=8, num_pages=32, slots=2,
                        pages_per_seq=6, gen=6),
    )


@pytest.fixture(scope="module")
def trained(tmp_path_factory):
    """One short fit shared by the read-only checkpoint tests."""
    ckpt_dir = tmp_path_factory.mktemp("api_ckpt")
    spec = _spec(ckpt_dir)
    trainer = Trainer(spec)
    state = trainer.fit()
    return spec, str(ckpt_dir), state


def _prompts(vocab, lens=(5, 9)):
    rng = np.random.default_rng(3)
    return [rng.integers(0, vocab, size=(n,)).astype(np.int32) for n in lens]


# ---------------------------------------------------------------------------
def test_fit_embeds_run_spec_in_sidecar(trained):
    spec, ckpt_dir, _ = trained
    step, spec_dict = CheckpointManager(ckpt_dir).latest_run_spec()
    assert step == spec.train.steps
    assert spec_dict == spec.to_dict()
    assert RunSpec.from_dict(spec_dict) == spec


def test_server_from_checkpoint_zero_flags_matches_oracle(trained):
    spec, ckpt_dir, _ = trained
    server = Server.from_checkpoint(ckpt_dir)
    assert server.spec == spec                     # nothing re-specified
    assert server.checkpoint_step == spec.train.steps
    prompts = _prompts(server.cfg.vocab)
    rids = [server.submit(p) for p in prompts]     # gen from the spec
    out = server.run()
    max_seq = spec.serve.paged_config().max_seq
    for rid, prompt in zip(rids, prompts):
        ref = static_greedy_reference(server.cfg, server.params, prompt,
                                      spec.serve.gen, max_seq)
        np.testing.assert_array_equal(out[rid], ref)
        assert server.last_statuses[rid] == "finished"


def test_server_from_checkpoint_rank_override(trained):
    spec, ckpt_dir, state = trained
    (base_rank,) = set(current_ranks(state["params"]))
    target = base_rank // 2
    server = Server.from_checkpoint(ckpt_dir, **{"serve.rank": target})
    assert set(current_ranks(server.params)) == {target}
    # the resized model still serves token-identically to its own
    # static oracle (resize correctness is rank/'s concern; the facade
    # must wire the resized params through unchanged)
    prompt = _prompts(server.cfg.vocab, lens=(7,))[0]
    rid = server.submit(prompt, max_new_tokens=5)
    out = server.run()
    ref = static_greedy_reference(server.cfg, server.params, prompt, 5,
                                  spec.serve.paged_config().max_seq)
    np.testing.assert_array_equal(out[rid], ref)


def test_server_stream_yields_completions(trained):
    _, ckpt_dir, _ = trained
    server = Server.from_checkpoint(ckpt_dir)
    rids = {server.submit(p, max_new_tokens=4)
            for p in _prompts(server.cfg.vocab, lens=(4, 6, 8))}
    events = list(server.stream())
    assert {rid for rid, _, _ in events} == rids
    assert all(status == "finished" for _, _, status in events)
    assert all(len(tokens) == 4 for _, tokens, _ in events)
    with pytest.raises(ValueError, match="submit"):
        server.run()                               # queue already drained
    # explicit rids: auto-assignment continues past them, and a
    # duplicate is an error (results key on rid)
    assert server.submit([1, 2, 3], rid=7) == 7
    assert server.submit([1, 2, 3]) == 8
    with pytest.raises(ValueError, match="already queued"):
        server.submit([1, 2, 3], rid=7)


def test_trainer_resume_zero_flags_extends_run(trained, tmp_path):
    spec, ckpt_dir, state = trained
    trainer = Trainer.resume(ckpt_dir, **{"train.steps": spec.train.steps + 2})
    # everything but the override came from the sidecar
    assert trainer.spec.model == spec.model
    assert trainer.spec.train.lr == spec.train.lr
    new_state = trainer.fit()
    assert int(new_state["step"]) == int(state["step"]) + 2


def test_trainer_resume_cross_rank_override(tmp_path):
    spec = _spec(tmp_path / "ckpt", steps=2)
    Trainer(spec).fit()
    trainer = Trainer.resume(str(tmp_path / "ckpt"),
                             **{"rank.schedule": "static:8",
                                "train.steps": 3})
    metrics = trainer.step()                       # restores + resizes
    assert set(current_ranks(trainer.params)) == {8}
    assert np.isfinite(float(metrics["loss"]))
    assert trainer.controller.resizes              # the event was recorded


def test_trainer_resume_requires_checkpoint_and_spec(tmp_path):
    with pytest.raises(FileNotFoundError):
        Trainer.resume(str(tmp_path / "empty"))
    # the read path must not create the mistyped directory
    assert not os.path.exists(tmp_path / "empty")
    # a pre-API checkpoint (no embedded spec) is a clear error, not a
    # silent default
    mgr = CheckpointManager(str(tmp_path / "old"))
    mgr.save(1, {"x": np.zeros((2,), np.float32)}, block=True)
    with pytest.raises(ValueError, match="predates spec embedding"):
        Trainer.resume(str(tmp_path / "old"))


def test_server_stream_abandoned_midway_recovers(trained):
    """A stream() dropped mid-trace strands its remaining requests in
    the engine; a fresh stream() with nothing new submitted drains
    them — outcomes included — instead of raising."""
    _, ckpt_dir, _ = trained
    server = Server.from_checkpoint(ckpt_dir)
    rids = {server.submit(p, max_new_tokens=3)
            for p in _prompts(server.cfg.vocab, lens=(4, 5, 6))}
    gen = server.stream()
    first_rid, _, _ = next(gen)                    # one completion, then bail
    # in-flight rids are still owned by the runtime: duplicates rejected
    with pytest.raises(ValueError, match="already queued"):
        server.submit([1, 2, 3], rid=min(rids - {first_rid}))
    gen.close()
    rest = list(server.stream())                   # recovery: empty take
    assert {rid for rid, _, _ in rest} == rids - {first_rid}
    assert all(server.last_statuses[rid] == "finished"
               for rid in rids - {first_rid})
    with pytest.raises(ValueError, match="submit"):
        server.run()                               # now truly drained


def test_server_stream_future_arrivals_and_unconsumed_generators(trained):
    """Requests live on the engine, not in generator locals: a stream()
    abandoned before a future arrival lands — or never iterated at all
    — loses nothing; the recovery call serves everything."""
    _, ckpt_dir, _ = trained
    server = Server.from_checkpoint(ckpt_dir)
    p_now, p_later = _prompts(server.cfg.vocab, lens=(4, 5))
    r_now = server.submit(p_now, max_new_tokens=3)
    r_later = server.submit(p_later, max_new_tokens=3, arrival=40)
    gen = server.stream()
    first_rid, _, _ = next(gen)                    # r_now finishes first
    assert first_rid == r_now
    gen.close()                                    # r_later never arrived
    out = server.run()                             # recovery serves it
    assert set(out) == {r_later}
    # never-iterated generator: registration already happened
    r3 = server.submit(p_now, max_new_tokens=2)
    server.stream()                                # discarded unconsumed
    assert set(server.run()) == {r3}


def test_server_auto_rid_dodges_explicit_trace_rids(trained):
    """Auto-assigned rids must skip rids the engine learned from an
    explicit Request list (results key on rid)."""
    _, ckpt_dir, _ = trained
    server = Server.from_checkpoint(ckpt_dir)
    (p,) = _prompts(server.cfg.vocab, lens=(4,))
    server.stream([Request(rid=0, prompt=p, max_new_tokens=2),
                   Request(rid=1, prompt=p, max_new_tokens=2)])
    auto = server.submit(p, max_new_tokens=2)
    assert auto == 2
    out = server.run()
    assert set(out) == {0, 1, 2}


def test_trainer_fit_preserves_step_progress(tmp_path):
    """In-memory progress made via step() is checkpointed before fit()
    hands control to the disk-backed loop (regression: it used to be
    silently re-run from the last checkpoint)."""
    spec = _spec(tmp_path / "ckpt", steps=3)
    trainer = Trainer(spec)
    trainer.step()
    trainer.step()                                 # 2 steps, never saved
    state = trainer.fit()
    assert int(state["step"]) == 3
    assert 2 in CheckpointManager(str(tmp_path / "ckpt")).list_steps()


def test_trainer_step_continues_after_fit(tmp_path):
    """fit() leaves the trainer in a usable step-at-a-time state: the
    batch stream continues from the achieved step (regression: the
    iterator used to be dropped)."""
    spec = _spec(tmp_path / "ckpt", steps=2)
    trainer = Trainer(spec)
    trainer.fit()
    metrics = trainer.step()
    assert np.isfinite(float(metrics["loss"]))
    assert trainer.current_step == 3
    assert int(trainer.state["step"]) == 3


def test_trainer_fit_past_budget_reports_achieved_step(tmp_path):
    """A checkpoint already past train.steps restores, runs zero steps,
    and current_step reflects the checkpoint — not the smaller budget
    (regression: save() used to write a stale-ordered snapshot)."""
    spec = _spec(tmp_path / "ckpt", steps=2)
    Trainer(spec).fit()
    trainer = Trainer.resume(str(tmp_path / "ckpt"), **{"train.steps": 1})
    state = trainer.fit()
    assert int(state["step"]) == 2
    assert trainer.current_step == 2


def test_trainer_fit_requires_directory_step_does_not():
    spec = _spec(None, steps=1).replace(checkpoint=CheckpointSpec())
    trainer = Trainer(spec)
    with pytest.raises(ValueError, match="checkpoint.directory"):
        trainer.fit()
    metrics = trainer.step()                       # fresh init, no disk
    assert np.isfinite(float(metrics["loss"]))
    assert trainer.current_step == 1
