"""Per-architecture smoke tests (assignment requirement): a REDUCED
config of the same family runs one forward/train step on CPU with
correct output shapes and no NaNs — for all 10 assigned archs plus the
paper's own configs."""
import jax
import jax.numpy as jnp
import pytest

from repro.config import ARCH_IDS, get_config
from repro.models.model import init_model, train_loss, forward, param_count


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step_shapes_and_finite(arch, key):
    cfg = get_config(arch, reduced=True)
    params = init_model(key, cfg)
    b, s = 2, 16
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab),
    }
    if cfg.family == "encdec":
        batch["encoder_frames"] = jnp.ones((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    loss, metrics = train_loss(params, batch, cfg)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"
    assert param_count(params) > 0


@pytest.mark.parametrize("arch", ["llama3.2-1b", "jamba-v0.1-52b", "xlstm-1.3b",
                                  "deepseek-v3-671b"])
def test_reduced_forward_logit_shapes(arch, key):
    cfg = get_config(arch, reduced=True)
    params = init_model(key, cfg)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab)
    logits, aux = forward(params, tokens, cfg)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_IDS[:10])
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact published dims (never allocated
    on CPU — only eval_shape'd by the dry-run)."""
    cfg = get_config(arch)
    expected = {
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "deepseek-v3-671b": (61, 7168, 128, 128, 18432, 129280),
        "deepseek-v2-236b": (60, 5120, 128, 128, 12288, 102400),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab)
    assert got == expected, (arch, got, expected)


def test_moe_config_dims():
    v3 = get_config("deepseek-v3-671b")
    assert (v3.n_experts, v3.top_k, v3.moe_d_ff, v3.n_shared_experts) == (256, 8, 2048, 1)
    assert (v3.kv_lora_rank, v3.q_lora_rank) == (512, 1536)
    v2 = get_config("deepseek-v2-236b")
    assert (v2.n_experts, v2.top_k, v2.moe_d_ff, v2.n_shared_experts) == (160, 6, 1536, 2)
    jb = get_config("jamba-v0.1-52b")
    assert (jb.n_experts, jb.top_k, jb.attn_every, jb.moe_every) == (16, 2, 8, 2)
