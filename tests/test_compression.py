"""Gradient-compression correctness: int8 round-trip, error feedback,
and the compressed all-reduce on a real 2-device shard_map (subprocess +
``XLA_FLAGS=--xla_force_host_platform_device_count`` pattern from
tests/test_sharding.py, so the main process stays single-device).

The regression of record: shards quantized against *different* per-shard
scales cannot be summed as raw int8 payloads and rescaled by the
averaged scale — with a 1000x scale ratio the small shard's
contribution is inflated by orders of magnitude. The fixed path agrees
on the max scale first (scalar pmax), requantizes, and psums int8 under
the one shared scale; its mean error is bounded by shared_scale / 2 per
element. The subprocess computes the fp32 reference, the fixed result,
and the legacy math side by side: the fix must sit inside the bound and
the legacy math must blow it by orders of magnitude.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp

from repro.runtime.compression import (
    compress_int8,
    compressed_psum,
    decompress_int8,
    init_error_feedback,
)


def test_int8_roundtrip_and_error_feedback(key):
    g = jax.random.normal(key, (256,))
    q, scale = compress_int8(g)
    rec = decompress_int8(q, scale)
    rel = float(jnp.linalg.norm(rec - g) / jnp.linalg.norm(g))
    assert rel < 0.01  # int8 quantization error ~0.4% for gaussian
    ef = init_error_feedback({"g": g})
    assert float(jnp.max(jnp.abs(ef.residual["g"]))) == 0.0


def test_compressed_psum_single_device_is_identity_scale(key):
    """n=1 sanity inside shard_map: result equals the shard's own int8
    round-trip and the residual is exactly what the wire lost."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    g = {"w": jax.random.normal(key, (64,)) * 3.0}
    ef = init_error_feedback(g)
    mesh = Mesh(jax.devices()[:1], ("dp",))
    out, new_ef = shard_map(
        lambda gg, rr: compressed_psum(gg, "dp", rr),
        mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
    )(g, ef)
    q, scale = compress_int8(g["w"])
    rec = decompress_int8(q, scale)
    assert float(jnp.max(jnp.abs(out["w"] - rec))) < 1e-6
    assert float(jnp.max(jnp.abs(new_ef.residual["w"] - (g["w"] - rec)))) < 1e-6


_SUBPROCESS_PSUM = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import json
import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P
from repro.runtime.compression import (
    compress_int8, compressed_psum, init_error_feedback)

# two shards with a ~1000x magnitude ratio: the shard-scale mismatch
# that breaks the averaged-scale math
k0, k1 = jax.random.split(jax.random.PRNGKey(0))
g = jnp.concatenate([jax.random.normal(k0, (1, 128)) * 1e-3,
                     jax.random.normal(k1, (1, 128)) * 1.0], axis=0)  # (2, 128)
ref = jnp.mean(g, axis=0)                         # fp32 mean across "pods"

mesh = Mesh(jax.devices()[:2], ("dp",))

def fixed(gg, rr):
    out, new_ef = compressed_psum({"w": gg}, "dp", rr)
    return out["w"], new_ef

def legacy(gg):
    # the old math: per-shard scales, raw int8 sum, averaged scale
    q, scale = compress_int8(gg)
    summed = jax.lax.psum(q.astype(jnp.int32), "dp")
    scale_sum = jax.lax.psum(scale, "dp")
    n = jax.lax.psum(jnp.ones((), jnp.float32), "dp")
    return summed.astype(jnp.float32) * (scale_sum / n) / n

ef = init_error_feedback({"w": g})
out_fixed, new_ef = shard_map(
    fixed, mesh=mesh, in_specs=(P("dp"), P("dp")), out_specs=(P("dp"), P("dp")),
)(g, ef)
out_legacy = shard_map(
    legacy, mesh=mesh, in_specs=(P("dp"),), out_specs=P("dp"),
)(g)

# per-element bound for the fixed path: shared_scale / 2 (each shard's
# rounding error <= shared/2, averaged over n=2)
shared_scale = float(jnp.max(jnp.abs(g)) / 127.0)
bound = shared_scale / 2.0 + 1e-12
err_fixed = float(jnp.max(jnp.abs(out_fixed[0] - ref)))
err_legacy = float(jnp.max(jnp.abs(out_legacy[0] - ref)))
resid = jax.device_get(new_ef.residual["w"])
print(json.dumps({
    "bound": bound,
    "err_fixed": err_fixed,
    "err_legacy": err_legacy,
    "resid_finite": bool(jnp.all(jnp.isfinite(resid))),
}))
"""


def test_compressed_psum_mismatched_shard_scales_two_devices():
    """Two processes' worth of shards (2 host devices), 1000x apart in
    magnitude: the fixed all-reduce matches the fp32 mean within the
    int8 bound; the legacy averaged-scale math violates it by orders of
    magnitude (the demonstration the fix exists for)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS_PSUM],
                         capture_output=True, text=True, timeout=600, env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    p = json.loads(out.stdout.strip().splitlines()[-1])
    assert p["resid_finite"]
    assert p["err_fixed"] <= p["bound"], \
        f"fixed path error {p['err_fixed']} exceeds int8 bound {p['bound']}"
    assert p["err_legacy"] > 10 * p["bound"], \
        f"legacy math unexpectedly accurate ({p['err_legacy']} vs {p['bound']})"
