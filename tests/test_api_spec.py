"""RunSpec serialization contract (api/specs.py): property-based
JSON/dict round-trips over randomized specs, unknown-key rejection at
every nesting level, ``replace`` override semantics (sub-spec / dict /
dotted-path forms), and the explicit legacy precision mode."""
import json

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container without hypothesis: deterministic shim
    from _hypothesis_fallback import given, settings, st

from repro.api import (
    BenchSpec,
    CheckpointSpec,
    ModelSpec,
    PrecisionSpec,
    RankScheduleSpec,
    RunSpec,
    ServeSpec,
    ShardingSpec,
    SLOSpec,
    TrainSpec,
    WorkloadSpec,
)
from repro.core.precision import LEGACY, POLICIES, PrecisionPolicy, precision_policy

PRECISIONS = [LEGACY, *POLICIES]
SCHEDULES = [None, "static:16", "step:10=32,20=16", "energy:0.9,min=8,every=5"]
QUANTIZE = [None, "int8"]
ARCHS = ["smollm2-1.7b", "llama3.2-1b", "qwen1.5-0.5b"]


def _build_spec(arch_i, steps, lr, seed, prec_i, sched_i, quant_i, rank_i,
                telemetry, prefix_cache):
    """Deterministic spec from drawn scalars — the property-test
    generator shared by the round-trip cases."""
    return RunSpec(
        model=ModelSpec(arch=ARCHS[arch_i % len(ARCHS)], reduced=True,
                        rank=[None, 8, 32][rank_i % 3]),
        train=TrainSpec(steps=steps, lr=lr, seed=seed, telemetry=telemetry),
        precision=PrecisionSpec(mode=PRECISIONS[prec_i % len(PRECISIONS)]),
        rank=RankScheduleSpec(schedule=SCHEDULES[sched_i % len(SCHEDULES)]),
        serve=ServeSpec(quantize=QUANTIZE[quant_i % len(QUANTIZE)],
                        prefix_cache=prefix_cache,
                        request_timeout=[None, 64][seed % 2]),
        checkpoint=CheckpointSpec(directory=[None, "/tmp/x"][steps % 2]),
    )


@settings(max_examples=40, deadline=None)
@given(arch_i=st.integers(0, 10), steps=st.integers(1, 10_000),
       lr=st.floats(1e-6, 1.0), seed=st.integers(0, 2**31 - 1),
       prec_i=st.integers(0, 10), sched_i=st.integers(0, 10),
       quant_i=st.integers(0, 10), rank_i=st.integers(0, 10),
       telemetry=st.booleans(), prefix_cache=st.booleans())
def test_json_round_trip_bit_exact(arch_i, steps, lr, seed, prec_i, sched_i,
                                   quant_i, rank_i, telemetry, prefix_cache):
    spec = _build_spec(arch_i, steps, lr, seed, prec_i, sched_i, quant_i,
                       rank_i, telemetry, prefix_cache)
    text = spec.to_json()
    restored = RunSpec.from_json(text)
    assert restored == spec
    assert restored.to_json() == text            # bit-exact
    # dict round-trip too, and through an actual json encode/decode
    assert RunSpec.from_dict(json.loads(json.dumps(spec.to_dict()))) == spec


def test_unknown_keys_rejected_at_every_level():
    good = RunSpec().to_dict()
    with pytest.raises(ValueError, match="unknown key"):
        RunSpec.from_dict({**good, "extra": 1})
    bad_nested = {**good, "train": {**good["train"], "stepz": 3}}
    with pytest.raises(ValueError, match="TrainSpec: unknown key"):
        RunSpec.from_dict(bad_nested)
    with pytest.raises(TypeError):
        RunSpec.from_dict({**good, "serve": "paged"})   # not a dict


def test_value_validation_happens_on_deserialize():
    good = RunSpec().to_dict()
    with pytest.raises(ValueError, match="precision mode"):
        RunSpec.from_dict({**good, "precision": {"mode": "fp64"}})
    with pytest.raises(ValueError, match="rank schedule"):
        RunSpec.from_dict({**good, "rank": {"schedule": "bogus:1"}})
    with pytest.raises(ValueError, match="serve mode"):
        RunSpec.from_dict({**good, "serve": {**good["serve"], "mode": "warp"}})


def test_replace_subspec_dict_and_dotted_forms():
    spec = RunSpec(train=TrainSpec(steps=10))
    # sub-spec instance
    s1 = spec.replace(precision=PrecisionSpec("mixed"))
    assert s1.precision.mode == "mixed" and spec.precision.mode == LEGACY
    # dict merged into the existing sub-spec
    s2 = spec.replace(serve={"quantize": "int8"})
    assert s2.serve.quantize == "int8"
    assert s2.serve.page_size == spec.serve.page_size   # untouched fields kept
    # dotted leaf paths, several at once
    s3 = spec.replace(**{"train.steps": 77, "serve.rank": 8,
                         "checkpoint.directory": "/tmp/y"})
    assert (s3.train.steps, s3.serve.rank, s3.checkpoint.directory) == \
        (77, 8, "/tmp/y")
    assert spec.train.steps == 10                       # original frozen
    # dict + dotted on the same sub-spec compose
    s4 = spec.replace(serve={"slots": 8}, **{"serve.gen": 5})
    assert (s4.serve.slots, s4.serve.gen) == (8, 5)


def test_replace_rejects_unknown_and_mistyped():
    spec = RunSpec()
    with pytest.raises(ValueError, match="unknown field"):
        spec.replace(bogus=1)
    with pytest.raises(ValueError, match="unknown field"):
        spec.replace(**{"train.stepz": 3})
    with pytest.raises(TypeError, match="TrainSpec"):
        spec.replace(train=3)
    with pytest.raises(ValueError, match="unknown field"):
        spec.replace(**{"bogus.steps": 3})


def test_model_spec_overrides_reach_config():
    cfg = ModelSpec("smollm2-1.7b", reduced=True, rank=8).config()
    assert cfg.sct.rank == 8
    dense = ModelSpec("smollm2-1.7b", reduced=True, spectral_mlp=False).config()
    assert dense.sct.spectral_mlp is False
    plain = ModelSpec("smollm2-1.7b", reduced=True).config()
    assert plain.sct.rank != 8 and plain.sct.spectral_mlp is True


def test_precision_spec_legacy_is_explicit():
    """The legacy path is a named mode, not a sentinel: the spec says
    'legacy', the optimizer-facing policy is None, and the effective
    policy resolves to the config dtype with no scaling."""
    from repro.core.precision import effective_policy

    legacy = PrecisionSpec()                 # the default
    assert legacy.mode == LEGACY
    assert legacy.policy() is None
    assert precision_policy(LEGACY) is None  # name and sentinel agree

    cfg = ModelSpec("smollm2-1.7b", reduced=True).config()
    eff = effective_policy(cfg, LEGACY)
    assert isinstance(eff, PrecisionPolicy)
    assert eff.name == LEGACY
    assert eff.compute_dtype == cfg.dtype
    assert eff.accum_dtype == "float32"
    assert not eff.loss_scaling
    # presets pass through untouched
    assert effective_policy(cfg, "mixed") is POLICIES["mixed"]
    assert PrecisionSpec("mixed").policy() is POLICIES["mixed"]


def test_serve_spec_paged_config_geometry():
    sv = ServeSpec(page_size=8, num_pages=20, slots=3, pages_per_seq=5)
    pcfg = sv.paged_config()
    assert (pcfg.page_size, pcfg.num_pages, pcfg.max_slots,
            pcfg.max_pages_per_seq) == (8, 20, 3, 5)
    assert pcfg.max_seq == 40


def test_serve_spec_slo_fields_round_trip():
    sv = ServeSpec(scheduler="slo", shed=False, tenant="acme", priority=2,
                   default_deadline=40, request_timeout=64)
    restored = ServeSpec.from_json(sv.to_json())
    assert restored == sv
    assert restored.to_json() == sv.to_json()
    assert (restored.scheduler, restored.tenant, restored.priority,
            restored.default_deadline) == ("slo", "acme", 2, 40)
    # the submit-time deadline default prefers default_deadline, then
    # falls back to the pre-SLO request_timeout flag
    assert sv.effective_deadline == 40
    assert ServeSpec(request_timeout=64).effective_deadline == 64
    assert ServeSpec().effective_deadline is None


def test_serve_spec_slo_field_validation():
    with pytest.raises(ValueError, match="scheduler"):
        ServeSpec(scheduler="lifo")
    with pytest.raises(ValueError, match="priority"):
        ServeSpec(priority=-1)
    with pytest.raises(ValueError, match="tenant"):
        ServeSpec(tenant="")
    good = RunSpec().to_dict()
    with pytest.raises(ValueError, match="ServeSpec: unknown key"):
        RunSpec.from_dict({**good, "serve": {**good["serve"], "tennant": "x"}})
    with pytest.raises(ValueError, match="scheduler"):
        RunSpec.from_dict({**good,
                           "serve": {**good["serve"], "scheduler": "edf"}})


@settings(max_examples=40, deadline=None)
@given(arrival_i=st.integers(0, 10), rate=st.floats(0.05, 4.0),
       requests=st.integers(1, 200), seed=st.integers(0, 2**31 - 1),
       tenants_i=st.integers(0, 10), prefix=st.integers(0, 32),
       deadlines_i=st.integers(0, 10), shed=st.booleans(),
       overloads_i=st.integers(0, 10), scheds_i=st.integers(0, 10))
def test_bench_spec_round_trip_bit_exact(arrival_i, rate, requests, seed,
                                         tenants_i, prefix, deadlines_i,
                                         shed, overloads_i, scheds_i):
    bench = BenchSpec(
        workload=WorkloadSpec(
            arrival=["poisson", "onoff", "fixed"][arrival_i % 3],
            rate=rate, requests=requests, seed=seed,
            tenants=["1", "2,1", "1,1,1"][tenants_i % 3],
            shared_prefix=prefix),
        slo=SLOSpec(deadlines=[None, "64", "0=32,1=96"][deadlines_i % 3],
                    shed=shed),
        overloads=["1", "1,2", "1,1.5,2"][overloads_i % 3],
        schedulers=["fifo", "slo", "fifo,slo"][scheds_i % 3],
    )
    text = bench.to_json()
    restored = BenchSpec.from_json(text)
    assert restored == bench
    assert restored.to_json() == text
    assert BenchSpec.from_dict(json.loads(json.dumps(bench.to_dict()))) == bench


def test_bench_spec_unknown_keys_rejected_at_every_level():
    good = BenchSpec().to_dict()
    with pytest.raises(ValueError, match="unknown key"):
        BenchSpec.from_dict({**good, "extra": 1})
    with pytest.raises(ValueError, match="WorkloadSpec: unknown key"):
        BenchSpec.from_dict(
            {**good, "workload": {**good["workload"], "ratez": 1.0}})
    with pytest.raises(ValueError, match="SLOSpec: unknown key"):
        BenchSpec.from_dict({**good, "slo": {**good["slo"], "ttf": 4}})


def test_bench_spec_validation_and_replace():
    with pytest.raises(ValueError, match="arrival"):
        WorkloadSpec(arrival="bursty")
    with pytest.raises(ValueError, match="rate"):
        WorkloadSpec(rate=0)
    with pytest.raises(ValueError, match="tenants"):
        WorkloadSpec(tenants="1,-2")
    with pytest.raises(ValueError, match="deadlines"):
        SLOSpec(deadlines="fast")
    with pytest.raises(ValueError, match="scheduler"):
        BenchSpec(schedulers="fifo,edf")
    with pytest.raises(ValueError, match="overloads"):
        BenchSpec(overloads="")
    # replace: dict-merge and dotted forms, same semantics as RunSpec
    bench = BenchSpec()
    b2 = bench.replace(workload={"rate": 2.0}, **{"slo.deadlines": "32"})
    assert b2.workload.rate == 2.0
    assert b2.workload.requests == bench.workload.requests
    assert b2.slo.deadlines == "32"
    assert bench.slo.deadlines is None          # original frozen
    with pytest.raises(ValueError, match="unknown field"):
        bench.replace(**{"workload.ratez": 3})


def test_slo_spec_deadline_semantics():
    assert SLOSpec().deadline_for(0) is None
    flat = SLOSpec(deadlines="64")
    assert flat.deadline_for(0) == 64 and flat.deadline_for(3) == 64
    per = SLOSpec(deadlines="0=32,1=96")
    assert per.deadline_for(0) == 32 and per.deadline_for(1) == 96
    # classes beyond the map inherit the lowest-urgency entry
    assert per.deadline_for(5) == 96
    assert per.deadline_map() == {0: 32, 1: 96}


def test_workload_spec_weight_parsing():
    assert WorkloadSpec(tenants="2,1").tenant_weights() == [2.0, 1.0]
    assert WorkloadSpec(priority_mix="1,1,2").priority_weights() == \
        [1.0, 1.0, 2.0]
    assert BenchSpec(overloads="1,1.5,2").overload_factors() == [1.0, 1.5, 2.0]
    assert BenchSpec(ranks="8,16").rank_arms() == [8, 16]
    with pytest.raises(ValueError, match="ranks"):
        BenchSpec(ranks="8,x")


def test_sharding_spec_single_device_mesh_is_none():
    cfg = ModelSpec("smollm2-1.7b", reduced=True).config()
    assert ShardingSpec().mesh(cfg) is None              # 1 visible device
    assert ShardingSpec(data=1, model=1).mesh(cfg) is None
    with pytest.raises(ValueError, match="devices"):
        ShardingSpec(data=4, model=2).mesh(cfg)


def test_serve_spec_speculative_validation():
    """Speculative-decoding knobs: ladder grammar checked at spec
    construction, paged-mode and prefix-cache exclusivity enforced, and
    the ladder round-trips through JSON like every other field."""
    good = ServeSpec(speculative_rank="8,16", draft_tokens=3)
    assert good.speculative_ladder() == [8, 16]
    assert ServeSpec().speculative_ladder() == []    # off by default
    run = RunSpec(serve=good)
    assert RunSpec.from_json(run.to_json()) == run
    for bad in ("16,8", "", "a", "0"):               # decreasing/empty/junk
        with pytest.raises(ValueError):
            ServeSpec(speculative_rank=bad)
    with pytest.raises(ValueError):
        ServeSpec(speculative_rank="8", prefix_cache=True)
    with pytest.raises(ValueError):
        ServeSpec(mode="static", speculative_rank="8")
    with pytest.raises(ValueError):
        ServeSpec(draft_tokens=0)
