"""Fuzzed continuous-batching invariants: random
admit/append/finish/evict schedules driven through the real scheduler
API, asserting after every transition that pages never double-book,
free-list + held pages always partition the pool exactly, and no page
is aliased across sequences. Plus direct PagePool allocator fuzzing."""
import random as pyrandom

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container without hypothesis: deterministic shim
    from _hypothesis_fallback import given, settings, st

from repro.serving import PagedCacheConfig, PagePool, Request
from repro.serving.scheduler import ContinuousBatchingScheduler

EOS = 7


def _full_invariants(sched: ContinuousBatchingScheduler, pcfg: PagedCacheConfig):
    sched.check_invariants()
    held = [p for s in sched.active.values() for p in s.pages]
    # free-list + held pages partition the pool exactly (no leak, no
    # double-count)
    assert sched.pool.free_count + len(held) == pcfg.num_pages
    # no cross-sequence page aliasing, null page never handed out
    owner = {}
    for slot, seq in sched.active.items():
        for p in seq.pages:
            assert p != pcfg.null_page
            assert p not in owner, f"page {p} aliased by slots {owner[p]} and {slot}"
            owner[p] = slot
    # block-table rows of *free* slots hold only the null page
    for slot in sched._free_slots:
        assert (sched.block_table[slot] == pcfg.null_page).all()
        assert sched.seq_lens[slot] == 0


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    page_size=st.integers(2, 8),
    slots=st.integers(1, 6),
    pool_pages=st.integers(8, 40),
)
def test_scheduler_random_schedule_invariants(seed, page_size, slots, pool_pages):
    rng = pyrandom.Random(seed)
    mpps = max(2, min(8, pool_pages // 2))
    pcfg = PagedCacheConfig(page_size=page_size, num_pages=pool_pages,
                            max_slots=slots, max_pages_per_seq=mpps)
    budget = rng.choice([None, 2 * page_size, 6 * page_size])
    sched = ContinuousBatchingScheduler(pcfg, prefill_token_budget=budget)

    cap = mpps * page_size
    reqs = []
    for i in range(rng.randint(1, 16)):
        max_new = rng.randint(1, cap - 1)
        plen = rng.randint(1, cap - max_new)
        reqs.append(Request(
            rid=i,
            prompt=np.zeros((plen,), dtype=np.int32),
            max_new_tokens=max_new,
            arrival=rng.randint(0, 8),
            eos_id=EOS if rng.random() < 0.5 else None,
        ))
    reqs = [r for r in reqs if pcfg.pages_for(r.max_total_len) <= pcfg.num_pages]
    pending = sorted(reqs, key=lambda r: r.arrival)

    clock = 0
    guard = 0
    while pending or sched.has_work:
        guard += 1
        assert guard < 5000, "scheduler failed to drain (live/deadlock)"
        while pending and pending[0].arrival <= clock:
            sched.submit(pending.pop(0))
        admitted = sched.admit()
        _full_invariants(sched, pcfg)
        for seq in admitted:                       # simulated prefill token
            tok = EOS if (seq.request.eos_id and rng.random() < 0.15) else 1
            sched.on_prefill_token(seq.slot, tok)
            _full_invariants(sched, pcfg)
        if sched.active:
            sched.ensure_append_capacity()         # page-boundary appends
            _full_invariants(sched, pcfg)
            for slot in list(sched.active):        # decode + random finishes
                seq = sched.active[slot]
                tok = EOS if (seq.request.eos_id and rng.random() < 0.2) else 1
                sched.on_token(slot, tok)
                _full_invariants(sched, pcfg)
        clock += 1

    # fully drained: every page back on the free list, every slot free
    assert sched.pool.allocated_count == 0
    assert sched.pool.free_count == pcfg.num_pages
    assert len(sched.finished) == len(reqs)
    assert not sched.active and len(sched._free_slots) == slots
    # every finished sequence respected its bounds
    for seq in sched.finished:
        assert len(seq.generated) <= seq.request.max_new_tokens
        if seq.request.eos_id is None:
            assert len(seq.generated) == seq.request.max_new_tokens


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), pool_pages=st.integers(1, 32))
def test_pagepool_random_alloc_free(seed, pool_pages):
    """Direct allocator fuzz against a model: counts always sum to pool
    size, no page handed out twice, double-free always raises."""
    rng = pyrandom.Random(seed)
    pool = PagePool(pool_pages)
    held = []
    for _ in range(200):
        assert pool.free_count + pool.allocated_count == pool_pages
        assert len(set(held)) == len(held)
        if held and rng.random() < 0.45:
            n = rng.randint(1, len(held))
            back, held = held[:n], held[n:]
            pool.free(back)
            with pytest.raises(RuntimeError):
                pool.free([back[0]])               # double free always raises
            # the failed double-free must not have changed state
            assert pool.free_count + pool.allocated_count == pool_pages
        else:
            want = rng.randint(1, max(1, pool_pages // 2))
            if want > pool.free_count:
                with pytest.raises(RuntimeError):
                    pool.alloc(want)               # exhaustion raises cleanly
            else:
                held += pool.alloc(want)
    pool.free(held)
    assert pool.free_count == pool_pages and pool.allocated_count == 0


def test_pagepool_null_page_never_allocated():
    pcfg = PagedCacheConfig(page_size=4, num_pages=6, max_slots=2,
                            max_pages_per_seq=3)
    pool = PagePool(pcfg.num_pages)
    pages = pool.alloc(pcfg.num_pages)
    assert pcfg.null_page not in pages
    assert sorted(pages) == list(range(pcfg.num_pages))
