"""Fuzzed continuous-batching invariants: random
admit/chunk-prefill/append/finish/evict/cancel schedules driven through
the real scheduler API — with and without prefix sharing — asserting
after every transition that refcounts account for every holder, pages
never leak or double-book, no write-targeted page stays shared (COW
forks fire), and pool accounting is exact. Plus direct PagePool
allocator fuzzing of the refcount (alloc/share/release) state machine.
"""
import random as pyrandom

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container without hypothesis: deterministic shim
    from _hypothesis_fallback import given, settings, st

from repro.serving import PagedCacheConfig, PagePool, Request, StreamingConfig
from repro.serving.scheduler import ContinuousBatchingScheduler
from repro.serving.streaming import resident_cap

EOS = 7


def _full_invariants(sched: ContinuousBatchingScheduler, pcfg: PagedCacheConfig):
    sched.check_invariants()
    # block-table rows of *free* slots hold only the null page
    for slot in sched._free_slots:
        assert (sched.block_table[slot] == pcfg.null_page).all()
        assert sched.seq_lens[slot] == 0
    # without a prefix cache, pages never alias across sequences
    if sched.prefix_cache is None:
        owner = {}
        for slot, seq in sched.active.items():
            for p in seq.pages:
                assert p != pcfg.null_page
                assert p not in owner, f"page {p} aliased by {owner[p]} and {slot}"
                owner[p] = slot


def _rand_requests(rng, pcfg, n_max=16, shared_pool=None):
    cap = pcfg.max_pages_per_seq * pcfg.page_size
    reqs = []
    for i in range(rng.randint(1, n_max)):
        max_new = rng.randint(1, cap - 1)
        plen = rng.randint(1, cap - max_new)
        if shared_pool is not None and rng.random() < 0.6:
            # draw the prompt head from a small pool of shared prefixes
            # so the index actually hits
            head = shared_pool[rng.randrange(len(shared_pool))][:plen]
            tail = rng.getrandbits(16)
            prompt = np.concatenate(
                [head, np.full((max(plen - len(head), 0),), tail % 97, np.int32)])
            prompt = prompt[:plen]
        else:
            prompt = np.asarray([rng.randint(0, 96) for _ in range(plen)], np.int32)
        reqs.append(Request(
            rid=i,
            prompt=prompt.astype(np.int32),
            max_new_tokens=max_new,
            arrival=rng.randint(0, 8),
            eos_id=EOS if rng.random() < 0.5 else None,
            deadline=rng.randint(4, 40) if rng.random() < 0.25 else None,
        ))
    return [r for r in reqs if pcfg.pages_for(r.max_total_len) <= pcfg.num_pages]


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    page_size=st.integers(2, 8),
    slots=st.integers(1, 6),
    pool_pages=st.integers(8, 40),
    prefix_sharing=st.booleans(),
)
def test_scheduler_random_schedule_invariants(seed, page_size, slots, pool_pages,
                                              prefix_sharing):
    rng = pyrandom.Random(seed)
    mpps = max(2, min(8, pool_pages // 2))
    pcfg = PagedCacheConfig(page_size=page_size, num_pages=pool_pages,
                            max_slots=slots, max_pages_per_seq=mpps)
    budget = rng.choice([None, 2 * page_size, 6 * page_size])
    sched = ContinuousBatchingScheduler(pcfg, prefill_token_budget=budget,
                                        prefix_sharing=prefix_sharing)

    shared_pool = [np.asarray([rng.randint(0, 96)
                               for _ in range(mpps * page_size)], np.int32)
                   for _ in range(2)] if prefix_sharing else None
    reqs = _rand_requests(rng, pcfg, shared_pool=shared_pool)
    pending = sorted(reqs, key=lambda r: r.arrival)
    submitted = {r.rid for r in reqs}

    drained = []
    clock = 0
    guard = 0
    while pending or sched.has_work:
        guard += 1
        assert guard < 5000, "scheduler failed to drain (live/deadlock)"
        while pending and pending[0].arrival <= clock:
            sched.submit(pending.pop(0))
        sched.expire_deadlines(clock)
        _full_invariants(sched, pcfg)
        sched.admit()
        _full_invariants(sched, pcfg)
        for seq in sched.prefilling():               # chunked prefill: advance
            plen = seq.request.prompt_len            # by a random chunk
            c = rng.randint(1, max(1, plen - seq.prefill_pos))
            seq.prefill_pos = min(plen, seq.prefill_pos + c)
            if seq.prefill_pos == plen:
                sched.finish_prefill(seq.slot)
                tok = EOS if (seq.request.eos_id and rng.random() < 0.15) else 1
                sched.on_prefill_token(seq.slot, tok)
            _full_invariants(sched, pcfg)
        if rng.random() < 0.1 and sched.active:      # random mid-flight cancel
            sched.cancel(rng.choice([s.request.rid for s in sched.active.values()]))
            _full_invariants(sched, pcfg)
        decoding = [s for s in sched.active.values() if s.status == "decoding"]
        if decoding:
            sched.ensure_append_capacity()           # page-boundary appends + COW
            _full_invariants(sched, pcfg)
            for seq in decoding:
                if seq.slot not in sched.active:     # cancelled above
                    continue
                # after capacity assurance no append target is shared —
                # a decode write can never reach a page another holder
                # still references
                tgt = seq.pages[seq.seq_len // pcfg.page_size]
                assert sched.pool.refcount(tgt) >= 1
                assert not sched.pool.is_shared(tgt), \
                    f"append target page {tgt} still shared after COW pass"
            for seq in list(decoding):
                if seq.slot not in sched.active:     # cancelled above
                    continue
                tok = EOS if (seq.request.eos_id and rng.random() < 0.2) else 1
                sched.on_token(seq.slot, tok)
                _full_invariants(sched, pcfg)
        drained += sched.drain_finished()
        clock += 1

    # fully drained: every remaining page belongs to the prefix index,
    # every slot free, every submitted rid surfaced exactly once
    cache_pages = len(sched.prefix_cache.pages) if sched.prefix_cache else 0
    assert sched.pool.allocated_count == cache_pages
    assert sched.pool.free_count == pcfg.num_pages - cache_pages
    assert not sched.active and len(sched._free_slots) == slots
    assert not sched.drain_finished()
    assert sorted(s.request.rid for s in drained) == sorted(submitted)
    assert sched.finished_count == len(submitted)
    for seq in drained:
        assert len(seq.generated) <= seq.request.max_new_tokens
        if seq.request.eos_id is None and seq.status == "finished":
            assert len(seq.generated) == seq.request.max_new_tokens
    # the index fully evicts on demand once nothing references its pages
    if sched.prefix_cache is not None:
        sched.prefix_cache.evict(pcfg.num_pages)
        assert sched.pool.allocated_count == 0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), pool_pages=st.integers(1, 32))
def test_pagepool_random_alloc_share_release(seed, pool_pages):
    """Direct allocator fuzz against a reference refcount model: counts
    always partition the pool, no page handed out twice, refcounts
    exact, double-release always raises and never mutates state."""
    rng = pyrandom.Random(seed)
    pool = PagePool(pool_pages)
    refs = {}                                   # model: page -> refcount
    for _ in range(300):
        assert pool.free_count + pool.allocated_count == pool_pages
        assert pool.allocated_count == len(refs)
        for p, n in refs.items():
            assert pool.refcount(p) == n
            assert pool.is_shared(p) == (n > 1)
        op = rng.random()
        if refs and op < 0.3:                   # release one ref somewhere
            p = rng.choice(list(refs))
            pool.release([p])
            refs[p] -= 1
            if refs[p] == 0:
                del refs[p]
                with pytest.raises(RuntimeError):
                    pool.release([p])           # double free always raises
                assert pool.free_count + pool.allocated_count == pool_pages
        elif refs and op < 0.55:                # share (refcount bump)
            p = rng.choice(list(refs))
            pool.share([p])
            refs[p] += 1
        elif op < 0.6 and not refs:
            with pytest.raises(RuntimeError):
                pool.share([0])                 # share of unallocated raises
        else:
            want = rng.randint(1, max(1, pool_pages // 2))
            if want > pool.free_count:
                with pytest.raises(RuntimeError):
                    pool.alloc(want)            # exhaustion raises cleanly
            else:
                for p in pool.alloc(want):
                    assert p not in refs        # never hand out a held page
                    refs[p] = 1
    for p, n in list(refs.items()):
        pool.release([p] * n)
    assert pool.free_count == pool_pages and pool.allocated_count == 0


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    page_size=st.integers(2, 6),
    slots=st.integers(1, 4),
    sink=st.integers(1, 2),
    window=st.integers(1, 3),
)
def test_streaming_scheduler_random_invariants(seed, page_size, slots,
                                               sink, window):
    """Streaming state machine fuzz: random prefill-chunk and decode
    lengths repeatedly crossing window/eviction boundaries, with random
    mid-flight cancels. After every transition: sinks are never
    evicted (the pinned head of the page list is stable), residency
    never exceeds sink+window+1 pages, the block-table row stays dense,
    refcounts and pins balance; at drain the pool is empty and every
    pin is unwound."""
    rng = pyrandom.Random(seed)
    scfg = StreamingConfig(sink_pages=sink, window_pages=window)
    cap = resident_cap(scfg)
    pool_pages = cap * slots + rng.randint(0, 4)
    pcfg = PagedCacheConfig(page_size=page_size, num_pages=pool_pages,
                            max_slots=slots, max_pages_per_seq=cap)
    sched = ContinuousBatchingScheduler(pcfg, streaming=scfg)

    logical_cap = pool_pages * page_size        # non-streaming capacity
    reqs = []
    for i in range(rng.randint(2, 10)):
        plen = rng.randint(1, 3 * cap * page_size)
        # decode lengths from just-under-a-page to several windows past
        # the pool's whole capacity — the boundary-crossing coverage
        max_new = rng.randint(1, 2 * logical_cap)
        reqs.append(Request(
            rid=i, prompt=np.asarray([rng.randint(0, 96)
                                      for _ in range(plen)], np.int32),
            max_new_tokens=max_new, arrival=rng.randint(0, 6),
            eos_id=EOS if rng.random() < 0.4 else None))
    pending = sorted(reqs, key=lambda r: r.arrival)
    submitted = {r.rid for r in reqs}
    sinks = {}                                  # rid -> pinned sink ids

    def _streaming_invariants():
        sched.check_invariants()
        for seq in sched.active.values():
            assert len(seq.pages) <= cap
            if seq.pinned:
                prev = sinks.setdefault(seq.request.rid, list(seq.pinned))
                # pins only ever extend (lazily, page by page) — a sink,
                # once pinned, stays at its position for the seq's life
                assert seq.pinned[:len(prev)] == prev
                sinks[seq.request.rid] = list(seq.pinned)

    drained, clock, guard = [], 0, 0
    while pending or sched.has_work:
        guard += 1
        assert guard < 20000, "streaming scheduler failed to drain"
        while pending and pending[0].arrival <= clock:
            sched.submit(pending.pop(0))
        sched.admit()
        _streaming_invariants()
        for seq in sched.prefilling():
            plen = seq.request.prompt_len
            c = rng.randint(1, max(1, min(window * page_size,
                                          plen - seq.prefill_pos)))
            sched.stream_prepare_chunk(seq.slot, c)
            seq.prefill_pos += c
            if seq.prefill_pos == plen:
                sched.finish_prefill(seq.slot)
                tok = EOS if (seq.request.eos_id and rng.random() < 0.1) else 1
                sched.on_prefill_token(seq.slot, tok)
            _streaming_invariants()
        if rng.random() < 0.08 and sched.active:
            sched.cancel(rng.choice(
                [s.request.rid for s in sched.active.values()]))
            _streaming_invariants()
        decoding = [s for s in sched.active.values()
                    if s.status == "decoding"]
        if decoding:
            for seq in decoding:
                if seq.slot in sched.active:
                    sched.stream_maintain(seq.slot, 1)
            sched.ensure_append_capacity()
            _streaming_invariants()
            for seq in list(decoding):
                if seq.slot not in sched.active:
                    continue
                tok = EOS if (seq.request.eos_id and rng.random() < 0.1) else 1
                sched.on_token(seq.slot, tok)
                _streaming_invariants()
        drained += sched.drain_finished()
        clock += 1

    assert sched.pool.allocated_count == 0 and not sched.active
    assert sorted(s.request.rid for s in drained) == sorted(submitted)
    for p in range(pool_pages):                 # every pin unwound
        assert sched.pool.pin_count(p) == 0


def test_pagepool_null_page_never_allocated():
    pcfg = PagedCacheConfig(page_size=4, num_pages=6, max_slots=2,
                            max_pages_per_seq=3)
    pool = PagePool(pcfg.num_pages)
    pages = pool.alloc(pcfg.num_pages)
    assert pcfg.null_page not in pages
    assert sorted(pages) == list(range(pcfg.num_pages))


def test_pagepool_failed_release_is_atomic():
    """A release list containing any bad page must not change state."""
    pool = PagePool(4)
    a = pool.alloc(2)
    with pytest.raises(RuntimeError):
        pool.release([a[0], 99])
    assert pool.refcount(a[0]) == 1 and pool.allocated_count == 2
