"""Differential suite for the paged flash-decode Pallas kernels
(kernels/paged_decode.py) against the jnp gather-then-attend oracles
(kernels/paged_ref.py), built on the kernels/testing.py harness.

Fuzz axes: non-tile-multiple head dims, odd page sizes, ragged page
occupancy (empty slots, page-boundary lengths), shuffled physical pages
with null-page tails, MQA/grouped/MHA head layouts, and absorbed MLA.
The end-to-end leg asserts full ServingEngine.run greedy decode through
the kernels is token-for-token identical to the static-cache oracle —
the same contract test_decode_consistency.py pins for the engine itself.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_decode import (
    paged_gqa_decode_pallas,
    paged_gqa_decode_cold_pallas,
    paged_mla_decode_pallas,
    paged_mla_decode_cold_pallas,
    paged_kernel_enabled,
)
from repro.kernels.paged_ref import paged_gqa_decode_ref, paged_mla_decode_ref
from repro.serving.quantize import dequantize_kv_pages, quantize_kv_pages
from repro.kernels.testing import (
    assert_kernel_matches,
    forced_interpret,
    make_block_table,
    ragged_seq_lens,
)


def _paged_state(key, b, n_pages_per_seq, num_pages, page, feature, dtype,
                 seed=0):
    """Pools + shuffled block table + ragged lengths for one fuzz case.
    The pool is dense random noise including the null page row — anything
    the mask lets through shows up as a mismatch against the oracle."""
    ks = jax.random.split(key, len(feature) + 1)
    pools = [jax.random.normal(k, (num_pages + 1, page, *f), dtype)
             for k, f in zip(ks, feature)]
    seq_lens = ragged_seq_lens(b, page * n_pages_per_seq - 1, page, seed)
    block_table = make_block_table(b, n_pages_per_seq, num_pages, seq_lens,
                                   page, seed)
    return pools, block_table, seq_lens


# b, kvh, rep, hd, page, n_pages_per_seq — covers MQA (kvh=1), grouped,
# MHA (rep=1), non-tile head dims (20/48/100), odd page sizes (3).
GQA_CASES = [
    (4, 2, 3, 64, 4, 6),
    (2, 1, 4, 20, 3, 5),
    (2, 4, 1, 48, 8, 4),
    (4, 2, 2, 100, 4, 6),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,kvh,rep,hd,page,n", GQA_CASES)
def test_paged_gqa_decode_vs_oracle(b, kvh, rep, hd, page, n, dtype, key):
    num_pages = b * n + 3
    (k_pool, v_pool), bt, sl = _paged_state(
        key, b, n, num_pages, page, [(kvh, hd), (kvh, hd)], dtype)
    q = jax.random.normal(jax.random.fold_in(key, 7), (b, kvh, rep, hd), dtype)
    assert_kernel_matches(
        paged_gqa_decode_pallas, paged_gqa_decode_ref,
        (q, k_pool, v_pool, bt, sl), label=f"gqa hd={hd} page={page}")


# b, h, latent, rope_d, page, n_pages_per_seq — non-tile latent dims.
MLA_CASES = [
    (2, 4, 32, 16, 4, 6),
    (3, 2, 24, 12, 3, 5),
    (2, 8, 100, 20, 8, 4),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,lat,rope,page,n", MLA_CASES)
def test_paged_mla_decode_vs_oracle(b, h, lat, rope, page, n, dtype, key):
    num_pages = b * n + 3
    (ckv_pool, kr_pool), bt, sl = _paged_state(
        key, b, n, num_pages, page, [(lat,), (rope,)], dtype)
    ks = jax.random.split(jax.random.fold_in(key, 7))
    q_lat = jax.random.normal(ks[0], (b, h, lat), dtype)
    q_rope = jax.random.normal(ks[1], (b, h, rope), dtype)
    scale = 1.0 / float(48 + rope) ** 0.5     # pre-absorption head dim
    assert_kernel_matches(
        lambda *a: paged_mla_decode_pallas(*a, scale=scale),
        lambda *a: paged_mla_decode_ref(*a, scale=scale),
        (q_lat, q_rope, ckv_pool, kr_pool, bt, sl),
        label=f"mla lat={lat} page={page}")


def test_paged_gqa_forced_interpret_matches(key):
    """Explicit SCT_INTERPRET=1 leg — independent of whatever mode the
    surrounding CI matrix leg runs, the interpret path must agree."""
    b, kvh, rep, hd, page, n = 2, 2, 2, 64, 4, 4
    (k_pool, v_pool), bt, sl = _paged_state(
        key, b, n, b * n + 2, page, [(kvh, hd), (kvh, hd)], jnp.float32)
    q = jax.random.normal(jax.random.fold_in(key, 7), (b, kvh, rep, hd))
    with forced_interpret():
        assert_kernel_matches(paged_gqa_decode_pallas, paged_gqa_decode_ref,
                              (q, k_pool, v_pool, bt, sl))


def test_paged_all_slots_empty_is_finite(key):
    """Inactive slots (seq_lens=0, null-page tables) attend over the one
    position the convention leaves valid — output must stay finite, not
    NaN from an all-masked softmax."""
    b, kvh, rep, hd, page, n = 2, 1, 2, 32, 4, 3
    num_pages = 8
    k_pool = jax.random.normal(key, (num_pages + 1, page, kvh, hd))
    v_pool = jax.random.normal(jax.random.fold_in(key, 1),
                               (num_pages + 1, page, kvh, hd))
    bt = jnp.full((b, n), num_pages, jnp.int32)       # all null
    sl = jnp.zeros((b,), jnp.int32)
    q = jax.random.normal(jax.random.fold_in(key, 2), (b, kvh, rep, hd))
    out = paged_gqa_decode_pallas(q, k_pool, v_pool, bt, sl)
    assert bool(jnp.all(jnp.isfinite(out)))
    ref = paged_gqa_decode_ref(q, k_pool, v_pool, bt, sl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-5, atol=5e-5)


def test_paged_kernel_gate_parses():
    import os

    assert paged_kernel_enabled()                     # default: on
    prev = os.environ.get("SCT_PAGED_KERNEL")
    try:
        os.environ["SCT_PAGED_KERNEL"] = "0"
        assert not paged_kernel_enabled()
        os.environ["SCT_PAGED_KERNEL"] = "yes"
        assert paged_kernel_enabled()
        os.environ["SCT_PAGED_KERNEL"] = "maybe"
        with pytest.raises(ValueError):
            paged_kernel_enabled()
    finally:
        if prev is None:
            os.environ.pop("SCT_PAGED_KERNEL", None)
        else:
            os.environ["SCT_PAGED_KERNEL"] = prev


# --------------------------------------------------------------- cold-KV --

def _cold_shadow(key, hot):
    """Int8 shadow pool quantized from noise *independent* of the hot
    pool, plus its dequantized expansion. Because the two tiers carry
    uncorrelated values, a kernel that reads the wrong tier for any
    page mismatches by O(1), not by quantization error."""
    src = jax.random.normal(key, hot.shape, jnp.float32)
    qt = quantize_kv_pages(src, token_axis=1)
    return qt["q8"], qt["scale"], dequantize_kv_pages(qt, token_axis=1)


@pytest.mark.parametrize("p_cold", [0.0, 0.5, 1.0])
@pytest.mark.parametrize("b,kvh,rep,hd,page,n", [(4, 2, 3, 64, 4, 6),
                                                 (2, 1, 4, 20, 3, 5)])
def test_paged_gqa_cold_decode_vs_oracle(b, kvh, rep, hd, page, n, p_cold,
                                         key):
    """Cold-aware GQA kernel vs the plain oracle run on a pool whose
    flagged pages are replaced by the dequantized shadow — per-page
    tier selection, in-register dequant, and the all-hot / all-cold
    edges in one sweep."""
    num_pages = b * n + 3
    (k_pool, v_pool), bt, sl = _paged_state(
        key, b, n, num_pages, page, [(kvh, hd), (kvh, hd)], jnp.float32)
    kq, ksc, k_deq = _cold_shadow(jax.random.fold_in(key, 11), k_pool)
    vq, vsc, v_deq = _cold_shadow(jax.random.fold_in(key, 12), v_pool)
    cold = jax.random.bernoulli(jax.random.fold_in(key, 13), p_cold,
                                (num_pages + 1,)).astype(jnp.int32)
    sel = cold.astype(bool)[:, None, None, None]
    q = jax.random.normal(jax.random.fold_in(key, 7), (b, kvh, rep, hd))
    assert_kernel_matches(
        paged_gqa_decode_cold_pallas, paged_gqa_decode_ref,
        (q, k_pool, v_pool, kq, ksc, vq, vsc, bt, sl, cold),
        ref_args=(q, jnp.where(sel, k_deq, k_pool),
                  jnp.where(sel, v_deq, v_pool), bt, sl),
        label=f"gqa-cold hd={hd} page={page} p={p_cold}")


@pytest.mark.parametrize("p_cold", [0.0, 0.5, 1.0])
@pytest.mark.parametrize("b,h,lat,rope,page,n", [(2, 4, 32, 16, 4, 6),
                                                 (3, 2, 24, 12, 3, 5)])
def test_paged_mla_cold_decode_vs_oracle(b, h, lat, rope, page, n, p_cold,
                                         key):
    """Cold-aware absorbed-MLA kernel vs the plain oracle on the
    tier-substituted latent/rope pools."""
    num_pages = b * n + 3
    (ckv_pool, kr_pool), bt, sl = _paged_state(
        key, b, n, num_pages, page, [(lat,), (rope,)], jnp.float32)
    cq, csc, ckv_deq = _cold_shadow(jax.random.fold_in(key, 11), ckv_pool)
    rq, rsc, kr_deq = _cold_shadow(jax.random.fold_in(key, 12), kr_pool)
    cold = jax.random.bernoulli(jax.random.fold_in(key, 13), p_cold,
                                (num_pages + 1,)).astype(jnp.int32)
    sel = cold.astype(bool)[:, None, None]
    ks = jax.random.split(jax.random.fold_in(key, 7))
    q_lat = jax.random.normal(ks[0], (b, h, lat))
    q_rope = jax.random.normal(ks[1], (b, h, rope))
    scale = 1.0 / float(48 + rope) ** 0.5
    assert_kernel_matches(
        lambda *a: paged_mla_decode_cold_pallas(*a, scale=scale),
        lambda *a: paged_mla_decode_ref(*a, scale=scale),
        (q_lat, q_rope, ckv_pool, kr_pool, cq, csc, rq, rsc, bt, sl, cold),
        ref_args=(q_lat, q_rope, jnp.where(sel, ckv_deq, ckv_pool),
                  jnp.where(sel, kr_deq, kr_pool), bt, sl),
        label=f"mla-cold lat={lat} page={page} p={p_cold}")


# ---------------------------------------------------------------- engine --

@pytest.mark.parametrize("arch", ["llama3.2-1b", "deepseek-v3-671b"])
@pytest.mark.parametrize("gate", ["1", "0"])
def test_engine_greedy_token_identity(arch, gate, key, monkeypatch):
    """Full ServingEngine.run greedy decode — through the paged kernels
    (gate=1, the default) and through the jnp reference branch (gate=0)
    — must be token-for-token identical to the static-cache oracle for
    both paging attention families (GQA and absorbed MLA). Same request
    mix as test_decode_consistency.py's prefix/chunking test."""
    from repro.config import get_config
    from repro.launch.serve import static_greedy_reference
    from repro.models.model import init_model
    from repro.serving import PagedCacheConfig, Request
    from repro.serving.engine import ServingEngine

    monkeypatch.setenv("SCT_PAGED_KERNEL", gate)
    cfg = get_config(arch, reduced=True).replace(dtype="float32",
                                                 capacity_factor=8.0)
    params = init_model(key, cfg)
    pcfg = PagedCacheConfig(page_size=4, num_pages=32, max_slots=2,
                            max_pages_per_seq=6)
    rng = np.random.default_rng(0)
    system = rng.integers(0, cfg.vocab, size=(9,)).astype(np.int32)
    reqs = [Request(rid=i,
                    prompt=np.concatenate(
                        [system,
                         rng.integers(0, cfg.vocab, size=(t,)).astype(np.int32)]),
                    max_new_tokens=g, arrival=a)
            for i, (t, g, a) in enumerate([(3, 4, 0), (2, 3, 2), (4, 4, 4)])]
    engine = ServingEngine(cfg, params, pcfg, prefill_token_budget=6,
                           prefix_cache=True, chunked_prefill=True)
    out = engine.run(reqs)
    for r in reqs:
        ref = static_greedy_reference(cfg, params, r.prompt, r.max_new_tokens,
                                      pcfg.max_seq)
        np.testing.assert_array_equal(
            out[r.rid], ref, err_msg=f"{arch} gate={gate} rid {r.rid}")
