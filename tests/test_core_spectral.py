"""Core SCT unit + property tests: spectral parameterization, truncated
SVD conversion, Eckart-Young optimality (hypothesis), storage math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container without hypothesis: deterministic shim
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    spectral_init,
    spectral_apply,
    spectral_param_count,
    dense_param_count,
    dense_to_spectral,
    spectral_to_dense,
    rank_for_energy,
    orthogonality_error,
)
from repro.core.convert import truncation_error
from repro.core.manifold import frobenius_tail


def test_spectral_init_on_manifold(key):
    p = spectral_init(key, 64, 96, 16)
    assert float(orthogonality_error(p["U"])) < 1e-5
    assert float(orthogonality_error(p["V"])) < 1e-5
    assert p["U"].shape == (64, 16) and p["V"].shape == (96, 16) and p["s"].shape == (16,)


def test_spectral_apply_matches_dense_materialization(key):
    p = spectral_init(key, 32, 48, 8)
    x = jax.random.normal(key, (5, 32))
    y = spectral_apply(p, x)
    W = spectral_to_dense(p)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ W), rtol=2e-5, atol=2e-5)


def test_full_rank_conversion_exact(key):
    W = jax.random.normal(key, (24, 40))
    p = dense_to_spectral(W, k=24)
    np.testing.assert_allclose(np.asarray(spectral_to_dense(p)), np.asarray(W),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(8, 48),
    n=st.integers(8, 48),
    seed=st.integers(0, 2**31 - 1),
    frac=st.floats(0.2, 0.9),
)
def test_eckart_young_optimality(m, n, seed, frac):
    """Truncation error of dense_to_spectral equals the optimal
    Frobenius tail sqrt(sum_{i>k} sigma_i^2) — the paper's rank
    truncation is exactly the optimal rank-k approximation."""
    k = max(1, int(frac * min(m, n)))
    W = jax.random.normal(jax.random.PRNGKey(seed), (m, n))
    p = dense_to_spectral(W, k)
    err = float(truncation_error(W, p))
    s = jnp.linalg.svd(W, compute_uv=False)
    opt = float(frobenius_tail(s, k))
    assert err <= opt * 1.001 + 1e-4
    assert err >= opt * 0.999 - 1e-4


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), energy=st.floats(0.5, 0.999))
def test_rank_for_energy_property(seed, energy):
    s = jnp.abs(jax.random.normal(jax.random.PRNGKey(seed), (32,))) + 1e-3
    k = rank_for_energy(s, energy)
    s2 = np.sort(np.asarray(s) ** 2)[::-1]
    cum = np.cumsum(s2) / np.sum(s2)
    assert cum[k - 1] >= energy - 1e-6
    if k > 1:
        assert cum[k - 2] < energy


def test_paper_table1_storage_counts():
    """Paper Table 1: k(m+n+1) vs 4mn with Adam (weights+grads+2 moments).
    LLaMA-70B MLP layer at k=32 must give the famous 199x."""
    rows = [
        (576, 1536, 13),      # SmolLM2-135M
        (1024, 4096, 26),     # SmolLM2-360M
        (2048, 8192, 51),     # SmolLM2-1.7B
        (4096, 11008, 93),    # LLaMA-7B
        (4096, 17408, 104),   # Qwen-27B
        (8192, 28672, 199),   # LLaMA-70B
    ]
    for m, n, expected in rows:
        ratio = dense_param_count(m, n) / spectral_param_count(m, n, 32)
        assert round(ratio) == expected, (m, n, ratio)


def test_spectral_apply_bf16_no_upcast(key):
    p = spectral_init(key, 32, 48, 8)
    x = jax.random.normal(key, (4, 32)).astype(jnp.bfloat16)
    assert spectral_apply(p, x).dtype == jnp.bfloat16


def test_convert_mlp_tree_selects_energy_ranks(key):
    """Tree-level conversion touches only /mlp/ dense leaves and picks
    ranks meeting the energy threshold (paper S4.4)."""
    from repro.core.convert import convert_mlp_tree_to_spectral

    tree = {
        "layers": {
            "mlp": {"up": {"w": jax.random.normal(key, (3, 32, 64))}},
            "attn": {"wq": {"w": jax.random.normal(key, (3, 32, 32))}},
        }
    }
    out, ranks = convert_mlp_tree_to_spectral(tree, energy=0.9)
    assert len(ranks) == 1 and 1 <= ranks[0] <= 32
    assert set(out["layers"]["mlp"]["up"].keys()) >= {"U", "s", "V"}
    assert "w" in out["layers"]["attn"]["wq"]  # attention untouched
