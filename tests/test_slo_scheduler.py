"""SLO scheduler contract (serving/scheduler.py:SLOScheduler): tenant
fair-share admission that provably cannot starve a tenant, priority
classes ordered *inside* the fair share (so a priority flood can't
starve anyone either), EDF within a class, deadline-aware shedding of
provably-doomed requests — plus randomized full-invariant fuzzing and
the engine-level token-identity check against the static greedy oracle
when no SLO pressure exists.
"""
import random as pyrandom

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container without hypothesis: deterministic shim
    from _hypothesis_fallback import given, settings, st

from repro.serving import PagedCacheConfig, Request, SLOScheduler
from test_serving import _finish_prefill
from test_serving_fuzz import _full_invariants

# ======================================================================
# host-side driver (no model): instant prefill, one decode token per
# engine step, deadline expiry against an explicit clock
# ======================================================================


def _drive_clocked(sched, pending, max_steps=2000):
    """Run the full scheduler protocol to drain; returns (admission
    order, drained seqs). ``pending`` must be arrival-sorted."""
    pending = list(pending)
    admitted, drained = [], []
    clock = 0
    while pending or sched.has_work:
        assert clock < max_steps, "scheduler wedged"
        while pending and pending[0].arrival <= clock:
            sched.submit(pending.pop(0))
        sched.expire_deadlines(clock)
        admitted += [s.request.rid for s in sched.admit()]
        for seq in sched.prefilling():
            _finish_prefill(sched, seq)
        sched.ensure_append_capacity()
        for slot, seq in list(sched.active.items()):
            if seq.status == "decoding":
                sched.on_token(slot, 1)
        sched.check_invariants()
        drained += sched.drain_finished()
        clock += 1
    return admitted, drained


def _pcfg(slots=1, page_size=4, num_pages=32, mpps=4):
    return PagedCacheConfig(page_size=page_size, num_pages=num_pages,
                            max_slots=slots, max_pages_per_seq=mpps)


def _req(rid, *, plen=4, gen=4, tenant="t0", priority=0, deadline=None,
         arrival=0):
    return Request(rid=rid, prompt=np.zeros(plen, np.int32),
                   max_new_tokens=gen, arrival=arrival, deadline=deadline,
                   tenant=tenant, priority=priority)


# ======================================================================
# fair share / starvation
# ======================================================================

def test_fair_share_interleaves_tenants_queued_back_to_back():
    """Tenant B's whole queue arrives behind tenant A's; FIFO would
    serve all of A first, fair share alternates from the second
    admission on (equal-size requests -> served-token counts tie-break
    exactly one apart)."""
    sched = SLOScheduler(_pcfg(slots=1))
    reqs = [_req(i, tenant="t0") for i in range(6)] + \
           [_req(i + 6, tenant="t1") for i in range(6)]
    order, drained = _drive_clocked(sched, reqs)
    assert len(drained) == 12
    tenants = ["t0" if r < 6 else "t1" for r in order]
    # B is admitted second, not eleventh — and the prefix counts never
    # diverge by more than one request either way
    assert tenants[1] == "t1"
    for k in range(1, len(tenants) + 1):
        a, b = tenants[:k].count("t0"), tenants[:k].count("t1")
        assert abs(a - b) <= 1, f"prefix {k}: {a} vs {b}"


def test_priority_flood_cannot_starve_another_tenant():
    """Priority ranks *below* tenant share: a tenant pushing all
    priority-0 traffic still alternates with a tenant pushing only
    priority-1 traffic (the no-starvation guarantee is unconditional,
    not just for equal priorities)."""
    sched = SLOScheduler(_pcfg(slots=1))
    reqs = [_req(i, tenant="t0", priority=0) for i in range(5)] + \
           [_req(i + 5, tenant="t1", priority=1) for i in range(5)]
    order, _ = _drive_clocked(sched, reqs)
    tenants = ["t0" if r < 5 else "t1" for r in order]
    for k in range(1, len(tenants) + 1):
        a, b = tenants[:k].count("t0"), tenants[:k].count("t1")
        assert abs(a - b) <= 1


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_tenants=st.integers(2, 4),
       per_tenant=st.integers(2, 6), slots=st.integers(1, 3))
def test_fair_share_bounded_skew_property(seed, n_tenants, per_tenant, slots):
    """For any submission interleaving of equal-size requests under
    sustained overload, no tenant ever falls more than one *round*
    behind any other in completed requests — the property form of
    no-starvation."""
    rng = pyrandom.Random(seed)
    sched = SLOScheduler(_pcfg(slots=slots, num_pages=64))
    reqs = [_req(t * per_tenant + i, tenant=f"t{t}")
            for t in range(n_tenants) for i in range(per_tenant)]
    rng.shuffle(reqs)
    order, drained = _drive_clocked(sched, reqs)
    assert len(drained) == n_tenants * per_tenant
    tenant_of = {r.rid: r.tenant for r in reqs}
    for k in range(1, len(order) + 1):
        seen = [tenant_of[r] for r in order[:k]]
        counts = [seen.count(f"t{t}") for t in range(n_tenants)]
        # a tenant can be ahead by at most the concurrent slots (ties
        # admitted the same step resolve by queue position)
        assert max(counts) - min(counts) <= slots + 1, \
            f"prefix {k}: {counts}"


# ======================================================================
# priority x deadline ordering
# ======================================================================

def test_priority_beats_deadline_within_tenant_edf_within_class():
    """Within one tenant's share: class 0 preempts class 1 even when
    the class-1 deadline is tighter; within a class, earliest absolute
    deadline first; ties fall back to queue order."""
    sched = SLOScheduler(_pcfg(slots=1), shed=False)
    blocker = _req(0, gen=3)                 # holds the slot first
    r_lo_tight = _req(1, priority=1, deadline=30, arrival=1)
    r_hi_loose = _req(2, priority=0, deadline=200, arrival=1)
    r_lo_tighter = _req(3, priority=1, deadline=20, arrival=1)
    r_lo_none = _req(4, priority=1, arrival=1)   # no deadline: after EDF peers
    order, drained = _drive_clocked(
        sched, [blocker, r_lo_tight, r_hi_loose, r_lo_tighter, r_lo_none])
    assert order == [0, 2, 3, 1, 4]
    assert all(s.status == "finished" for s in drained)


def test_deadline_tiebreak_is_fifo():
    sched = SLOScheduler(_pcfg(slots=1), shed=False)
    reqs = [_req(0, gen=2)] + \
        [_req(i, deadline=100, arrival=1) for i in (1, 2, 3)]
    order, _ = _drive_clocked(sched, reqs)
    assert order == [0, 1, 2, 3]


# ======================================================================
# deadline-aware shedding
# ======================================================================

def test_doomed_request_is_shed_not_served():
    """deadline < max_new_tokens can never finish in time: with
    shedding on it is refused at admission (status "shed", zero decode
    work); with shedding off it is admitted and burns its slot until
    the deadline evicts it (status "timeout")."""
    for shed, want in ((True, "shed"), (False, "timeout")):
        sched = SLOScheduler(_pcfg(slots=1), shed=shed)
        doomed = _req(0, gen=8, deadline=5)
        fine = _req(1, gen=4, deadline=100)
        _, drained = _drive_clocked(sched, [doomed, fine])
        by_rid = {s.request.rid: s for s in drained}
        assert by_rid[0].status == want
        assert by_rid[1].status == "finished"
        assert sched.shed_count == (1 if shed else 0)
        if shed:
            assert len(by_rid[0].generated) == 0    # no wasted decode


def test_request_doomed_by_queueing_is_shed_at_admission_time():
    """A request feasible at arrival but infeasible after waiting
    behind the queue is shed when its turn comes, freeing the slot for
    feasible work."""
    sched = SLOScheduler(_pcfg(slots=1))
    # blocker holds the only slot ~13 steps; victim needs 8 of its 10
    blocker = _req(0, plen=4, gen=12)
    victim = _req(1, gen=8, deadline=10, arrival=1)
    late = _req(2, gen=4, deadline=100, arrival=1)
    _, drained = _drive_clocked(sched, [blocker, victim, late])
    by_rid = {s.request.rid: s for s in drained}
    assert by_rid[0].status == "finished"
    assert by_rid[1].status == "shed"
    assert by_rid[2].status == "finished"


def test_served_token_accounting():
    """The fair-share ledger charges prompt + generated tokens to the
    owning tenant."""
    sched = SLOScheduler(_pcfg(slots=2))
    _drive_clocked(sched, [_req(0, plen=6, gen=4, tenant="a"),
                           _req(1, plen=3, gen=2, tenant="b")])
    assert sched.served_tokens == {"a": 10, "b": 5}
    stats = sched.stats()
    assert stats["tenant_a_tokens"] == 10 and stats["tenant_b_tokens"] == 5
    assert stats["shed"] == 0


# ======================================================================
# randomized full-invariant fuzz (the PR4 fuzz harness, SLO flavour)
# ======================================================================

@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), page_size=st.integers(2, 8),
       slots=st.integers(1, 6), pool_pages=st.integers(8, 40),
       shed=st.booleans())
def test_slo_random_schedule_invariants(seed, page_size, slots, pool_pages,
                                        shed):
    """Random tenant/priority/deadline mixes through the full protocol:
    page/slot/refcount invariants hold after every transition, the
    trace always drains, and every submitted rid surfaces exactly once
    with a legal terminal status."""
    rng = pyrandom.Random(seed)
    mpps = max(2, min(8, pool_pages // 2))
    pcfg = PagedCacheConfig(page_size=page_size, num_pages=pool_pages,
                            max_slots=slots, max_pages_per_seq=mpps)
    sched = SLOScheduler(pcfg, shed=shed)
    cap = mpps * page_size
    reqs = []
    for i in range(rng.randint(1, 16)):
        gen = rng.randint(1, cap - 1)
        plen = rng.randint(1, cap - gen)
        reqs.append(Request(
            rid=i, prompt=np.asarray([rng.randint(0, 96)
                                      for _ in range(plen)], np.int32),
            max_new_tokens=gen, arrival=rng.randint(0, 8),
            deadline=rng.randint(2, 60) if rng.random() < 0.5 else None,
            tenant=f"t{rng.randint(0, 2)}", priority=rng.randint(0, 2)))
    reqs = [r for r in reqs if pcfg.pages_for(r.max_total_len) <= pcfg.num_pages]
    pending = sorted(reqs, key=lambda r: r.arrival)

    drained = []
    clock = 0
    guard = 0
    while pending or sched.has_work:
        guard += 1
        assert guard < 5000, "scheduler wedged"
        while pending and pending[0].arrival <= clock:
            sched.submit(pending.pop(0))
        sched.expire_deadlines(clock)
        _full_invariants(sched, pcfg)
        sched.admit()
        _full_invariants(sched, pcfg)
        for seq in sched.prefilling():
            plen = seq.request.prompt_len
            c = rng.randint(1, max(1, plen - seq.prefill_pos))
            seq.prefill_pos = min(plen, seq.prefill_pos + c)
            if seq.prefill_pos == plen:
                sched.finish_prefill(seq.slot)
                sched.on_prefill_token(seq.slot, 1)
            _full_invariants(sched, pcfg)
        if rng.random() < 0.1 and sched.active:
            sched.cancel(rng.choice(
                [s.request.rid for s in sched.active.values()]))
            _full_invariants(sched, pcfg)
        decoding = [s for s in sched.active.values() if s.status == "decoding"]
        if decoding:
            sched.ensure_append_capacity()
            _full_invariants(sched, pcfg)
            for seq in list(decoding):
                if seq.slot not in sched.active:
                    continue
                sched.on_token(seq.slot, 1)
                _full_invariants(sched, pcfg)
        drained += sched.drain_finished()
        clock += 1

    assert sched.pool.allocated_count == 0
    assert not sched.active and len(sched._free_slots) == slots
    assert sorted(s.request.rid for s in drained) == \
        sorted(r.rid for r in reqs)
    legal = {"finished", "timeout", "cancelled", "shed"}
    assert all(s.status in legal for s in drained)
    shed_n = sum(1 for s in drained if s.status == "shed")
    assert shed_n == sched.shed_count
    if not shed:
        assert shed_n == 0
    # shed requests never received decode work
    for s in drained:
        if s.status == "shed":
            assert len(s.generated) == 0


# ======================================================================
# engine level: SLO scheduling must not change what gets generated
# ======================================================================

def test_engine_slo_outputs_token_identical_to_oracle(key):
    """With no deadline pressure the SLO scheduler may reorder
    admissions but every request's tokens must match the static greedy
    oracle exactly — scheduling is not allowed to touch the math."""
    from repro.api import ModelSpec, RunSpec, ServeSpec, Server
    from repro.launch.serve import static_greedy_reference

    spec = RunSpec(
        model=ModelSpec("smollm2-135m", reduced=True),
        serve=ServeSpec(slots=2, page_size=4, num_pages=24, pages_per_seq=4,
                        prefill_budget=16, gen=4, scheduler="slo"))
    server = Server(spec)
    cfg = server.cfg
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (3, 7, 5, 9, 4, 6)]
    for i, p in enumerate(prompts):
        server.submit(p, tenant=f"t{i % 3}", priority=i % 2)
    out = server.run()
    assert all(v == "finished" for v in server.last_statuses.values())
    for i, p in enumerate(prompts):
        ref = static_greedy_reference(cfg, server.params, p,
                                      spec.serve.gen,
                                      spec.serve.paged_config().max_seq)
        assert np.array_equal(out[i], ref), f"request {i} diverged"
    st_ = server.stats()
    assert st_["shed"] == 0 and st_["peak_pages"] > 0
    assert sum(1 for k in st_ if k.startswith("tenant_")) == 3
