"""The traffic harness: workload generator determinism, the
BENCH_<area>.json schema contract, and an end-to-end tiny bench run.

The load generator's central promise is body/arrival separation — the
*same* requests are offered at every overload factor, only their
arrival stamps change — because that is what makes FIFO-vs-SLO goodput
at 2x a controlled comparison rather than two different workloads.
These tests pin that promise, the arrival processes' shapes, the
geometry clipping that keeps every request admissible, and the schema
validator both ways (accepts the emitter's output, rejects drift).
"""
import json
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.api.specs import BenchSpec, ModelSpec, SLOSpec, WorkloadSpec
from repro.bench import (
    bench_envelope,
    generate_requests,
    run_bench,
    validate_bench,
    write_bench,
)
from repro.bench.schema import ARM_METRIC_KEYS, SCHEMA_VERSION

REPO_ROOT = Path(__file__).resolve().parent.parent

VOCAB = 256
MAX_TOTAL = 128


def _bodies(reqs):
    """Everything about a request except its arrival stamp."""
    return [(r.rid, r.prompt.tolist(), r.max_new_tokens, r.deadline,
             r.tenant, r.priority) for r in reqs]


# ------------------------------------------------------------ workload --

def test_bodies_identical_across_overload_factors():
    wl = WorkloadSpec(requests=24, tenants="2,1", priority_mix="3,1",
                      shared_prefix=8, seed=7)
    slo = SLOSpec(deadlines="0=20,1=40")
    one = generate_requests(wl, slo, vocab=VOCAB, max_total=MAX_TOTAL,
                            overload=1.0)
    two = generate_requests(wl, slo, vocab=VOCAB, max_total=MAX_TOTAL,
                            overload=2.0)
    assert _bodies(one) == _bodies(two)
    # ... and the trace itself is reproducible end to end
    again = generate_requests(wl, slo, vocab=VOCAB, max_total=MAX_TOTAL,
                              overload=1.0)
    assert _bodies(one) == _bodies(again)
    assert [r.arrival for r in one] == [r.arrival for r in again]


def test_overload_compresses_arrivals():
    wl = WorkloadSpec(arrival="fixed", rate=0.25, requests=16)
    one = generate_requests(wl, vocab=VOCAB, max_total=MAX_TOTAL,
                            overload=1.0)
    four = generate_requests(wl, vocab=VOCAB, max_total=MAX_TOTAL,
                             overload=4.0)
    assert one[-1].arrival == 4 * four[-1].arrival
    for reqs in (one, four):
        arr = [r.arrival for r in reqs]
        assert arr == sorted(arr)
        assert arr[0] == 0


def test_onoff_arrivals_respect_silent_windows():
    wl = WorkloadSpec(arrival="onoff", rate=2.0, requests=64,
                      on_steps=4, off_steps=4, seed=3)
    reqs = generate_requests(wl, vocab=VOCAB, max_total=MAX_TOTAL)
    period = wl.on_steps + wl.off_steps
    assert all(r.arrival % period < wl.on_steps for r in reqs)
    # poisson at the same rate does land arrivals inside those windows
    wl_p = wl.replace(arrival="poisson")
    reqs_p = generate_requests(wl_p, vocab=VOCAB, max_total=MAX_TOTAL)
    assert any(r.arrival % period >= wl.on_steps for r in reqs_p)


def test_geometry_clipping_and_shared_prefixes():
    wl = WorkloadSpec(requests=32, tenants="1,1", shared_prefix=16,
                      prompt_mean=200, prompt_cv=2.0, gen_mean=200,
                      gen_cv=2.0, seed=11)
    reqs = generate_requests(wl, vocab=VOCAB, max_total=MAX_TOTAL)
    prefixes = {}
    for r in reqs:
        assert r.prompt_len + r.max_new_tokens <= MAX_TOTAL
        assert r.prompt_len > wl.shared_prefix      # prefix + >=1 tail token
        assert r.max_new_tokens >= 1
        head = r.prompt[:wl.shared_prefix].tolist()
        prefixes.setdefault(r.tenant, head)
        # one stable system prompt per tenant, distinct across tenants
        assert prefixes[r.tenant] == head
    assert len(prefixes) == 2
    assert prefixes["t0"] != prefixes["t1"]


def test_deadlines_follow_priority_classes():
    wl = WorkloadSpec(requests=48, priority_mix="1,1", seed=5)
    slo = SLOSpec(deadlines="0=10,1=99")
    reqs = generate_requests(wl, slo, vocab=VOCAB, max_total=MAX_TOTAL)
    seen = {r.priority for r in reqs}
    assert seen == {0, 1}
    for r in reqs:
        assert r.deadline == {0: 10, 1: 99}[r.priority]
    # no SLOSpec -> unbounded requests
    assert all(r.deadline is None
               for r in generate_requests(wl, vocab=VOCAB,
                                          max_total=MAX_TOTAL))


def test_geometry_too_small_for_prefix_rejected():
    wl = WorkloadSpec(shared_prefix=30)
    with pytest.raises(ValueError, match="shared_prefix"):
        generate_requests(wl, vocab=VOCAB, max_total=31)


# -------------------------------------------------------------- schema --

def _valid_arm():
    return {"overload": 1.0, "scheduler": "fifo",
            "metrics": {k: 0.0 for k in ARM_METRIC_KEYS}}


def test_envelope_builder_emits_valid_doc():
    doc = bench_envelope("serving", BenchSpec().to_dict(), [_valid_arm()])
    assert validate_bench(doc) == []
    # round-trips through the committed-file formatting
    assert validate_bench(json.loads(json.dumps(doc))) == []


def test_validator_collects_all_drift():
    arm = _valid_arm()
    del arm["metrics"]["goodput_tokens_per_s"]
    arm["metrics"]["tokens_per_s"] = "fast"
    doc = {"schema_version": 99, "area": "", "spec": [],
           "results": [arm]}
    errs = validate_bench(doc)
    assert any("schema_version" in e for e in errs)
    assert any("area" in e for e in errs)
    assert any("spec" in e for e in errs)
    assert any("goodput_tokens_per_s" in e for e in errs)
    assert any("tokens_per_s" in e for e in errs)


def test_envelope_without_measurements_rejected():
    with pytest.raises(ValueError, match="results / entries"):
        bench_envelope("serving", {}, [])
    # table-style envelopes may carry entries instead of arms
    doc = bench_envelope("table3", {}, [], entries=[{"kind": "config"}])
    assert validate_bench(doc) == []


def test_null_percentiles_are_legal():
    # an arm where nothing completed reports null percentiles, not NaN
    arm = _valid_arm()
    arm["metrics"]["ttft_p50_steps"] = None
    arm["metrics"]["itl_p99_s"] = None
    assert validate_bench(
        bench_envelope("serving", {}, [_valid_arm(), arm])) == []


# ------------------------------------------------------- end to end --

def _tiny_bench():
    return BenchSpec(
        model=ModelSpec("smollm2-135m", reduced=True),
        workload=WorkloadSpec(requests=6, prompt_mean=8, gen_mean=4,
                              rate=0.5, tenants="1,1"),
        slo=SLOSpec(deadlines="24"),
        overloads="1,2",
        schedulers="fifo,slo",
    )


def test_run_bench_tiny_envelope_validates(tmp_path):
    doc = run_bench(_tiny_bench())
    assert validate_bench(doc) == []
    assert len(doc["results"]) == 4                # 2 overloads x 2 arms
    assert "throughput" not in doc                 # single fp32 variant
    for arm in doc["results"]:
        m = arm["metrics"]
        assert m["requests"] == 6.0
        assert m["completed"] + m["timed_out"] + m["shed"] == 6.0
        # tenant fair-share accounting rides along on the slo arms
        if arm["scheduler"] == "slo":
            assert "tenant_t0_tokens" in m or "tenant_t1_tokens" in m
    out = tmp_path / "BENCH_tiny.json"
    write_bench(doc, str(out))
    assert validate_bench(json.loads(out.read_text())) == []
    assert out.read_text().endswith("\n")


# ------------------------------------------------------- dispatcher --

def test_bench_dispatcher_dump_spec_round_trips():
    proc = subprocess.run(
        [sys.executable, "-m", "repro", "bench", "serving", "--dump-spec",
         "--overloads", "1,3", "--schedulers", "slo",
         "--tenants", "2,1", "--rate", "0.125"],
        capture_output=True, text=True, cwd=str(REPO_ROOT),
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"},
    )
    assert proc.returncode == 0, proc.stderr
    spec = BenchSpec.from_json(proc.stdout)
    assert spec.overload_factors() == [1.0, 3.0]
    assert spec.scheduler_arms() == ["slo"]
    assert spec.workload.tenants == "2,1"
    assert spec.workload.rate == 0.125
    # the committed BENCH_serving.json stays schema-valid in-tree
    committed = REPO_ROOT / "BENCH_serving.json"
    if committed.exists():
        assert validate_bench(json.loads(committed.read_text())) == []


def test_committed_bench_matches_dispatcher_defaults():
    """BENCH_serving.json must be regenerable: its embedded spec equals
    the dispatcher's default spec, so `python -m repro bench serving`
    reproduces the committed numbers (same seed, same trace)."""
    committed = REPO_ROOT / "BENCH_serving.json"
    if not committed.exists():
        pytest.skip("no committed BENCH_serving.json")
    doc = json.loads(committed.read_text())
    spec = BenchSpec.from_dict(doc["spec"])
    proc = subprocess.run(
        [sys.executable, "-m", "repro", "bench", "serving", "--dump-spec"],
        capture_output=True, text=True, cwd=str(REPO_ROOT),
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"},
    )
    assert proc.returncode == 0, proc.stderr
    assert BenchSpec.from_json(proc.stdout) == spec


def test_committed_streaming_bench_matches_dispatcher_defaults():
    """BENCH_streaming.json must be regenerable the same way: embedded
    spec equals `python -m repro bench streaming`'s defaults, and the
    committed file stays schema-valid in-tree."""
    committed = REPO_ROOT / "BENCH_streaming.json"
    if not committed.exists():
        pytest.skip("no committed BENCH_streaming.json")
    doc = json.loads(committed.read_text())
    assert validate_bench(doc) == []
    spec = BenchSpec.from_dict(doc["spec"])
    assert spec.serve.streaming.enabled
    proc = subprocess.run(
        [sys.executable, "-m", "repro", "bench", "streaming", "--dump-spec"],
        capture_output=True, text=True, cwd=str(REPO_ROOT),
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"},
    )
    assert proc.returncode == 0, proc.stderr
    assert BenchSpec.from_json(proc.stdout) == spec


def test_workload_draws_cover_weighted_classes():
    wl = WorkloadSpec(requests=64, tenants="1,1,1", priority_mix="1,1",
                      seed=2)
    reqs = generate_requests(wl, vocab=VOCAB, max_total=MAX_TOTAL)
    assert {r.tenant for r in reqs} == {"t0", "t1", "t2"}
    counts = np.bincount([r.priority for r in reqs], minlength=2)
    assert counts.min() > 0


# ------------------------------------------------- speculative bench --

def test_run_speculative_bench_tiny():
    """Baseline/speculative arms over one tiny workload: schema-valid
    envelope, acceptance counters on the speculative arm only, and the
    cross-arm token-identity gate recorded as outputs_match."""
    from repro.api.specs import ServeSpec
    from repro.bench import run_speculative_bench

    bench = BenchSpec(
        name="speculative",
        model=ModelSpec("llama3.2-1b", reduced=True),
        workload=WorkloadSpec(requests=4, rate=1.0, prompt_mean=6,
                              prompt_cv=0.5, gen_mean=5, gen_cv=0.0, seed=0),
        serve=ServeSpec(slots=2, page_size=8, num_pages=32, pages_per_seq=4,
                        speculative_rank="8", draft_tokens=3),
        overloads="1", schedulers="fifo",
    )
    doc = run_speculative_bench(bench)
    assert validate_bench(doc) == []
    assert [a["variant"] for a in doc["results"]] == \
        ["baseline", "speculative"]
    base_m, spec_m = (a["metrics"] for a in doc["results"])
    assert base_m["tokens_per_step"] > 0
    assert "acceptance_rate" not in base_m
    assert spec_m["outputs_match"] == 1.0
    assert 0.0 <= spec_m["acceptance_rate"] <= 1.0
    assert spec_m["draft_accepted"] <= spec_m["draft_proposed"]
    assert spec_m["ladder_levels"] == 1.0
    with pytest.raises(ValueError, match="speculative_rank"):
        run_speculative_bench(bench.replace(
            serve=bench.serve.replace(speculative_rank=None)))


# ------------------------------------------------- check_bench --diff --

def _load_check_bench():
    import importlib.util

    path = REPO_ROOT / "tools" / "check_bench.py"
    spec = importlib.util.spec_from_file_location("check_bench", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_check_bench_diff_deterministic_columns_only():
    """The staleness gate: identical envelopes pass, a moved
    engine-step-clock column fails, wall-clock drift is ignored, and
    one-sided arms are reported by name."""
    import copy

    cb = _load_check_bench()
    arm = {"overload": 1.0, "scheduler": "fifo", "variant": "baseline",
           "metrics": {k: 1.0 for k in ARM_METRIC_KEYS}}
    doc = bench_envelope("speculative", {"seed": 0}, [arm])
    assert cb.diff_envelopes(doc, doc) == []

    moved = copy.deepcopy(doc)
    moved["results"][0]["metrics"]["peak_pages"] = 7.0
    assert any("peak_pages" in e for e in cb.diff_envelopes(moved, doc))

    wall = copy.deepcopy(doc)
    wall["results"][0]["metrics"]["wall_s"] = 99.0
    wall["results"][0]["metrics"]["tokens_per_s"] = 0.125
    assert cb.diff_envelopes(wall, doc) == []    # machine-dependent: ignored

    extra = copy.deepcopy(doc)
    extra["results"].append(
        {**copy.deepcopy(arm), "variant": "speculative"})
    assert any("regenerated file only" in e
               for e in cb.diff_envelopes(extra, doc))
    assert any("committed file only" in e
               for e in cb.diff_envelopes(doc, extra))

    other = copy.deepcopy(doc)
    other["area"] = "serving"
    assert any("area" in e for e in cb.diff_envelopes(other, doc))


def test_check_bench_diff_entries_by_name():
    """Entries rows (BENCH_kernels.json style) are matched by name and
    their 'deterministic' sub-objects compared exactly; wall-clock
    us_per_call outside it is ignored."""
    import copy

    cb = _load_check_bench()
    rows = [{"name": "spectral_q8", "us_per_call": 10.0,
             "deterministic": {"flops": 100, "bound": "memory"}},
            {"name": "paged_gqa_decode",
             "deterministic": {"flops": 7}}]
    doc = bench_envelope("kernels", {"seed": 0}, [], entries=rows)
    assert cb.diff_envelopes(doc, doc) == []

    wall = copy.deepcopy(doc)
    wall["entries"][0]["us_per_call"] = 9999.0
    assert cb.diff_envelopes(wall, doc) == []     # machine-dependent

    moved = copy.deepcopy(doc)
    moved["entries"][0]["deterministic"]["flops"] = 101
    errs = cb.diff_envelopes(moved, doc)
    assert any("spectral_q8" in e and "flops" in e for e in errs)

    missing = copy.deepcopy(doc)
    del missing["entries"][1]
    assert any("committed file only" in e
               for e in cb.diff_envelopes(missing, doc))


def test_envelope_entries_with_deterministic_require_name():
    """Schema: a deterministic row without a name is unaddressable by
    the diff and must be rejected at emit time."""
    bad = {"schema_version": SCHEMA_VERSION, "area": "kernels",
           "spec": {}, "results": [],
           "entries": [{"deterministic": {"flops": 1}}]}
    assert any("name" in e for e in validate_bench(bad))
    bad["entries"] = [{"name": "x", "deterministic": "not-a-dict"}]
    assert any("deterministic" in e for e in validate_bench(bad))
    good = dict(bad, entries=[{"name": "x", "deterministic": {"flops": 1}}])
    assert validate_bench(good) == []
